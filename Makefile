# Build entrypoints (see README.md).
#
# `artifacts` needs the python env (jax) once; everything else is
# rust-only.  Tier-1 verify: `make build test`.  Lint gate: `make lint`.

.PHONY: artifacts build test bench bench-sched bench-trace bench-mem bench-robust bench-async bench-transport bench-netfault lint clean

# AOT-lower the HLO artifacts + params.bin the runtime executes.
# Output lands in rust/artifacts/<config>/ (cargo's working directory
# is rust/, so Engine::load(Path::new("artifacts"), ...) finds it).
artifacts:
	cd python && python3 -m compile.aot --config mini,small --outdir ../rust/artifacts

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

# Hot-path micro-benches; writes rust/BENCH_hotpath.json (name → median
# ns) next to the grep-able `bench ...` lines (EXPERIMENTS.md §Perf).
bench:
	cd rust && cargo bench --bench hotpath

# Fleet-scale scheduler sweep; writes rust/BENCH_sched.json (makespan +
# order wall-clock per policy at N up to 100k — EXPERIMENTS.md
# §Scheduling).  CI runs the same bench capped via SCHED_SCALE_MAX_N.
bench-sched:
	cd rust && cargo bench --bench sched_scale

# Non-stationary scheduling regret sweep; writes rust/BENCH_trace.json
# (cumulative policy regret vs the clairvoyant oracle per trace kind —
# EXPERIMENTS.md §Traces).  CI runs the same bench with TRACE_SMOKE=1.
bench-trace:
	cd rust && cargo bench --bench trace_regret

# Pooled-vs-eager memory sweep; writes rust/BENCH_memory.json (peak
# resident state bytes + round wall-clock at N up to 10k, pool hit /
# evict counters — EXPERIMENTS.md §Memory).  CI runs the same bench
# with MEM_SMOKE=1 (caps the sweep at N = 1000).
bench-mem:
	cd rust && cargo bench --bench mem_scale

# Attack × fraction × aggregator robustness sweep; writes
# rust/BENCH_robust.json (recovered quality per defense —
# EXPERIMENTS.md §Robustness).  CI runs the same bench with
# ROBUST_SMOKE=1 (caps the sweep at the 20%-attacker gate column).
bench-robust:
	cd rust && cargo bench --bench robust

# Async-vs-sync pacing sweep on the event-engine testbed; writes
# rust/BENCH_async.json (time-to-target + speedup per trace × τ × K —
# EXPERIMENTS.md §Async).  CI runs the same bench with ASYNC_SMOKE=1
# (markov trace at the default merge settings only).
bench-async:
	cd rust && cargo bench --bench async_churn

# Compression frontier sweep (top-k fraction × quantization × error
# feedback); writes rust/BENCH_transport.json (uplink reduction +
# quality delta per config — EXPERIMENTS.md §Transport).  CI runs the
# same bench with TRANSPORT_SMOKE=1 (gate config only).
bench-transport:
	cd rust && cargo bench --bench transport

# Network-fault sweep (loss rate × retry budget on the lossy-channel
# testbed); writes rust/BENCH_netfault.json (recovered quality + retry
# counters — EXPERIMENTS.md §Network faults).  CI runs the same bench
# with NETFAULT_SMOKE=1 (gate configs only).
bench-netfault:
	cd rust && cargo bench --bench netfault

# Format + clippy + sflint gate (CI tier-1 companion).  sflint is the
# in-tree invariant analyzer (rust/lint/README.md): nonzero exit on any
# finding not grandfathered in rust/lint/baseline.jsonl.
lint:
	cd rust && cargo fmt --check \
	        && cargo clippy --all-targets -- -D warnings -D clippy::dbg_macro \
	        && cargo run --release --bin sflint -- --json sflint-findings.jsonl

clean:
	cd rust && cargo clean
	rm -f rust/BENCH_hotpath.json rust/BENCH_sched.json rust/BENCH_trace.json \
	      rust/BENCH_memory.json rust/BENCH_robust.json rust/BENCH_async.json \
	      rust/BENCH_transport.json rust/BENCH_netfault.json rust/sflint-findings.jsonl
