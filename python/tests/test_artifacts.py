"""Artifact-level tests: the flat wrappers compute the same thing as the
dict-based model functions, and the AOT lowering emits loadable HLO."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import artifacts as art
from compile import model, packing
from compile.configs import MINI as cfg


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(11)
    kf, kl, kh, kd = jax.random.split(key, 4)
    frozen = model.init_frozen(cfg, kf)
    lora = model.init_lora(cfg, kl, cfg.layers)
    head = model.init_head(cfg, kh)
    tokens = jax.random.randint(kd, (cfg.batch, cfg.seq), 0, cfg.vocab, dtype=jnp.int32)
    labels = jax.random.randint(kd, (cfg.batch,), 0, cfg.classes, dtype=jnp.int32)
    return frozen, lora, head, tokens, labels


def _flat_frozen(frozen):
    return packing.flatten_frozen(frozen)


def test_client_fwd_wrapper_matches_model(setup):
    frozen, lora, _, tokens, _ = setup
    k = 2
    clora = {kk: v[:k] for kk, v in lora.items()}
    fn, inputs, outputs = art.build_client_fwd(cfg, k)
    assert len(inputs) == 1 + packing.N_FROZEN + packing.N_LORA
    got = fn(tokens, *_flat_frozen(frozen), *packing.flatten_lora(clora))
    want = model.client_forward(cfg, k, tokens, frozen, clora)
    assert_allclose(np.asarray(got[0]), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_server_step_wrapper_matches_model(setup):
    frozen, lora, head, tokens, labels = setup
    k = 1
    clora = {kk: v[:k] for kk, v in lora.items()}
    slora = {kk: v[k:] for kk, v in lora.items()}
    acts = model.client_forward(cfg, k, tokens, frozen, clora)
    zeros_t = [np.zeros(s, np.float32)
               for _, s in packing.lora_spec(cfg, cfg.layers - k) + packing.head_spec(cfg)]
    fn, inputs, outputs = art.build_server_step(cfg, k)
    flat = [acts, labels] + _flat_frozen(frozen) \
        + packing.flatten_lora(slora) + packing.flatten_head(head) \
        + zeros_t + zeros_t + [jnp.float32(1.0), jnp.float32(1e-3)]
    assert len(flat) == len(inputs)
    out = fn(*flat)
    assert len(out) == len(outputs)
    t0 = {"lora": slora, "head": head}
    z = jax.tree.map(jnp.zeros_like, t0)
    loss, dacts, *_ = model.server_step(
        cfg, k, acts, labels, frozen, slora, head, z, z,
        jnp.float32(1.0), jnp.float32(1e-3),
    )
    assert abs(float(out[0]) - float(loss)) < 1e-6
    assert_allclose(np.asarray(out[1]), np.asarray(dacts), rtol=1e-5, atol=1e-7)


def test_client_bwd_wrapper_matches_model(setup):
    frozen, lora, _, tokens, _ = setup
    k = 3
    clora = {kk: v[:k] for kk, v in lora.items()}
    act_grads = jnp.ones((cfg.batch, cfg.seq, cfg.hidden), jnp.float32) * 0.01
    zl = [np.zeros(s, np.float32) for _, s in packing.lora_spec(cfg, k)]
    fn, inputs, outputs = art.build_client_bwd(cfg, k)
    flat = [tokens] + _flat_frozen(frozen) + packing.flatten_lora(clora) \
        + [act_grads] + zl + zl + [jnp.float32(1.0), jnp.float32(1e-3)]
    assert len(flat) == len(inputs)
    out = fn(*flat)
    z = jax.tree.map(jnp.zeros_like, clora)
    new_lora, _, _ = model.client_backward(
        cfg, k, tokens, frozen, clora, act_grads, z, z,
        jnp.float32(1.0), jnp.float32(1e-3),
    )
    for i, kk in enumerate(packing.LORA_KEYS):
        assert_allclose(np.asarray(out[i]), np.asarray(new_lora[kk]),
                        rtol=1e-5, atol=1e-7)


def test_all_artifacts_specs_are_wellformed():
    arts = art.all_artifacts(cfg)
    expected = {f"{p}_{k}" for k in cfg.cuts
                for p in ("client_fwd", "server_step", "client_bwd")}
    expected |= {"eval", "full_step"}
    assert set(arts) == expected
    for name, (fn, inputs, outputs) in arts.items():
        names = [e["name"] for e in inputs]
        assert len(names) == len(set(names)), f"duplicate input names in {name}"
        for e in inputs + outputs:
            assert e["dtype"] in ("f32", "i32")
            assert all(isinstance(d, int) and d > 0 for d in e["shape"]) or e["shape"] == []


def test_lowering_one_artifact_produces_hlo_text(setup):
    """End-of-pipe check: the smallest artifact lowers to HLO text that
    contains an ENTRY computation (what the rust loader parses)."""
    from compile.aot import to_hlo_text
    fn, inputs, _ = art.build_client_fwd(cfg, 1)
    lowered = jax.jit(fn).lower(*art.shape_structs(inputs))
    text = to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    assert len(text) > 1000


def test_example_args_match_spec():
    fn, inputs, _ = art.build_eval(cfg)
    args = art.example_args(inputs)
    assert len(args) == len(inputs)
    for a, e in zip(args, inputs):
        assert list(a.shape) == e["shape"]
        assert (a.dtype == np.int32) == (e["dtype"] == "i32")
