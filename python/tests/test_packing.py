"""Packing/interchange invariants: the flat I/O convention and the
params.bin binary format that rust consumes."""

import os
import tempfile

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import packing
from compile.configs import CONFIGS, MINI as cfg


def test_spec_counts():
    assert packing.N_FROZEN == 20
    assert packing.N_LORA == 4
    assert packing.N_HEAD == 2


@pytest.mark.parametrize("name", list(CONFIGS))
def test_frozen_spec_shapes_consistent(name):
    c = CONFIGS[name]
    spec = packing.frozen_spec(c)
    assert len(spec) == packing.N_FROZEN
    by_name = dict(spec)
    assert by_name["tok_emb"] == (c.vocab, c.hidden)
    assert by_name["wq"] == (c.layers, c.hidden, c.hidden)
    assert by_name["w1"] == (c.layers, c.hidden, c.ffn)
    assert by_name["w2"] == (c.layers, c.ffn, c.hidden)


def test_flatten_unflatten_frozen_roundtrip():
    rng = np.random.default_rng(0)
    frozen = {
        **{k: rng.normal(size=s).astype(np.float32)
           for k, s in packing.emb_shapes(cfg).items()},
        "stacks": {k: rng.normal(size=s).astype(np.float32)
                   for k, s in packing.stack_shapes(cfg).items()},
    }
    flat = packing.flatten_frozen(frozen)
    back = packing.unflatten_frozen(flat)
    for k in packing.EMB_KEYS:
        assert back[k] is frozen[k]
    for k in packing.STACK_KEYS:
        assert back["stacks"][k] is frozen["stacks"][k]


def test_lora_spec_scales_with_layers():
    s1 = dict(packing.lora_spec(cfg, 1))
    s3 = dict(packing.lora_spec(cfg, 3))
    assert s1["lora.aq"][0] == 1 and s3["lora.aq"][0] == 3


def test_adam_spec_mirrors_trainables():
    t = packing.lora_spec(cfg, 2) + packing.head_spec(cfg)
    a = packing.adam_spec(t)
    assert len(a) == 2 * len(t)
    assert a[0][0].startswith("adam_m.") and a[len(t)][0].startswith("adam_v.")
    assert a[0][1] == t[0][1]


def test_params_bin_roundtrip():
    rng = np.random.default_rng(1)
    tensors = [
        ("alpha", rng.normal(size=(3, 4)).astype(np.float32)),
        ("beta", np.arange(6, dtype=np.int32).reshape(2, 3)),
        ("scalarish", np.asarray([1.5], np.float32)),
    ]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "p.bin")
        packing.write_params_bin(path, tensors)
        back = packing.read_params_bin(path)
    assert [n for n, _ in back] == [n for n, _ in tensors]
    for (_, a), (_, b) in zip(tensors, back):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert_allclose(a, b)


def test_params_bin_rejects_bad_dtype():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError):
            packing.write_params_bin(
                os.path.join(d, "p.bin"), [("x", np.zeros(2, np.float64))]
            )
