"""L2 correctness: model shapes, gradients, and — the core SFL property —
split-step == monolithic-step for every cut point."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model, packing
from compile.configs import MINI as cfg


@pytest.fixture(scope="module")
def params():
    key = jax.random.PRNGKey(7)
    kf, kl, kh, kd = jax.random.split(key, 4)
    frozen = model.init_frozen(cfg, kf)
    lora = model.init_lora(cfg, kl, cfg.layers)
    head = model.init_head(cfg, kh)
    tokens = jax.random.randint(kd, (cfg.batch, cfg.seq), 0, cfg.vocab, dtype=jnp.int32)
    labels = jax.random.randint(kd, (cfg.batch,), 0, cfg.classes, dtype=jnp.int32)
    return frozen, lora, head, tokens, labels


def _zeros_like(t):
    return jax.tree.map(jnp.zeros_like, t)


def _split_lora(lora, k):
    return (
        {kk: v[:k] for kk, v in lora.items()},
        {kk: v[k:] for kk, v in lora.items()},
    )


def test_embed_shape(params):
    frozen, _, _, tokens, _ = params
    x = model.embed(cfg, frozen, tokens)
    assert x.shape == (cfg.batch, cfg.seq, cfg.hidden)


def test_client_forward_shapes_all_cuts(params):
    frozen, lora, _, tokens, _ = params
    for k in cfg.cuts:
        clora, _ = _split_lora(lora, k)
        acts = model.client_forward(cfg, k, tokens, frozen, clora)
        assert acts.shape == (cfg.batch, cfg.seq, cfg.hidden)
        assert np.isfinite(np.asarray(acts)).all()


def test_eval_batch_logits(params):
    frozen, lora, head, tokens, labels = params
    logits, loss = model.eval_batch(cfg, tokens, labels, frozen, lora, head)
    assert logits.shape == (cfg.batch, cfg.classes)
    assert float(loss) > 0
    # B=0 LoRA init: logits must equal the frozen model's logits exactly.


def test_lora_init_is_noop_on_function(params):
    """With B=0, LoRA adapters must not change the forward function."""
    frozen, lora, head, tokens, labels = params
    logits1, _ = model.eval_batch(cfg, tokens, labels, frozen, lora, head)
    zero_lora = _zeros_like(lora)
    logits2, _ = model.eval_batch(cfg, tokens, labels, frozen, zero_lora, head)
    assert_allclose(np.asarray(logits1), np.asarray(logits2), atol=1e-5)


@pytest.mark.parametrize("k", cfg.cuts)
def test_split_step_equals_full_step(params, k):
    """client_forward ∘ server_step ∘ client_backward must produce exactly
    the same updated adapters as the monolithic full_step — the defining
    correctness property of the split protocol (paper Alg. 1 vs eq. 2)."""
    frozen, lora, head, tokens, labels = params
    clora, slora = _split_lora(lora, k)
    step, lr = jnp.float32(1.0), jnp.float32(1e-3)

    acts = model.client_forward(cfg, k, tokens, frozen, clora)
    t0 = {"lora": slora, "head": head}
    loss, dacts, nslora, nhead, _, _ = model.server_step(
        cfg, k, acts, labels, frozen, slora, head,
        _zeros_like(t0), _zeros_like(t0), step, lr,
    )
    nclora, _, _ = model.client_backward(
        cfg, k, tokens, frozen, clora, dacts,
        _zeros_like(clora), _zeros_like(clora), step, lr,
    )

    full_t = {"lora": lora, "head": head}
    floss, flora, fhead, _, _ = model.full_step(
        cfg, tokens, labels, frozen, lora, head,
        _zeros_like(full_t), _zeros_like(full_t), step, lr,
    )

    assert abs(float(loss) - float(floss)) < 1e-5
    for kk in packing.LORA_KEYS:
        merged = np.concatenate([np.asarray(nclora[kk]), np.asarray(nslora[kk])], axis=0)
        assert_allclose(merged, np.asarray(flora[kk]), rtol=1e-4, atol=1e-6)
    for kk in packing.HEAD_KEYS:
        assert_allclose(np.asarray(nhead[kk]), np.asarray(fhead[kk]), rtol=1e-4, atol=1e-6)


def test_training_reduces_loss(params):
    """A few full steps on one fixed batch must reduce the loss — the
    minimal 'learning actually happens' check."""
    frozen, lora, head, tokens, labels = params
    t = {"lora": lora, "head": head}
    mom, vel = _zeros_like(t), _zeros_like(t)
    lr = jnp.float32(5e-3)
    losses = []
    cur_lora, cur_head = lora, head
    for i in range(8):
        loss, cur_lora, cur_head, mom, vel = model.full_step(
            cfg, tokens, labels, frozen, cur_lora, cur_head, mom, vel,
            jnp.float32(i + 1), lr,
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_adam_update_moves_params_and_state():
    p = {"a": jnp.ones((4,), jnp.float32)}
    g = {"a": jnp.full((4,), 0.5, jnp.float32)}
    z = {"a": jnp.zeros((4,), jnp.float32)}
    p2, m2, v2 = model.adam_update(p, g, z, z, jnp.float32(1.0), jnp.float32(0.1))
    assert not np.allclose(np.asarray(p2["a"]), 1.0)
    assert np.asarray(m2["a"]).max() > 0
    assert np.asarray(v2["a"]).max() > 0
    # Adam's first step is ~ -lr * sign(g) after bias correction.
    assert_allclose(np.asarray(p2["a"]), 1.0 - 0.1, atol=1e-3)


def test_ce_loss_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, 0.0], [0.0, 3.0, 0.0]], jnp.float32)
    labels = jnp.asarray([0, 1], jnp.int32)
    got = float(model.ce_loss(logits, labels))
    want = float(np.mean([
        np.log(np.exp([2, 0, 0]).sum()) - 2.0,
        np.log(np.exp([0, 3, 0]).sum()) - 3.0,
    ]))
    assert abs(got - want) < 1e-6


def test_server_step_act_grads_shape(params):
    frozen, lora, head, tokens, labels = params
    k = 1
    clora, slora = _split_lora(lora, k)
    acts = model.client_forward(cfg, k, tokens, frozen, clora)
    t0 = {"lora": slora, "head": head}
    _, dacts, *_ = model.server_step(
        cfg, k, acts, labels, frozen, slora, head,
        _zeros_like(t0), _zeros_like(t0), jnp.float32(1.0), jnp.float32(1e-3),
    )
    assert dacts.shape == acts.shape
    assert np.abs(np.asarray(dacts)).max() > 0
