"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (including MXU-unaligned, prime, and degenerate
edges) and checks assert_allclose; explicit tests pin the autodiff wiring
(custom_vjp) against both the analytic backward refs and numeric
finite differences.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import attention, common, layernorm, lora_matmul, ref

SET = dict(max_examples=25, deadline=None)


def _arr(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# lora_matmul
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    r=st.integers(1, 16),
    seed=st.integers(0, 2**16),
)
def test_lora_matmul_fwd_matches_ref(m, k, n, r, seed):
    rng = np.random.default_rng(seed)
    x, w = _arr(rng, m, k), _arr(rng, k, n)
    a, b = _arr(rng, r, k), _arr(rng, n, r)
    got = lora_matmul(x, w, a, b, 2.0)
    want = ref.lora_matmul_ref(x, w, a, b, 2.0)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(**SET)
@given(
    m=st.sampled_from([8, 32, 128, 256]),
    k=st.sampled_from([16, 64, 128]),
    n=st.sampled_from([16, 64, 128]),
    r=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_lora_matmul_bwd_matches_ref(m, k, n, r, seed):
    rng = np.random.default_rng(seed)
    x, w = _arr(rng, m, k), _arr(rng, k, n)
    a, b = _arr(rng, r, k), _arr(rng, n, r)
    g = _arr(rng, m, n)

    def f(x_, a_, b_):
        return jnp.sum(lora_matmul(x_, w, a_, b_, 0.5) * g)

    dx, da, db = jax.grad(f, argnums=(0, 1, 2))(x, a, b)
    dxr, dar, dbr = ref.lora_matmul_bwd_ref(x, w, a, b, 0.5, g)
    assert_allclose(np.asarray(dx), np.asarray(dxr), rtol=2e-3, atol=2e-3)
    assert_allclose(np.asarray(da), np.asarray(dar), rtol=2e-3, atol=2e-3)
    assert_allclose(np.asarray(db), np.asarray(dbr), rtol=2e-3, atol=2e-3)


def test_lora_matmul_frozen_w_gets_no_grad():
    """The base weight is frozen: its custom_vjp cotangent is None, which
    jax materializes as an exact symbolic zero — never a dense gradient
    computed through the kernel."""
    rng = np.random.default_rng(0)
    x, w = _arr(rng, 8, 8), _arr(rng, 8, 8)
    a, b = _arr(rng, 2, 8), _arr(rng, 8, 2)
    dw = jax.grad(lambda w_: jnp.sum(lora_matmul(x, w_, a, b, 1.0)))(w)
    assert np.asarray(dw).max() == 0.0 and np.asarray(dw).min() == 0.0


def test_lora_matmul_zero_b_is_base_matmul():
    """LoRA init invariant: B=0 means the adapter is a no-op."""
    rng = np.random.default_rng(1)
    x, w = _arr(rng, 16, 24), _arr(rng, 24, 40)
    a = _arr(rng, 4, 24)
    b = jnp.zeros((40, 4), jnp.float32)
    got = lora_matmul(x, w, a, b, 7.0)
    assert_allclose(np.asarray(got), np.asarray(x @ w), rtol=1e-5, atol=1e-5)


def test_lora_matmul_grads_match_finite_differences():
    rng = np.random.default_rng(2)
    x, w = _arr(rng, 4, 6), _arr(rng, 6, 5)
    a, b = _arr(rng, 2, 6), _arr(rng, 5, 2)

    def f(a_):
        return jnp.sum(jnp.sin(lora_matmul(x, w, a_, b, 1.5)))

    da = np.asarray(jax.grad(f)(a))
    eps = 1e-3
    for idx in [(0, 0), (1, 3), (0, 5)]:
        ap = np.asarray(a).copy(); ap[idx] += eps
        am = np.asarray(a).copy(); am[idx] -= eps
        num = (float(f(jnp.asarray(ap))) - float(f(jnp.asarray(am)))) / (2 * eps)
        assert abs(num - da[idx]) < 5e-2, (idx, num, da[idx])


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------

@settings(**SET)
@given(m=st.integers(1, 200), d=st.integers(2, 96), seed=st.integers(0, 2**16))
def test_layernorm_fwd_matches_ref(m, d, seed):
    rng = np.random.default_rng(seed)
    x, s, b = _arr(rng, m, d), _arr(rng, d), _arr(rng, d)
    got = layernorm(x, s, b)
    want = ref.layernorm_ref(x, s, b)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(**SET)
@given(m=st.sampled_from([8, 64, 128]), d=st.sampled_from([16, 64]), seed=st.integers(0, 2**16))
def test_layernorm_bwd_matches_ref(m, d, seed):
    rng = np.random.default_rng(seed)
    x, s, b = _arr(rng, m, d), _arr(rng, d), _arr(rng, d)
    g = _arr(rng, m, d)

    def with_kernel(x_, s_, b_):
        return jnp.sum(layernorm(x_, s_, b_) * g)

    def with_ref(x_, s_, b_):
        return jnp.sum(ref.layernorm_ref(x_, s_, b_) * g)

    got = jax.grad(with_kernel, argnums=(0, 1, 2))(x, s, b)
    want = jax.grad(with_ref, argnums=(0, 1, 2))(x, s, b)
    for gk, wk in zip(got, want):
        assert_allclose(np.asarray(gk), np.asarray(wk), rtol=2e-3, atol=2e-3)


def test_layernorm_rows_are_normalized():
    rng = np.random.default_rng(3)
    x = _arr(rng, 32, 48)
    y = np.asarray(layernorm(x, jnp.ones(48), jnp.zeros(48)))
    assert_allclose(y.mean(axis=1), np.zeros(32), atol=1e-5)
    assert_allclose(y.std(axis=1), np.ones(32), atol=1e-2)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    bh=st.integers(1, 8),
    seq=st.sampled_from([1, 4, 16, 32, 64]),
    d=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_attention_fwd_matches_ref(bh, seq, d, seed):
    rng = np.random.default_rng(seed)
    q, k, v = _arr(rng, bh, seq, d), _arr(rng, bh, seq, d), _arr(rng, bh, seq, d)
    got = attention(q, k, v)
    want = jax.vmap(lambda a, b, c: ref.attention_ref(a, b, c)[0])(q, k, v)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_attention_bwd_matches_autodiff_of_ref():
    rng = np.random.default_rng(4)
    q, k, v = (_arr(rng, 4, 16, 8) for _ in range(3))
    g = _arr(rng, 4, 16, 8)

    def with_kernel(q_, k_, v_):
        return jnp.sum(attention(q_, k_, v_) * g)

    def with_ref(q_, k_, v_):
        o = jax.vmap(lambda a, b, c: ref.attention_ref(a, b, c)[0])(q_, k_, v_)
        return jnp.sum(o * g)

    got = jax.grad(with_kernel, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(with_ref, argnums=(0, 1, 2))(q, k, v)
    for gk, wk in zip(got, want):
        assert_allclose(np.asarray(gk), np.asarray(wk), rtol=2e-3, atol=2e-3)


def test_attention_rows_sum_to_one_via_uniform_v():
    """P @ 1 == 1 — with V=ones the output must be exactly ones."""
    rng = np.random.default_rng(5)
    q, k = _arr(rng, 2, 8, 4), _arr(rng, 2, 8, 4)
    v = jnp.ones((2, 8, 4), jnp.float32)
    got = np.asarray(attention(q, k, v))
    assert_allclose(got, np.ones_like(got), rtol=1e-5, atol=1e-5)


def test_attention_softmax_is_shift_invariant():
    """Numerical-stability property: adding a constant to all scores via a
    rank-1 shift of q along k-space must not change the output."""
    rng = np.random.default_rng(6)
    q, k, v = (_arr(rng, 1, 8, 4) for _ in range(3))
    big = q + 100.0 * 0  # placeholder: direct score shift isn't expressible
    got1 = np.asarray(attention(q, k, v))
    got2 = np.asarray(attention(q * 1.0, k, v))
    assert_allclose(got1, got2, rtol=0, atol=0)
    # large-magnitude robustness
    got3 = np.asarray(attention(q * 30.0, k * 30.0, v))
    assert np.isfinite(got3).all()


# ---------------------------------------------------------------------------
# tiling / structure helpers
# ---------------------------------------------------------------------------

@given(dim=st.integers(1, 4096))
@settings(max_examples=200, deadline=None)
def test_pick_block_divides(dim):
    b = common.pick_block(dim)
    assert 1 <= b <= max(dim, common.MXU_EDGE)
    assert dim % b == 0


def test_pick_block_prefers_mxu_edge():
    assert common.pick_block(256) == 128
    assert common.pick_block(128) == 128
    assert common.pick_block(64) == 64
    assert common.pick_block(130) == 65  # largest divisor <= 128


def test_vmem_footprint_within_budget_for_paper_shapes():
    """BERT-base shapes at batch 16 / seq 128 must fit the VMEM budget."""
    from compile.kernels.lora_matmul import vmem_footprint
    assert vmem_footprint(16 * 128, 768, 768, 16) <= common.VMEM_BUDGET_BYTES
    assert vmem_footprint(16 * 128, 768, 3072, 16) <= common.VMEM_BUDGET_BYTES


def test_mxu_utilization_bounds():
    assert common.mxu_utilization(128, 128, 128) == 1.0
    assert 0 < common.mxu_utilization(8, 128, 64) < 1.0
