"""Build-time performance analysis (EXPERIMENTS.md §Perf inputs).

L1 — Pallas kernels: static VMEM footprint + MXU-utilization estimates
from the chosen BlockSpecs (interpret=True gives no TPU wallclock; the
tile structure is what we can and do optimize — DESIGN.md §8).

L2 — lowered artifacts: XLA cost analysis (flops / bytes accessed) per
artifact, verifying the graph has no redundant recompute beyond the
*intentional* client-side rematerialization.

Usage (from python/):  python -m compile.analyze --config mini
"""

import argparse

import jax

from . import artifacts as art
from .configs import get_config
from .kernels import common
from .kernels.attention import vmem_footprint as attn_vmem
from .kernels.lora_matmul import vmem_footprint as lora_vmem


def l1_report(cfg):
    print(f"== L1 Pallas kernel structure ({cfg.name}) ==")
    rows = []
    m_rows = cfg.batch * cfg.seq
    for (name, m_dim, k_dim, n_dim) in [
        ("lora_matmul q/v proj", m_rows, cfg.hidden, cfg.hidden),
        ("lora_matmul (bert-base q/v)", 16 * 128, 768, 768),
        ("lora_matmul (bert-base ffn-shaped)", 16 * 128, 768, 3072),
    ]:
        bm = common.pick_block(m_dim)
        bn = common.pick_block(n_dim)
        vmem = lora_vmem(m_dim, k_dim, n_dim, cfg.rank)
        util = common.mxu_utilization(bm, bn, k_dim)
        rows.append((name, f"{bm}x{k_dim}->{bn}", vmem, util))
    vmem_a = attn_vmem(cfg.seq, cfg.head_dim)
    rows.append(
        (
            "attention (per head)",
            f"L={cfg.seq} d={cfg.head_dim}",
            vmem_a,
            common.mxu_utilization(cfg.seq, cfg.head_dim, cfg.seq),
        )
    )
    rows.append(
        (
            "attention (bert-base head)",
            "L=128 d=64",
            attn_vmem(128, 64),
            common.mxu_utilization(128, 64, 128),
        )
    )
    for name, tile, vmem, util in rows:
        ok = "OK " if vmem <= common.VMEM_BUDGET_BYTES else "OVER"
        print(
            f"  {name:<36} tile={tile:<16} vmem={vmem/1024:8.1f} KiB "
            f"({ok}/{common.VMEM_BUDGET_BYTES//1024//1024} MiB) mxu~{util:4.0%}"
        )


def l2_report(cfg):
    print(f"\n== L2 artifact cost analysis ({cfg.name}) ==")
    total_flops = 0.0
    for name, (fn, inputs, _outputs) in sorted(art.all_artifacts(cfg).items()):
        compiled = jax.jit(fn, keep_unused=True).lower(*art.shape_structs(inputs)).compile()
        try:
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            flops = cost.get("flops", float("nan"))
            bytes_acc = cost.get("bytes accessed", float("nan"))
        except Exception as e:  # pragma: no cover - cost API variance
            flops, bytes_acc = float("nan"), float("nan")
            print(f"  {name}: cost analysis unavailable ({e})")
            continue
        ai = flops / bytes_acc if bytes_acc else float("nan")
        total_flops += flops
        print(
            f"  {name:<16} flops={flops/1e6:9.1f}M  bytes={bytes_acc/1e6:9.1f}MB  "
            f"arith-intensity={ai:5.2f}"
        )
    print(f"  total (all artifacts): {total_flops/1e9:.2f} GFLOP")

    # Rematerialization accounting: client_bwd recomputes client_fwd by
    # design (client memory saving). Verify the overhead matches theory:
    # bwd ≈ fwd(remat) + 2x fwd ⇒ bwd/fwd ≈ 3.
    arts = art.all_artifacts(cfg)
    for k in cfg.cuts:
        fwd = jax.jit(arts[f"client_fwd_{k}"][0], keep_unused=True).lower(
            *art.shape_structs(arts[f"client_fwd_{k}"][1])
        ).compile()
        bwd = jax.jit(arts[f"client_bwd_{k}"][0], keep_unused=True).lower(
            *art.shape_structs(arts[f"client_bwd_{k}"][1])
        ).compile()
        try:
            cf = fwd.cost_analysis()
            cb = bwd.cost_analysis()
            if isinstance(cf, list):
                cf, cb = cf[0], cb[0]
            ratio = cb["flops"] / cf["flops"]
            print(f"  client_bwd_{k}/client_fwd_{k} flops ratio = {ratio:.2f} (theory ~3)")
        except Exception:
            pass


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="mini")
    args = ap.parse_args()
    cfg = get_config(args.config)
    l1_report(cfg)
    l2_report(cfg)


if __name__ == "__main__":
    main()
