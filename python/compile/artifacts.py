"""Artifact builders: flat-positional wrappers around model.py steps.

Each builder returns (fn, input_spec, output_names) where `fn` takes the
inputs as a flat positional tuple in exactly `input_spec` order and
returns a flat tuple.  aot.py lowers `fn` and records the spec in
manifest.json; the rust runtime marshals literals in the same order.
"""

import jax.numpy as jnp
import numpy as np

from . import model, packing


def _spec_entry(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _frozen_entries(cfg):
    return [_spec_entry(f"frozen.{n}", s) for n, s in packing.frozen_spec(cfg)]


def _lora_entries(cfg, n_layers, prefix):
    return [_spec_entry(n, s) for n, s in packing.lora_spec(cfg, n_layers, prefix)]


def _head_entries(cfg):
    return [_spec_entry(n, s) for n, s in packing.head_spec(cfg)]


def _adam_entries(trainable_entries):
    return (
        [_spec_entry("adam_m." + e["name"], e["shape"]) for e in trainable_entries]
        + [_spec_entry("adam_v." + e["name"], e["shape"]) for e in trainable_entries]
    )


def _scalar_entries():
    return [_spec_entry("step", ()), _spec_entry("lr", ())]


def _tokens_entry(cfg):
    return _spec_entry("tokens", (cfg.batch, cfg.seq), "i32")


def _labels_entry(cfg):
    return _spec_entry("labels", (cfg.batch,), "i32")


def _acts_entry(cfg, name="acts"):
    return _spec_entry(name, (cfg.batch, cfg.seq, cfg.hidden))


def _take(flat, n):
    return flat[:n], flat[n:]


def _unpack_trainables(flat, n_lora_tensors=packing.N_LORA):
    lora_flat, flat = _take(flat, n_lora_tensors)
    head_flat, flat = _take(flat, packing.N_HEAD)
    t = {"lora": packing.unflatten_lora(lora_flat), "head": packing.unflatten_head(head_flat)}
    return t, flat


def _flatten_trainables(t):
    return packing.flatten_lora(t["lora"]) + packing.flatten_head(t["head"])


def build_client_fwd(cfg, k):
    def fn(*flat):
        tokens, flat = flat[0], list(flat[1:])
        frozen_flat, flat = _take(flat, packing.N_FROZEN)
        lora_flat, flat = _take(flat, packing.N_LORA)
        assert not flat
        frozen = packing.unflatten_frozen(frozen_flat)
        lora = packing.unflatten_lora(lora_flat)
        return (model.client_forward(cfg, k, tokens, frozen, lora),)

    inputs = [_tokens_entry(cfg)] + _frozen_entries(cfg) + _lora_entries(cfg, k, "client_lora")
    outputs = [_acts_entry(cfg)]
    return fn, inputs, outputs


def build_server_step(cfg, k):
    ns = cfg.layers - k

    def fn(*flat):
        acts, labels = flat[0], flat[1]
        flat = list(flat[2:])
        frozen_flat, flat = _take(flat, packing.N_FROZEN)
        t, flat = _unpack_trainables(flat)
        mom, flat = _unpack_trainables(flat)
        vel, flat = _unpack_trainables(flat)
        step, lr = flat
        frozen = packing.unflatten_frozen(frozen_flat)
        loss, dacts, new_lora, new_head, new_m, new_v = model.server_step(
            cfg, k, acts, labels, frozen, t["lora"], t["head"],
            {"lora": mom["lora"], "head": mom["head"]},
            {"lora": vel["lora"], "head": vel["head"]},
            step, lr,
        )
        out = [loss, dacts]
        out += _flatten_trainables({"lora": new_lora, "head": new_head})
        out += _flatten_trainables(new_m) + _flatten_trainables(new_v)
        return tuple(out)

    t_entries = _lora_entries(cfg, ns, "server_lora") + _head_entries(cfg)
    inputs = (
        [_acts_entry(cfg), _labels_entry(cfg)]
        + _frozen_entries(cfg)
        + t_entries
        + _adam_entries(t_entries)
        + _scalar_entries()
    )
    outputs = (
        [_spec_entry("loss", ()), _acts_entry(cfg, "act_grads")]
        + [_spec_entry("new." + e["name"], e["shape"]) for e in t_entries]
        + [_spec_entry("new.adam_m." + e["name"], e["shape"]) for e in t_entries]
        + [_spec_entry("new.adam_v." + e["name"], e["shape"]) for e in t_entries]
    )
    return fn, inputs, outputs


def build_client_bwd(cfg, k):
    def fn(*flat):
        tokens, flat = flat[0], list(flat[1:])
        frozen_flat, flat = _take(flat, packing.N_FROZEN)
        lora_flat, flat = _take(flat, packing.N_LORA)
        act_grads, flat = flat[0], flat[1:]
        mom_flat, flat = _take(flat, packing.N_LORA)
        vel_flat, flat = _take(flat, packing.N_LORA)
        step, lr = flat
        frozen = packing.unflatten_frozen(frozen_flat)
        lora = packing.unflatten_lora(lora_flat)
        mom = packing.unflatten_lora(mom_flat)
        vel = packing.unflatten_lora(vel_flat)
        new_lora, new_m, new_v = model.client_backward(
            cfg, k, tokens, frozen, lora, act_grads, mom, vel, step, lr
        )
        return tuple(
            packing.flatten_lora(new_lora)
            + packing.flatten_lora(new_m)
            + packing.flatten_lora(new_v)
        )

    l_entries = _lora_entries(cfg, k, "client_lora")
    inputs = (
        [_tokens_entry(cfg)]
        + _frozen_entries(cfg)
        + l_entries
        + [_acts_entry(cfg, "act_grads")]
        + _adam_entries(l_entries)
        + _scalar_entries()
    )
    outputs = (
        [_spec_entry("new." + e["name"], e["shape"]) for e in l_entries]
        + [_spec_entry("new.adam_m." + e["name"], e["shape"]) for e in l_entries]
        + [_spec_entry("new.adam_v." + e["name"], e["shape"]) for e in l_entries]
    )
    return fn, inputs, outputs


def build_eval(cfg):
    n = cfg.layers

    def fn(*flat):
        tokens, labels = flat[0], flat[1]
        flat = list(flat[2:])
        frozen_flat, flat = _take(flat, packing.N_FROZEN)
        lora_flat, flat = _take(flat, packing.N_LORA)
        head_flat, flat = _take(flat, packing.N_HEAD)
        assert not flat
        frozen = packing.unflatten_frozen(frozen_flat)
        logits, loss = model.eval_batch(
            cfg, tokens, labels, frozen,
            packing.unflatten_lora(lora_flat), packing.unflatten_head(head_flat),
        )
        return (logits, loss)

    inputs = (
        [_tokens_entry(cfg), _labels_entry(cfg)]
        + _frozen_entries(cfg)
        + _lora_entries(cfg, n, "lora")
        + _head_entries(cfg)
    )
    outputs = [
        _spec_entry("logits", (cfg.batch, cfg.classes)),
        _spec_entry("loss", ()),
    ]
    return fn, inputs, outputs


def build_full_step(cfg):
    n = cfg.layers

    def fn(*flat):
        tokens, labels = flat[0], flat[1]
        flat = list(flat[2:])
        frozen_flat, flat = _take(flat, packing.N_FROZEN)
        t, flat = _unpack_trainables(flat)
        mom, flat = _unpack_trainables(flat)
        vel, flat = _unpack_trainables(flat)
        step, lr = flat
        frozen = packing.unflatten_frozen(frozen_flat)
        loss, new_lora, new_head, new_m, new_v = model.full_step(
            cfg, tokens, labels, frozen, t["lora"], t["head"], mom, vel, step, lr
        )
        out = [loss]
        out += _flatten_trainables({"lora": new_lora, "head": new_head})
        out += _flatten_trainables(new_m) + _flatten_trainables(new_v)
        return tuple(out)

    t_entries = _lora_entries(cfg, n, "lora") + _head_entries(cfg)
    inputs = (
        [_tokens_entry(cfg), _labels_entry(cfg)]
        + _frozen_entries(cfg)
        + t_entries
        + _adam_entries(t_entries)
        + _scalar_entries()
    )
    outputs = (
        [_spec_entry("loss", ())]
        + [_spec_entry("new." + e["name"], e["shape"]) for e in t_entries]
        + [_spec_entry("new.adam_m." + e["name"], e["shape"]) for e in t_entries]
        + [_spec_entry("new.adam_v." + e["name"], e["shape"]) for e in t_entries]
    )
    return fn, inputs, outputs


def example_args(input_spec):
    """Concrete example arrays matching a spec (for lowering/tests)."""
    out = []
    for e in input_spec:
        shape = tuple(e["shape"])
        if e["dtype"] == "i32":
            out.append(np.zeros(shape, np.int32))
        else:
            out.append(np.zeros(shape, np.float32))
    return out


def shape_structs(input_spec):
    """jax.ShapeDtypeStruct list matching a spec (for AOT lowering)."""
    import jax

    out = []
    for e in input_spec:
        dt = jnp.int32 if e["dtype"] == "i32" else jnp.float32
        out.append(jax.ShapeDtypeStruct(tuple(e["shape"]), dt))
    return out


def all_artifacts(cfg):
    """{artifact_name: (fn, inputs, outputs)} for one model config."""
    arts = {}
    for k in cfg.cuts:
        arts[f"client_fwd_{k}"] = build_client_fwd(cfg, k)
        arts[f"server_step_{k}"] = build_server_step(cfg, k)
        arts[f"client_bwd_{k}"] = build_client_bwd(cfg, k)
    arts["eval"] = build_eval(cfg)
    arts["full_step"] = build_full_step(cfg)
    return arts
