"""L2 — the BERT-like encoder and the split training steps (paper Alg. 1).

The model is written so the *same* parameter tensors serve every artifact:
frozen weights arrive as full per-layer stacks [N, ...] and each artifact
statically slices the layers it owns (client: [0, k), server: [k, N)).
LoRA adapters ride on the attention Q/V projections via the fused
kernels.lora_matmul (paper eq. 1); the classification head is trained on
the server side, as in FedBERT-style SFL.

Four step functions map 1:1 onto the paper's protocol:
  client_forward  — eq. (3): v_u = f(W_u, R_c^u; x_u)
  server_step     — eq. (4) + loss + server-LoRA/head Adam update + dv_u
  client_backward — client-side LoRA Adam update from dv_u (forward is
                    rematerialized: activations are *not* stored between
                    the fwd and bwd phases — that is the client-memory
                    story of the paper)
  eval_batch      — full-model logits for accuracy/F1 tracking

All functions are pure; optimizer state is explicit (rust owns it).
"""

import jax
import jax.numpy as jnp

from . import packing
from .kernels import attention, layernorm, lora_matmul
from .kernels.ref import gelu_ref as gelu

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


# ---------------------------------------------------------------------------
# Initialization (the "pretrained" weights — seeded random on this testbed;
# see DESIGN.md §2 for why this preserves the fine-tuning dynamics).
# ---------------------------------------------------------------------------

def init_frozen(cfg, key):
    ks = jax.random.split(key, 8)
    m, f, n = cfg.hidden, cfg.ffn, cfg.layers
    std = 0.05

    def norm(k, shape, s=std):
        return (jax.random.normal(k, shape) * s).astype(jnp.float32)

    stacks = {
        "wq": norm(ks[0], (n, m, m)), "bq": jnp.zeros((n, m), jnp.float32),
        "wk": norm(ks[1], (n, m, m)), "bk": jnp.zeros((n, m), jnp.float32),
        "wv": norm(ks[2], (n, m, m)), "bv": jnp.zeros((n, m), jnp.float32),
        "wo": norm(ks[3], (n, m, m)), "bo": jnp.zeros((n, m), jnp.float32),
        "ln1_s": jnp.ones((n, m), jnp.float32),
        "ln1_b": jnp.zeros((n, m), jnp.float32),
        "ln2_s": jnp.ones((n, m), jnp.float32),
        "ln2_b": jnp.zeros((n, m), jnp.float32),
        "w1": norm(ks[4], (n, m, f)), "b1": jnp.zeros((n, f), jnp.float32),
        "w2": norm(ks[5], (n, f, m)), "b2": jnp.zeros((n, m), jnp.float32),
    }
    return {
        "tok_emb": norm(ks[6], (cfg.vocab, m), 0.1),
        "pos_emb": norm(ks[7], (cfg.seq, m), 0.02),
        "emb_ln_s": jnp.ones((m,), jnp.float32),
        "emb_ln_b": jnp.zeros((m,), jnp.float32),
        "stacks": stacks,
    }


def init_lora(cfg, key, n_layers):
    """Standard LoRA init: A ~ N(0, 1/r), B = 0 so the adapter starts as a
    no-op on the pretrained function."""
    m, r = cfg.hidden, cfg.rank
    k1, k2 = jax.random.split(key)
    sa = 1.0 / r
    return {
        "aq": (jax.random.normal(k1, (n_layers, r, m)) * sa).astype(jnp.float32),
        "bq": jnp.zeros((n_layers, m, r), jnp.float32),
        "av": (jax.random.normal(k2, (n_layers, r, m)) * sa).astype(jnp.float32),
        "bv": jnp.zeros((n_layers, m, r), jnp.float32),
    }


def init_head(cfg, key):
    w = (jax.random.normal(key, (cfg.hidden, cfg.classes)) * 0.05).astype(jnp.float32)
    return {"w": w, "b": jnp.zeros((cfg.classes,), jnp.float32)}


# ---------------------------------------------------------------------------
# Forward pieces
# ---------------------------------------------------------------------------

def embed(cfg, frozen, tokens):
    """tokens [B, L] int32 -> [B, L, m]."""
    b, seq = tokens.shape
    m = cfg.hidden
    x = jnp.take(frozen["tok_emb"], tokens, axis=0) + frozen["pos_emb"][None, :, :]
    x2 = layernorm(x.reshape(b * seq, m), frozen["emb_ln_s"], frozen["emb_ln_b"])
    return x2.reshape(b, seq, m)


def encoder_layer(cfg, x, lp, ll):
    """One post-LN transformer layer.

    x: [B, L, m]; lp: per-layer frozen tensors; ll: per-layer LoRA tensors.
    Q and V projections are LoRA-augmented (fused kernel); K and the output
    projection stay frozen, matching the paper's eq. (1) placement.
    """
    b, seq, m = x.shape
    h, d = cfg.heads, cfg.head_dim
    s = cfg.lora_scale
    xm = x.reshape(b * seq, m)

    q = lora_matmul(xm, lp["wq"], ll["aq"], ll["bq"], s) + lp["bq"]
    k = xm @ lp["wk"] + lp["bk"]
    v = lora_matmul(xm, lp["wv"], ll["av"], ll["bv"], s) + lp["bv"]

    def heads(t):  # [B*L, m] -> [B*h, L, d]
        return (
            t.reshape(b, seq, h, d).transpose(0, 2, 1, 3).reshape(b * h, seq, d)
        )

    o = attention(heads(q), heads(k), heads(v))
    o = o.reshape(b, h, seq, d).transpose(0, 2, 1, 3).reshape(b * seq, m)
    o = o @ lp["wo"] + lp["bo"]

    x1 = layernorm(xm + o, lp["ln1_s"], lp["ln1_b"])
    ff = gelu(x1 @ lp["w1"] + lp["b1"]) @ lp["w2"] + lp["b2"]
    x2 = layernorm(x1 + ff, lp["ln2_s"], lp["ln2_b"])
    return x2.reshape(b, seq, m)


def _layer_params(frozen, i):
    return {k: frozen["stacks"][k][i] for k in packing.STACK_KEYS}


def _lora_layer(lora, j):
    return {k: lora[k][j] for k in packing.LORA_KEYS}


def run_layers(cfg, x, frozen, lora, start, end):
    """Layers [start, end) with `lora` stacked over exactly end-start layers.

    Static python loop: cut points are compile-time constants, so each
    artifact bakes in precisely the layers it owns (the server artifact is
    the paper's 'skip the client's submodel' — eq. 4's W_o − W_u).
    """
    for i in range(start, end):
        x = encoder_layer(cfg, x, _layer_params(frozen, i), _lora_layer(lora, i - start))
    return x


def pool_logits(cfg, x, head):
    """Mean-pool over the sequence then classify. x: [B, L, m] -> [B, C]."""
    pooled = jnp.mean(x, axis=1)
    return pooled @ head["w"] + head["b"]


def ce_loss(logits, labels):
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - picked)


# ---------------------------------------------------------------------------
# Adam (explicit state — rust owns it across steps)
# ---------------------------------------------------------------------------

def adam_update(params, grads, mom, vel, step, lr):
    """step: f32 scalar (1-based). Returns (params', mom', vel')."""
    c1 = 1.0 - jnp.power(ADAM_B1, step)
    c2 = 1.0 - jnp.power(ADAM_B2, step)

    def upd(p, g, m_, v_):
        m2 = ADAM_B1 * m_ + (1.0 - ADAM_B1) * g
        v2 = ADAM_B2 * v_ + (1.0 - ADAM_B2) * g * g
        p2 = p - lr * (m2 / c1) / (jnp.sqrt(v2 / c2) + ADAM_EPS)
        return p2, m2, v2

    flat_p, tree = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(mom)
    flat_v = jax.tree_util.tree_leaves(vel)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tree, [o[2] for o in out])
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# The four protocol steps (paper Alg. 1)
# ---------------------------------------------------------------------------

def client_forward(cfg, k, tokens, frozen, client_lora):
    """eq. (3): embedding + layers [0, k) -> activations at the cut."""
    x = embed(cfg, frozen, tokens)
    return run_layers(cfg, x, frozen, client_lora, 0, k)


def server_step(cfg, k, acts, labels, frozen, server_lora, head, mom, vel, step, lr):
    """eq. (4) + backward: returns (loss, act_grads, new_server_lora,
    new_head, new_mom, new_vel)."""

    def loss_fn(trainables, acts_in):
        x = run_layers(cfg, acts_in, frozen, trainables["lora"], k, cfg.layers)
        return ce_loss(pool_logits(cfg, x, trainables["head"]), labels)

    trainables = {"lora": server_lora, "head": head}
    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(trainables, acts)
    tgrads, act_grads = grads
    new_t, new_m, new_v = adam_update(trainables, tgrads, mom, vel, step, lr)
    return loss, act_grads, new_t["lora"], new_t["head"], new_m, new_v


def client_backward(cfg, k, tokens, frozen, client_lora, act_grads, mom, vel, step, lr):
    """Client-side LoRA update from the activation gradients.

    The forward through layers [0, k) is *recomputed* here (rematerialized)
    — the client never holds activations between protocol phases, which is
    exactly the client-memory saving the split buys.
    """

    def fwd(lora):
        return client_forward(cfg, k, tokens, frozen, lora)

    _, vjp = jax.vjp(fwd, client_lora)
    (grads,) = vjp(act_grads)
    new_lora, new_m, new_v = adam_update(client_lora, grads, mom, vel, step, lr)
    return new_lora, new_m, new_v


def eval_batch(cfg, tokens, labels, frozen, full_lora, head):
    """Full-model forward: returns (logits [B, C], mean CE loss)."""
    x = embed(cfg, frozen, tokens)
    x = run_layers(cfg, x, frozen, full_lora, 0, cfg.layers)
    logits = pool_logits(cfg, x, head)
    return logits, ce_loss(logits, labels)


def full_step(cfg, tokens, labels, frozen, full_lora, head, mom, vel, step, lr):
    """Monolithic (centralized) training step over the whole model — used by
    the split-consistency tests and the centralized-reference example."""

    def loss_fn(trainables):
        x = embed(cfg, frozen, tokens)
        x = run_layers(cfg, x, frozen, trainables["lora"], 0, cfg.layers)
        return ce_loss(pool_logits(cfg, x, trainables["head"]), labels)

    trainables = {"lora": full_lora, "head": head}
    loss, grads = jax.value_and_grad(loss_fn)(trainables)
    new_t, new_m, new_v = adam_update(trainables, grads, mom, vel, step, lr)
    return loss, new_t["lora"], new_t["head"], new_m, new_v
