"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth the pytest/hypothesis suites compare against
(see python/tests/test_kernels.py). They are also used directly by the
model when a dimension is too ragged for the tiled kernels (guarded by
`kernels.common.supports_tiling`).
"""

import jax.numpy as jnp
from jax import nn as jnn


def lora_matmul_ref(x, w, a, b, scale):
    """y = x @ w + scale * (x @ a.T) @ b.T

    x: [M, K]   activations
    w: [K, N]   frozen base weight
    a: [r, K]   LoRA down-projection
    b: [N, r]   LoRA up-projection
    scale: python float (alpha / r)
    """
    return x @ w + scale * ((x @ a.T) @ b.T)


def lora_matmul_bwd_ref(x, w, a, b, scale, g):
    """Cotangents of lora_matmul_ref wrt (x, a, b); w is frozen.

    g: [M, N] upstream gradient.
    Returns (dx [M, K], da [r, K], db [N, r]).
    """
    u = x @ a.T                      # [M, r]
    dx = g @ w.T + scale * ((g @ b) @ a)
    da = scale * (g @ b).T @ x       # [r, K]
    db = scale * g.T @ u             # [N, r]
    return dx, da, db


def layernorm_ref(x, scale, bias, eps=1e-5):
    """Row-wise layer normalization. x: [M, D], scale/bias: [D]."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    xhat = (x - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
    return xhat * scale + bias


def attention_ref(q, k, v):
    """Scaled dot-product attention for one (batch, head) slice.

    q, k, v: [L, d].  Returns (o [L, d], p [L, L]) where p is the softmax
    matrix (returned so custom_vjp backward passes can reuse it).
    """
    d = q.shape[-1]
    s = (q @ k.T) * (1.0 / jnp.sqrt(jnp.asarray(d, q.dtype)))
    p = jnn.softmax(s, axis=-1)
    return p @ v, p


def attention_bwd_ref(q, k, v, p, g):
    """Cotangents of attention_ref output `o` wrt (q, k, v) given residual p."""
    d = q.shape[-1]
    inv = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    dv = p.T @ g                                   # [L, d]
    dp = g @ v.T                                   # [L, L]
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = (ds @ k) * inv
    dk = (ds.T @ q) * inv
    return dq, dk, dv


def gelu_ref(x):
    """tanh-approximated GELU (matches the kernel)."""
    c = jnp.asarray(0.7978845608028654, x.dtype)  # sqrt(2/pi)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))
