"""Shared tiling helpers for the Pallas kernels.

TPU mapping notes (DESIGN.md §7): the MXU is a 128×128 systolic array and
VMEM tiles for f32 are (8, 128)-aligned.  We therefore prefer block edges
of 128 (or the full dimension when it is smaller), and fall back to the
pure-jnp reference when a dimension cannot be tiled cleanly — interpret
mode would accept ragged blocks, but real Mosaic lowering would not, and
we keep the kernels structurally TPU-valid.
"""

INTERPRET = True  # CPU PJRT cannot execute Mosaic custom-calls (README).

# Preferred MXU-aligned block edge.
MXU_EDGE = 128
# f32 VMEM sublane granularity.
SUBLANE = 8
# Practical per-core VMEM budget used by the static footprint estimator.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024


def pick_block(dim: int, preferred: int = MXU_EDGE) -> int:
    """Largest divisor of `dim` that is <= preferred, biased to MXU edges.

    Guarantees the returned block evenly divides `dim` so every grid step
    maps to a full tile (no masking needed in the kernel body).
    """
    if dim <= preferred:
        return dim
    if dim % preferred == 0:
        return preferred
    # Fall back to the largest divisor <= preferred.
    for cand in range(preferred, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def supports_tiling(*dims: int) -> bool:
    """True when every dim is positive — pick_block always finds a divisor,
    so tiling support is unconditional for positive shapes.  Kept as an
    explicit guard point so future dtype/shape restrictions live here."""
    return all(d > 0 for d in dims)


def vmem_bytes(*shapes, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for a set of resident blocks."""
    total = 0
    for shape in shapes:
        n = dtype_bytes
        for d in shape:
            n *= d
        total += n
    return total


def mxu_utilization(bm: int, bn: int, bk: int) -> float:
    """Fraction of the 128x128 MXU a (bm, bk) @ (bk, bn) tile keeps busy.

    A dimension smaller than the systolic edge leaves rows/columns of the
    array idle; utilization is the product of the per-edge occupancies.
    """
    occ_m = min(bm, MXU_EDGE) / MXU_EDGE
    occ_n = min(bn, MXU_EDGE) / MXU_EDGE
    # The contraction dim streams through the array; only alignment to the
    # sublane granularity matters.
    occ_k = 1.0 if bk % SUBLANE == 0 else bk / ((bk // SUBLANE + 1) * SUBLANE)
    return occ_m * occ_n * occ_k
