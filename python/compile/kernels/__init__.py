"""L1 — Pallas kernels for the paper's compute hot-spots.

- lora_matmul: fused base + low-rank projection (paper eq. 1), fwd + bwd
- layernorm:   fused row-wise normalization, fwd + bwd-dx
- attention:   per-(batch,head) fused scores/softmax/PV
- ref:         pure-jnp oracles for all of the above

All kernels run interpret=True on this CPU testbed (Mosaic custom-calls
need a real TPU plugin) but are tiled to be Mosaic-valid — common.py.
"""

from .attention import attention
from .layernorm import layernorm
from .lora_matmul import lora_matmul
from . import common, ref

__all__ = ["attention", "layernorm", "lora_matmul", "common", "ref"]
