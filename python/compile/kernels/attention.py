"""Batched scaled-dot-product attention as a Pallas kernel.

One grid step processes one (batch*head) slice: Q, K, V [L, d] tiles are
brought into VMEM, scores + softmax + PV are computed without touching
HBM in between (the CUDA analogue would be a fused flash-style block; at
the paper's L=128 the whole [L, L] score tile fits in VMEM so no online
softmax is needed — see DESIGN.md §7).

The softmax matrix P is emitted as a second output and saved as the
custom_vjp residual so the backward pass (attention_bwd_ref — small,
fusion-friendly contractions) avoids recomputing the softmax.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common
from .ref import attention_bwd_ref, attention_ref


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, p_ref):
    q = q_ref[0]                                           # [L, d]
    k = k_ref[0]
    v = v_ref[0]
    d = q.shape[-1]
    s = jnp.dot(q, k.T) * (1.0 / jnp.sqrt(jnp.asarray(d, q.dtype)))
    s = s - jnp.max(s, axis=-1, keepdims=True)             # numerics
    e = jnp.exp(s)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    p_ref[0] = p
    o_ref[0] = jnp.dot(p, v)


def _fwd_call(q, k, v):
    bh, seq, d = q.shape
    kspec = pl.BlockSpec((1, seq, d), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _fwd_kernel,
        grid=(bh,),
        in_specs=[kspec, kspec, kspec],
        out_specs=[
            pl.BlockSpec((1, seq, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, seq, seq), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, seq, d), q.dtype),
            jax.ShapeDtypeStruct((bh, seq, seq), q.dtype),
        ],
        interpret=common.INTERPRET,
    )(q, k, v)


@jax.custom_vjp
def attention(q, k, v):
    """Batched attention. q/k/v: [BH, L, d] -> o: [BH, L, d]."""
    o, _ = _attention_with_p(q, k, v)
    return o


def _attention_with_p(q, k, v):
    if not common.supports_tiling(*q.shape):
        o, p = jax.vmap(attention_ref)(q, k, v)
        return o, p
    return _fwd_call(q, k, v)


def _vjp_fwd(q, k, v):
    o, p = _attention_with_p(q, k, v)
    return o, (q, k, v, p)


def _vjp_bwd(res, g):
    q, k, v, p = res
    dq, dk, dv = jax.vmap(attention_bwd_ref)(q, k, v, p, g)
    return dq, dk, dv


attention.defvjp(_vjp_fwd, _vjp_bwd)


def vmem_footprint(seq, d):
    """Bytes resident per grid step: Q, K, V, O tiles + the score tile."""
    return common.vmem_bytes((seq, d), (seq, d), (seq, d), (seq, d), (seq, seq))
