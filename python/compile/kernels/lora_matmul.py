"""Fused LoRA projection kernel — the fine-tuning hot-spot.

The paper fine-tunes BERT with LoRA on the attention projections; every
client-side and server-side training step is dominated by projections of
the form

    y = x @ W + (alpha/r) * (x @ A^T) @ B^T        (paper eq. 1)

On CUDA the natural implementation is two GEMM launches + an epilogue.
On TPU we fuse all three into one Pallas kernel: a (bm, K) block of `x`
and a (K, bn) block of `W` stream through VMEM, while the *entire* rank-r
factors A [r, K] and the (bn, r) slice of B stay resident — r=16 means
the low-rank residency is ~K*r*4 bytes, negligible next to the W tile —
so the low-rank update rides along with the base matmul at zero extra
HBM traffic for `x`.

Backward is fused the same way (see `_dx_kernel`, `_da_db_kernel`) and
wired up with jax.custom_vjp so the L2 model can differentiate straight
through the kernel.  All kernels run interpret=True on this testbed
(CPU PJRT cannot run Mosaic custom-calls); tiling is still chosen to be
Mosaic-valid — see common.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common
from .ref import lora_matmul_bwd_ref, lora_matmul_ref


def _fwd_kernel(x_ref, w_ref, a_ref, b_ref, o_ref, *, scale):
    """One (bm, bn) output tile: base GEMM + rank-r correction, fused."""
    x = x_ref[...]                       # [bm, K]
    u = jnp.dot(x, a_ref[...].T)         # [bm, r]   rank-r down-projection
    o_ref[...] = jnp.dot(x, w_ref[...]) + scale * jnp.dot(u, b_ref[...].T)


def _dx_kernel(g_ref, w_ref, a_ref, b_ref, dx_ref, *, scale):
    """dx tile = g @ W^T + scale * (g @ B) @ A, fused like the forward."""
    g = g_ref[...]                       # [bm, N]
    t = jnp.dot(g, b_ref[...])           # [bm, r]
    dx_ref[...] = jnp.dot(g, w_ref[...].T) + scale * jnp.dot(t, a_ref[...])


def _da_db_kernel(x_ref, g_ref, a_ref, b_ref, da_ref, db_ref, *, scale, steps):
    """Accumulate dA [r, K] and dB [N, r] over the M grid dimension.

    Grid iterates over M blocks; the (small) dA/dB outputs alias the same
    block every step, so we initialize at step 0 and accumulate after.
    """
    i = pl.program_id(0)
    x = x_ref[...]                       # [bm, K]
    g = g_ref[...]                       # [bm, N]
    t = jnp.dot(g, b_ref[...])           # [bm, r]
    u = jnp.dot(x, a_ref[...].T)         # [bm, r]
    da = scale * jnp.dot(t.T, x)         # [r, K]
    db = scale * jnp.dot(g.T, u)         # [N, r]

    @pl.when(i == 0)
    def _init():
        da_ref[...] = da
        db_ref[...] = db

    @pl.when(i > 0)
    def _acc():
        da_ref[...] += da
        db_ref[...] += db


def _fwd_call(x, w, a, b, scale):
    m_dim, k_dim = x.shape
    n_dim = w.shape[1]
    r = a.shape[0]
    bm = common.pick_block(m_dim)
    bn = common.pick_block(n_dim)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale),
        grid=(m_dim // bm, n_dim // bn),
        in_specs=[
            pl.BlockSpec((bm, k_dim), lambda i, j: (i, 0)),   # x row-block
            pl.BlockSpec((k_dim, bn), lambda i, j: (0, j)),   # W col-block
            pl.BlockSpec((r, k_dim), lambda i, j: (0, 0)),    # A resident
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),       # B col-block
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), x.dtype),
        interpret=common.INTERPRET,
    )(x, w, a, b)


def _dx_call(g, w, a, b, scale):
    m_dim, n_dim = g.shape
    k_dim = w.shape[0]
    r = a.shape[0]
    bm = common.pick_block(m_dim)
    bk = common.pick_block(k_dim)
    return pl.pallas_call(
        functools.partial(_dx_kernel, scale=scale),
        grid=(m_dim // bm, k_dim // bk),
        in_specs=[
            pl.BlockSpec((bm, n_dim), lambda i, j: (i, 0)),   # g row-block
            pl.BlockSpec((bk, n_dim), lambda i, j: (j, 0)),   # W^T via rows
            pl.BlockSpec((r, bk), lambda i, j: (0, j)),       # A col-block
            pl.BlockSpec((n_dim, r), lambda i, j: (0, 0)),    # B resident
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, k_dim), g.dtype),
        interpret=common.INTERPRET,
    )(g, w, a, b)


def _da_db_call(x, g, a, b, scale):
    m_dim, k_dim = x.shape
    n_dim = g.shape[1]
    r = a.shape[0]
    bm = common.pick_block(m_dim)
    steps = m_dim // bm
    return pl.pallas_call(
        functools.partial(_da_db_kernel, scale=scale, steps=steps),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((bm, k_dim), lambda i: (i, 0)),
            pl.BlockSpec((bm, n_dim), lambda i: (i, 0)),
            pl.BlockSpec((r, k_dim), lambda i: (0, 0)),
            pl.BlockSpec((n_dim, r), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((r, k_dim), lambda i: (0, 0)),
            pl.BlockSpec((n_dim, r), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, k_dim), x.dtype),
            jax.ShapeDtypeStruct((n_dim, r), x.dtype),
        ],
        interpret=common.INTERPRET,
    )(x, g, a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def lora_matmul(x, w, a, b, scale):
    """Differentiable fused LoRA projection.  Shapes as in ref.py.

    W is frozen: its cotangent is returned as None so no dense [K, N]
    gradient buffer is ever materialized (the memory point of LoRA).
    """
    if not common.supports_tiling(*x.shape, w.shape[1]):
        return lora_matmul_ref(x, w, a, b, scale)
    return _fwd_call(x, w, a, b, scale)


def _vjp_fwd(x, w, a, b, scale):
    return lora_matmul(x, w, a, b, scale), (x, w, a, b)


def _vjp_bwd(scale, res, g):
    x, w, a, b = res
    if not common.supports_tiling(*x.shape, w.shape[1]):
        dx, da, db = lora_matmul_bwd_ref(x, w, a, b, scale, g)
    else:
        dx = _dx_call(g, w, a, b, scale)
        da, db = _da_db_call(x, g, a, b, scale)
    return dx, None, da, db


lora_matmul.defvjp(_vjp_fwd, _vjp_bwd)


def vmem_footprint(m_dim, k_dim, n_dim, r):
    """Static VMEM estimate (bytes) for the forward tile set — used by the
    §Perf roofline notes and asserted < budget in tests."""
    bm = common.pick_block(m_dim)
    bn = common.pick_block(n_dim)
    return common.vmem_bytes(
        (bm, k_dim), (k_dim, bn), (r, k_dim), (bn, r), (bm, bn)
    )
