"""Row-wise LayerNorm as a Pallas kernel with a fused backward.

LayerNorm brackets every residual branch in the encoder, so it runs 4x
per layer per direction; fusing the normalization (one pass, no separate
mean/var kernels) keeps it off the HBM-bandwidth critical path.  A (bm, D)
row-block is normalized entirely in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common
from .ref import layernorm_ref

EPS = 1e-5


def _fwd_kernel(x_ref, s_ref, b_ref, o_ref):
    x = x_ref[...]                                        # [bm, D]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + EPS)
    o_ref[...] = xhat * s_ref[...] + b_ref[...]


def _dx_kernel(x_ref, s_ref, g_ref, dx_ref):
    """dx for y = xhat*s + b, re-deriving xhat in-register (rematerialized —
    cheaper than an HBM round-trip for the residual)."""
    x = x_ref[...]
    g = g_ref[...]
    s = s_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + EPS)
    xhat = (x - mu) * inv
    gs = g * s                                            # [bm, D]
    m1 = jnp.mean(gs, axis=-1, keepdims=True)
    m2 = jnp.mean(gs * xhat, axis=-1, keepdims=True)
    dx_ref[...] = (gs - m1 - xhat * m2) * inv


def _fwd_call(x, scale, bias):
    m_dim, d = x.shape
    bm = common.pick_block(m_dim)
    return pl.pallas_call(
        _fwd_kernel,
        grid=(m_dim // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_dim, d), x.dtype),
        interpret=common.INTERPRET,
    )(x, scale, bias)


def _dx_call(x, scale, g):
    m_dim, d = x.shape
    bm = common.pick_block(m_dim)
    return pl.pallas_call(
        _dx_kernel,
        grid=(m_dim // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_dim, d), x.dtype),
        interpret=common.INTERPRET,
    )(x, scale, g)


@jax.custom_vjp
def layernorm(x, scale, bias):
    """LayerNorm over the last axis. x: [M, D]; scale/bias: [D]."""
    if not common.supports_tiling(*x.shape):
        return layernorm_ref(x, scale, bias, EPS)
    return _fwd_call(x, scale, bias)


def _vjp_fwd(x, scale, bias):
    return layernorm(x, scale, bias), (x, scale)


def _vjp_bwd(res, g):
    x, scale = res
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + EPS)
    # Parameter grads are tiny reductions — leave them to XLA's fusion.
    dscale = jnp.sum(g * xhat, axis=0)
    dbias = jnp.sum(g, axis=0)
    if not common.supports_tiling(*x.shape):
        gs = g * scale
        m1 = jnp.mean(gs, axis=-1, keepdims=True)
        m2 = jnp.mean(gs * xhat, axis=-1, keepdims=True)
        dx = (gs - m1 - xhat * m2) * jax.lax.rsqrt(var + EPS)
    else:
        dx = _dx_call(x, scale, g)
    return dx, dscale, dbias


layernorm.defvjp(_vjp_fwd, _vjp_bwd)
