"""AOT compile path: lower every artifact to HLO *text* + emit params.bin
and manifest.json.

HLO text (NOT lowered.serialize() / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (the version the rust `xla` 0.1.6 crate links)
rejects; the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --config small --outdir ../artifacts
Python runs ONCE here; the rust binary is self-contained afterwards.
"""

import argparse
import hashlib
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import artifacts as art
from . import model, packing
from .configs import CONFIGS, get_config

SEED = 20250711


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def initial_tensors(cfg):
    """The 'pretrained' checkpoint: frozen weights + LoRA(N) + head, in the
    packing order rust expects (DESIGN.md §2 substitution: seeded random
    base weights stand in for the BERT-base checkpoint)."""
    key = jax.random.PRNGKey(SEED)
    kf, kl, kh = jax.random.split(key, 3)
    frozen = model.init_frozen(cfg, kf)
    lora = model.init_lora(cfg, kl, cfg.layers)
    head = model.init_head(cfg, kh)

    tensors = []
    for (name, _), arr in zip(packing.frozen_spec(cfg), packing.flatten_frozen(frozen)):
        tensors.append((f"frozen.{name}", np.asarray(arr)))
    for (name, _), arr in zip(packing.lora_spec(cfg, cfg.layers), packing.flatten_lora(lora)):
        tensors.append((name, np.asarray(arr)))
    for (name, _), arr in zip(packing.head_spec(cfg), packing.flatten_head(head)):
        tensors.append((name, np.asarray(arr)))
    return tensors


def manifest_txt(manifest) -> str:
    """Line-based manifest (see rust/src/runtime/manifest.rs for the
    grammar). Scalar shapes are encoded as `-`."""

    def shape_str(shape):
        return ",".join(str(d) for d in shape) if shape else "-"

    c = manifest["config"]
    lines = [
        "config "
        + " ".join(
            f"{k}={c[k]}"
            for k in (
                "name", "vocab", "hidden", "layers", "heads", "ffn",
                "seq", "classes", "rank", "alpha", "batch",
            )
        )
        + f" cuts={','.join(str(k) for k in c['cuts'])}",
        f"params {manifest['params_bin']}",
    ]
    for name in sorted(manifest["artifacts"]):
        a = manifest["artifacts"][name]
        lines.append(f"artifact {name} {a['path']}")
        for e in a["inputs"]:
            lines.append(f"in {e['name']} {e['dtype']} {shape_str(e['shape'])}")
        for e in a["outputs"]:
            lines.append(f"out {e['name']} {e['dtype']} {shape_str(e['shape'])}")
        lines.append("end")
    for n in manifest["param_tensors"]:
        lines.append(f"param {n}")
    return "\n".join(lines) + "\n"


def build_config(cfg, outdir, force=False):
    cdir = os.path.join(outdir, cfg.name)
    os.makedirs(cdir, exist_ok=True)

    # Input fingerprint: skip work when sources + config are unchanged.
    srcdir = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in os.walk(srcdir):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    h.update(repr(cfg).encode())
    stamp = h.hexdigest()
    stamp_path = os.path.join(cdir, ".stamp")
    if not force and os.path.exists(stamp_path):
        with open(stamp_path) as fh:
            if fh.read().strip() == stamp:
                print(f"[aot] {cfg.name}: up to date, skipping")
                return

    manifest = {
        "config": {
            "name": cfg.name, "vocab": cfg.vocab, "hidden": cfg.hidden,
            "layers": cfg.layers, "heads": cfg.heads, "ffn": cfg.ffn,
            "seq": cfg.seq, "classes": cfg.classes, "rank": cfg.rank,
            "alpha": cfg.alpha, "batch": cfg.batch, "cuts": list(cfg.cuts),
        },
        "params_bin": "params.bin",
        "artifacts": {},
    }

    for name, (fn, inputs, outputs) in art.all_artifacts(cfg).items():
        specs = art.shape_structs(inputs)
        print(f"[aot] {cfg.name}/{name}: lowering ({len(inputs)} inputs)...")
        # keep_unused: server artifacts don't touch the embedding tensors,
        # but the rust marshaler passes the full frozen block everywhere —
        # argument lists must match the manifest exactly.
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(cdir, fname), "w") as fh:
            fh.write(text)
        manifest["artifacts"][name] = {
            "path": fname,
            "inputs": inputs,
            "outputs": outputs,
        }
        print(f"[aot] {cfg.name}/{name}: wrote {len(text)} chars")

    tensors = initial_tensors(cfg)
    packing.write_params_bin(os.path.join(cdir, "params.bin"), tensors)
    manifest["param_tensors"] = [n for n, _ in tensors]
    # JSON twin for humans/tools; rust parses the line-based manifest.txt
    # (the workspace builds offline with no JSON crate).
    with open(os.path.join(cdir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    with open(os.path.join(cdir, "manifest.txt"), "w") as fh:
        fh.write(manifest_txt(manifest))
    with open(stamp_path, "w") as fh:
        fh.write(stamp)
    print(f"[aot] {cfg.name}: done -> {cdir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="mini,small",
                    help="comma-separated config names, or 'all'")
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    names = list(CONFIGS) if args.config == "all" else args.config.split(",")
    for name in names:
        build_config(get_config(name.strip()), args.outdir, force=args.force)


if __name__ == "__main__":
    main()
