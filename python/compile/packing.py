"""Flat tensor I/O convention shared between python (AOT lowering) and
rust (runtime marshaling).

Every artifact function takes/returns a *flat positional tuple* of arrays
in the deterministic order defined here; aot.py records the same order in
artifacts/manifest.json so the rust runtime never has to guess jax pytree
flattening rules.

Ordering convention:
  FROZEN   : tok_emb, pos_emb, emb_ln_s, emb_ln_b, then the 16 per-layer
             stacks (STACK_KEYS order), each [N, ...]
  LORA(n)  : aq, bq, av, bv — each stacked over n layers
  HEAD     : w [m, C], b [C]
  ADAM(t)  : first-moment tensors mirroring trainable order t, then
             second-moment tensors in the same order
"""

import numpy as np

EMB_KEYS = ["tok_emb", "pos_emb", "emb_ln_s", "emb_ln_b"]
STACK_KEYS = [
    "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
    "ln1_s", "ln1_b", "ln2_s", "ln2_b",
    "w1", "b1", "w2", "b2",
]
LORA_KEYS = ["aq", "bq", "av", "bv"]
HEAD_KEYS = ["w", "b"]


def emb_shapes(cfg):
    m = cfg.hidden
    return {
        "tok_emb": (cfg.vocab, m),
        "pos_emb": (cfg.seq, m),
        "emb_ln_s": (m,),
        "emb_ln_b": (m,),
    }


def stack_shapes(cfg):
    n, m, f = cfg.layers, cfg.hidden, cfg.ffn
    return {
        "wq": (n, m, m), "bq": (n, m),
        "wk": (n, m, m), "bk": (n, m),
        "wv": (n, m, m), "bv": (n, m),
        "wo": (n, m, m), "bo": (n, m),
        "ln1_s": (n, m), "ln1_b": (n, m),
        "ln2_s": (n, m), "ln2_b": (n, m),
        "w1": (n, m, f), "b1": (n, f),
        "w2": (n, f, m), "b2": (n, m),
    }


def lora_shapes(cfg, n_layers):
    m, r = cfg.hidden, cfg.rank
    return {
        "aq": (n_layers, r, m), "bq": (n_layers, m, r),
        "av": (n_layers, r, m), "bv": (n_layers, m, r),
    }


def head_shapes(cfg):
    return {"w": (cfg.hidden, cfg.classes), "b": (cfg.classes,)}


def frozen_spec(cfg):
    """[(name, shape)] for the full frozen parameter block."""
    spec = [(k, emb_shapes(cfg)[k]) for k in EMB_KEYS]
    spec += [(k, stack_shapes(cfg)[k]) for k in STACK_KEYS]
    return spec


def lora_spec(cfg, n_layers, prefix="lora"):
    return [(f"{prefix}.{k}", lora_shapes(cfg, n_layers)[k]) for k in LORA_KEYS]


def head_spec(cfg):
    return [(f"head.{k}", head_shapes(cfg)[k]) for k in HEAD_KEYS]


def adam_spec(trainable_spec):
    """Adam m then v tensors mirroring a trainable spec."""
    return (
        [(f"adam_m.{n}", s) for n, s in trainable_spec]
        + [(f"adam_v.{n}", s) for n, s in trainable_spec]
    )


def flatten_frozen(frozen):
    return [frozen[k] for k in EMB_KEYS] + [frozen["stacks"][k] for k in STACK_KEYS]


def unflatten_frozen(flat):
    out = dict(zip(EMB_KEYS, flat[: len(EMB_KEYS)]))
    out["stacks"] = dict(zip(STACK_KEYS, flat[len(EMB_KEYS):]))
    return out


def flatten_lora(lora):
    return [lora[k] for k in LORA_KEYS]


def unflatten_lora(flat):
    return dict(zip(LORA_KEYS, flat))


def flatten_head(head):
    return [head[k] for k in HEAD_KEYS]


def unflatten_head(flat):
    return dict(zip(HEAD_KEYS, flat))


N_FROZEN = len(EMB_KEYS) + len(STACK_KEYS)
N_LORA = len(LORA_KEYS)
N_HEAD = len(HEAD_KEYS)


# ---------------------------------------------------------------------------
# params.bin — simple binary interchange for initial weights (read by
# rust/src/tensor/store.rs).  Layout:
#   magic  b"SFLP"  | u32 version | u32 tensor count
#   per tensor: u16 name_len | name utf8 | u8 dtype (0=f32, 1=i32)
#               | u8 ndim | u32 dims[ndim] | raw little-endian data
# ---------------------------------------------------------------------------

MAGIC = b"SFLP"
VERSION = 1
DTYPE_F32 = 0
DTYPE_I32 = 1


def write_params_bin(path, tensors):
    """tensors: list of (name, np.ndarray) — order preserved."""
    import struct

    with open(path, "wb") as fh:
        fh.write(MAGIC)
        fh.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            if arr.dtype == np.float32:
                dt = DTYPE_F32
            elif arr.dtype == np.int32:
                dt = DTYPE_I32
            else:
                raise ValueError(f"unsupported dtype {arr.dtype} for {name}")
            nb = name.encode()
            fh.write(struct.pack("<H", len(nb)))
            fh.write(nb)
            fh.write(struct.pack("<BB", dt, arr.ndim))
            for d in arr.shape:
                fh.write(struct.pack("<I", d))
            fh.write(arr.tobytes())


def read_params_bin(path):
    """Inverse of write_params_bin (used by python tests)."""
    import struct

    out = []
    with open(path, "rb") as fh:
        assert fh.read(4) == MAGIC, "bad magic"
        version, count = struct.unpack("<II", fh.read(8))
        assert version == VERSION
        for _ in range(count):
            (nlen,) = struct.unpack("<H", fh.read(2))
            name = fh.read(nlen).decode()
            dt, ndim = struct.unpack("<BB", fh.read(2))
            dims = struct.unpack(f"<{ndim}I", fh.read(4 * ndim))
            dtype = np.float32 if dt == DTYPE_F32 else np.int32
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(fh.read(n * 4), dtype=dtype).reshape(dims)
            out.append((name, data))
    return out
