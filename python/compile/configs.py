"""Model configurations.

`base` mirrors the paper's BERT-base setup and drives the *analytic*
memory/FLOPs models on the rust side (DESIGN.md §2 — the 110M-param model
is not trained numerically on this single-core CPU testbed).  `small` and
`mini` are scaled configs whose artifacts are actually executed; all
schemes consume the same artifacts so relative behaviour is preserved.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    hidden: int        # m — feature dimension of the hidden layer (paper §II)
    layers: int        # N — transformer layers
    heads: int
    ffn: int
    seq: int           # L — maximum sequence length
    classes: int       # CARER has 6 emotion classes
    rank: int          # r — LoRA rank (paper: 16)
    alpha: float       # LoRA scaling numerator (scale = alpha / rank)
    batch: int         # B — mini-batch size (paper: 16)
    cuts: tuple = (1, 2, 3)  # client-side cut points k_u used in the paper

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    @property
    def lora_scale(self) -> float:
        return self.alpha / self.rank

    def validate(self) -> None:
        assert self.hidden % self.heads == 0, "hidden must divide into heads"
        assert all(0 < k < self.layers for k in self.cuts), (
            "every cut must leave at least one server-side layer"
        )


# Fast config for pytest and criterion micro-benches.
MINI = ModelConfig(
    name="mini", vocab=1024, hidden=64, layers=4, heads=2, ffn=256,
    seq=32, classes=6, rank=8, alpha=16.0, batch=8, cuts=(1, 2, 3),
)

# Default numeric config: big enough to show real learning curves,
# small enough to train for hundreds of steps on one CPU core.
SMALL = ModelConfig(
    name="small", vocab=2048, hidden=128, layers=6, heads=4, ffn=512,
    seq=64, classes=6, rank=16, alpha=32.0, batch=16, cuts=(1, 2, 3),
)

# The paper's BERT-base setting (analytics only on this testbed).
BASE = ModelConfig(
    name="base", vocab=30522, hidden=768, layers=12, heads=12, ffn=3072,
    seq=128, classes=6, rank=16, alpha=32.0, batch=16, cuts=(1, 2, 3),
)

CONFIGS = {c.name: c for c in (MINI, SMALL, BASE)}


def get_config(name: str) -> ModelConfig:
    cfg = CONFIGS[name]
    cfg.validate()
    return cfg
