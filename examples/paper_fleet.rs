//! End-to-end driver on the paper's §V-A setup (EXPERIMENTS.md §E2E).
//!
//! Trains the transformer through the full three-layer stack — rust
//! coordinator → PJRT → AOT HLO (JAX model + Pallas kernels) — on the
//! synthetic CARER-like corpus with the six-device heterogeneous fleet,
//! running all three schemes to convergence and printing Table I plus
//! the final loss curves.
//!
//!     cargo run --release --example paper_fleet -- [mini|small] [max_rounds]

use anyhow::Result;
use sfl::config::{ExperimentConfig, SchedulerKind, SchemeKind};
use sfl::coordinator::Session;
use sfl::runtime::Engine;
use sfl::telemetry::{self, StdoutObserver};
use std::path::Path;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let config = args.get(1).map(|s| s.as_str()).unwrap_or("mini").to_string();
    let max_rounds: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(60);

    let engine = Engine::load(Path::new("artifacts"), &config)?;
    engine.warmup(&[1, 2, 3])?;
    println!(
        "paper fleet on `{config}` artifacts ({} layers, hidden {}) — {max_rounds} max rounds\n",
        engine.dims().layers,
        engine.dims().hidden
    );

    let mut cfg = ExperimentConfig::paper();
    cfg.artifact_config = config;
    cfg.train.max_rounds = max_rounds;
    cfg.train.steps_per_round = 2;
    cfg.train.eval_interval = 2;
    cfg.train.lr = 5e-3;
    cfg.scheduler = SchedulerKind::Proposed;

    let mut results = Vec::new();
    for scheme in [SchemeKind::Sl, SchemeKind::Sfl, SchemeKind::Ours] {
        let mut c = cfg.clone();
        c.scheme = scheme;
        let mut session = Session::new(&engine, &c)?;
        session.add_observer(Box::new(StdoutObserver));
        println!("=== {scheme} ===");
        let r = session.run_to_convergence()?;
        println!("{}\n", telemetry::summary(&scheme.to_string(), &r));
        results.push((scheme.to_string(), r));
    }

    let rows: Vec<(&str, &sfl::coordinator::RunResult)> =
        results.iter().map(|(n, r)| (n.as_str(), r)).collect();
    println!("Table I (reproduced on this testbed):\n{}", telemetry::table1(&rows));

    // Paper headline ratios.
    let by: std::collections::HashMap<&str, &sfl::coordinator::RunResult> =
        rows.iter().copied().collect();
    let (sl, sfl_r, ours) = (by["sl"], by["sfl"], by["ours"]);
    println!(
        "memory vs SFL: -{:.0}% (paper -79%) | memory vs SL: +{:.0}% (paper +10%)",
        (1.0 - ours.memory_mb / sfl_r.memory_mb) * 100.0,
        (ours.memory_mb / sl.memory_mb - 1.0) * 100.0
    );
    println!(
        "time vs SL: -{:.0}% (paper -41%) | time vs SFL: -{:.1}% (paper -6.1%)",
        (1.0 - ours.total_time() / sl.total_time()) * 100.0,
        (1.0 - ours.total_time() / sfl_r.total_time()) * 100.0
    );
    Ok(())
}
