//! Quickstart: the smallest end-to-end use of the public API.
//!
//! Loads the `mini` artifacts, builds the paper's six-device fleet, runs
//! the memory-efficient SFL scheme (Alg. 1 + Alg. 2) for a few rounds,
//! and prints the loss curve + run summary.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use sfl::config::ExperimentConfig;
use sfl::coordinator::Trainer;
use sfl::runtime::Engine;
use sfl::telemetry;
use std::path::Path;

fn main() -> Result<()> {
    // 1. Load the AOT artifacts (HLO text compiled by `make artifacts`).
    let engine = Engine::load(Path::new("artifacts"), "mini")?;
    println!(
        "model: {} layers, hidden {}, batch {}",
        engine.dims().layers,
        engine.dims().hidden,
        engine.dims().batch
    );

    // 2. Configure the experiment: paper fleet, proposed scheduler.
    let mut cfg = ExperimentConfig::mini();
    cfg.train.max_rounds = 10;
    cfg.train.steps_per_round = 2;
    cfg.train.eval_interval = 2;
    cfg.train.lr = 5e-3;

    // 3. Train.
    let mut trainer = Trainer::new(&engine, &cfg)?;
    println!("cut assignment: {:?}", trainer.cuts());
    let result = trainer.run(false)?;

    // 4. Report.
    println!("\nloss curve:");
    for r in &result.rounds {
        println!("  round {:2}  t={:7.1}s  loss={:.4}", r.round, r.sim_time, r.mean_loss);
    }
    println!("\n{}", telemetry::summary("quickstart", &result));
    Ok(())
}
