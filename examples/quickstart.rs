//! Quickstart: the smallest end-to-end use of the Session API.
//!
//! Loads the `mini` artifacts, builds the paper's six-device fleet, and
//! drives the memory-efficient SFL scheme (Alg. 1 + Alg. 2) round by
//! round with `Session::step_round`, streaming progress through a
//! `RoundObserver`, then prints the loss curve + run summary.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use sfl::config::ExperimentConfig;
use sfl::coordinator::Session;
use sfl::runtime::Engine;
use sfl::telemetry::{self, StdoutObserver};
use std::path::Path;

fn main() -> Result<()> {
    // 1. Load the AOT artifacts (HLO text compiled by `make artifacts`).
    let engine = Engine::load(Path::new("artifacts"), "mini")?;
    println!(
        "model: {} layers, hidden {}, batch {}",
        engine.dims().layers,
        engine.dims().hidden,
        engine.dims().batch
    );

    // 2. Configure the experiment: paper fleet, proposed scheduler.
    let mut cfg = ExperimentConfig::mini();
    cfg.train.max_rounds = 10;
    cfg.train.steps_per_round = 2;
    cfg.train.eval_interval = 2;
    cfg.train.lr = 5e-3;

    // 3. Train, one observable round at a time.  `run_to_convergence()`
    //    wraps this loop when round-level control isn't needed.
    let mut session = Session::new(&engine, &cfg)?;
    session.add_observer(Box::new(StdoutObserver));
    println!("cut assignment: {:?}", session.cuts());
    while !session.done() {
        let report = session.step_round()?;
        // The report is also available programmatically per round:
        if report.round == 1 {
            println!("  (round 1 trained {} participants)", report.participants.len());
        }
    }
    let result = session.result();

    // 4. Report.
    println!("\nloss curve:");
    for r in &result.rounds {
        println!("  round {:2}  t={:7.1}s  loss={:.4}", r.round, r.sim_time, r.mean_loss);
    }
    println!("\n{}", telemetry::summary("quickstart", &result));
    Ok(())
}
