//! Memory deep-dive (Table I column 1 + the §I scalability argument):
//! per-scheme server memory breakdowns across model scale, fleet size,
//! and cut assignment — all analytic, no artifacts needed.
//!
//!     cargo run --release --example memory_analysis

use sfl::devices::paper_fleet;
use sfl::model::{memory, ModelDims};

fn report(dims: &ModelDims, cuts: &[usize], label: &str) {
    let sl = memory::sl_server_memory(dims, cuts);
    let sfl = memory::sfl_server_memory(dims, cuts);
    let ours = memory::ours_server_memory(dims, cuts);
    println!(
        "{label:<28} SL={:>8.1}  SFL={:>8.1}  Ours={:>8.1} MB   SFL/Ours={:.2}x",
        sl.total_mb(),
        sfl.total_mb(),
        ours.total_mb(),
        sfl.total_mb() / ours.total_mb()
    );
}

fn main() {
    let paper_cuts: Vec<usize> = paper_fleet().iter().map(|(_, k)| *k).collect();

    println!("— model scale (paper fleet cuts {paper_cuts:?}) —");
    for dims in [ModelDims::mini(), ModelDims::small(), ModelDims::bert_base()] {
        report(&dims, &paper_cuts, &format!("{} ({}M params)", dims.name, dims.total_params() / 1_000_000));
    }

    println!("\n— fleet size (BERT-base) —");
    let dims = ModelDims::bert_base();
    for mult in [1usize, 2, 4, 8] {
        let cuts: Vec<usize> =
            (0..mult).flat_map(|_| paper_cuts.iter().copied()).collect();
        report(&dims, &cuts, &format!("{} clients", cuts.len()));
    }

    println!("\n— cut assignment (BERT-base, 6 clients) —");
    for (cuts, label) in [
        (vec![1; 6], "all shallow (k=1)"),
        (vec![3; 6], "all deep (k=3)"),
        (paper_cuts.clone(), "paper heterogeneous"),
    ] {
        report(&dims, &cuts, label);
    }

    println!("\n— Ours breakdown (BERT-base, paper fleet) —");
    let b = memory::ours_server_memory(&dims, &paper_cuts);
    println!(
        "  model={:.1} MB  activations={:.1} MB  lora_states={:.1} MB  buffers={:.1} MB",
        b.model_params / 1048576.0,
        b.activations / 1048576.0,
        b.lora_states / 1048576.0,
        b.buffers / 1048576.0
    );
    println!(
        "  -> the full-model reuse means adding a client costs only {:.1} MB (LoRA + buffer)",
        (memory::lora_state_bytes(&dims, dims.layers - 1, true)
            + dims.activation_bytes() as f64)
            / 1048576.0
    );

    println!("\n— client-side memory by cut (BERT-base) —");
    for k in 1..=3 {
        let c = memory::client_memory(&dims, k);
        println!("  k={k}: {:.1} MB (model {:.1} + acts {:.1} + lora {:.1} + buf {:.1})",
            c.total_mb(),
            c.model_params / 1048576.0,
            c.activations / 1048576.0,
            c.lora_states / 1048576.0,
            c.buffers / 1048576.0);
    }
}
