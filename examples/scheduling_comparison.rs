//! Scheduling deep-dive (paper §IV / Fig. 2c): compares the proposed
//! Alg. 2 order against FIFO, workload-first, and random — first
//! analytically on growing fleets, then with a short numeric run that
//! shows the identical-learning / different-time behaviour.
//!
//!     cargo run --release --example scheduling_comparison

use anyhow::Result;
use sfl::config::{ClientConfig, ExperimentConfig, SchedulerKind};
use sfl::coordinator::scheduler::{make_scheduler, JobInfo};
use sfl::coordinator::{timing, Session};
use sfl::devices::paper_fleet;
use sfl::net::Link;
use sfl::runtime::Engine;
use std::path::Path;

fn fleet(mult: usize) -> (Vec<ClientConfig>, Vec<usize>) {
    let mut clients = Vec::new();
    let mut cuts = Vec::new();
    for _ in 0..mult {
        for (d, k) in paper_fleet() {
            clients.push(ClientConfig { device: d, cut: Some(k), link: Link::paper_default() });
            cuts.push(k);
        }
    }
    (clients, cuts)
}

fn main() -> Result<()> {
    let cfg = ExperimentConfig::paper();
    let dims = cfg.timing_dims();

    println!("— analytic per-step makespan (BERT-base dims, paper fleet xN) —\n");
    println!("{:>7} {:>11} {:>11} {:>11} {:>11}  best", "clients", "proposed", "fifo", "wf", "random");
    for mult in [1, 2, 3, 4, 6, 8] {
        let (clients, cuts) = fleet(mult);
        let mut row = format!("{:>7}", clients.len());
        let mut best = ("", f64::INFINITY);
        for kind in [
            SchedulerKind::Proposed,
            SchedulerKind::Fifo,
            SchedulerKind::WorkloadFirst,
            SchedulerKind::Random,
        ] {
            let mut s = make_scheduler(kind, 7);
            let (t, _) = timing::ours_step(&dims, &clients, &cuts, &cfg.server, s.as_mut());
            row.push_str(&format!(" {t:>11.3}"));
            if t < best.1 {
                best = (s.name(), t);
            }
        }
        println!("{row}  {}", best.0);
    }

    // Show the actual Alg. 2 ordering on the paper fleet.
    println!("\n— Alg. 2 order on the paper fleet (desc N_c/C) —");
    let (clients, cuts) = fleet(1);
    let jobs: Vec<JobInfo> = timing::build_jobs(&dims, &clients, &cuts, &cfg.server);
    let mut s = make_scheduler(SchedulerKind::Proposed, 0);
    for &u in &s.order(&jobs) {
        let j = &jobs[u];
        println!(
            "  {:22} cut={} N_c={} C={:5.3} TFLOPS  N_c/C={:.2}  T_b={:.2}s",
            clients[u].device.name,
            cuts[u],
            j.n_client_adapters,
            j.compute_capability,
            j.n_client_adapters as f64 / j.compute_capability,
            j.client_bwd_time,
        );
    }

    // Short numeric confirmation: same losses, different virtual time.
    println!("\n— numeric runs (mini artifacts, 4 rounds): same curve, shifted time —");
    let engine = Engine::load(Path::new("artifacts"), "mini")?;
    for kind in [SchedulerKind::Proposed, SchedulerKind::Fifo, SchedulerKind::WorkloadFirst] {
        let mut c = ExperimentConfig::mini();
        c.scheduler = kind;
        c.train.max_rounds = 4;
        c.train.eval_batches = 4;
        let r = Session::new(&engine, &c)?.run_to_convergence()?;
        let last = r.rounds.last().unwrap();
        println!(
            "  {kind:<16} final loss={:.4}  virtual time={:.1}s",
            last.mean_loss, last.sim_time
        );
    }
    Ok(())
}
