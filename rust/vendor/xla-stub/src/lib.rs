//! Offline stub of the PJRT surface of the `xla` crate (0.1.6) that
//! `sfl::runtime` links against.
//!
//! Scope: everything host-side — literal creation from untyped bytes,
//! typed readback, shapes — behaves like the real crate, so marshaling
//! code and its tests run anywhere.  Device-side entry points
//! (HLO parsing, compilation, execution) return an explanatory error:
//! they need the real PJRT runtime, which this offline workspace does
//! not ship.  Swap `xla = { path = "vendor/xla-stub" }` for
//! `xla = "0.1.6"` in rust/Cargo.toml to run against real PJRT — the
//! API is call-compatible, no source changes needed.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

type XlaResult<T> = std::result::Result<T, Error>;

fn stub_unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is unavailable in the offline xla stub — swap rust/Cargo.toml's \
         `xla` path dependency for the real `xla = \"0.1.6\"` crate to run PJRT"
    ))
}

/// Element dtypes the artifacts use (subset of the real enum).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Native Rust types a literal can be read back into.
pub trait NativeType: Copy {
    const ELEMENT: ElementType;
    fn from_le_bytes(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const ELEMENT: ElementType = ElementType::F32;
    fn from_le_bytes(b: [u8; 4]) -> Self {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    const ELEMENT: ElementType = ElementType::S32;
    fn from_le_bytes(b: [u8; 4]) -> Self {
        i32::from_le_bytes(b)
    }
}

/// A host-resident literal: dtype + shape + packed little-endian bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> XlaResult<Self> {
        let numel: usize = shape.iter().product();
        if data.len() != numel * 4 {
            return Err(Error(format!(
                "literal data is {} bytes but shape {shape:?} needs {}",
                data.len(),
                numel * 4
            )));
        }
        Ok(Self { ty, shape: shape.to_vec(), data: data.to_vec() })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn to_vec<T: NativeType>(&self) -> XlaResult<Vec<T>> {
        if T::ELEMENT != self.ty {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::ELEMENT
            )));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| T::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_tuple(&self) -> XlaResult<Vec<Literal>> {
        Err(stub_unavailable("tuple literal decomposition"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> XlaResult<Self> {
        Err(stub_unavailable("HLO text parsing"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self
    }
}

pub struct PjRtClient;

impl PjRtClient {
    /// Succeeds so that host-only paths (`Engine::load`, params/frozen
    /// staging) work against the stub; the first compile reports the
    /// missing runtime instead.
    pub fn cpu() -> XlaResult<Self> {
        Ok(Self)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        Err(stub_unavailable("XLA compilation"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(stub_unavailable("PJRT execution"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Err(stub_unavailable("device → host literal transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.5f32, -2.0, 0.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.shape(), &[3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert!(lit.to_vec::<i32>().is_err(), "dtype-checked readback");
    }

    #[test]
    fn literal_roundtrip_i32_scalar() {
        let bytes = 7i32.to_le_bytes();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[], &bytes).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn byte_length_validated() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4]).is_err()
        );
    }

    #[test]
    fn device_paths_report_stub() {
        let err = HloModuleProto::from_text_file("x.hlo").unwrap_err();
        assert!(err.to_string().contains("offline xla stub"), "{err}");
        assert!(PjRtClient::cpu().is_ok());
    }
}
