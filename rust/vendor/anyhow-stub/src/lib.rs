//! Offline stand-in for the subset of the `anyhow` crate this
//! workspace uses: `Error`, `Result<T>`, the `anyhow!`/`bail!` macros,
//! and the `Context` extension trait for `Result` and `Option`.
//!
//! The workspace builds with no network access, so external crates are
//! vendored as API-compatible stubs (see rust/Cargo.toml).  Swapping in
//! the real `anyhow = "1"` requires no source changes.

use std::fmt;

/// A string-backed error: message chains are flattened eagerly instead
/// of kept as a source chain (sufficient for this workspace's
/// error-reporting needs).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string() }
    }

    /// Flattened context chain (outermost first), mirroring how the
    /// real anyhow renders `{:#}`/`Debug`.
    pub fn to_string_chain(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent alongside core's reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Attach context to errors (`Result`) or missing values (`Option`).
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("boom {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
        assert_eq!(format!("{e:?}"), "boom 42");
    }

    #[test]
    fn from_std_error_via_question_mark() {
        fn parse() -> Result<i32> {
            let v: i32 = "nope".parse()?;
            Ok(v)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let o: Option<u8> = None;
        assert_eq!(o.with_context(|| "missing").unwrap_err().to_string(), "missing");
        let some: Option<u8> = Some(7);
        assert_eq!(some.context("x").unwrap(), 7);
    }

    #[test]
    fn anyhow_macro_forms() {
        assert_eq!(anyhow!("plain").to_string(), "plain");
        assert_eq!(anyhow!("v={}", 3).to_string(), "v=3");
        let owned = String::from("owned");
        assert_eq!(anyhow!(owned).to_string(), "owned");
    }
}
