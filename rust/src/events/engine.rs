//! The clock-owning event engine: schedules events with monotone
//! sequence numbers, pops them in deterministic time order, and
//! serializes its complete state (clock, sequence counter, pending
//! events) to flat `u64` words for bit-exact checkpoint/resume.

use super::queue::{EventQueue, Scheduled};
use super::Event;
use anyhow::{bail, Result};

/// Words per serialized queue entry: time bits, seq, kind, payload.
const ENTRY_WORDS: usize = 4;

/// Discrete-event engine (see module docs).  `now` only moves forward:
/// it is set to each popped event's fire time, and [`EventEngine::set_now`]
/// lets the driver accrue post-event phases (e.g. aggregation time)
/// that happen outside the queue.
#[derive(Debug, Default)]
pub struct EventEngine {
    queue: EventQueue,
    /// Next sequence number — monotone over the engine's lifetime so
    /// FIFO tie-breaks survive checkpoint/resume.
    seq: u64,
    now: f64,
}

impl EventEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current sim clock.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance the clock outside the queue (post-event accruals).
    /// Refuses to move backwards — time only flows one way.
    pub fn set_now(&mut self, t: f64) {
        debug_assert!(t >= self.now, "engine clock may not move backwards");
        self.now = t;
    }

    /// Schedule `event` at absolute time `at`; returns its sequence
    /// number.  Scheduling in the past is a driver bug.
    pub fn schedule(&mut self, at: f64, event: Event) -> u64 {
        debug_assert!(at >= self.now, "event scheduled before the current clock");
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Scheduled { time: at, seq, event });
        seq
    }

    /// Pop the earliest event and advance the clock to its fire time.
    pub fn pop(&mut self) -> Option<Scheduled> {
        let ev = self.queue.pop()?;
        self.now = ev.time;
        Some(ev)
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.queue.peek_time()
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Serialize the full engine state to flat words:
    /// `[seq, now_bits, n_entries, (time_bits, seq, kind, payload)*]`,
    /// entries in pop order (the canonical order — heap layout is not
    /// part of the contract).
    pub fn state(&self) -> Vec<u64> {
        let entries = self.queue.sorted_entries();
        let mut words = Vec::with_capacity(3 + entries.len() * ENTRY_WORDS);
        words.push(self.seq);
        words.push(self.now.to_bits());
        words.push(entries.len() as u64);
        for e in &entries {
            let (kind, payload) = e.event.encode();
            words.push(e.time.to_bits());
            words.push(e.seq);
            words.push(kind);
            words.push(payload);
        }
        words
    }

    /// Restore a state serialized by [`EventEngine::state`] — the
    /// resumed engine pops the identical event sequence.
    pub fn restore_state(&mut self, words: &[u64]) -> Result<()> {
        if words.len() < 3 {
            bail!("event engine state needs ≥3 words, got {}", words.len());
        }
        let n = words[2] as usize;
        if words.len() != 3 + n * ENTRY_WORDS {
            bail!(
                "event engine state declares {n} entries but has {} words",
                words.len()
            );
        }
        self.seq = words[0];
        self.now = f64::from_bits(words[1]);
        self.queue.clear();
        for chunk in words[3..].chunks_exact(ENTRY_WORDS) {
            self.queue.push(Scheduled {
                time: f64::from_bits(chunk[0]),
                seq: chunk[1],
                event: Event::decode(chunk[2], chunk[3])?,
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pop_advances_the_clock() {
        let mut e = EventEngine::new();
        e.schedule(2.5, Event::ClientArrival { client: 1 });
        e.schedule(1.5, Event::ClientArrival { client: 0 });
        assert_eq!(e.now(), 0.0);
        assert_eq!(e.pop().unwrap().time, 1.5);
        assert_eq!(e.now(), 1.5);
        e.set_now(2.0);
        assert_eq!(e.pop().unwrap().time, 2.5);
        assert_eq!(e.now(), 2.5);
        assert!(e.pop().is_none());
    }

    #[test]
    fn sequence_numbers_are_monotone_across_pops() {
        let mut e = EventEngine::new();
        let s0 = e.schedule(1.0, Event::ClientArrival { client: 0 });
        e.pop();
        let s1 = e.schedule(2.0, Event::ClientArrival { client: 1 });
        assert!(s1 > s0, "seq must never reset while the engine lives");
    }

    #[test]
    fn state_roundtrip_reproduces_the_exact_pop_order() {
        let mut a = EventEngine::new();
        // Same fire time for three events — the FIFO tie-break must
        // survive serialization.
        a.schedule(3.0, Event::ClientCompletion { client: 4 });
        a.schedule(1.0, Event::ClientArrival { client: 2 });
        a.schedule(3.0, Event::AggregationTrigger { epoch: 9 });
        a.schedule(3.0, Event::AvailabilityFlip { client: 7 });
        a.pop(); // consume the arrival; clock = 1.0
        let words = a.state();

        let mut b = EventEngine::new();
        b.restore_state(&words).unwrap();
        assert_eq!(b.now(), a.now());
        let mut popped_a = Vec::new();
        let mut popped_b = Vec::new();
        while let Some(ev) = a.pop() {
            popped_a.push((ev.time.to_bits(), ev.seq, ev.event));
        }
        while let Some(ev) = b.pop() {
            popped_b.push((ev.time.to_bits(), ev.seq, ev.event));
        }
        assert_eq!(popped_a, popped_b);
        // New schedules on the restored engine continue the seq stream.
        let s = b.schedule(10.0, Event::ClientArrival { client: 0 });
        assert_eq!(s, 4, "restored seq counter continues where it left off");
    }

    #[test]
    fn restore_rejects_malformed_words() {
        let mut e = EventEngine::new();
        assert!(e.restore_state(&[0, 0]).is_err());
        assert!(e.restore_state(&[0, 0, 2, 1, 2, 3, 4]).is_err());
        // Unknown event kind tag.
        assert!(e.restore_state(&[0, 0, 1, 0, 0, 9, 0]).is_err());
    }
}
