//! Closed-form async-vs-sync testbed: heterogeneous clients descend
//! per-client quadratic objectives under a real environment trace, and
//! we measure the sim time each mode needs to pull the global model
//! within `target` of the optimum.
//!
//! - **Sync** replays the session's barrier semantics: every available
//!   client trains from the current global model, the round costs the
//!   *maximum* per-client round time (one straggler stalls the world),
//!   and the round's updates merge by uniform FedAvg.
//! - **Async** runs the real [`EventEngine`]: clients dispatch, train
//!   eagerly from the model version they were handed, complete at
//!   their own pace, and the server merges whenever `buffer_k` updates
//!   are buffered or the oldest has waited `staleness_bound` — with
//!   `1/(1+s)^β` staleness decay and the dispatch-baseline re-centering
//!   the session applies (stale absolute updates are corrected by
//!   `b_now − b_dispatch` so they inject their *delta*, not their
//!   stale baseline).
//!
//! Per-client local training has a closed form — `steps` gradient
//! steps on `½‖x − x*_u‖²` contract `x` toward `x*_u` by
//! `(1−lr)^steps` — so no numeric artifacts are needed; the whole
//! world is a few hundred f64s.  Client optima cluster tightly around
//! the global optimum while the start point is far away, so both modes
//! converge to the same place and the measured difference is pure
//! pacing: the barrier pays the straggler tax, buffered-async does
//! not.  `benches/async_churn.rs` and `tests/events_async.rs` assert
//! the acceptance gate on this world: async strictly beats sync on
//! time-to-target under markov churn.

use super::{staleness_weight, BufferedUpdate, Event, EventEngine, UpdateBuffer, VersionVector};
use crate::tensor::rng::Rng;
use crate::trace::{EnvTimeline, TraceSpec};
use anyhow::{bail, Result};

/// One async-vs-sync world (see module docs).
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Fleet size.
    pub n: usize,
    /// Model dimension.
    pub dim: usize,
    /// Per-step learning rate on the quadratic, in (0, 1).
    pub lr: f64,
    /// Local steps per dispatch (sync: per round).
    pub steps: usize,
    /// Async merge threshold K.
    pub buffer_k: usize,
    /// Async staleness bound τ (sim seconds).
    pub staleness_bound: f64,
    /// Staleness-decay exponent β.
    pub staleness_beta: f64,
    /// Relative distance to the optimum that counts as "target hit".
    pub target: f64,
    /// Give-up horizon (sim seconds).
    pub max_time: f64,
    pub seed: u64,
    /// Lognormal σ of per-client base round times — the heterogeneity
    /// that makes the barrier's straggler tax real.
    pub speed_sigma: f64,
    /// Environment trace (markov churn / diurnal slowdowns) applied to
    /// both modes via [`EnvTimeline`].
    pub trace: TraceSpec,
}

impl Default for Scenario {
    fn default() -> Self {
        Self {
            n: 32,
            dim: 8,
            lr: 0.25,
            steps: 4,
            buffer_k: 4,
            staleness_bound: 240.0,
            staleness_beta: 0.5,
            target: 0.05,
            max_time: 1.0e7,
            seed: 11,
            speed_sigma: 1.0,
            trace: TraceSpec::default(),
        }
    }
}

/// What one testbed run reports.
#[derive(Debug, Clone, Copy)]
pub struct Outcome {
    /// Sim time when the relative distance first dropped to `target`
    /// (`max_time` if it never did).
    pub time_to_target: f64,
    /// Merges performed (async) / rounds executed (sync) until then.
    pub merges: u64,
    /// Relative distance when the run stopped.
    pub final_rel: f64,
    /// Largest per-update staleness observed (0 in sync mode).
    pub max_staleness: u64,
}

/// The deterministic world both modes share: per-client base round
/// times (lognormal heterogeneity) and per-client optima clustered
/// around the global optimum.
struct World {
    base_time: Vec<f64>,
    optima: Vec<Vec<f64>>,
    mean_opt: Vec<f64>,
    d0: f64,
    shrink: f64,
}

impl World {
    fn new(sc: &Scenario) -> Result<Self> {
        if sc.n == 0 || sc.dim == 0 || sc.steps == 0 {
            bail!("testbed needs n, dim, steps ≥ 1");
        }
        if !(0.0 < sc.lr && sc.lr < 1.0) {
            bail!("testbed lr must be in (0, 1), got {}", sc.lr);
        }
        if sc.buffer_k == 0 || sc.buffer_k > sc.n {
            bail!("buffer_k must be in [1, n], got {}", sc.buffer_k);
        }
        let mut rng = Rng::new(sc.seed);
        // Median base round time ~30 s; σ=1 spreads the slowest of 32
        // clients to ~10× the median — the straggler tax.
        let base_time: Vec<f64> =
            (0..sc.n).map(|_| rng.lognormal(30f64.ln(), sc.speed_sigma)).collect();
        // Optima cluster within 5% of the start-to-optimum distance, so
        // subset merges stay unbiased at the target resolution.
        let optima: Vec<Vec<f64>> = (0..sc.n)
            .map(|_| (0..sc.dim).map(|_| 1.0 + 0.05 * rng.normal()).collect())
            .collect();
        let mean_opt: Vec<f64> = (0..sc.dim)
            .map(|i| optima.iter().map(|o| o[i]).sum::<f64>() / sc.n as f64)
            .collect();
        // Start at the origin; ‖w0 − w̄*‖ is the unit of "distance".
        let d0 = mean_opt.iter().map(|x| x * x).sum::<f64>().sqrt();
        if d0 <= 0.0 {
            bail!("degenerate testbed: start equals the optimum");
        }
        Ok(Self {
            base_time,
            optima,
            mean_opt,
            d0,
            shrink: (1.0 - sc.lr).powi(sc.steps as i32),
        })
    }

    /// `steps` gradient steps on `½‖x − x*_u‖²` starting from `from`,
    /// in closed form.
    fn local_train(&self, u: usize, from: &[f64], out: &mut [f64]) {
        for i in 0..from.len() {
            let opt = self.optima[u][i];
            out[i] = opt + self.shrink * (from[i] - opt);
        }
    }

    fn rel(&self, w: &[f64]) -> f64 {
        let d: f64 = w
            .iter()
            .zip(self.mean_opt.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        d / self.d0
    }

    /// One client's wall time for a local round at the current trace
    /// sample (slower MFU ⇒ proportionally longer round).
    fn round_time(&self, u: usize, steps: usize, tl: &EnvTimeline) -> f64 {
        steps as f64 * self.base_time[u] / tl.mfu_mult(u)
    }

    fn median_base(&self) -> f64 {
        let mut b = self.base_time.clone();
        b.sort_unstable_by(f64::total_cmp);
        b[b.len() / 2]
    }
}

/// The synchronous barrier baseline.
pub fn run_sync(sc: &Scenario) -> Result<Outcome> {
    let world = World::new(sc)?;
    let mut tl = EnvTimeline::new(&sc.trace, sc.n)?;
    let mut w = vec![0.0f64; sc.dim];
    let mut x = vec![0.0f64; sc.dim];
    let mut next = vec![0.0f64; sc.dim];
    let mut t = 0.0f64;
    let mut rounds = 0u64;
    let retry = world.median_base();
    while t < sc.max_time {
        if tl.is_active() {
            tl.advance(t);
        }
        let participants: Vec<usize> = (0..sc.n).filter(|&u| tl.is_available(u)).collect();
        if participants.is_empty() {
            // Total blackout: the barrier waits it out.
            t += retry;
            continue;
        }
        // The barrier: the round costs the slowest participant.
        let duration = participants
            .iter()
            .map(|&u| world.round_time(u, sc.steps, &tl))
            .fold(0.0f64, f64::max);
        next.iter_mut().for_each(|v| *v = 0.0);
        for &u in &participants {
            world.local_train(u, &w, &mut x);
            for i in 0..sc.dim {
                next[i] += x[i] / participants.len() as f64;
            }
        }
        w.copy_from_slice(&next);
        t += duration;
        rounds += 1;
        if world.rel(&w) <= sc.target {
            return Ok(Outcome {
                time_to_target: t,
                merges: rounds,
                final_rel: world.rel(&w),
                max_staleness: 0,
            });
        }
    }
    Ok(Outcome {
        time_to_target: sc.max_time,
        merges: rounds,
        final_rel: world.rel(&w),
        max_staleness: 0,
    })
}

/// The buffered-async mode on the real [`EventEngine`], mirroring the
/// session's merge algebra on plain vectors.
pub fn run_async(sc: &Scenario) -> Result<Outcome> {
    let world = World::new(sc)?;
    let mut tl = EnvTimeline::new(&sc.trace, sc.n)?;
    let mut engine = EventEngine::new();
    let mut versions = VersionVector::new(sc.n);
    let mut buffer = UpdateBuffer::new();
    // Baseline history: `bases[v]` is the model at version v — what a
    // client dispatched at version v trained from.
    let mut bases: Vec<Vec<f64>> = vec![vec![0.0f64; sc.dim]];
    let mut pending: Vec<Vec<f64>> = vec![vec![0.0f64; sc.dim]; sc.n];
    let mut epoch = 0u64;
    let mut merges = 0u64;
    let mut max_staleness = 0u64;
    for u in 0..sc.n {
        engine.schedule(0.0, Event::ClientArrival { client: u });
    }
    while let Some(ev) = engine.pop() {
        let t = ev.time;
        if t > sc.max_time {
            break;
        }
        match ev.event {
            Event::ClientArrival { client: u } | Event::AvailabilityFlip { client: u } => {
                if tl.is_active() {
                    tl.advance(t);
                    if !tl.is_available(u) {
                        engine.schedule(
                            t + world.base_time[u],
                            Event::AvailabilityFlip { client: u },
                        );
                        continue;
                    }
                }
                // Dispatch: train eagerly from the current model (the
                // latest baseline IS the global model between merges).
                versions.mark_dispatch(u);
                let from = bases
                    .last()
                    .ok_or_else(|| anyhow::anyhow!("baseline history is empty"))?
                    .clone();
                world.local_train(u, &from, &mut pending[u]);
                let duration = world.round_time(u, sc.steps, &tl);
                engine.schedule(t + duration, Event::ClientCompletion { client: u });
            }
            Event::ClientCompletion { client: u } => {
                buffer.push(BufferedUpdate {
                    client: u,
                    version: versions.client_version(u),
                    loss: 0.0,
                    completed_at: t,
                });
                if buffer.len() >= sc.buffer_k {
                    // fall through to merge below
                } else {
                    if buffer.len() == 1 {
                        epoch += 1;
                        engine.schedule(
                            t + sc.staleness_bound,
                            Event::AggregationTrigger { epoch },
                        );
                    }
                    continue;
                }
            }
            Event::AggregationTrigger { epoch: e } => {
                if e != epoch || buffer.is_empty() {
                    continue; // stale trigger: its buffer already merged
                }
            }
            // Lossless testbed: the channel's retransmission machinery
            // never schedules here.
            Event::Timeout { .. } | Event::Retransmit { .. } => continue,
        }
        // ---- merge the buffer ----
        let cur = versions.model_version();
        let raws: Vec<f64> = buffer
            .entries()
            .iter()
            .map(|b| staleness_weight(cur - b.version, sc.staleness_beta) / sc.n as f64)
            .collect();
        let total: f64 = raws.iter().sum();
        let mut next = vec![0.0f64; sc.dim];
        for (b, &raw) in buffer.entries().iter().zip(raws.iter()) {
            let wgt = raw / total;
            let s = cur - b.version;
            max_staleness = max_staleness.max(s);
            for i in 0..sc.dim {
                next[i] += wgt * pending[b.client][i];
                if s > 0 {
                    // Re-center against the dispatch baseline: inject
                    // the client's delta, not its stale starting point.
                    next[i] += wgt * (bases[cur as usize][i] - bases[b.version as usize][i]);
                }
            }
        }
        // Merged clients go straight back to work.
        for b in buffer.entries() {
            engine.schedule(t, Event::ClientArrival { client: b.client });
        }
        buffer.clear();
        epoch += 1; // invalidate any armed τ trigger
        versions.advance_model();
        bases.push(next.clone());
        merges += 1;
        if world.rel(&next) <= sc.target {
            return Ok(Outcome {
                time_to_target: t,
                merges,
                final_rel: world.rel(&next),
                max_staleness,
            });
        }
    }
    let last = bases.last().ok_or_else(|| anyhow::anyhow!("baseline history is empty"))?;
    Ok(Outcome {
        time_to_target: sc.max_time,
        merges,
        final_rel: world.rel(last),
        max_staleness,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceKind;

    fn markov_scenario() -> Scenario {
        Scenario {
            trace: TraceSpec { kind: TraceKind::Markov, ..TraceSpec::default() },
            ..Scenario::default()
        }
    }

    #[test]
    fn both_modes_reach_the_target() {
        let sc = markov_scenario();
        let s = run_sync(&sc).unwrap();
        let a = run_async(&sc).unwrap();
        assert!(s.time_to_target < sc.max_time, "sync never converged");
        assert!(a.time_to_target < sc.max_time, "async never converged");
        assert!(s.final_rel <= sc.target);
        assert!(a.final_rel <= sc.target);
        assert!(a.merges > 0 && s.merges > 0);
    }

    #[test]
    fn async_beats_sync_under_markov_churn() {
        // The acceptance gate (also asserted in benches/async_churn.rs):
        // buffered-async reaches the target strictly faster than the
        // barrier on a heterogeneous markov-churn fleet.
        let sc = markov_scenario();
        let s = run_sync(&sc).unwrap();
        let a = run_async(&sc).unwrap();
        assert!(
            a.time_to_target < s.time_to_target,
            "async {:.1}s must beat sync {:.1}s under markov churn",
            a.time_to_target,
            s.time_to_target
        );
    }

    #[test]
    fn runs_are_deterministic() {
        let sc = markov_scenario();
        let a1 = run_async(&sc).unwrap();
        let a2 = run_async(&sc).unwrap();
        assert_eq!(a1.time_to_target.to_bits(), a2.time_to_target.to_bits());
        assert_eq!(a1.merges, a2.merges);
        let s1 = run_sync(&sc).unwrap();
        let s2 = run_sync(&sc).unwrap();
        assert_eq!(s1.time_to_target.to_bits(), s2.time_to_target.to_bits());
    }

    #[test]
    fn tighter_staleness_bound_still_converges() {
        let mut sc = markov_scenario();
        sc.staleness_bound = 60.0;
        let a = run_async(&sc).unwrap();
        assert!(a.time_to_target < sc.max_time);
    }
}
