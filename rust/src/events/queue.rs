//! The deterministic event queue: a binary min-heap keyed on the sim
//! clock with FIFO tie-breaking by sequence number.
//!
//! Determinism contract: two events at the *bit-identical* same time
//! pop in the order they were scheduled (`seq` is monotone), and time
//! ordering uses `f64::total_cmp`, so the pop order is a pure function
//! of the push sequence — never of heap internals or platform float
//! quirks.  This is what makes event-driven trajectories replayable
//! and checkpoints bit-exact.

use super::Event;
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

/// One scheduled event: fire time, schedule order, payload.
#[derive(Debug, Clone, Copy)]
pub struct Scheduled {
    /// Absolute sim time the event fires at.
    pub time: f64,
    /// Monotone schedule counter — the FIFO tie-break at equal times.
    pub seq: u64,
    pub event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Earliest time first; at bit-equal times, lowest seq first.
        self.time.total_cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// Min-heap of [`Scheduled`] events (see module docs for the
/// determinism contract).
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Scheduled>>,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn push(&mut self, ev: Scheduled) {
        self.heap.push(Reverse(ev));
    }

    /// Remove and return the earliest event (FIFO among time ties).
    pub fn pop(&mut self) -> Option<Scheduled> {
        self.heap.pop().map(|Reverse(ev)| ev)
    }

    /// Fire time of the earliest pending event.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse(ev)| ev.time)
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }

    /// All pending events in pop order, without disturbing the queue —
    /// the canonical serialization order (heap layout is an
    /// implementation detail; pop order is the contract).
    pub fn sorted_entries(&self) -> Vec<Scheduled> {
        let mut entries: Vec<Scheduled> =
            self.heap.iter().map(|Reverse(ev)| *ev).collect();
        entries.sort_unstable();
        entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(time: f64, seq: u64, client: usize) -> Scheduled {
        Scheduled { time, seq, event: Event::ClientArrival { client } }
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(ev(3.0, 0, 0));
        q.push(ev(1.0, 1, 1));
        q.push(ev(2.0, 2, 2));
        let order: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|e| e.time).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
        assert!(q.is_empty());
    }

    #[test]
    fn equal_times_break_ties_fifo() {
        let mut q = EventQueue::new();
        // Push in scrambled seq order at the bit-identical same time.
        q.push(ev(5.0, 2, 2));
        q.push(ev(5.0, 0, 0));
        q.push(ev(5.0, 1, 1));
        let clients: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|e| match e.event {
                Event::ClientArrival { client } => client,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(clients, vec![0, 1, 2], "FIFO by seq at equal times");
    }

    #[test]
    fn sorted_entries_matches_pop_order_and_preserves_queue() {
        let mut q = EventQueue::new();
        for (t, s) in [(2.0, 0u64), (1.0, 1), (1.0, 2), (4.0, 3)] {
            q.push(ev(t, s, s as usize));
        }
        let snap: Vec<(u64, u64)> =
            q.sorted_entries().iter().map(|e| (e.time.to_bits(), e.seq)).collect();
        assert_eq!(q.len(), 4, "snapshot must not consume the queue");
        let popped: Vec<(u64, u64)> =
            std::iter::from_fn(|| q.pop()).map(|e| (e.time.to_bits(), e.seq)).collect();
        assert_eq!(snap, popped);
    }

    #[test]
    fn peek_time_tracks_the_minimum() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(ev(7.0, 0, 0));
        q.push(ev(3.0, 1, 1));
        assert_eq!(q.peek_time(), Some(3.0));
        q.pop();
        assert_eq!(q.peek_time(), Some(7.0));
    }
}
