//! Bounded-staleness aggregation primitives: the per-client version
//! vector, the buffered-update set, and the staleness-decay weight.
//!
//! The buffered-async scheme merges whenever `K` updates are buffered
//! or the oldest buffered update has waited `τ` sim-seconds.  Each
//! update is weighted by its data weight **times** the staleness decay
//! `1/(1+s)^β`, where `s` is the number of model versions the global
//! model advanced between the update's dispatch and its merge — the
//! polynomial staleness function from the FedAsync line of work.  The
//! version vector additionally records *which* baseline each update
//! was computed from, so the merge can re-center stale absolute
//! updates against their dispatch baseline (see the session's
//! staleness correction).

use anyhow::{bail, Result};

/// The staleness-decay factor `1/(1+s)^β`.  `s = 0` or `β = 0` ⇒ 1
/// exactly (a fresh update, or decay disabled, carries full weight).
pub fn staleness_weight(staleness: u64, beta: f64) -> f64 {
    if staleness == 0 || beta == 0.0 {
        return 1.0;
    }
    1.0 / (1.0 + staleness as f64).powf(beta)
}

/// Per-client model-version bookkeeping: `model` counts completed
/// merges; `clients[u]` is the model version client `u` was last
/// dispatched from.
#[derive(Debug, Clone)]
pub struct VersionVector {
    model: u64,
    clients: Vec<u64>,
}

impl VersionVector {
    pub fn new(n: usize) -> Self {
        Self { model: 0, clients: vec![0; n] }
    }

    pub fn model_version(&self) -> u64 {
        self.model
    }

    pub fn client_version(&self, u: usize) -> u64 {
        self.clients[u]
    }

    /// Stamp client `u` with the current model version at dispatch.
    pub fn mark_dispatch(&mut self, u: usize) {
        self.clients[u] = self.model;
    }

    /// Versions the model advanced since `u`'s dispatch.
    pub fn staleness(&self, u: usize) -> u64 {
        self.model - self.clients[u]
    }

    /// One merge completed: the global model moved on.
    pub fn advance_model(&mut self) {
        self.model += 1;
    }

    /// Flat serialization: `[model, clients...]`.
    pub fn state(&self) -> Vec<u64> {
        let mut words = Vec::with_capacity(1 + self.clients.len());
        words.push(self.model);
        words.extend_from_slice(&self.clients);
        words
    }

    pub fn restore_state(&mut self, words: &[u64]) -> Result<()> {
        if words.len() != 1 + self.clients.len() {
            bail!(
                "version vector state has {} words, fleet needs {}",
                words.len(),
                1 + self.clients.len()
            );
        }
        self.model = words[0];
        self.clients.copy_from_slice(&words[1..]);
        Ok(())
    }
}

/// One completed-but-unmerged client update waiting in the buffer.
/// The trained tensors themselves stay in the state pool (protected
/// from baseline redistribution until merged); the buffer carries the
/// metadata the merge needs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BufferedUpdate {
    pub client: usize,
    /// Model version the client was dispatched from.
    pub version: u64,
    /// Mean training loss of the client's local round.
    pub loss: f32,
    /// Sim time the completion event fired.
    pub completed_at: f64,
}

/// The server-side aggregation buffer (FIFO by completion).
#[derive(Debug, Default)]
pub struct UpdateBuffer {
    entries: Vec<BufferedUpdate>,
}

/// Words per serialized buffer entry.
const ENTRY_WORDS: usize = 4;

impl UpdateBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn push(&mut self, u: BufferedUpdate) {
        self.entries.push(u);
    }

    pub fn entries(&self) -> &[BufferedUpdate] {
        &self.entries
    }

    /// Completion time of the oldest buffered update (the τ clock).
    pub fn oldest_completed_at(&self) -> Option<f64> {
        self.entries.first().map(|e| e.completed_at)
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Flat serialization: `[n, (client, version, loss_bits, time_bits)*]`.
    pub fn state(&self) -> Vec<u64> {
        let mut words = Vec::with_capacity(1 + self.entries.len() * ENTRY_WORDS);
        words.push(self.entries.len() as u64);
        for e in &self.entries {
            words.push(e.client as u64);
            words.push(e.version);
            words.push(e.loss.to_bits() as u64);
            words.push(e.completed_at.to_bits());
        }
        words
    }

    pub fn restore_state(&mut self, words: &[u64]) -> Result<()> {
        if words.is_empty() {
            bail!("update buffer state is empty");
        }
        let n = words[0] as usize;
        if words.len() != 1 + n * ENTRY_WORDS {
            bail!("update buffer state declares {n} entries but has {} words", words.len());
        }
        self.entries.clear();
        for chunk in words[1..].chunks_exact(ENTRY_WORDS) {
            self.entries.push(BufferedUpdate {
                client: chunk[0] as usize,
                version: chunk[1],
                loss: f32::from_bits(chunk[2] as u32),
                completed_at: f64::from_bits(chunk[3]),
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decay_is_one_when_fresh_or_disabled() {
        assert_eq!(staleness_weight(0, 0.5), 1.0);
        assert_eq!(staleness_weight(3, 0.0), 1.0);
        assert_eq!(staleness_weight(0, 0.0), 1.0);
    }

    #[test]
    fn decay_is_monotone_in_staleness_and_beta() {
        let w1 = staleness_weight(1, 0.5);
        let w2 = staleness_weight(2, 0.5);
        let w4 = staleness_weight(4, 0.5);
        assert!(w1 > w2 && w2 > w4);
        assert!((w1 - 1.0 / 2f64.sqrt()).abs() < 1e-12);
        // Larger β punishes the same staleness harder.
        assert!(staleness_weight(3, 1.0) < staleness_weight(3, 0.5));
        assert!(staleness_weight(3, 1.0) > 0.0);
    }

    #[test]
    fn version_vector_tracks_staleness() {
        let mut v = VersionVector::new(3);
        v.mark_dispatch(0);
        v.advance_model();
        v.mark_dispatch(1);
        v.advance_model();
        assert_eq!(v.model_version(), 2);
        assert_eq!(v.staleness(0), 2);
        assert_eq!(v.staleness(1), 1);
        assert_eq!(v.client_version(0), 0);

        let words = v.state();
        let mut back = VersionVector::new(3);
        back.restore_state(&words).unwrap();
        assert_eq!(back.model_version(), 2);
        assert_eq!(back.staleness(0), 2);
        assert!(back.restore_state(&words[..2]).is_err());
    }

    #[test]
    fn buffer_state_roundtrips_bit_exactly() {
        let mut b = UpdateBuffer::new();
        b.push(BufferedUpdate { client: 5, version: 2, loss: 0.125, completed_at: 33.5 });
        b.push(BufferedUpdate { client: 1, version: 3, loss: f32::MIN_POSITIVE, completed_at: 40.0 });
        assert_eq!(b.oldest_completed_at(), Some(33.5));
        let words = b.state();
        let mut back = UpdateBuffer::new();
        back.restore_state(&words).unwrap();
        assert_eq!(back.entries(), b.entries());
        assert!(back.restore_state(&words[..3]).is_err());
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.oldest_completed_at(), None);
    }
}
