//! Discrete-event simulation engine for asynchronous federated rounds.
//!
//! Every scheme used to be round-synchronous: the sim clock advanced by
//! the makespan of a barrier'd cohort, so one straggler stalled the
//! whole fleet.  This module provides the event-driven substrate that
//! removes the barrier:
//!
//! - [`queue::EventQueue`] — a binary-heap queue keyed on the sim clock
//!   with deterministic FIFO tie-breaking by monotone sequence number
//!   (same time ⇒ first-scheduled fires first, bit-reproducibly).
//! - [`engine::EventEngine`] — the clock-owning wrapper: schedules
//!   events, pops them in time order, and serializes its entire state
//!   (queue contents, sequence counter, clock) to flat `u64` words for
//!   bit-exact checkpoint/resume.
//! - [`staleness`] — the bounded-staleness aggregation primitives:
//!   per-client version vectors, the buffered-update set, and the
//!   `1/(1+s)^β` staleness-decay weight folded into the existing
//!   FedAvg / robust merge kernels.
//! - [`testbed`] — a closed-form async-vs-sync world (quadratic
//!   objectives, real trace timelines, the real engine) used by
//!   `benches/async_churn.rs` and the artifact-free acceptance tests:
//!   buffered-async must beat the synchronous barrier on
//!   time-to-target-loss under markov churn.
//!
//! The `Session` drives **both** modes through the engine: sync mode
//! expresses its barrier as a single [`Event::AggregationTrigger`]
//! fired at the cohort makespan (bit-identical to the historical
//! `sim_time += train_elapsed` accrual), while `--async` mode runs
//! client arrivals, completions, availability churn, and buffered
//! merges as genuine interleaved events.

pub mod engine;
pub mod queue;
pub mod staleness;
pub mod testbed;

pub use engine::EventEngine;
pub use queue::{EventQueue, Scheduled};
pub use staleness::{staleness_weight, BufferedUpdate, UpdateBuffer, VersionVector};

use anyhow::{bail, Result};

/// One simulation event.  `usize` payloads are global client ids;
/// the aggregation trigger carries an arming epoch so triggers armed
/// for an already-merged buffer are discarded as stale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// A client becomes ready to be dispatched (initial arrival, or
    /// re-dispatch after its update was merged).
    ClientArrival { client: usize },
    /// A dispatched client finishes its local round; its update enters
    /// the aggregation buffer.
    ClientCompletion { client: usize },
    /// Availability re-check for a client that was unavailable (or
    /// dropped out) at its last dispatch attempt.
    AvailabilityFlip { client: usize },
    /// The bounded-staleness timer: merge whatever is buffered.  Fired
    /// `τ` after the first update entered an empty buffer; `epoch`
    /// invalidates triggers that outlived their buffer.
    AggregationTrigger { epoch: u64 },
    /// A client's upload was lost/rejected on the lossy channel: the
    /// retransmission timer for `attempt` (0-based) expires here.
    Timeout { client: usize, attempt: u32 },
    /// The retransmission itself: re-send the client's update (its
    /// `attempt + 1`-th try over the wire).
    Retransmit { client: usize, attempt: u32 },
}

impl Event {
    /// Flat `(kind, payload)` encoding for checkpoint serialization.
    pub fn encode(&self) -> (u64, u64) {
        match *self {
            Event::ClientArrival { client } => (0, client as u64),
            Event::ClientCompletion { client } => (1, client as u64),
            Event::AvailabilityFlip { client } => (2, client as u64),
            Event::AggregationTrigger { epoch } => (3, epoch),
            // Client ids are bounded far below 2^32 (fleet synthesis
            // caps at 100k), so (client, attempt) packs into one word.
            Event::Timeout { client, attempt } => {
                (4, ((client as u64) << 32) | u64::from(attempt))
            }
            Event::Retransmit { client, attempt } => {
                (5, ((client as u64) << 32) | u64::from(attempt))
            }
        }
    }

    /// Inverse of [`Event::encode`].
    pub fn decode(kind: u64, payload: u64) -> Result<Self> {
        Ok(match kind {
            0 => Event::ClientArrival { client: payload as usize },
            1 => Event::ClientCompletion { client: payload as usize },
            2 => Event::AvailabilityFlip { client: payload as usize },
            3 => Event::AggregationTrigger { epoch: payload },
            4 => Event::Timeout {
                client: (payload >> 32) as usize,
                attempt: (payload & 0xFFFF_FFFF) as u32,
            },
            5 => Event::Retransmit {
                client: (payload >> 32) as usize,
                attempt: (payload & 0xFFFF_FFFF) as u32,
            },
            _ => bail!("unknown event kind tag {kind}"),
        })
    }
}

/// Per-merge asynchrony counters, streamed in round reports when
/// `--async` is active (the `"async"` jsonl block).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AsyncStats {
    /// Updates sitting in the buffer when the merge trigger fired.
    pub buffered: usize,
    /// Updates actually merged (equal to `buffered`; server-side
    /// robust rejections are reported in the `robust` block).
    pub merged: usize,
    /// Largest per-update staleness (model versions elapsed since the
    /// update's dispatch) in this merge.
    pub max_staleness: u64,
    /// Absolute engine clock when the merge fired — before the
    /// aggregation-time accrual that `sim_time` includes.
    pub wall_clock: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_encoding_roundtrips() {
        let events = [
            Event::ClientArrival { client: 7 },
            Event::ClientCompletion { client: 0 },
            Event::AvailabilityFlip { client: 123 },
            Event::AggregationTrigger { epoch: u64::MAX },
            Event::Timeout { client: 7, attempt: 0 },
            Event::Timeout { client: 99_999, attempt: u32::MAX },
            Event::Retransmit { client: 0, attempt: 3 },
        ];
        for e in events {
            let (k, p) = e.encode();
            assert_eq!(Event::decode(k, p).unwrap(), e);
        }
        assert!(Event::decode(6, 0).is_err());
    }
}
