//! Synthetic CARER-like emotion-classification corpus + non-IID partition.
//!
//! The paper fine-tunes on CARER (6 emotion classes, tweets).  We cannot
//! ship the real tweets, so the generator produces token sequences whose
//! class-conditional unigram statistics make the task learnable (class
//! "marker" tokens mixed into a shared background distribution), and the
//! Dirichlet partitioner reproduces the Non-IID client shards the paper
//! assumes (§II).  See DESIGN.md §2 for why this preserves the relative
//! scheme behaviour.

use crate::tensor::rng::Rng;

/// One classification example: token ids + label.
#[derive(Debug, Clone, PartialEq)]
pub struct Example {
    pub tokens: Vec<i32>,
    pub label: i32,
}

/// Generator parameters for the synthetic corpus.
#[derive(Debug, Clone)]
pub struct CorpusSpec {
    pub vocab: usize,
    pub seq: usize,
    pub classes: usize,
    /// Number of class-specific marker tokens per class.
    pub markers_per_class: usize,
    /// Probability that a position draws from the class markers rather
    /// than the shared background distribution. Controls task difficulty.
    pub marker_prob: f64,
    pub train_size: usize,
    pub test_size: usize,
    pub seed: u64,
}

impl CorpusSpec {
    /// CARER is ~16k train / 2k test, 6 classes; defaults mirror that at
    /// whatever vocab/seq the model config uses.
    pub fn carer_like(vocab: usize, seq: usize) -> Self {
        Self {
            vocab,
            seq,
            classes: 6,
            markers_per_class: 24.min(vocab / 12),
            marker_prob: 0.18,
            train_size: 16_000,
            test_size: 2_000,
            seed: 7,
        }
    }
}

/// A materialized dataset split.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub train: Vec<Example>,
    pub test: Vec<Example>,
    pub spec: CorpusSpec,
}

/// The class marker tokens are carved out of the top of the vocab so they
/// never collide with the background range.
fn marker_range(spec: &CorpusSpec, class: usize) -> std::ops::Range<i32> {
    let per = spec.markers_per_class;
    let base = spec.vocab - spec.classes * per + class * per;
    base as i32..(base + per) as i32
}

fn gen_example(spec: &CorpusSpec, rng: &mut Rng, label: usize) -> Example {
    let markers = marker_range(spec, label);
    let background = spec.vocab - spec.classes * spec.markers_per_class;
    let tokens = (0..spec.seq)
        .map(|_| {
            if rng.uniform() < spec.marker_prob {
                markers.start + rng.below(spec.markers_per_class) as i32
            } else {
                rng.below(background) as i32
            }
        })
        .collect();
    Example { tokens, label: label as i32 }
}

/// Generate the full corpus. Class priors are mildly imbalanced, like
/// CARER's (joy/sadness dominate, surprise is rare).
pub fn generate(spec: &CorpusSpec) -> Dataset {
    let mut rng = Rng::new(spec.seed);
    let priors: Vec<f64> = (0..spec.classes)
        .map(|c| 1.0 / (1.0 + 0.35 * c as f64))
        .collect();
    let gen_split = |n: usize, rng: &mut Rng| {
        (0..n)
            .map(|_| {
                let label = rng.categorical(&priors);
                gen_example(spec, rng, label)
            })
            .collect::<Vec<_>>()
    };
    let train = gen_split(spec.train_size, &mut rng);
    let test = gen_split(spec.test_size, &mut rng);
    Dataset { train, test, spec: spec.clone() }
}

/// Dirichlet(alpha) non-IID partition of `examples` across `clients`.
/// Lower alpha ⇒ more skewed label distributions per client.
/// Every client is guaranteed at least `min_per_client` examples.
pub fn dirichlet_partition(
    examples: &[Example],
    clients: usize,
    alpha: f64,
    seed: u64,
    min_per_client: usize,
) -> Vec<Vec<usize>> {
    // The rebalance loop below cannot terminate if the floor is
    // infeasible — fail loudly instead of spinning (fleet-scale specs
    // can request more clients than the corpus supports).
    assert!(
        examples.len() >= clients * min_per_client,
        "dirichlet_partition: {} examples cannot give {clients} clients {min_per_client} each",
        examples.len()
    );
    let mut rng = Rng::new(seed);
    let classes = examples.iter().map(|e| e.label).max().unwrap_or(0) as usize + 1;
    // Per-class client mixture.
    let mixtures: Vec<Vec<f64>> =
        (0..classes).map(|_| rng.dirichlet(alpha, clients)).collect();
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); clients];
    for (i, ex) in examples.iter().enumerate() {
        let u = rng.categorical(&mixtures[ex.label as usize]);
        shards[u].push(i);
    }
    // Rebalance: steal from the largest shard until everyone has a floor.
    loop {
        let min_idx = (0..clients).min_by_key(|&u| shards[u].len()).unwrap();
        if shards[min_idx].len() >= min_per_client {
            break;
        }
        let max_idx = (0..clients).max_by_key(|&u| shards[u].len()).unwrap();
        let moved = shards[max_idx].pop().expect("largest shard is empty");
        shards[min_idx].push(moved);
    }
    shards
}

/// Mini-batch iterator over a client shard: shuffles every epoch with a
/// client-specific stream, yields fixed-size batches (drops the ragged
/// tail, like the reference training loops).
#[derive(Debug)]
pub struct BatchIter {
    indices: Vec<usize>,
    cursor: usize,
    batch: usize,
    rng: Rng,
}

impl BatchIter {
    pub fn new(shard: &[usize], batch: usize, seed: u64) -> Self {
        let mut it =
            Self { indices: shard.to_vec(), cursor: 0, batch, rng: Rng::new(seed) };
        it.shuffle();
        it
    }

    fn shuffle(&mut self) {
        // Fisher–Yates.
        for i in (1..self.indices.len()).rev() {
            let j = self.rng.below(i + 1);
            self.indices.swap(i, j);
        }
        self.cursor = 0;
    }

    /// Next batch of dataset indices; reshuffles at epoch boundaries.
    pub fn next_batch(&mut self) -> &[usize] {
        if self.indices.len() < self.batch {
            return &self.indices; // degenerate shard: single short batch
        }
        if self.cursor + self.batch > self.indices.len() {
            self.shuffle();
        }
        let s = &self.indices[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        s
    }

    pub fn batches_per_epoch(&self) -> usize {
        (self.indices.len() / self.batch).max(1)
    }

    /// Snapshot for checkpoint/resume: (shuffled index order, cursor,
    /// RNG state). Restoring with [`BatchIter::restore_state`] continues
    /// the exact batch stream.
    pub fn state(&self) -> (&[usize], usize, u64) {
        (&self.indices, self.cursor, self.rng.state())
    }

    /// Rebuild the iterator mid-epoch from a saved [`BatchIter::state`].
    /// `indices` must be a permutation of the original shard.
    pub fn restore_state(&mut self, indices: Vec<usize>, cursor: usize, rng_state: u64) {
        self.indices = indices;
        self.cursor = cursor;
        self.rng = Rng::from_state(rng_state);
    }
}

/// Materialize a batch as flat (tokens, labels) buffers ready for the
/// runtime layer ([B*L] i32 row-major, [B] i32).
pub fn materialize_batch(ds: &Dataset, idx: &[usize]) -> (Vec<i32>, Vec<i32>) {
    let mut tokens = Vec::with_capacity(idx.len() * ds.spec.seq);
    let mut labels = Vec::with_capacity(idx.len());
    materialize_batch_into(ds, idx, &mut tokens, &mut labels);
    (tokens, labels)
}

/// Materialize a batch into caller-owned buffers (cleared, then filled).
/// The buffers keep their capacity across calls, so the steady-state
/// training loop never reallocates them.
pub fn materialize_batch_into(
    ds: &Dataset,
    idx: &[usize],
    tokens: &mut Vec<i32>,
    labels: &mut Vec<i32>,
) {
    tokens.clear();
    labels.clear();
    for &i in idx {
        tokens.extend_from_slice(&ds.train[i].tokens);
        labels.push(ds.train[i].label);
    }
}

/// Label histogram of a shard (for non-IID diagnostics + tests).
pub fn label_histogram(examples: &[Example], shard: &[usize], classes: usize) -> Vec<usize> {
    let mut h = vec![0usize; classes];
    for &i in shard {
        h[examples[i].label as usize] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> CorpusSpec {
        CorpusSpec {
            vocab: 512,
            seq: 16,
            classes: 6,
            markers_per_class: 16,
            marker_prob: 0.2,
            train_size: 600,
            test_size: 120,
            seed: 3,
        }
    }

    #[test]
    fn generate_respects_sizes_and_ranges() {
        let ds = generate(&small_spec());
        assert_eq!(ds.train.len(), 600);
        assert_eq!(ds.test.len(), 120);
        for ex in ds.train.iter().chain(ds.test.iter()) {
            assert_eq!(ex.tokens.len(), 16);
            assert!(ex.tokens.iter().all(|&t| t >= 0 && (t as usize) < 512));
            assert!((0..6).contains(&ex.label));
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = generate(&small_spec());
        let b = generate(&small_spec());
        assert_eq!(a.train[..10], b.train[..10]);
    }

    #[test]
    fn marker_tokens_identify_class() {
        // Examples of class c must contain tokens from c's marker range
        // far more often than from other classes' ranges.
        let spec = small_spec();
        let ds = generate(&spec);
        let mut own = 0usize;
        let mut other = 0usize;
        for ex in &ds.train {
            let r = marker_range(&spec, ex.label as usize);
            for &t in &ex.tokens {
                if r.contains(&t) {
                    own += 1;
                } else if (t as usize) >= spec.vocab - spec.classes * spec.markers_per_class {
                    other += 1;
                }
            }
        }
        assert!(own > 5 * other, "own={own} other={other}");
    }

    #[test]
    fn all_classes_present() {
        let ds = generate(&small_spec());
        let h = label_histogram(&ds.train, &(0..ds.train.len()).collect::<Vec<_>>(), 6);
        assert!(h.iter().all(|&c| c > 0), "{h:?}");
        // Imbalanced priors: class 0 more common than class 5.
        assert!(h[0] > h[5]);
    }

    #[test]
    fn dirichlet_partition_covers_everything_once() {
        let ds = generate(&small_spec());
        let shards = dirichlet_partition(&ds.train, 6, 0.5, 9, 10);
        let mut seen = vec![false; ds.train.len()];
        for shard in &shards {
            assert!(shard.len() >= 10);
            for &i in shard {
                assert!(!seen[i], "example {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn low_alpha_is_more_skewed_than_high_alpha() {
        let ds = generate(&small_spec());
        let skew = |alpha: f64| -> f64 {
            let shards = dirichlet_partition(&ds.train, 6, alpha, 11, 1);
            // Mean over clients of (max class share).
            shards
                .iter()
                .map(|s| {
                    let h = label_histogram(&ds.train, s, 6);
                    let total: usize = h.iter().sum();
                    *h.iter().max().unwrap() as f64 / total.max(1) as f64
                })
                .sum::<f64>()
                / 6.0
        };
        assert!(skew(0.1) > skew(100.0) + 0.05);
    }

    #[test]
    fn batch_iter_yields_full_batches_and_reshuffles() {
        let shard: Vec<usize> = (0..50).collect();
        let mut it = BatchIter::new(&shard, 16, 1);
        assert_eq!(it.batches_per_epoch(), 3);
        let mut seen_first_epoch: Vec<usize> = Vec::new();
        for _ in 0..3 {
            let b = it.next_batch().to_vec();
            assert_eq!(b.len(), 16);
            seen_first_epoch.extend(b);
        }
        // Within an epoch no duplicates.
        let mut sorted = seen_first_epoch.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), seen_first_epoch.len());
        // Crossing the epoch boundary still yields full batches.
        assert_eq!(it.next_batch().len(), 16);
    }

    #[test]
    fn materialize_batch_layout() {
        let ds = generate(&small_spec());
        let (tokens, labels) = materialize_batch(&ds, &[0, 1]);
        assert_eq!(tokens.len(), 2 * 16);
        assert_eq!(labels.len(), 2);
        assert_eq!(&tokens[..16], ds.train[0].tokens.as_slice());
    }

    #[test]
    fn materialize_batch_into_reuses_buffers() {
        let ds = generate(&small_spec());
        let mut tokens = Vec::with_capacity(2 * 16);
        let mut labels = Vec::with_capacity(2);
        materialize_batch_into(&ds, &[0, 1], &mut tokens, &mut labels);
        let cap = tokens.capacity();
        let ptr = tokens.as_ptr();
        materialize_batch_into(&ds, &[2, 3], &mut tokens, &mut labels);
        assert_eq!(tokens.capacity(), cap, "refill must not grow the buffer");
        assert_eq!(tokens.as_ptr(), ptr, "refill must not reallocate");
        assert_eq!(&tokens[..16], ds.train[2].tokens.as_slice());
        assert_eq!(labels, vec![ds.train[2].label, ds.train[3].label]);
    }
}
