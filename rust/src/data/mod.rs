//! Synthetic CARER-like emotion-classification corpus + non-IID partition.
//!
//! The paper fine-tunes on CARER (6 emotion classes, tweets).  We cannot
//! ship the real tweets, so the generator produces token sequences whose
//! class-conditional unigram statistics make the task learnable (class
//! "marker" tokens mixed into a shared background distribution), and the
//! Dirichlet partitioner reproduces the Non-IID client shards the paper
//! assumes (§II).  See DESIGN.md §2 for why this preserves the relative
//! scheme behaviour.

use crate::tensor::rng::Rng;
use anyhow::{bail, Result};

/// One classification example: token ids + label.
#[derive(Debug, Clone, PartialEq)]
pub struct Example {
    pub tokens: Vec<i32>,
    pub label: i32,
}

/// Generator parameters for the synthetic corpus.
#[derive(Debug, Clone)]
pub struct CorpusSpec {
    pub vocab: usize,
    pub seq: usize,
    pub classes: usize,
    /// Number of class-specific marker tokens per class.
    pub markers_per_class: usize,
    /// Probability that a position draws from the class markers rather
    /// than the shared background distribution. Controls task difficulty.
    pub marker_prob: f64,
    pub train_size: usize,
    pub test_size: usize,
    pub seed: u64,
}

impl CorpusSpec {
    /// CARER is ~16k train / 2k test, 6 classes; defaults mirror that at
    /// whatever vocab/seq the model config uses.
    pub fn carer_like(vocab: usize, seq: usize) -> Self {
        Self {
            vocab,
            seq,
            classes: 6,
            markers_per_class: 24.min(vocab / 12),
            marker_prob: 0.18,
            train_size: 16_000,
            test_size: 2_000,
            seed: 7,
        }
    }
}

/// A materialized dataset split.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub train: Vec<Example>,
    pub test: Vec<Example>,
    pub spec: CorpusSpec,
}

/// The class marker tokens are carved out of the top of the vocab so they
/// never collide with the background range.
fn marker_range(spec: &CorpusSpec, class: usize) -> std::ops::Range<i32> {
    let per = spec.markers_per_class;
    let base = spec.vocab - spec.classes * per + class * per;
    base as i32..(base + per) as i32
}

fn gen_example(spec: &CorpusSpec, rng: &mut Rng, label: usize) -> Example {
    let markers = marker_range(spec, label);
    let background = spec.vocab - spec.classes * spec.markers_per_class;
    let tokens = (0..spec.seq)
        .map(|_| {
            if rng.uniform() < spec.marker_prob {
                markers.start + rng.below(spec.markers_per_class) as i32
            } else {
                rng.below(background) as i32
            }
        })
        .collect();
    Example { tokens, label: label as i32 }
}

/// Generate the full corpus. Class priors are mildly imbalanced, like
/// CARER's (joy/sadness dominate, surprise is rare).
pub fn generate(spec: &CorpusSpec) -> Dataset {
    let mut rng = Rng::new(spec.seed);
    let priors: Vec<f64> = (0..spec.classes)
        .map(|c| 1.0 / (1.0 + 0.35 * c as f64))
        .collect();
    let gen_split = |n: usize, rng: &mut Rng| {
        (0..n)
            .map(|_| {
                let label = rng.categorical(&priors);
                gen_example(spec, rng, label)
            })
            .collect::<Vec<_>>()
    };
    let train = gen_split(spec.train_size, &mut rng);
    let test = gen_split(spec.test_size, &mut rng);
    Dataset { train, test, spec: spec.clone() }
}

/// Dirichlet(alpha) non-IID partition of `examples` across `clients`.
/// Lower alpha ⇒ more skewed label distributions per client.
/// Every client is guaranteed at least `min_per_client` examples.
pub fn dirichlet_partition(
    examples: &[Example],
    clients: usize,
    alpha: f64,
    seed: u64,
    min_per_client: usize,
) -> Vec<Vec<usize>> {
    // The rebalance loop below cannot terminate if the floor is
    // infeasible — fail loudly instead of spinning (fleet-scale specs
    // can request more clients than the corpus supports).
    assert!(
        examples.len() >= clients * min_per_client,
        "dirichlet_partition: {} examples cannot give {clients} clients {min_per_client} each",
        examples.len()
    );
    let mut rng = Rng::new(seed);
    let classes = examples.iter().map(|e| e.label).max().unwrap_or(0) as usize + 1;
    // Per-class client mixture.
    let mixtures: Vec<Vec<f64>> =
        (0..classes).map(|_| rng.dirichlet(alpha, clients)).collect();
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); clients];
    for (i, ex) in examples.iter().enumerate() {
        let u = rng.categorical(&mixtures[ex.label as usize]);
        shards[u].push(i);
    }
    // Rebalance: steal from the largest shard until everyone has a floor.
    // The `else` arms only fire for a zero-client call or a fully drained
    // corpus, where there is nothing left to move.
    loop {
        let Some(min_idx) = (0..clients).min_by_key(|&u| shards[u].len()) else { break };
        if shards[min_idx].len() >= min_per_client {
            break;
        }
        let Some(max_idx) = (0..clients).max_by_key(|&u| shards[u].len()) else { break };
        let Some(moved) = shards[max_idx].pop() else { break };
        shards[min_idx].push(moved);
    }
    shards
}

/// Per-client decorrelation constant for the shared pool's derivation
/// streams (odd multiplier, splitmix-style).
const SHARD_STREAM: u64 = 0xD1B5_4A32_D192_ED03;

/// Numeric-session feasibility floor: every *active* client needs at
/// least one mini-batch of examples.  With the shared data pool, shards
/// may overlap across the fleet, so the corpus only has to cover the
/// round cohort — not `clients * batch` as the pre-pool eager partition
/// required.  (`max_participants = 0` means full participation, so the
/// cohort is the whole fleet.)
pub fn numeric_feasibility(
    corpus: usize,
    clients: usize,
    min_per_client: usize,
    max_participants: usize,
) -> Result<()> {
    let cohort = if max_participants == 0 { clients } else { max_participants.min(clients) };
    if corpus < cohort * min_per_client {
        bail!(
            "a round cohort of {cohort} clients needs at least {} training examples \
             ({corpus} available) — bound the cohort with --max-participants or grow \
             the corpus",
            cohort * min_per_client
        );
    }
    Ok(())
}

/// The fleet's example-index layout, owned once and shared by every
/// consumer (batch iterators, aggregation weights, checkpoint
/// validation).  Two modes, chosen automatically:
///
/// - **Dense** (`corpus >= clients * batch`): the exact non-IID
///   Dirichlet partition ([`dirichlet_partition`]) — bit-identical
///   shards and weights to the pre-pool eager path.
/// - **Shared** (bench-scale fleets): the corpus is bucketed by class
///   once, and any client's shard is *derived on demand* from the
///   partition seed (a per-client Dirichlet class mixture sampled into
///   a fixed-size shard).  Shards overlap across clients, which is what
///   lifts the old `corpus_size / batch` fleet cap; per-client label
///   skew is preserved.
///
/// Either way, deriving client `u`'s shard is deterministic in
/// `(seed, u)` and independent of which other clients were ever asked
/// for — the property the lazy state pool builds on.
#[derive(Debug)]
pub struct DataPool {
    clients: usize,
    batch: usize,
    seed: u64,
    /// Σ shard lengths (the |D| in the |D_u|/|D| aggregation weights).
    total: usize,
    mode: PoolMode,
}

#[derive(Debug)]
enum PoolMode {
    Dense { shards: Vec<Vec<usize>> },
    Shared { buckets: Vec<Vec<usize>>, alpha: f64, shard_size: usize },
}

impl DataPool {
    /// Build the pool for `clients` over `examples`.  `min_per_client`
    /// is the per-client floor (one mini-batch); the Dirichlet `alpha`
    /// and `seed` match [`dirichlet_partition`]'s parameters so the
    /// Dense mode reproduces it exactly.
    pub fn new(
        examples: &[Example],
        clients: usize,
        alpha: f64,
        seed: u64,
        min_per_client: usize,
    ) -> Self {
        if examples.len() >= clients * min_per_client {
            let shards = dirichlet_partition(examples, clients, alpha, seed, min_per_client);
            let total = shards.iter().map(|s| s.len()).sum();
            return Self {
                clients,
                batch: min_per_client,
                seed,
                total,
                mode: PoolMode::Dense { shards },
            };
        }
        let classes = examples.iter().map(|e| e.label).max().unwrap_or(0) as usize + 1;
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); classes];
        for (i, ex) in examples.iter().enumerate() {
            buckets[ex.label as usize].push(i);
        }
        let shard_size = min_per_client.max(examples.len() / clients.max(1));
        Self {
            clients,
            batch: min_per_client,
            seed,
            total: clients * shard_size,
            mode: PoolMode::Shared { buckets, alpha, shard_size },
        }
    }

    pub fn clients(&self) -> usize {
        self.clients
    }

    /// True when shards are derived (and may overlap) rather than a
    /// disjoint Dirichlet partition.
    pub fn is_shared(&self) -> bool {
        matches!(self.mode, PoolMode::Shared { .. })
    }

    pub fn shard_len(&self, u: usize) -> usize {
        match &self.mode {
            PoolMode::Dense { shards } => shards[u].len(),
            PoolMode::Shared { shard_size, .. } => *shard_size,
        }
    }

    /// Data-size aggregation weight |D_u|/|D| — same arithmetic as the
    /// pre-pool eager `weights` vector, so Dense-mode weights are
    /// bit-identical to it.
    pub fn weight(&self, u: usize) -> f32 {
        self.shard_len(u) as f32 / self.total as f32
    }

    /// Derive client `u`'s shard into a caller-owned buffer (cleared,
    /// then filled) — the zero-allocation path the state pool uses when
    /// re-materializing a client.
    pub fn shard_into(&self, u: usize, out: &mut Vec<usize>) {
        out.clear();
        match &self.mode {
            PoolMode::Dense { shards } => out.extend_from_slice(&shards[u]),
            PoolMode::Shared { buckets, alpha, shard_size } => {
                let mut rng = Rng::new(self.seed ^ (u as u64).wrapping_mul(SHARD_STREAM));
                let mut mixture = rng.dirichlet(*alpha, buckets.len());
                for (c, w) in mixture.iter_mut().enumerate() {
                    if buckets[c].is_empty() {
                        *w = 0.0;
                    }
                }
                for _ in 0..*shard_size {
                    let c = rng.categorical(&mixture);
                    let b = &buckets[c];
                    out.push(b[rng.below(b.len())]);
                }
            }
        }
    }

    /// Allocating convenience wrapper over [`DataPool::shard_into`].
    pub fn shard(&self, u: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.shard_len(u));
        self.shard_into(u, &mut out);
        out
    }

    /// A fresh batch iterator for client `u` (seeded by the caller so
    /// the stream matches the session's `seed + 100 + u` convention).
    /// `scratch` is reused for the shard derivation.
    pub fn iter_for(&self, u: usize, iter_seed: u64, scratch: &mut Vec<usize>) -> BatchIter {
        self.shard_into(u, scratch);
        BatchIter::new(scratch, self.batch, iter_seed)
    }
}

/// Mini-batch iterator over a client shard: shuffles every epoch with a
/// client-specific stream, yields fixed-size batches (drops the ragged
/// tail, like the reference training loops).
#[derive(Debug)]
pub struct BatchIter {
    indices: Vec<usize>,
    cursor: usize,
    // sflint:allow(checkpoint-coverage, batch size is fixed at construction, not mutable run state)
    batch: usize,
    rng: Rng,
}

impl BatchIter {
    pub fn new(shard: &[usize], batch: usize, seed: u64) -> Self {
        let mut it =
            Self { indices: shard.to_vec(), cursor: 0, batch, rng: Rng::new(seed) };
        it.shuffle();
        it
    }

    fn shuffle(&mut self) {
        // Fisher–Yates.
        for i in (1..self.indices.len()).rev() {
            let j = self.rng.below(i + 1);
            self.indices.swap(i, j);
        }
        self.cursor = 0;
    }

    /// Next batch of dataset indices; reshuffles at epoch boundaries.
    pub fn next_batch(&mut self) -> &[usize] {
        if self.indices.len() < self.batch {
            return &self.indices; // degenerate shard: single short batch
        }
        if self.cursor + self.batch > self.indices.len() {
            self.shuffle();
        }
        let s = &self.indices[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        s
    }

    pub fn batches_per_epoch(&self) -> usize {
        (self.indices.len() / self.batch).max(1)
    }

    /// Snapshot for checkpoint/resume: (shuffled index order, cursor,
    /// RNG state). Restoring with [`BatchIter::restore_state`] continues
    /// the exact batch stream.
    pub fn state(&self) -> (&[usize], usize, u64) {
        (&self.indices, self.cursor, self.rng.state())
    }

    /// Rebuild the iterator mid-epoch from a saved [`BatchIter::state`].
    /// `indices` must be a permutation of the original shard.
    pub fn restore_state(&mut self, indices: Vec<usize>, cursor: usize, rng_state: u64) {
        self.indices = indices;
        self.cursor = cursor;
        self.rng = Rng::from_state(rng_state);
    }
}

/// Materialize a batch as flat (tokens, labels) buffers ready for the
/// runtime layer ([B*L] i32 row-major, [B] i32).
pub fn materialize_batch(ds: &Dataset, idx: &[usize]) -> (Vec<i32>, Vec<i32>) {
    let mut tokens = Vec::with_capacity(idx.len() * ds.spec.seq);
    let mut labels = Vec::with_capacity(idx.len());
    materialize_batch_into(ds, idx, &mut tokens, &mut labels);
    (tokens, labels)
}

/// Materialize a batch into caller-owned buffers (cleared, then filled).
/// The buffers keep their capacity across calls, so the steady-state
/// training loop never reallocates them.
pub fn materialize_batch_into(
    ds: &Dataset,
    idx: &[usize],
    tokens: &mut Vec<i32>,
    labels: &mut Vec<i32>,
) {
    tokens.clear();
    labels.clear();
    for &i in idx {
        tokens.extend_from_slice(&ds.train[i].tokens);
        labels.push(ds.train[i].label);
    }
}

/// Label histogram of a shard (for non-IID diagnostics + tests).
pub fn label_histogram(examples: &[Example], shard: &[usize], classes: usize) -> Vec<usize> {
    let mut h = vec![0usize; classes];
    for &i in shard {
        h[examples[i].label as usize] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> CorpusSpec {
        CorpusSpec {
            vocab: 512,
            seq: 16,
            classes: 6,
            markers_per_class: 16,
            marker_prob: 0.2,
            train_size: 600,
            test_size: 120,
            seed: 3,
        }
    }

    #[test]
    fn generate_respects_sizes_and_ranges() {
        let ds = generate(&small_spec());
        assert_eq!(ds.train.len(), 600);
        assert_eq!(ds.test.len(), 120);
        for ex in ds.train.iter().chain(ds.test.iter()) {
            assert_eq!(ex.tokens.len(), 16);
            assert!(ex.tokens.iter().all(|&t| t >= 0 && (t as usize) < 512));
            assert!((0..6).contains(&ex.label));
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = generate(&small_spec());
        let b = generate(&small_spec());
        assert_eq!(a.train[..10], b.train[..10]);
    }

    #[test]
    fn marker_tokens_identify_class() {
        // Examples of class c must contain tokens from c's marker range
        // far more often than from other classes' ranges.
        let spec = small_spec();
        let ds = generate(&spec);
        let mut own = 0usize;
        let mut other = 0usize;
        for ex in &ds.train {
            let r = marker_range(&spec, ex.label as usize);
            for &t in &ex.tokens {
                if r.contains(&t) {
                    own += 1;
                } else if (t as usize) >= spec.vocab - spec.classes * spec.markers_per_class {
                    other += 1;
                }
            }
        }
        assert!(own > 5 * other, "own={own} other={other}");
    }

    #[test]
    fn all_classes_present() {
        let ds = generate(&small_spec());
        let h = label_histogram(&ds.train, &(0..ds.train.len()).collect::<Vec<_>>(), 6);
        assert!(h.iter().all(|&c| c > 0), "{h:?}");
        // Imbalanced priors: class 0 more common than class 5.
        assert!(h[0] > h[5]);
    }

    #[test]
    fn dirichlet_partition_covers_everything_once() {
        let ds = generate(&small_spec());
        let shards = dirichlet_partition(&ds.train, 6, 0.5, 9, 10);
        let mut seen = vec![false; ds.train.len()];
        for shard in &shards {
            assert!(shard.len() >= 10);
            for &i in shard {
                assert!(!seen[i], "example {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn low_alpha_is_more_skewed_than_high_alpha() {
        let ds = generate(&small_spec());
        let skew = |alpha: f64| -> f64 {
            let shards = dirichlet_partition(&ds.train, 6, alpha, 11, 1);
            // Mean over clients of (max class share).
            shards
                .iter()
                .map(|s| {
                    let h = label_histogram(&ds.train, s, 6);
                    let total: usize = h.iter().sum();
                    *h.iter().max().unwrap() as f64 / total.max(1) as f64
                })
                .sum::<f64>()
                / 6.0
        };
        assert!(skew(0.1) > skew(100.0) + 0.05);
    }

    #[test]
    fn batch_iter_yields_full_batches_and_reshuffles() {
        let shard: Vec<usize> = (0..50).collect();
        let mut it = BatchIter::new(&shard, 16, 1);
        assert_eq!(it.batches_per_epoch(), 3);
        let mut seen_first_epoch: Vec<usize> = Vec::new();
        for _ in 0..3 {
            let b = it.next_batch().to_vec();
            assert_eq!(b.len(), 16);
            seen_first_epoch.extend(b);
        }
        // Within an epoch no duplicates.
        let mut sorted = seen_first_epoch.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), seen_first_epoch.len());
        // Crossing the epoch boundary still yields full batches.
        assert_eq!(it.next_batch().len(), 16);
    }

    #[test]
    fn materialize_batch_layout() {
        let ds = generate(&small_spec());
        let (tokens, labels) = materialize_batch(&ds, &[0, 1]);
        assert_eq!(tokens.len(), 2 * 16);
        assert_eq!(labels.len(), 2);
        assert_eq!(&tokens[..16], ds.train[0].tokens.as_slice());
    }

    #[test]
    fn dense_pool_reproduces_dirichlet_partition_exactly() {
        let ds = generate(&small_spec());
        let pool = DataPool::new(&ds.train, 6, 0.5, 9, 10);
        assert!(!pool.is_shared());
        let shards = dirichlet_partition(&ds.train, 6, 0.5, 9, 10);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        for (u, s) in shards.iter().enumerate() {
            assert_eq!(&pool.shard(u), s, "client {u} shard diverged");
            assert_eq!(pool.shard_len(u), s.len());
            let w = s.len() as f32 / total as f32;
            assert_eq!(pool.weight(u).to_bits(), w.to_bits(), "client {u} weight diverged");
        }
    }

    #[test]
    fn shared_pool_lifts_the_corpus_over_batch_cap() {
        // 600 examples cannot give 200 clients 10 each disjointly — the
        // pool switches to derived, overlapping shards.
        let ds = generate(&small_spec());
        let pool = DataPool::new(&ds.train, 200, 0.5, 9, 10);
        assert!(pool.is_shared());
        let mut weight_sum = 0.0f64;
        for u in [0usize, 7, 199] {
            let s = pool.shard(u);
            assert_eq!(s.len(), pool.shard_len(u));
            assert!(s.len() >= 10);
            assert!(s.iter().all(|&i| i < ds.train.len()));
            // Deriving twice (and out of order) is deterministic.
            assert_eq!(pool.shard(u), s);
        }
        for u in 0..200 {
            weight_sum += pool.weight(u) as f64;
        }
        assert!((weight_sum - 1.0).abs() < 1e-3, "weights sum to {weight_sum}");
        // Different clients draw different (label-skewed) shards.
        assert_ne!(pool.shard(0), pool.shard(1));
    }

    #[test]
    fn shared_pool_shards_are_label_skewed() {
        // The derived shards must preserve the non-IID property: a
        // low-alpha client concentrates on few classes.
        let ds = generate(&small_spec());
        let pool = DataPool::new(&ds.train, 100, 0.1, 9, 12);
        assert!(pool.is_shared());
        let mut dominated = 0usize;
        for u in 0..20 {
            let h = label_histogram(&ds.train, &pool.shard(u), 6);
            let total: usize = h.iter().sum();
            if *h.iter().max().unwrap() * 2 > total {
                dominated += 1;
            }
        }
        assert!(dominated >= 8, "only {dominated}/20 shards were class-dominated");
    }

    #[test]
    fn iter_for_matches_manual_batch_iter() {
        let ds = generate(&small_spec());
        let pool = DataPool::new(&ds.train, 6, 0.5, 9, 10);
        let mut scratch = Vec::new();
        for u in 0..6 {
            let mut a = pool.iter_for(u, 1000 + u as u64, &mut scratch);
            let mut b = BatchIter::new(&pool.shard(u), 10, 1000 + u as u64);
            for _ in 0..5 {
                assert_eq!(a.next_batch(), b.next_batch());
            }
        }
    }

    #[test]
    fn numeric_feasibility_boundary() {
        // Full participation: the whole fleet is the cohort.
        assert!(numeric_feasibility(60, 6, 10, 0).is_ok());
        assert!(numeric_feasibility(59, 6, 10, 0).is_err());
        // Bounded cohorts only need to cover the cohort.
        assert!(numeric_feasibility(30, 10_000, 10, 3).is_ok());
        assert!(numeric_feasibility(29, 10_000, 10, 3).is_err());
        // A cap larger than the fleet clamps to the fleet.
        assert!(numeric_feasibility(60, 6, 10, 99).is_ok());
        assert!(numeric_feasibility(59, 6, 10, 99).is_err());
    }

    #[test]
    fn materialize_batch_into_reuses_buffers() {
        let ds = generate(&small_spec());
        let mut tokens = Vec::with_capacity(2 * 16);
        let mut labels = Vec::with_capacity(2);
        materialize_batch_into(&ds, &[0, 1], &mut tokens, &mut labels);
        let cap = tokens.capacity();
        let ptr = tokens.as_ptr();
        materialize_batch_into(&ds, &[2, 3], &mut tokens, &mut labels);
        assert_eq!(tokens.capacity(), cap, "refill must not grow the buffer");
        assert_eq!(tokens.as_ptr(), ptr, "refill must not reallocate");
        assert_eq!(&tokens[..16], ds.train[2].tokens.as_slice());
        assert_eq!(labels, vec![ds.train[2].label, ds.train[3].label]);
    }
}
