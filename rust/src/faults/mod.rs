//! Byzantine fault layer: seeded fault injection, pre-merge update
//! sanitization, and committee-based spot verification.
//!
//! The paper's fleet model assumes every device computes its client-side
//! step honestly; at production scale some fraction will not.  This
//! module makes that fraction explicit: a [`FaultInjector`] rewrites a
//! seeded subset of client submissions (corrupt / scaled / stale /
//! timing lies) before aggregation, [`sanitize_updates`] rejects
//! non-finite or norm-outlier deltas before they can reach
//! `StatePool::apply_aggregate`, and a [`Committee`] draws a seeded
//! witness sample per round whose submissions are checked bit-for-bit
//! against the server-side re-execution (the full model is already
//! resident per the paper's split design, so re-running a witness step
//! costs no extra memory).  All randomness is SplitMix64 with
//! checkpointable state, so faulty runs resume bit-exactly.

use crate::lora::{joined_delta_norm, joined_non_finite, AdapterSet};
use crate::tensor::rng::Rng;
use anyhow::{bail, Result};

pub mod testbed;

/// What a faulty client does to its update (the threat model).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AttackKind {
    /// Honest fleet (the default; injector is inert).
    #[default]
    None,
    /// Overwrite a seeded segment of one adapter tensor with NaN/Inf —
    /// the "bit-rot / OOM-kill mid-upload" failure mode.
    Corrupt,
    /// Submit `b + λ·(x − b)`: sign-flipped (λ < 0) or inflated (λ > 1)
    /// gradient — the classic model-poisoning shape.
    Scale,
    /// Replay the previous round's honest update (stragglers resending
    /// stale state); the first round has nothing to replay and is honest.
    Stale,
    /// Submit honestly but lie to the timing estimator by a factor of
    /// |λ| to game the Alg. 2 schedule.
    TimingLie,
}

impl AttackKind {
    /// Stable tag for the train fingerprint.
    pub fn tag(&self) -> u64 {
        match self {
            AttackKind::None => 0,
            AttackKind::Corrupt => 1,
            AttackKind::Scale => 2,
            AttackKind::Stale => 3,
            AttackKind::TimingLie => 4,
        }
    }
}

impl std::str::FromStr for AttackKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "none" => AttackKind::None,
            "corrupt" => AttackKind::Corrupt,
            "scale" => AttackKind::Scale,
            "stale" => AttackKind::Stale,
            "timing-lie" => AttackKind::TimingLie,
            other => bail!("unknown attack kind `{other}` (none|corrupt|scale|stale|timing-lie)"),
        })
    }
}

impl std::fmt::Display for AttackKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            AttackKind::None => "none",
            AttackKind::Corrupt => "corrupt",
            AttackKind::Scale => "scale",
            AttackKind::Stale => "stale",
            AttackKind::TimingLie => "timing-lie",
        })
    }
}

/// Which merge kernel the aggregator runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AggKind {
    /// Plain weighted FedAvg (paper eqs. 6–7).
    #[default]
    Mean,
    /// Coordinate-wise trimmed mean (`lora::trimmed_fedavg_joined_into`).
    Trimmed,
    /// Per-client delta norm clipping (`lora::clipped_fedavg_joined_into`).
    Clip,
}

impl AggKind {
    /// Stable tag for the train fingerprint.
    pub fn tag(&self) -> u64 {
        match self {
            AggKind::Mean => 0,
            AggKind::Trimmed => 1,
            AggKind::Clip => 2,
        }
    }
}

impl std::str::FromStr for AggKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "mean" => AggKind::Mean,
            "trimmed" => AggKind::Trimmed,
            "clip" => AggKind::Clip,
            other => bail!("unknown aggregator `{other}` (mean|trimmed|clip)"),
        })
    }
}

impl std::fmt::Display for AggKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            AggKind::Mean => "mean",
            AggKind::Trimmed => "trimmed",
            AggKind::Clip => "clip",
        })
    }
}

/// Per-round defense counters surfaced in jsonl telemetry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RobustStats {
    /// Clients whose witness re-execution mismatched this round.
    pub flagged: u64,
    /// Clients currently quarantined (cumulative; quarantine is sticky).
    pub quarantined: u64,
    /// Updates rejected by the sanitizer this round.
    pub rejected: u64,
    /// Contributors trimmed (2·trim) or norm-clipped this round.
    pub trim_count: u64,
}

fn copy_adapters(dst: &mut AdapterSet, src: &AdapterSet) -> Result<()> {
    if dst.layers != src.layers {
        bail!("fault submission depth changed ({} vs {})", dst.layers, src.layers);
    }
    for (d, s) in dst.tensors.iter_mut().zip(src.tensors.iter()) {
        let dv = d.as_f32_mut()?;
        let sv = s.as_f32()?;
        if dv.len() != sv.len() {
            bail!("fault submission width changed on {}", s.name);
        }
        dv.copy_from_slice(sv);
    }
    Ok(())
}

/// Bitwise comparison of two adapter sets (NaN-safe: `f32::max`-style
/// reductions swallow NaN, so spot verification compares raw bits).
pub fn differs(a: &AdapterSet, b: &AdapterSet) -> Result<bool> {
    if a.layers != b.layers {
        return Ok(true);
    }
    for (x, y) in a.tensors.iter().zip(b.tensors.iter()) {
        let xv = x.as_f32()?;
        let yv = y.as_f32()?;
        if xv.len() != yv.len() || xv.iter().zip(yv).any(|(p, q)| p.to_bits() != q.to_bits()) {
            return Ok(true);
        }
    }
    Ok(false)
}

/// Pre-merge sanitizer: reject non-finite updates outright, then reject
/// deltas whose L2 norm exceeds `mult ×` the cohort's median finite
/// norm.  `norms`/`keep` are caller-owned scratch (cleared and refilled,
/// zero tensor allocations).  Returns the number rejected.
pub fn sanitize_updates(
    subs: &[(f32, &AdapterSet, &AdapterSet)],
    baseline: &AdapterSet,
    mult: f64,
    norms: &mut Vec<f64>,
    keep: &mut Vec<bool>,
) -> Result<u64> {
    norms.clear();
    keep.clear();
    for (_, c, s) in subs {
        let norm = if joined_non_finite(c, s)? {
            f64::NAN
        } else {
            joined_delta_norm(c, s, baseline)?
        };
        norms.push(norm);
    }
    let mut finite: Vec<f64> = norms.iter().copied().filter(|x| x.is_finite()).collect();
    finite.sort_by(|a, b| a.total_cmp(b));
    let median = if finite.is_empty() { 0.0 } else { finite[finite.len() / 2] };
    let mut rejected = 0u64;
    for &n in norms.iter() {
        // A zero median (fresh cohort, zero deltas) disables the outlier
        // test rather than rejecting everyone.
        let ok = n.is_finite() && (median <= 0.0 || n <= mult * median);
        if !ok {
            rejected += 1;
        }
        keep.push(ok);
    }
    Ok(rejected)
}

/// Spread of a cohort's finite delta norms: max / median (the same
/// median the sanitizer thresholds against).  `None` when fewer than
/// two finite norms exist or the median is non-positive — degenerate
/// cohorts carry no spread signal.  Drives the `--sanitize-mult
/// adaptive` EWMA.
pub fn norm_spread(norms: &[f64]) -> Option<f64> {
    let mut finite: Vec<f64> = norms.iter().copied().filter(|x| x.is_finite()).collect();
    if finite.len() < 2 {
        return None;
    }
    finite.sort_by(|a, b| a.total_cmp(b));
    let median = finite[finite.len() / 2];
    if median <= 0.0 {
        return None;
    }
    Some(finite[finite.len() - 1] / median)
}

/// Seeded fault injector: a fixed, deterministic subset of clients
/// (⌈frac·n⌉, drawn by partial Fisher–Yates exactly like the session's
/// participant sampler) rewrites its submission each round according to
/// [`AttackKind`].  Submission buffers are allocated lazily on a
/// client's first faulty round and reused thereafter — steady-state
/// rounds perform zero tensor allocations.
#[derive(Debug)]
pub struct FaultInjector {
    kind: AttackKind,
    lambda: f32,
    attackers: Vec<bool>,
    rng: Rng,
    subs: Vec<Option<(AdapterSet, AdapterSet)>>,
    /// Previous round's honest halves per Stale attacker (checkpointed
    /// by the session so replays survive resume bit-exactly).
    pub prev: Vec<Option<(AdapterSet, AdapterSet)>>,
}

impl FaultInjector {
    pub fn new(n: usize, kind: AttackKind, frac: f64, lambda: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut attackers = vec![false; n];
        if kind != AttackKind::None && frac > 0.0 && n > 0 {
            let m = ((frac * n as f64).ceil() as usize).min(n);
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..m {
                let j = i + rng.below(n - i);
                idx.swap(i, j);
            }
            for &u in &idx[..m] {
                attackers[u] = true;
            }
        }
        Self {
            kind,
            lambda: lambda as f32,
            attackers,
            rng,
            subs: (0..n).map(|_| None).collect(),
            prev: (0..n).map(|_| None).collect(),
        }
    }

    pub fn kind(&self) -> AttackKind {
        self.kind
    }

    pub fn is_attacker(&self, u: usize) -> bool {
        self.attackers[u]
    }

    pub fn attacker_count(&self) -> usize {
        self.attackers.iter().filter(|&&a| a).count()
    }

    /// The multiplier a TimingLie attacker applies to its reported step
    /// times (|λ|, so the default sign-flip λ lies by over-reporting).
    pub fn lie_factor(&self) -> f64 {
        (self.lambda as f64).abs()
    }

    pub fn rng_state(&self) -> u64 {
        self.rng.state()
    }

    pub fn set_rng_state(&mut self, state: u64) {
        self.rng = Rng::from_state(state);
    }

    /// Stage client `u`'s submission for this round: copy the honest
    /// `{client, server}` halves into the reusable buffer, then apply
    /// the configured fault if `u` is an attacker.  `baseline` is the
    /// model the cohort started the round from (attack reference point).
    pub fn prepare(
        &mut self,
        u: usize,
        client: &AdapterSet,
        server: &AdapterSet,
        baseline: &AdapterSet,
    ) -> Result<()> {
        if let Some((c, s)) = self.subs[u].as_mut() {
            copy_adapters(c, client)?;
            copy_adapters(s, server)?;
        } else {
            self.subs[u] = Some((client.clone(), server.clone()));
        }
        if !self.attackers[u] {
            return Ok(());
        }
        match self.kind {
            AttackKind::None | AttackKind::TimingLie => {}
            AttackKind::Corrupt => {
                let t = self.rng.below(4);
                let Some((c, s)) = self.subs[u].as_mut() else {
                    bail!("corrupt attack: submission for client {u} was not staged");
                };
                // Corrupt the client half when it has layers (the fault
                // models the device side); fall back to the server half
                // for cut-0 clients.
                let half =
                    if c.tensors[t].numel() > 0 { &mut c.tensors[t] } else { &mut s.tensors[t] };
                let d = half.as_f32_mut()?;
                let len = d.len();
                if len > 0 {
                    let seg = (len / 8).max(1);
                    let start = self.rng.below(len);
                    for off in 0..seg {
                        d[(start + off) % len] =
                            if off % 2 == 0 { f32::NAN } else { f32::INFINITY };
                    }
                }
            }
            AttackKind::Scale => {
                let lam = self.lambda;
                let Some((c, s)) = self.subs[u].as_mut() else {
                    bail!("scale attack: submission for client {u} was not staged");
                };
                let k = c.layers;
                if k + s.layers != baseline.layers {
                    bail!("scale attack: baseline depth mismatch");
                }
                for i in 0..4 {
                    let inner: usize = baseline.tensors[i].shape[1..].iter().product();
                    let b = baseline.tensors[i].as_f32()?;
                    for (x, bb) in c.tensors[i].as_f32_mut()?.iter_mut().zip(&b[..k * inner]) {
                        *x = *bb + lam * (*x - *bb);
                    }
                    for (x, bb) in s.tensors[i].as_f32_mut()?.iter_mut().zip(&b[k * inner..]) {
                        *x = *bb + lam * (*x - *bb);
                    }
                }
            }
            AttackKind::Stale => {
                if let Some(p) = self.prev[u].as_mut() {
                    // Submit last round's honest halves; bank this
                    // round's honest copy for the next replay.  `subs[u]`
                    // was staged at the top of this call.
                    if let Some(cur) = self.subs[u].as_mut() {
                        std::mem::swap(cur, p);
                    }
                } else {
                    self.prev[u] = Some((client.clone(), server.clone()));
                }
            }
        }
        Ok(())
    }

    /// The halves client `u` actually uploads (valid after `prepare`).
    pub fn submission(&self, u: usize) -> Option<(&AdapterSet, &AdapterSet)> {
        self.subs[u].as_ref().map(|(c, s)| (c, s))
    }
}

/// Seeded spot-verification committee: each round a shuffled-index
/// witness sample of ⌈frac·m⌉ cohort members is re-checked server-side;
/// mismatching clients are flagged and quarantined.  With `ttl = 0`
/// (the default) quarantine is permanent — the historical behavior,
/// bit-exactly.  With `ttl = N`, a flagged client re-enters after `N`
/// rounds *on probation*: its next participating round it is forced
/// into the witness sample (always re-verified), and only a clean check
/// clears the probation; a second mismatch re-quarantines it with a
/// fresh TTL.  RNG state is checkpointable so witness draws survive
/// resume, and the probation force-add consumes no RNG draws.
#[derive(Debug)]
pub struct Committee {
    frac: f64,
    rng: Rng,
    quarantined: Vec<bool>,
    /// Re-admission TTL in rounds (0 = permanent quarantine).
    ttl: usize,
    /// Round each client was last flagged at (valid while quarantined).
    flagged_round: Vec<u64>,
    /// Re-admitted on probation: next update is always verified.
    probation: Vec<bool>,
    pub flagged_total: u64,
    witness_buf: Vec<usize>,
}

impl Committee {
    pub fn new(n: usize, frac: f64, seed: u64) -> Self {
        Self {
            frac,
            rng: Rng::new(seed),
            quarantined: vec![false; n],
            ttl: 0,
            flagged_round: vec![0; n],
            probation: vec![false; n],
            flagged_total: 0,
            witness_buf: Vec::new(),
        }
    }

    pub fn is_active(&self) -> bool {
        self.frac > 0.0
    }

    /// Enable re-admission after `ttl` quarantined rounds (0 keeps
    /// quarantine permanent).
    pub fn set_ttl(&mut self, ttl: usize) {
        self.ttl = ttl;
    }

    pub fn ttl(&self) -> usize {
        self.ttl
    }

    /// Advance the quarantine clocks at the start of round `round`:
    /// clients whose TTL expired re-enter on probation.  A no-op when
    /// `ttl = 0` (permanent quarantine).
    pub fn tick(&mut self, round: u64) {
        let mut readmitted = Vec::new();
        self.tick_into(round, &mut readmitted);
    }

    /// [`Committee::tick`] that also reports which clients re-entered
    /// on probation this round (`readmitted` is caller-owned scratch,
    /// cleared first) — the session clears a re-admitted client's
    /// error-feedback residual so quarantine-era mass is never
    /// retransmitted.
    pub fn tick_into(&mut self, round: u64, readmitted: &mut Vec<usize>) {
        readmitted.clear();
        if self.ttl == 0 {
            return;
        }
        for u in 0..self.quarantined.len() {
            if self.quarantined[u] && round >= self.flagged_round[u] + self.ttl as u64 {
                self.quarantined[u] = false;
                self.probation[u] = true;
                readmitted.push(u);
            }
        }
    }

    /// Draw this round's witnesses from `pool` (client ids): partial
    /// Fisher–Yates over the pool, first ⌈frac·m⌉ slots kept, sorted
    /// for stable iteration.  Exactly ⌈frac·m⌉ RNG draws per call.
    /// Pool members on probation are then force-added (no RNG cost) —
    /// a re-admitted client's first update is always verified.
    pub fn select(&mut self, pool: &[usize]) -> &[usize] {
        self.witness_buf.clear();
        if !self.is_active() || pool.is_empty() {
            return &self.witness_buf;
        }
        self.witness_buf.extend_from_slice(pool);
        let m = self.witness_buf.len();
        let w = ((self.frac * m as f64).ceil() as usize).min(m);
        for i in 0..w {
            let j = i + self.rng.below(m - i);
            self.witness_buf.swap(i, j);
        }
        self.witness_buf.truncate(w);
        for &u in pool {
            if self.probation[u] && !self.witness_buf.contains(&u) {
                self.witness_buf.push(u);
            }
        }
        self.witness_buf.sort_unstable();
        &self.witness_buf
    }

    /// Flag client `u` at round `round`: re-quarantine (probation, if
    /// any, is revoked) and restart its TTL clock.
    pub fn flag(&mut self, u: usize, round: u64) {
        self.flagged_total += 1;
        self.quarantined[u] = true;
        self.probation[u] = false;
        self.flagged_round[u] = round;
    }

    /// A probationer passed its forced re-verification.
    pub fn clear_probation(&mut self, u: usize) {
        self.probation[u] = false;
    }

    pub fn is_probation(&self, u: usize) -> bool {
        self.probation[u]
    }

    pub fn probation_count(&self) -> u64 {
        self.probation.iter().filter(|&&p| p).count() as u64
    }

    pub fn is_quarantined(&self, u: usize) -> bool {
        self.quarantined[u]
    }

    pub fn quarantined_count(&self) -> u64 {
        self.quarantined.iter().filter(|&&q| q).count() as u64
    }

    pub fn rng_state(&self) -> u64 {
        self.rng.state()
    }

    pub fn set_rng_state(&mut self, state: u64) {
        self.rng = Rng::from_state(state);
    }

    /// Quarantine flags bit-packed into u64 words (checkpoint payload).
    pub fn quarantine_words(&self) -> Vec<u64> {
        self.quarantined
            .chunks(64)
            .map(|c| c.iter().enumerate().fold(0u64, |a, (i, &b)| a | ((b as u64) << i)))
            .collect()
    }

    pub fn restore_quarantine(&mut self, words: &[u64]) -> Result<()> {
        let expect = (self.quarantined.len() + 63) / 64;
        if words.len() != expect {
            bail!("quarantine mask has {} words, expected {expect}", words.len());
        }
        for (u, q) in self.quarantined.iter_mut().enumerate() {
            *q = (words[u / 64] >> (u % 64)) & 1 == 1;
        }
        Ok(())
    }

    /// TTL bookkeeping for checkpoints — probation flags bit-packed
    /// like the quarantine mask, followed by the per-client flag
    /// rounds.  Written only when `ttl > 0` (the permanent-quarantine
    /// checkpoint layout is unchanged).
    pub fn ttl_state(&self) -> Vec<u64> {
        let mut words: Vec<u64> = self
            .probation
            .chunks(64)
            .map(|c| c.iter().enumerate().fold(0u64, |a, (i, &b)| a | ((b as u64) << i)))
            .collect();
        words.extend_from_slice(&self.flagged_round);
        words
    }

    pub fn restore_ttl_state(&mut self, words: &[u64]) -> Result<()> {
        let n = self.probation.len();
        let mask_words = (n + 63) / 64;
        if words.len() != mask_words + n {
            bail!("ttl state has {} words, expected {}", words.len(), mask_words + n);
        }
        for (u, p) in self.probation.iter_mut().enumerate() {
            *p = (words[u / 64] >> (u % 64)) & 1 == 1;
        }
        self.flagged_round.copy_from_slice(&words[mask_words..]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelDims;

    fn dims() -> ModelDims {
        ModelDims::mini()
    }

    fn halves(seed: u64, k: usize) -> (AdapterSet, AdapterSet, AdapterSet) {
        let full = AdapterSet::init(&dims(), 4, seed);
        let (c, s) = full.split_at(k).unwrap();
        (full, c, s)
    }

    #[test]
    fn attacker_selection_is_seeded_and_sized() {
        let a = FaultInjector::new(20, AttackKind::Scale, 0.2, -10.0, 7);
        let b = FaultInjector::new(20, AttackKind::Scale, 0.2, -10.0, 7);
        assert_eq!(a.attacker_count(), 4, "ceil(0.2 * 20)");
        for u in 0..20 {
            assert_eq!(a.is_attacker(u), b.is_attacker(u), "same seed, same set");
        }
        let c = FaultInjector::new(20, AttackKind::Scale, 0.2, -10.0, 8);
        assert!((0..20).any(|u| a.is_attacker(u) != c.is_attacker(u)), "seed must matter");
        let none = FaultInjector::new(20, AttackKind::None, 0.5, -10.0, 7);
        assert_eq!(none.attacker_count(), 0, "attack none disables selection");
        assert_eq!(FaultInjector::new(10, AttackKind::Corrupt, 0.05, 1.0, 1).attacker_count(), 1);
    }

    #[test]
    fn corrupt_attack_injects_non_finite_segment() {
        let (baseline, c, s) = halves(3, 2);
        let mut inj = FaultInjector::new(1, AttackKind::Corrupt, 1.0, -10.0, 5);
        inj.prepare(0, &c, &s, &baseline).unwrap();
        let (fc, fs) = inj.submission(0).unwrap();
        assert!(joined_non_finite(fc, fs).unwrap());
        // Honest clients pass through bit-exactly.
        let mut honest = FaultInjector::new(2, AttackKind::Corrupt, 0.5, -10.0, 5);
        let victim = (0..2).find(|&u| !honest.is_attacker(u)).unwrap();
        honest.prepare(victim, &c, &s, &baseline).unwrap();
        let (hc, hs) = honest.submission(victim).unwrap();
        assert!(!differs(hc, &c).unwrap());
        assert!(!differs(hs, &s).unwrap());
    }

    #[test]
    fn scale_attack_applies_lambda_around_baseline() {
        let (baseline, c, s) = halves(9, 2);
        let mut drifted_c = c.clone();
        for t in drifted_c.tensors.iter_mut() {
            for x in t.as_f32_mut().unwrap() {
                *x += 0.5;
            }
        }
        let mut inj = FaultInjector::new(1, AttackKind::Scale, 1.0, -2.0, 5);
        inj.prepare(0, &drifted_c, &s, &baseline).unwrap();
        let (fc, fs) = inj.submission(0).unwrap();
        // Client delta was +0.5 everywhere ⇒ attacked delta is −1.0.
        for (i, t) in fc.tensors.iter().enumerate() {
            let b = c.tensors[i].as_f32().unwrap();
            for (x, bb) in t.as_f32().unwrap().iter().zip(b) {
                assert!((x - (bb - 1.0)).abs() < 1e-5);
            }
        }
        // Server half had zero delta ⇒ unchanged.
        assert!(!differs(fs, &s).unwrap());
    }

    #[test]
    fn stale_attack_replays_previous_round() {
        let (baseline, c1, s1) = halves(11, 2);
        let (_, c2, s2) = halves(12, 2);
        let mut inj = FaultInjector::new(1, AttackKind::Stale, 1.0, -10.0, 5);
        inj.prepare(0, &c1, &s1, &baseline).unwrap();
        let (f, _) = inj.submission(0).unwrap();
        assert!(!differs(f, &c1).unwrap(), "first round has nothing to replay");
        inj.prepare(0, &c2, &s2, &baseline).unwrap();
        let (f2, g2) = inj.submission(0).unwrap();
        assert!(!differs(f2, &c1).unwrap(), "second round replays round 1");
        assert!(!differs(g2, &s1).unwrap());
        inj.prepare(0, &c1, &s1, &baseline).unwrap();
        let (f3, _) = inj.submission(0).unwrap();
        assert!(!differs(f3, &c2).unwrap(), "third round replays round 2");
    }

    #[test]
    fn prepare_is_tensor_alloc_free_after_first_round() {
        let (baseline, c, s) = halves(13, 2);
        let mut inj = FaultInjector::new(2, AttackKind::Corrupt, 0.5, -10.0, 5);
        for u in 0..2 {
            inj.prepare(u, &c, &s, &baseline).unwrap();
        }
        let before = crate::tensor::alloc_count();
        for _ in 0..3 {
            for u in 0..2 {
                inj.prepare(u, &c, &s, &baseline).unwrap();
            }
        }
        assert_eq!(crate::tensor::alloc_count(), before, "steady-state prepare must not allocate");
    }

    #[test]
    fn injector_rng_state_roundtrips() {
        let (baseline, c, s) = halves(17, 2);
        let mut a = FaultInjector::new(1, AttackKind::Corrupt, 1.0, -10.0, 5);
        a.prepare(0, &c, &s, &baseline).unwrap();
        let mut b = FaultInjector::new(1, AttackKind::Corrupt, 1.0, -10.0, 5);
        b.set_rng_state(a.rng_state());
        a.prepare(0, &c, &s, &baseline).unwrap();
        b.prepare(0, &c, &s, &baseline).unwrap();
        let (ac, as_) = a.submission(0).unwrap();
        let (bc, bs) = b.submission(0).unwrap();
        assert!(!differs(ac, bc).unwrap());
        assert!(!differs(as_, bs).unwrap());
    }

    #[test]
    fn committee_selection_is_seeded_subset() {
        let pool: Vec<usize> = vec![2, 5, 7, 11, 13, 17, 19, 23];
        let mut a = Committee::new(30, 0.25, 9);
        let mut b = Committee::new(30, 0.25, 9);
        let wa: Vec<usize> = a.select(&pool).to_vec();
        assert_eq!(wa.len(), 2, "ceil(0.25 * 8)");
        assert!(wa.iter().all(|u| pool.contains(u)));
        assert_eq!(wa, b.select(&pool).to_vec(), "same seed, same witnesses");
        // Resuming from saved RNG state reproduces the next draw.
        let state = a.rng_state();
        let next: Vec<usize> = a.select(&pool).to_vec();
        b.set_rng_state(state);
        assert_eq!(next, b.select(&pool).to_vec());
        let mut off = Committee::new(30, 0.0, 9);
        assert!(off.select(&pool).is_empty(), "frac 0 draws nothing");
    }

    #[test]
    fn committee_quarantine_is_sticky_and_checkpointable() {
        let mut c = Committee::new(70, 0.5, 3);
        c.flag(4, 1);
        c.flag(69, 1);
        assert_eq!(c.flagged_total, 2);
        assert_eq!(c.quarantined_count(), 2);
        assert!(c.is_quarantined(4) && c.is_quarantined(69) && !c.is_quarantined(5));
        let words = c.quarantine_words();
        assert_eq!(words.len(), 2);
        let mut d = Committee::new(70, 0.5, 3);
        d.restore_quarantine(&words).unwrap();
        for u in 0..70 {
            assert_eq!(c.is_quarantined(u), d.is_quarantined(u));
        }
        assert!(d.restore_quarantine(&[0]).is_err(), "wrong word count rejected");
    }

    #[test]
    fn quarantine_ttl_readmits_on_probation() {
        let mut c = Committee::new(8, 0.25, 3);
        c.set_ttl(2);
        c.flag(5, 10);
        assert!(c.is_quarantined(5));
        c.tick(11);
        assert!(c.is_quarantined(5), "TTL not yet elapsed");
        c.tick(12);
        assert!(!c.is_quarantined(5), "TTL elapsed: re-admitted");
        assert!(c.is_probation(5));
        assert_eq!(c.probation_count(), 1);
        // A probationer in the pool is force-added to the witnesses.
        let pool: Vec<usize> = (0..8).collect();
        let w = c.select(&pool).to_vec();
        assert!(w.contains(&5), "probationer must be verified");
        // Clean check clears probation; a repeat offense re-quarantines
        // with a fresh TTL clock.
        c.clear_probation(5);
        assert!(!c.is_probation(5));
        c.flag(5, 20);
        assert!(c.is_quarantined(5));
        c.tick(21);
        assert!(c.is_quarantined(5), "fresh TTL clock after re-flag");
        c.tick(22);
        assert!(!c.is_quarantined(5));
    }

    #[test]
    fn ttl_zero_is_permanent_and_costs_no_rng() {
        // tick() is a no-op and select() draws identically with and
        // without the TTL machinery compiled in — ttl = 0 must stay
        // bit-identical to the historical permanent quarantine.
        let pool: Vec<usize> = vec![1, 2, 3, 4, 5, 6];
        let mut a = Committee::new(10, 0.5, 9);
        let mut b = Committee::new(10, 0.5, 9);
        b.set_ttl(0);
        a.flag(2, 0);
        b.flag(2, 0);
        for round in 0..5 {
            b.tick(round);
            assert_eq!(a.select(&pool).to_vec(), b.select(&pool).to_vec());
            assert!(b.is_quarantined(2), "ttl 0 never re-admits");
        }
    }

    #[test]
    fn ttl_state_roundtrips() {
        let mut c = Committee::new(70, 0.25, 3);
        c.set_ttl(4);
        c.flag(4, 7);
        c.flag(69, 9);
        c.tick(11); // client 4 re-admitted on probation; 69 still in
        assert!(c.is_probation(4) && c.is_quarantined(69));
        let ttl_words = c.ttl_state();
        let q_words = c.quarantine_words();
        let mut d = Committee::new(70, 0.25, 3);
        d.set_ttl(4);
        d.restore_quarantine(&q_words).unwrap();
        d.restore_ttl_state(&ttl_words).unwrap();
        for u in 0..70 {
            assert_eq!(c.is_quarantined(u), d.is_quarantined(u));
            assert_eq!(c.is_probation(u), d.is_probation(u));
        }
        // The restored TTL clock keeps ticking from the same origin.
        c.tick(13);
        d.tick(13);
        assert_eq!(c.is_quarantined(69), d.is_quarantined(69));
        assert!(!d.is_quarantined(69), "round 13 >= 9 + 4");
        assert!(d.restore_ttl_state(&ttl_words[..3]).is_err());
    }

    #[test]
    fn sanitizer_rejects_non_finite_and_outlier_norms() {
        let dims = dims();
        let baseline = AdapterSet::init(&dims, 4, 21);
        let honest = {
            let mut h = baseline.clone();
            h.tensors[0].as_f32_mut().unwrap()[0] += 0.1;
            h
        };
        let (hc, hs) = honest.split_at(2).unwrap();
        let mut corrupt_c = hc.clone();
        corrupt_c.tensors[0].as_f32_mut().unwrap()[1] = f32::NAN;
        let mut huge = baseline.clone();
        for t in huge.tensors.iter_mut() {
            for x in t.as_f32_mut().unwrap() {
                *x += 50.0;
            }
        }
        let (gc, gs) = huge.split_at(2).unwrap();
        let w = 0.25f32;
        let subs: Vec<(f32, &AdapterSet, &AdapterSet)> =
            vec![(w, &hc, &hs), (w, &corrupt_c, &hs), (w, &gc, &gs), (w, &hc, &hs)];
        let mut norms = Vec::new();
        let mut keep = Vec::new();
        let rejected = sanitize_updates(&subs, &baseline, 10.0, &mut norms, &mut keep).unwrap();
        assert_eq!(rejected, 2);
        assert_eq!(keep, vec![true, false, false, true]);
        assert!(norms[1].is_nan());
        assert!(norms[2] > 10.0 * norms[0]);
    }

    #[test]
    fn tick_into_reports_readmissions() {
        let mut c = Committee::new(8, 0.5, 3);
        c.set_ttl(4);
        c.flag(2, 9);
        c.flag(5, 10);
        let mut readmitted = Vec::new();
        c.tick_into(12, &mut readmitted);
        assert!(readmitted.is_empty(), "TTLs still running at round 12");
        c.tick_into(13, &mut readmitted);
        assert_eq!(readmitted, vec![2], "client 2's TTL expires at round 13");
        assert!(c.is_probation(2) && !c.is_quarantined(2));
        c.tick_into(14, &mut readmitted);
        assert_eq!(readmitted, vec![5], "scratch must be cleared between calls");
    }

    #[test]
    fn norm_spread_is_max_over_median() {
        assert_eq!(norm_spread(&[]), None);
        assert_eq!(norm_spread(&[1.0]), None, "one norm carries no spread");
        assert_eq!(norm_spread(&[0.0, 0.0, 0.0]), None, "zero median is degenerate");
        assert_eq!(norm_spread(&[f64::NAN, 2.0]), None, "non-finite norms are excluded");
        let s = norm_spread(&[1.0, 2.0, 6.0]).unwrap();
        assert!((s - 3.0).abs() < 1e-12, "max 6 / median 2 = 3, got {s}");
        let s = norm_spread(&[4.0, f64::NAN, 1.0, 8.0]).unwrap();
        assert!((s - 2.0).abs() < 1e-12, "finite [1,4,8]: max 8 / median 4, got {s}");
    }

    #[test]
    fn differs_is_bitwise_and_nan_safe() {
        let a = AdapterSet::init(&dims(), 2, 31);
        let mut b = a.clone();
        assert!(!differs(&a, &b).unwrap());
        let i = b.tensors[2].as_f32().unwrap().len() / 2;
        b.tensors[2].as_f32_mut().unwrap()[i] = f32::NAN;
        assert!(differs(&a, &b).unwrap(), "NaN-poisoned copy must differ");
        let mut c = a.clone();
        let v = c.tensors[0].as_f32().unwrap()[0];
        c.tensors[0].as_f32_mut().unwrap()[0] = f32::from_bits(v.to_bits() ^ 1);
        assert!(differs(&a, &c).unwrap(), "single-ULP flip must differ");
    }
}
