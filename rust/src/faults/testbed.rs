//! Synthetic attack/defense testbed: a closed-form federated run whose
//! convergence is analytically known, used by `benches/robust.rs` and
//! the artifact-free robustness tests to measure how much of the clean
//! run's final quality each defense recovers under each attack.
//!
//! World model: the full-depth global adapters `G` start at zero and
//! the (unknown to the defenses) optimum `T` is all-ones.  Each round
//! every client takes the same contractive step
//! `G + η·(T − G) + ε,  ε ~ N(0, σ²)` per coordinate, splits it at a
//! fixed cut, and submits the halves.  Honest-only FedAvg therefore
//! converges linearly (`‖G − T‖` shrinks by `1 − η` per round down to
//! the `σ/(η√n)` noise floor), so "quality" has a crisp meaning:
//! `1 − min(1, ‖G − T‖ / ‖G₀ − T‖)`, with a non-finite distance
//! (NaN-poisoned global) scored 0.
//!
//! Attacks go through the real [`FaultInjector`]; defenses are the real
//! [`Committee`], [`sanitize_updates`], and the trimmed / clipped merge
//! kernels — the testbed only replaces the PJRT training step with the
//! closed-form one, so the bench needs no artifacts.

use super::{differs, sanitize_updates, AggKind, AttackKind, Committee, FaultInjector};
use crate::lora::{
    clipped_fedavg_joined_into, fedavg_joined_into, trimmed_fedavg_joined_into, AdapterSet,
};
use crate::model::ModelDims;
use crate::tensor::rng::Rng;
use anyhow::Result;

/// Per-round contraction toward the optimum (the "learning rate" of the
/// closed-form client step).
pub const ETA: f32 = 0.3;
/// Per-coordinate honest noise std — small against the unit optimum so
/// the clean noise floor sits at quality ≈ 0.9999.
pub const NOISE: f64 = 1e-4;

/// One attack × defense configuration of the synthetic run.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub n: usize,
    pub rounds: usize,
    pub attack: AttackKind,
    pub frac: f64,
    pub lambda: f64,
    pub agg: AggKind,
    pub trim: usize,
    /// Clip threshold as a fraction of the initial distance ‖G₀ − T‖
    /// (`f64::INFINITY` disables clipping).
    pub clip_rel: f64,
    pub sanitize: bool,
    pub sanitize_mult: f64,
    pub verify_frac: f64,
    pub seed: u64,
}

impl Default for Scenario {
    fn default() -> Self {
        Self {
            n: 10,
            rounds: 150,
            attack: AttackKind::None,
            frac: 0.0,
            lambda: -10.0,
            agg: AggKind::Mean,
            trim: 0,
            clip_rel: f64::INFINITY,
            sanitize: false,
            sanitize_mult: 3.0,
            verify_frac: 0.0,
            seed: 33,
        }
    }
}

/// What a scenario run produced.
#[derive(Debug, Clone, Copy)]
pub struct Outcome {
    /// `1 − min(1, final_dist / d0)`; 0 if the global went non-finite.
    pub quality: f64,
    pub final_dist: f64,
    pub d0: f64,
    pub flagged: u64,
    pub quarantined: u64,
    pub rejected: u64,
    /// Cumulative trimmed slots / clipped contributors across rounds.
    pub trim_count: u64,
}

fn dist(a: &AdapterSet, b: &AdapterSet) -> Result<f64> {
    let mut acc = 0.0f64;
    for (x, y) in a.tensors.iter().zip(b.tensors.iter()) {
        for (p, q) in x.as_f32()?.iter().zip(y.as_f32()?) {
            let d = (*p - *q) as f64;
            acc += d * d;
        }
    }
    Ok(acc.sqrt())
}

/// Run one scenario to completion and score it.
pub fn run(sc: &Scenario) -> Result<Outcome> {
    let dims = ModelDims::mini();
    let layers = dims.layers;
    let k = layers / 2;
    let mut truth = AdapterSet::zeros(&dims, layers);
    for t in truth.tensors.iter_mut() {
        t.as_f32_mut()?.fill(1.0);
    }
    let mut global = AdapterSet::zeros(&dims, layers);
    let d0 = dist(&global, &truth)?;
    let clip = sc.clip_rel * d0;
    let mut rng = Rng::new(sc.seed);
    let mut inj = (sc.attack != AttackKind::None && sc.frac > 0.0)
        .then(|| FaultInjector::new(sc.n, sc.attack, sc.frac, sc.lambda, sc.seed ^ 0xFA17_5EED));
    let mut committee = Committee::new(sc.n, sc.verify_frac, sc.seed ^ 0xC077_EE5E);
    let mut cs: Vec<AdapterSet> = (0..sc.n).map(|_| AdapterSet::zeros(&dims, k)).collect();
    let mut ss: Vec<AdapterSet> = (0..sc.n).map(|_| AdapterSet::zeros(&dims, layers - k)).collect();
    let mut agg = AdapterSet::zeros(&dims, layers);
    let mut col: Vec<(f32, f32)> = Vec::new();
    let mut norms: Vec<f64> = Vec::new();
    let mut keep: Vec<bool> = Vec::new();
    let mut witnesses: Vec<usize> = Vec::new();
    let mut rejected_total = 0u64;
    let mut trim_total = 0u64;

    for round in 0..sc.rounds {
        // Closed-form honest step: every client contracts toward T.
        for u in 0..sc.n {
            for i in 0..4 {
                let inner: usize = global.tensors[i].shape[1..].iter().product();
                let b = global.tensors[i].as_f32()?;
                let t = truth.tensors[i].as_f32()?;
                let split = k * inner;
                for (j, x) in cs[u].tensors[i].as_f32_mut()?.iter_mut().enumerate() {
                    *x = b[j] + ETA * (t[j] - b[j]) + (NOISE * rng.normal()) as f32;
                }
                for (j, x) in ss[u].tensors[i].as_f32_mut()?.iter_mut().enumerate() {
                    let g = split + j;
                    *x = b[g] + ETA * (t[g] - b[g]) + (NOISE * rng.normal()) as f32;
                }
            }
        }
        let mut survivors: Vec<usize> =
            (0..sc.n).filter(|&u| !committee.is_quarantined(u)).collect();
        if let Some(inj) = inj.as_mut() {
            for &u in &survivors {
                inj.prepare(u, &cs[u], &ss[u], &global)?;
            }
        }
        if committee.is_active() {
            witnesses.clear();
            witnesses.extend_from_slice(committee.select(&survivors));
            for &u in &witnesses {
                let bad = match inj.as_ref().and_then(|i| i.submission(u)) {
                    Some((c, s)) => differs(c, &cs[u])? || differs(s, &ss[u])?,
                    None => false,
                };
                if bad {
                    committee.flag(u, round as u64);
                }
            }
            survivors.retain(|&u| !committee.is_quarantined(u));
        }
        let injr = inj.as_ref();
        let mut subs: Vec<(f32, &AdapterSet, &AdapterSet)> = survivors
            .iter()
            .map(|&u| match injr.and_then(|i| i.submission(u)) {
                Some((c, s)) => (1.0f32, c, s),
                None => (1.0f32, &cs[u], &ss[u]),
            })
            .collect();
        if sc.sanitize {
            rejected_total +=
                sanitize_updates(&subs, &global, sc.sanitize_mult, &mut norms, &mut keep)?;
            let mut i = 0;
            subs.retain(|_| {
                let kept = keep[i];
                i += 1;
                kept
            });
        }
        if subs.is_empty() {
            continue;
        }
        let w = 1.0 / subs.len() as f32;
        for sub in subs.iter_mut() {
            sub.0 = w;
        }
        match sc.agg {
            AggKind::Mean => fedavg_joined_into(&subs, &mut agg)?,
            AggKind::Trimmed => {
                let trim = sc.trim.min(subs.len().saturating_sub(1) / 2);
                trim_total += 2 * trim as u64;
                trimmed_fedavg_joined_into(&subs, trim, &mut col, &mut agg)?;
            }
            AggKind::Clip => {
                trim_total += clipped_fedavg_joined_into(&subs, &global, clip, &mut agg)?;
            }
        }
        drop(subs);
        for (g, a) in global.tensors.iter_mut().zip(agg.tensors.iter()) {
            g.as_f32_mut()?.copy_from_slice(a.as_f32()?);
        }
    }
    let final_dist = dist(&global, &truth)?;
    let quality =
        if final_dist.is_finite() { 1.0 - (final_dist / d0).min(1.0) } else { 0.0 };
    Ok(Outcome {
        quality,
        final_dist,
        d0,
        flagged: committee.flagged_total,
        quarantined: committee.quarantined_count(),
        rejected: rejected_total,
        trim_count: trim_total,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_run_converges_to_noise_floor() {
        let out = run(&Scenario::default()).unwrap();
        assert!(out.quality > 0.99, "clean quality {} below noise-floor bound", out.quality);
        assert_eq!(out.flagged, 0);
        assert_eq!(out.rejected, 0);
    }

    #[test]
    fn testbed_is_seed_deterministic() {
        let sc = Scenario {
            attack: AttackKind::Scale,
            frac: 0.2,
            agg: AggKind::Trimmed,
            trim: 2,
            rounds: 40,
            ..Scenario::default()
        };
        let a = run(&sc).unwrap();
        let b = run(&sc).unwrap();
        assert_eq!(a.quality.to_bits(), b.quality.to_bits(), "same seed, same trajectory");
        let c = run(&Scenario { seed: 34, ..sc }).unwrap();
        assert_ne!(a.quality.to_bits(), c.quality.to_bits(), "seed must matter");
    }
}
