//! Training-state checkpointing: persist/restore the full coordinator
//! state (per-client client/server LoRA, heads, Adam moments, round
//! counter) so long fine-tuning runs survive restarts.
//!
//! Uses the same SFLP binary tensor format as params.bin (one format,
//! one parser — see python/compile/packing.py), with a `meta.*` scalar
//! namespace for counters.  `coordinator::Session::checkpoint` builds on
//! this writer (plus the bit-exact 64-bit encoders below) to persist a
//! *resumable* session whose remaining rounds replay bit-identically.

use crate::data::BatchIter;
use crate::lora::{AdapterSet, LORA_KEYS};
use crate::runtime::{AdamState, ClientState, HeadState, ServerState};
use crate::tensor::{ops, store::ParamStore, HostTensor, TensorData};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

const MAGIC: &[u8; 4] = b"SFLP";
const VERSION: u32 = 1;

/// Serialize tensors into the SFLP binary format (the rust-side writer
/// mirroring packing.write_params_bin).
pub fn write_sflp(path: &Path, tensors: &[(&str, &HostTensor)]) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for (name, t) in tensors {
        let nb = name.as_bytes();
        if nb.len() > u16::MAX as usize {
            bail!("tensor name too long: {name}");
        }
        buf.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        buf.extend_from_slice(nb);
        buf.push(match t.data {
            TensorData::F32(_) => 0,
            TensorData::I32(_) => 1,
        });
        buf.push(t.shape.len() as u8);
        for &d in &t.shape {
            buf.extend_from_slice(&(d as u32).to_le_bytes());
        }
        buf.extend_from_slice(&t.to_le_bytes());
    }
    let mut fh = std::fs::File::create(path)
        .with_context(|| format!("creating checkpoint {}", path.display()))?;
    fh.write_all(&buf)?;
    Ok(())
}

/// Bit-exact u64 → i32-pair encoding.  SFLP has no 64-bit dtype, but
/// session checkpoints must round-trip `f64` clocks and RNG states
/// exactly (bit-identical resume), so 64-bit values are stored as two
/// little-endian i32 words each.
pub fn encode_u64s(name: impl Into<String>, vals: &[u64]) -> HostTensor {
    let mut words = Vec::with_capacity(vals.len() * 2);
    for &v in vals {
        words.push((v & 0xFFFF_FFFF) as u32 as i32);
        words.push((v >> 32) as u32 as i32);
    }
    let n = words.len();
    HostTensor::i32(name, vec![n], words)
}

/// Inverse of [`encode_u64s`].
pub fn decode_u64s(t: &HostTensor) -> Result<Vec<u64>> {
    let w = t.as_i32()?;
    if w.len() % 2 != 0 {
        bail!("u64 tensor {} has odd word count {}", t.name, w.len());
    }
    Ok(w.chunks_exact(2)
        .map(|c| (c[0] as u32 as u64) | ((c[1] as u32 as u64) << 32))
        .collect())
}

/// Bit-exact f64 encoding via [`encode_u64s`] (`f64::to_bits`).
pub fn encode_f64s(name: impl Into<String>, vals: &[f64]) -> HostTensor {
    let bits: Vec<u64> = vals.iter().map(|v| v.to_bits()).collect();
    encode_u64s(name, &bits)
}

/// Inverse of [`encode_f64s`].
pub fn decode_f64s(t: &HostTensor) -> Result<Vec<f64>> {
    Ok(decode_u64s(t)?.into_iter().map(f64::from_bits).collect())
}

// ---------------------------------------------------------------------
// Shared named-tensor plumbing used by the session checkpoint and the
// state pool's sparse spill/serialization (one encoding, two callers).
// ---------------------------------------------------------------------

/// Copy a stored tensor's payload into an existing buffer (shape- and
/// dtype-checked) — resume never swaps buffers, only refills them.
pub fn load_tensor_into(store: &ParamStore, key: &str, dst: &mut HostTensor) -> Result<()> {
    ops::copy_from(dst, store.get(key)?)
}

/// Decode a u64 tensor and require at least `n` elements — malformed
/// checkpoints must surface as errors, not index panics.
pub fn u64s_exact(store: &ParamStore, key: &str, n: usize) -> Result<Vec<u64>> {
    let v = decode_u64s(store.get(key)?)?;
    if v.len() < n {
        bail!("checkpoint tensor {key} has {} values, expected {n}", v.len());
    }
    Ok(v)
}

pub fn one_u64(store: &ParamStore, key: &str) -> Result<u64> {
    Ok(u64s_exact(store, key, 1)?[0])
}

/// Decode an f64 tensor and require at least `n` elements.
pub fn f64s_exact(store: &ParamStore, key: &str, n: usize) -> Result<Vec<f64>> {
    let v = decode_f64s(store.get(key)?)?;
    if v.len() < n {
        bail!("checkpoint tensor {key} has {} values, expected {n}", v.len());
    }
    Ok(v)
}

pub fn one_f64(store: &ParamStore, key: &str) -> Result<f64> {
    Ok(f64s_exact(store, key, 1)?[0])
}

/// Read a single i32 scalar, erroring (not panicking) on empty tensors.
pub fn one_i32(store: &ParamStore, key: &str) -> Result<i32> {
    store
        .get(key)?
        .as_i32()?
        .first()
        .copied()
        .ok_or_else(|| anyhow::anyhow!("checkpoint tensor {key} is empty"))
}

/// Save an adapter set's four tensors under `{prefix}.{aq,bq,av,bv}`.
pub fn save_adapters(out: &mut Vec<(String, HostTensor)>, prefix: &str, set: &AdapterSet) {
    for (t, key) in set.tensors.iter().zip(LORA_KEYS.iter()) {
        out.push((format!("{prefix}.{key}"), t.clone()));
    }
}

/// Inverse of [`save_adapters`]: refill `set`'s buffers in place.
pub fn load_adapters(store: &ParamStore, prefix: &str, set: &mut AdapterSet) -> Result<()> {
    for (t, key) in set.tensors.iter_mut().zip(LORA_KEYS.iter()) {
        load_tensor_into(store, &format!("{prefix}.{key}"), t)?;
    }
    Ok(())
}

/// Save Adam moments under `{prefix}.m{i}` / `{prefix}.v{i}`.
pub fn save_adam(out: &mut Vec<(String, HostTensor)>, prefix: &str, adam: &AdamState) {
    for (i, t) in adam.m.iter().enumerate() {
        out.push((format!("{prefix}.m{i}"), t.clone()));
    }
    for (i, t) in adam.v.iter().enumerate() {
        out.push((format!("{prefix}.v{i}"), t.clone()));
    }
}

/// Inverse of [`save_adam`]: refill the moment buffers in place.
pub fn load_adam(store: &ParamStore, prefix: &str, adam: &mut AdamState) -> Result<()> {
    for (i, t) in adam.m.iter_mut().enumerate() {
        load_tensor_into(store, &format!("{prefix}.m{i}"), t)?;
    }
    for (i, t) in adam.v.iter_mut().enumerate() {
        load_tensor_into(store, &format!("{prefix}.v{i}"), t)?;
    }
    Ok(())
}

/// Save a batch-iterator snapshot (shuffled order, cursor, RNG word)
/// under `scheme.iter{u}.*` — callers pass the raw triple so spilled
/// (non-resident) iterators serialize without rebuilding a `BatchIter`.
pub fn save_iter_state(
    out: &mut Vec<(String, HostTensor)>,
    u: usize,
    indices: &[usize],
    cursor: usize,
    rng: u64,
) {
    let idx32: Vec<i32> = indices.iter().map(|&x| x as i32).collect();
    let n = idx32.len();
    out.push((
        format!("scheme.iter{u}.indices"),
        HostTensor::i32(format!("scheme.iter{u}.indices"), vec![n], idx32),
    ));
    out.push((format!("scheme.iter{u}.cursor"), encode_u64s("cursor", &[cursor as u64])));
    out.push((format!("scheme.iter{u}.rng"), encode_u64s("rng", &[rng])));
}

/// Restore one batch iterator saved by [`save_iter_state`].  The
/// restored order must be a permutation of the iterator's own shard —
/// anything else is a corrupted or mismatched checkpoint and must error
/// here, not panic in `next_batch()` later.
pub fn load_iter_state(store: &ParamStore, u: usize, it: &mut BatchIter) -> Result<()> {
    let raw = store.get(&format!("scheme.iter{u}.indices"))?.as_i32()?;
    if raw.iter().any(|&x| x < 0) {
        bail!("checkpoint iter{u} contains a negative dataset index");
    }
    let indices: Vec<usize> = raw.iter().map(|&x| x as usize).collect();
    let mut restored = indices.clone();
    restored.sort_unstable();
    let mut current = it.state().0.to_vec();
    current.sort_unstable();
    if restored != current {
        bail!("checkpoint iter{u} indices are not a permutation of the client's shard");
    }
    let cursor = one_u64(store, &format!("scheme.iter{u}.cursor"))? as usize;
    if cursor > indices.len() {
        bail!("checkpoint iter{u} cursor {cursor} exceeds shard size {}", indices.len());
    }
    let rng = one_u64(store, &format!("scheme.iter{u}.rng"))?;
    it.restore_state(indices, cursor, rng);
    Ok(())
}

/// A full coordinator checkpoint (Ours/SFL schemes).
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub round: usize,
    pub sim_time: f64,
    pub clients: Vec<ClientState>,
    pub servers: Vec<ServerState>,
}

fn push_adapters<'a>(
    out: &mut Vec<(String, &'a HostTensor)>,
    prefix: &str,
    set: &'a AdapterSet,
) {
    for t in &set.tensors {
        out.push((format!("{prefix}.{}", t.name), t));
    }
}

fn push_adam<'a>(out: &mut Vec<(String, &'a HostTensor)>, prefix: &str, adam: &'a AdamState) {
    for (i, t) in adam.m.iter().enumerate() {
        out.push((format!("{prefix}.m{i}"), t));
    }
    for (i, t) in adam.v.iter().enumerate() {
        out.push((format!("{prefix}.v{i}"), t));
    }
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        let meta_round = HostTensor::scalar("round", self.round as f32);
        let meta_time = HostTensor::scalar("sim_time", self.sim_time as f32);
        let meta_clients = HostTensor::scalar("clients", self.clients.len() as f32);
        let mut named: Vec<(String, &HostTensor)> = vec![
            ("meta.round".into(), &meta_round),
            ("meta.sim_time".into(), &meta_time),
            ("meta.clients".into(), &meta_clients),
        ];
        let steps: Vec<HostTensor> = self
            .clients
            .iter()
            .zip(self.servers.iter())
            .enumerate()
            .flat_map(|(u, (c, s))| {
                vec![
                    HostTensor::scalar(format!("c{u}.step"), c.step as f32),
                    HostTensor::scalar(format!("s{u}.step"), s.step as f32),
                ]
            })
            .collect();
        for (u, (c, s)) in self.clients.iter().zip(self.servers.iter()).enumerate() {
            named.push((format!("meta.c{u}.step"), &steps[2 * u]));
            named.push((format!("meta.s{u}.step"), &steps[2 * u + 1]));
            push_adapters(&mut named, &format!("c{u}.lora"), &c.lora);
            push_adam(&mut named, &format!("c{u}.adam"), &c.adam);
            push_adapters(&mut named, &format!("s{u}.lora"), &s.lora);
            named.push((format!("s{u}.head.w"), &s.head.w));
            named.push((format!("s{u}.head.b"), &s.head.b));
            push_adam(&mut named, &format!("s{u}.adam"), &s.adam);
        }
        let borrowed: Vec<(&str, &HostTensor)> =
            named.iter().map(|(n, t)| (n.as_str(), *t)).collect();
        write_sflp(path, &borrowed)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let store = ParamStore::load(path)?;
        let scalar = |name: &str| -> Result<f32> {
            Ok(store.get(name)?.as_f32()?[0])
        };
        let n_clients = scalar("meta.clients")? as usize;
        let grab_set = |prefix: &str| -> Result<AdapterSet> {
            let tensors = ["aq", "bq", "av", "bv"]
                .iter()
                .map(|k| {
                    let mut t = store.get(&format!("{prefix}.{k}"))?.clone();
                    t.name = k.to_string();
                    Ok(t)
                })
                .collect::<Result<Vec<_>>>()?;
            let layers = tensors[0].shape[0];
            AdapterSet::from_tensors(layers, tensors)
        };
        let grab_adam = |prefix: &str, n: usize| -> Result<AdamState> {
            let m = (0..n)
                .map(|i| Ok(store.get(&format!("{prefix}.m{i}"))?.clone()))
                .collect::<Result<Vec<_>>>()?;
            let v = (0..n)
                .map(|i| Ok(store.get(&format!("{prefix}.v{i}"))?.clone()))
                .collect::<Result<Vec<_>>>()?;
            Ok(AdamState { m, v })
        };

        let mut clients = Vec::with_capacity(n_clients);
        let mut servers = Vec::with_capacity(n_clients);
        for u in 0..n_clients {
            let c_lora = grab_set(&format!("c{u}.lora"))?;
            let c_adam = grab_adam(&format!("c{u}.adam"), 4)?;
            clients.push(ClientState {
                lora: c_lora,
                adam: c_adam,
                step: scalar(&format!("meta.c{u}.step"))? as u64,
            });
            let s_lora = grab_set(&format!("s{u}.lora"))?;
            let head = HeadState {
                w: store.get(&format!("s{u}.head.w"))?.clone(),
                b: store.get(&format!("s{u}.head.b"))?.clone(),
            };
            let s_adam = grab_adam(&format!("s{u}.adam"), 6)?;
            servers.push(ServerState {
                lora: s_lora,
                head,
                adam: s_adam,
                step: scalar(&format!("meta.s{u}.step"))? as u64,
            });
        }
        Ok(Self {
            round: scalar("meta.round")? as usize,
            sim_time: scalar("meta.sim_time")? as f64,
            clients,
            servers,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelDims;

    fn sample() -> Checkpoint {
        let dims = ModelDims::mini();
        let mut clients = Vec::new();
        let mut servers = Vec::new();
        for (u, &k) in [1usize, 2].iter().enumerate() {
            let full = AdapterSet::init(&dims, dims.layers, u as u64);
            let (c, s) = full.split_at(k).unwrap();
            let mut cs = ClientState::fresh(c);
            cs.step = 5 + u as u64;
            let head = HeadState {
                w: HostTensor::f32("w", vec![dims.hidden, dims.classes],
                    vec![0.5; dims.hidden * dims.classes]),
                b: HostTensor::zeros("b", vec![dims.classes]),
            };
            let mut ss = ServerState::fresh(s, head);
            ss.step = 9 + u as u64;
            clients.push(cs);
            servers.push(ss);
        }
        Checkpoint { round: 17, sim_time: 123.5, clients, servers }
    }

    #[test]
    fn save_load_roundtrip() {
        let ck = sample();
        let dir = std::env::temp_dir().join("sfl_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.sflp");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.round, 17);
        assert!((back.sim_time - 123.5).abs() < 1e-3);
        assert_eq!(back.clients.len(), 2);
        for (a, b) in ck.clients.iter().zip(back.clients.iter()) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.lora.max_abs_diff(&b.lora).unwrap(), 0.0);
        }
        for (a, b) in ck.servers.iter().zip(back.servers.iter()) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.lora.max_abs_diff(&b.lora).unwrap(), 0.0);
            assert_eq!(a.head.w.as_f32().unwrap(), b.head.w.as_f32().unwrap());
            assert_eq!(a.adam.m.len(), b.adam.m.len());
        }
    }

    #[test]
    fn writer_output_parses_with_param_store() {
        let t = HostTensor::f32("x", vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let dir = std::env::temp_dir().join("sfl_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.sflp");
        write_sflp(&path, &[("x", &t)]).unwrap();
        let store = ParamStore::load(&path).unwrap();
        assert_eq!(store.get("x").unwrap().as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(Checkpoint::load(Path::new("/nonexistent/ckpt.sflp")).is_err());
    }

    #[test]
    fn u64_f64_encoding_roundtrips_bitwise() {
        let vals = [0u64, 1, u64::MAX, 0xDEAD_BEEF_0123_4567];
        let t = encode_u64s("u", &vals);
        assert_eq!(decode_u64s(&t).unwrap(), vals);
        let fs = [0.0f64, -1.5, 1e300, f64::MIN_POSITIVE, std::f64::consts::PI];
        let t = encode_f64s("f", &fs);
        let back = decode_f64s(&t).unwrap();
        assert_eq!(back.len(), fs.len());
        for (a, b) in fs.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn decode_rejects_odd_word_count() {
        let t = HostTensor::i32("odd", vec![3], vec![1, 2, 3]);
        assert!(decode_u64s(&t).is_err());
    }
}
