//! Heterogeneous device profiles and capability-driven split selection.
//!
//! Before training, each client reports its resources and the server
//! "replicates a reasonable client-side submodel for each client"
//! (paper §III).  `select_cut` is that policy: the deepest cut whose
//! client-side memory footprint and per-step latency fit the device.

use crate::model::{memory, ModelDims};

/// A mobile device participating in training (paper §V-A fleet).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    pub name: String,
    /// Peak compute, TFLOPS (fp16/fp32 mix as the paper quotes them).
    pub tflops: f64,
    /// Usable memory budget for the training process, MB.
    pub memory_mb: f64,
    /// Achievable fraction of peak on transformer workloads (MFU).
    pub mfu: f64,
}

impl DeviceProfile {
    pub fn new(name: &str, tflops: f64, memory_mb: f64) -> Self {
        Self { name: name.into(), tflops, memory_mb, mfu: DEFAULT_CLIENT_MFU }
    }

    /// Effective FLOP/s the device actually sustains.
    pub fn effective_flops(&self) -> f64 {
        self.tflops * 1e12 * self.mfu
    }

    /// Seconds to execute `flops` of transformer work.
    pub fn compute_time(&self, flops: f64) -> f64 {
        flops / self.effective_flops()
    }

    /// The profile as the server *believes* it from reported specs:
    /// same peak TFLOPS and memory, class-default MFU.  This is the
    /// input to the static eq. 10–12 cold-start model; the per-device
    /// MFU deviation (throttling, background load — synthesized by
    /// `fleet::FleetSpec`) is exactly what the online `TimingEstimator`
    /// has to learn.
    pub fn nominal(&self) -> DeviceProfile {
        DeviceProfile { mfu: DEFAULT_CLIENT_MFU, ..self.clone() }
    }
}

/// Default MFU for mobile-class accelerators on attention workloads.
pub const DEFAULT_CLIENT_MFU: f64 = 0.30;
/// Default MFU for the edge-server GPU.
pub const DEFAULT_SERVER_MFU: f64 = 0.40;

/// The edge server (paper: RTX 4080S, 52.2 TFLOPS).
#[derive(Debug, Clone, PartialEq)]
pub struct ServerProfile {
    pub name: String,
    pub tflops: f64,
    pub memory_mb: f64,
    pub mfu: f64,
    /// Throughput degradation per *additional* concurrent training job —
    /// the "fragmentation of server computational resources / memory access
    /// competition" the paper attributes SFL's slowdown to (§V-B).
    pub contention_per_job: f64,
}

impl ServerProfile {
    pub fn rtx4080s() -> Self {
        Self {
            name: "RTX 4080S".into(),
            tflops: 52.2,
            memory_mb: 16.0 * 1024.0,
            mfu: DEFAULT_SERVER_MFU,
            contention_per_job: 0.06,
        }
    }

    pub fn effective_flops(&self, concurrent_jobs: usize) -> f64 {
        let slowdown = 1.0 + self.contention_per_job * concurrent_jobs.saturating_sub(1) as f64;
        self.tflops * 1e12 * self.mfu / slowdown
    }

    /// Seconds for `flops` of work when `concurrent_jobs` share the GPU.
    pub fn compute_time(&self, flops: f64, concurrent_jobs: usize) -> f64 {
        // With J parallel jobs each job gets 1/J of the (contended) rate.
        let jobs = concurrent_jobs.max(1) as f64;
        flops * jobs / self.effective_flops(concurrent_jobs)
    }
}

/// The paper's six-device heterogeneous fleet (§V-A), with the cut
/// assignment the authors used.
pub fn paper_fleet() -> Vec<(DeviceProfile, usize)> {
    vec![
        (DeviceProfile::new("Jetson Nano", 0.472, 4096.0), 1),
        (DeviceProfile::new("Jetson TX2", 1.33, 8192.0), 1),
        (DeviceProfile::new("Snapdragon 8s Gen 3", 1.689, 8192.0), 2),
        (DeviceProfile::new("Snapdragon 8 Gen 3", 2.774, 12288.0), 2),
        (DeviceProfile::new("A17 Pro", 2.147, 8192.0), 3),
        (DeviceProfile::new("M3", 3.533, 16384.0), 3),
    ]
}

/// Choose the deepest cut in `dims.cuts` that fits the device: the
/// client-side submodel must fit the memory budget and one client step
/// (fwd + rematerialized bwd) must complete within `max_step_seconds`.
pub fn select_cut(dims: &ModelDims, dev: &DeviceProfile, max_step_seconds: f64) -> usize {
    let mut sorted = dims.cuts.clone();
    sorted.sort_unstable();
    // Degenerate model with no candidate cuts: nothing runs on-device.
    let Some(&shallowest) = sorted.first() else { return 0 };
    let mut best = shallowest;
    for &k in &sorted {
        let mem_ok = memory::client_memory(dims, k).total_mb() <= dev.memory_mb;
        let step =
            dev.compute_time(dims.client_fwd_flops(k)) + dev.compute_time(dims.client_bwd_flops(k));
        if mem_ok && step <= max_step_seconds {
            best = k;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fleet_matches_section_v() {
        let fleet = paper_fleet();
        assert_eq!(fleet.len(), 6);
        assert_eq!(fleet[0].0.name, "Jetson Nano");
        assert!((fleet[0].0.tflops - 0.472).abs() < 1e-9);
        assert_eq!(fleet[0].1, 1);
        assert_eq!(fleet[5].0.name, "M3");
        assert_eq!(fleet[5].1, 3);
    }

    #[test]
    fn compute_time_scales_inverse_with_tflops() {
        let slow = DeviceProfile::new("slow", 1.0, 8192.0);
        let fast = DeviceProfile::new("fast", 2.0, 8192.0);
        let f = 1e12;
        assert!((slow.compute_time(f) / fast.compute_time(f) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn server_contention_slows_parallel_jobs() {
        let s = ServerProfile::rtx4080s();
        let f = 1e12;
        let alone = s.compute_time(f, 1);
        let contended = s.compute_time(f, 6);
        assert!(contended > 6.0 * alone, "contention must exceed fair-share");
    }

    #[test]
    fn select_cut_respects_memory_budget() {
        let dims = ModelDims::bert_base();
        let tiny = DeviceProfile::new("tiny", 5.0, 400.0); // < client model
        let big = DeviceProfile::new("big", 5.0, 16384.0);
        let kt = select_cut(&dims, &tiny, 1e9);
        let kb = select_cut(&dims, &big, 1e9);
        assert!(kt <= kb);
        assert_eq!(kb, 3);
    }

    #[test]
    fn select_cut_respects_latency_budget() {
        let dims = ModelDims::bert_base();
        let weak = DeviceProfile::new("weak", 0.05, 16384.0);
        let strong = DeviceProfile::new("strong", 10.0, 16384.0);
        let kw = select_cut(&dims, &weak, 0.5);
        let ks = select_cut(&dims, &strong, 0.5);
        assert!(kw <= ks);
    }

    #[test]
    fn effective_flops_includes_mfu() {
        let d = DeviceProfile::new("d", 1.0, 1024.0);
        assert!((d.effective_flops() - 0.30e12).abs() < 1e6);
    }

    #[test]
    fn nominal_resets_only_the_mfu() {
        let mut d = DeviceProfile::new("throttled", 2.0, 8192.0);
        d.mfu = 0.12;
        let n = d.nominal();
        assert!((n.mfu - DEFAULT_CLIENT_MFU).abs() < 1e-12);
        assert_eq!(n.name, d.name);
        assert!((n.tflops - d.tflops).abs() < 1e-12);
        assert!((n.memory_mb - d.memory_mb).abs() < 1e-12);
    }
}
