//! `artifacts/<config>/manifest.txt` — the contract between the python
//! AOT path and this runtime (emitted by python/compile/aot.py; a JSON
//! twin is written for humans, but rust parses the line-based format —
//! this workspace builds offline with no JSON crate).
//!
//! Format (one record per line):
//! ```text
//! config name=mini vocab=1024 hidden=64 ... cuts=1,2,3
//! params params.bin
//! artifact client_fwd_1 client_fwd_1.hlo.txt
//! in tokens i32 8,32
//! in frozen.tok_emb f32 1024,64
//! out acts f32 8,32,64
//! end
//! param frozen.tok_emb
//! ```

use crate::model::ModelDims;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape.iter().product()
        }
    }

    pub fn is_i32(&self) -> bool {
        self.dtype == "i32"
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub path: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dims: ModelDims,
    pub params_bin: String,
    pub artifacts: HashMap<String, ArtifactSpec>,
    pub param_tensors: Vec<String>,
    pub dir: PathBuf,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    if s == "-" {
        return Ok(vec![]); // scalar
    }
    s.split(',')
        .filter(|p| !p.is_empty())
        .map(|p| p.parse::<usize>().with_context(|| format!("bad dim {p:?}")))
        .collect()
}

fn parse_tensor_line(rest: &str) -> Result<TensorSpec> {
    let parts: Vec<&str> = rest.split_whitespace().collect();
    if parts.len() != 3 {
        bail!("tensor line needs `name dtype shape`, got {rest:?}");
    }
    if parts[1] != "f32" && parts[1] != "i32" {
        bail!("unsupported dtype {:?}", parts[1]);
    }
    Ok(TensorSpec {
        name: parts[0].to_string(),
        dtype: parts[1].to_string(),
        shape: parse_shape(parts[2])?,
    })
}

impl Manifest {
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let mut kv: HashMap<String, String> = HashMap::new();
        let mut params_bin = String::from("params.bin");
        let mut artifacts = HashMap::new();
        let mut param_tensors = Vec::new();
        let mut current: Option<(String, ArtifactSpec)> = None;

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (tag, rest) = line.split_once(' ').unwrap_or((line, ""));
            match tag {
                "config" => {
                    for pair in rest.split_whitespace() {
                        let (k, v) = pair
                            .split_once('=')
                            .with_context(|| format!("line {}: bad config pair", lineno + 1))?;
                        kv.insert(k.to_string(), v.to_string());
                    }
                }
                "params" => params_bin = rest.trim().to_string(),
                "artifact" => {
                    if current.is_some() {
                        bail!("line {}: artifact without end", lineno + 1);
                    }
                    let mut it = rest.split_whitespace();
                    let name = it.next().context("artifact needs a name")?.to_string();
                    let path = it.next().context("artifact needs a path")?.to_string();
                    current = Some((
                        name,
                        ArtifactSpec { path, inputs: Vec::new(), outputs: Vec::new() },
                    ));
                }
                "in" => {
                    let (_, spec) = current
                        .as_mut()
                        .with_context(|| format!("line {}: `in` outside artifact", lineno + 1))?;
                    spec.inputs.push(parse_tensor_line(rest)?);
                }
                "out" => {
                    let (_, spec) = current
                        .as_mut()
                        .with_context(|| format!("line {}: `out` outside artifact", lineno + 1))?;
                    spec.outputs.push(parse_tensor_line(rest)?);
                }
                "end" => {
                    let (name, spec) = current
                        .take()
                        .with_context(|| format!("line {}: stray end", lineno + 1))?;
                    artifacts.insert(name, spec);
                }
                "param" => param_tensors.push(rest.trim().to_string()),
                other => bail!("line {}: unknown record {other:?}", lineno + 1),
            }
        }
        if current.is_some() {
            bail!("unterminated artifact record");
        }

        let get = |k: &str| -> Result<String> {
            kv.get(k).cloned().with_context(|| format!("manifest config missing {k}"))
        };
        let dims = ModelDims {
            name: get("name")?,
            vocab: get("vocab")?.parse()?,
            hidden: get("hidden")?.parse()?,
            layers: get("layers")?.parse()?,
            heads: get("heads")?.parse()?,
            ffn: get("ffn")?.parse()?,
            seq: get("seq")?.parse()?,
            classes: get("classes")?.parse()?,
            rank: get("rank")?.parse()?,
            alpha: get("alpha")?.parse()?,
            batch: get("batch")?.parse()?,
            cuts: parse_shape(&get("cuts")?)?,
        };
        let m = Self { dims, params_bin, artifacts, param_tensors, dir };
        m.validate()?;
        Ok(m)
    }

    pub fn load(artifacts_dir: &Path, config_name: &str) -> Result<Self> {
        let dir = artifacts_dir.join(config_name);
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {} — run `make artifacts` first", path.display())
        })?;
        Self::parse(&text, dir)
    }

    pub fn validate(&self) -> Result<()> {
        for k in &self.dims.cuts {
            for prefix in ["client_fwd", "server_step", "client_bwd"] {
                let name = format!("{prefix}_{k}");
                if !self.artifacts.contains_key(&name) {
                    bail!("manifest missing artifact {name}");
                }
            }
        }
        for required in ["eval", "full_step"] {
            if !self.artifacts.contains_key(required) {
                bail!("manifest missing artifact {required}");
            }
        }
        Ok(())
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {name}"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.path))
    }

    pub fn params_path(&self) -> PathBuf {
        self.dir.join(&self.params_bin)
    }

    pub fn dims(&self) -> ModelDims {
        self.dims.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> String {
        let mut s = String::from(
            "config name=mini vocab=1024 hidden=64 layers=4 heads=2 ffn=256 \
             seq=32 classes=6 rank=8 alpha=16.0 batch=8 cuts=1\n\
             params params.bin\n",
        );
        for name in ["client_fwd_1", "server_step_1", "client_bwd_1", "eval", "full_step"] {
            s.push_str(&format!(
                "artifact {name} {name}.hlo.txt\nin tokens i32 8,32\nin step f32 -\nout acts f32 8,32,64\nend\n"
            ));
        }
        s.push_str("param frozen.tok_emb\n");
        s
    }

    #[test]
    fn parse_roundtrip() {
        let m = Manifest::parse(&sample(), PathBuf::from("/tmp/x")).unwrap();
        assert_eq!(m.dims.hidden, 64);
        assert_eq!(m.dims.cuts, vec![1]);
        let a = m.artifact("client_fwd_1").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert!(a.inputs[0].is_i32());
        assert_eq!(a.inputs[1].shape, Vec::<usize>::new());
        assert_eq!(a.inputs[1].numel(), 1);
        assert_eq!(a.outputs[0].numel(), 8 * 32 * 64);
        assert_eq!(m.param_tensors, vec!["frozen.tok_emb"]);
        assert_eq!(
            m.hlo_path("eval").unwrap(),
            PathBuf::from("/tmp/x/eval.hlo.txt")
        );
    }

    #[test]
    fn missing_artifact_fails_validation() {
        let text = sample().replace("artifact full_step", "artifact other_step");
        assert!(Manifest::parse(&text, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn unterminated_artifact_rejected() {
        let mut text = sample();
        text.push_str("artifact dangling d.hlo.txt\nin x f32 1\n");
        assert!(Manifest::parse(&text, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn bad_dtype_rejected() {
        let text = sample().replace("in tokens i32 8,32", "in tokens f64 8,32");
        assert!(Manifest::parse(&text, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn stray_end_rejected() {
        let text = format!("{}end\n", sample());
        assert!(Manifest::parse(&text, PathBuf::from("/tmp")).is_err());
    }
}
