//! PJRT runtime: loads the AOT HLO-text artifacts and executes them.
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Executables compile lazily (first use) and are cached; the frozen
//! parameter block is converted to literals once at engine construction
//! and shared across every call — only the small trainable state moves
//! per step.
//!
//! Marshaling follows the flat input/output order recorded in
//! manifest.json (see python/compile/packing.py — never jax pytree
//! guessing).

pub mod manifest;

use crate::lora::AdapterSet;
use crate::model::ModelDims;
use crate::tensor::{store::ParamStore, HostTensor, TensorData};
use anyhow::{bail, Result};
use manifest::{ArtifactSpec, Manifest, TensorSpec};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

/// Classifier-head trainables.
#[derive(Debug, Clone, PartialEq)]
pub struct HeadState {
    pub w: HostTensor,
    pub b: HostTensor,
}

/// Adam moments mirroring a flat trainable list (m tensors then v
/// tensors, same order as the trainables — packing.adam_spec).
#[derive(Debug, Clone, PartialEq)]
pub struct AdamState {
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
}

impl AdamState {
    /// Zeroed first/second moments mirroring `trainables`.  The moments
    /// get distinct `adam.m.{name}` / `adam.v.{name}` tensor names (they
    /// are different buffers; identical names made checkpoint diffs and
    /// debug dumps ambiguous), and `v` is constructed directly instead
    /// of cloning the whole `m` vector.
    pub fn zeros_like(trainables: &[&HostTensor]) -> Self {
        let m = trainables
            .iter()
            .map(|t| HostTensor::zeros(format!("adam.m.{}", t.name), t.shape.clone()))
            .collect();
        let v = trainables
            .iter()
            .map(|t| HostTensor::zeros(format!("adam.v.{}", t.name), t.shape.clone()))
            .collect();
        Self { m, v }
    }
}

/// Server-side training state for one client: LoRA over layers [k, N),
/// the classifier head, Adam moments, and the step counter.
#[derive(Debug, Clone)]
pub struct ServerState {
    pub lora: AdapterSet,
    pub head: HeadState,
    pub adam: AdamState,
    pub step: u64,
}

impl ServerState {
    pub fn fresh(lora: AdapterSet, head: HeadState) -> Self {
        let flat: Vec<&HostTensor> =
            lora.tensors.iter().chain([&head.w, &head.b]).collect();
        let adam = AdamState::zeros_like(&flat);
        Self { lora, head, adam, step: 0 }
    }
}

/// Client-side training state: LoRA over layers [0, k) + Adam.
#[derive(Debug, Clone)]
pub struct ClientState {
    pub lora: AdapterSet,
    pub adam: AdamState,
    pub step: u64,
}

impl ClientState {
    pub fn fresh(lora: AdapterSet) -> Self {
        let flat: Vec<&HostTensor> = lora.tensors.iter().collect();
        let adam = AdamState::zeros_like(&flat);
        Self { lora, adam, step: 0 }
    }
}

/// Output of one server-side training step (paper eq. 4 + backward).
#[derive(Debug)]
pub struct ServerStepOut {
    pub loss: f32,
    pub act_grads: HostTensor,
    pub state: ServerState,
}

/// The PJRT execution engine for one artifact config.
///
/// The telemetry counters are atomics so the engine carries no
/// structural single-thread assumption — the only interior mutability
/// left is the lazily-populated executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dims: ModelDims,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Frozen parameter literals in packing order (built once).
    frozen: Vec<xla::Literal>,
    params: ParamStore,
    /// Executions performed (telemetry).
    exec_count: AtomicU64,
    /// Cumulative host->device bytes staged per call (telemetry / perf).
    bytes_uploaded: AtomicU64,
}

fn host_to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let ty = match t.data {
        TensorData::F32(_) => xla::ElementType::F32,
        TensorData::I32(_) => xla::ElementType::S32,
    };
    // payload_bytes is a zero-copy view — avoids a per-upload Vec
    // allocation on the hot path (EXPERIMENTS.md §Perf, L3 iteration 1).
    xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, t.payload_bytes())
        .map_err(|e| anyhow::anyhow!("literal for {}: {e}", t.name))
}

/// Scalar f32 literal staged straight from the stack — no `HostTensor`.
fn scalar_literal(v: f32) -> Result<xla::Literal> {
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &[],
        crate::tensor::f32_bytes(std::slice::from_ref(&v)),
    )
    .map_err(|e| anyhow::anyhow!("scalar literal: {e}"))
}

fn literal_to_host(spec: &TensorSpec, lit: &xla::Literal) -> Result<HostTensor> {
    if spec.is_i32() {
        let v = lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{}: {e}", spec.name))?;
        Ok(HostTensor::i32(spec.name.clone(), spec.shape.clone(), v))
    } else {
        let v = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{}: {e}", spec.name))?;
        Ok(HostTensor::f32(spec.name.clone(), spec.shape.clone(), v))
    }
}

/// Read an output literal into a preallocated host tensor (shape and
/// dtype must match the manifest spec) — the zero-`HostTensor` path the
/// in-place step APIs use.
fn literal_to_host_into(spec: &TensorSpec, lit: &xla::Literal, dst: &mut HostTensor) -> Result<()> {
    if dst.numel() != spec.numel() {
        bail!(
            "output {}: dst numel {} != spec numel {} (shape {:?})",
            spec.name,
            dst.numel(),
            spec.numel(),
            spec.shape
        );
    }
    if spec.is_i32() {
        let v = lit.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{}: {e}", spec.name))?;
        match &mut dst.data {
            TensorData::I32(d) if d.len() == v.len() => d.copy_from_slice(&v),
            TensorData::I32(d) => bail!("output {}: literal has {} elems, dst {}", spec.name, v.len(), d.len()),
            TensorData::F32(_) => bail!("output {} is i32 but dst {} is f32", spec.name, dst.name),
        }
    } else {
        let v = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{}: {e}", spec.name))?;
        match &mut dst.data {
            TensorData::F32(d) if d.len() == v.len() => d.copy_from_slice(&v),
            TensorData::F32(d) => bail!("output {}: literal has {} elems, dst {}", spec.name, v.len(), d.len()),
            TensorData::I32(_) => bail!("output {} is f32 but dst {} is i32", spec.name, dst.name),
        }
    }
    Ok(())
}

impl Engine {
    /// Load manifest + params.bin and prepare the frozen literal block.
    pub fn load(artifacts_dir: &Path, config_name: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir, config_name)?;
        let dims = manifest.dims();
        let params = ParamStore::load(&manifest.params_path())?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        let frozen = params
            .names()
            .iter()
            .filter(|n| n.starts_with("frozen."))
            .map(|n| host_to_literal(params.get(n)?))
            .collect::<Result<Vec<_>>>()?;
        if frozen.len() != 20 {
            bail!("expected 20 frozen tensors, found {}", frozen.len());
        }
        Ok(Self {
            client,
            manifest,
            dims,
            exes: RefCell::new(HashMap::new()),
            frozen,
            params,
            exec_count: AtomicU64::new(0),
            bytes_uploaded: AtomicU64::new(0),
        })
    }

    pub fn dims(&self) -> &ModelDims {
        &self.dims
    }

    /// Executions performed so far (telemetry).
    pub fn exec_count(&self) -> u64 {
        self.exec_count.load(Ordering::Relaxed)
    }

    /// Cumulative host->device bytes staged so far (telemetry / perf).
    pub fn bytes_uploaded(&self) -> u64 {
        self.bytes_uploaded.load(Ordering::Relaxed)
    }

    /// Initial full-depth LoRA adapters from the checkpoint.
    pub fn initial_lora(&self) -> Result<AdapterSet> {
        let tensors = ["lora.aq", "lora.bq", "lora.av", "lora.bv"]
            .iter()
            .map(|n| {
                let mut t = self.params.get(n)?.clone();
                t.name = n.trim_start_matches("lora.").to_string();
                Ok(t)
            })
            .collect::<Result<Vec<_>>>()?;
        AdapterSet::from_tensors(self.dims.layers, tensors)
    }

    /// Initial classifier head from the checkpoint.
    pub fn initial_head(&self) -> Result<HeadState> {
        Ok(HeadState {
            w: self.params.get("head.w")?.clone(),
            b: self.params.get("head.b")?.clone(),
        })
    }

    /// Compile (or fetch cached) an executable.
    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
        let rc = Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Pre-compile every artifact needed for the given cuts (plus eval) —
    /// callers pay compilation cost upfront instead of on the first step.
    pub fn warmup(&self, cuts: &[usize]) -> Result<()> {
        for &k in cuts {
            for prefix in ["client_fwd", "server_step", "client_bwd"] {
                self.executable(&format!("{prefix}_{k}"))?;
            }
        }
        self.executable("eval")?;
        Ok(())
    }

    /// Execute `name`; returns output literals in manifest order.
    fn execute(
        &self,
        name: &str,
        spec: &ArtifactSpec,
        args: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        if args.len() != spec.inputs.len() {
            bail!(
                "{name}: arg count {} != manifest inputs {}",
                args.len(),
                spec.inputs.len()
            );
        }
        let exe = self.executable(name)?;
        let outs = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {name}: {e}"))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling {name}: {e}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{name}: output count {} != manifest outputs {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        self.exec_count.fetch_add(1, Ordering::Relaxed);
        Ok(parts)
    }

    /// Stage the token batch directly from the caller's buffer — no
    /// intermediate `HostTensor` (the buffer is reused across steps).
    fn tokens_literal(&self, tokens: &[i32]) -> Result<xla::Literal> {
        let (b, l) = (self.dims.batch, self.dims.seq);
        if tokens.len() != b * l {
            bail!("tokens len {} != {}x{}", tokens.len(), b, l);
        }
        self.bytes_uploaded.fetch_add((tokens.len() * 4) as u64, Ordering::Relaxed);
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            &[b, l],
            crate::tensor::i32_bytes(tokens),
        )
        .map_err(|e| anyhow::anyhow!("tokens literal: {e}"))
    }

    fn labels_literal(&self, labels: &[i32]) -> Result<xla::Literal> {
        if labels.len() != self.dims.batch {
            bail!("labels len {} != batch {}", labels.len(), self.dims.batch);
        }
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            &[self.dims.batch],
            crate::tensor::i32_bytes(labels),
        )
        .map_err(|e| anyhow::anyhow!("labels literal: {e}"))
    }

    fn upload(&self, t: &HostTensor) -> Result<xla::Literal> {
        self.bytes_uploaded.fetch_add(t.byte_len() as u64, Ordering::Relaxed);
        host_to_literal(t)
    }

    /// Client-side forward (paper eq. 3): tokens → activations at cut k,
    /// written into the caller's preallocated buffer (zero `HostTensor`
    /// allocations at steady state).
    pub fn client_fwd_into(
        &self,
        k: usize,
        tokens: &[i32],
        lora: &AdapterSet,
        acts: &mut HostTensor,
    ) -> Result<()> {
        let name = format!("client_fwd_{k}");
        let spec = self.manifest.artifact(&name)?;
        let mut owned = vec![self.tokens_literal(tokens)?];
        for t in &lora.tensors {
            owned.push(self.upload(t)?);
        }
        let mut args: Vec<&xla::Literal> = vec![&owned[0]];
        args.extend(self.frozen.iter());
        args.extend(owned[1..].iter());
        let outs = self.execute(&name, spec, &args)?;
        literal_to_host_into(&spec.outputs[0], &outs[0], acts)
    }

    /// Allocating convenience wrapper over [`Engine::client_fwd_into`].
    pub fn client_fwd(
        &self,
        k: usize,
        tokens: &[i32],
        lora: &AdapterSet,
    ) -> Result<HostTensor> {
        let spec = self.manifest.artifact(&format!("client_fwd_{k}"))?;
        let out = &spec.outputs[0];
        let mut acts = HostTensor::zeros(out.name.clone(), out.shape.clone());
        self.client_fwd_into(k, tokens, lora, &mut acts)?;
        Ok(acts)
    }

    /// Server-side fwd+bwd+Adam (paper eq. 4), fully in place: `state`
    /// (LoRA, head, Adam moments, step counter) is updated in its own
    /// buffers and the activation gradients land in `act_grads`.
    /// Returns the loss.  Bit-identical to [`Engine::server_step`] —
    /// the same artifact executes with the same inputs.
    ///
    /// Error contract: if reading the outputs back fails partway,
    /// `state`/`act_grads` may be left mixed between the old and new
    /// step — treat them as poisoned and discard (the allocating
    /// wrapper steps a clone, so its input state is never affected).
    pub fn server_step_into(
        &self,
        k: usize,
        acts: &HostTensor,
        labels: &[i32],
        state: &mut ServerState,
        act_grads: &mut HostTensor,
        lr: f32,
    ) -> Result<f32> {
        let name = format!("server_step_{k}");
        let spec = self.manifest.artifact(&name)?;
        let step = state.step + 1;

        let mut owned = Vec::with_capacity(22);
        owned.push(self.upload(acts)?);
        owned.push(self.labels_literal(labels)?);
        for t in &state.lora.tensors {
            owned.push(self.upload(t)?);
        }
        owned.push(self.upload(&state.head.w)?);
        owned.push(self.upload(&state.head.b)?);
        for t in state.adam.m.iter().chain(state.adam.v.iter()) {
            owned.push(self.upload(t)?);
        }
        owned.push(scalar_literal(step as f32)?);
        owned.push(scalar_literal(lr)?);

        let mut args: Vec<&xla::Literal> = vec![&owned[0], &owned[1]];
        args.extend(self.frozen.iter());
        args.extend(owned[2..].iter());
        let outs = self.execute(&name, spec, &args)?;

        let loss = outs[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("loss: {e}"))?[0];
        literal_to_host_into(&spec.outputs[1], &outs[1], act_grads)?;
        let mut cursor = 2usize;
        for t in state.lora.tensors.iter_mut() {
            literal_to_host_into(&spec.outputs[cursor], &outs[cursor], t)?;
            cursor += 1;
        }
        literal_to_host_into(&spec.outputs[cursor], &outs[cursor], &mut state.head.w)?;
        literal_to_host_into(&spec.outputs[cursor + 1], &outs[cursor + 1], &mut state.head.b)?;
        cursor += 2;
        for t in state.adam.m.iter_mut().chain(state.adam.v.iter_mut()) {
            literal_to_host_into(&spec.outputs[cursor], &outs[cursor], t)?;
            cursor += 1;
        }
        state.step = step;
        Ok(loss)
    }

    /// Allocating wrapper over [`Engine::server_step_into`]: clones the
    /// state, steps the clone, and returns it (tests + SL baseline).
    pub fn server_step(
        &self,
        k: usize,
        acts: &HostTensor,
        labels: &[i32],
        state: &ServerState,
        lr: f32,
    ) -> Result<ServerStepOut> {
        let spec = self.manifest.artifact(&format!("server_step_{k}"))?;
        let gspec = &spec.outputs[1];
        let mut act_grads = HostTensor::zeros(gspec.name.clone(), gspec.shape.clone());
        let mut new_state = state.clone();
        let loss = self.server_step_into(k, acts, labels, &mut new_state, &mut act_grads, lr)?;
        Ok(ServerStepOut { loss, act_grads, state: new_state })
    }

    /// Client-side backward (rematerialized fwd + LoRA Adam update),
    /// fully in place: the client's LoRA, Adam moments, and step counter
    /// are updated in their own buffers.  Same error contract as
    /// [`Engine::server_step_into`]: on error the state may be mixed
    /// between steps — discard it.
    pub fn client_bwd_into(
        &self,
        k: usize,
        tokens: &[i32],
        state: &mut ClientState,
        act_grads: &HostTensor,
        lr: f32,
    ) -> Result<()> {
        let name = format!("client_bwd_{k}");
        let spec = self.manifest.artifact(&name)?;
        let step = state.step + 1;

        let mut owned = vec![self.tokens_literal(tokens)?];
        for t in &state.lora.tensors {
            owned.push(self.upload(t)?);
        }
        owned.push(self.upload(act_grads)?);
        for t in state.adam.m.iter().chain(state.adam.v.iter()) {
            owned.push(self.upload(t)?);
        }
        owned.push(scalar_literal(step as f32)?);
        owned.push(scalar_literal(lr)?);

        let mut args: Vec<&xla::Literal> = vec![&owned[0]];
        args.extend(self.frozen.iter());
        args.extend(owned[1..].iter());
        let outs = self.execute(&name, spec, &args)?;

        let mut cursor = 0usize;
        for t in state.lora.tensors.iter_mut() {
            literal_to_host_into(&spec.outputs[cursor], &outs[cursor], t)?;
            cursor += 1;
        }
        for t in state.adam.m.iter_mut().chain(state.adam.v.iter_mut()) {
            literal_to_host_into(&spec.outputs[cursor], &outs[cursor], t)?;
            cursor += 1;
        }
        state.step = step;
        Ok(())
    }

    /// Allocating wrapper over [`Engine::client_bwd_into`].
    pub fn client_bwd(
        &self,
        k: usize,
        tokens: &[i32],
        state: &ClientState,
        act_grads: &HostTensor,
        lr: f32,
    ) -> Result<ClientState> {
        let mut new_state = state.clone();
        self.client_bwd_into(k, tokens, &mut new_state, act_grads, lr)?;
        Ok(new_state)
    }

    /// Full-model evaluation on one batch: returns (logits [B*C], loss).
    pub fn eval(
        &self,
        tokens: &[i32],
        labels: &[i32],
        lora: &AdapterSet,
        head: &HeadState,
    ) -> Result<(Vec<f32>, f32)> {
        let spec = self.manifest.artifact("eval")?;
        let mut owned = vec![self.tokens_literal(tokens)?, self.labels_literal(labels)?];
        for t in &lora.tensors {
            owned.push(self.upload(t)?);
        }
        owned.push(self.upload(&head.w)?);
        owned.push(self.upload(&head.b)?);
        let mut args: Vec<&xla::Literal> = vec![&owned[0], &owned[1]];
        args.extend(self.frozen.iter());
        args.extend(owned[2..].iter());
        let outs = self.execute("eval", spec, &args)?;
        let logits = outs[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("logits: {e}"))?;
        let loss = outs[1].to_vec::<f32>().map_err(|e| anyhow::anyhow!("loss: {e}"))?[0];
        Ok((logits, loss))
    }

    /// Monolithic centralized training step (tests + SL reference).
    pub fn full_step(
        &self,
        tokens: &[i32],
        labels: &[i32],
        state: &ServerState,
        lr: f32,
    ) -> Result<(f32, ServerState)> {
        let spec = self.manifest.artifact("full_step")?;
        let step = state.step + 1;
        let mut owned = vec![self.tokens_literal(tokens)?, self.labels_literal(labels)?];
        for t in &state.lora.tensors {
            owned.push(self.upload(t)?);
        }
        owned.push(self.upload(&state.head.w)?);
        owned.push(self.upload(&state.head.b)?);
        for t in state.adam.m.iter().chain(state.adam.v.iter()) {
            owned.push(self.upload(t)?);
        }
        owned.push(scalar_literal(step as f32)?);
        owned.push(scalar_literal(lr)?);
        let mut args: Vec<&xla::Literal> = vec![&owned[0], &owned[1]];
        args.extend(self.frozen.iter());
        args.extend(owned[2..].iter());
        let outs = self.execute("full_step", spec, &args)?;

        let loss = outs[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("loss: {e}"))?[0];
        let mut cursor = 1usize;
        let mut grab = |n: usize| -> Result<Vec<HostTensor>> {
            let out = (cursor..cursor + n)
                .map(|i| literal_to_host(&spec.outputs[i], &outs[i]))
                .collect::<Result<Vec<_>>>()?;
            cursor += n;
            Ok(out)
        };
        let mut lora_t = grab(4)?;
        for (t, old) in lora_t.iter_mut().zip(state.lora.tensors.iter()) {
            t.name = old.name.clone();
        }
        let head_t = grab(2)?;
        let m = grab(6)?;
        let v = grab(6)?;
        let new_state = ServerState {
            lora: AdapterSet::from_tensors(state.lora.layers, lora_t)?,
            head: HeadState { w: head_t[0].clone(), b: head_t[1].clone() },
            adam: AdamState { m, v },
            step,
        };
        Ok((loss, new_state))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_zeros_like_gives_moments_distinct_names() {
        let a = HostTensor::zeros("aq", vec![2, 3]);
        let b = HostTensor::zeros("head.w", vec![4]);
        let adam = AdamState::zeros_like(&[&a, &b]);
        assert_eq!(adam.m.len(), 2);
        assert_eq!(adam.v.len(), 2);
        assert_eq!(adam.m[0].name, "adam.m.aq");
        assert_eq!(adam.v[0].name, "adam.v.aq");
        assert_eq!(adam.m[1].name, "adam.m.head.w");
        assert_eq!(adam.v[1].name, "adam.v.head.w");
        for t in adam.m.iter().chain(adam.v.iter()) {
            assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
        }
        assert_eq!(adam.m[0].shape, vec![2, 3]);
        assert_eq!(adam.v[1].shape, vec![4]);
    }
}
