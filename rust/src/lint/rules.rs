//! The five sflint rules (R1–R5).  Each rule scans a [`SourceFile`]'s
//! masked code lines — string/char contents blanked, comments removed,
//! `#[cfg(test)]` regions marked — so matches are structural, not
//! textual accidents inside literals or docs.
//!
//! All matching is hand-rolled on word boundaries (std-only, no regex):
//! an identifier occurrence counts only when it is not embedded in a
//! longer identifier.  The rules deliberately over-approximate (e.g. R2
//! treats *any mention* of a field inside a serializer body as
//! coverage); false negatives are cheap here because the runtime
//! bit-exactness tests backstop them, while false positives would drown
//! the gate in pragmas.

use super::{contains_word, word_positions, Finding, SourceFile};

/// Run every rule over one parsed file.
pub fn all(f: &SourceFile, out: &mut Vec<Finding>) {
    r1_determinism(f, out);
    r4_panic_discipline(f, out);
    r5_float_order(f, out);
    let structs = parse_structs(f);
    r2_checkpoint_coverage(f, &structs, out);
    r3_config_symmetry(f, &structs, out);
}

fn emit(
    f: &SourceFile,
    line: usize,
    rule: &'static str,
    name: &'static str,
    msg: String,
    out: &mut Vec<Finding>,
) {
    if f.allowed(line, rule, name) {
        return;
    }
    out.push(Finding { rule, name, path: f.rel.clone(), line: line + 1, msg });
}

// ---------------------------------------------------------------------------
// Token helpers.
// ---------------------------------------------------------------------------

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Longest identifier prefix of `s`.
fn ident_prefix(s: &str) -> &str {
    let end = s.find(|c: char| !is_ident_char(c)).unwrap_or(s.len());
    &s[..end]
}

/// Longest identifier suffix of `s`.
fn ident_suffix(s: &str) -> &str {
    let start = s.rfind(|c: char| !is_ident_char(c)).map_or(0, |p| p + c_len(s, p));
    &s[start..]
}

fn c_len(s: &str, at: usize) -> usize {
    s[at..].chars().next().map_or(1, char::len_utf8)
}

/// True when the line declares `fn <name>` for one of `names`; when
/// `require_paren`, a `(` must follow the name (after whitespace), so
/// `fn state_words(` never matches `state`.
fn fn_decl_any(code: &str, names: &[&str], require_paren: bool) -> bool {
    for at in word_positions(code, "fn") {
        let rest = code[at + 2..].trim_start();
        let id = ident_prefix(rest);
        if !id.is_empty()
            && names.contains(&id)
            && (!require_paren || rest[id.len()..].trim_start().starts_with('('))
        {
            return true;
        }
    }
    false
}

/// True when `code` declares `fn <name>` (paren not required).
fn fn_decl(code: &str, name: &str) -> bool {
    fn_decl_any(code, &[name], false)
}

/// `.name ( ` method call on the line (whitespace tolerated before the
/// parenthesis, `name` a full identifier so `.expect_err` never matches
/// `expect`).
fn method_call(code: &str, name: &str) -> bool {
    for at in word_positions(code, name) {
        if at == 0 || !code[..at].ends_with('.') {
            continue;
        }
        if code[at + name.len()..].trim_start().starts_with('(') {
            return true;
        }
    }
    false
}

/// `name! ( ` macro invocation on the line.
fn macro_call(code: &str, name: &str) -> bool {
    for at in word_positions(code, name) {
        let rest = &code[at + name.len()..];
        if let Some(r) = rest.strip_prefix('!') {
            if r.trim_start().starts_with('(') {
                return true;
            }
        }
    }
    false
}

// ---------------------------------------------------------------------------
// R1 — determinism.
// ---------------------------------------------------------------------------

/// Modules allowed to touch wall clocks / entropy by design.
const R1_EXEMPT_PREFIX: &str = "simclock/";
const R1_EXEMPT_FILE: &str = "tensor/rng.rs";

const HASH_ITER_METHODS: [&str; 7] =
    ["iter", "iter_mut", "keys", "values", "values_mut", "into_iter", "drain"];

fn r1_determinism(f: &SourceFile, out: &mut Vec<Finding>) {
    if f.rel.starts_with(R1_EXEMPT_PREFIX) || f.rel == R1_EXEMPT_FILE {
        return;
    }
    let idents = hash_idents(f);
    for (i, c) in f.code.iter().enumerate() {
        if f.test[i] {
            continue;
        }
        if contains_word(c, "SystemTime") {
            let msg = "std::time::SystemTime is wall-clock: use the sim clock".to_string();
            emit(f, i, "R1", "determinism", msg, out);
        } else if contains_word(c, "Instant") {
            let msg = "std::time::Instant is wall-clock: use the sim clock".to_string();
            emit(f, i, "R1", "determinism", msg, out);
        }
        if contains_word(c, "thread_rng") || contains_word(c, "from_entropy") || rand_path(c) {
            let msg = "external RNG: use the checkpointable tensor::rng::Rng".to_string();
            emit(f, i, "R1", "determinism", msg, out);
        }
        for id in &idents {
            if hash_iter_call(c, id) || for_over_hash(c, id) {
                let msg = format!(
                    "iteration over hash collection `{id}` is order-nondeterministic: \
                     sort keys or use an ordered container"
                );
                emit(f, i, "R1", "determinism", msg, out);
            }
        }
    }
}

/// `rand::` path use (the word `rand` immediately followed by `::`).
fn rand_path(code: &str) -> bool {
    word_positions(code, "rand").iter().any(|&at| code[at + 4..].starts_with("::"))
}

/// Identifiers declared as `HashMap`/`HashSet` anywhere in the file
/// (struct fields, lets) — the candidates whose iteration R1 flags.
fn hash_idents(f: &SourceFile) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for c in &f.code {
        for ty in ["HashMap", "HashSet"] {
            for at in word_positions(c, ty) {
                // `ident: [RefCell<] [std::collections::] HashMap`.
                if let Some(id) = typed_decl_ident(&c[..at]) {
                    if !out.contains(&id) {
                        out.push(id);
                    }
                }
                // `let [mut] ident [: T] = [std::collections::] HashMap::`.
                if c[at + ty.len()..].starts_with("::") {
                    if let Some(id) = let_binding_ident(c, at) {
                        if !out.contains(&id) {
                            out.push(id);
                        }
                    }
                }
            }
        }
    }
    out
}

fn typed_decl_ident(prefix: &str) -> Option<String> {
    let mut s = prefix.trim_end();
    s = s.strip_suffix("std::collections::").unwrap_or(s).trim_end();
    s = s.strip_suffix("RefCell<").unwrap_or(s).trim_end();
    if s.ends_with("::") {
        return None;
    }
    let s = s.strip_suffix(':')?.trim_end();
    let id = ident_suffix(s);
    if id.is_empty() || id.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        return None;
    }
    Some(id.to_string())
}

fn let_binding_ident(code: &str, ty_at: usize) -> Option<String> {
    let pre = code[..ty_at].trim_end();
    let pre = pre.strip_suffix("std::collections::").unwrap_or(pre).trim_end();
    if !pre.ends_with('=') {
        return None;
    }
    let lp = *word_positions(code, "let").first()?;
    if lp >= ty_at {
        return None;
    }
    let mut rest = code[lp + 3..].trim_start();
    if let Some(r) = rest.strip_prefix("mut ") {
        rest = r.trim_start();
    }
    let id = ident_prefix(rest);
    if id.is_empty() {
        return None;
    }
    Some(id.to_string())
}

/// `ident.iter()` / `.keys()` / `.values()` / `.drain()` etc.
fn hash_iter_call(code: &str, ident: &str) -> bool {
    for at in word_positions(code, ident) {
        let rest = code[at + ident.len()..].trim_start();
        let Some(rest) = rest.strip_prefix('.') else { continue };
        let rest = rest.trim_start();
        let m = ident_prefix(rest);
        if HASH_ITER_METHODS.contains(&m) && rest[m.len()..].trim_start().starts_with('(') {
            return true;
        }
    }
    false
}

/// `for x in &ident {` / `for x in &mut ident {` — direct borrow
/// iteration, which desugars to the same nondeterministic order.
fn for_over_hash(code: &str, ident: &str) -> bool {
    for at in word_positions(code, "in") {
        let mut rest = code[at + 2..].trim_start();
        let Some(r) = rest.strip_prefix('&') else { continue };
        rest = r.trim_start();
        if let Some(r) = rest.strip_prefix("mut ") {
            rest = r.trim_start();
        }
        if ident_prefix(rest) != ident {
            continue;
        }
        let tail = rest[ident.len()..].trim_start();
        if tail.is_empty() || tail.starts_with('{') {
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// R4 — panic discipline.
// ---------------------------------------------------------------------------

fn r4_panic_discipline(f: &SourceFile, out: &mut Vec<Finding>) {
    for (i, c) in f.code.iter().enumerate() {
        if f.test[i] {
            continue;
        }
        if c.contains(".unwrap()") {
            let msg = "unwrap() in non-test code: propagate with ? or handle".to_string();
            emit(f, i, "R4", "panic-discipline", msg, out);
        }
        if method_call(c, "expect") {
            let msg = "expect() in non-test code: propagate with ? or handle".to_string();
            emit(f, i, "R4", "panic-discipline", msg, out);
        }
        if macro_call(c, "panic") {
            let msg = "panic! in non-test code: return an error instead".to_string();
            emit(f, i, "R4", "panic-discipline", msg, out);
        }
        if macro_call(c, "todo") || macro_call(c, "unimplemented") {
            let msg = "todo!/unimplemented! in non-test code".to_string();
            emit(f, i, "R4", "panic-discipline", msg, out);
        }
    }
}

// ---------------------------------------------------------------------------
// R5 — float-order determinism.
// ---------------------------------------------------------------------------

fn r5_float_order(f: &SourceFile, out: &mut Vec<Finding>) {
    for (i, c) in f.code.iter().enumerate() {
        if f.test[i] || fn_decl(c, "partial_cmp") {
            continue;
        }
        if c.contains(".partial_cmp(") {
            let msg = "partial_cmp on floats: use total_cmp for deterministic order".to_string();
            emit(f, i, "R5", "float-order", msg, out);
        }
    }
}

// ---------------------------------------------------------------------------
// Struct parsing shared by R2/R3.
// ---------------------------------------------------------------------------

pub(crate) struct FieldDef {
    pub name: String,
    /// 0-based declaration line.
    pub line: usize,
    pub ty: String,
}

pub(crate) struct StructDef {
    pub name: String,
    pub fields: Vec<FieldDef>,
}

const NOT_FIELD_KEYWORDS: [&str; 11] =
    ["impl", "fn", "pub", "let", "match", "if", "for", "while", "return", "type", "where"];

/// Braced struct definitions in the file (test regions included — an
/// impl binds to the nearest definition by name, last one wins).
pub(crate) fn parse_structs(f: &SourceFile) -> Vec<StructDef> {
    let mut out = Vec::new();
    for i in 0..f.code.len() {
        let c = &f.code[i];
        if !c.contains('{') {
            continue;
        }
        let Some(at) = word_positions(c, "struct").first().copied() else { continue };
        let name = ident_prefix(c[at + 6..].trim_start());
        if name.is_empty() {
            continue;
        }
        if i + 1 >= f.code.len() {
            continue;
        }
        let end = f.block_end(i).min(f.code.len() - 1);
        let inner = f.depth[i + 1];
        let mut fields = Vec::new();
        for j in (i + 1)..=end {
            if f.depth[j] == inner {
                if let Some((fname, ty)) = field_decl(&f.code[j]) {
                    fields.push(FieldDef { name: fname, line: j, ty });
                }
            }
        }
        out.push(StructDef { name: name.to_string(), fields });
    }
    out
}

/// `pub(…) name: Type,` → (name, Type).  Lowercase/underscore-leading
/// identifiers only; `::`-paths and keyword starts rejected.
fn field_decl(code: &str) -> Option<(String, String)> {
    let mut s = code.trim_start();
    if let Some(r) = s.strip_prefix("pub") {
        if let Some(r2) = r.strip_prefix('(') {
            let close = r2.find(')')?;
            s = r2[close + 1..].trim_start();
        } else if r.starts_with(char::is_whitespace) {
            s = r.trim_start();
        }
        // else: an identifier that merely starts with "pub" — fall through.
    }
    if let Some(r) = s.strip_prefix("r#") {
        s = r;
    }
    let name = ident_prefix(s);
    let lead = name.chars().next()?;
    if !(lead.is_ascii_lowercase() || lead == '_') || NOT_FIELD_KEYWORDS.contains(&name) {
        return None;
    }
    let rest = s[name.len()..].trim_start();
    if !rest.starts_with(':') || rest.starts_with("::") {
        return None;
    }
    let ty = rest[1..].trim().trim_end_matches(',').trim_end().to_string();
    Some((name.to_string(), ty))
}

/// Remove `<…>` spans (nesting-aware) so `impl<T> Foo<T> for Bar<T>`
/// reads `impl Foo for Bar`.
fn strip_generics(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut depth = 0u32;
    for ch in s.chars() {
        match ch {
            '<' => depth += 1,
            '>' if depth > 0 => depth -= 1,
            c if depth == 0 => out.push(c),
            _ => {}
        }
    }
    out
}

/// The type an `impl` header targets: the identifier after `for` if
/// present, else the one after `impl`.
fn impl_target(header: &str) -> Option<String> {
    let s = strip_generics(header);
    for kw in ["for", "impl"] {
        for at in word_positions(&s, kw) {
            let id = ident_prefix(s[at + kw.len()..].trim_start());
            if !id.is_empty() && !id.chars().next().is_some_and(|c| c.is_ascii_digit()) {
                return Some(id.to_string());
            }
        }
    }
    None
}

// ---------------------------------------------------------------------------
// R2 — checkpoint coverage.
// ---------------------------------------------------------------------------

const SER_FNS: [&str; 4] = ["save_state", "load_state", "state", "restore_state"];

fn r2_checkpoint_coverage(f: &SourceFile, structs: &[StructDef], out: &mut Vec<Finding>) {
    let n = f.code.len();
    let mut i = 0usize;
    while i < n {
        let t = f.code[i].trim_start();
        let is_impl = t.starts_with("impl")
            && !t[4..].chars().next().is_some_and(is_ident_char)
            && !f.test[i];
        if !is_impl {
            i += 1;
            continue;
        }
        let mut j = i;
        while j < n && !f.code[j].contains('{') {
            j += 1;
        }
        if j >= n {
            break;
        }
        let end = f.block_end(j).min(n - 1);
        let header = f.code[i..=j].join(" ");
        // Concatenate the bodies of all serializer fns in this impl.
        let mut body = String::new();
        let mut k = j;
        while k <= end {
            if !fn_decl_any(&f.code[k], &SER_FNS, true) {
                k += 1;
                continue;
            }
            let mut fj = k;
            while fj <= end && !f.code[fj].contains('{') {
                fj += 1;
            }
            if fj > end {
                break;
            }
            let fend = f.block_end(fj).min(end);
            for line in &f.code[k..=fend] {
                body.push_str(line);
                body.push('\n');
            }
            k = fend + 1;
        }
        if !body.is_empty() {
            if let Some(name) = impl_target(&header) {
                if let Some(sd) = structs.iter().rev().find(|s| s.name == name) {
                    for field in &sd.fields {
                        if !contains_word(&body, &field.name) {
                            let msg = format!(
                                "field `{}` of `{name}` not referenced by {name}'s state serializers",
                                field.name
                            );
                            emit(f, field.line, "R2", "checkpoint-coverage", msg, out);
                        }
                    }
                }
            }
        }
        i = end + 1;
    }
}

// ---------------------------------------------------------------------------
// R3 — config/kv symmetry.
// ---------------------------------------------------------------------------

/// Full text of the first `fn <name>` in the file (decl through closing
/// brace), or empty when absent.
fn fn_body_text(f: &SourceFile, name: &str) -> String {
    for i in 0..f.code.len() {
        if !fn_decl_any(&f.code[i], &[name], false) {
            continue;
        }
        let mut j = i;
        while j < f.code.len() && !f.code[j].contains('{') {
            j += 1;
        }
        if j >= f.code.len() {
            return String::new();
        }
        let end = f.block_end(j).min(f.code.len() - 1);
        let mut out = String::new();
        for line in &f.code[i..=end] {
            out.push_str(line);
            out.push('\n');
        }
        return out;
    }
    String::new()
}

/// Leaf scalar/string field types R3 tracks directly on
/// `ExperimentConfig` (sub-struct fields are always tracked).
const R3_DIRECT_TYPES: [&str; 3] = ["String", "SchemeKind", "SchedulerKind"];

fn r3_config_symmetry(f: &SourceFile, structs: &[StructDef], out: &mut Vec<Finding>) {
    let Some(exp) = structs.iter().rev().find(|s| s.name == "ExperimentConfig") else {
        return;
    };
    let to_kv = fn_body_text(f, "to_kv");
    let parser = fn_body_text(f, "from_kv_file");
    let validate = fn_body_text(f, "validate");
    // (label, token, 0-based line, declared type)
    let mut targets: Vec<(String, String, usize, String)> = Vec::new();
    for field in &exp.fields {
        let base = field.ty.replace("Option<", "").replace("Vec<", "").replace('>', "");
        let base = base.trim();
        if let Some(sub) = structs.iter().rev().find(|s| s.name == base) {
            for sf in &sub.fields {
                let label = format!("{}.{}", field.name, sf.name);
                targets.push((label, sf.name.clone(), sf.line, sf.ty.clone()));
            }
        } else if R3_DIRECT_TYPES.contains(&base) {
            targets.push((field.name.clone(), field.name.clone(), field.line, field.ty.clone()));
        }
    }
    for (label, tok, line, ty) in &targets {
        if !contains_word(&to_kv, tok) {
            let msg = format!("config field `{label}` missing from to_kv");
            emit(f, *line, "R3", "config-symmetry", msg, out);
        }
        if !contains_word(&parser, tok) {
            let msg = format!("config field `{label}` missing from the kv parser");
            emit(f, *line, "R3", "config-symmetry", msg, out);
        }
        if (ty == "f32" || ty == "f64") && !contains_word(&validate, tok) {
            let msg = format!("float config field `{label}` missing from validate()");
            emit(f, *line, "R3", "config-symmetry", msg, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_decl_variants() {
        assert_eq!(field_decl("    pub lr: f32,").map(|x| x.0), Some("lr".into()));
        assert_eq!(field_decl("pub(crate) cap: usize,").map(|x| x.1), Some("usize".into()));
        assert_eq!(field_decl("r#type: String,").map(|x| x.0), Some("type".into()));
        assert!(field_decl("impl Foo {").is_none());
        assert!(field_decl("Some(x) => y,").is_none());
        assert!(field_decl("std::mem::swap(a, b);").is_none());
    }

    #[test]
    fn impl_target_variants() {
        assert_eq!(impl_target("impl StatePool {").as_deref(), Some("StatePool"));
        assert_eq!(impl_target("impl Scheme for SlScheme {").as_deref(), Some("SlScheme"));
        assert_eq!(impl_target("impl<T: Clone> Ring<T> {").as_deref(), Some("Ring"));
    }

    #[test]
    fn method_and_macro_calls() {
        assert!(method_call("x.expect (\"msg\")", "expect"));
        assert!(!method_call("x.expect_err(\"msg\")", "expect"));
        assert!(macro_call("panic!(\"boom\")", "panic"));
        assert!(!macro_call("self.panic_count += 1;", "panic"));
    }

    #[test]
    fn hash_ident_detection() {
        let f = SourceFile::parse(
            "x.rs",
            "struct S {\n    by_name: std::collections::HashMap<String, u32>,\n}\nfn g() {\n    let mut seen = HashSet::new();\n}",
        );
        let ids = hash_idents(&f);
        assert!(ids.contains(&"by_name".to_string()));
        assert!(ids.contains(&"seen".to_string()));
    }
}
