//! `sflint` — the in-tree static invariant analyzer (`cargo run --bin
//! sflint`; wired into `make lint` and CI).
//!
//! Every headline claim of this reproduction — bit-exact pooled/robust/
//! async twins, mid-flight resume, deterministic trajectories — rests
//! on source-level invariants that no runtime test can enforce
//! exhaustively: checkpointable RNG only, sim-clock only, every mutable
//! field serialized, every config knob symmetric across `to_kv` / the
//! kv parser / `validate()`.  This module is a lightweight line scanner
//! (strings and comments masked, brace depth tracked, `#[cfg(test)]`
//! regions excluded) that enforces them as named rules:
//!
//! | rule | name                | invariant |
//! |------|---------------------|-----------|
//! | R1   | determinism         | no wall clock, no external RNG, no hash-order iteration |
//! | R2   | checkpoint-coverage | struct fields reachable from `save_state`/`load_state`/`state`/`restore_state` are referenced by those serializers |
//! | R3   | config-symmetry     | `ExperimentConfig` sub-struct fields appear in `to_kv`, the kv parser, and (floats) `validate()` |
//! | R4   | panic-discipline    | no `unwrap`/`expect`/`panic!`/`todo!` outside tests |
//! | R5   | float-order         | float comparators use `total_cmp`, never `partial_cmp` |
//!
//! Findings can be suppressed case-by-case with a pragma comment on the
//! offending line or on a comment line directly above it:
//!
//! ```text
//! // sflint:allow(checkpoint-coverage, rebuilt from the spec on resume)
//! ```
//!
//! or grandfathered wholesale via `rust/lint/baseline.jsonl` (matched
//! on rule + path + message, so line drift never un-baselines an
//! entry).  See `rust/lint/README.md` for the full workflow.

pub mod rules;

use anyhow::{bail, Context, Result};
use std::path::Path;

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Short rule id ("R1".."R5").
    pub rule: &'static str,
    /// Human rule name ("determinism", ...), also accepted in pragmas.
    pub name: &'static str,
    /// Path relative to the scanned root, `/`-separated.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub msg: String,
}

impl Finding {
    /// Baseline identity: line numbers drift, so entries match on
    /// (rule, path, message) only.
    pub fn key(&self) -> (String, String, String) {
        (self.rule.to_string(), self.path.clone(), self.msg.clone())
    }

    /// One JSONL record (the machine-readable output and the baseline
    /// format are the same shape).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"rule\":\"{}\",\"name\":\"{}\",\"path\":\"{}\",\"line\":{},\"msg\":\"{}\"}}",
            json_escape(self.rule),
            json_escape(self.name),
            json_escape(&self.path),
            self.line,
            json_escape(&self.msg)
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Extract a string field from one sflint-written JSONL record.  This
/// is deliberately not a general JSON parser: it reads exactly the
/// shape [`Finding::to_json`] emits (and unescapes what
/// [`json_escape`] escapes), which is all the baseline file may
/// contain.
fn json_str_field(line: &str, key: &str) -> Option<String> {
    let tag = format!("\"{key}\":\"");
    let start = line.find(&tag)? + tag.len();
    let rest = &line[start..];
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => return Some(out),
            '\\' => match chars.next()? {
                'n' => out.push('\n'),
                't' => out.push('\t'),
                other => out.push(other),
            },
            c => out.push(c),
        }
    }
    None
}

// ---------------------------------------------------------------------------
// Source model: masked lines, brace depth, test regions, pragmas.
// ---------------------------------------------------------------------------

/// Lexer state carried across lines.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Mode {
    Normal,
    /// Inside `/* ... */`; payload = nesting depth (Rust block comments nest).
    Block(u32),
    /// Inside a raw string `r##"..."##`; payload = number of `#`s.
    Raw(u32),
}

/// One parsed source file: per line, the code with strings and comments
/// masked out (structure preserved), the comment text (where pragmas
/// live), the brace depth at line start, and whether the line sits in a
/// `#[cfg(test)]` region.
pub struct SourceFile {
    pub rel: String,
    pub code: Vec<String>,
    pub comment: Vec<String>,
    pub depth: Vec<i64>,
    pub test: Vec<bool>,
}

/// Mask one line: string/char literal contents become spaces (the
/// delimiters stay, so token boundaries hold), comment text moves to
/// the side channel.  Returns the mode to carry into the next line.
fn mask_line(line: &str, mode: Mode, code: &mut String, comment: &mut String) -> Mode {
    let b: Vec<char> = line.chars().collect();
    let n = b.len();
    let mut i = 0usize;
    let mut mode = mode;
    let mut in_str = false;
    while i < n {
        match mode {
            Mode::Block(depth) => {
                if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    mode = if depth > 1 { Mode::Block(depth - 1) } else { Mode::Normal };
                    i += 2;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    mode = Mode::Block(depth + 1);
                    i += 2;
                } else {
                    comment.push(b[i]);
                    i += 1;
                }
                continue;
            }
            Mode::Raw(hashes) => {
                let h = hashes as usize;
                if b[i] == '"' && i + h < n && b[i + 1..i + 1 + h].iter().all(|&c| c == '#') {
                    code.push('"');
                    for _ in 0..h {
                        code.push(' ');
                    }
                    i += 1 + h;
                    mode = Mode::Normal;
                    continue;
                }
                code.push(' ');
                i += 1;
                continue;
            }
            Mode::Normal => {}
        }
        let c = b[i];
        if in_str {
            if c == '\\' {
                code.push_str("  ");
                i += 2;
            } else if c == '"' {
                in_str = false;
                code.push('"');
                i += 1;
            } else {
                code.push(' ');
                i += 1;
            }
            continue;
        }
        // Raw string openers: r"..."  r#"..."#  (b/br prefixes too).
        if (c == 'r' || c == 'b') && !prev_is_ident(&b, i) {
            let mut j = i;
            if b[j] == 'b' && j + 1 < n && b[j + 1] == 'r' {
                j += 1;
            }
            if b[j] == 'r' {
                let mut k = j + 1;
                let mut hashes = 0u32;
                while k < n && b[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && b[k] == '"' {
                    for _ in i..=k {
                        code.push(' ');
                    }
                    code.push('"');
                    i = k + 1;
                    mode = Mode::Raw(hashes);
                    continue;
                }
            }
        }
        match c {
            '"' => {
                in_str = true;
                code.push('"');
                i += 1;
            }
            '\'' => {
                // Char literal ('x', '\n') vs lifetime ('a in generics).
                if i + 2 < n && b[i + 1] == '\\' {
                    // '\x' style escape: find the closing quote.
                    let mut k = i + 2;
                    while k < n && b[k] != '\'' {
                        k += 1;
                    }
                    code.push('\'');
                    for _ in i + 1..k.min(n) {
                        code.push(' ');
                    }
                    if k < n {
                        code.push('\'');
                    }
                    i = k + 1;
                } else if i + 2 < n && b[i + 2] == '\'' {
                    code.push_str("'  ");
                    i += 3;
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            '/' if i + 1 < n && b[i + 1] == '/' => {
                comment.extend(&b[i + 2..]);
                return mode;
            }
            '/' if i + 1 < n && b[i + 1] == '*' => {
                mode = Mode::Block(1);
                i += 2;
            }
            c => {
                code.push(c);
                i += 1;
            }
        }
    }
    mode
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_')
}

impl SourceFile {
    pub fn parse(rel: &str, text: &str) -> SourceFile {
        let mut code = Vec::new();
        let mut comment = Vec::new();
        let mut mode = Mode::Normal;
        for line in text.lines() {
            let mut c = String::with_capacity(line.len());
            let mut com = String::new();
            mode = mask_line(line, mode, &mut c, &mut com);
            code.push(c);
            comment.push(com);
        }
        let mut depth = Vec::with_capacity(code.len());
        let mut d = 0i64;
        for c in &code {
            depth.push(d);
            d += braces(c);
        }
        let mut f = SourceFile { rel: rel.to_string(), code, comment, depth, test: Vec::new() };
        f.test = f.test_regions();
        f
    }

    /// Brace depth after the given line.
    pub fn depth_after(&self, line: usize) -> i64 {
        self.depth[line] + braces(&self.code[line])
    }

    /// Last line of the block whose opening brace sits on (or after)
    /// `start` — the first line where depth returns to `depth[start]`.
    pub fn block_end(&self, start: usize) -> usize {
        let d0 = self.depth[start];
        let mut opened = false;
        for k in start..self.code.len() {
            if self.code[k].contains('{') {
                opened = true;
            }
            if opened && self.depth_after(k) <= d0 {
                return k;
            }
        }
        self.code.len().saturating_sub(1)
    }

    fn test_regions(&self) -> Vec<bool> {
        let mut test = vec![false; self.code.len()];
        let mut i = 0usize;
        while i < self.code.len() {
            if !self.code[i].contains("#[cfg(test)]") {
                i += 1;
                continue;
            }
            // The attribute applies to the next item; its body is the
            // next brace-delimited block.
            let mut open = i;
            while open < self.code.len() && !self.code[open].contains('{') {
                open += 1;
            }
            if open >= self.code.len() {
                break;
            }
            let end = self.block_end(open);
            for t in test.iter_mut().take(end + 1).skip(i) {
                *t = true;
            }
            i = end + 1;
        }
        test
    }

    /// True when a `sflint:allow(rule, reason)` pragma covers `line`
    /// (0-based): trailing on the line itself, or on the run of
    /// comment-only lines directly above it (so a pragma can sit
    /// anywhere in a field's doc block).
    pub fn allowed(&self, line: usize, rule: &str, name: &str) -> bool {
        if pragma_allows(&self.comment[line], rule, name) {
            return true;
        }
        let mut j = line;
        while j > 0 {
            j -= 1;
            let comment_only = self.code[j].trim().is_empty() && !self.comment[j].is_empty();
            if !comment_only {
                break;
            }
            if pragma_allows(&self.comment[j], rule, name) {
                return true;
            }
        }
        false
    }
}

fn braces(code: &str) -> i64 {
    let mut d = 0i64;
    for c in code.chars() {
        if c == '{' {
            d += 1;
        } else if c == '}' {
            d -= 1;
        }
    }
    d
}

/// Parse every `sflint:allow(rule, reason)` occurrence in a comment and
/// check whether one names this rule (id or name).  A pragma without a
/// reason is ignored — suppressions must be justified.
fn pragma_allows(comment: &str, rule: &str, name: &str) -> bool {
    let mut rest = comment;
    while let Some(pos) = rest.find("sflint:allow(") {
        rest = &rest[pos + "sflint:allow(".len()..];
        let Some(close) = rest.find(')') else { return false };
        let inner = &rest[..close];
        rest = &rest[close + 1..];
        let Some((tag, reason)) = inner.split_once(',') else { continue };
        if reason.trim().is_empty() {
            continue;
        }
        let tag = tag.trim();
        if tag == rule || tag == name {
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// Word-level helpers shared by the rules (std-only: no regex).
// ---------------------------------------------------------------------------

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Byte offsets of every whole-word occurrence of `word` in `hay`.
pub(crate) fn word_positions(hay: &str, word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(p) = hay[from..].find(word) {
        let at = from + p;
        let before_ok = !hay[..at].chars().next_back().is_some_and(is_ident_char);
        let after = at + word.len();
        let after_ok = !hay[after..].chars().next().is_some_and(is_ident_char);
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + word.len().max(1);
    }
    out
}

pub(crate) fn contains_word(hay: &str, word: &str) -> bool {
    !word_positions(hay, word).is_empty()
}

// ---------------------------------------------------------------------------
// Tree analysis, baseline, reporting.
// ---------------------------------------------------------------------------

/// Run every rule over one file's source text.
pub fn analyze_source(rel: &str, text: &str) -> Vec<Finding> {
    let f = SourceFile::parse(rel, text);
    let mut out = Vec::new();
    rules::all(&f, &mut out);
    out
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<()> {
    let rd = std::fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))?;
    for entry in rd {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Analyze every `.rs` file under `root` (deterministic path order).
pub fn analyze_tree(root: &Path) -> Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for path in files {
        let rel: String = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
        out.extend(analyze_source(&rel, &text));
    }
    out.sort_by(|a, b| (a.rule, &a.path, a.line).cmp(&(b.rule, &b.path, b.line)));
    Ok(out)
}

/// Load a baseline file (JSONL of [`Finding::to_json`] records) into
/// match keys.  Malformed lines are an error — a silently ignored
/// baseline entry would un-grandfather a finding.
pub fn load_baseline(path: &Path) -> Result<Vec<(String, String, String)>> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let rule = json_str_field(line, "rule");
        let p = json_str_field(line, "path");
        let msg = json_str_field(line, "msg");
        match (rule, p, msg) {
            (Some(rule), Some(p), Some(msg)) => out.push((rule, p, msg)),
            _ => bail!("{}:{}: malformed baseline record", path.display(), i + 1),
        }
    }
    Ok(out)
}

/// Split findings into (fresh, baselined).  Each baseline entry
/// absorbs any number of findings with its key — the baseline
/// grandfathers a *message at a path*, not a count.
pub fn split_baselined(
    findings: Vec<Finding>,
    baseline: &[(String, String, String)],
) -> (Vec<Finding>, Vec<Finding>) {
    let mut fresh = Vec::new();
    let mut old = Vec::new();
    for f in findings {
        let k = f.key();
        if baseline.iter().any(|b| *b == k) {
            old.push(f);
        } else {
            fresh.push(f);
        }
    }
    (fresh, old)
}

/// Human-readable findings table.
pub fn render_table(findings: &[Finding]) -> String {
    let mut out = String::new();
    let loc_w = findings
        .iter()
        .map(|f| f.path.len() + 1 + f.line.to_string().len())
        .max()
        .unwrap_or(8)
        .max(8);
    for f in findings {
        let loc = format!("{}:{}", f.path, f.line);
        out.push_str(&format!("{} {:<6} {:<loc_w$}  {}\n", f.rule, f.name, loc, f.msg));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masking_strips_strings_and_comments() {
        let f = SourceFile::parse("x.rs", "let s = \"a.unwrap()\"; // .unwrap()\nlet t = 1;");
        assert!(!f.code[0].contains("unwrap"));
        assert!(f.comment[0].contains(".unwrap()"));
        assert_eq!(f.code[1], "let t = 1;");
    }

    #[test]
    fn masking_handles_block_comments_and_chars() {
        let src = "let a = 1; /* x { */\nlet b = '{';\n/* multi\nline } */ let c = 2;";
        let f = SourceFile::parse("x.rs", src);
        assert!(!f.code[0].contains('{'));
        assert!(!f.code[1].contains('{'));
        assert!(f.code[3].contains("let c"));
        assert_eq!(f.depth[3], 0);
    }

    #[test]
    fn raw_strings_mask_across_lines() {
        let src = "let s = r#\"for x in map.iter() {\nstill text }\"#;\nlet y = 3;";
        let f = SourceFile::parse("x.rs", src);
        assert!(!f.code[0].contains("iter"));
        assert!(!f.code[1].contains('}'));
        assert_eq!(f.code[2], "let y = 3;");
        assert_eq!(f.depth[2], 0);
    }

    #[test]
    fn test_regions_cover_cfg_test_mods() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}";
        let f = SourceFile::parse("x.rs", src);
        assert!(!f.test[0]);
        assert!(f.test[2]);
        assert!(f.test[3]);
        assert!(f.test[4]);
        assert!(!f.test[5]);
    }

    #[test]
    fn pragma_same_line_and_above() {
        let src = "// sflint:allow(determinism, bench harness)\nlet t = x;\nlet u = y; // sflint:allow(R4, infallible by construction)\nlet v = z;";
        let f = SourceFile::parse("x.rs", src);
        assert!(f.allowed(1, "R1", "determinism"));
        assert!(f.allowed(2, "R4", "panic-discipline"));
        assert!(!f.allowed(3, "R4", "panic-discipline"));
    }

    #[test]
    fn pragma_without_reason_is_ignored() {
        let f = SourceFile::parse("x.rs", "let t = x; // sflint:allow(R1, )");
        assert!(!f.allowed(0, "R1", "determinism"));
    }

    #[test]
    fn json_roundtrip() {
        let f = Finding {
            rule: "R2",
            name: "checkpoint-coverage",
            path: "pool/mod.rs".into(),
            line: 7,
            msg: "field `x` of `Y` not referenced".into(),
        };
        let j = f.to_json();
        assert_eq!(json_str_field(&j, "rule").as_deref(), Some("R2"));
        assert_eq!(json_str_field(&j, "path").as_deref(), Some("pool/mod.rs"));
        assert_eq!(json_str_field(&j, "msg").as_deref(), Some("field `x` of `Y` not referenced"));
    }

    #[test]
    fn word_positions_respect_boundaries() {
        assert!(contains_word("let x = Instant::now();", "Instant"));
        assert!(!contains_word("let instant_total = 3;", "Instant"));
        assert!(!contains_word("NotAnInstantX", "Instant"));
    }
}
