//! Host-side tensors: the coordinator's own representation of model state.
//!
//! All adapter/optimizer state lives on the host as [`HostTensor`]s (LoRA
//! state is small — a few hundred KB per client), and is marshaled into
//! `xla::Literal`s at call boundaries by the runtime layer.  Aggregation
//! (paper eqs. 6–7) and adapter splitting (eq. 9) operate directly on
//! these host buffers.

pub mod ops;
pub mod rng;
pub mod store;

use anyhow::{bail, Result};

/// Element type of a host tensor. Mirrors the two dtypes the artifacts use.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A named, shaped, host-resident tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(name: impl Into<String>, shape: Vec<usize>, data: Vec<f32>) -> Self {
        let t = Self { name: name.into(), shape, data: TensorData::F32(data) };
        debug_assert_eq!(t.len(), t.numel(), "data length must match shape");
        t
    }

    pub fn i32(name: impl Into<String>, shape: Vec<usize>, data: Vec<i32>) -> Self {
        let t = Self { name: name.into(), shape, data: TensorData::I32(data) };
        debug_assert_eq!(t.len(), t.numel(), "data length must match shape");
        t
    }

    /// All-zeros f32 tensor of the given shape.
    pub fn zeros(name: impl Into<String>, shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self::f32(name, shape, vec![0.0; n])
    }

    /// Scalar f32 (shape []).
    pub fn scalar(name: impl Into<String>, v: f32) -> Self {
        Self::f32(name, vec![], vec![v])
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn len(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor {} is i32, expected f32", self.name),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor {} is i32, expected f32", self.name),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => bail!("tensor {} is f32, expected i32", self.name),
        }
    }

    /// Bytes occupied by the payload.
    pub fn byte_len(&self) -> usize {
        self.len() * 4
    }

    /// Raw little-endian bytes of the payload (both dtypes are 4-byte LE).
    pub fn to_le_bytes(&self) -> Vec<u8> {
        match &self.data {
            TensorData::F32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            TensorData::I32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        }
    }

    /// Zero-copy view of the payload as bytes (native endianness — this
    /// build targets little-endian; the hot marshaling path uses this to
    /// avoid a per-upload allocation; see EXPERIMENTS.md §Perf).
    pub fn payload_bytes(&self) -> &[u8] {
        #[cfg(target_endian = "big")]
        compile_error!("payload_bytes assumes a little-endian target");
        match &self.data {
            TensorData::F32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
            TensorData::I32(v) => unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4)
            },
        }
    }

    /// Slice the leading (stack) axis: rows `[lo, hi)`. Used to split LoRA
    /// stacks at a client's cut point (paper eq. 9).
    pub fn slice_axis0(&self, lo: usize, hi: usize) -> Result<HostTensor> {
        if self.shape.is_empty() {
            bail!("cannot slice a scalar tensor {}", self.name);
        }
        let n0 = self.shape[0];
        if lo > hi || hi > n0 {
            bail!("slice [{lo},{hi}) out of bounds for axis-0 size {n0} ({})", self.name);
        }
        let inner: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        match &self.data {
            TensorData::F32(v) => Ok(HostTensor::f32(
                self.name.clone(),
                shape,
                v[lo * inner..hi * inner].to_vec(),
            )),
            TensorData::I32(v) => Ok(HostTensor::i32(
                self.name.clone(),
                shape,
                v[lo * inner..hi * inner].to_vec(),
            )),
        }
    }

    /// Concatenate along the leading axis (inverse of `slice_axis0`).
    /// Used to join client + server adapter halves into the full adapter
    /// set (paper eq. 5).
    pub fn concat_axis0(parts: &[&HostTensor]) -> Result<HostTensor> {
        let first = parts.first().ok_or_else(|| anyhow::anyhow!("empty concat"))?;
        let inner: usize = first.shape[1..].iter().product();
        let mut total0 = 0usize;
        for p in parts {
            if p.shape[1..] != first.shape[1..] {
                bail!("concat shape mismatch: {:?} vs {:?}", p.shape, first.shape);
            }
            total0 += p.shape[0];
        }
        let mut shape = first.shape.clone();
        shape[0] = total0;
        let mut data = Vec::with_capacity(total0 * inner);
        for p in parts {
            data.extend_from_slice(p.as_f32()?);
        }
        Ok(HostTensor::f32(first.name.clone(), shape, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar("s", 2.5);
        assert_eq!(t.numel(), 1);
        assert_eq!(t.as_f32().unwrap(), &[2.5]);
    }

    #[test]
    fn zeros_shape() {
        let t = HostTensor::zeros("z", vec![2, 3]);
        assert_eq!(t.numel(), 6);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn slice_then_concat_roundtrips() {
        let t = HostTensor::f32("x", vec![4, 2], (0..8).map(|i| i as f32).collect());
        let a = t.slice_axis0(0, 1).unwrap();
        let b = t.slice_axis0(1, 4).unwrap();
        assert_eq!(a.shape, vec![1, 2]);
        assert_eq!(b.shape, vec![3, 2]);
        let joined = HostTensor::concat_axis0(&[&a, &b]).unwrap();
        assert_eq!(joined.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn slice_out_of_bounds_errors() {
        let t = HostTensor::zeros("x", vec![2, 2]);
        assert!(t.slice_axis0(1, 3).is_err());
        assert!(t.slice_axis0(2, 1).is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = HostTensor::i32("x", vec![1], vec![3]);
        assert!(t.as_f32().is_err());
        assert!(t.as_i32().is_ok());
    }

    #[test]
    fn le_bytes_f32() {
        let t = HostTensor::f32("x", vec![1], vec![1.0]);
        assert_eq!(t.to_le_bytes(), 1.0f32.to_le_bytes().to_vec());
    }
}
