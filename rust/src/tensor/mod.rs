//! Host-side tensors: the coordinator's own representation of model state.
//!
//! All adapter/optimizer state lives on the host as [`HostTensor`]s (LoRA
//! state is small — a few hundred KB per client), and is marshaled into
//! `xla::Literal`s at call boundaries by the runtime layer.  Aggregation
//! (paper eqs. 6–7) and adapter splitting (eq. 9) operate directly on
//! these host buffers.

pub mod ops;
pub mod rng;
pub mod store;

use anyhow::{bail, Result};

std::thread_local! {
    /// Per-thread count of `HostTensor` payload allocations
    /// (constructors + clones).  Thread-local so concurrent tests (or
    /// future parallel client fan-out) can't perturb each other's
    /// measurements.  The steady-state training loop is required to be
    /// allocation-free after round 1; tests and benches assert that by
    /// diffing this counter (EXPERIMENTS.md §Perf documents the
    /// methodology).
    static HOST_TENSOR_ALLOCS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Snapshot of the calling thread's `HostTensor` allocation counter.
pub fn alloc_count() -> u64 {
    HOST_TENSOR_ALLOCS.with(|c| c.get())
}

/// Reset the calling thread's allocation counter to zero, returning the
/// previous value.  Zero-alloc gates reset before measuring and then
/// prove the counter is live with a one-allocation canary, so a gate
/// cannot pass vacuously against a poisoned or dead counter (see
/// `tests/integration_training.rs`).
pub fn reset_alloc_count() -> u64 {
    HOST_TENSOR_ALLOCS.with(|c| c.replace(0))
}

fn note_alloc() {
    HOST_TENSOR_ALLOCS.with(|c| c.set(c.get() + 1));
}

#[cfg(target_endian = "big")]
compile_error!("the zero-copy byte views below assume a little-endian target");

/// Zero-copy view of an f32 slice as bytes — the single home of this
/// unsafe cast (native endianness; guarded little-endian above).  Used
/// by `payload_bytes` and the runtime's literal staging.
pub(crate) fn f32_bytes(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// Zero-copy view of an i32 slice as bytes (see [`f32_bytes`]).
pub(crate) fn i32_bytes(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// Element type of a host tensor. Mirrors the two dtypes the artifacts use.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A named, shaped, host-resident tensor.
#[derive(Debug, PartialEq)]
pub struct HostTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Clone for HostTensor {
    fn clone(&self) -> Self {
        note_alloc();
        Self { name: self.name.clone(), shape: self.shape.clone(), data: self.data.clone() }
    }
}

/// Borrowed view of rows `[lo, hi)` along a tensor's leading axis.
/// Splitting an adapter stack at a cut point is O(1) with views — no
/// payload copy (the aggregation path relies on this).
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    pub name: &'a str,
    /// Rows in the axis-0 window.
    pub rows: usize,
    /// Trailing dims (`shape[1..]` of the parent tensor).
    pub inner: &'a [usize],
    pub data: &'a [f32],
}

impl TensorView<'_> {
    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// Mutable counterpart of [`TensorView`].
#[derive(Debug)]
pub struct TensorViewMut<'a> {
    pub name: &'a str,
    pub rows: usize,
    pub inner: &'a [usize],
    pub data: &'a mut [f32],
}

impl TensorViewMut<'_> {
    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// Validate an axis-0 window and return the flat element range it covers.
fn axis0_range(name: &str, shape: &[usize], lo: usize, hi: usize) -> Result<std::ops::Range<usize>> {
    if shape.is_empty() {
        bail!("cannot take an axis-0 view of scalar tensor {name}");
    }
    let n0 = shape[0];
    if lo > hi || hi > n0 {
        bail!("view [{lo},{hi}) out of bounds for axis-0 size {n0} ({name})");
    }
    let inner: usize = shape[1..].iter().product();
    Ok(lo * inner..hi * inner)
}

impl HostTensor {
    pub fn f32(name: impl Into<String>, shape: Vec<usize>, data: Vec<f32>) -> Self {
        note_alloc();
        let t = Self { name: name.into(), shape, data: TensorData::F32(data) };
        debug_assert_eq!(t.len(), t.numel(), "data length must match shape");
        t
    }

    pub fn i32(name: impl Into<String>, shape: Vec<usize>, data: Vec<i32>) -> Self {
        note_alloc();
        let t = Self { name: name.into(), shape, data: TensorData::I32(data) };
        debug_assert_eq!(t.len(), t.numel(), "data length must match shape");
        t
    }

    /// All-zeros f32 tensor of the given shape.
    pub fn zeros(name: impl Into<String>, shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self::f32(name, shape, vec![0.0; n])
    }

    /// Scalar f32 (shape []).
    pub fn scalar(name: impl Into<String>, v: f32) -> Self {
        Self::f32(name, vec![], vec![v])
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn len(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor {} is i32, expected f32", self.name),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor {} is i32, expected f32", self.name),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => bail!("tensor {} is f32, expected i32", self.name),
        }
    }

    /// Bytes occupied by the payload.
    pub fn byte_len(&self) -> usize {
        self.len() * 4
    }

    /// Raw little-endian bytes of the payload (both dtypes are 4-byte LE).
    pub fn to_le_bytes(&self) -> Vec<u8> {
        match &self.data {
            TensorData::F32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            TensorData::I32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        }
    }

    /// Zero-copy view of the payload as bytes (native endianness — this
    /// build targets little-endian; the hot marshaling path uses this to
    /// avoid a per-upload allocation; see EXPERIMENTS.md §Perf).
    pub fn payload_bytes(&self) -> &[u8] {
        match &self.data {
            TensorData::F32(v) => f32_bytes(v),
            TensorData::I32(v) => i32_bytes(v),
        }
    }

    /// Slice the leading (stack) axis: rows `[lo, hi)`. Used to split LoRA
    /// stacks at a client's cut point (paper eq. 9).
    pub fn slice_axis0(&self, lo: usize, hi: usize) -> Result<HostTensor> {
        let range = axis0_range(&self.name, &self.shape, lo, hi)?;
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        match &self.data {
            TensorData::F32(v) => {
                Ok(HostTensor::f32(self.name.clone(), shape, v[range].to_vec()))
            }
            TensorData::I32(v) => {
                Ok(HostTensor::i32(self.name.clone(), shape, v[range].to_vec()))
            }
        }
    }

    /// O(1) borrowed view of rows `[lo, hi)` along the leading axis —
    /// the zero-copy counterpart of [`HostTensor::slice_axis0`] the
    /// aggregation hot path uses (f32 tensors only).
    pub fn view_axis0(&self, lo: usize, hi: usize) -> Result<TensorView<'_>> {
        let range = axis0_range(&self.name, &self.shape, lo, hi)?;
        Ok(TensorView {
            name: &self.name,
            rows: hi - lo,
            inner: &self.shape[1..],
            data: &self.as_f32()?[range],
        })
    }

    /// Mutable O(1) view of rows `[lo, hi)` along the leading axis.
    pub fn view_axis0_mut(&mut self, lo: usize, hi: usize) -> Result<TensorViewMut<'_>> {
        let range = axis0_range(&self.name, &self.shape, lo, hi)?;
        let Self { name, shape, data } = self;
        let slice = match data {
            TensorData::F32(v) => &mut v[range],
            TensorData::I32(_) => bail!("tensor {name} is i32, expected f32"),
        };
        Ok(TensorViewMut {
            name: name.as_str(),
            rows: hi - lo,
            inner: &shape[1..],
            data: slice,
        })
    }

    /// Concatenate along the leading axis (inverse of `slice_axis0`).
    /// Used to join client + server adapter halves into the full adapter
    /// set (paper eq. 5).  Dtype-generic: all parts must share one dtype
    /// (and trailing shape); mixing f32 and i32 is rejected.
    pub fn concat_axis0(parts: &[&HostTensor]) -> Result<HostTensor> {
        let first = parts.first().ok_or_else(|| anyhow::anyhow!("empty concat"))?;
        let total0 = Self::concat_axis0_check(parts)?;
        let inner: usize = first.shape[1..].iter().product();
        let mut shape = first.shape.clone();
        shape[0] = total0;
        match &first.data {
            TensorData::F32(_) => {
                let mut data = Vec::with_capacity(total0 * inner);
                for p in parts {
                    data.extend_from_slice(p.as_f32()?);
                }
                Ok(HostTensor::f32(first.name.clone(), shape, data))
            }
            TensorData::I32(_) => {
                let mut data = Vec::with_capacity(total0 * inner);
                for p in parts {
                    data.extend_from_slice(p.as_i32()?);
                }
                Ok(HostTensor::i32(first.name.clone(), shape, data))
            }
        }
    }

    /// In-place concatenation: write the parts, in order, into `dst`
    /// (which must already have the concatenated shape and matching
    /// dtype).  Zero-allocation counterpart of `concat_axis0`.
    pub fn concat_axis0_into(parts: &[&HostTensor], dst: &mut HostTensor) -> Result<()> {
        let first = parts.first().ok_or_else(|| anyhow::anyhow!("empty concat"))?;
        let total0 = Self::concat_axis0_check(parts)?;
        if dst.shape.first() != Some(&total0) || dst.shape[1..] != first.shape[1..] {
            bail!(
                "concat_axis0_into dst shape {:?} incompatible with parts (axis0 {total0}, inner {:?})",
                dst.shape,
                &first.shape[1..]
            );
        }
        match &mut dst.data {
            TensorData::F32(out) => {
                let mut at = 0usize;
                for p in parts {
                    let s = p.as_f32()?;
                    out[at..at + s.len()].copy_from_slice(s);
                    at += s.len();
                }
            }
            TensorData::I32(out) => {
                let mut at = 0usize;
                for p in parts {
                    let s = p.as_i32()?;
                    out[at..at + s.len()].copy_from_slice(s);
                    at += s.len();
                }
            }
        }
        Ok(())
    }

    /// Shared validation for the concat variants: consistent trailing
    /// shape and a single dtype across all parts. Returns the total
    /// axis-0 extent.
    fn concat_axis0_check(parts: &[&HostTensor]) -> Result<usize> {
        let first = parts.first().ok_or_else(|| anyhow::anyhow!("empty concat"))?;
        let first_is_f32 = matches!(first.data, TensorData::F32(_));
        let mut total0 = 0usize;
        for p in parts {
            if p.shape.is_empty() {
                bail!("cannot concat scalar tensor {}", p.name);
            }
            if p.shape[1..] != first.shape[1..] {
                bail!("concat shape mismatch: {:?} vs {:?}", p.shape, first.shape);
            }
            if matches!(p.data, TensorData::F32(_)) != first_is_f32 {
                bail!(
                    "concat dtype mismatch: {} and {} differ (all parts must be f32 or all i32)",
                    first.name,
                    p.name
                );
            }
            total0 += p.shape[0];
        }
        Ok(total0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar("s", 2.5);
        assert_eq!(t.numel(), 1);
        assert_eq!(t.as_f32().unwrap(), &[2.5]);
    }

    #[test]
    fn zeros_shape() {
        let t = HostTensor::zeros("z", vec![2, 3]);
        assert_eq!(t.numel(), 6);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn slice_then_concat_roundtrips() {
        let t = HostTensor::f32("x", vec![4, 2], (0..8).map(|i| i as f32).collect());
        let a = t.slice_axis0(0, 1).unwrap();
        let b = t.slice_axis0(1, 4).unwrap();
        assert_eq!(a.shape, vec![1, 2]);
        assert_eq!(b.shape, vec![3, 2]);
        let joined = HostTensor::concat_axis0(&[&a, &b]).unwrap();
        assert_eq!(joined.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn slice_out_of_bounds_errors() {
        let t = HostTensor::zeros("x", vec![2, 2]);
        assert!(t.slice_axis0(1, 3).is_err());
        assert!(t.slice_axis0(2, 1).is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let t = HostTensor::i32("x", vec![1], vec![3]);
        assert!(t.as_f32().is_err());
        assert!(t.as_i32().is_ok());
    }

    #[test]
    fn le_bytes_f32() {
        let t = HostTensor::f32("x", vec![1], vec![1.0]);
        assert_eq!(t.to_le_bytes(), 1.0f32.to_le_bytes().to_vec());
    }

    #[test]
    fn concat_i32_roundtrips() {
        let t = HostTensor::i32("x", vec![3, 2], (0..6).collect());
        let a = t.slice_axis0(0, 2).unwrap();
        let b = t.slice_axis0(2, 3).unwrap();
        let joined = HostTensor::concat_axis0(&[&a, &b]).unwrap();
        assert_eq!(joined.shape, vec![3, 2]);
        assert_eq!(joined.as_i32().unwrap(), t.as_i32().unwrap());
    }

    #[test]
    fn concat_mixed_dtype_rejected_with_clear_message() {
        let f = HostTensor::f32("f", vec![1, 2], vec![1.0, 2.0]);
        let i = HostTensor::i32("i", vec![1, 2], vec![1, 2]);
        let err = HostTensor::concat_axis0(&[&f, &i]).unwrap_err();
        assert!(err.to_string().contains("dtype mismatch"), "{err}");
        let err = HostTensor::concat_axis0(&[&i, &f]).unwrap_err();
        assert!(err.to_string().contains("dtype mismatch"), "{err}");
    }

    #[test]
    fn concat_into_matches_allocating_concat() {
        let t = HostTensor::f32("x", vec![4, 3], (0..12).map(|i| i as f32).collect());
        let a = t.slice_axis0(0, 1).unwrap();
        let b = t.slice_axis0(1, 4).unwrap();
        let mut dst = HostTensor::zeros("x", vec![4, 3]);
        HostTensor::concat_axis0_into(&[&a, &b], &mut dst).unwrap();
        assert_eq!(dst.as_f32().unwrap(), t.as_f32().unwrap());
        // Shape mismatch is rejected.
        let mut short = HostTensor::zeros("x", vec![3, 3]);
        assert!(HostTensor::concat_axis0_into(&[&a, &b], &mut short).is_err());
    }

    #[test]
    fn views_are_zero_copy_windows() {
        let t = HostTensor::f32("x", vec![4, 2], (0..8).map(|i| i as f32).collect());
        let before = alloc_count();
        let v = t.view_axis0(1, 3).unwrap();
        assert_eq!(v.rows, 2);
        assert_eq!(v.inner, &[2]);
        assert_eq!(v.data, &[2.0, 3.0, 4.0, 5.0]);
        assert_eq!(v.numel(), 4);
        assert_eq!(alloc_count(), before, "views must not allocate tensors");
        assert!(t.view_axis0(3, 5).is_err());
        assert!(HostTensor::scalar("s", 1.0).view_axis0(0, 0).is_err());
    }

    #[test]
    fn mut_views_write_through() {
        let mut t = HostTensor::zeros("x", vec![2, 2]);
        {
            let v = t.view_axis0_mut(1, 2).unwrap();
            v.data.fill(7.0);
        }
        assert_eq!(t.as_f32().unwrap(), &[0.0, 0.0, 7.0, 7.0]);
        let mut i = HostTensor::i32("i", vec![2], vec![1, 2]);
        assert!(i.view_axis0_mut(0, 1).is_err(), "i32 tensors have no f32 views");
    }

    #[test]
    fn alloc_counter_counts_ctors_and_clones() {
        let before = alloc_count();
        let t = HostTensor::zeros("x", vec![2]);
        let _c = t.clone();
        assert_eq!(alloc_count(), before + 2);
    }

    #[test]
    fn reset_alloc_count_zeroes_and_counter_stays_live() {
        let _t = HostTensor::zeros("t", vec![2]);
        assert!(alloc_count() > 0);
        reset_alloc_count();
        assert_eq!(alloc_count(), 0, "reset must zero this thread's counter");
        let _u = HostTensor::zeros("u", vec![2]);
        assert_eq!(alloc_count(), 1, "counter must stay live after a reset");
    }
}
