//! Deterministic RNG for the data generator and partitioner.
//!
//! SplitMix64 core + Box–Muller normals + a gamma sampler good enough for
//! the Dirichlet non-IID partition (Marsaglia–Tsang). No external crates:
//! determinism across platforms matters more than speed here and keeps
//! every experiment exactly reproducible from a seed.

/// SplitMix64 — tiny, fast, well-distributed; state advances by a Weyl
/// sequence so short seeds are fine.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Raw generator state, for checkpoint/resume. Restoring with
    /// [`Rng::from_state`] continues the exact stream.
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild a generator mid-stream from a saved [`Rng::state`].
    pub fn from_state(state: u64) -> Self {
        Self { state }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal: exp(mu + sigma · N(0, 1)).  Fleet synthesis uses this
    /// for device TFLOPS / link-rate / MFU spreads — multiplicative
    /// heterogeneity with a heavy right tail, never negative.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Zipf-distributed rank in [0, n): P(r) ∝ 1/(r+1)^s.  Inverse-CDF
    /// by linear scan — intended for small n (device classes), where
    /// rank 0 (the cheapest, most common device) dominates.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        let total: f64 = (1..=n).map(|r| (r as f64).powf(-s)).sum();
        let mut t = self.uniform() * total;
        for r in 0..n {
            t -= ((r + 1) as f64).powf(-s);
            if t <= 0.0 {
                return r;
            }
        }
        n - 1
    }

    /// Gamma(alpha, 1) via Marsaglia–Tsang (with the alpha < 1 boost).
    pub fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            let u = self.uniform().max(1e-12);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform().max(1e-12);
            if u.ln() < 0.5 * x * x + d - d * v3 + d * v3.ln() {
                return d * v3;
            }
        }
    }

    /// Dirichlet(alpha * 1) over `k` categories.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha).max(1e-12)).collect();
        let s: f64 = g.iter().sum();
        for x in &mut g {
            *x /= s;
        }
        g
    }

    /// Sample an index from a (not necessarily normalized) weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(3);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let d = r.dirichlet(alpha, 6);
            assert_eq!(d.len(), 6);
            assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x > 0.0));
        }
    }

    #[test]
    fn low_alpha_dirichlet_is_skewed() {
        let mut r = Rng::new(4);
        // With alpha=0.1 the max component should usually dominate.
        let mut dominated = 0;
        for _ in 0..50 {
            let d = r.dirichlet(0.1, 6);
            if d.iter().cloned().fold(0.0, f64::max) > 0.5 {
                dominated += 1;
            }
        }
        assert!(dominated > 30, "only {dominated}/50 draws dominated");
    }

    #[test]
    fn lognormal_is_positive_with_matching_log_moments() {
        let mut r = Rng::new(8);
        let (mu, sigma) = (0.5, 0.65);
        let n = 20_000;
        let logs: Vec<f64> = (0..n)
            .map(|_| {
                let x = r.lognormal(mu, sigma);
                assert!(x > 0.0);
                x.ln()
            })
            .collect();
        let mean = logs.iter().sum::<f64>() / n as f64;
        let var = logs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - mu).abs() < 0.03, "log-mean {mean}");
        assert!((var.sqrt() - sigma).abs() < 0.03, "log-std {}", var.sqrt());
    }

    #[test]
    fn zipf_ranks_are_bounded_and_skewed_to_rank_zero() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 6];
        for _ in 0..6000 {
            counts[r.zipf(6, 1.1)] += 1;
        }
        assert!(counts[0] > counts[1] && counts[1] > counts[2], "{counts:?}");
        assert!(counts[0] > 6000 / 3, "rank 0 must dominate: {counts:?}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(5);
        let w = [0.0, 0.0, 1.0];
        for _ in 0..20 {
            assert_eq!(r.categorical(&w), 2);
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(6);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
