//! Host-side numeric ops used by aggregation and tests.
//!
//! The `*_into` variants are the zero-allocation hot-path suite: they
//! write into caller-owned buffers and are bit-identical to their
//! allocating counterparts (same per-element accumulation order), so
//! swapping one for the other never changes training numerics.

use super::HostTensor;
use anyhow::{bail, Result};

/// `dst += alpha * src` over raw slices (the innermost aggregation
/// kernel; length mismatch is a caller bug and is rejected).
pub fn axpy_into(alpha: f32, src: &[f32], dst: &mut [f32]) -> Result<()> {
    if src.len() != dst.len() {
        bail!("axpy_into length mismatch: {} vs {}", src.len(), dst.len());
    }
    for (di, si) in dst.iter_mut().zip(src.iter()) {
        *di += alpha * si;
    }
    Ok(())
}

/// `dst += alpha * src` (elementwise).
pub fn axpy(alpha: f32, src: &HostTensor, dst: &mut HostTensor) -> Result<()> {
    if src.shape != dst.shape {
        bail!("axpy shape mismatch: {:?} vs {:?}", src.shape, dst.shape);
    }
    axpy_into(alpha, src.as_f32()?, dst.as_f32_mut()?)
}

/// `t *= alpha` (elementwise).
pub fn scale(alpha: f32, t: &mut HostTensor) -> Result<()> {
    for x in t.as_f32_mut()? {
        *x *= alpha;
    }
    Ok(())
}

/// Copy `src`'s payload into `dst` (shapes and dtypes must match).
/// The in-place counterpart of `dst = src.clone()`.
pub fn copy_from(dst: &mut HostTensor, src: &HostTensor) -> Result<()> {
    if src.shape != dst.shape {
        bail!("copy_from shape mismatch: {:?} vs {:?}", src.shape, dst.shape);
    }
    use super::TensorData;
    match (&mut dst.data, &src.data) {
        (TensorData::F32(d), TensorData::F32(s)) => d.copy_from_slice(s),
        (TensorData::I32(d), TensorData::I32(s)) => d.copy_from_slice(s),
        _ => bail!("copy_from dtype mismatch: {} vs {}", dst.name, src.name),
    }
    Ok(())
}

/// Fused single-pass weighted sum over raw slices:
/// `dst[i] = sum_j w_j * src_j[i]` (overwrites `dst`).  One pass over
/// the output instead of one pass per source — the cache-friendly core
/// of FedAvg aggregation.
pub fn weighted_sum_slices_into(srcs: &[(f32, &[f32])], dst: &mut [f32]) -> Result<()> {
    for (j, (_, s)) in srcs.iter().enumerate() {
        if s.len() != dst.len() {
            bail!("weighted_sum source {j} length {} != dst {}", s.len(), dst.len());
        }
    }
    for (i, d) in dst.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for (w, s) in srcs {
            acc += *w * s[i];
        }
        *d = acc;
    }
    Ok(())
}

/// In-place weighted sum of equally-shaped tensors: overwrite `dst`
/// with `sum_i w_i * t_i`.  Bit-identical to `weighted_sum` (same
/// accumulation order per element) with zero tensor allocations.
pub fn weighted_sum_into(pairs: &[(f32, &HostTensor)], dst: &mut HostTensor) -> Result<()> {
    if pairs.is_empty() {
        bail!("empty weighted_sum");
    }
    let mut srcs: Vec<(f32, &[f32])> = Vec::with_capacity(pairs.len());
    for (w, t) in pairs {
        if t.shape != dst.shape {
            bail!("weighted_sum shape mismatch: {:?} vs dst {:?}", t.shape, dst.shape);
        }
        srcs.push((*w, t.as_f32()?));
    }
    weighted_sum_slices_into(&srcs, dst.as_f32_mut()?)
}

/// Weighted sum of equally-shaped tensors: `sum_i w_i * t_i`.
/// This is exactly the FedAvg aggregation primitive (paper eqs. 6–7).
pub fn weighted_sum(pairs: &[(f32, &HostTensor)]) -> Result<HostTensor> {
    let (_, first) = pairs.first().ok_or_else(|| anyhow::anyhow!("empty weighted_sum"))?;
    let mut out = HostTensor::zeros(first.name.clone(), first.shape.clone());
    weighted_sum_into(pairs, &mut out)?;
    Ok(out)
}

/// Max |a - b| over all elements.
pub fn max_abs_diff(a: &HostTensor, b: &HostTensor) -> Result<f32> {
    if a.shape != b.shape {
        bail!("shape mismatch: {:?} vs {:?}", a.shape, b.shape);
    }
    let (av, bv) = (a.as_f32()?, b.as_f32()?);
    Ok(av
        .iter()
        .zip(bv.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max))
}

/// Approximate equality within `tol` (used by integration tests).
pub fn allclose(a: &HostTensor, b: &HostTensor, tol: f32) -> bool {
    matches!(max_abs_diff(a, b), Ok(d) if d <= tol)
}

/// L2 norm of the payload.
pub fn l2_norm(t: &HostTensor) -> Result<f32> {
    Ok(t.as_f32()?.iter().map(|x| x * x).sum::<f32>().sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(name: &str, data: Vec<f32>) -> HostTensor {
        let n = data.len();
        HostTensor::f32(name, vec![n], data)
    }

    #[test]
    fn axpy_accumulates() {
        let src = t("s", vec![1.0, 2.0]);
        let mut dst = t("d", vec![10.0, 20.0]);
        axpy(0.5, &src, &mut dst).unwrap();
        assert_eq!(dst.as_f32().unwrap(), &[10.5, 21.0]);
    }

    #[test]
    fn axpy_rejects_shape_mismatch() {
        let src = t("s", vec![1.0]);
        let mut dst = t("d", vec![1.0, 2.0]);
        assert!(axpy(1.0, &src, &mut dst).is_err());
    }

    #[test]
    fn weighted_sum_is_convex_combination() {
        let a = t("a", vec![0.0, 10.0]);
        let b = t("b", vec![10.0, 0.0]);
        let out = weighted_sum(&[(0.25, &a), (0.75, &b)]).unwrap();
        assert_eq!(out.as_f32().unwrap(), &[7.5, 2.5]);
    }

    #[test]
    fn weighted_sum_identity_with_single_weight_one() {
        let a = t("a", vec![3.0, -1.0, 2.0]);
        let out = weighted_sum(&[(1.0, &a)]).unwrap();
        assert_eq!(out.as_f32().unwrap(), a.as_f32().unwrap());
    }

    #[test]
    fn allclose_tolerates() {
        let a = t("a", vec![1.0]);
        let b = t("b", vec![1.0005]);
        assert!(allclose(&a, &b, 1e-3));
        assert!(!allclose(&a, &b, 1e-5));
    }

    #[test]
    fn l2_norm_works() {
        let a = t("a", vec![3.0, 4.0]);
        assert!((l2_norm(&a).unwrap() - 5.0).abs() < 1e-6);
    }

    #[test]
    fn weighted_sum_into_matches_allocating_bitwise() {
        let a = t("a", vec![0.1, -2.5, 3.25]);
        let b = t("b", vec![10.0, 0.5, -1.0]);
        let c = t("c", vec![-3.0, 7.0, 0.0]);
        let pairs = [(0.2f32, &a), (0.3, &b), (0.5, &c)];
        let alloc = weighted_sum(&pairs).unwrap();
        let mut into = t("d", vec![9.0, 9.0, 9.0]);
        weighted_sum_into(&pairs, &mut into).unwrap();
        assert_eq!(alloc.as_f32().unwrap(), into.as_f32().unwrap());
    }

    #[test]
    fn weighted_sum_into_rejects_mismatch_and_empty() {
        let a = t("a", vec![1.0, 2.0]);
        let mut d3 = t("d", vec![0.0; 3]);
        assert!(weighted_sum_into(&[(1.0, &a)], &mut d3).is_err());
        assert!(weighted_sum_into(&[], &mut d3).is_err());
    }

    #[test]
    fn axpy_into_accumulates_over_slices() {
        let mut d = [1.0f32, 2.0];
        axpy_into(2.0, &[10.0, 20.0], &mut d).unwrap();
        assert_eq!(d, [21.0, 42.0]);
        assert!(axpy_into(1.0, &[1.0], &mut d).is_err());
    }

    #[test]
    fn copy_from_copies_and_checks() {
        let src = t("s", vec![1.0, 2.0]);
        let mut dst = t("d", vec![0.0, 0.0]);
        copy_from(&mut dst, &src).unwrap();
        assert_eq!(dst.as_f32().unwrap(), &[1.0, 2.0]);
        let mut short = t("d", vec![0.0]);
        assert!(copy_from(&mut short, &src).is_err());
        let isrc = HostTensor::i32("i", vec![2], vec![1, 2]);
        assert!(copy_from(&mut dst, &isrc).is_err(), "dtype mismatch rejected");
    }
}
