//! Host-side numeric ops used by aggregation and tests.

use super::HostTensor;
use anyhow::{bail, Result};

/// `dst += alpha * src` (elementwise).
pub fn axpy(alpha: f32, src: &HostTensor, dst: &mut HostTensor) -> Result<()> {
    if src.shape != dst.shape {
        bail!("axpy shape mismatch: {:?} vs {:?}", src.shape, dst.shape);
    }
    let s = src.as_f32()?;
    let d = dst.as_f32_mut()?;
    for (di, si) in d.iter_mut().zip(s.iter()) {
        *di += alpha * si;
    }
    Ok(())
}

/// `t *= alpha` (elementwise).
pub fn scale(alpha: f32, t: &mut HostTensor) -> Result<()> {
    for x in t.as_f32_mut()? {
        *x *= alpha;
    }
    Ok(())
}

/// Weighted sum of equally-shaped tensors: `sum_i w_i * t_i`.
/// This is exactly the FedAvg aggregation primitive (paper eqs. 6–7).
pub fn weighted_sum(pairs: &[(f32, &HostTensor)]) -> Result<HostTensor> {
    let (_, first) = pairs.first().ok_or_else(|| anyhow::anyhow!("empty weighted_sum"))?;
    let mut out = HostTensor::zeros(first.name.clone(), first.shape.clone());
    for (w, t) in pairs {
        axpy(*w, t, &mut out)?;
    }
    Ok(out)
}

/// Max |a - b| over all elements.
pub fn max_abs_diff(a: &HostTensor, b: &HostTensor) -> Result<f32> {
    if a.shape != b.shape {
        bail!("shape mismatch: {:?} vs {:?}", a.shape, b.shape);
    }
    let (av, bv) = (a.as_f32()?, b.as_f32()?);
    Ok(av
        .iter()
        .zip(bv.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max))
}

/// Approximate equality within `tol` (used by integration tests).
pub fn allclose(a: &HostTensor, b: &HostTensor, tol: f32) -> bool {
    matches!(max_abs_diff(a, b), Ok(d) if d <= tol)
}

/// L2 norm of the payload.
pub fn l2_norm(t: &HostTensor) -> Result<f32> {
    Ok(t.as_f32()?.iter().map(|x| x * x).sum::<f32>().sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(name: &str, data: Vec<f32>) -> HostTensor {
        let n = data.len();
        HostTensor::f32(name, vec![n], data)
    }

    #[test]
    fn axpy_accumulates() {
        let src = t("s", vec![1.0, 2.0]);
        let mut dst = t("d", vec![10.0, 20.0]);
        axpy(0.5, &src, &mut dst).unwrap();
        assert_eq!(dst.as_f32().unwrap(), &[10.5, 21.0]);
    }

    #[test]
    fn axpy_rejects_shape_mismatch() {
        let src = t("s", vec![1.0]);
        let mut dst = t("d", vec![1.0, 2.0]);
        assert!(axpy(1.0, &src, &mut dst).is_err());
    }

    #[test]
    fn weighted_sum_is_convex_combination() {
        let a = t("a", vec![0.0, 10.0]);
        let b = t("b", vec![10.0, 0.0]);
        let out = weighted_sum(&[(0.25, &a), (0.75, &b)]).unwrap();
        assert_eq!(out.as_f32().unwrap(), &[7.5, 2.5]);
    }

    #[test]
    fn weighted_sum_identity_with_single_weight_one() {
        let a = t("a", vec![3.0, -1.0, 2.0]);
        let out = weighted_sum(&[(1.0, &a)]).unwrap();
        assert_eq!(out.as_f32().unwrap(), a.as_f32().unwrap());
    }

    #[test]
    fn allclose_tolerates() {
        let a = t("a", vec![1.0]);
        let b = t("b", vec![1.0005]);
        assert!(allclose(&a, &b, 1e-3));
        assert!(!allclose(&a, &b, 1e-5));
    }

    #[test]
    fn l2_norm_works() {
        let a = t("a", vec![3.0, 4.0]);
        assert!((l2_norm(&a).unwrap() - 5.0).abs() < 1e-6);
    }
}
