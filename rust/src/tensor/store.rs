//! Reader for `artifacts/<config>/params.bin` — the initial "pretrained"
//! checkpoint emitted by `python/compile/aot.py` (format documented in
//! python/compile/packing.py).

use super::{HostTensor, TensorData};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

const MAGIC: &[u8; 4] = b"SFLP";
const VERSION: u32 = 1;
const DTYPE_F32: u8 = 0;
const DTYPE_I32: u8 = 1;

/// An ordered, name-indexed collection of tensors loaded from params.bin.
#[derive(Debug, Clone)]
pub struct ParamStore {
    order: Vec<String>,
    by_name: HashMap<String, HostTensor>,
}

impl ParamStore {
    pub fn load(path: &Path) -> Result<Self> {
        let mut fh = std::fs::File::open(path)
            .with_context(|| format!("opening params.bin at {}", path.display()))?;
        let mut buf = Vec::new();
        fh.read_to_end(&mut buf)?;
        Self::parse(&buf)
    }

    pub fn parse(buf: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("params.bin truncated at offset {}", *pos);
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        // Infallible LE decoders for slices whose length `take`/
        // `chunks_exact` already guarantees — no unwrap on the decode
        // path.
        let u32_le = |b: &[u8]| u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        let u16_le = |b: &[u8]| u16::from_le_bytes([b[0], b[1]]);

        if take(&mut pos, 4)? != MAGIC {
            bail!("bad params.bin magic");
        }
        let version = u32_le(take(&mut pos, 4)?);
        if version != VERSION {
            bail!("unsupported params.bin version {version}");
        }
        let count = u32_le(take(&mut pos, 4)?) as usize;

        let mut order = Vec::with_capacity(count);
        let mut by_name = HashMap::with_capacity(count);
        for _ in 0..count {
            let nlen = u16_le(take(&mut pos, 2)?) as usize;
            let name = String::from_utf8(take(&mut pos, nlen)?.to_vec())
                .context("tensor name is not utf8")?;
            let dt = take(&mut pos, 1)?[0];
            let ndim = take(&mut pos, 1)?[0] as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u32_le(take(&mut pos, 4)?) as usize);
            }
            let numel: usize = if ndim == 0 { 1 } else { shape.iter().product() };
            let raw = take(&mut pos, numel * 4)?;
            let data = match dt {
                DTYPE_F32 => TensorData::F32(
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                DTYPE_I32 => TensorData::I32(
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                other => bail!("unknown dtype tag {other} for {name}"),
            };
            order.push(name.clone());
            by_name.insert(name.clone(), HostTensor { name, shape, data });
        }
        Ok(Self { order, by_name })
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.by_name
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("tensor {name} not in params.bin"))
    }

    pub fn names(&self) -> &[String] {
        &self.order
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Total parameter count across all tensors.
    pub fn total_params(&self) -> usize {
        // sflint:allow(determinism, usize sum is order-independent)
        self.by_name.values().map(|t| t.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_bin() -> Vec<u8> {
        // magic | version | count=2
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&VERSION.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        // tensor "a": f32 [2, 2]
        b.extend_from_slice(&(1u16).to_le_bytes());
        b.push(b'a');
        b.push(DTYPE_F32);
        b.push(2);
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            b.extend_from_slice(&v.to_le_bytes());
        }
        // tensor "b": i32 scalar
        b.extend_from_slice(&(1u16).to_le_bytes());
        b.push(b'b');
        b.push(DTYPE_I32);
        b.push(0);
        b.extend_from_slice(&7i32.to_le_bytes());
        b
    }

    #[test]
    fn parse_roundtrip() {
        let store = ParamStore::parse(&sample_bin()).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(store.names(), &["a".to_string(), "b".to_string()]);
        let a = store.get("a").unwrap();
        assert_eq!(a.shape, vec![2, 2]);
        assert_eq!(a.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        let b = store.get("b").unwrap();
        assert_eq!(b.shape, Vec::<usize>::new());
        assert_eq!(b.as_i32().unwrap(), &[7]);
        assert_eq!(store.total_params(), 5);
    }

    #[test]
    fn truncation_is_detected() {
        let bin = sample_bin();
        assert!(ParamStore::parse(&bin[..bin.len() - 2]).is_err());
    }

    #[test]
    fn bad_magic_is_detected() {
        let mut bin = sample_bin();
        bin[0] = b'X';
        assert!(ParamStore::parse(&bin).is_err());
    }

    #[test]
    fn missing_tensor_errors() {
        let store = ParamStore::parse(&sample_bin()).unwrap();
        assert!(store.get("nope").is_err());
    }
}
