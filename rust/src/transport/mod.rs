//! Compressed update transport: top-k sparse + quantized LoRA deltas
//! with error feedback and content-hash integrity.
//!
//! At fleet scale the binding constraint is uplink, not memory — nobody
//! ships dense f32 deltas.  The [`Codec`] turns a client's LoRA delta
//! (vs the round's dispatch baseline) into a compact wire message:
//!
//! 1. **Delta extraction** — `d = x − b` over the client-half adapter
//!    tensors, flattened in `LORA_KEYS` order.
//! 2. **Error feedback** (optional) — the client's residual from prior
//!    rounds is added back (`d += e`), so mass dropped by
//!    sparsification/quantization is retransmitted later instead of
//!    lost.  Residuals live in the [`crate::pool::StatePool`] like Adam
//!    state: spilled, reloaded, and checkpointed bit-exactly.
//! 3. **Top-k sparsification** — the `⌈frac·n⌉` largest-magnitude
//!    coordinates survive, deterministically (`total_cmp` on |d|,
//!    ascending-index tie-break); indices are wired in ascending order.
//! 4. **Linear quantization** — surviving values ship as raw f32, q8
//!    (symmetric i8, scale = max|v|/127), or q4 (symmetric 4-bit,
//!    scale = max|v|/7, two values per byte).
//! 5. **Integrity** — an FNV-1a hash over the serialized payload is
//!    appended; the server verifies it before merge and routes a
//!    mismatch through the PR 6 sanitizer/quarantine path as a
//!    detected fault.
//!
//! Wire layout (little-endian):
//!
//! ```text
//! | n: u32 | k: u32 | quant: u8 | scale: f32 | [seq: u32] |  idx: k × u32  | values | hash: u64 |
//! ```
//!
//! The `seq` field is present iff the [`SEQ_FLAG`] high bit of the
//! quant-tag byte is set — the lossy channel stamps a per-client
//! monotone sequence number there for duplicate/stale suppression;
//! payloads without the flag keep the historical layout byte-for-byte.
//!
//! The new residual after an encode is `e' = d − d̂` (selected
//! coordinates keep their quantization error, unselected ones keep the
//! full delta).  All work buffers are lazily grown and reused, so the
//! encode/decode path performs zero steady-state allocations (the same
//! `tensor::alloc_count` discipline as the rest of the hot path).
//!
//! Degenerate settings (`--compress none`, or top-k at `frac = 1.0`
//! with f32 values and no error feedback) never construct a codec at
//! all — the session keeps the dense path verbatim, so trajectories,
//! traffic, and checkpoint layouts stay bit-identical (the repo's
//! eager-twin invariant; `fl(b + fl(x − b)) ≠ x` in general, so
//! bitwise identity *through* a delta codec is impossible).

pub mod testbed;

use crate::lora::{AdapterSet, AdapterViews};
use crate::model::ModelDims;
use crate::util::fnv1a;
use anyhow::{bail, Result};

/// Fixed wire-header size: n (u32) + k (u32) + quant tag (u8) + scale (f32).
pub const HEADER_BYTES: usize = 13;
/// FNV-1a trailer size.
pub const HASH_BYTES: usize = 8;
/// Optional sequence-number field size (lossy-channel duplicate
/// suppression).  Presence is signaled by [`SEQ_FLAG`] on the quant
/// tag byte; the field sits immediately after the scale.
pub const SEQ_BYTES: usize = 4;
/// High bit of the wire quant-tag byte: set ⇒ a `u32` sequence number
/// follows the scale.  Quant tags proper stay in the low 7 bits, so
/// pre-channel payloads (flag clear) decode unchanged.
pub const SEQ_FLAG: u8 = 0x80;

/// Compression mode (`--compress`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressKind {
    /// Dense f32 uploads — the pre-transport behavior.
    None,
    /// Top-k-by-magnitude sparsification (+ optional quantization / EF).
    TopK,
}

impl CompressKind {
    /// Stable tag for checkpoint fingerprints.
    pub fn tag(&self) -> u64 {
        match self {
            CompressKind::None => 0,
            CompressKind::TopK => 1,
        }
    }
}

impl std::fmt::Display for CompressKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            CompressKind::None => "none",
            CompressKind::TopK => "topk",
        })
    }
}

impl std::str::FromStr for CompressKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "none" => Ok(CompressKind::None),
            "topk" => Ok(CompressKind::TopK),
            other => bail!("unknown compress kind {other:?} (none|topk)"),
        }
    }
}

/// Value quantization level (`--quant`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantKind {
    /// Raw little-endian f32 values (lossless for selected coords).
    F32,
    /// Symmetric linear 8-bit (scale = max|v| / 127).
    Q8,
    /// Symmetric linear 4-bit, two values per byte (scale = max|v| / 7).
    Q4,
}

impl QuantKind {
    /// Wire tag (also the checkpoint-fingerprint tag).
    pub fn tag(&self) -> u8 {
        match self {
            QuantKind::F32 => 0,
            QuantKind::Q8 => 1,
            QuantKind::Q4 => 2,
        }
    }

    fn from_tag(tag: u8) -> Result<Self> {
        match tag {
            0 => Ok(QuantKind::F32),
            1 => Ok(QuantKind::Q8),
            2 => Ok(QuantKind::Q4),
            other => bail!("unknown quant tag {other} on the wire"),
        }
    }

    /// Packed bytes for `k` quantized values.
    pub fn packed_bytes(&self, k: usize) -> usize {
        match self {
            QuantKind::F32 => 4 * k,
            QuantKind::Q8 => k,
            QuantKind::Q4 => k.div_ceil(2),
        }
    }

    /// Symmetric quantization range bound (0 disables: f32 is lossless).
    fn max_q(&self) -> i32 {
        match self {
            QuantKind::F32 => 0,
            QuantKind::Q8 => 127,
            QuantKind::Q4 => 7,
        }
    }
}

impl std::fmt::Display for QuantKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            QuantKind::F32 => "f32",
            QuantKind::Q8 => "q8",
            QuantKind::Q4 => "q4",
        })
    }
}

impl std::str::FromStr for QuantKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(QuantKind::F32),
            "q8" => Ok(QuantKind::Q8),
            "q4" => Ok(QuantKind::Q4),
            other => bail!("unknown quant kind {other:?} (f32|q8|q4)"),
        }
    }
}

/// Per-merge transport telemetry, streamed in the jsonl `"transport"`
/// block and asserted by `benches/transport.rs`.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TransportStats {
    /// Encoded uplink bytes billed this merge.
    pub up_bytes: u64,
    /// Dense downlink bytes billed this merge (the aggregate broadcast
    /// is not compressed — every client needs every coordinate).
    pub down_bytes: u64,
    /// Uplink compression ratio: dense bytes / encoded bytes (0 when
    /// nothing was uploaded).
    pub ratio: f64,
    /// L2 norm of the participants' error-feedback residuals after
    /// their encodes (0 when EF is off).
    pub ef_norm: f64,
}

/// Number of surviving coordinates for `params` total at `frac`
/// (`⌈frac·params⌉`, at least 1, at most all).
pub fn topk_count(params: usize, frac: f64) -> usize {
    if params == 0 {
        return 0;
    }
    ((params as f64 * frac).ceil() as usize).clamp(1, params)
}

/// Exact serialized size of one encoded upload: header + ascending
/// u32 indices + packed values + FNV-1a trailer.  The traffic meter
/// bills this analytic size over the *timing* model's parameter counts
/// while the codec runs on the executed tensors; the formula is
/// asserted equal to the real payload length in the codec tests.
pub fn encoded_bytes(params: usize, frac: f64, quant: QuantKind) -> usize {
    let k = topk_count(params, frac);
    HEADER_BYTES + 4 * k + quant.packed_bytes(k) + HASH_BYTES
}

fn quantize(v: f32, scale: f32, max_q: i32) -> i32 {
    if scale == 0.0 || !scale.is_finite() {
        return 0;
    }
    let q = (v / scale).round();
    // `as` saturates (and maps NaN to 0), so corrupt inputs degrade to
    // an in-range code instead of poisoning the wire format.
    (q as i32).clamp(-max_q, max_q)
}

/// The per-session transport codec.  Owns lazily-grown reusable work
/// buffers; one instance serves every client in a merge (payloads are
/// consumed — billed, verified, decoded — before the next encode).
#[derive(Debug)]
pub struct Codec {
    frac: f64,
    quant: QuantKind,
    error_feedback: bool,
    /// Staged flattened delta `x − b (+ e)` in LORA_KEYS order.
    delta: Vec<f32>,
    /// Index sort buffer for top-k selection.
    order: Vec<u32>,
    /// Serialized wire message (reused across encodes).
    payload: Vec<u8>,
    /// Per-merge stats accumulators (reset by [`Codec::round_reset`]).
    up_bytes: u64,
    dense_bytes: u64,
    ef_sq: f64,
    /// Sequence number for the next encode (set per upload by the
    /// lossy-channel path; absent ⇒ the historical header layout).
    staged_seq: Option<u32>,
    /// Test hook: corrupt the next `n` payloads after hashing.
    tamper_next: u32,
}

impl Codec {
    pub fn new(frac: f64, quant: QuantKind, error_feedback: bool) -> Self {
        Self {
            frac,
            quant,
            error_feedback,
            delta: Vec::new(),
            order: Vec::new(),
            payload: Vec::new(),
            up_bytes: 0,
            dense_bytes: 0,
            ef_sq: 0.0,
            staged_seq: None,
            tamper_next: 0,
        }
    }

    /// Stamp the next encode with a sequence number (the lossy channel
    /// draws one per upload; retransmissions reuse the same payload, so
    /// the stamp survives retries byte-identically).
    pub fn stage_seq(&mut self, seq: u32) {
        self.staged_seq = Some(seq);
    }

    pub fn error_feedback(&self) -> bool {
        self.error_feedback
    }

    /// Analytic encoded size for a `params`-coordinate upload under
    /// this codec's knobs (what the traffic meter bills).
    pub fn billed_bytes(&self, params: usize) -> usize {
        encoded_bytes(params, self.frac, self.quant)
    }

    /// Stage the flattened client-half delta `x − b` into the work
    /// buffer.  Split from [`Codec::encode_staged`] so the caller can
    /// drop its immutable borrows (baseline views) before handing over
    /// the mutable error-feedback residual.
    pub fn stage_delta(&mut self, x: &AdapterSet, b: &AdapterViews) -> Result<()> {
        self.delta.clear();
        for (t, bv) in x.tensors.iter().zip(b.tensors.iter()) {
            let xs = t.as_f32()?;
            if xs.len() != bv.data.len() {
                bail!(
                    "transport delta shape mismatch on {}: {} vs baseline {}",
                    t.name,
                    xs.len(),
                    bv.data.len()
                );
            }
            for (p, q) in xs.iter().zip(bv.data.iter()) {
                self.delta.push(p - q);
            }
        }
        Ok(())
    }

    /// Sparsify + quantize + serialize + hash the staged delta and
    /// return the wire payload (borrowed from the codec's reusable
    /// buffer — consume it before the next encode).  When `ef` is
    /// given, the residual is added to the delta before selection and
    /// replaced with `d − d̂` afterwards; an empty residual is sized on
    /// first use.
    pub fn encode_staged(&mut self, ef: Option<&mut Vec<f32>>) -> Result<&[u8]> {
        let n = self.delta.len();
        if n == 0 {
            bail!("encode_staged called with no staged delta");
        }
        if n > u32::MAX as usize {
            bail!("delta has {n} coordinates, wire format caps at u32");
        }
        let ef = match (self.error_feedback, ef) {
            (true, Some(e)) => {
                if e.is_empty() {
                    e.resize(n, 0.0);
                } else if e.len() != n {
                    bail!("error-feedback residual has {} coords, delta {n}", e.len());
                }
                for (d, r) in self.delta.iter_mut().zip(e.iter()) {
                    *d += r;
                }
                Some(e)
            }
            (false, None) => None,
            (true, None) => bail!("codec has error feedback on but no residual was passed"),
            (false, Some(_)) => bail!("residual passed to a codec with error feedback off"),
        };
        let k = topk_count(n, self.frac);
        self.order.clear();
        self.order.extend(0..n as u32);
        if k < n {
            let delta = &self.delta;
            let by_magnitude = |&i: &u32, &j: &u32| {
                let a = delta[i as usize].abs();
                let b = delta[j as usize].abs();
                // Largest magnitude first; NaN sorts largest under
                // total_cmp, so corrupt coords surface (and the PR 6
                // sanitizer sees them server-side).  Ascending-index
                // tie-break keeps the selection deterministic.
                b.total_cmp(&a).then(i.cmp(&j))
            };
            self.order.select_nth_unstable_by(k - 1, by_magnitude);
            self.order.truncate(k);
        }
        self.order.sort_unstable();
        let max_q = self.quant.max_q();
        let scale = if max_q == 0 {
            0.0f32
        } else {
            let mut max_abs = 0.0f32;
            for &i in &self.order {
                let a = self.delta[i as usize].abs();
                if a.is_finite() && a > max_abs {
                    max_abs = a;
                }
            }
            max_abs / max_q as f32
        };
        let seq = self.staged_seq.take();
        self.payload.clear();
        self.payload.extend_from_slice(&(n as u32).to_le_bytes());
        self.payload.extend_from_slice(&(k as u32).to_le_bytes());
        self.payload.push(self.quant.tag() | if seq.is_some() { SEQ_FLAG } else { 0 });
        self.payload.extend_from_slice(&scale.to_le_bytes());
        if let Some(s) = seq {
            self.payload.extend_from_slice(&s.to_le_bytes());
        }
        for &i in &self.order {
            self.payload.extend_from_slice(&i.to_le_bytes());
        }
        match self.quant {
            QuantKind::F32 => {
                for &i in &self.order {
                    self.payload.extend_from_slice(&self.delta[i as usize].to_le_bytes());
                }
            }
            QuantKind::Q8 => {
                for &i in &self.order {
                    let q = quantize(self.delta[i as usize], scale, max_q);
                    self.payload.push(q as i8 as u8);
                }
            }
            QuantKind::Q4 => {
                // Biased nibbles (q + 7 ∈ [0, 14]), low nibble first.
                let mut pair = 0u8;
                for (pos, &i) in self.order.iter().enumerate() {
                    let q = (quantize(self.delta[i as usize], scale, max_q) + 7) as u8;
                    if pos % 2 == 0 {
                        pair = q;
                        if pos == self.order.len() - 1 {
                            self.payload.push(pair);
                        }
                    } else {
                        self.payload.push(pair | (q << 4));
                    }
                }
            }
        }
        let hash = fnv1a(&self.payload);
        self.payload.extend_from_slice(&hash.to_le_bytes());
        debug_assert_eq!(
            self.payload.len(),
            encoded_bytes(n, self.frac, self.quant)
                + if seq.is_some() { SEQ_BYTES } else { 0 },
            "analytic encoded size must match the real payload"
        );
        if let Some(e) = ef {
            // New residual: full delta where unsent, quantization error
            // where sent.
            e.copy_from_slice(&self.delta);
            for &i in &self.order {
                let d = self.delta[i as usize];
                e[i as usize] = d - dequant_one(d, scale, max_q);
            }
            let mut sq = 0.0f64;
            for &r in e.iter() {
                sq += (r as f64) * (r as f64);
            }
            self.ef_sq += sq;
        }
        if self.tamper_next > 0 {
            self.tamper_next -= 1;
            // Flip a bit after hashing so server-side verification fails.
            self.payload[HEADER_BYTES] ^= 0x01;
        }
        Ok(&self.payload)
    }

    /// One-shot encode (tests / testbed — the session uses the staged
    /// two-phase form to satisfy pool borrow discipline).
    pub fn encode(
        &mut self,
        x: &AdapterSet,
        b: &AdapterViews,
        ef: Option<&mut Vec<f32>>,
    ) -> Result<&[u8]> {
        self.stage_delta(x, b)?;
        self.encode_staged(ef)
    }

    /// The sequence number stamped on a payload, if any (flag on the
    /// quant-tag byte).  Runs before decode so duplicate/stale copies
    /// are suppressed without touching the arena.
    pub fn read_seq(payload: &[u8]) -> Option<u32> {
        if payload.len() < HEADER_BYTES + SEQ_BYTES + HASH_BYTES || payload[8] & SEQ_FLAG == 0 {
            return None;
        }
        let bytes: [u8; 4] = payload[13..17].try_into().ok()?;
        Some(u32::from_le_bytes(bytes))
    }

    /// Server-side integrity check: recompute the FNV-1a trailer.
    pub fn verify(payload: &[u8]) -> bool {
        if payload.len() < HEADER_BYTES + HASH_BYTES {
            return false;
        }
        let (body, trailer) = payload.split_at(payload.len() - HASH_BYTES);
        let Ok(bytes) = <[u8; 8]>::try_from(trailer) else {
            return false;
        };
        fnv1a(body) == u64::from_le_bytes(bytes)
    }

    /// Decode a verified payload into `dst` as an *absolute* client
    /// half: `dst = b + d̂`.  Allocation-free; `dst` must already have
    /// the client-half shape (`DecodeArena` provides recycled sets).
    pub fn decode_into(payload: &[u8], b: &AdapterViews, dst: &mut AdapterSet) -> Result<()> {
        if payload.len() < HEADER_BYTES + HASH_BYTES {
            bail!("transport payload too short ({} bytes)", payload.len());
        }
        let rd_u32 = |at: usize| -> Result<u32> {
            let bytes: [u8; 4] = payload[at..at + 4]
                .try_into()
                .map_err(|_| anyhow::anyhow!("transport header truncated"))?;
            Ok(u32::from_le_bytes(bytes))
        };
        let n = rd_u32(0)? as usize;
        let k = rd_u32(4)? as usize;
        let has_seq = payload[8] & SEQ_FLAG != 0;
        let quant = QuantKind::from_tag(payload[8] & !SEQ_FLAG)?;
        let scale = f32::from_le_bytes(
            payload[9..13]
                .try_into()
                .map_err(|_| anyhow::anyhow!("transport header truncated"))?,
        );
        let header = HEADER_BYTES + if has_seq { SEQ_BYTES } else { 0 };
        let expect = header + 4 * k + quant.packed_bytes(k) + HASH_BYTES;
        if payload.len() != expect {
            bail!("transport payload is {} bytes, header implies {expect}", payload.len());
        }
        if k > n {
            bail!("transport payload selects {k} of {n} coordinates");
        }
        let total: usize = b.param_count();
        if n != total {
            bail!("transport payload covers {n} coordinates, baseline has {total}");
        }
        if dst.param_count() != total {
            bail!(
                "decode scratch has {} coordinates, payload covers {total}",
                dst.param_count()
            );
        }
        // Start from the baseline, then add the sparse delta.
        for (t, bv) in dst.tensors.iter_mut().zip(b.tensors.iter()) {
            t.as_f32_mut()?.copy_from_slice(bv.data);
        }
        let idx_at = header;
        let val_at = idx_at + 4 * k;
        // Ascending indices let the tensor walk be a single forward scan.
        let mut tensor = 0usize;
        let mut base = 0usize;
        let mut prev: Option<u32> = None;
        for pos in 0..k {
            let idx = rd_u32(idx_at + 4 * pos)?;
            if let Some(p) = prev {
                if idx <= p {
                    bail!("transport indices must be strictly ascending ({p} then {idx})");
                }
            }
            prev = Some(idx);
            let flat = idx as usize;
            if flat >= total {
                bail!("transport index {flat} out of range ({total} coordinates)");
            }
            let v = match quant {
                QuantKind::F32 => {
                    let bytes: [u8; 4] = payload[val_at + 4 * pos..val_at + 4 * pos + 4]
                        .try_into()
                        .map_err(|_| anyhow::anyhow!("transport values truncated"))?;
                    f32::from_le_bytes(bytes)
                }
                QuantKind::Q8 => (payload[val_at + pos] as i8) as f32 * scale,
                QuantKind::Q4 => {
                    let byte = payload[val_at + pos / 2];
                    let nib = if pos % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                    if nib > 14 {
                        bail!("transport q4 nibble {nib} out of range");
                    }
                    (nib as i32 - 7) as f32 * scale
                }
            };
            while flat >= base + dst.tensors[tensor].numel() {
                base += dst.tensors[tensor].numel();
                tensor += 1;
            }
            let d = dst.tensors[tensor].as_f32_mut()?;
            d[flat - base] += v;
        }
        Ok(())
    }

    /// Reset the per-merge stats accumulators.
    pub fn round_reset(&mut self) {
        self.up_bytes = 0;
        self.dense_bytes = 0;
        self.ef_sq = 0.0;
    }

    /// Record one billed upload (encoded vs what dense would have cost).
    pub fn note_upload(&mut self, encoded: u64, dense: u64) {
        self.up_bytes += encoded;
        self.dense_bytes += dense;
    }

    /// Snapshot this merge's stats (`down_bytes` is the dense broadcast
    /// the session billed alongside).
    pub fn round_stats(&self, down_bytes: u64) -> TransportStats {
        TransportStats {
            up_bytes: self.up_bytes,
            down_bytes,
            ratio: if self.up_bytes == 0 {
                0.0
            } else {
                self.dense_bytes as f64 / self.up_bytes as f64
            },
            ef_norm: self.ef_sq.sqrt(),
        }
    }

    /// Test hook: corrupt the next `n` encoded payloads (one flipped
    /// bit after hashing), so server-side verification rejects them.
    #[doc(hidden)]
    pub fn tamper_next(&mut self, n: u32) {
        self.tamper_next = n;
    }
}

/// Flip one bit of the hash-covered body of a wire payload — the
/// lossy channel's on-wire corruption.  `raw` is an arbitrary seeded
/// draw, reduced modulo the body's bit count; the FNV-1a trailer is
/// never touched (corrupting the checksum itself would also be caught,
/// but body corruption is the interesting case for decode safety).
/// XOR is self-inverse, so applying the same call twice restores the
/// payload — retransmissions reuse the clean bytes.
pub fn corrupt_wire(payload: &mut [u8], raw: u64) {
    if payload.len() <= HASH_BYTES {
        return;
    }
    let body_bits = (payload.len() - HASH_BYTES) * 8;
    let bit = (raw % body_bits as u64) as usize;
    payload[bit / 8] ^= 1 << (bit % 8);
}

fn dequant_one(v: f32, scale: f32, max_q: i32) -> f32 {
    if max_q == 0 {
        v
    } else {
        quantize(v, scale, max_q) as f32 * scale
    }
}

/// Recycled decode scratch: one client-half [`AdapterSet`] per merge
/// survivor, reshaped in place across cut depths so the steady state
/// allocates no `HostTensor`s (same arena discipline as the pool).
#[derive(Debug, Default)]
pub struct DecodeArena {
    sets: Vec<AdapterSet>,
}

impl DecodeArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Scratch set `i`, reshaped for a `k`-layer client half.
    pub fn slot_mut(&mut self, i: usize, dims: &ModelDims, k: usize) -> &mut AdapterSet {
        while self.sets.len() <= i {
            self.sets.push(AdapterSet::zeros(dims, k));
        }
        let set = &mut self.sets[i];
        if set.layers != k {
            for t in set.tensors.iter_mut() {
                crate::pool::reshape_rows(t, k);
            }
            set.layers = k;
        }
        set
    }

    /// Immutable borrow of scratch set `i` (for the merge-kernel
    /// contributor list, after all decodes are done).
    pub fn get(&self, i: usize) -> &AdapterSet {
        &self.sets[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;
    use crate::util::propcheck::{check, gen};

    fn dims() -> ModelDims {
        ModelDims::mini()
    }

    fn random_half(seed: u64, k: usize, spread: f32) -> AdapterSet {
        let d = dims();
        let mut set = AdapterSet::zeros(&d, k);
        let mut rng = Rng::new(seed);
        for t in set.tensors.iter_mut() {
            for x in t.as_f32_mut().unwrap() {
                *x = (rng.normal() as f32) * spread;
            }
        }
        set
    }

    fn flat(set: &AdapterSet) -> Vec<f32> {
        set.tensors.iter().flat_map(|t| t.as_f32().unwrap().iter().copied()).collect()
    }

    #[test]
    fn full_frac_f32_roundtrip_recovers_exact_delta() {
        let d = dims();
        let k = d.layers / 2;
        let x = random_half(1, k, 0.5);
        let b = random_half(2, k, 0.5);
        let (bv, _) = split_client(&b, k);
        let mut codec = Codec::new(1.0, QuantKind::F32, false);
        let payload = codec.encode(&x, &bv, None).unwrap().to_vec();
        assert!(Codec::verify(&payload));
        assert_eq!(payload.len(), encoded_bytes(x.param_count(), 1.0, QuantKind::F32));
        let mut out = AdapterSet::zeros(&d, k);
        Codec::decode_into(&payload, &bv, &mut out).unwrap();
        // b + ((x − b) + b's own value) — every coordinate shipped as
        // raw f32, so the reconstruction is b + fl(x − b) exactly.
        for (got, (xi, bi)) in flat(&out).iter().zip(flat(&x).iter().zip(flat(&b).iter())) {
            assert_eq!(*got, bi + (xi - bi));
        }
    }

    /// The wire holds the top-k by |delta| and the decode touches only
    /// those coordinates.
    #[test]
    fn topk_keeps_largest_magnitudes() {
        let d = dims();
        let k_layers = d.layers / 2;
        let b = AdapterSet::zeros(&d, k_layers);
        let mut x = AdapterSet::zeros(&d, k_layers);
        let n = x.param_count();
        // Coordinate j has magnitude j+1 → top-k is the tail.
        {
            let mut j = 0f32;
            for t in x.tensors.iter_mut() {
                for v in t.as_f32_mut().unwrap() {
                    j += 1.0;
                    *v = if (j as usize) % 2 == 0 { j } else { -j };
                }
            }
        }
        let (bv, _) = split_client(&b, k_layers);
        let frac = 0.1;
        let keep = topk_count(n, frac);
        let mut codec = Codec::new(frac, QuantKind::F32, false);
        let payload = codec.encode(&x, &bv, None).unwrap().to_vec();
        let mut out = AdapterSet::zeros(&d, k_layers);
        Codec::decode_into(&payload, &bv, &mut out).unwrap();
        let got = flat(&out);
        let want = flat(&x);
        for (j, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            if j >= n - keep {
                assert_eq!(g, w, "top-k coordinate {j} must ship");
            } else {
                assert_eq!(*g, 0.0, "coordinate {j} must be dropped");
            }
        }
    }

    /// Deterministic tie-break: equal magnitudes keep the lowest index.
    #[test]
    fn ties_resolve_to_ascending_indices() {
        let d = dims();
        let kl = d.layers / 2;
        let b = AdapterSet::zeros(&d, kl);
        let mut x = AdapterSet::zeros(&d, kl);
        for t in x.tensors.iter_mut() {
            t.as_f32_mut().unwrap().fill(1.0);
        }
        let (bv, _) = split_client(&b, kl);
        let mut codec = Codec::new(0.25, QuantKind::F32, false);
        let payload = codec.encode(&x, &bv, None).unwrap().to_vec();
        let k = topk_count(x.param_count(), 0.25);
        for pos in 0..k {
            let at = HEADER_BYTES + 4 * pos;
            let idx = u32::from_le_bytes(payload[at..at + 4].try_into().unwrap());
            assert_eq!(idx as usize, pos, "all-equal deltas must keep the lowest indices");
        }
    }

    fn split_client(set: &AdapterSet, k: usize) -> (AdapterViews<'_>, AdapterViews<'_>) {
        set.split_at_views(k).unwrap()
    }

    /// Encode→decode round-trip error is bounded by the quantization
    /// step for every quant level, on every coordinate (selected ones —
    /// unselected are exactly baseline).
    #[test]
    fn prop_roundtrip_error_bounded_by_quant_step() {
        check(
            "transport-roundtrip",
            71,
            40,
            |rng| {
                let seed = gen::usize_in(rng, 1, 1 << 30) as u64;
                let frac = gen::f64_in(rng, 0.05, 1.0);
                let quant = match gen::usize_in(rng, 0, 2) {
                    0 => QuantKind::F32,
                    1 => QuantKind::Q8,
                    _ => QuantKind::Q4,
                };
                (seed, frac, quant)
            },
            |&(seed, frac, quant)| {
                let d = dims();
                let kl = d.layers / 2;
                let x = random_half(seed, kl, 0.3);
                let b = random_half(seed ^ 0xB0B, kl, 0.3);
                let (bv, _) = split_client(&b, kl);
                let mut codec = Codec::new(frac, quant, false);
                let payload = codec.encode(&x, &bv, None).unwrap().to_vec();
                if !Codec::verify(&payload) {
                    return false;
                }
                if payload.len() != encoded_bytes(x.param_count(), frac, quant) {
                    return false;
                }
                let mut out = AdapterSet::zeros(&d, kl);
                Codec::decode_into(&payload, &bv, &mut out).unwrap();
                let xs = flat(&x);
                let bs = flat(&b);
                let os = flat(&out);
                let mut max_abs = 0.0f32;
                for (xi, bi) in xs.iter().zip(bs.iter()) {
                    max_abs = max_abs.max((xi - bi).abs());
                }
                let step = match quant {
                    QuantKind::F32 => 0.0,
                    QuantKind::Q8 => max_abs / 127.0,
                    QuantKind::Q4 => max_abs / 7.0,
                };
                // Selected coords: |decoded − x| ≤ step/2 (+f32 slop);
                // unselected: decoded == b exactly.
                let tol = step * 0.5 + max_abs * 1e-5;
                os.iter().zip(xs.iter().zip(bs.iter())).all(|(o, (xi, bi))| {
                    (o - xi).abs() <= tol || o.to_bits() == bi.to_bits()
                })
            },
        );
    }

    /// Error feedback makes lossy transport exact over time: after
    /// repeated encodes of the *same* target, baseline + Σ decoded
    /// deltas converges to the target even at q4 + 10% sparsity.
    #[test]
    fn error_feedback_retransmits_dropped_mass() {
        let d = dims();
        let kl = d.layers / 2;
        let x = random_half(9, kl, 0.5);
        let mut b = AdapterSet::zeros(&d, kl); // evolving server model
        let mut codec = Codec::new(0.1, QuantKind::Q4, true);
        let mut ef: Vec<f32> = Vec::new();
        let mut out = AdapterSet::zeros(&d, kl);
        for _ in 0..60 {
            let (bv, _) = split_client(&b, kl);
            let payload = codec.encode(&x, &bv, Some(&mut ef)).unwrap().to_vec();
            assert!(Codec::verify(&payload));
            let (bv, _) = split_client(&b, kl);
            Codec::decode_into(&payload, &bv, &mut out).unwrap();
            for (bt, ot) in b.tensors.iter_mut().zip(out.tensors.iter()) {
                bt.as_f32_mut().unwrap().copy_from_slice(ot.as_f32().unwrap());
            }
        }
        let err = b.max_abs_diff(&x).unwrap();
        assert!(err < 1e-3, "EF must recover the full target, residual err {err}");
        // Without EF the same lossy pipe stalls far from the target.
        let mut b2 = AdapterSet::zeros(&d, kl);
        let mut codec2 = Codec::new(0.1, QuantKind::Q4, false);
        for _ in 0..60 {
            let (bv, _) = split_client(&b2, kl);
            let payload = codec2.encode(&x, &bv, None).unwrap().to_vec();
            let (bv, _) = split_client(&b2, kl);
            Codec::decode_into(&payload, &bv, &mut out).unwrap();
            for (bt, ot) in b2.tensors.iter_mut().zip(out.tensors.iter()) {
                bt.as_f32_mut().unwrap().copy_from_slice(ot.as_f32().unwrap());
            }
        }
        let err2 = b2.max_abs_diff(&x).unwrap();
        assert!(err2 > err * 10.0, "EF off must be visibly lossier ({err2} vs {err})");
    }

    #[test]
    fn tampered_payload_fails_verification() {
        let d = dims();
        let kl = d.layers / 2;
        let x = random_half(3, kl, 0.5);
        let b = random_half(4, kl, 0.5);
        let (bv, _) = split_client(&b, kl);
        let mut codec = Codec::new(0.2, QuantKind::Q8, false);
        let clean = codec.encode(&x, &bv, None).unwrap().to_vec();
        assert!(Codec::verify(&clean));
        // Every single-bit flip anywhere in the message is detected.
        for at in [0, HEADER_BYTES, clean.len() - HASH_BYTES - 1, clean.len() - 1] {
            let mut bad = clean.clone();
            bad[at] ^= 0x10;
            assert!(!Codec::verify(&bad), "flip at byte {at} must fail verification");
        }
        // The built-in tamper hook produces exactly such a payload.
        codec.tamper_next(1);
        let tampered = codec.encode(&x, &bv, None).unwrap().to_vec();
        assert!(!Codec::verify(&tampered));
        let next = codec.encode(&x, &bv, None).unwrap().to_vec();
        assert!(Codec::verify(&next), "tampering must stop after n payloads");
    }

    #[test]
    fn decode_rejects_malformed_payloads() {
        let d = dims();
        let kl = d.layers / 2;
        let x = random_half(5, kl, 0.5);
        let b = AdapterSet::zeros(&d, kl);
        let (bv, _) = split_client(&b, kl);
        let mut codec = Codec::new(0.2, QuantKind::Q8, false);
        let good = codec.encode(&x, &bv, None).unwrap().to_vec();
        let mut out = AdapterSet::zeros(&d, kl);
        // Truncated.
        assert!(Codec::decode_into(&good[..good.len() - 1], &bv, &mut out).is_err());
        // Bad quant tag.
        let mut bad = good.clone();
        bad[8] = 9;
        assert!(Codec::decode_into(&bad, &bv, &mut out).is_err());
        // Scratch with the wrong depth.
        let mut short = AdapterSet::zeros(&d, kl + 1);
        assert!(Codec::decode_into(&good, &bv, &mut short).is_err());
        // Non-ascending indices.
        let mut swapped = good.clone();
        let (a0, a1) = (HEADER_BYTES, HEADER_BYTES + 4);
        for i in 0..4 {
            swapped.swap(a0 + i, a1 + i);
        }
        assert!(Codec::decode_into(&swapped, &bv, &mut out).is_err());
    }

    #[test]
    fn encoded_bytes_formula_and_counts() {
        assert_eq!(topk_count(100, 0.05), 5);
        assert_eq!(topk_count(100, 1.0), 100);
        assert_eq!(topk_count(100, 0.001), 1, "at least one coordinate always ships");
        assert_eq!(topk_count(0, 0.5), 0);
        // 21 fixed bytes + 4/idx + packed values.
        assert_eq!(encoded_bytes(100, 0.05, QuantKind::F32), 21 + 5 * 4 + 5 * 4);
        assert_eq!(encoded_bytes(100, 0.05, QuantKind::Q8), 21 + 5 * 4 + 5);
        assert_eq!(encoded_bytes(100, 0.05, QuantKind::Q4), 21 + 5 * 4 + 3);
        assert_eq!(QuantKind::Q4.packed_bytes(1), 1);
        assert_eq!(QuantKind::Q4.packed_bytes(2), 1);
        assert_eq!(QuantKind::Q4.packed_bytes(3), 2);
    }

    #[test]
    fn kind_parse_display_roundtrip() {
        for k in [CompressKind::None, CompressKind::TopK] {
            assert_eq!(k.to_string().parse::<CompressKind>().unwrap(), k);
        }
        for q in [QuantKind::F32, QuantKind::Q8, QuantKind::Q4] {
            assert_eq!(q.to_string().parse::<QuantKind>().unwrap(), q);
        }
        assert!("gzip".parse::<CompressKind>().is_err());
        assert!("q2".parse::<QuantKind>().is_err());
    }

    /// Steady-state encode/decode is HostTensor-allocation-free: after
    /// one warm-up pass the codec buffers and the decode arena are all
    /// reused in place.
    #[test]
    fn encode_decode_path_is_allocation_free_at_steady_state() {
        let d = dims();
        let kl = d.layers / 2;
        let x = random_half(11, kl, 0.5);
        let b = random_half(12, kl, 0.5);
        let mut codec = Codec::new(0.1, QuantKind::Q8, true);
        let mut ef: Vec<f32> = Vec::new();
        let mut arena = DecodeArena::new();
        // Warm-up: buffers grow to their high-water marks.
        for _ in 0..2 {
            let (bv, _) = b.split_at_views(kl).unwrap();
            codec.stage_delta(&x, &bv).unwrap();
            let payload = codec.encode_staged(Some(&mut ef)).unwrap().to_vec();
            let (bv, _) = b.split_at_views(kl).unwrap();
            Codec::decode_into(&payload, &bv, arena.slot_mut(0, &d, kl)).unwrap();
        }
        crate::tensor::reset_alloc_count();
        // Canary: prove the counter is live.
        let canary = crate::lora::AdapterSet::zeros(&d, 1);
        assert_eq!(crate::tensor::alloc_count(), 4, "counter must be live");
        drop(canary);
        crate::tensor::reset_alloc_count();
        for _ in 0..5 {
            let (bv, _) = b.split_at_views(kl).unwrap();
            codec.stage_delta(&x, &bv).unwrap();
            let len = {
                let payload = codec.encode_staged(Some(&mut ef)).unwrap();
                assert!(Codec::verify(payload));
                payload.len()
            };
            assert_eq!(len, codec.billed_bytes(x.param_count()));
            // Decode straight from the codec's payload buffer.
            let (bv, _) = b.split_at_views(kl).unwrap();
            let dst = arena.slot_mut(0, &d, kl);
            Codec::decode_into(&codec.payload, &bv, dst).unwrap();
        }
        assert_eq!(
            crate::tensor::alloc_count(),
            0,
            "steady-state encode/decode must not allocate HostTensors"
        );
    }

    #[test]
    fn seq_field_roundtrips_and_decodes_identically() {
        let d = dims();
        let kl = d.layers / 2;
        let x = random_half(21, kl, 0.5);
        let b = random_half(22, kl, 0.5);
        let (bv, _) = split_client(&b, kl);
        let mut codec = Codec::new(0.2, QuantKind::Q8, false);
        let plain = codec.encode(&x, &bv, None).unwrap().to_vec();
        assert_eq!(Codec::read_seq(&plain), None, "no flag ⇒ no sequence field");
        codec.stage_seq(417);
        let stamped = codec.encode(&x, &bv, None).unwrap().to_vec();
        assert!(Codec::verify(&stamped), "stamped payload must still hash clean");
        assert_eq!(stamped.len(), plain.len() + SEQ_BYTES);
        assert_eq!(Codec::read_seq(&stamped), Some(417));
        // The stamp is consumed: the next encode reverts to plain.
        let again = codec.encode(&x, &bv, None).unwrap().to_vec();
        assert_eq!(again, plain, "stage_seq must apply to exactly one encode");
        // Both layouts decode to the same numerics.
        let mut out_p = AdapterSet::zeros(&d, kl);
        let mut out_s = AdapterSet::zeros(&d, kl);
        Codec::decode_into(&plain, &bv, &mut out_p).unwrap();
        Codec::decode_into(&stamped, &bv, &mut out_s).unwrap();
        for (a, b) in flat(&out_p).iter().zip(flat(&out_s).iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn corrupt_wire_is_detected_and_self_inverse() {
        let d = dims();
        let kl = d.layers / 2;
        let x = random_half(23, kl, 0.5);
        let b = random_half(24, kl, 0.5);
        let (bv, _) = split_client(&b, kl);
        let mut codec = Codec::new(0.2, QuantKind::Q8, false);
        codec.stage_seq(1);
        let clean = codec.encode(&x, &bv, None).unwrap().to_vec();
        for raw in [0u64, 7, 1 << 40, u64::MAX] {
            let mut wire = clean.clone();
            corrupt_wire(&mut wire, raw);
            assert_ne!(wire, clean, "raw {raw}: a bit must flip");
            assert!(!Codec::verify(&wire), "raw {raw}: corruption must fail verification");
            corrupt_wire(&mut wire, raw);
            assert_eq!(wire, clean, "raw {raw}: double flip must restore the payload");
            assert!(Codec::verify(&wire));
        }
        // Tiny payloads (shorter than the trailer) are left alone.
        let mut stub = vec![0u8; HASH_BYTES];
        corrupt_wire(&mut stub, 3);
        assert_eq!(stub, vec![0u8; HASH_BYTES]);
    }

    #[test]
    fn round_stats_track_bytes_and_ratio() {
        let mut codec = Codec::new(0.05, QuantKind::Q8, false);
        codec.round_reset();
        codec.note_upload(100, 1600);
        codec.note_upload(100, 1600);
        let st = codec.round_stats(3200);
        assert_eq!(st.up_bytes, 200);
        assert_eq!(st.down_bytes, 3200);
        assert!((st.ratio - 16.0).abs() < 1e-12);
        assert_eq!(st.ef_norm, 0.0);
        codec.round_reset();
        assert_eq!(codec.round_stats(0), TransportStats::default());
    }
}
