//! Synthetic compression/convergence testbed: the closed-form
//! contraction world from [`crate::faults::testbed`], with the real
//! transport [`Codec`] spliced into the uplink.  Used by
//! `benches/transport.rs` and the artifact-free acceptance tests to
//! record the compression-vs-convergence frontier (the Fig. 2-style
//! traffic/quality trade-off).
//!
//! World model: full-depth global adapters `G` start at zero, the
//! optimum `T` is all-ones, and each round every client takes the same
//! contractive step `G + η·(T − G) + ε, ε ~ N(0, σ²)` per coordinate.
//! The *client* half of each submission goes through encode → verify →
//! decode exactly as the session does (the server half is
//! server-resident and never crosses the wire); byte counters bill the
//! real payload sizes against what dense f32 would have cost.
//!
//! η is deliberately smaller here than in the faults testbed: with
//! error feedback at sparsity `f`, a coordinate flushes roughly every
//! `1/f` rounds and applies `≈ η/f` of its accumulated gap at once, so
//! the contraction only stays monotone while `η/f < 2`.  η = 0.05 keeps
//! the gate configuration (`f = 0.05`) at a flush gain of ≈1 — the
//! regime the bench is meant to measure, not a divergence artifact.

use super::{Codec, CompressKind, QuantKind};
use crate::lora::{fedavg_joined_into, AdapterSet};
use crate::model::ModelDims;
use crate::tensor::rng::Rng;
use anyhow::Result;

/// Per-round contraction toward the optimum (see module docs for why
/// this is smaller than the faults-testbed η).
pub const ETA: f32 = 0.05;
/// Per-coordinate honest noise std.
pub const NOISE: f64 = 1e-4;

/// One transport configuration of the synthetic run.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub n: usize,
    pub rounds: usize,
    pub compress: CompressKind,
    pub topk_frac: f64,
    pub quant: QuantKind,
    pub error_feedback: bool,
    /// Clients `0..tamper` have every payload corrupted post-hash; the
    /// server must reject them all on the integrity check.
    pub tamper: usize,
    pub seed: u64,
}

impl Default for Scenario {
    fn default() -> Self {
        Self {
            n: 10,
            rounds: 200,
            compress: CompressKind::None,
            topk_frac: 1.0,
            quant: QuantKind::F32,
            error_feedback: false,
            tamper: 0,
            seed: 41,
        }
    }
}

/// What a scenario run produced.
#[derive(Debug, Clone, Copy)]
pub struct Outcome {
    /// `1 − min(1, final_dist / d0)`; 0 if the global went non-finite.
    pub quality: f64,
    pub final_dist: f64,
    pub d0: f64,
    /// Cumulative billed uplink bytes across the run.
    pub up_bytes: u64,
    /// What the same uploads would have cost dense (f32).
    pub dense_bytes: u64,
    /// `dense_bytes / up_bytes` (1.0 for the dense path).
    pub ratio: f64,
    /// L2 norm of all error-feedback residuals after the final round.
    pub ef_norm: f64,
    /// Payloads rejected by the server-side hash check.
    pub rejected: u64,
}

fn dist(a: &AdapterSet, b: &AdapterSet) -> Result<f64> {
    let mut acc = 0.0f64;
    for (x, y) in a.tensors.iter().zip(b.tensors.iter()) {
        for (p, q) in x.as_f32()?.iter().zip(y.as_f32()?) {
            let d = (*p - *q) as f64;
            acc += d * d;
        }
    }
    Ok(acc.sqrt())
}

/// Run one scenario to completion and score it.
pub fn run(sc: &Scenario) -> Result<Outcome> {
    let dims = ModelDims::mini();
    let layers = dims.layers;
    let k = layers / 2;
    let mut truth = AdapterSet::zeros(&dims, layers);
    for t in truth.tensors.iter_mut() {
        t.as_f32_mut()?.fill(1.0);
    }
    let mut global = AdapterSet::zeros(&dims, layers);
    let d0 = dist(&global, &truth)?;
    let mut rng = Rng::new(sc.seed);
    let mut cs: Vec<AdapterSet> = (0..sc.n).map(|_| AdapterSet::zeros(&dims, k)).collect();
    let mut ss: Vec<AdapterSet> = (0..sc.n).map(|_| AdapterSet::zeros(&dims, layers - k)).collect();
    let mut agg = AdapterSet::zeros(&dims, layers);
    let mut codec = (sc.compress == CompressKind::TopK)
        .then(|| Codec::new(sc.topk_frac, sc.quant, sc.error_feedback));
    let mut residuals: Vec<Vec<f32>> = vec![Vec::new(); sc.n];
    let mut decoded: Vec<AdapterSet> = (0..sc.n).map(|_| AdapterSet::zeros(&dims, k)).collect();
    let mut wire: Vec<u8> = Vec::new();
    let mut ok: Vec<bool> = vec![true; sc.n];
    let mut up_bytes = 0u64;
    let mut dense_bytes = 0u64;
    let mut rejected = 0u64;
    let mut ef_norm = 0.0f64;

    for _round in 0..sc.rounds {
        for u in 0..sc.n {
            for i in 0..4 {
                let inner: usize = global.tensors[i].shape[1..].iter().product();
                let b = global.tensors[i].as_f32()?;
                let t = truth.tensors[i].as_f32()?;
                let split = k * inner;
                for (j, x) in cs[u].tensors[i].as_f32_mut()?.iter_mut().enumerate() {
                    *x = b[j] + ETA * (t[j] - b[j]) + (NOISE * rng.normal()) as f32;
                }
                for (j, x) in ss[u].tensors[i].as_f32_mut()?.iter_mut().enumerate() {
                    let g = split + j;
                    *x = b[g] + ETA * (t[g] - b[g]) + (NOISE * rng.normal()) as f32;
                }
            }
        }
        if let Some(codec) = codec.as_mut() {
            codec.round_reset();
            for u in 0..sc.n {
                let dense = cs[u].byte_len() as u64;
                if u < sc.tamper {
                    codec.tamper_next(1);
                }
                {
                    let (bv, _) = global.split_at_views(k)?;
                    codec.stage_delta(&cs[u], &bv)?;
                    let ef = if sc.error_feedback { Some(&mut residuals[u]) } else { None };
                    let payload = codec.encode_staged(ef)?;
                    wire.clear();
                    wire.extend_from_slice(payload);
                }
                codec.note_upload(wire.len() as u64, dense);
                up_bytes += wire.len() as u64;
                dense_bytes += dense;
                // Server side: integrity check before anything touches
                // the merge; a bad hash drops the contribution.
                ok[u] = Codec::verify(&wire);
                if ok[u] {
                    let (bv, _) = global.split_at_views(k)?;
                    Codec::decode_into(&wire, &bv, &mut decoded[u])?;
                } else {
                    rejected += 1;
                }
            }
            ef_norm = codec.round_stats(0).ef_norm;
        } else {
            for u in 0..sc.n {
                let dense = cs[u].byte_len() as u64;
                up_bytes += dense;
                dense_bytes += dense;
                ok[u] = true;
            }
        }
        let use_codec = codec.is_some();
        let mut subs: Vec<(f32, &AdapterSet, &AdapterSet)> = (0..sc.n)
            .filter(|&u| ok[u])
            .map(|u| (1.0f32, if use_codec { &decoded[u] } else { &cs[u] }, &ss[u]))
            .collect();
        if subs.is_empty() {
            continue;
        }
        let w = 1.0 / subs.len() as f32;
        for sub in subs.iter_mut() {
            sub.0 = w;
        }
        fedavg_joined_into(&subs, &mut agg)?;
        drop(subs);
        for (g, a) in global.tensors.iter_mut().zip(agg.tensors.iter()) {
            g.as_f32_mut()?.copy_from_slice(a.as_f32()?);
        }
    }
    let final_dist = dist(&global, &truth)?;
    let quality =
        if final_dist.is_finite() { 1.0 - (final_dist / d0).min(1.0) } else { 0.0 };
    Ok(Outcome {
        quality,
        final_dist,
        d0,
        up_bytes,
        dense_bytes,
        ratio: if up_bytes == 0 { 0.0 } else { dense_bytes as f64 / up_bytes as f64 },
        ef_norm,
        rejected,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_run_converges_to_noise_floor() {
        let out = run(&Scenario::default()).unwrap();
        assert!(out.quality > 0.995, "dense quality {} below noise-floor bound", out.quality);
        assert_eq!(out.rejected, 0);
        assert_eq!(out.up_bytes, out.dense_bytes);
        assert!((out.ratio - 1.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_codec_matches_dense_quality() {
        let dense = run(&Scenario::default()).unwrap();
        let passthrough = run(&Scenario {
            compress: CompressKind::TopK,
            topk_frac: 1.0,
            quant: QuantKind::F32,
            ..Scenario::default()
        }).unwrap();
        // Through-the-codec at k=100%/f32 is numerically (not bitwise —
        // it ships a delta) equivalent; the session never takes this
        // path (degenerate settings delegate to dense), the testbed
        // exercises it as a codec sanity check.
        assert!(
            (dense.quality - passthrough.quality).abs() < 1e-4,
            "passthrough codec drifted: {} vs {}",
            passthrough.quality,
            dense.quality
        );
        // f32 at full k costs *more* than dense (indices + framing).
        assert!(passthrough.ratio < 1.0);
    }

    #[test]
    fn gate_config_hits_ratio_at_negligible_quality_cost() {
        let dense = run(&Scenario::default()).unwrap();
        let out = run(&Scenario {
            compress: CompressKind::TopK,
            topk_frac: 0.05,
            quant: QuantKind::Q8,
            error_feedback: true,
            ..Scenario::default()
        }).unwrap();
        assert!(out.ratio >= 10.0, "uplink reduction {}x below the 10x gate", out.ratio);
        assert!(
            dense.quality - out.quality <= 0.01,
            "quality delta {} exceeds 1% (dense {}, compressed {})",
            dense.quality - out.quality,
            dense.quality,
            out.quality
        );
        assert!(out.ef_norm > 0.0, "error feedback must be carrying residual mass");
    }

    #[test]
    fn error_feedback_beats_plain_topk() {
        let base = Scenario {
            compress: CompressKind::TopK,
            topk_frac: 0.05,
            quant: QuantKind::Q8,
            ..Scenario::default()
        };
        let with_ef = run(&Scenario { error_feedback: true, ..base.clone() }).unwrap();
        let without = run(&base).unwrap();
        assert!(
            with_ef.quality > without.quality + 0.05,
            "EF must visibly improve sparse convergence ({} vs {})",
            with_ef.quality,
            without.quality
        );
    }

    #[test]
    fn tampered_payloads_are_all_rejected() {
        let out = run(&Scenario {
            compress: CompressKind::TopK,
            topk_frac: 0.05,
            quant: QuantKind::Q8,
            error_feedback: true,
            tamper: 2,
            ..Scenario::default()
        }).unwrap();
        assert_eq!(out.rejected, 2 * 200, "every tampered payload must fail the hash check");
        // Honest clients alone still converge.
        assert!(out.quality > 0.98, "quality {} collapsed under tampering", out.quality);
    }

    #[test]
    fn testbed_is_seed_deterministic() {
        let sc = Scenario {
            compress: CompressKind::TopK,
            topk_frac: 0.1,
            quant: QuantKind::Q4,
            error_feedback: true,
            rounds: 60,
            ..Scenario::default()
        };
        let a = run(&sc).unwrap();
        let b = run(&sc).unwrap();
        assert_eq!(a.quality.to_bits(), b.quality.to_bits(), "same seed, same trajectory");
        assert_eq!(a.up_bytes, b.up_bytes);
        let c = run(&Scenario { seed: 42, ..sc }).unwrap();
        assert_ne!(a.quality.to_bits(), c.quality.to_bits(), "seed must matter");
    }
}
