//! Discrete-event virtual clock.
//!
//! The paper's latency results come from Jetson/Snapdragon/Apple devices
//! and an RTX 4080S; on this testbed those are simulated (DESIGN.md §2),
//! so all protocol timing runs on a virtual clock: compute and transfer
//! durations are *derived* from the analytic models and composed with an
//! event queue that reproduces eqs. (10)–(12), including the sequential
//! server queue (waiting time, eq. 11) and client-side parallelism.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in seconds.
pub type SimTime = f64;

#[derive(Debug, Clone, PartialEq)]
struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    event: E,
}

impl<E: PartialEq> Eq for Scheduled<E> {}

impl<E: PartialEq> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E: PartialEq> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on (time, insertion seq) via reversed comparison.
        other.at.total_cmp(&self.at).then(other.seq.cmp(&self.seq))
    }
}

/// An event queue advancing a virtual clock. FIFO among simultaneous
/// events (stable by insertion order) so runs are fully deterministic.
#[derive(Debug)]
pub struct EventQueue<E: PartialEq> {
    heap: BinaryHeap<Scheduled<E>>,
    now: SimTime,
    seq: u64,
}

impl<E: PartialEq> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E: PartialEq> EventQueue<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), now: 0.0, seq: 0 }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` to fire `delay` seconds from now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        assert!(delay >= 0.0, "negative delay");
        self.schedule_at(self.now + delay, event);
    }

    /// Schedule `event` at absolute virtual time `at` (>= now).
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        assert!(at >= self.now, "cannot schedule in the past");
        self.heap.push(Scheduled { at, seq: self.seq, event });
        self.seq += 1;
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn next(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|s| {
            self.now = s.at;
            (s.at, s.event)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

/// A single-server FIFO resource on the virtual clock — models the GPU
/// executing server-side jobs *sequentially* (the core of the paper's
/// memory-efficient design).  `busy_until` is the queue's horizon.
#[derive(Debug, Clone, Default)]
pub struct SequentialResource {
    busy_until: SimTime,
    /// Total busy seconds (for utilization reporting).
    pub busy_time: SimTime,
    pub jobs: u64,
}

impl SequentialResource {
    /// Admit a job arriving at `arrival` needing `duration` seconds.
    /// Returns (start, finish). Eq. (11): start = max(arrival, horizon).
    pub fn admit(&mut self, arrival: SimTime, duration: SimTime) -> (SimTime, SimTime) {
        let start = arrival.max(self.busy_until);
        let finish = start + duration;
        self.busy_until = finish;
        self.busy_time += duration;
        self.jobs += 1;
        (start, finish)
    }

    pub fn horizon(&self) -> SimTime {
        self.busy_until
    }

    /// Reset the horizon (e.g., at a round boundary) keeping counters.
    pub fn reset_horizon(&mut self, to: SimTime) {
        self.busy_until = to;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_in(3.0, "c");
        q.schedule_in(1.0, "a");
        q.schedule_in(2.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn simultaneous_events_are_fifo() {
        let mut q = EventQueue::new();
        q.schedule_in(1.0, "first");
        q.schedule_in(1.0, "second");
        q.schedule_in(1.0, "third");
        let order: Vec<_> = std::iter::from_fn(|| q.next()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["first", "second", "third"]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_in(5.0, 1u32);
        q.schedule_in(2.0, 2u32);
        let mut last = 0.0;
        while let Some((t, _)) = q.next() {
            assert!(t >= last);
            last = t;
        }
        assert_eq!(q.now(), 5.0);
    }

    #[test]
    #[should_panic(expected = "cannot schedule in the past")]
    fn scheduling_in_the_past_panics() {
        let mut q = EventQueue::new();
        q.schedule_in(1.0, ());
        q.next();
        q.schedule_at(0.5, ());
    }

    #[test]
    fn sequential_resource_queues_jobs() {
        let mut r = SequentialResource::default();
        // Job A arrives at t=0 and runs 10s.
        let (s1, f1) = r.admit(0.0, 10.0);
        assert_eq!((s1, f1), (0.0, 10.0));
        // Job B arrives at t=2 but must wait for A — eq. (11).
        let (s2, f2) = r.admit(2.0, 5.0);
        assert_eq!((s2, f2), (10.0, 15.0));
        // Job C arrives after the queue drained: no waiting.
        let (s3, f3) = r.admit(20.0, 1.0);
        assert_eq!((s3, f3), (20.0, 21.0));
        assert_eq!(r.jobs, 3);
        assert!((r.busy_time - 16.0).abs() < 1e-12);
    }

    #[test]
    fn waiting_time_matches_eq11() {
        // With all arrivals at 0, client at position p waits sum of the
        // durations of the earlier clients — exactly eq. (11).
        let mut r = SequentialResource::default();
        let durations = [3.0, 5.0, 2.0, 7.0];
        let mut expected_wait = 0.0;
        for d in durations {
            let (start, _) = r.admit(0.0, d);
            assert!((start - expected_wait).abs() < 1e-12);
            expected_wait += d;
        }
    }
}
