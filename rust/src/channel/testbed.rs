//! Lossy-channel acceptance testbed: the contraction world from
//! [`crate::transport::testbed`] with the real [`LossyChannel`] spliced
//! between every client and the server.  Used by `benches/netfault.rs`
//! and the artifact-free acceptance tests for the netfault gate: with
//! retry + partial-cohort merging, a 10% loss / 2% corruption link must
//! recover ≥ 97% of clean quality with no honest client quarantined,
//! while the no-retry baseline visibly degrades.
//!
//! World model: the *mean* optimum `T` is all-ones, but each client `u`
//! contracts toward its own target `T + o_u` where the offsets `o_u`
//! are seeded and **centered** (`Σ_u o_u = 0`).  A full-cohort FedAvg
//! therefore converges to `T` exactly, while every excluded client
//! biases the fixed point toward the survivors' mean — so give-ups and
//! quarantines have a real, measurable quality cost instead of merely
//! shrinking the averaging set.  This is what makes the no-retry
//! baseline degrade: at `--tamper-threshold 1` a single benign
//! corrupted delivery (no retry to disambiguate) quarantines an honest
//! client permanently, and the fleet bias compounds.
//!
//! Every upload crosses the wire through the real transport codec
//! (seq-stamped header, FNV-1a trailer); corruption flips a real
//! payload bit via [`corrupt_wire`] and is caught by `Codec::verify`,
//! tampering is applied post-hash at encode (so retransmissions carry
//! it too — the signature that distinguishes it from benign noise).

use super::LossyChannel;
use crate::config::ChannelConfig;
use crate::lora::{fedavg_joined_into, AdapterSet};
use crate::model::ModelDims;
use crate::tensor::rng::Rng;
use crate::transport::{corrupt_wire, Codec, QuantKind};
use anyhow::Result;

/// Per-round contraction toward each client's target (see
/// [`crate::transport::testbed`] for why 0.05).
pub const ETA: f32 = 0.05;
/// Per-coordinate honest noise std.
pub const NOISE: f64 = 1e-4;
/// Per-coordinate std of the centered client-target offsets: large
/// enough that losing clients visibly biases the fixed point, small
/// enough that the clean run still converges to ≈ the noise floor.
pub const OFFSET: f64 = 0.15;

/// One channel configuration of the synthetic run.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub n: usize,
    pub rounds: usize,
    /// Channel dice: stationary drop probability per attempt.
    pub loss: f64,
    /// Per-delivery bit-corruption probability.
    pub corrupt: f64,
    /// Duplicate-copy probability (sequence-suppressed at the server).
    pub dup: f64,
    /// Stale-reordered-arrival probability (also sequence-suppressed).
    pub reorder: f64,
    /// Gilbert–Elliott P(stay Bad); 0 ⇒ independent losses.
    pub burst: f64,
    /// Retransmissions allowed after the first attempt (0 = no retry).
    pub retry_max: usize,
    /// Consecutive hash mismatches before a client is quarantined.
    pub tamper_threshold: usize,
    /// Clients `0..tamper` corrupt every payload post-hash (a real
    /// attacker: retransmissions fail verification too).
    pub tamper: usize,
    /// Transport knobs (the wire is always the real codec here).
    pub topk_frac: f64,
    pub quant: QuantKind,
    pub seed: u64,
}

impl Default for Scenario {
    fn default() -> Self {
        Self {
            n: 10,
            rounds: 200,
            loss: 0.0,
            corrupt: 0.0,
            dup: 0.0,
            reorder: 0.0,
            burst: 0.0,
            retry_max: 3,
            tamper_threshold: 1,
            tamper: 0,
            topk_frac: 0.05,
            quant: QuantKind::Q8,
            seed: 41,
        }
    }
}

/// What a scenario run produced.
#[derive(Debug, Clone, Copy)]
pub struct Outcome {
    /// `1 − min(1, final_dist / d0)`; 0 if the global went non-finite.
    pub quality: f64,
    pub final_dist: f64,
    pub d0: f64,
    /// Cumulative channel counters over the whole run.
    pub net: super::NetStats,
    /// Honest clients (`u ≥ tamper`) quarantined by the mismatch
    /// threshold — the gate requires exactly zero.
    pub quarantined_honest: usize,
    /// Tampering clients caught by the threshold.
    pub quarantined_tamper: usize,
}

fn dist(a: &AdapterSet, b: &AdapterSet) -> Result<f64> {
    let mut acc = 0.0f64;
    for (x, y) in a.tensors.iter().zip(b.tensors.iter()) {
        for (p, q) in x.as_f32()?.iter().zip(y.as_f32()?) {
            let d = (*p - *q) as f64;
            acc += d * d;
        }
    }
    Ok(acc.sqrt())
}

/// Run one scenario to completion and score it.
pub fn run(sc: &Scenario) -> Result<Outcome> {
    let dims = ModelDims::mini();
    let layers = dims.layers;
    let k = layers / 2;
    let mut truth = AdapterSet::zeros(&dims, layers);
    for t in truth.tensors.iter_mut() {
        t.as_f32_mut()?.fill(1.0);
    }
    let mut global = AdapterSet::zeros(&dims, layers);
    let d0 = dist(&global, &truth)?;
    let mut rng = Rng::new(sc.seed);
    // Centered per-client target offsets: draw, then subtract the
    // cross-client mean per coordinate so the full-fleet optimum is T.
    let mut offsets: Vec<AdapterSet> =
        (0..sc.n).map(|_| AdapterSet::zeros(&dims, layers)).collect();
    for i in 0..4 {
        let len = offsets[0].tensors[i].as_f32()?.len();
        for j in 0..len {
            let mut mean = 0.0f64;
            for o in offsets.iter_mut() {
                let v = OFFSET * rng.normal();
                o.tensors[i].as_f32_mut()?[j] = v as f32;
                mean += v;
            }
            let mean = (mean / sc.n as f64) as f32;
            for o in offsets.iter_mut() {
                o.tensors[i].as_f32_mut()?[j] -= mean;
            }
        }
    }
    let cfg = ChannelConfig {
        loss: sc.loss,
        corrupt: sc.corrupt,
        dup: sc.dup,
        reorder: sc.reorder,
        burst: sc.burst,
        retry_max: sc.retry_max,
        tamper_threshold: sc.tamper_threshold,
        ..ChannelConfig::default()
    };
    let mut ch = LossyChannel::new(&cfg, vec![1.0; sc.n], sc.seed);
    let mut codec = Codec::new(sc.topk_frac, sc.quant, false);
    let mut cs: Vec<AdapterSet> = (0..sc.n).map(|_| AdapterSet::zeros(&dims, k)).collect();
    let mut ss: Vec<AdapterSet> = (0..sc.n).map(|_| AdapterSet::zeros(&dims, layers - k)).collect();
    let mut decoded: Vec<AdapterSet> = (0..sc.n).map(|_| AdapterSet::zeros(&dims, k)).collect();
    let mut agg = AdapterSet::zeros(&dims, layers);
    let mut wire: Vec<u8> = Vec::new();
    let mut buf: Vec<u8> = Vec::new();
    let mut ok: Vec<bool> = vec![false; sc.n];
    let mut quarantined: Vec<bool> = vec![false; sc.n];

    for _round in 0..sc.rounds {
        for u in 0..sc.n {
            if quarantined[u] {
                continue;
            }
            for i in 0..4 {
                let inner: usize = global.tensors[i].shape[1..].iter().product();
                let b = global.tensors[i].as_f32()?;
                let t = truth.tensors[i].as_f32()?;
                let o = offsets[u].tensors[i].as_f32()?;
                let split = k * inner;
                for (j, x) in cs[u].tensors[i].as_f32_mut()?.iter_mut().enumerate() {
                    let tgt = t[j] + o[j];
                    *x = b[j] + ETA * (tgt - b[j]) + (NOISE * rng.normal()) as f32;
                }
                for (j, x) in ss[u].tensors[i].as_f32_mut()?.iter_mut().enumerate() {
                    let g = split + j;
                    let tgt = t[g] + o[g];
                    *x = b[g] + ETA * (tgt - b[g]) + (NOISE * rng.normal()) as f32;
                }
            }
        }
        codec.round_reset();
        for u in 0..sc.n {
            ok[u] = false;
            if quarantined[u] {
                continue;
            }
            // Encode once per upload; every retransmission re-sends the
            // same bytes under the same sequence number.
            let seq = ch.next_seq(u);
            codec.stage_seq(seq);
            if u < sc.tamper {
                codec.tamper_next(1);
            }
            {
                let (bv, _) = global.split_at_views(k)?;
                codec.stage_delta(&cs[u], &bv)?;
                let payload = codec.encode_staged(None)?;
                wire.clear();
                wire.extend_from_slice(payload);
            }
            let attempts = sc.retry_max + 1;
            for a in 0..attempts {
                let tx = ch.transmit(u);
                let mut failed = tx.dropped;
                if !failed {
                    buf.clear();
                    buf.extend_from_slice(&wire);
                    if tx.corrupted {
                        corrupt_wire(&mut buf, tx.corrupt_bit);
                    }
                    if !Codec::verify(&buf) {
                        // Hash mismatch: benign corruption retries; only
                        // threshold consecutive failures escalate.
                        let m = ch.note_mismatch(u) as usize;
                        if m >= sc.tamper_threshold {
                            quarantined[u] = true;
                        }
                        failed = true;
                    } else {
                        // A stale reordered arrival carries the previous
                        // sequence number; dup/stale copies never merge.
                        let eff = if tx.reordered { seq.wrapping_sub(1) } else { seq };
                        if ch.accept_seq(u, eff) {
                            ch.clear_mismatch(u);
                            let (bv, _) = global.split_at_views(k)?;
                            Codec::decode_into(&buf, &bv, &mut decoded[u])?;
                            ok[u] = true;
                        } else {
                            failed = true;
                        }
                    }
                }
                if ok[u] || quarantined[u] {
                    break;
                }
                if failed && a + 1 < attempts {
                    ch.note_retry();
                } else if failed {
                    ch.note_gave_up();
                }
            }
        }
        let active = quarantined.iter().filter(|&&q| !q).count();
        let mut subs: Vec<(f32, &AdapterSet, &AdapterSet)> = (0..sc.n)
            .filter(|&u| ok[u])
            .map(|u| (1.0f32, &decoded[u], &ss[u]))
            .collect();
        if subs.is_empty() {
            // Graceful degradation: an empty merge leaves the model
            // standing; the round simply produced no aggregate.
            continue;
        }
        if subs.len() < active {
            ch.note_partial_merge();
        }
        // Renormalize over the partial cohort.
        let w = 1.0 / subs.len() as f32;
        for sub in subs.iter_mut() {
            sub.0 = w;
        }
        fedavg_joined_into(&subs, &mut agg)?;
        drop(subs);
        for (g, a) in global.tensors.iter_mut().zip(agg.tensors.iter()) {
            g.as_f32_mut()?.copy_from_slice(a.as_f32()?);
        }
    }
    let final_dist = dist(&global, &truth)?;
    let quality =
        if final_dist.is_finite() { 1.0 - (final_dist / d0).min(1.0) } else { 0.0 };
    let quarantined_tamper = quarantined[..sc.tamper.min(sc.n)].iter().filter(|&&q| q).count();
    let quarantined_honest =
        quarantined[sc.tamper.min(sc.n)..].iter().filter(|&&q| q).count();
    Ok(Outcome {
        quality,
        final_dist,
        d0,
        net: ch.stats(),
        quarantined_honest,
        quarantined_tamper,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_channel_converges_and_counts_cleanly() {
        let out = run(&Scenario::default()).unwrap();
        assert!(out.quality > 0.99, "clean quality {} below noise floor", out.quality);
        let s = out.net;
        assert_eq!(s.sent, s.delivered, "zero loss must deliver every attempt");
        assert_eq!(s.dropped + s.corrupted + s.retries + s.gave_up + s.partial_merges, 0);
        assert_eq!(out.quarantined_honest + out.quarantined_tamper, 0);
    }

    #[test]
    fn gate_config_recovers_clean_quality() {
        let clean = run(&Scenario::default()).unwrap();
        let out = run(&Scenario {
            loss: 0.10,
            corrupt: 0.02,
            retry_max: 3,
            tamper_threshold: 4,
            ..Scenario::default()
        })
        .unwrap();
        assert!(
            out.quality >= 0.97 * clean.quality,
            "lossy quality {} below 97% of clean {}",
            out.quality,
            clean.quality
        );
        assert_eq!(out.quarantined_honest, 0, "benign corruption must never quarantine");
        assert!(out.net.retries > 0, "a 10% loss run must exercise retransmission");
        assert!(out.net.dropped > 0);
    }

    #[test]
    fn no_retry_baseline_degrades() {
        let with_retry = run(&Scenario {
            loss: 0.10,
            corrupt: 0.02,
            retry_max: 3,
            tamper_threshold: 4,
            ..Scenario::default()
        })
        .unwrap();
        let bare = run(&Scenario {
            loss: 0.10,
            corrupt: 0.02,
            retry_max: 0,
            tamper_threshold: 1,
            ..Scenario::default()
        })
        .unwrap();
        assert!(bare.net.gave_up > 0, "no-retry must give up on lost uploads");
        assert!(bare.net.partial_merges > 0, "no-retry must merge partial cohorts");
        assert!(
            bare.quarantined_honest > 0,
            "immediate-flag at threshold 1 must misfire on benign corruption"
        );
        assert!(
            bare.quality < with_retry.quality - 0.005,
            "no-retry quality {} must trail retry quality {}",
            bare.quality,
            with_retry.quality
        );
    }

    #[test]
    fn tamperers_are_quarantined_while_honest_corruption_is_retried() {
        let out = run(&Scenario {
            loss: 0.05,
            corrupt: 0.02,
            retry_max: 3,
            tamper_threshold: 3,
            tamper: 2,
            ..Scenario::default()
        })
        .unwrap();
        assert_eq!(out.quarantined_tamper, 2, "both tamperers must hit the threshold");
        assert_eq!(out.quarantined_honest, 0, "honest corruption must be retried, not flagged");
        // The 8 honest clients alone still converge (their offsets no
        // longer cancel exactly, so the bar is below the clean floor).
        assert!(out.quality > 0.9, "quality {} collapsed under tampering", out.quality);
    }

    #[test]
    fn dup_and_reorder_are_suppressed_not_merged_twice() {
        let clean = run(&Scenario::default()).unwrap();
        let out = run(&Scenario { dup: 0.2, reorder: 0.1, ..Scenario::default() }).unwrap();
        // Duplicate copies and reorder-retries cost traffic (> one
        // attempt per upload) but never correctness.
        assert!(out.net.sent > 2000, "sent {} should exceed n*rounds", out.net.sent);
        assert!(
            (out.quality - clean.quality).abs() < 0.02,
            "dup/reorder shifted quality: {} vs clean {}",
            out.quality,
            clean.quality
        );
        assert_eq!(out.net.gave_up, 0, "reordered copies must be re-sent within budget");
    }

    #[test]
    fn testbed_is_seed_deterministic() {
        let sc = Scenario {
            loss: 0.15,
            corrupt: 0.05,
            dup: 0.05,
            reorder: 0.05,
            burst: 0.5,
            rounds: 60,
            tamper_threshold: 4,
            ..Scenario::default()
        };
        let a = run(&sc).unwrap();
        let b = run(&sc).unwrap();
        assert_eq!(a.quality.to_bits(), b.quality.to_bits(), "same seed, same trajectory");
        assert_eq!(a.net, b.net);
        let c = run(&Scenario { seed: 42, ..sc }).unwrap();
        assert_ne!(
            (a.net.dropped, a.net.corrupted),
            (c.net.dropped, c.net.corrupted),
            "seed must matter"
        );
    }
}
