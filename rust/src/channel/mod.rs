//! Lossy uplink channel between clients and the edge server.
//!
//! The paper assumes every update exchange completes intact on the
//! first attempt; real mobile links (the WiFi/LTE/5G tiers in `net/`)
//! drop, duplicate, reorder, and corrupt packets.  This module models
//! that benign unreliability as a **seeded, checkpointable** process so
//! lossy runs are exactly reproducible and `--net-loss 0` stays
//! bit-identical to the reliable path (the channel draws from its own
//! RNG stream, independent of training/faults/committee):
//!
//! - per-attempt drop/corrupt/duplicate/reorder dice, scaled by the
//!   client's link tier ([`tier_mult`]: slow links fail more often);
//! - burst loss via a 2-state Gilbert–Elliott Markov chain per client
//!   (`--net-burst` = P(stay Bad); 0 ⇒ independent Bernoulli losses),
//!   parameterized so the stationary loss rate equals `--net-loss`;
//! - bounded retransmission with seeded exponential backoff + jitter
//!   ([`LossyChannel::rto`]);
//! - duplicate/stale suppression via per-client monotone sequence
//!   numbers stamped into the transport header
//!   ([`LossyChannel::next_seq`] / [`LossyChannel::accept_seq`]);
//! - consecutive hash-mismatch counters so the server can distinguish
//!   benign corruption (retry) from tampering (escalate to the
//!   committee once `--tamper-threshold` mismatches accumulate).
//!
//! The server-side retry/timeout/partial-merge machinery lives in
//! `coordinator::session`; [`testbed`] is the closed-form world used by
//! `benches/netfault.rs` and the artifact-free acceptance tests.

pub mod testbed;

use crate::config::ChannelConfig;
use crate::tensor::rng::Rng;
use anyhow::{bail, Result};

/// Channel RNG stream tag: `seed ^ CHANNEL_SEED_XOR` keeps the loss
/// dice independent from training, fault-injection, and committee
/// streams so enabling the channel never perturbs them.
pub const CHANNEL_SEED_XOR: u64 = 0xC4A2_2E17;

/// Per-round network counters, streamed in the `"net"` jsonl block.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Uplink transmission attempts (retries and duplicate copies count).
    pub sent: u64,
    /// Attempts that reached the server (corrupted arrivals included).
    pub delivered: u64,
    /// Attempts lost in flight.
    pub dropped: u64,
    /// Deliveries with at least one flipped payload bit.
    pub corrupted: u64,
    /// Retransmissions triggered by timeouts / failed verification.
    pub retries: u64,
    /// Clients that exhausted their retry budget this round.
    pub gave_up: u64,
    /// Merges that proceeded with a partial cohort.
    pub partial_merges: u64,
}

/// Outcome of one transmission attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Transmission {
    /// Lost in flight — nothing arrives.
    pub dropped: bool,
    /// Arrived with a flipped bit (`corrupt_bit` selects it).
    pub corrupted: bool,
    /// Raw draw for which payload bit flips; the caller reduces it
    /// modulo the hash-covered body size.
    pub corrupt_bit: u64,
    /// A second identical copy also arrives (sequence-suppressed).
    pub duplicated: bool,
    /// Arrived out of order — the copy carries a stale sequence number
    /// and must be rejected by [`LossyChannel::accept_seq`].
    pub reordered: bool,
}

impl Transmission {
    /// A clean first-try delivery (what `--net-loss 0` always yields).
    pub fn clean() -> Self {
        Self { dropped: false, corrupted: false, corrupt_bit: 0, duplicated: false, reordered: false }
    }
}

/// Failure-probability multiplier for a link tier: slower links see
/// proportionally more loss/corruption (products are clamped to [0, 1]
/// at draw time).
pub fn tier_mult(rate_mbps: f64) -> f64 {
    if rate_mbps < 50.0 {
        1.5
    } else if rate_mbps >= 200.0 {
        0.5
    } else {
        1.0
    }
}

/// Effective loss probabilities are clamped below 1 so the
/// Gilbert–Elliott transition math (`1 - loss` in a denominator) stays
/// finite and a client can always eventually get a packet through.
const MAX_EFF_LOSS: f64 = 0.99;

/// Backoff jitter is uniform in `[0, JITTER_FRAC)` of the base RTO.
const JITTER_FRAC: f64 = 0.5;

/// The seeded lossy channel shared by every client uplink.
///
/// All mutable state (RNG, Gilbert–Elliott chains, sequence counters,
/// mismatch counters, round stats) serializes to flat `u64` words for
/// bit-exact mid-retry checkpoint/resume.
#[derive(Debug, Clone)]
pub struct LossyChannel {
    // sflint:allow(checkpoint-coverage, rebuilt from config at load)
    cfg: ChannelConfig,
    /// Per-client failure-probability multiplier from the link tier.
    // sflint:allow(checkpoint-coverage, rebuilt from the fleet's links at load)
    tier: Vec<f64>,
    rng: Rng,
    /// Gilbert–Elliott chain state per client: true = Bad (bursting).
    ge_bad: Vec<bool>,
    /// Next uplink sequence number each client stamps (starts at 1).
    seq_next: Vec<u32>,
    /// Highest sequence number accepted per client (0 = none yet).
    seq_seen: Vec<u32>,
    /// Consecutive hash mismatches per client; reset on clean receipt.
    mismatch: Vec<u32>,
    stats: NetStats,
}

impl LossyChannel {
    /// `tier` holds one [`tier_mult`] per client; `seed` is the
    /// experiment seed (the stream tag is applied here).
    pub fn new(cfg: &ChannelConfig, tier: Vec<f64>, seed: u64) -> Self {
        let n = tier.len();
        Self {
            cfg: cfg.clone(),
            tier,
            rng: Rng::new(seed ^ CHANNEL_SEED_XOR),
            ge_bad: vec![false; n],
            seq_next: vec![1; n],
            seq_seen: vec![0; n],
            mismatch: vec![0; n],
            stats: NetStats::default(),
        }
    }

    pub fn n(&self) -> usize {
        self.tier.len()
    }

    fn mult(&self, u: usize) -> f64 {
        self.tier.get(u).copied().unwrap_or(1.0)
    }

    /// Roll the dice for one uplink attempt from client `u`.
    ///
    /// Draw order is fixed (loss → corrupt → dup → reorder, with an
    /// early return on drop) so trajectories are reproducible; each
    /// probability is scaled by the client's tier multiplier.
    pub fn transmit(&mut self, u: usize) -> Transmission {
        self.stats.sent += 1;
        let mult = self.mult(u);
        let loss = (self.cfg.loss * mult).clamp(0.0, MAX_EFF_LOSS);
        let dropped = if loss <= 0.0 {
            false
        } else if self.cfg.burst > 0.0 {
            // Gilbert–Elliott: Bad always drops, Good never.  With
            // B = P(stay Bad), good→bad = L(1-B)/(1-L) and bad→good =
            // 1-B give a stationary Bad (= loss) fraction of exactly L.
            let b = self.cfg.burst;
            let p_gb = (loss * (1.0 - b) / (1.0 - loss)).clamp(0.0, 1.0);
            let p_bg = 1.0 - b;
            let bad = if self.ge_bad[u] {
                self.rng.uniform() >= p_bg
            } else {
                self.rng.uniform() < p_gb
            };
            self.ge_bad[u] = bad;
            bad
        } else {
            self.rng.uniform() < loss
        };
        if dropped {
            self.stats.dropped += 1;
            return Transmission { dropped: true, ..Transmission::clean() };
        }
        let p_corrupt = (self.cfg.corrupt * mult).clamp(0.0, 1.0);
        let corrupted = p_corrupt > 0.0 && self.rng.uniform() < p_corrupt;
        let corrupt_bit = if corrupted { self.rng.next_u64() } else { 0 };
        let p_dup = (self.cfg.dup * mult).clamp(0.0, 1.0);
        let duplicated = p_dup > 0.0 && self.rng.uniform() < p_dup;
        let p_reorder = (self.cfg.reorder * mult).clamp(0.0, 1.0);
        let reordered = p_reorder > 0.0 && self.rng.uniform() < p_reorder;
        self.stats.delivered += 1;
        if duplicated {
            // The second copy traverses the link too.
            self.stats.sent += 1;
            self.stats.delivered += 1;
        }
        if corrupted {
            self.stats.corrupted += 1;
        }
        Transmission { dropped: false, corrupted, corrupt_bit, duplicated, reordered }
    }

    /// The sequence number client `u` stamps on its next upload.
    pub fn next_seq(&mut self, u: usize) -> u32 {
        let s = self.seq_next[u];
        self.seq_next[u] = s.wrapping_add(1);
        s
    }

    /// The sequence number of client `u`'s most recent upload — the
    /// one a retransmission re-sends.  Meaningful only after at least
    /// one [`LossyChannel::next_seq`] draw for `u`.
    pub fn current_seq(&self, u: usize) -> u32 {
        self.seq_next[u].wrapping_sub(1)
    }

    /// Accept `seq` from client `u` iff it is strictly newer than
    /// anything already accepted — duplicates and reordered stale
    /// copies return false and must not reach the merge.
    pub fn accept_seq(&mut self, u: usize, seq: u32) -> bool {
        if seq > self.seq_seen[u] {
            self.seq_seen[u] = seq;
            true
        } else {
            false
        }
    }

    /// Retransmission timeout for the given (0-based) attempt number:
    /// `retry_base · rto_mult^attempt · (1 + jitter)`, jitter seeded.
    pub fn rto(&mut self, attempt: u32) -> f64 {
        let base = self.cfg.retry_base * self.cfg.rto_mult.powi(attempt as i32);
        base * (1.0 + JITTER_FRAC * self.rng.uniform())
    }

    /// Record a hash mismatch from client `u`; returns the consecutive
    /// count (≥ `tamper_threshold` ⇒ escalate to the committee).
    pub fn note_mismatch(&mut self, u: usize) -> u32 {
        self.mismatch[u] = self.mismatch[u].saturating_add(1);
        self.mismatch[u]
    }

    /// A verified payload arrived from `u` — benign corruption over.
    pub fn clear_mismatch(&mut self, u: usize) {
        self.mismatch[u] = 0;
    }

    pub fn note_retry(&mut self) {
        self.stats.retries += 1;
    }

    pub fn note_gave_up(&mut self) {
        self.stats.gave_up += 1;
    }

    pub fn note_partial_merge(&mut self) {
        self.stats.partial_merges += 1;
    }

    pub fn stats(&self) -> NetStats {
        self.stats
    }

    /// Zero the per-round counters (sequence/chain state persists).
    pub fn round_reset(&mut self) {
        self.stats = NetStats::default();
    }

    /// Flat `u64` words: RNG state, n, per-client chain/sequence/
    /// mismatch state, then the in-flight round counters (so a
    /// mid-retry checkpoint reproduces the same jsonl block).
    pub fn state(&self) -> Vec<u64> {
        let n = self.tier.len();
        let mut w = Vec::with_capacity(2 + 4 * n + 7);
        w.push(self.rng.state());
        w.push(n as u64);
        for u in 0..n {
            w.push(u64::from(self.ge_bad[u]));
            w.push(u64::from(self.seq_next[u]));
            w.push(u64::from(self.seq_seen[u]));
            w.push(u64::from(self.mismatch[u]));
        }
        let s = &self.stats;
        w.extend([
            s.sent,
            s.delivered,
            s.dropped,
            s.corrupted,
            s.retries,
            s.gave_up,
            s.partial_merges,
        ]);
        w
    }

    /// Inverse of [`LossyChannel::state`].
    pub fn restore_state(&mut self, words: &[u64]) -> Result<()> {
        let n = self.tier.len();
        if words.len() != 2 + 4 * n + 7 {
            bail!("channel state has {} words, expected {}", words.len(), 2 + 4 * n + 7);
        }
        if words[1] as usize != n {
            bail!("channel state is for {} clients, fleet has {n}", words[1]);
        }
        self.rng = Rng::from_state(words[0]);
        for u in 0..n {
            let at = 2 + 4 * u;
            self.ge_bad[u] = words[at] != 0;
            self.seq_next[u] = words[at + 1] as u32;
            self.seq_seen[u] = words[at + 2] as u32;
            self.mismatch[u] = words[at + 3] as u32;
        }
        let at = 2 + 4 * n;
        self.stats = NetStats {
            sent: words[at],
            delivered: words[at + 1],
            dropped: words[at + 2],
            corrupted: words[at + 3],
            retries: words[at + 4],
            gave_up: words[at + 5],
            partial_merges: words[at + 6],
        };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(loss: f64, burst: f64) -> ChannelConfig {
        ChannelConfig { loss, burst, ..ChannelConfig::default() }
    }

    fn chan(c: &ChannelConfig, n: usize, seed: u64) -> LossyChannel {
        LossyChannel::new(c, vec![1.0; n], seed)
    }

    #[test]
    fn zero_loss_delivers_everything_clean() {
        let mut ch = chan(&cfg(0.0, 0.0), 4, 7);
        for _ in 0..200 {
            for u in 0..4 {
                assert_eq!(ch.transmit(u), Transmission::clean());
            }
        }
        let s = ch.stats();
        assert_eq!(s.sent, 800);
        assert_eq!(s.delivered, 800);
        assert_eq!(s.dropped + s.corrupted, 0);
    }

    #[test]
    fn iid_loss_rate_matches_config() {
        let mut ch = chan(&cfg(0.2, 0.0), 1, 11);
        let n = 20_000;
        let mut dropped = 0;
        for _ in 0..n {
            if ch.transmit(0).dropped {
                dropped += 1;
            }
        }
        let rate = dropped as f64 / n as f64;
        assert!((rate - 0.2).abs() < 0.02, "iid loss rate {rate}");
    }

    #[test]
    fn gilbert_elliott_stationary_loss_matches_config_and_bursts() {
        let mut ch = chan(&cfg(0.2, 0.8), 1, 13);
        let n = 50_000;
        let mut dropped = 0;
        let mut runs = 0;
        let mut prev = false;
        for _ in 0..n {
            let d = ch.transmit(0).dropped;
            if d {
                dropped += 1;
                if !prev {
                    runs += 1;
                }
            }
            prev = d;
        }
        let rate = dropped as f64 / n as f64;
        assert!((rate - 0.2).abs() < 0.03, "GE stationary loss {rate}");
        // Burstiness: mean loss-run length must be ≈ 1/(1-B) = 5, far
        // above the iid value of 1/(1-L) = 1.25.
        let mean_run = dropped as f64 / runs as f64;
        assert!(mean_run > 3.0, "mean loss-run length {mean_run} not bursty");
    }

    #[test]
    fn tier_multiplier_scales_loss() {
        let c = cfg(0.1, 0.0);
        let mut slow = LossyChannel::new(&c, vec![tier_mult(35.0)], 17);
        let mut fast = LossyChannel::new(&c, vec![tier_mult(300.0)], 17);
        let n = 20_000;
        let (mut ds, mut df) = (0, 0);
        for _ in 0..n {
            ds += u32::from(slow.transmit(0).dropped);
            df += u32::from(fast.transmit(0).dropped);
        }
        let (rs, rf) = (ds as f64 / n as f64, df as f64 / n as f64);
        assert!((rs - 0.15).abs() < 0.02, "lte-tier loss {rs}");
        assert!((rf - 0.05).abs() < 0.02, "5g-tier loss {rf}");
    }

    #[test]
    fn sequence_suppression_is_monotone() {
        let mut ch = chan(&cfg(0.0, 0.0), 2, 1);
        let s1 = ch.next_seq(0);
        assert_eq!(s1, 1);
        assert!(ch.accept_seq(0, s1));
        assert!(!ch.accept_seq(0, s1), "duplicate must be suppressed");
        let s2 = ch.next_seq(0);
        assert!(ch.accept_seq(0, s2));
        assert!(!ch.accept_seq(0, s1), "stale reordered copy must be suppressed");
        // Client 1's stream is independent.
        let t1 = ch.next_seq(1);
        assert!(ch.accept_seq(1, t1));
    }

    #[test]
    fn rto_grows_exponentially_with_bounded_jitter() {
        let c = ChannelConfig { retry_base: 0.5, rto_mult: 2.0, ..ChannelConfig::default() };
        let mut ch = LossyChannel::new(&c, vec![1.0], 3);
        for attempt in 0..4u32 {
            let base = 0.5 * 2.0f64.powi(attempt as i32);
            let rto = ch.rto(attempt);
            assert!(rto >= base && rto < base * 1.5, "attempt {attempt}: rto {rto}");
        }
    }

    #[test]
    fn mismatch_counter_accumulates_and_clears() {
        let mut ch = chan(&cfg(0.0, 0.0), 1, 5);
        assert_eq!(ch.note_mismatch(0), 1);
        assert_eq!(ch.note_mismatch(0), 2);
        ch.clear_mismatch(0);
        assert_eq!(ch.note_mismatch(0), 1);
    }

    #[test]
    fn state_roundtrip_continues_exact_stream() {
        let c = ChannelConfig { loss: 0.3, corrupt: 0.1, dup: 0.05, burst: 0.5, ..Default::default() };
        let mut a = chan(&c, 3, 99);
        for i in 0..57 {
            a.transmit(i % 3);
            a.next_seq(i % 3);
        }
        a.note_mismatch(1);
        let words = a.state();
        let mut b = chan(&c, 3, 99);
        b.restore_state(&words).unwrap();
        for i in 0..100 {
            assert_eq!(a.transmit(i % 3), b.transmit(i % 3), "attempt {i}");
            assert_eq!(a.rto((i % 5) as u32).to_bits(), b.rto((i % 5) as u32).to_bits());
        }
        assert_eq!(a.stats(), b.stats());
        assert!(b.restore_state(&words[..3]).is_err(), "truncated state must be rejected");
    }

    #[test]
    fn seeded_determinism_and_seed_sensitivity() {
        let c = cfg(0.25, 0.4);
        let mut a = chan(&c, 2, 41);
        let mut b = chan(&c, 2, 41);
        let mut other = chan(&c, 2, 42);
        let mut diverged = false;
        for i in 0..500 {
            let u = i % 2;
            assert_eq!(a.transmit(u), b.transmit(u));
            if a.stats().dropped != {
                other.transmit(u);
                other.stats().dropped
            } {
                diverged = true;
            }
        }
        assert!(diverged, "different seeds must yield different loss patterns");
    }

    #[test]
    fn tier_mult_bands() {
        assert!((tier_mult(35.0) - 1.5).abs() < 1e-12);
        assert!((tier_mult(100.0) - 1.0).abs() < 1e-12);
        assert!((tier_mult(300.0) - 0.5).abs() < 1e-12);
    }
}
