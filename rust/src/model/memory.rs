//! Analytic memory accountant — reproduces Table I's "Memory Consumption"
//! column for each scheme.
//!
//! Calibration (DESIGN.md §2): parameters are fp32 (4 B each); stored
//! activations for backward are `6m + 2f` floats per token per trained
//! layer (inputs to each matmul + the two FFN intermediates), which puts
//! BERT-base at batch 16 / seq 128 within ~5% of the paper's measured
//! numbers for all three schemes:
//!
//!   SL   paper 1346.85 MB  |  model ≈ 1.41 GB-ish band
//!   SFL  paper 7327.90 MB  |  ≈ 5x ours (Σ per-client submodels + acts)
//!   Ours paper 1482.63 MB  |  one full model + one act set + U LoRA states
//!
//! The *orderings and ratios* (SFL ≈ 5x ours; ours ≈ SL + 10%) are the
//! paper's claims and are asserted in tests; absolute MBs are testbed-
//! dependent.

use super::ModelDims;

const BYTES_F32: f64 = 4.0;
const MB: f64 = 1024.0 * 1024.0;

/// Server-side memory breakdown (bytes) for one scheme configuration.
#[derive(Debug, Clone, Default)]
pub struct MemoryBreakdown {
    pub model_params: f64,
    pub activations: f64,
    pub lora_states: f64,
    pub buffers: f64,
}

impl MemoryBreakdown {
    pub fn total_bytes(&self) -> f64 {
        self.model_params + self.activations + self.lora_states + self.buffers
    }

    pub fn total_mb(&self) -> f64 {
        self.total_bytes() / MB
    }
}

/// Stored-activation bytes for training `layers` transformer layers on one
/// mini-batch (the backward-pass residency).
pub fn activation_bytes(d: &ModelDims, layers: usize) -> f64 {
    let per_token_floats = (6 * d.hidden + 2 * d.ffn) as f64;
    layers as f64 * d.tokens_per_batch() as f64 * per_token_floats * BYTES_F32
}

/// LoRA optimizer state for `k` adapted layers (+ optionally the head):
/// param + grad + Adam m + Adam v = 4 copies.
pub fn lora_state_bytes(d: &ModelDims, layers: usize, with_head: bool) -> f64 {
    let mut p = layers * d.lora_params_per_layer();
    if with_head {
        p += d.head_params();
    }
    4.0 * p as f64 * BYTES_F32
}

fn server_layers(d: &ModelDims, cut: usize) -> usize {
    d.layers - cut
}

/// **Ours** (paper §III): ONE full model, per-client LoRA states, and —
/// because the server trains clients *sequentially* — a single activation
/// set sized for the deepest server-side portion, plus one in-flight
/// activation receive buffer per client.
pub fn ours_server_memory(d: &ModelDims, cuts: &[usize]) -> MemoryBreakdown {
    let max_server_layers = cuts.iter().map(|&k| server_layers(d, k)).max().unwrap_or(0);
    MemoryBreakdown {
        model_params: d.total_params() as f64 * BYTES_F32,
        activations: activation_bytes(d, max_server_layers),
        lora_states: cuts
            .iter()
            .map(|&k| lora_state_bytes(d, server_layers(d, k), true))
            .sum(),
        buffers: cuts.len() as f64 * d.activation_bytes() as f64,
    }
}

/// **SFL** (FedBERT-style, paper §I/§V baselines): the server keeps U
/// *separate* server-side submodels and trains them in parallel — U
/// model copies, U live activation sets, U LoRA states.  Parallel
/// multi-model execution also fragments the allocator; the paper points
/// at memory-access competition, we model it as a small overhead factor.
pub fn sfl_server_memory(d: &ModelDims, cuts: &[usize]) -> MemoryBreakdown {
    const FRAGMENTATION: f64 = 1.05;
    let mut model = 0.0;
    let mut acts = 0.0;
    let mut lora = 0.0;
    for &k in cuts {
        let sl = server_layers(d, k);
        model += (sl * d.layer_params() + d.head_params()) as f64 * BYTES_F32;
        acts += activation_bytes(d, sl);
        lora += lora_state_bytes(d, sl, true);
    }
    MemoryBreakdown {
        model_params: model * FRAGMENTATION,
        activations: acts * FRAGMENTATION,
        lora_states: lora,
        buffers: cuts.len() as f64 * d.activation_bytes() as f64,
    }
}

/// **SL** (sequential split learning): one client at a time, so one
/// server-side submodel (sized for the deepest cut) and one activation
/// set; a relay buffer holds the client model handed to the next client.
pub fn sl_server_memory(d: &ModelDims, cuts: &[usize]) -> MemoryBreakdown {
    let max_server_layers = cuts.iter().map(|&k| server_layers(d, k)).max().unwrap_or(0);
    let max_cut = cuts.iter().copied().max().unwrap_or(0);
    let client_model =
        (d.embedding_params() + max_cut * d.layer_params()) as f64 * BYTES_F32;
    MemoryBreakdown {
        model_params: (max_server_layers * d.layer_params() + d.head_params()) as f64
            * BYTES_F32,
        activations: activation_bytes(d, max_server_layers),
        lora_states: lora_state_bytes(d, max_server_layers, true),
        buffers: client_model + d.activation_bytes() as f64,
    }
}

/// **Ours + state pool**: identical to [`ours_server_memory`] except
/// only the pool-resident clients hold LoRA/optimizer state and an
/// in-flight receive buffer — the model copy and the deepest-cut
/// activation set are fleet-shape properties and stay.  `cuts` is the
/// whole fleet (sizes the shared activation set); `resident_cuts` is
/// the currently resident subset.  With `resident_cuts == cuts` this
/// degenerates to the eager accountant exactly.
pub fn pooled_server_memory(
    d: &ModelDims,
    cuts: &[usize],
    resident_cuts: &[usize],
) -> MemoryBreakdown {
    let max_server_layers = cuts.iter().map(|&k| server_layers(d, k)).max().unwrap_or(0);
    MemoryBreakdown {
        model_params: d.total_params() as f64 * BYTES_F32,
        activations: activation_bytes(d, max_server_layers),
        lora_states: resident_cuts
            .iter()
            .map(|&k| lora_state_bytes(d, server_layers(d, k), true))
            .sum(),
        buffers: resident_cuts.len() as f64 * d.activation_bytes() as f64,
    }
}

/// Client-side memory for a device holding `k` layers (used by the split
/// selector to match submodels to device budgets).
pub fn client_memory(d: &ModelDims, k: usize) -> MemoryBreakdown {
    MemoryBreakdown {
        model_params: (d.embedding_params() + k * d.layer_params()) as f64 * BYTES_F32,
        // client_backward rematerializes: peak residency is one layer's
        // activations plus the cut tensor.
        activations: activation_bytes(d, 1) + d.activation_bytes() as f64,
        lora_states: lora_state_bytes(d, k, false),
        buffers: 2.0 * d.activation_bytes() as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_cuts() -> Vec<usize> {
        vec![1, 1, 2, 2, 3, 3]
    }

    #[test]
    fn table1_orderings_hold_for_bert_base() {
        let d = ModelDims::bert_base();
        let cuts = paper_cuts();
        let ours = ours_server_memory(&d, &cuts).total_mb();
        let sfl = sfl_server_memory(&d, &cuts).total_mb();
        let sl = sl_server_memory(&d, &cuts).total_mb();
        assert!(sl < ours, "SL ({sl:.0}) must be < Ours ({ours:.0})");
        assert!(ours < sfl, "Ours ({ours:.0}) must be < SFL ({sfl:.0})");
    }

    #[test]
    fn table1_ratios_match_paper_shape() {
        let d = ModelDims::bert_base();
        let cuts = paper_cuts();
        let ours = ours_server_memory(&d, &cuts).total_mb();
        let sfl = sfl_server_memory(&d, &cuts).total_mb();
        let sl = sl_server_memory(&d, &cuts).total_mb();
        // Paper: ours reduces 79% vs SFL => sfl/ours ≈ 4.9; and ours is
        // ~10% above SL. Allow generous bands — shape, not absolutes.
        let r1 = sfl / ours;
        assert!((3.0..7.0).contains(&r1), "sfl/ours = {r1:.2}");
        let r2 = ours / sl;
        assert!((1.0..1.35).contains(&r2), "ours/sl = {r2:.2}");
    }

    #[test]
    fn absolute_mb_in_paper_ballpark() {
        let d = ModelDims::bert_base();
        let cuts = paper_cuts();
        let ours = ours_server_memory(&d, &cuts).total_mb();
        let sfl = sfl_server_memory(&d, &cuts).total_mb();
        let sl = sl_server_memory(&d, &cuts).total_mb();
        // Within ~35% of Table I's measured MBs.
        assert!((900.0..1900.0).contains(&sl), "SL = {sl:.1} MB");
        assert!((4800.0..9900.0).contains(&sfl), "SFL = {sfl:.1} MB");
        assert!((1000.0..2100.0).contains(&ours), "Ours = {ours:.1} MB");
    }

    #[test]
    fn deeper_client_cuts_shrink_server_memory_in_sfl() {
        let d = ModelDims::bert_base();
        let shallow = sfl_server_memory(&d, &[1, 1, 1, 1, 1, 1]).total_mb();
        let deep = sfl_server_memory(&d, &[3, 3, 3, 3, 3, 3]).total_mb();
        assert!(deep < shallow);
    }

    #[test]
    fn ours_memory_nearly_flat_in_client_count() {
        // The headline scalability claim: adding clients adds only LoRA
        // state + a receive buffer, never model or activation copies.
        let d = ModelDims::bert_base();
        let six = ours_server_memory(&d, &[1, 1, 2, 2, 3, 3]).total_mb();
        let twelve = ours_server_memory(&d, &[1, 1, 2, 2, 3, 3, 1, 1, 2, 2, 3, 3]).total_mb();
        let growth = twelve / six;
        assert!(growth < 1.25, "doubling clients grew memory {growth:.2}x");
        // while SFL roughly doubles:
        let sfl6 = sfl_server_memory(&d, &[1, 1, 2, 2, 3, 3]).total_mb();
        let sfl12 =
            sfl_server_memory(&d, &[1, 1, 2, 2, 3, 3, 1, 1, 2, 2, 3, 3]).total_mb();
        assert!(sfl12 / sfl6 > 1.8);
    }

    #[test]
    fn pooled_accountant_degenerates_to_eager_and_scales_with_residency() {
        let d = ModelDims::bert_base();
        let cuts = paper_cuts();
        let eager = ours_server_memory(&d, &cuts);
        let full = pooled_server_memory(&d, &cuts, &cuts);
        assert_eq!(full.total_bytes().to_bits(), eager.total_bytes().to_bits());
        // Fewer residents shrink only the per-client terms.
        let two = pooled_server_memory(&d, &cuts, &cuts[..2]);
        assert_eq!(two.model_params.to_bits(), eager.model_params.to_bits());
        assert_eq!(two.activations.to_bits(), eager.activations.to_bits());
        assert!(two.lora_states < eager.lora_states);
        assert!(two.buffers < eager.buffers);
    }

    #[test]
    fn pooled_client_state_is_o_active_not_o_fleet() {
        // The acceptance shape: 10k-client fleet, 32 resident — the
        // resident client-state bytes must be well under 5% of eager's.
        let d = ModelDims::bert_base();
        let fleet: Vec<usize> = (0..10_000).map(|u| [1, 2, 3][u % 3]).collect();
        let resident: Vec<usize> = fleet[..32].to_vec();
        let eager = ours_server_memory(&d, &fleet);
        let pooled = pooled_server_memory(&d, &fleet, &resident);
        assert!(
            pooled.lora_states * 20.0 <= eager.lora_states,
            "pooled {} vs eager {}",
            pooled.lora_states,
            eager.lora_states
        );
        assert!(pooled.buffers * 20.0 <= eager.buffers);
    }

    #[test]
    fn client_memory_grows_with_cut() {
        let d = ModelDims::bert_base();
        let m1 = client_memory(&d, 1).total_mb();
        let m3 = client_memory(&d, 3).total_mb();
        assert!(m3 > m1);
    }

    #[test]
    fn breakdown_total_is_sum_of_parts() {
        let b = MemoryBreakdown {
            model_params: 1.0,
            activations: 2.0,
            lora_states: 3.0,
            buffers: 4.0,
        };
        assert!((b.total_bytes() - 10.0).abs() < 1e-9);
    }
}
