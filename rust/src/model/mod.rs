//! Model dimension descriptors + analytic FLOPs model.
//!
//! The paper's timing results (Table I, Fig. 2) are functions of the
//! *compute cost* of each submodel on each device.  This module derives
//! those costs analytically from the transformer dimensions, mirroring
//! the configs in `python/compile/configs.py` (the `base` entry is the
//! paper's BERT-base).  The numeric artifacts use the same dims, so the
//! analytic and executed models always agree structurally.

pub mod memory;


/// Transformer dimensions (one-to-one with python ModelConfig).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDims {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub seq: usize,
    pub classes: usize,
    pub rank: usize,
    pub alpha: f64,
    pub batch: usize,
    pub cuts: Vec<usize>,
}

impl ModelDims {
    /// The paper's BERT-base evaluation setting (§V-A).
    pub fn bert_base() -> Self {
        Self {
            name: "base".into(),
            vocab: 30522,
            hidden: 768,
            layers: 12,
            heads: 12,
            ffn: 3072,
            seq: 128,
            classes: 6,
            rank: 16,
            alpha: 32.0,
            batch: 16,
            cuts: vec![1, 2, 3],
        }
    }

    /// Scaled config matching python `small` (numerically executed).
    pub fn small() -> Self {
        Self {
            name: "small".into(),
            vocab: 2048,
            hidden: 128,
            layers: 6,
            heads: 4,
            ffn: 512,
            seq: 64,
            classes: 6,
            rank: 16,
            alpha: 32.0,
            batch: 16,
            cuts: vec![1, 2, 3],
        }
    }

    /// Scaled config matching python `mini` (fast tests/benches).
    pub fn mini() -> Self {
        Self {
            name: "mini".into(),
            vocab: 1024,
            hidden: 64,
            layers: 4,
            heads: 2,
            ffn: 256,
            seq: 32,
            classes: 6,
            rank: 8,
            alpha: 16.0,
            batch: 8,
            cuts: vec![1, 2, 3],
        }
    }

    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.seq
    }

    /// Frozen parameters in one transformer layer.
    pub fn layer_params(&self) -> usize {
        let m = self.hidden;
        let f = self.ffn;
        // 4 projections + biases, 2 LN pairs, 2 FFN mats + biases.
        4 * (m * m + m) + 4 * m + 2 * (m * f) + f + m
    }

    /// Embedding-block parameters (token + position + LN).
    pub fn embedding_params(&self) -> usize {
        self.vocab * self.hidden + self.seq * self.hidden + 2 * self.hidden
    }

    /// Classifier head parameters.
    pub fn head_params(&self) -> usize {
        self.hidden * self.classes + self.classes
    }

    /// Full frozen model parameter count.
    pub fn total_params(&self) -> usize {
        self.embedding_params() + self.layers * self.layer_params() + self.head_params()
    }

    /// LoRA parameters per adapted layer (A+B on Q and V projections).
    pub fn lora_params_per_layer(&self) -> usize {
        4 * self.rank * self.hidden
    }

    /// Number of trainable LoRA adapter modules per layer (paper counts
    /// each (A, B) pair as one adapter; we adapt Q and V).
    pub const ADAPTERS_PER_LAYER: usize = 2;

    // ------------------------------------------------------------------
    // FLOPs model (per mini-batch). Forward; backward ≈ 2x forward.
    // ------------------------------------------------------------------

    /// Forward FLOPs for one transformer layer on one mini-batch.
    pub fn layer_fwd_flops(&self) -> f64 {
        let t = self.tokens_per_batch() as f64;
        let m = self.hidden as f64;
        let f = self.ffn as f64;
        let l = self.seq as f64;
        let r = self.rank as f64;
        let proj = 4.0 * 2.0 * t * m * m; // Q,K,V,O
        let attn = 2.0 * 2.0 * t * l * m; // scores + PV
        let ffn = 2.0 * 2.0 * t * m * f;
        let lora = 2.0 * (2.0 * t * r * m * 2.0); // Q and V adapters (down+up)
        proj + attn + ffn + lora
    }

    /// Forward FLOPs for the embedding block (gather is cheap; LN dominates).
    pub fn embedding_fwd_flops(&self) -> f64 {
        8.0 * self.tokens_per_batch() as f64 * self.hidden as f64
    }

    /// Forward FLOPs for the classifier head.
    pub fn head_fwd_flops(&self) -> f64 {
        2.0 * self.batch as f64 * self.hidden as f64 * self.classes as f64
    }

    /// Client-side forward FLOPs at cut `k` (embedding + k layers). Eq. (3).
    pub fn client_fwd_flops(&self, k: usize) -> f64 {
        self.embedding_fwd_flops() + k as f64 * self.layer_fwd_flops()
    }

    /// Client-side backward FLOPs at cut `k` (≈ 2x fwd, plus the
    /// rematerialized forward the client runs — see model.py docstring).
    pub fn client_bwd_flops(&self, k: usize) -> f64 {
        3.0 * self.client_fwd_flops(k)
    }

    /// Server-side fwd+bwd FLOPs at cut `k` (layers k..N + head). Eq. (4).
    pub fn server_flops(&self, k: usize) -> f64 {
        let fwd = (self.layers - k) as f64 * self.layer_fwd_flops() + self.head_fwd_flops();
        3.0 * fwd
    }

    /// Full-model training-step FLOPs (the SL client+server total).
    pub fn full_step_flops(&self) -> f64 {
        3.0 * (self.embedding_fwd_flops()
            + self.layers as f64 * self.layer_fwd_flops()
            + self.head_fwd_flops())
    }

    // ------------------------------------------------------------------
    // Wire sizes (bytes) for the protocol messages.
    // ------------------------------------------------------------------

    /// Activation tensor at the split layer: [B, L, m] f32. Same size for
    /// its gradient (the paper notes gradient size equals activation size).
    pub fn activation_bytes(&self) -> usize {
        self.batch * self.seq * self.hidden * 4
    }

    /// One client's LoRA adapter upload for `k` adapted layers.
    pub fn lora_bytes(&self, k: usize) -> usize {
        k * self.lora_params_per_layer() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_base_param_count_is_bertlike() {
        let d = ModelDims::bert_base();
        let p = d.total_params();
        // BERT-base is ~110M params; our variant (no pooler/token-type,
        // learned positions to seq=128) should land in 85–115M.
        assert!(p > 85_000_000 && p < 115_000_000, "params = {p}");
    }

    #[test]
    fn layer_params_match_formula() {
        let d = ModelDims::mini();
        let m = 64usize;
        let f = 256usize;
        let expect = 4 * (m * m + m) + 4 * m + 2 * m * f + f + m;
        assert_eq!(d.layer_params(), expect);
    }

    #[test]
    fn server_plus_client_covers_full_model_flops() {
        let d = ModelDims::bert_base();
        for &k in &[1usize, 2, 3] {
            let split = d.client_fwd_flops(k) * 3.0 + d.server_flops(k);
            let full = d.full_step_flops();
            let ratio = split / full;
            assert!((0.99..1.01).contains(&ratio), "k={k} ratio={ratio}");
        }
    }

    #[test]
    fn server_flops_decrease_with_cut() {
        let d = ModelDims::bert_base();
        assert!(d.server_flops(1) > d.server_flops(2));
        assert!(d.server_flops(2) > d.server_flops(3));
    }

    #[test]
    fn activation_bytes_paper_setting() {
        let d = ModelDims::bert_base();
        // 16 * 128 * 768 * 4 = 6.29 MB
        assert_eq!(d.activation_bytes(), 16 * 128 * 768 * 4);
    }

    #[test]
    fn lora_bytes_scale_with_cut() {
        let d = ModelDims::bert_base();
        assert_eq!(d.lora_bytes(2), 2 * d.lora_bytes(1));
    }

    #[test]
    fn configs_match_python_side() {
        // Guard: these dims must mirror python/compile/configs.py.
        let s = ModelDims::small();
        assert_eq!((s.vocab, s.hidden, s.layers, s.heads), (2048, 128, 6, 4));
        assert_eq!((s.ffn, s.seq, s.rank, s.batch), (512, 64, 16, 16));
        let m = ModelDims::mini();
        assert_eq!((m.vocab, m.hidden, m.layers, m.heads), (1024, 64, 4, 2));
    }
}
