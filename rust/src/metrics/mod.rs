//! Evaluation metrics: accuracy, macro-F1 (the paper reports both),
//! loss tracking, and the convergence detector used for Table I's
//! "Convergence Round / Convergence Time" columns.

/// Confusion matrix over `classes` labels.
#[derive(Debug, Clone)]
pub struct Confusion {
    classes: usize,
    /// counts[truth][pred]
    counts: Vec<Vec<usize>>,
}

impl Confusion {
    pub fn new(classes: usize) -> Self {
        Self { classes, counts: vec![vec![0; classes]; classes] }
    }

    pub fn record(&mut self, truth: usize, pred: usize) {
        assert!(truth < self.classes && pred < self.classes);
        self.counts[truth][pred] += 1;
    }

    /// Record a batch from logits laid out [B, C] row-major.
    pub fn record_logits(&mut self, logits: &[f32], labels: &[i32]) {
        let c = self.classes;
        assert_eq!(logits.len(), labels.len() * c);
        for (i, &lab) in labels.iter().enumerate() {
            let row = &logits[i * c..(i + 1) * c];
            // Argmax with total_cmp; >= keeps the last maximum, matching
            // Iterator::max_by's tie behavior.
            let mut pred = 0usize;
            for (j, v) in row.iter().enumerate().skip(1) {
                if v.total_cmp(&row[pred]).is_ge() {
                    pred = j;
                }
            }
            self.record(lab as usize, pred);
        }
    }

    pub fn total(&self) -> usize {
        self.counts.iter().map(|r| r.iter().sum::<usize>()).sum()
    }

    pub fn accuracy(&self) -> f64 {
        let correct: usize = (0..self.classes).map(|i| self.counts[i][i]).sum();
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        correct as f64 / total as f64
    }

    /// Macro-averaged F1 over classes that appear in truth or predictions
    /// (absent classes are skipped, matching sklearn's behaviour on
    /// undefined precision/recall with zero_division elision).
    pub fn macro_f1(&self) -> f64 {
        let mut f1s = Vec::new();
        for c in 0..self.classes {
            let tp = self.counts[c][c];
            let truth: usize = self.counts[c].iter().sum();
            let pred: usize = (0..self.classes).map(|i| self.counts[i][c]).sum();
            if truth == 0 && pred == 0 {
                continue;
            }
            let f1 = if tp == 0 {
                0.0
            } else {
                let p = tp as f64 / pred as f64;
                let r = tp as f64 / truth as f64;
                2.0 * p * r / (p + r)
            };
            f1s.push(f1);
        }
        if f1s.is_empty() {
            0.0
        } else {
            f1s.iter().sum::<f64>() / f1s.len() as f64
        }
    }
}

/// A (time, round, value) series — the payload of Fig. 2(a)/(b).
#[derive(Debug, Clone, Default)]
pub struct MetricSeries {
    pub points: Vec<SeriesPoint>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeriesPoint {
    pub round: usize,
    pub sim_time: f64,
    pub value: f64,
}

impl MetricSeries {
    pub fn push(&mut self, round: usize, sim_time: f64, value: f64) {
        self.points.push(SeriesPoint { round, sim_time, value });
    }

    pub fn last_value(&self) -> Option<f64> {
        self.points.last().map(|p| p.value)
    }

    /// First virtual time at which the series reaches `threshold`
    /// (time-to-accuracy — Fig. 2's comparison axis).
    pub fn time_to_reach(&self, threshold: f64) -> Option<f64> {
        self.points.iter().find(|p| p.value >= threshold).map(|p| p.sim_time)
    }
}

/// Convergence detector matching the paper's protocol: training has
/// converged when the metric's best value hasn't improved by more than
/// `min_delta` for `patience` consecutive evaluation rounds.
#[derive(Debug, Clone)]
pub struct ConvergenceDetector {
    // sflint:allow(checkpoint-coverage, config knob fixed at construction, not mutable run state)
    pub patience: usize,
    // sflint:allow(checkpoint-coverage, config knob fixed at construction, not mutable run state)
    pub min_delta: f64,
    best: f64,
    stale: usize,
    converged_at: Option<(usize, f64)>,
}

impl ConvergenceDetector {
    pub fn new(patience: usize, min_delta: f64) -> Self {
        Self { patience, min_delta, best: f64::NEG_INFINITY, stale: 0, converged_at: None }
    }

    /// Feed one evaluation point; returns true once converged.
    pub fn update(&mut self, round: usize, sim_time: f64, value: f64) -> bool {
        if self.converged_at.is_some() {
            return true;
        }
        if value > self.best + self.min_delta {
            self.best = value;
            self.stale = 0;
        } else {
            self.stale += 1;
            if self.stale >= self.patience {
                self.converged_at = Some((round, sim_time));
            }
        }
        self.converged_at.is_some()
    }

    pub fn converged(&self) -> Option<(usize, f64)> {
        self.converged_at
    }

    /// Snapshot for checkpoint/resume: (best, stale, converged_at).
    pub fn state(&self) -> (f64, usize, Option<(usize, f64)>) {
        (self.best, self.stale, self.converged_at)
    }

    /// Restore a detector mid-run from a saved [`ConvergenceDetector::state`].
    pub fn restore_state(&mut self, best: f64, stale: usize, converged_at: Option<(usize, f64)>) {
        self.best = best;
        self.stale = stale;
        self.converged_at = converged_at;
    }

    pub fn best(&self) -> f64 {
        self.best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_predictions_are_perfect() {
        let mut c = Confusion::new(3);
        for t in 0..3 {
            for _ in 0..5 {
                c.record(t, t);
            }
        }
        assert_eq!(c.accuracy(), 1.0);
        assert_eq!(c.macro_f1(), 1.0);
    }

    #[test]
    fn accuracy_counts_diagonal() {
        let mut c = Confusion::new(2);
        c.record(0, 0);
        c.record(0, 1);
        c.record(1, 1);
        c.record(1, 1);
        assert!((c.accuracy() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn macro_f1_penalizes_minority_collapse() {
        // Predicting the majority class everywhere: high accuracy on an
        // imbalanced set, low macro-F1.
        let mut c = Confusion::new(2);
        for _ in 0..90 {
            c.record(0, 0);
        }
        for _ in 0..10 {
            c.record(1, 0);
        }
        assert!(c.accuracy() > 0.89);
        assert!(c.macro_f1() < 0.5, "macro_f1 = {}", c.macro_f1());
    }

    #[test]
    fn macro_f1_known_value() {
        // Class 0: tp=1 fp=1 fn=0 -> p=0.5 r=1 f1=2/3.
        // Class 1: tp=1 fp=0 fn=1 -> p=1 r=0.5 f1=2/3.
        let mut c = Confusion::new(2);
        c.record(0, 0);
        c.record(1, 0);
        c.record(1, 1);
        let f1 = c.macro_f1();
        assert!((f1 - 2.0 / 3.0).abs() < 1e-9, "{f1}");
    }

    #[test]
    fn record_logits_argmax() {
        let mut c = Confusion::new(3);
        let logits = [0.1f32, 0.9, 0.0, /* pred 1 */ 2.0, 0.0, 1.0 /* pred 0 */];
        c.record_logits(&logits, &[1, 0]);
        assert_eq!(c.accuracy(), 1.0);
    }

    #[test]
    fn series_time_to_reach() {
        let mut s = MetricSeries::default();
        s.push(1, 10.0, 0.5);
        s.push(2, 20.0, 0.8);
        s.push(3, 30.0, 0.9);
        assert_eq!(s.time_to_reach(0.75), Some(20.0));
        assert_eq!(s.time_to_reach(0.95), None);
    }

    #[test]
    fn convergence_triggers_after_patience() {
        let mut d = ConvergenceDetector::new(3, 0.001);
        assert!(!d.update(1, 1.0, 0.5));
        assert!(!d.update(2, 2.0, 0.6)); // improvement resets
        assert!(!d.update(3, 3.0, 0.6));
        assert!(!d.update(4, 4.0, 0.6005)); // below min_delta => stale
        assert!(d.update(5, 5.0, 0.6));
        assert_eq!(d.converged().map(|(r, _)| r), Some(5));
    }

    #[test]
    fn convergence_is_sticky() {
        let mut d = ConvergenceDetector::new(1, 0.0);
        d.update(1, 1.0, 0.5);
        assert!(d.update(2, 2.0, 0.5));
        // Later improvements do not un-converge.
        assert!(d.update(3, 3.0, 0.99));
        assert_eq!(d.converged().map(|(r, _)| r), Some(2));
    }
}
