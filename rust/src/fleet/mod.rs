//! Synthetic heterogeneous fleets (fleet-scale scheduling workloads).
//!
//! The paper evaluates on six physical devices (§V-A); the scheduling
//! subsystem has to hold up on the regime related systems stress —
//! thousands to hundreds of thousands of heterogeneous clients.
//! [`FleetSpec`] synthesizes such fleets deterministically from a seed:
//! distributions over device TFLOPS, link rates, and cut depths,
//! calibrated against the paper fleet, via the in-tree [`Rng`]
//! (lognormal / zipf samplers — no external crates).
//!
//! Presets:
//! - **paper** — tiles the six §V-A devices in order (n = 6 is exactly
//!   the paper fleet; n = 12 the doubled fleet of the ablation bench).
//! - **lognormal** — TFLOPS lognormal with log-moments fitted to the
//!   paper fleet; memory tier tracks the compute class; link tier
//!   (Wi-Fi / LTE / 5G) sampled per client with rate jitter; cut depth
//!   left to the split selector (`resolve_cuts`).
//! - **zipf** — device *classes* are the six paper devices ranked by
//!   compute, sampled by Zipf rank: the cheapest, weakest device is the
//!   most common, a realistic mobile install base.
//!
//! On top of any preset, `mfu_sigma` applies a hidden multiplicative
//! lognormal jitter to each device's achieved MFU.  The static timing
//! model only sees *nominal* profiles ([`DeviceProfile::nominal`]), so
//! this jitter is the ground truth the online
//! [`TimingEstimator`](crate::coordinator::estimator::TimingEstimator)
//! must learn.

use crate::config::ClientConfig;
use crate::devices::{paper_fleet, DeviceProfile};
use crate::net::Link;
use crate::tensor::rng::Rng;
use anyhow::{bail, Result};
use std::str::FromStr;

/// Which distribution family generates the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetPreset {
    /// Tile the paper's six devices in §V-A order.
    Paper,
    /// Lognormal compute/link spreads calibrated to the paper fleet.
    Lognormal,
    /// Zipf-ranked paper device classes (weakest device most common).
    Zipf,
}

impl FromStr for FleetPreset {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "paper" => Ok(Self::Paper),
            "lognormal" => Ok(Self::Lognormal),
            "zipf" => Ok(Self::Zipf),
            other => bail!("unknown fleet preset {other:?} (paper|lognormal|zipf)"),
        }
    }
}

impl std::fmt::Display for FleetPreset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Self::Paper => "paper",
            Self::Lognormal => "lognormal",
            Self::Zipf => "zipf",
        };
        write!(f, "{s}")
    }
}

/// Log-moments of the six paper-fleet TFLOPS figures (0.472 … 3.533):
/// mean(ln tflops) ≈ 0.517, std ≈ 0.649 — the lognormal preset's
/// calibration anchor.
const LN_TFLOPS_MU: f64 = 0.517;
const LN_TFLOPS_SIGMA: f64 = 0.649;
/// Zipf exponent for the device-class install-base skew.
const ZIPF_EXPONENT: f64 = 1.1;
/// Default hidden-MFU jitter for the sampled presets (off for paper).
const DEFAULT_MFU_SIGMA: f64 = 0.15;

/// A seeded recipe for a synthetic fleet.  Same spec ⇒ bit-identical
/// fleet (the determinism every experiment and checkpoint relies on).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    pub preset: FleetPreset,
    /// Number of clients to synthesize.
    pub n: usize,
    pub seed: u64,
    /// Lognormal σ of the hidden per-device MFU multiplier (achieved
    /// vs. nominal compute efficiency).  0 disables the jitter; the
    /// sampled presets default to a mild spread.
    pub mfu_sigma: f64,
}

impl FleetSpec {
    pub fn new(preset: FleetPreset, n: usize, seed: u64) -> Self {
        let mfu_sigma = match preset {
            FleetPreset::Paper => 0.0,
            _ => DEFAULT_MFU_SIGMA,
        };
        Self { preset, n, seed, mfu_sigma }
    }

    /// Memory tier (MB) for a sampled compute class — tracks the paper
    /// fleet's 4/8/12/16 GB ladder.
    fn memory_for_tflops(tflops: f64) -> f64 {
        match tflops {
            t if t < 1.0 => 4096.0,
            t if t < 2.0 => 8192.0,
            t if t < 3.0 => 12288.0,
            _ => 16384.0,
        }
    }

    /// Sample a link: tier by install-base weight, then mild rate
    /// jitter around the tier's nominal rate.
    fn sample_link(rng: &mut Rng) -> Link {
        let tier = match rng.categorical(&[0.5, 0.3, 0.2]) {
            0 => Link::wifi(),
            1 => Link::lte(),
            _ => Link::five_g(),
        };
        tier.scaled(rng.lognormal(0.0, 0.25).clamp(0.25, 4.0))
    }

    /// Materialize the fleet.  Pinned cuts come with the paper device
    /// classes; the lognormal preset leaves `cut: None` so the split
    /// selector assigns the deepest feasible cut per device.
    pub fn synthesize(&self) -> Vec<ClientConfig> {
        let mut rng = Rng::new(self.seed ^ 0x00F1_EE75);
        let catalog = paper_fleet();
        let mut ranked = catalog.clone();
        ranked.sort_by(|a, b| a.0.tflops.total_cmp(&b.0.tflops));
        (0..self.n)
            .map(|i| {
                let (mut device, cut, link) = match self.preset {
                    FleetPreset::Paper => {
                        let (d, k) = catalog[i % catalog.len()].clone();
                        (d, Some(k), Link::paper_default())
                    }
                    FleetPreset::Lognormal => {
                        let tflops =
                            rng.lognormal(LN_TFLOPS_MU, LN_TFLOPS_SIGMA).clamp(0.05, 50.0);
                        let d = DeviceProfile::new(
                            &format!("syn-ln-{i}"),
                            tflops,
                            Self::memory_for_tflops(tflops),
                        );
                        (d, None, Self::sample_link(&mut rng))
                    }
                    FleetPreset::Zipf => {
                        let r = rng.zipf(ranked.len(), ZIPF_EXPONENT);
                        let (mut d, k) = ranked[r].clone();
                        d.name = format!("{}-{i}", d.name);
                        (d, Some(k), Self::sample_link(&mut rng))
                    }
                };
                if self.mfu_sigma > 0.0 {
                    device.mfu =
                        (device.mfu * rng.lognormal(0.0, self.mfu_sigma)).clamp(0.05, 0.95);
                }
                ClientConfig { device, cut, link }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::DEFAULT_CLIENT_MFU;

    fn fingerprint(fleet: &[ClientConfig]) -> Vec<u64> {
        fleet
            .iter()
            .flat_map(|c| {
                [
                    c.device.tflops.to_bits(),
                    c.device.memory_mb.to_bits(),
                    c.device.mfu.to_bits(),
                    c.link.rate_mbps.to_bits(),
                    c.link.latency_ms.to_bits(),
                    c.cut.map(|k| k as u64 + 1).unwrap_or(0),
                ]
            })
            .collect()
    }

    #[test]
    fn same_seed_same_fleet_different_seed_different_fleet() {
        for preset in [FleetPreset::Paper, FleetPreset::Lognormal, FleetPreset::Zipf] {
            let a = FleetSpec::new(preset, 64, 7).synthesize();
            let b = FleetSpec::new(preset, 64, 7).synthesize();
            assert_eq!(fingerprint(&a), fingerprint(&b), "{preset}: not deterministic");
            if preset != FleetPreset::Paper {
                let c = FleetSpec::new(preset, 64, 8).synthesize();
                assert_ne!(fingerprint(&a), fingerprint(&c), "{preset}: seed ignored");
            }
        }
    }

    #[test]
    fn paper_preset_tiles_the_paper_fleet() {
        let fleet = FleetSpec::new(FleetPreset::Paper, 12, 3).synthesize();
        assert_eq!(fleet.len(), 12);
        let paper = paper_fleet();
        for (i, c) in fleet.iter().enumerate() {
            let (d, k) = &paper[i % 6];
            assert_eq!(c.device.name, d.name);
            assert!((c.device.tflops - d.tflops).abs() < 1e-12);
            assert_eq!(c.cut, Some(*k));
            assert!((c.device.mfu - DEFAULT_CLIENT_MFU).abs() < 1e-12, "paper jitter off");
        }
    }

    #[test]
    fn lognormal_preset_is_heterogeneous_and_in_range() {
        let fleet = FleetSpec::new(FleetPreset::Lognormal, 500, 11).synthesize();
        assert_eq!(fleet.len(), 500);
        let tf: Vec<f64> = fleet.iter().map(|c| c.device.tflops).collect();
        let lo = tf.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = tf.iter().copied().fold(0.0f64, f64::max);
        assert!(lo >= 0.05 && hi <= 50.0);
        assert!(hi / lo > 3.0, "spread too narrow: {lo}..{hi}");
        // Hidden MFU jitter on by default — some devices off nominal.
        assert!(fleet.iter().any(|c| (c.device.mfu - DEFAULT_CLIENT_MFU).abs() > 1e-3));
        // Cut left to the split selector.
        assert!(fleet.iter().all(|c| c.cut.is_none()));
    }

    #[test]
    fn zipf_preset_skews_to_the_weakest_class() {
        let fleet = FleetSpec::new(FleetPreset::Zipf, 600, 5).synthesize();
        let nano = fleet
            .iter()
            .filter(|c| c.device.name.starts_with("Jetson Nano"))
            .count();
        let m3 = fleet.iter().filter(|c| c.device.name.starts_with("M3")).count();
        assert!(nano > m3, "weakest class must dominate: nano={nano} m3={m3}");
        assert!(fleet.iter().all(|c| c.cut.is_some()));
    }

    #[test]
    fn preset_parsing_roundtrips() {
        for preset in [FleetPreset::Paper, FleetPreset::Lognormal, FleetPreset::Zipf] {
            assert_eq!(preset.to_string().parse::<FleetPreset>().unwrap(), preset);
        }
        assert!("bogus".parse::<FleetPreset>().is_err());
    }
}
