//! LoRA adapter state: the paper's R = {A, B} sets, their split/join at
//! cut points (eqs. 5, 9) and FedAvg aggregation (eqs. 6–7).
//!
//! An [`AdapterSet`] holds the four stacked tensors (A_q, B_q, A_v, B_v)
//! over some contiguous range of layers.  Client state is layers
//! `[0, k)`, server state is `[k, N)`; `join`/`split_at` convert between
//! the per-client halves and the full set the aggregator works on.

use crate::model::ModelDims;
use crate::tensor::{ops, rng::Rng, HostTensor, TensorView};
use anyhow::{bail, Result};

/// Tensor keys in packing order (mirrors python packing.LORA_KEYS).
pub const LORA_KEYS: [&str; 4] = ["aq", "bq", "av", "bv"];

/// Borrowed adapter half: O(1) views of the four stacked tensors over a
/// contiguous layer window.  Splitting at a cut point with views costs
/// nothing — the aggregation path never materializes the halves.
#[derive(Debug, Clone, Copy)]
pub struct AdapterViews<'a> {
    pub layers: usize,
    /// In LORA_KEYS order, each a view of rows `[lo, hi)` of the parent.
    pub tensors: [TensorView<'a>; 4],
}

impl AdapterViews<'_> {
    /// Total adapter parameters in the window.
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }
}

/// LoRA adapters stacked over `layers` consecutive transformer layers.
#[derive(Debug, Clone, PartialEq)]
pub struct AdapterSet {
    pub layers: usize,
    /// In LORA_KEYS order: aq [n,r,m], bq [n,m,r], av [n,r,m], bv [n,m,r].
    pub tensors: Vec<HostTensor>,
}

impl AdapterSet {
    /// Shapes for an adapter stack over `n` layers.
    pub fn shapes(dims: &ModelDims, n: usize) -> [(String, Vec<usize>); 4] {
        let (m, r) = (dims.hidden, dims.rank);
        [
            ("aq".into(), vec![n, r, m]),
            ("bq".into(), vec![n, m, r]),
            ("av".into(), vec![n, r, m]),
            ("bv".into(), vec![n, m, r]),
        ]
    }

    /// Zero-initialized adapters (B=0 ⇒ no-op adapter; A is also zero here
    /// — use `init` for the standard LoRA init).
    pub fn zeros(dims: &ModelDims, layers: usize) -> Self {
        let tensors = Self::shapes(dims, layers)
            .into_iter()
            .map(|(name, shape)| HostTensor::zeros(name, shape))
            .collect();
        Self { layers, tensors }
    }

    /// Standard LoRA init: A ~ N(0, 1/r), B = 0.
    pub fn init(dims: &ModelDims, layers: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let sa = 1.0 / dims.rank as f64;
        let tensors = Self::shapes(dims, layers)
            .into_iter()
            .map(|(name, shape)| {
                let n: usize = shape.iter().product();
                let data = if name.starts_with('a') {
                    (0..n).map(|_| (rng.normal() * sa) as f32).collect()
                } else {
                    vec![0.0; n]
                };
                HostTensor::f32(name, shape, data)
            })
            .collect();
        Self { layers, tensors }
    }

    /// Build from tensors loaded out of params.bin (names `lora.aq`, ...).
    pub fn from_tensors(layers: usize, tensors: Vec<HostTensor>) -> Result<Self> {
        if tensors.len() != 4 {
            bail!("adapter set needs 4 tensors, got {}", tensors.len());
        }
        for t in &tensors {
            if t.shape[0] != layers {
                bail!("tensor {} has {} layers, expected {layers}", t.name, t.shape[0]);
            }
        }
        Ok(Self { layers, tensors })
    }

    /// Split at `k`: layers [0, k) → client half, [k, n) → server half.
    /// Paper eq. (9).
    pub fn split_at(&self, k: usize) -> Result<(AdapterSet, AdapterSet)> {
        if k > self.layers {
            bail!("cut {k} beyond {} layers", self.layers);
        }
        let client = self
            .tensors
            .iter()
            .map(|t| t.slice_axis0(0, k))
            .collect::<Result<Vec<_>>>()?;
        let server = self
            .tensors
            .iter()
            .map(|t| t.slice_axis0(k, self.layers))
            .collect::<Result<Vec<_>>>()?;
        Ok((
            AdapterSet { layers: k, tensors: client },
            AdapterSet { layers: self.layers - k, tensors: server },
        ))
    }

    /// O(1) split at `k` into borrowed views: layers [0, k) → client
    /// half, [k, n) → server half.  The zero-copy counterpart of
    /// [`AdapterSet::split_at`] (paper eq. 9) used on the aggregation
    /// path.
    pub fn split_at_views(&self, k: usize) -> Result<(AdapterViews<'_>, AdapterViews<'_>)> {
        if k > self.layers {
            bail!("cut {k} beyond {} layers", self.layers);
        }
        let n = self.layers;
        let client = AdapterViews {
            layers: k,
            tensors: [
                self.tensors[0].view_axis0(0, k)?,
                self.tensors[1].view_axis0(0, k)?,
                self.tensors[2].view_axis0(0, k)?,
                self.tensors[3].view_axis0(0, k)?,
            ],
        };
        let server = AdapterViews {
            layers: n - k,
            tensors: [
                self.tensors[0].view_axis0(k, n)?,
                self.tensors[1].view_axis0(k, n)?,
                self.tensors[2].view_axis0(k, n)?,
                self.tensors[3].view_axis0(k, n)?,
            ],
        };
        Ok((client, server))
    }

    /// In-place split: copy layers [0, k) into `client` and [k, n) into
    /// `server`, which must already have the right depths.  Zero
    /// allocations — this is how the aggregate is redistributed to the
    /// per-client state buffers.
    pub fn split_into(&self, k: usize, client: &mut AdapterSet, server: &mut AdapterSet) -> Result<()> {
        if k > self.layers {
            bail!("cut {k} beyond {} layers", self.layers);
        }
        if client.layers != k || server.layers != self.layers - k {
            bail!(
                "split_into depth mismatch: dst ({}, {}) vs cut {k} of {}",
                client.layers,
                server.layers,
                self.layers
            );
        }
        let (cv, sv) = self.split_at_views(k)?;
        for (dst, src) in client.tensors.iter_mut().zip(cv.tensors.iter()) {
            let d = dst.as_f32_mut()?;
            if d.len() != src.data.len() {
                bail!("split_into width mismatch on {} ({} vs {})", src.name, d.len(), src.data.len());
            }
            d.copy_from_slice(src.data);
        }
        for (dst, src) in server.tensors.iter_mut().zip(sv.tensors.iter()) {
            let d = dst.as_f32_mut()?;
            if d.len() != src.data.len() {
                bail!("split_into width mismatch on {} ({} vs {})", src.name, d.len(), src.data.len());
            }
            d.copy_from_slice(src.data);
        }
        Ok(())
    }

    /// Join a client half and a server half back into a full set.
    /// Paper eq. (5): R_f^u = {R_c^u, R_s^u}.
    pub fn join(client: &AdapterSet, server: &AdapterSet) -> Result<AdapterSet> {
        let tensors = client
            .tensors
            .iter()
            .zip(server.tensors.iter())
            .map(|(c, s)| HostTensor::concat_axis0(&[c, s]))
            .collect::<Result<Vec<_>>>()?;
        Ok(AdapterSet { layers: client.layers + server.layers, tensors })
    }

    /// In-place join: write `{client, server}` into a preallocated full
    /// set (inverse of `split_into`, zero allocations).
    pub fn join_into(client: &AdapterSet, server: &AdapterSet, dst: &mut AdapterSet) -> Result<()> {
        if dst.layers != client.layers + server.layers {
            bail!(
                "join_into depth mismatch: dst {} vs {} + {}",
                dst.layers,
                client.layers,
                server.layers
            );
        }
        for ((c, s), d) in client
            .tensors
            .iter()
            .zip(server.tensors.iter())
            .zip(dst.tensors.iter_mut())
        {
            HostTensor::concat_axis0_into(&[c, s], d)?;
        }
        Ok(())
    }

    /// Total adapter parameters.
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// Payload bytes (what a client uploads in aggregation step 2a).
    pub fn byte_len(&self) -> usize {
        self.tensors.iter().map(|t| t.byte_len()).sum()
    }

    /// Max |a-b| across all four tensors (tests/diagnostics).
    pub fn max_abs_diff(&self, other: &AdapterSet) -> Result<f32> {
        let mut worst = 0.0f32;
        for (a, b) in self.tensors.iter().zip(other.tensors.iter()) {
            worst = worst.max(ops::max_abs_diff(a, b)?);
        }
        Ok(worst)
    }
}

fn check_weights(total_w: f32) -> Result<()> {
    // NaN fails *both* comparisons below ((NaN - 1).abs() > eps is
    // false), so non-finite sums must be rejected explicitly or a
    // single NaN weight would silently poison the whole aggregate.
    if !total_w.is_finite() {
        bail!("aggregation weights must be finite, got sum {total_w}");
    }
    if (total_w - 1.0).abs() > 1e-4 {
        bail!("aggregation weights must sum to 1, got {total_w}");
    }
    Ok(())
}

/// FedAvg over full adapter sets with data-size weights |D_u|/|D| —
/// paper eqs. (6)–(7): A and B matrices are aggregated *separately*.
pub fn fedavg(sets: &[(f32, &AdapterSet)]) -> Result<AdapterSet> {
    let (_, first) = sets.first().ok_or_else(|| anyhow::anyhow!("empty aggregation"))?;
    let mut out = AdapterSet {
        layers: first.layers,
        tensors: first
            .tensors
            .iter()
            .map(|t| HostTensor::zeros(t.name.clone(), t.shape.clone()))
            .collect(),
    };
    fedavg_into(sets, &mut out)?;
    Ok(out)
}

/// In-place FedAvg: overwrite `dst` with the weighted aggregate.
/// Bit-identical to [`fedavg`] with zero tensor allocations — the
/// coordinator calls this against a scratch set allocated once.
pub fn fedavg_into(sets: &[(f32, &AdapterSet)], dst: &mut AdapterSet) -> Result<()> {
    let (_, first) = sets.first().ok_or_else(|| anyhow::anyhow!("empty aggregation"))?;
    check_weights(sets.iter().map(|(w, _)| w).sum())?;
    let layers = first.layers;
    if dst.layers != layers {
        bail!("fedavg_into dst depth {} != {layers}", dst.layers);
    }
    for (_, s) in sets {
        if s.layers != layers {
            bail!("cannot aggregate adapter sets of differing depth");
        }
    }
    for i in 0..4 {
        let pairs: Vec<(f32, &HostTensor)> =
            sets.iter().map(|(w, s)| (*w, &s.tensors[i])).collect();
        ops::weighted_sum_into(&pairs, &mut dst.tensors[i])?;
    }
    Ok(())
}

/// Fused heterogeneous FedAvg (paper eqs. 5–7 collapsed): each
/// contributor is a `(weight, client half [0, k_u), server half
/// [k_u, N))` pair, and the aggregate is accumulated directly into the
/// full-depth `dst` — the per-client joins of eq. (5) are never
/// materialized.  Each contributor's halves are scattered into `dst`
/// via axis-0 views, so the whole aggregation performs zero tensor
/// allocations and one pass per contributor.
///
/// Bit-identical to `fedavg(&[(w, join(c, s)), ...])`: the per-element
/// accumulation order is the same.
pub fn fedavg_joined_into(
    contribs: &[(f32, &AdapterSet, &AdapterSet)],
    dst: &mut AdapterSet,
) -> Result<()> {
    if contribs.is_empty() {
        bail!("empty aggregation");
    }
    check_weights(contribs.iter().map(|(w, _, _)| w).sum())?;
    for t in dst.tensors.iter_mut() {
        t.as_f32_mut()?.fill(0.0);
    }
    for (w, client, server) in contribs {
        let k = client.layers;
        if k + server.layers != dst.layers {
            bail!(
                "contributor depth {} + {} != aggregate depth {}",
                k,
                server.layers,
                dst.layers
            );
        }
        for i in 0..4 {
            let inner: usize = dst.tensors[i].shape[1..].iter().product();
            let d = dst.tensors[i].as_f32_mut()?;
            ops::axpy_into(*w, client.tensors[i].as_f32()?, &mut d[..k * inner])?;
            ops::axpy_into(*w, server.tensors[i].as_f32()?, &mut d[k * inner..])?;
        }
    }
    Ok(())
}

/// True if any coordinate of the joined `{client, server}` update is
/// NaN or ±Inf — the sanitizer's first rejection test.
pub fn joined_non_finite(client: &AdapterSet, server: &AdapterSet) -> Result<bool> {
    for half in [client, server] {
        for t in &half.tensors {
            if t.as_f32()?.iter().any(|x| !x.is_finite()) {
                return Ok(true);
            }
        }
    }
    Ok(false)
}

/// L2 norm of the joined update delta ‖{c, s} − baseline‖₂ — the
/// per-client statistic the sanitizer and the norm-clip defense key on.
/// `client` covers layers [0, k), `server` [k, N); `baseline` is the
/// full-depth reference the cohort started the round from.  Accumulates
/// in f64 with zero tensor allocations; a non-finite update yields a
/// non-finite norm (callers treat that as "reject").
pub fn joined_delta_norm(
    client: &AdapterSet,
    server: &AdapterSet,
    baseline: &AdapterSet,
) -> Result<f64> {
    let k = client.layers;
    if k + server.layers != baseline.layers {
        bail!(
            "delta depth {} + {} != baseline depth {}",
            k,
            server.layers,
            baseline.layers
        );
    }
    let mut acc = 0.0f64;
    for i in 0..4 {
        let inner: usize = baseline.tensors[i].shape[1..].iter().product();
        let b = baseline.tensors[i].as_f32()?;
        for (x, y) in client.tensors[i].as_f32()?.iter().zip(&b[..k * inner]) {
            let d = (*x - *y) as f64;
            acc += d * d;
        }
        for (x, y) in server.tensors[i].as_f32()?.iter().zip(&b[k * inner..]) {
            let d = (*x - *y) as f64;
            acc += d * d;
        }
    }
    Ok(acc.sqrt())
}

/// Coordinate-wise trimmed-mean variant of [`fedavg_joined_into`]: at
/// every scalar coordinate the `trim` smallest and `trim` largest
/// contributor values are discarded and the survivors re-weighted to a
/// weighted mean.  NaN sorts above +Inf under `total_cmp`, so corrupt
/// coordinates always land in the trimmed upper tail.  `col` is
/// caller-owned scratch (value, weight per contributor) so steady-state
/// rounds perform zero tensor allocations.  `trim == 0` delegates to
/// [`fedavg_joined_into`] and is bit-identical to it.
pub fn trimmed_fedavg_joined_into(
    contribs: &[(f32, &AdapterSet, &AdapterSet)],
    trim: usize,
    col: &mut Vec<(f32, f32)>,
    dst: &mut AdapterSet,
) -> Result<()> {
    if trim == 0 {
        return fedavg_joined_into(contribs, dst);
    }
    if contribs.is_empty() {
        bail!("empty aggregation");
    }
    let n = contribs.len();
    if 2 * trim >= n {
        bail!("trim {trim} leaves no survivors out of {n} contributors");
    }
    check_weights(contribs.iter().map(|(w, _, _)| w).sum())?;
    for i in 0..4 {
        let inner: usize = dst.tensors[i].shape[1..].iter().product();
        let table: Vec<(usize, &[f32], &[f32], f32)> = contribs
            .iter()
            .map(|(w, c, s)| {
                if c.layers + s.layers != dst.layers {
                    bail!(
                        "contributor depth {} + {} != aggregate depth {}",
                        c.layers,
                        s.layers,
                        dst.layers
                    );
                }
                Ok((c.layers * inner, c.tensors[i].as_f32()?, s.tensors[i].as_f32()?, *w))
            })
            .collect::<Result<_>>()?;
        let d = dst.tensors[i].as_f32_mut()?;
        for (j, dj) in d.iter_mut().enumerate() {
            col.clear();
            for (split, cv, sv, w) in &table {
                let v = if j < *split { cv[j] } else { sv[j - *split] };
                col.push((v, *w));
            }
            col.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
            let survivors = &col[trim..n - trim];
            let wsum: f32 = survivors.iter().map(|&(_, w)| w).sum();
            if wsum <= 0.0 || !wsum.is_finite() {
                bail!("trimmed mean: surviving weights sum to {wsum}");
            }
            let mut acc = 0.0f64;
            for &(v, w) in survivors {
                acc += v as f64 * w as f64;
            }
            *dj = (acc / wsum as f64) as f32;
        }
    }
    Ok(())
}

/// Norm-clipped variant of [`fedavg_joined_into`]: every contributor is
/// read as `baseline + delta`; deltas with L2 norm above `clip` are
/// scaled down to the threshold, and non-finite deltas are scaled to
/// zero (the client contributes the baseline unchanged — a 0-weight
/// axpy would still propagate NaN, so those updates are skipped
/// entirely).  Computed with a single residual pass,
/// `Σ w·s·x + (1 − Σ w·s)·b  ==  Σ w·(b + s·(x − b))`,
/// zero tensor allocations.  A non-finite `clip` disables clipping and
/// delegates to [`fedavg_joined_into`], bit-identical to it.  Returns
/// the number of contributors that were clipped or zeroed.
pub fn clipped_fedavg_joined_into(
    contribs: &[(f32, &AdapterSet, &AdapterSet)],
    baseline: &AdapterSet,
    clip: f64,
    dst: &mut AdapterSet,
) -> Result<u64> {
    if !clip.is_finite() {
        fedavg_joined_into(contribs, dst)?;
        return Ok(0);
    }
    if contribs.is_empty() {
        bail!("empty aggregation");
    }
    if clip <= 0.0 {
        bail!("clip threshold must be positive, got {clip}");
    }
    if baseline.layers != dst.layers {
        bail!("baseline depth {} != aggregate depth {}", baseline.layers, dst.layers);
    }
    check_weights(contribs.iter().map(|(w, _, _)| w).sum())?;
    for t in dst.tensors.iter_mut() {
        t.as_f32_mut()?.fill(0.0);
    }
    let mut clipped = 0u64;
    let mut carry = 1.0f32;
    for (w, client, server) in contribs {
        let k = client.layers;
        if k + server.layers != dst.layers {
            bail!(
                "contributor depth {} + {} != aggregate depth {}",
                k,
                server.layers,
                dst.layers
            );
        }
        let norm = joined_delta_norm(client, server, baseline)?;
        let s = if !norm.is_finite() {
            clipped += 1;
            0.0f32
        } else if norm > clip {
            clipped += 1;
            (clip / norm) as f32
        } else {
            1.0f32
        };
        let ws = *w * s;
        carry -= ws;
        if ws != 0.0 {
            for i in 0..4 {
                let inner: usize = dst.tensors[i].shape[1..].iter().product();
                let d = dst.tensors[i].as_f32_mut()?;
                ops::axpy_into(ws, client.tensors[i].as_f32()?, &mut d[..k * inner])?;
                ops::axpy_into(ws, server.tensors[i].as_f32()?, &mut d[k * inner..])?;
            }
        }
    }
    for i in 0..4 {
        ops::axpy_into(carry, baseline.tensors[i].as_f32()?, dst.tensors[i].as_f32_mut()?)?;
    }
    Ok(clipped)
}

/// Per-client adapter bookkeeping on the server: the "LoRA adapter
/// switching" store (paper step 1d) — the server keeps U server-side
/// adapter sets and swaps the active one between sequential jobs.
#[derive(Debug)]
pub struct AdapterStore {
    /// (client id → (cut, server-side adapters for layers [cut, N))).
    entries: Vec<(usize, AdapterSet)>,
    /// Currently loaded client (simulating the switch cost bookkeeping).
    active: Option<usize>,
    pub switches: u64,
}

impl AdapterStore {
    pub fn new(dims: &ModelDims, cuts: &[usize], seed: u64) -> Self {
        let entries = cuts
            .iter()
            .enumerate()
            .map(|(u, &k)| (k, AdapterSet::init(dims, dims.layers - k, seed + u as u64)))
            .collect();
        Self { entries, active: None, switches: 0 }
    }

    pub fn cut(&self, client: usize) -> usize {
        self.entries[client].0
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Load client `u`'s adapters as the active set (counts switches).
    pub fn activate(&mut self, client: usize) -> &AdapterSet {
        if self.active != Some(client) {
            self.switches += 1;
            self.active = Some(client);
        }
        &self.entries[client].1
    }

    pub fn get(&self, client: usize) -> &AdapterSet {
        &self.entries[client].1
    }

    pub fn set(&mut self, client: usize, adapters: AdapterSet) {
        debug_assert_eq!(adapters.layers, self.entries[client].1.layers);
        self.entries[client].1 = adapters;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims::mini()
    }

    #[test]
    fn init_has_zero_b_and_nonzero_a() {
        let s = AdapterSet::init(&dims(), 3, 1);
        assert!(ops::l2_norm(&s.tensors[0]).unwrap() > 0.0); // aq
        assert_eq!(ops::l2_norm(&s.tensors[1]).unwrap(), 0.0); // bq
        assert!(ops::l2_norm(&s.tensors[2]).unwrap() > 0.0); // av
        assert_eq!(ops::l2_norm(&s.tensors[3]).unwrap(), 0.0); // bv
    }

    #[test]
    fn split_join_roundtrip() {
        let full = AdapterSet::init(&dims(), 4, 2);
        for k in 1..4 {
            let (c, s) = full.split_at(k).unwrap();
            assert_eq!(c.layers, k);
            assert_eq!(s.layers, 4 - k);
            let joined = AdapterSet::join(&c, &s).unwrap();
            assert_eq!(joined.max_abs_diff(&full).unwrap(), 0.0);
        }
    }

    #[test]
    fn fedavg_weights_must_sum_to_one() {
        let a = AdapterSet::init(&dims(), 2, 1);
        let b = AdapterSet::init(&dims(), 2, 2);
        assert!(fedavg(&[(0.5, &a), (0.2, &b)]).is_err());
        assert!(fedavg(&[(0.5, &a), (0.5, &b)]).is_ok());
    }

    #[test]
    fn fedavg_fixed_point_on_identical_sets() {
        let a = AdapterSet::init(&dims(), 2, 7);
        let agg = fedavg(&[(0.3, &a), (0.7, &a)]).unwrap();
        assert!(agg.max_abs_diff(&a).unwrap() < 1e-6);
    }

    #[test]
    fn fedavg_is_weighted_mean() {
        let dims = dims();
        let mut a = AdapterSet::zeros(&dims, 1);
        let mut b = AdapterSet::zeros(&dims, 1);
        a.tensors[0].as_f32_mut().unwrap().fill(0.0);
        b.tensors[0].as_f32_mut().unwrap().fill(4.0);
        let agg = fedavg(&[(0.25, &a), (0.75, &b)]).unwrap();
        assert!(agg.tensors[0].as_f32().unwrap().iter().all(|&x| (x - 3.0).abs() < 1e-6));
    }

    #[test]
    fn aggregate_then_split_equals_split_then_aggregate() {
        // The paper aggregates full sets then re-splits (eq. 9); doing it
        // per-segment must give the same result because both are linear.
        let dims = dims();
        let u1 = AdapterSet::init(&dims, 4, 11);
        let u2 = AdapterSet::init(&dims, 4, 22);
        let agg_full = fedavg(&[(0.6, &u1), (0.4, &u2)]).unwrap();
        let (agg_c, agg_s) = agg_full.split_at(2).unwrap();

        let (c1, s1) = u1.split_at(2).unwrap();
        let (c2, s2) = u2.split_at(2).unwrap();
        let agg_c2 = fedavg(&[(0.6, &c1), (0.4, &c2)]).unwrap();
        let agg_s2 = fedavg(&[(0.6, &s1), (0.4, &s2)]).unwrap();

        assert!(agg_c.max_abs_diff(&agg_c2).unwrap() < 1e-6);
        assert!(agg_s.max_abs_diff(&agg_s2).unwrap() < 1e-6);
    }

    #[test]
    fn adapter_store_counts_switches() {
        let dims = dims();
        let mut store = AdapterStore::new(&dims, &[1, 2, 3], 5);
        assert_eq!(store.len(), 3);
        store.activate(0);
        store.activate(0); // no switch
        store.activate(1);
        store.activate(2);
        store.activate(1);
        assert_eq!(store.switches, 4);
        assert_eq!(store.get(1).layers, dims.layers - 2);
    }

    #[test]
    fn byte_len_matches_dims_formula() {
        let dims = dims();
        let s = AdapterSet::zeros(&dims, 2);
        assert_eq!(s.byte_len(), dims.lora_bytes(2));
    }

    #[test]
    fn split_views_match_owned_split() {
        let full = AdapterSet::init(&dims(), 4, 9);
        for k in 0..=4 {
            let (co, so) = full.split_at(k).unwrap();
            let before = crate::tensor::alloc_count();
            let (cv, sv) = full.split_at_views(k).unwrap();
            assert_eq!(crate::tensor::alloc_count(), before, "views must not allocate");
            assert_eq!(cv.layers, k);
            assert_eq!(sv.layers, 4 - k);
            for i in 0..4 {
                assert_eq!(cv.tensors[i].data, co.tensors[i].as_f32().unwrap());
                assert_eq!(sv.tensors[i].data, so.tensors[i].as_f32().unwrap());
            }
            assert_eq!(cv.param_count() + sv.param_count(), full.param_count());
        }
        assert!(full.split_at_views(5).is_err());
    }

    #[test]
    fn split_into_join_into_roundtrip_is_alloc_free() {
        let dims = dims();
        let full = AdapterSet::init(&dims, 4, 13);
        let mut client = AdapterSet::zeros(&dims, 1);
        let mut server = AdapterSet::zeros(&dims, 3);
        let mut rejoined = AdapterSet::zeros(&dims, 4);
        let before = crate::tensor::alloc_count();
        full.split_into(1, &mut client, &mut server).unwrap();
        AdapterSet::join_into(&client, &server, &mut rejoined).unwrap();
        assert_eq!(crate::tensor::alloc_count(), before, "in-place split/join must not allocate");
        assert_eq!(rejoined.max_abs_diff(&full).unwrap(), 0.0);
        // Depth mismatches are rejected.
        assert!(full.split_into(2, &mut client, &mut server).is_err());
        let mut shallow = AdapterSet::zeros(&dims, 3);
        assert!(AdapterSet::join_into(&client, &server, &mut shallow).is_err());
    }

    #[test]
    fn fedavg_into_matches_fedavg_bitwise() {
        let dims = dims();
        let a = AdapterSet::init(&dims, 2, 3);
        let b = AdapterSet::init(&dims, 2, 4);
        let sets = [(0.25f32, &a), (0.75, &b)];
        let alloc = fedavg(&sets).unwrap();
        let mut into = AdapterSet::init(&dims, 2, 5); // garbage dst: must be overwritten
        fedavg_into(&sets, &mut into).unwrap();
        assert_eq!(alloc.max_abs_diff(&into).unwrap(), 0.0);
    }

    #[test]
    fn fused_join_fedavg_matches_reference_path() {
        // fedavg_joined_into over (client, server) halves at mixed cuts
        // must equal join → fedavg bit-for-bit.
        let dims = dims();
        let n = dims.layers;
        let fulls: Vec<AdapterSet> =
            (0..3).map(|i| AdapterSet::init(&dims, n, 40 + i)).collect();
        let cuts = [1usize, 2, 3];
        let halves: Vec<(AdapterSet, AdapterSet)> = fulls
            .iter()
            .zip(cuts.iter())
            .map(|(f, &k)| f.split_at(k).unwrap())
            .collect();
        let w = 1.0 / 3.0f32;
        let reference = {
            let joined: Vec<AdapterSet> = halves
                .iter()
                .map(|(c, s)| AdapterSet::join(c, s).unwrap())
                .collect();
            let pairs: Vec<(f32, &AdapterSet)> = joined.iter().map(|j| (w, j)).collect();
            fedavg(&pairs).unwrap()
        };
        let mut fused = AdapterSet::zeros(&dims, n);
        let contribs: Vec<(f32, &AdapterSet, &AdapterSet)> =
            halves.iter().map(|(c, s)| (w, c, s)).collect();
        let before = crate::tensor::alloc_count();
        fedavg_joined_into(&contribs, &mut fused).unwrap();
        assert_eq!(crate::tensor::alloc_count(), before, "fused aggregation must not allocate");
        assert_eq!(fused.max_abs_diff(&reference).unwrap(), 0.0);
    }

    #[test]
    fn fused_fedavg_validates_inputs() {
        let dims = dims();
        let f = AdapterSet::init(&dims, 4, 1);
        let (c, s) = f.split_at(2).unwrap();
        let mut dst = AdapterSet::zeros(&dims, 4);
        assert!(fedavg_joined_into(&[], &mut dst).is_err());
        assert!(fedavg_joined_into(&[(0.4, &c, &s)], &mut dst).is_err(), "weights must sum to 1");
        let mut shallow = AdapterSet::zeros(&dims, 3);
        assert!(fedavg_joined_into(&[(1.0, &c, &s)], &mut shallow).is_err());
    }

    #[test]
    fn fedavg_rejects_non_finite_weights_and_empty_cohorts() {
        let dims = dims();
        let a = AdapterSet::init(&dims, 2, 1);
        let b = AdapterSet::init(&dims, 2, 2);
        let mut dst = AdapterSet::zeros(&dims, 2);
        // A NaN weight makes the sum NaN, which the old |sum - 1| > eps
        // check silently accepted.
        assert!(fedavg(&[(f32::NAN, &a), (0.5, &b)]).is_err());
        assert!(fedavg_into(&[(0.5, &a), (f32::INFINITY, &b)], &mut dst).is_err());
        assert!(fedavg_into(&[], &mut dst).is_err(), "empty cohort must bail");
        let f = AdapterSet::init(&dims, 4, 3);
        let (c, s) = f.split_at(2).unwrap();
        let mut full = AdapterSet::zeros(&dims, 4);
        assert!(fedavg_joined_into(&[(f32::NAN, &c, &s)], &mut full).is_err());
    }

    #[test]
    fn joined_non_finite_flags_nan_and_inf() {
        let dims = dims();
        let f = AdapterSet::init(&dims, 4, 8);
        let (c, s) = f.split_at(2).unwrap();
        assert!(!joined_non_finite(&c, &s).unwrap());
        let mut bad = c.clone();
        bad.tensors[1].as_f32_mut().unwrap()[3] = f32::NAN;
        assert!(joined_non_finite(&bad, &s).unwrap());
        let mut bad_s = s.clone();
        bad_s.tensors[2].as_f32_mut().unwrap()[0] = f32::INFINITY;
        assert!(joined_non_finite(&c, &bad_s).unwrap());
    }

    #[test]
    fn joined_delta_norm_matches_closed_form() {
        let dims = dims();
        let baseline = AdapterSet::zeros(&dims, 4);
        let mut full = AdapterSet::zeros(&dims, 4);
        for t in full.tensors.iter_mut() {
            t.as_f32_mut().unwrap().fill(2.0);
        }
        let n = full.param_count() as f64;
        for k in 0..=4 {
            let (c, s) = full.split_at(k).unwrap();
            let got = joined_delta_norm(&c, &s, &baseline).unwrap();
            assert!((got - 2.0 * n.sqrt()).abs() < 1e-9 * n.sqrt());
        }
        let (c, s) = full.split_at(2).unwrap();
        let shallow = AdapterSet::zeros(&dims, 3);
        assert!(joined_delta_norm(&c, &s, &shallow).is_err());
    }

    #[test]
    fn trimmed_mean_discards_corrupt_and_scaled_outliers() {
        let dims = dims();
        let honest = AdapterSet::init(&dims, 4, 21);
        let (hc, hs) = honest.split_at(2).unwrap();
        // One corrupt contributor (NaN/Inf segment) and one ×100 scaled
        // contributor among four honest copies: trim=1 at each tail
        // removes the worst value per coordinate, so attacks at
        // *different* coordinates are still absorbed one tail at a time.
        let mut corrupt = hc.clone();
        for (i, x) in corrupt.tensors[0].as_f32_mut().unwrap().iter_mut().enumerate() {
            *x = if i % 2 == 0 { f32::NAN } else { f32::INFINITY };
        }
        let mut scaled_s = hs.clone();
        for t in scaled_s.tensors.iter_mut() {
            for x in t.as_f32_mut().unwrap() {
                *x *= 100.0;
            }
        }
        let w = 1.0 / 6.0f32;
        let contribs: Vec<(f32, &AdapterSet, &AdapterSet)> = vec![
            (w, &hc, &hs),
            (w, &corrupt, &hs),
            (w, &hc, &hs),
            (w, &hc, &scaled_s),
            (w, &hc, &hs),
            (w, &hc, &hs),
        ];
        let mut col: Vec<(f32, f32)> = Vec::with_capacity(contribs.len());
        let mut dst = AdapterSet::zeros(&dims, 4);
        trimmed_fedavg_joined_into(&contribs, 1, &mut col, &mut dst).unwrap();
        assert!(dst.max_abs_diff(&honest).unwrap() < 1e-5, "trim=1 must recover the honest model");
        // Over-trimming and empty cohorts are rejected.
        assert!(trimmed_fedavg_joined_into(&contribs, 3, &mut col, &mut dst).is_err());
        assert!(trimmed_fedavg_joined_into(&[], 1, &mut col, &mut dst).is_err());
    }

    #[test]
    fn trimmed_mean_trim_zero_is_bitwise_fedavg() {
        let dims = dims();
        let fulls: Vec<AdapterSet> = (0..3).map(|i| AdapterSet::init(&dims, 4, 60 + i)).collect();
        let halves: Vec<(AdapterSet, AdapterSet)> =
            fulls.iter().map(|f| f.split_at(2).unwrap()).collect();
        let w = 1.0 / 3.0f32;
        let contribs: Vec<(f32, &AdapterSet, &AdapterSet)> =
            halves.iter().map(|(c, s)| (w, c, s)).collect();
        let mut reference = AdapterSet::zeros(&dims, 4);
        fedavg_joined_into(&contribs, &mut reference).unwrap();
        let mut col: Vec<(f32, f32)> = Vec::new();
        let mut dst = AdapterSet::zeros(&dims, 4);
        trimmed_fedavg_joined_into(&contribs, 0, &mut col, &mut dst).unwrap();
        assert_eq!(dst.max_abs_diff(&reference).unwrap(), 0.0);
    }

    #[test]
    fn clipped_fedavg_bounds_attacker_influence() {
        let dims = dims();
        let baseline = AdapterSet::zeros(&dims, 4);
        let honest = AdapterSet::zeros(&dims, 4); // zero delta
        let (hc, hs) = honest.split_at(2).unwrap();
        let mut attacker = AdapterSet::zeros(&dims, 4);
        for t in attacker.tensors.iter_mut() {
            t.as_f32_mut().unwrap().fill(5.0);
        }
        let (ac, as_) = attacker.split_at(2).unwrap();
        let clip = 0.25f64;
        let mut dst = AdapterSet::zeros(&dims, 4);
        let clipped = clipped_fedavg_joined_into(
            &[(0.5, &hc, &hs), (0.5, &ac, &as_)],
            &baseline,
            clip,
            &mut dst,
        )
        .unwrap();
        assert_eq!(clipped, 1);
        let norm: f64 = dst
            .tensors
            .iter()
            .map(|t| {
                let n = ops::l2_norm(t).unwrap() as f64;
                n * n
            })
            .sum::<f64>()
            .sqrt();
        // Attacker delta is rescaled to exactly `clip`, honest delta is
        // zero, so the aggregate moves by w·clip = 0.125.
        assert!((norm - 0.5 * clip).abs() < 1e-4 * clip, "got {norm}");
    }

    #[test]
    fn clipped_fedavg_zeroes_non_finite_updates() {
        let dims = dims();
        let baseline = AdapterSet::init(&dims, 4, 31);
        let honest = baseline.clone();
        let (hc, hs) = honest.split_at(2).unwrap();
        let mut corrupt_c = hc.clone();
        corrupt_c.tensors[0].as_f32_mut().unwrap().fill(f32::NAN);
        let mut dst = AdapterSet::zeros(&dims, 4);
        let clipped = clipped_fedavg_joined_into(
            &[(0.5, &hc, &hs), (0.5, &corrupt_c, &hs)],
            &baseline,
            1.0,
            &mut dst,
        )
        .unwrap();
        assert_eq!(clipped, 1);
        // Honest == baseline, corrupt zeroed to baseline ⇒ dst == baseline.
        for t in &dst.tensors {
            assert!(t.as_f32().unwrap().iter().all(|x| x.is_finite()));
        }
        assert!(dst.max_abs_diff(&baseline).unwrap() < 1e-6);
    }

    #[test]
    fn clipped_fedavg_infinite_threshold_is_bitwise_fedavg() {
        let dims = dims();
        let baseline = AdapterSet::init(&dims, 4, 41);
        let fulls: Vec<AdapterSet> = (0..3).map(|i| AdapterSet::init(&dims, 4, 70 + i)).collect();
        let halves: Vec<(AdapterSet, AdapterSet)> =
            fulls.iter().map(|f| f.split_at(3).unwrap()).collect();
        let w = 1.0 / 3.0f32;
        let contribs: Vec<(f32, &AdapterSet, &AdapterSet)> =
            halves.iter().map(|(c, s)| (w, c, s)).collect();
        let mut reference = AdapterSet::zeros(&dims, 4);
        fedavg_joined_into(&contribs, &mut reference).unwrap();
        let mut dst = AdapterSet::zeros(&dims, 4);
        let clipped =
            clipped_fedavg_joined_into(&contribs, &baseline, f64::INFINITY, &mut dst).unwrap();
        assert_eq!(clipped, 0);
        assert_eq!(dst.max_abs_diff(&reference).unwrap(), 0.0);
    }

    #[test]
    fn robust_kernels_are_tensor_alloc_free() {
        let dims = dims();
        let baseline = AdapterSet::init(&dims, 4, 51);
        let fulls: Vec<AdapterSet> = (0..4).map(|i| AdapterSet::init(&dims, 4, 80 + i)).collect();
        let halves: Vec<(AdapterSet, AdapterSet)> =
            fulls.iter().map(|f| f.split_at(2).unwrap()).collect();
        let w = 0.25f32;
        let contribs: Vec<(f32, &AdapterSet, &AdapterSet)> =
            halves.iter().map(|(c, s)| (w, c, s)).collect();
        let mut col: Vec<(f32, f32)> = Vec::with_capacity(contribs.len());
        let mut dst = AdapterSet::zeros(&dims, 4);
        let before = crate::tensor::alloc_count();
        trimmed_fedavg_joined_into(&contribs, 1, &mut col, &mut dst).unwrap();
        clipped_fedavg_joined_into(&contribs, &baseline, 0.5, &mut dst).unwrap();
        joined_delta_norm(&halves[0].0, &halves[0].1, &baseline).unwrap();
        joined_non_finite(&halves[0].0, &halves[0].1).unwrap();
        assert_eq!(crate::tensor::alloc_count(), before, "robust kernels must not allocate tensors");
    }
}
