//! Environment traces — non-stationary fleet dynamics.
//!
//! The scheduling results (Alg. 2, eqs. 10–12) assume static per-device
//! capability, but real mobile fleets drift: thermal throttling moves
//! MFU, wireless links fluctuate, and devices come and go.  This module
//! synthesizes exactly that drift as *deterministic, seeded traces* —
//! every fleet parameter becomes a function of simulated time:
//!
//! - [`Trace`] is the generator contract: `value_at(t)` advances the
//!   trace's internal state to virtual time `t` and returns its value.
//!   Sampling at the same `t` twice returns the same value without
//!   consuming randomness, so checkpointed sessions resume bit-exactly.
//! - Generators: [`Constant`], [`RandomWalk`] (bounded, mean-reverting),
//!   [`Diurnal`] (sinusoid + multiplicative jitter), [`MarkovOnOff`]
//!   (availability churn with exponential holding times), and
//!   [`Replay`] (a step function read from a jsonl trace file).
//! - [`timeline::EnvTimeline`] composes per-client generators into the
//!   fleet view the session samples once per round.
//! - [`NoisyObservation`] injects lognormal measurement noise between
//!   the simulated "true" timings and what the
//!   `TimingEstimator` observes.
//!
//! All randomness flows through the in-tree checkpointable
//! [`Rng`](crate::tensor::rng::Rng); each generator's mutable state is a
//! flat `u64` word list (`save_state`/`restore_state`), persisted with
//! the session checkpoint.

pub mod timeline;

pub use timeline::{EnvSnapshot, EnvTimeline};

use crate::tensor::rng::Rng;
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::rc::Rc;
use std::str::FromStr;

/// Which trace family drives the environment (`[trace]` config section,
/// `--trace` CLI flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceKind {
    /// Static environment (the paper's setting) — no timeline runs.
    #[default]
    None,
    /// Bounded mean-reverting random walks on MFU and link multipliers.
    RandomWalk,
    /// Sinusoidal MFU/link cycles with per-sample jitter (per-client
    /// phases) — daily thermal/usage waves.
    Diurnal,
    /// Two-state availability churn with exponential holding times;
    /// multipliers stay nominal.
    Markov,
    /// A shared MFU-multiplier trajectory replayed from a jsonl file.
    Replay,
}

impl FromStr for TraceKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Ok(Self::None),
            "random_walk" | "random-walk" | "walk" => Ok(Self::RandomWalk),
            "diurnal" => Ok(Self::Diurnal),
            "markov" => Ok(Self::Markov),
            "replay" => Ok(Self::Replay),
            other => bail!("unknown trace kind {other:?} (none|random_walk|diurnal|markov|replay)"),
        }
    }
}

impl std::fmt::Display for TraceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Self::None => "none",
            Self::RandomWalk => "random_walk",
            Self::Diurnal => "diurnal",
            Self::Markov => "markov",
            Self::Replay => "replay",
        };
        write!(f, "{s}")
    }
}

/// A seeded recipe for the environment timeline.  Same spec ⇒
/// bit-identical trajectory (given the same per-round sample times).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSpec {
    pub kind: TraceKind,
    pub seed: u64,
    /// Random-walk step σ (per √second) of the per-client MFU multiplier.
    pub mfu_sigma: f64,
    /// Random-walk step σ (per √second) of the per-client link multiplier.
    pub link_sigma: f64,
    /// Mean-reversion rate toward 1.0 (per second) for the walks.
    pub revert: f64,
    /// Diurnal period in virtual seconds.
    pub period: f64,
    /// Diurnal amplitude (fraction of nominal, in [0, 0.95]).
    pub amp: f64,
    /// Diurnal per-sample multiplicative jitter σ.
    pub jitter: f64,
    /// Markov mean up-time (virtual seconds).
    pub mean_up: f64,
    /// Markov mean down-time (virtual seconds).
    pub mean_down: f64,
    /// Lognormal σ of the measurement noise applied to the timings the
    /// estimator observes (0 disables — active even with `kind = none`).
    pub obs_noise_sigma: f64,
    /// Fleet-wide correlated drift: σ of one extra mean-reverting
    /// random-walk multiplier composed onto *every* client's MFU and
    /// link values (0 disables).  Models events that hit the whole
    /// fleet at once — regional throttling, a backbone brown-out — so
    /// attacks and fleet-wide slowdowns can coincide in benchmarks.
    /// Requires an active `kind` (the static timeline never runs).
    pub drift_sigma: f64,
    /// jsonl trace file for `kind = replay`.
    pub replay_path: String,
}

impl Default for TraceSpec {
    fn default() -> Self {
        Self {
            kind: TraceKind::None,
            seed: 7,
            mfu_sigma: 0.05,
            link_sigma: 0.05,
            revert: 0.02,
            period: 600.0,
            amp: 0.3,
            jitter: 0.02,
            mean_up: 300.0,
            mean_down: 60.0,
            obs_noise_sigma: 0.0,
            drift_sigma: 0.0,
            replay_path: String::new(),
        }
    }
}

impl TraceSpec {
    /// Whether any environment machinery must run (timeline or noise).
    pub fn is_static(&self) -> bool {
        self.kind == TraceKind::None && self.obs_noise_sigma <= 0.0
    }
}

/// FNV-1a content fingerprint — canonical definition lives in
/// [`crate::util::fnv1a`]; re-exported here because trace replay was
/// its first consumer and existing call sites name it via this path.
pub use crate::util::fnv1a;

/// A deterministic function of simulated time with checkpointable
/// internal state.
///
/// `value_at(t)` must be called with non-decreasing `t` (the session
/// samples once per round at the sim clock).  Calling it again at the
/// same `t` returns the stored value without consuming randomness —
/// the property that makes checkpoint/resume bit-exact.
pub trait Trace {
    /// Advance to virtual time `t` and return the trace value.
    fn value_at(&mut self, t: f64) -> f64;
    /// Number of `u64` words `save_state` appends.
    fn state_words(&self) -> usize;
    /// Append the mutable state (RNG bits, current value, last sample
    /// time) to `out`.
    fn save_state(&self, out: &mut Vec<u64>);
    /// Restore state saved by [`Trace::save_state`] (`words` holds
    /// exactly [`Trace::state_words`] entries).
    fn restore_state(&mut self, words: &[u64]) -> Result<()>;
}

fn words_exact<'w>(words: &'w [u64], n: usize, who: &str) -> Result<&'w [u64]> {
    if words.len() != n {
        bail!("{who} state has {} words, expected {n}", words.len());
    }
    Ok(words)
}

/// The degenerate trace: always `value` (stateless).
#[derive(Debug, Clone)]
pub struct Constant {
    pub value: f64,
}

impl Trace for Constant {
    fn value_at(&mut self, _t: f64) -> f64 {
        self.value
    }

    fn state_words(&self) -> usize {
        0
    }

    fn save_state(&self, _out: &mut Vec<u64>) {}

    fn restore_state(&mut self, words: &[u64]) -> Result<()> {
        words_exact(words, 0, "Constant").map(|_| ())
    }
}

/// Bounded mean-reverting random walk (discrete OU step): each sample
/// at `t` advances the value by `revert·dt` pull toward `mean` plus a
/// `sigma·√dt` Gaussian step, clamped to `[lo, hi]`.
#[derive(Debug, Clone)]
pub struct RandomWalk {
    rng: Rng,
    value: f64,
    mean: f64,
    sigma: f64,
    revert: f64,
    lo: f64,
    hi: f64,
    last_t: f64,
}

impl RandomWalk {
    pub fn new(seed: u64, mean: f64, sigma: f64, revert: f64, lo: f64, hi: f64) -> Self {
        Self { rng: Rng::new(seed), value: mean, mean, sigma, revert, lo, hi, last_t: 0.0 }
    }
}

impl Trace for RandomWalk {
    fn value_at(&mut self, t: f64) -> f64 {
        if t > self.last_t {
            let dt = t - self.last_t;
            // Cap the reversion pull at 1 so huge gaps between samples
            // cannot overshoot past the mean and oscillate.
            let pull = (self.revert * dt).min(1.0);
            let step = self.sigma * dt.sqrt() * self.rng.normal();
            let next = self.value + pull * (self.mean - self.value) + step;
            self.value = next.clamp(self.lo, self.hi);
            self.last_t = t;
        }
        self.value
    }

    fn state_words(&self) -> usize {
        3
    }

    fn save_state(&self, out: &mut Vec<u64>) {
        out.extend_from_slice(&[self.rng.state(), self.value.to_bits(), self.last_t.to_bits()]);
    }

    fn restore_state(&mut self, words: &[u64]) -> Result<()> {
        let w = words_exact(words, 3, "RandomWalk")?;
        self.rng = Rng::from_state(w[0]);
        self.value = f64::from_bits(w[1]);
        self.last_t = f64::from_bits(w[2]);
        Ok(())
    }
}

/// Sinusoid around `base` with per-sample multiplicative lognormal
/// jitter: `base · (1 + amp·sin(2πt/period + phase)) · e^{jitter·N}`,
/// floored at a small positive value.
#[derive(Debug, Clone)]
pub struct Diurnal {
    rng: Rng,
    base: f64,
    amp: f64,
    period: f64,
    phase: f64,
    jitter: f64,
    value: f64,
    last_t: f64,
}

impl Diurnal {
    pub fn new(seed: u64, base: f64, amp: f64, period: f64, phase: f64, jitter: f64) -> Self {
        let value = base * (1.0 + amp * phase.sin());
        Self { rng: Rng::new(seed), base, amp, period, phase, jitter, value, last_t: 0.0 }
    }
}

impl Trace for Diurnal {
    fn value_at(&mut self, t: f64) -> f64 {
        if t > self.last_t {
            let s = self.base
                * (1.0 + self.amp * (std::f64::consts::TAU * t / self.period + self.phase).sin());
            self.value = (s * self.rng.lognormal(0.0, self.jitter)).max(0.05);
            self.last_t = t;
        }
        self.value
    }

    fn state_words(&self) -> usize {
        3
    }

    fn save_state(&self, out: &mut Vec<u64>) {
        out.extend_from_slice(&[self.rng.state(), self.value.to_bits(), self.last_t.to_bits()]);
    }

    fn restore_state(&mut self, words: &[u64]) -> Result<()> {
        let w = words_exact(words, 3, "Diurnal")?;
        self.rng = Rng::from_state(w[0]);
        self.value = f64::from_bits(w[1]);
        self.last_t = f64::from_bits(w[2]);
        Ok(())
    }
}

/// Two-state availability churn: a continuous-time Markov chain with
/// exponential holding times (means `mean_up`/`mean_down`), observed at
/// the sample instants via its *exact* transition probabilities
/// `P(flip | dt) = (rate_out/s)·(1 − e^(−s·dt))` with
/// `s = 1/mean_up + 1/mean_down` — so the long-run availability equals
/// [`MarkovOnOff::stationary_availability`] at *any* sampling interval,
/// including the round-scale gaps a 100-client makespan produces (a
/// naive single-flip `1 − e^(−dt/hold)` discretization skews the
/// stationary distribution once `dt` approaches the holding times).
/// `value_at` returns 1.0 (up) or 0.0 (down).  The initial state is
/// drawn from the stationary distribution.
#[derive(Debug, Clone)]
pub struct MarkovOnOff {
    rng: Rng,
    up: bool,
    mean_up: f64,
    mean_down: f64,
    last_t: f64,
}

impl MarkovOnOff {
    pub fn new(seed: u64, mean_up: f64, mean_down: f64) -> Self {
        let mut rng = Rng::new(seed);
        let up = rng.uniform() < mean_up / (mean_up + mean_down);
        Self { rng, up, mean_up, mean_down, last_t: 0.0 }
    }

    /// The chain's long-run fraction of up time.
    pub fn stationary_availability(&self) -> f64 {
        self.mean_up / (self.mean_up + self.mean_down)
    }
}

impl Trace for MarkovOnOff {
    fn value_at(&mut self, t: f64) -> f64 {
        if t > self.last_t {
            let dt = t - self.last_t;
            // Exact 2-state CTMC transition probability over dt:
            // P(up→down) = (λ_down/s)(1−e^{−s·dt}), λ_down = 1/mean_up,
            // s = 1/mean_up + 1/mean_down — detailed balance holds for
            // any dt, so the observed chain stays stationary-correct.
            let rate_out = 1.0 / if self.up { self.mean_up } else { self.mean_down };
            let s = 1.0 / self.mean_up + 1.0 / self.mean_down;
            let p_flip = (rate_out / s) * (1.0 - (-s * dt).exp());
            if self.rng.uniform() < p_flip {
                self.up = !self.up;
            }
            self.last_t = t;
        }
        if self.up {
            1.0
        } else {
            0.0
        }
    }

    fn state_words(&self) -> usize {
        3
    }

    fn save_state(&self, out: &mut Vec<u64>) {
        out.extend_from_slice(&[self.rng.state(), self.up as u64, self.last_t.to_bits()]);
    }

    fn restore_state(&mut self, words: &[u64]) -> Result<()> {
        let w = words_exact(words, 3, "MarkovOnOff")?;
        self.rng = Rng::from_state(w[0]);
        self.up = w[1] != 0;
        self.last_t = f64::from_bits(w[2]);
        Ok(())
    }
}

/// A recorded trajectory replayed as a step function: `value_at(t)` is
/// the value of the last point with timestamp ≤ `t` (the first point's
/// value before the recording starts).  Points are shared (`Rc`) so a
/// fleet-wide replay costs one parse.  Stateless — the jsonl content is
/// the whole trace, which is why resume fingerprints the file content.
#[derive(Debug, Clone)]
pub struct Replay {
    points: Rc<Vec<(f64, f64)>>,
}

impl Replay {
    /// Build from `(t, value)` points; `t` must be non-decreasing and
    /// every value finite.
    pub fn from_points(points: Vec<(f64, f64)>) -> Result<Self> {
        if points.is_empty() {
            bail!("replay trace needs at least one point");
        }
        for (i, &(t, v)) in points.iter().enumerate() {
            if !t.is_finite() || !v.is_finite() {
                bail!("replay point {i} is not finite: ({t}, {v})");
            }
            if i > 0 && t < points[i - 1].0 {
                bail!("replay timestamps must be non-decreasing (point {i}: {t})");
            }
        }
        Ok(Self { points: Rc::new(points) })
    }

    pub fn points(&self) -> &[(f64, f64)] {
        &self.points
    }

    /// Parse the jsonl trace format: one `{"t": <secs>, "v": <value>}`
    /// object per line (blank lines ignored).
    pub fn parse_jsonl(text: &str) -> Result<Self> {
        let mut points = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            let body = line
                .strip_prefix('{')
                .and_then(|s| s.strip_suffix('}'))
                .with_context(|| format!("trace line {}: expected a JSON object", lineno + 1))?;
            let (mut t, mut v) = (None, None);
            for part in body.split(',') {
                let (key, val) = part
                    .split_once(':')
                    .with_context(|| format!("trace line {}: expected key:value", lineno + 1))?;
                let num: f64 = val.trim().parse().with_context(|| {
                    format!("trace line {}: bad number {:?}", lineno + 1, val.trim())
                })?;
                match key.trim().trim_matches('"') {
                    "t" => t = Some(num),
                    "v" => v = Some(num),
                    other => bail!("trace line {}: unknown key {other:?}", lineno + 1),
                }
            }
            match (t, v) {
                (Some(t), Some(v)) => points.push((t, v)),
                _ => bail!("trace line {}: needs both \"t\" and \"v\"", lineno + 1),
            }
        }
        Self::from_points(points)
    }

    /// Serialize back to the jsonl format ([`Replay::parse_jsonl`]'s
    /// inverse; round-trips bit-exactly through the `{:?}` float form).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for &(t, v) in self.points.iter() {
            out.push_str(&format!("{{\"t\": {t:?}, \"v\": {v:?}}}\n"));
        }
        out
    }

    /// Load from a jsonl file, returning the trace and the raw content
    /// hash (see [`fnv1a`]) for resume verification.
    pub fn load(path: &Path) -> Result<(Self, u64)> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading replay trace {}", path.display()))?;
        let hash = fnv1a(text.as_bytes());
        let replay = Self::parse_jsonl(&text)
            .with_context(|| format!("parsing replay trace {}", path.display()))?;
        Ok((replay, hash))
    }
}

impl Trace for Replay {
    fn value_at(&mut self, t: f64) -> f64 {
        // Last point with timestamp <= t; the first value before that.
        match self.points.partition_point(|&(pt, _)| pt <= t) {
            0 => self.points[0].1,
            i => self.points[i - 1].1,
        }
    }

    fn state_words(&self) -> usize {
        0
    }

    fn save_state(&self, _out: &mut Vec<u64>) {}

    fn restore_state(&mut self, words: &[u64]) -> Result<()> {
        words_exact(words, 0, "Replay").map(|_| ())
    }
}

/// Closed set of generators the timeline composes (enum, not `Box<dyn>`,
/// so per-client traces stay allocation-light at fleet scale).
#[derive(Debug, Clone)]
pub enum TraceGen {
    Constant(Constant),
    Walk(RandomWalk),
    Diurnal(Diurnal),
    OnOff(MarkovOnOff),
    Replay(Replay),
}

impl Trace for TraceGen {
    fn value_at(&mut self, t: f64) -> f64 {
        match self {
            Self::Constant(g) => g.value_at(t),
            Self::Walk(g) => g.value_at(t),
            Self::Diurnal(g) => g.value_at(t),
            Self::OnOff(g) => g.value_at(t),
            Self::Replay(g) => g.value_at(t),
        }
    }

    fn state_words(&self) -> usize {
        match self {
            Self::Constant(g) => g.state_words(),
            Self::Walk(g) => g.state_words(),
            Self::Diurnal(g) => g.state_words(),
            Self::OnOff(g) => g.state_words(),
            Self::Replay(g) => g.state_words(),
        }
    }

    fn save_state(&self, out: &mut Vec<u64>) {
        match self {
            Self::Constant(g) => g.save_state(out),
            Self::Walk(g) => g.save_state(out),
            Self::Diurnal(g) => g.save_state(out),
            Self::OnOff(g) => g.save_state(out),
            Self::Replay(g) => g.save_state(out),
        }
    }

    fn restore_state(&mut self, words: &[u64]) -> Result<()> {
        match self {
            Self::Constant(g) => g.restore_state(words),
            Self::Walk(g) => g.restore_state(words),
            Self::Diurnal(g) => g.restore_state(words),
            Self::OnOff(g) => g.restore_state(words),
            Self::Replay(g) => g.restore_state(words),
        }
    }
}

/// Multiplicative lognormal measurement noise between the simulated
/// true timings and what the estimator observes (`--obs-noise-sigma`).
/// Inactive (`sigma ≤ 0`) draws nothing from the RNG, so enabling the
/// knob never perturbs other streams.
#[derive(Debug, Clone)]
pub struct NoisyObservation {
    rng: Rng,
    // sflint:allow(checkpoint-coverage, noise level is fixed at construction)
    sigma: f64,
}

impl NoisyObservation {
    pub fn new(seed: u64, sigma: f64) -> Self {
        Self { rng: Rng::new(seed), sigma }
    }

    pub fn is_active(&self) -> bool {
        self.sigma > 0.0
    }

    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    /// One multiplicative noise factor (median 1).
    pub fn factor(&mut self) -> f64 {
        if self.sigma <= 0.0 {
            1.0
        } else {
            self.rng.lognormal(0.0, self.sigma)
        }
    }

    /// RNG state for checkpointing.
    pub fn state(&self) -> u64 {
        self.rng.state()
    }

    /// Restore from [`NoisyObservation::state`].
    pub fn restore_state(&mut self, state: u64) {
        self.rng = Rng::from_state(state);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_kind_parsing_roundtrips() {
        for kind in [
            TraceKind::None,
            TraceKind::RandomWalk,
            TraceKind::Diurnal,
            TraceKind::Markov,
            TraceKind::Replay,
        ] {
            assert_eq!(kind.to_string().parse::<TraceKind>().unwrap(), kind);
        }
        assert_eq!("random-walk".parse::<TraceKind>().unwrap(), TraceKind::RandomWalk);
        assert!("bogus".parse::<TraceKind>().is_err());
    }

    #[test]
    fn constant_is_constant_and_stateless() {
        let mut c = Constant { value: 1.5 };
        assert_eq!(c.value_at(0.0), 1.5);
        assert_eq!(c.value_at(1e9), 1.5);
        let mut out = Vec::new();
        c.save_state(&mut out);
        assert!(out.is_empty());
        assert!(c.restore_state(&[1]).is_err());
    }

    #[test]
    fn random_walk_is_deterministic_bounded_and_mean_reverting() {
        let mut a = RandomWalk::new(3, 1.0, 0.2, 0.05, 0.2, 5.0);
        let mut b = RandomWalk::new(3, 1.0, 0.2, 0.05, 0.2, 5.0);
        let mut sum = 0.0;
        let n = 5_000;
        for i in 1..=n {
            let t = i as f64 * 2.0;
            let (va, vb) = (a.value_at(t), b.value_at(t));
            assert_eq!(va.to_bits(), vb.to_bits(), "walk not deterministic at t={t}");
            assert!((0.2..=5.0).contains(&va), "walk out of bounds: {va}");
            sum += va;
        }
        let mean = sum / n as f64;
        assert!((mean - 1.0).abs() < 0.35, "walk drifted off its mean: {mean}");
    }

    #[test]
    fn resampling_the_same_time_consumes_no_randomness() {
        let mut w = RandomWalk::new(9, 1.0, 0.1, 0.02, 0.2, 5.0);
        let v1 = w.value_at(10.0);
        let mut st = Vec::new();
        w.save_state(&mut st);
        let v2 = w.value_at(10.0);
        let mut st2 = Vec::new();
        w.save_state(&mut st2);
        assert_eq!(v1.to_bits(), v2.to_bits());
        assert_eq!(st, st2, "same-t sample must not advance the RNG");
    }

    #[test]
    fn walk_state_roundtrip_resumes_bit_exactly() {
        let mut a = RandomWalk::new(11, 1.0, 0.15, 0.03, 0.2, 5.0);
        for i in 1..=7 {
            a.value_at(i as f64 * 3.1);
        }
        let mut words = Vec::new();
        a.save_state(&mut words);
        let mut b = RandomWalk::new(11, 1.0, 0.15, 0.03, 0.2, 5.0);
        b.restore_state(&words).unwrap();
        for i in 8..=20 {
            let t = i as f64 * 3.1;
            assert_eq!(a.value_at(t).to_bits(), b.value_at(t).to_bits(), "diverged at t={t}");
        }
        assert!(b.restore_state(&words[..2]).is_err());
    }

    #[test]
    fn diurnal_follows_its_period() {
        // Jitter off: the sinusoid repeats every period.
        let mut d = Diurnal::new(5, 1.0, 0.4, 100.0, 0.3, 0.0);
        let v1 = d.value_at(30.0);
        let v2 = d.value_at(130.0);
        assert!((v1 - v2).abs() < 1e-9, "{v1} vs {v2}");
        // Amplitude reached: values spread across the configured band.
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for i in 0..200 {
            let v = d.value_at(131.0 + i as f64);
            lo = lo.min(v);
            hi = hi.max(v);
        }
        assert!(lo < 0.7 && hi > 1.3, "sinusoid band too narrow: {lo}..{hi}");
    }

    #[test]
    fn markov_long_run_availability_matches_stationary_distribution() {
        let mut m = MarkovOnOff::new(13, 300.0, 100.0);
        let expect = m.stationary_availability();
        assert!((expect - 0.75).abs() < 1e-12);
        let mut up = 0usize;
        let n = 40_000;
        for i in 1..=n {
            if m.value_at(i as f64 * 5.0) > 0.5 {
                up += 1;
            }
        }
        let frac = up as f64 / n as f64;
        assert!((frac - expect).abs() < 0.06, "availability {frac} vs stationary {expect}");
    }

    #[test]
    fn markov_state_roundtrip_resumes_bit_exactly() {
        let mut a = MarkovOnOff::new(17, 50.0, 20.0);
        for i in 1..=30 {
            a.value_at(i as f64 * 7.0);
        }
        let mut words = Vec::new();
        a.save_state(&mut words);
        let mut b = MarkovOnOff::new(17, 50.0, 20.0);
        b.restore_state(&words).unwrap();
        for i in 31..=120 {
            let t = i as f64 * 7.0;
            assert_eq!(a.value_at(t).to_bits(), b.value_at(t).to_bits(), "diverged at t={t}");
        }
    }

    #[test]
    fn replay_roundtrips_through_jsonl() {
        let r = Replay::from_points(vec![(0.0, 1.0), (5.0, 0.7), (9.5, 1.25)]).unwrap();
        let text = r.to_jsonl();
        let back = Replay::parse_jsonl(&text).unwrap();
        assert_eq!(r.points().len(), back.points().len());
        for (&(ta, va), &(tb, vb)) in r.points().iter().zip(back.points().iter()) {
            assert_eq!(ta.to_bits(), tb.to_bits());
            assert_eq!(va.to_bits(), vb.to_bits());
        }
        // Step-function semantics.
        let mut back = back;
        assert_eq!(back.value_at(-1.0), 1.0); // before the recording
        assert_eq!(back.value_at(0.0), 1.0);
        assert_eq!(back.value_at(4.999), 1.0);
        assert_eq!(back.value_at(5.0), 0.7);
        assert_eq!(back.value_at(100.0), 1.25);
    }

    #[test]
    fn replay_rejects_malformed_input() {
        assert!(Replay::from_points(vec![]).is_err());
        assert!(Replay::from_points(vec![(1.0, 1.0), (0.5, 1.0)]).is_err());
        assert!(Replay::from_points(vec![(0.0, f64::NAN)]).is_err());
        assert!(Replay::parse_jsonl("not json\n").is_err());
        assert!(Replay::parse_jsonl("{\"t\": 0.0}\n").is_err());
        assert!(Replay::parse_jsonl("{\"t\": 0.0, \"x\": 1.0}\n").is_err());
        assert!(Replay::load(Path::new("/nonexistent/trace.jsonl")).is_err());
    }

    #[test]
    fn noisy_observation_is_median_one_and_inert_at_sigma_zero() {
        let mut off = NoisyObservation::new(1, 0.0);
        let st = off.state();
        assert!(!off.is_active());
        assert_eq!(off.factor(), 1.0);
        assert_eq!(off.state(), st, "sigma=0 must not consume RNG");

        let mut on = NoisyObservation::new(1, 0.3);
        assert!(on.is_active());
        let n = 10_000;
        let mut below = 0usize;
        for _ in 0..n {
            let f = on.factor();
            assert!(f > 0.0);
            if f < 1.0 {
                below += 1;
            }
        }
        let frac = below as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.03, "lognormal median off 1: {frac}");
        let mut twin = NoisyObservation::new(1, 0.3);
        twin.restore_state(on.state());
        assert_eq!(twin.factor().to_bits(), on.factor().to_bits());
    }

    #[test]
    fn fnv1a_is_stable_and_content_sensitive() {
        assert_eq!(fnv1a(b""), 0xCBF2_9CE4_8422_2325);
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
        assert_eq!(fnv1a(b"trace"), fnv1a(b"trace"));
    }
}
