//! The fleet-wide environment timeline: per-client MFU multipliers,
//! link-bandwidth multipliers, and availability, each a [`Trace`]
//! sampled once per round at the session's current virtual time.
//!
//! The timeline owns only *multipliers* — the synthesized fleet (and
//! its hidden MFU jitter) stays the static baseline; the timeline
//! modulates it over simulated time.  An unavailable client is
//! *skipped* for the round (composing with dropout sampling), never
//! removed from the fleet.
//!
//! Determinism contract: the timeline is re-synthesized from its
//! [`TraceSpec`] on session construction (exactly like
//! `fleet::FleetSpec`), and only the mutable per-generator state (RNG
//! bits, current values, last sample time) is checkpointed — so a
//! resumed session continues the identical trajectory bit-exactly.

use super::{
    Constant, Diurnal, MarkovOnOff, RandomWalk, Replay, Trace, TraceGen, TraceKind, TraceSpec,
};
use anyhow::{bail, Result};
use std::path::Path;

/// Multiplier clamp for MFU/link traces — keeps pathological walks from
/// producing zero or absurd device speeds.
const MULT_LO: f64 = 0.2;
const MULT_HI: f64 = 5.0;

/// One round's fleet-wide environment summary (telemetry).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnvSnapshot {
    /// Mean MFU multiplier across the fleet.
    pub mfu_mean: f64,
    /// Mean link multiplier across the fleet.
    pub link_mean: f64,
    /// Number of currently available clients.
    pub available: usize,
}

/// Per-client environment traces, sampled once per round.
#[derive(Debug)]
pub struct EnvTimeline {
    // sflint:allow(checkpoint-coverage, rebuilt from config at load)
    kind: TraceKind,
    mfu: Vec<TraceGen>,
    link: Vec<TraceGen>,
    avail: Vec<TraceGen>,
    // sflint:allow(checkpoint-coverage, re-sampled from the restored generators each round)
    cur_mfu: Vec<f64>,
    // sflint:allow(checkpoint-coverage, re-sampled from the restored generators each round)
    cur_link: Vec<f64>,
    // sflint:allow(checkpoint-coverage, re-sampled from the restored generators each round)
    cur_avail: Vec<bool>,
    /// Fleet-wide correlated drift multiplier composed onto every
    /// client's MFU and link samples (`spec.drift_sigma > 0`).  One
    /// shared mean-reverting walk — regional throttling, backbone
    /// brown-outs — seeded *after* the per-client generators so a
    /// drift-off spec draws the identical per-client streams.
    drift: Option<TraceGen>,
    // sflint:allow(checkpoint-coverage, re-sampled from the restored drift walk each round)
    cur_drift: f64,
    /// FNV-1a of the replay file's content (0 for non-replay kinds) —
    /// verified on resume so a changed or re-generated trace file fails
    /// loudly instead of silently desyncing the trajectory.
    // sflint:allow(checkpoint-coverage, recomputed from the trace file at load)
    replay_hash: u64,
}

impl EnvTimeline {
    /// The static timeline: no traces, every multiplier 1, everyone
    /// available.  What `kind = none` (the paper's setting) builds.
    pub fn inactive() -> Self {
        Self {
            kind: TraceKind::None,
            mfu: Vec::new(),
            link: Vec::new(),
            avail: Vec::new(),
            cur_mfu: Vec::new(),
            cur_link: Vec::new(),
            cur_avail: Vec::new(),
            drift: None,
            cur_drift: 1.0,
            replay_hash: 0,
        }
    }

    /// Synthesize the timeline for `n` clients from a spec.  Same spec
    /// ⇒ bit-identical trajectory (given the same sample times).
    pub fn new(spec: &TraceSpec, n: usize) -> Result<Self> {
        if spec.kind == TraceKind::None {
            return Ok(Self::inactive());
        }
        let mut root = crate::tensor::rng::Rng::new(spec.seed ^ 0x7AC3_5EED);
        let ones = || TraceGen::Constant(Constant { value: 1.0 });
        let mut mfu = Vec::with_capacity(n);
        let mut link = Vec::with_capacity(n);
        let mut avail = Vec::with_capacity(n);
        let mut replay_hash = 0u64;
        match spec.kind {
            TraceKind::None => unreachable!("handled above"),
            TraceKind::RandomWalk => {
                for _ in 0..n {
                    mfu.push(TraceGen::Walk(RandomWalk::new(
                        root.next_u64(),
                        1.0,
                        spec.mfu_sigma,
                        spec.revert,
                        MULT_LO,
                        MULT_HI,
                    )));
                    link.push(TraceGen::Walk(RandomWalk::new(
                        root.next_u64(),
                        1.0,
                        spec.link_sigma,
                        spec.revert,
                        MULT_LO,
                        MULT_HI,
                    )));
                    avail.push(ones());
                }
            }
            TraceKind::Diurnal => {
                for _ in 0..n {
                    let phase = root.uniform() * std::f64::consts::TAU;
                    mfu.push(TraceGen::Diurnal(Diurnal::new(
                        root.next_u64(),
                        1.0,
                        spec.amp,
                        spec.period,
                        phase,
                        spec.jitter,
                    )));
                    let link_phase = root.uniform() * std::f64::consts::TAU;
                    link.push(TraceGen::Diurnal(Diurnal::new(
                        root.next_u64(),
                        1.0,
                        spec.amp * 0.5,
                        spec.period,
                        link_phase,
                        spec.jitter,
                    )));
                    avail.push(ones());
                }
            }
            TraceKind::Markov => {
                for _ in 0..n {
                    mfu.push(ones());
                    link.push(ones());
                    avail.push(TraceGen::OnOff(MarkovOnOff::new(
                        root.next_u64(),
                        spec.mean_up,
                        spec.mean_down,
                    )));
                }
            }
            TraceKind::Replay => {
                let (replay, hash) = Replay::load(Path::new(&spec.replay_path))?;
                replay_hash = hash;
                // One shared trajectory broadcast to the whole fleet:
                // a single generator, sampled once per `advance` —
                // not n clones doing n identical binary searches.
                mfu.push(TraceGen::Replay(replay));
            }
        }
        // Drift is seeded from the *tail* of the root stream: a
        // drift-off spec draws nothing here, so every per-client
        // generator above keeps its exact historical seed.
        let drift = if spec.drift_sigma > 0.0 {
            Some(TraceGen::Walk(RandomWalk::new(
                root.next_u64(),
                1.0,
                spec.drift_sigma,
                spec.revert,
                MULT_LO,
                MULT_HI,
            )))
        } else {
            None
        };
        Ok(Self {
            kind: spec.kind,
            mfu,
            link,
            avail,
            cur_mfu: vec![1.0; n],
            cur_link: vec![1.0; n],
            cur_avail: vec![true; n],
            drift,
            cur_drift: 1.0,
            replay_hash,
        })
    }

    /// Whether any traces run (false for the static `none` timeline).
    pub fn is_active(&self) -> bool {
        self.kind != TraceKind::None
    }

    pub fn kind(&self) -> TraceKind {
        self.kind
    }

    pub fn n_clients(&self) -> usize {
        self.cur_mfu.len()
    }

    /// Content hash of the replay trace file (0 unless `kind = replay`).
    pub fn replay_hash(&self) -> u64 {
        self.replay_hash
    }

    /// Sample every trace at virtual time `t` into the current
    /// snapshot.  Called once per round; re-sampling the same `t`
    /// changes nothing (and consumes no randomness).
    pub fn advance(&mut self, t: f64) {
        // Fleet-wide drift multiplier: sampled once, composed onto
        // every client's MFU and link values (×1.0 when off — which is
        // bit-identical to not multiplying at all).
        self.cur_drift = match &mut self.drift {
            Some(g) => g.value_at(t).clamp(MULT_LO, MULT_HI),
            None => 1.0,
        };
        let d = self.cur_drift;
        if self.kind == TraceKind::Replay {
            // The fleet shares one replayed trajectory: sample it once
            // and broadcast (link/avail snapshots stay at their
            // constant 1.0 / true).
            let v = (self.mfu[0].value_at(t) * d).clamp(MULT_LO, MULT_HI);
            self.cur_mfu.fill(v);
            return;
        }
        for u in 0..self.mfu.len() {
            self.cur_mfu[u] = (self.mfu[u].value_at(t) * d).clamp(MULT_LO, MULT_HI);
            self.cur_link[u] = (self.link[u].value_at(t) * d).clamp(MULT_LO, MULT_HI);
            self.cur_avail[u] = self.avail[u].value_at(t) >= 0.5;
        }
    }

    /// The current fleet-wide drift multiplier (1 when drift is off).
    pub fn drift_mult(&self) -> f64 {
        self.cur_drift
    }

    /// Client `u`'s current MFU multiplier (1 when inactive).
    pub fn mfu_mult(&self, u: usize) -> f64 {
        if self.cur_mfu.is_empty() {
            1.0
        } else {
            self.cur_mfu[u]
        }
    }

    /// Client `u`'s current link-rate multiplier (1 when inactive).
    pub fn link_mult(&self, u: usize) -> f64 {
        if self.cur_link.is_empty() {
            1.0
        } else {
            self.cur_link[u]
        }
    }

    /// Whether client `u` is currently reachable (true when inactive).
    pub fn is_available(&self, u: usize) -> bool {
        self.cur_avail.is_empty() || self.cur_avail[u]
    }

    /// Fleet-wide summary of the current sample (telemetry).
    pub fn snapshot(&self) -> EnvSnapshot {
        let n = self.cur_mfu.len().max(1) as f64;
        EnvSnapshot {
            mfu_mean: self.cur_mfu.iter().sum::<f64>() / n,
            link_mean: self.cur_link.iter().sum::<f64>() / n,
            available: self.cur_avail.iter().filter(|&&a| a).count(),
        }
    }

    /// Flat checkpoint state: every generator's words, in a fixed
    /// (all mfu, all link, all avail) order.  Replay and constant
    /// generators contribute zero words, so a replay timeline's state
    /// is empty — its trajectory is the (hash-verified) file content.
    pub fn state(&self) -> Vec<u64> {
        let mut out = Vec::new();
        for gen in self.mfu.iter().chain(self.link.iter()).chain(self.avail.iter()) {
            gen.save_state(&mut out);
        }
        // Drift words ride at the very end so drift-off checkpoints
        // keep their historical layout.
        if let Some(g) = &self.drift {
            g.save_state(&mut out);
        }
        out
    }

    /// Restore from [`EnvTimeline::state`] — the timeline must have
    /// been re-synthesized from the *same* spec first.  The next
    /// `advance` rebuilds the current snapshot from the restored
    /// generator states.
    pub fn restore_state(&mut self, words: &[u64]) -> Result<()> {
        let gens = || self.mfu.iter().chain(self.link.iter()).chain(self.avail.iter());
        let expected: usize = gens().map(|g| g.state_words()).sum::<usize>()
            + self.drift.as_ref().map_or(0, |g| g.state_words());
        if words.len() != expected {
            bail!(
                "timeline state has {} words, expected {expected} — checkpoint was taken \
                 under a different trace configuration",
                words.len()
            );
        }
        let mut off = 0usize;
        for gen in self
            .mfu
            .iter_mut()
            .chain(self.link.iter_mut())
            .chain(self.avail.iter_mut())
        {
            let n = gen.state_words();
            gen.restore_state(&words[off..off + n])?;
            off += n;
        }
        if let Some(g) = &mut self.drift {
            let n = g.state_words();
            g.restore_state(&words[off..off + n])?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn walk_spec() -> TraceSpec {
        TraceSpec { kind: TraceKind::RandomWalk, seed: 21, ..TraceSpec::default() }
    }

    #[test]
    fn inactive_timeline_is_identity() {
        let tl = EnvTimeline::inactive();
        assert!(!tl.is_active());
        assert_eq!(tl.mfu_mult(3), 1.0);
        assert_eq!(tl.link_mult(0), 1.0);
        assert!(tl.is_available(99));
        assert!(tl.state().is_empty());
        let none = TraceSpec::default();
        let built = EnvTimeline::new(&none, 8).unwrap();
        assert!(!built.is_active());
        assert!(built.state().is_empty());
    }

    #[test]
    fn walk_timeline_is_deterministic_and_moves() {
        let spec = walk_spec();
        let mut a = EnvTimeline::new(&spec, 16).unwrap();
        let mut b = EnvTimeline::new(&spec, 16).unwrap();
        let mut moved = false;
        for r in 1..=20 {
            let t = r as f64 * 9.0;
            a.advance(t);
            b.advance(t);
            for u in 0..16 {
                assert_eq!(a.mfu_mult(u).to_bits(), b.mfu_mult(u).to_bits());
                assert_eq!(a.link_mult(u).to_bits(), b.link_mult(u).to_bits());
                assert!((MULT_LO..=MULT_HI).contains(&a.mfu_mult(u)));
                if (a.mfu_mult(u) - 1.0).abs() > 1e-3 {
                    moved = true;
                }
            }
        }
        assert!(moved, "random-walk timeline never left nominal");
        // Different seed, different trajectory.
        let mut c = EnvTimeline::new(&TraceSpec { seed: 22, ..spec }, 16).unwrap();
        c.advance(9.0);
        let mut fresh = EnvTimeline::new(&walk_spec(), 16).unwrap();
        fresh.advance(9.0);
        assert!(
            (0..16).any(|u| fresh.mfu_mult(u).to_bits() != c.mfu_mult(u).to_bits()),
            "seed ignored"
        );
    }

    #[test]
    fn markov_timeline_churns_but_only_availability() {
        let spec = TraceSpec {
            kind: TraceKind::Markov,
            seed: 3,
            mean_up: 50.0,
            mean_down: 25.0,
            ..TraceSpec::default()
        };
        let mut tl = EnvTimeline::new(&spec, 32).unwrap();
        let mut saw_down = false;
        for r in 1..=40 {
            tl.advance(r as f64 * 10.0);
            for u in 0..32 {
                assert_eq!(tl.mfu_mult(u), 1.0);
                assert_eq!(tl.link_mult(u), 1.0);
                if !tl.is_available(u) {
                    saw_down = true;
                }
            }
            let snap = tl.snapshot();
            assert_eq!(snap.available, (0..32).filter(|&u| tl.is_available(u)).count());
        }
        assert!(saw_down, "markov timeline never took a client down");
    }

    #[test]
    fn timeline_state_roundtrip_is_bit_exact_mid_trajectory() {
        for kind in [TraceKind::RandomWalk, TraceKind::Diurnal, TraceKind::Markov] {
            let spec = TraceSpec { kind, seed: 31, mean_up: 40.0, ..TraceSpec::default() };
            let mut a = EnvTimeline::new(&spec, 8).unwrap();
            for r in 1..=6 {
                a.advance(r as f64 * 7.3);
            }
            let words = a.state();
            // Restore into a *fresh* timeline (the resume path).
            let mut b = EnvTimeline::new(&spec, 8).unwrap();
            b.restore_state(&words).unwrap();
            for r in 7..=30 {
                let t = r as f64 * 7.3;
                a.advance(t);
                b.advance(t);
                for u in 0..8 {
                    assert_eq!(
                        a.mfu_mult(u).to_bits(),
                        b.mfu_mult(u).to_bits(),
                        "{kind:?}: mfu diverged at t={t}"
                    );
                    assert_eq!(a.is_available(u), b.is_available(u), "{kind:?}: avail at t={t}");
                }
            }
            // Word-count mismatch (different trace config) is rejected.
            assert!(b.restore_state(&words[..words.len() - 1]).is_err());
        }
    }

    #[test]
    fn replay_timeline_shares_the_trajectory_and_hashes_content() {
        let dir = std::env::temp_dir().join("sfl_trace_timeline_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("replay.jsonl");
        std::fs::write(&path, "{\"t\": 0.0, \"v\": 1.0}\n{\"t\": 10.0, \"v\": 0.5}\n").unwrap();
        let spec = TraceSpec {
            kind: TraceKind::Replay,
            replay_path: path.to_string_lossy().into_owned(),
            ..TraceSpec::default()
        };
        let mut tl = EnvTimeline::new(&spec, 4).unwrap();
        assert_ne!(tl.replay_hash(), 0);
        tl.advance(5.0);
        for u in 0..4 {
            assert_eq!(tl.mfu_mult(u), 1.0);
        }
        tl.advance(11.0);
        for u in 0..4 {
            assert_eq!(tl.mfu_mult(u), 0.5);
            assert_eq!(tl.link_mult(u), 1.0);
            assert!(tl.is_available(u));
        }
        // The broadcast still averages over the *fleet*, not the single
        // shared generator.
        let snap = tl.snapshot();
        assert!((snap.mfu_mean - 0.5).abs() < 1e-12);
        assert_eq!(snap.available, 4);
        assert_eq!(tl.n_clients(), 4);
        // Missing file fails loudly at construction (the resume path).
        let missing = TraceSpec {
            replay_path: dir.join("nope.jsonl").to_string_lossy().into_owned(),
            ..spec
        };
        assert!(EnvTimeline::new(&missing, 4).is_err());
    }

    #[test]
    fn fleet_drift_moves_every_client_coherently() {
        // Freeze the per-client walks (sigma 0) so the only motion is
        // the shared drift multiplier — every client must then carry
        // the identical value, and it must move.
        let spec = TraceSpec {
            kind: TraceKind::RandomWalk,
            seed: 77,
            mfu_sigma: 0.0,
            link_sigma: 0.0,
            revert: 0.0,
            drift_sigma: 0.4,
            ..TraceSpec::default()
        };
        let mut a = EnvTimeline::new(&spec, 12).unwrap();
        let mut b = EnvTimeline::new(&spec, 12).unwrap();
        let mut moved = false;
        for r in 1..=20 {
            let t = r as f64 * 9.0;
            a.advance(t);
            b.advance(t);
            let d = a.drift_mult();
            assert!((MULT_LO..=MULT_HI).contains(&d));
            for u in 0..12 {
                assert_eq!(a.mfu_mult(u).to_bits(), d.to_bits(), "drift not fleet-wide");
                assert_eq!(a.link_mult(u).to_bits(), d.to_bits());
                assert_eq!(a.mfu_mult(u).to_bits(), b.mfu_mult(u).to_bits());
            }
            if (d - 1.0).abs() > 1e-3 {
                moved = true;
            }
        }
        assert!(moved, "drift walk never left nominal");
    }

    #[test]
    fn drift_leaves_per_client_streams_untouched() {
        // The drift generator is seeded after every per-client
        // generator, so turning it on must not reshuffle their seeds:
        // the composed sample is exactly (base × drift) wherever the
        // clamp doesn't bind.
        let base_spec = TraceSpec {
            kind: TraceKind::RandomWalk,
            seed: 5,
            mfu_sigma: 0.05,
            link_sigma: 0.05,
            ..TraceSpec::default()
        };
        let drift_spec = TraceSpec { drift_sigma: 0.05, ..base_spec.clone() };
        let mut plain = EnvTimeline::new(&base_spec, 6).unwrap();
        let mut drifted = EnvTimeline::new(&drift_spec, 6).unwrap();
        assert_eq!(drifted.state().len(), plain.state().len() + 3, "drift adds its own words");
        for r in 1..=10 {
            let t = r as f64 * 5.0;
            plain.advance(t);
            drifted.advance(t);
            let d = drifted.drift_mult();
            for u in 0..6 {
                assert!(
                    (drifted.mfu_mult(u) - plain.mfu_mult(u) * d).abs() < 1e-12,
                    "per-client mfu stream changed when drift was enabled"
                );
            }
        }
    }

    #[test]
    fn drift_state_roundtrips_bit_exactly() {
        let spec = TraceSpec { drift_sigma: 0.3, ..walk_spec() };
        let mut a = EnvTimeline::new(&spec, 8).unwrap();
        for r in 1..=6 {
            a.advance(r as f64 * 7.3);
        }
        let words = a.state();
        let mut b = EnvTimeline::new(&spec, 8).unwrap();
        b.restore_state(&words).unwrap();
        for r in 7..=30 {
            let t = r as f64 * 7.3;
            a.advance(t);
            b.advance(t);
            assert_eq!(a.drift_mult().to_bits(), b.drift_mult().to_bits());
            for u in 0..8 {
                assert_eq!(a.mfu_mult(u).to_bits(), b.mfu_mult(u).to_bits());
            }
        }
        // A drift-off timeline refuses the drift-on word count.
        let mut off = EnvTimeline::new(&walk_spec(), 8).unwrap();
        assert!(off.restore_state(&words).is_err());
    }

    #[test]
    fn snapshot_means_track_the_samples() {
        let mut tl = EnvTimeline::new(&walk_spec(), 10).unwrap();
        tl.advance(50.0);
        let snap = tl.snapshot();
        let mfu_mean = (0..10).map(|u| tl.mfu_mult(u)).sum::<f64>() / 10.0;
        assert!((snap.mfu_mean - mfu_mean).abs() < 1e-12);
        assert_eq!(snap.available, 10);
    }
}
