//! Experiment configuration: schemes, schedulers, fleet, training knobs.
//!
//! Configs load from the sectioned key=value format (`configs/*.exp`,
//! parsed by `util::kv` — this workspace builds offline, so the format
//! and parser are in-tree) or from built-in presets;
//! `ExperimentConfig::paper()` is the §V-A setup.

use crate::devices::{paper_fleet, DeviceProfile, ServerProfile, DEFAULT_CLIENT_MFU};
use crate::faults::{AggKind, AttackKind};
use crate::fleet::{FleetPreset, FleetSpec};
use crate::model::ModelDims;
use crate::net::Link;
use crate::trace::{TraceKind, TraceSpec};
use crate::transport::{CompressKind, QuantKind};
use crate::util::kv::KvDocument;
use anyhow::{bail, Result};
use std::path::Path;
use std::str::FromStr;

/// Which end-to-end scheme to run (Table I / Fig. 2 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchemeKind {
    /// The paper's memory-efficient SFL (Alg. 1) with a pluggable scheduler.
    Ours,
    /// Sequential split learning (baseline [18]).
    Sl,
    /// Parallel SFL with per-client server submodels (baseline [14]).
    Sfl,
}

impl FromStr for SchemeKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "ours" => Ok(Self::Ours),
            "sl" => Ok(Self::Sl),
            "sfl" => Ok(Self::Sfl),
            other => bail!("unknown scheme {other:?} (ours|sl|sfl)"),
        }
    }
}

impl std::fmt::Display for SchemeKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Self::Ours => "ours",
            Self::Sl => "sl",
            Self::Sfl => "sfl",
        };
        write!(f, "{s}")
    }
}

/// Server-side processing order policy (§IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Alg. 2: descending N_c^u / C_u (longest client backprop first).
    Proposed,
    /// First-in-first-out by activation arrival (baseline [19]).
    Fifo,
    /// Workload-first: largest server-side workload first (baseline [6]).
    WorkloadFirst,
    /// Uniform-random order (control).
    Random,
}

impl FromStr for SchedulerKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "proposed" => Ok(Self::Proposed),
            "fifo" => Ok(Self::Fifo),
            "wf" | "workload_first" | "workload-first" => Ok(Self::WorkloadFirst),
            "random" => Ok(Self::Random),
            other => bail!("unknown scheduler {other:?} (proposed|fifo|wf|random)"),
        }
    }
}

impl std::fmt::Display for SchedulerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Self::Proposed => "proposed",
            Self::Fifo => "fifo",
            Self::WorkloadFirst => "workload_first",
            Self::Random => "random",
        };
        write!(f, "{s}")
    }
}

std::thread_local! {
    /// Per-thread count of `ClientConfig` clones.  Each clone allocates
    /// the device-name `String`, so the steady-state round loop is
    /// required to perform none — asserted in the same style as
    /// `tensor::alloc_count` (see
    /// `integration_training.rs::round_loop_does_not_clone_client_configs`).
    static CLIENT_CONFIG_CLONES: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Snapshot of the calling thread's `ClientConfig` clone counter.
pub fn client_clone_count() -> u64 {
    CLIENT_CONFIG_CLONES.with(|c| c.get())
}

/// Reset the calling thread's clone counter to zero, returning the
/// previous value.  Clone gates reset before measuring and then prove
/// the counter is live with a one-clone canary, so a gate cannot pass
/// vacuously against a poisoned or dead counter (see
/// `tests/integration_training.rs`).
pub fn reset_client_clone_count() -> u64 {
    CLIENT_CONFIG_CLONES.with(|c| c.replace(0))
}

/// One client entry: device + (optional) pinned cut point.
#[derive(Debug)]
pub struct ClientConfig {
    pub device: DeviceProfile,
    /// If None, the split selector picks the deepest feasible cut.
    pub cut: Option<usize>,
    pub link: Link,
}

impl Clone for ClientConfig {
    fn clone(&self) -> Self {
        CLIENT_CONFIG_CLONES.with(|c| c.set(c.get() + 1));
        Self { device: self.device.clone(), cut: self.cut, link: self.link.clone() }
    }
}

/// Training-loop knobs.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Mini-batch steps each client performs per round.
    pub steps_per_round: usize,
    /// Aggregate LoRA adapters every `aggregation_interval` rounds (paper I).
    pub aggregation_interval: usize,
    /// Max rounds before giving up on convergence.
    pub max_rounds: usize,
    /// Learning rate (paper: 1e-5 on real BERT; the scaled model trains
    /// with a correspondingly larger rate).
    pub lr: f32,
    /// Per-round learning-rate schedule (constant = the paper's setting).
    pub lr_schedule: crate::coordinator::lr::LrSchedule,
    /// Evaluate every `eval_interval` rounds.
    pub eval_interval: usize,
    /// Test batches per evaluation (bounds eval cost on this testbed).
    pub eval_batches: usize,
    /// Convergence: patience (eval points) and min improvement.
    pub patience: usize,
    pub min_delta: f64,
    /// Dirichlet alpha for the non-IID partition.
    pub dirichlet_alpha: f64,
    /// Per-round probability that a client drops out (failure injection;
    /// 0.0 = the paper's setting). Dropped clients skip the round and
    /// are excluded from that round's aggregation weights.
    pub dropout_prob: f64,
    /// Upper bound on per-round participants (0 = everyone).  Fleet-
    /// scale runs sample this many of the round's surviving clients
    /// uniformly, so a 100k-client fleet still runs bounded rounds.
    pub max_participants: usize,
    /// Drive the scheduler from the analytic (oracle) eq. 10–12 timings
    /// instead of the online `TimingEstimator` — the paper benches'
    /// original behavior.
    pub oracle_timing: bool,
    /// EWMA smoothing factor for the online timing estimator, in (0, 1].
    pub timing_ewma_alpha: f64,
    /// Adapt the EWMA factor per client from observed residual variance
    /// (`--timing-ewma-alpha adaptive`): clients whose residuals stay
    /// large (a drifting device the EWMA is lagging) track faster,
    /// stable clients smooth harder.  `false` keeps the fixed-α path
    /// bit-identical.
    pub timing_ewma_adaptive: bool,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            steps_per_round: 4,
            aggregation_interval: 2,
            max_rounds: 200,
            lr: 2e-3,
            lr_schedule: crate::coordinator::lr::LrSchedule::Constant,
            eval_interval: 2,
            eval_batches: 12,
            patience: 8,
            min_delta: 1e-3,
            dirichlet_alpha: 0.5,
            dropout_prob: 0.0,
            max_participants: 0,
            oracle_timing: false,
            timing_ewma_alpha: crate::coordinator::estimator::DEFAULT_EWMA_ALPHA,
            timing_ewma_adaptive: false,
            seed: 42,
        }
    }
}

/// State-pool knobs (server-side per-client state residency).
///
/// `state_cap = 0` keeps the eager behavior: every client's LoRA/Adam
/// state materialized at session construction (right for the 6-device
/// paper fleet, and the bench comparison point).  `state_cap = N > 0`
/// bounds residency at `max(N, round cohort)` — cold clients spill to
/// a compact serialized form and rematerialize bit-exactly on their
/// next participation, so fleet-scale numeric runs hold O(active)
/// state instead of O(fleet).  The cap never changes training
/// numerics (pooled and eager trajectories are bit-identical), which
/// is why it is deliberately absent from the checkpoint fingerprint:
/// resuming under a different cap is legitimate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolConfig {
    pub state_cap: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self { state_cap: 0 }
    }
}

/// Byzantine-robustness knobs (`[robust]` section): what fraction of
/// the fleet attacks and how, plus the server-side defenses (robust
/// merge kernel, pre-merge sanitizer, spot-verification committee,
/// estimator winsorization).  Every default is "off", and an all-off
/// config is guaranteed bit-identical to a run without this layer.
#[derive(Debug, Clone, PartialEq)]
pub struct RobustConfig {
    /// Fault injected into attacker submissions.
    pub attack: AttackKind,
    /// Fraction of the fleet that attacks (⌈frac·n⌉ seeded clients).
    pub attack_frac: f64,
    /// Attack magnitude λ: scale attacks submit `b + λ·(x − b)`; timing
    /// lies misreport step times by |λ|.
    pub attack_lambda: f64,
    /// Merge kernel (mean|trimmed|clip).
    pub agg: AggKind,
    /// Per-coordinate tail size for the trimmed mean.
    pub trim: usize,
    /// L2 delta-norm threshold for clip (`inf` disables ⇒ plain mean).
    pub clip: f64,
    /// Pre-merge sanitizer: reject non-finite and norm-outlier deltas.
    pub sanitize: bool,
    /// Sanitizer rejects deltas with norm > mult × the cohort median.
    pub sanitize_mult: f64,
    /// Drive the median-norm multiple from an EWMA of the observed
    /// per-round norm spread (`--sanitize-mult adaptive`) instead of
    /// the fixed `sanitize_mult`.  Off (the default) keeps the fixed
    /// threshold bit-identically; adaptive state is checkpointed only
    /// when this is set.
    pub sanitize_adaptive: bool,
    /// Committee witness fraction per round (0 = no spot verification).
    pub verify_frac: f64,
    /// Estimator winsor factor k: observations clamped into
    /// [EWMA/k, EWMA·k] (`inf` disables the clamp).
    pub winsor: f64,
    /// Committee re-admission: a flagged client re-enters after this
    /// many rounds of quarantine, on probation (its next update is
    /// always committee-verified).  `0` keeps the historical permanent
    /// quarantine bit-identically.  Requires `verify_frac > 0`.
    pub quarantine_ttl: usize,
}

impl Default for RobustConfig {
    fn default() -> Self {
        Self {
            attack: AttackKind::None,
            attack_frac: 0.0,
            attack_lambda: -10.0,
            agg: AggKind::Mean,
            trim: 1,
            clip: 1.0,
            sanitize: false,
            sanitize_mult: 10.0,
            sanitize_adaptive: false,
            verify_frac: 0.0,
            winsor: f64::INFINITY,
            quarantine_ttl: 0,
        }
    }
}

/// Asynchronous-round knobs (`[async]` section): the discrete-event
/// engine replaces the round barrier with buffered bounded-staleness
/// aggregation.  Disabled (the default) is guaranteed bit-identical to
/// the historical synchronous barrier — the engine still runs, but the
/// barrier is expressed as a single aggregation-trigger event at the
/// cohort makespan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AsyncConfig {
    pub enabled: bool,
    /// Staleness bound τ (sim seconds): merge whatever is buffered once
    /// the oldest buffered update has waited this long.
    pub staleness_bound: f64,
    /// Merge as soon as this many updates are buffered.
    pub buffer_k: usize,
    /// Staleness-decay exponent β in `1/(1+s)^β` (0 disables decay).
    pub staleness_beta: f64,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        Self { enabled: false, staleness_bound: 60.0, buffer_k: 4, staleness_beta: 0.5 }
    }
}

/// Compressed-update-transport knobs (`[transport]` section): top-k
/// sparse + quantized LoRA delta uploads with optional error feedback.
/// `compress = none` (the default) is the historical dense path,
/// bit-exactly — as is the degenerate top-k setting (k = 100%, f32, no
/// error feedback), which [`TransportConfig::is_active`] excludes so
/// the session never routes it through the codec.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransportConfig {
    pub compress: CompressKind,
    /// Fraction of client-half LoRA coordinates that survive top-k
    /// selection (`⌈frac·n⌉`, at least 1).
    pub topk_frac: f64,
    /// Wire precision of surviving values.
    pub quant: QuantKind,
    /// Keep per-client residuals of the dropped/rounded mass and add
    /// them back before the next encode (stored in the StatePool,
    /// spilled and checkpointed like Adam state).
    pub error_feedback: bool,
}

impl Default for TransportConfig {
    fn default() -> Self {
        Self {
            compress: CompressKind::None,
            topk_frac: 0.05,
            quant: QuantKind::F32,
            error_feedback: false,
        }
    }
}

impl TransportConfig {
    /// Whether uploads actually route through the codec.  The
    /// degenerate top-k setting (every coordinate, full precision, no
    /// residuals) is excluded: a delta codec cannot be bit-identical to
    /// the dense path (`fl(b + fl(x − b)) ≠ x`), so the session keeps
    /// degenerate configs on the dense path entirely — numerics,
    /// traffic billing, and checkpoint layout.
    pub fn is_active(&self) -> bool {
        self.compress == CompressKind::TopK
            && !(self.topk_frac >= 1.0 && self.quant == QuantKind::F32 && !self.error_feedback)
    }
}

/// Lossy-channel knobs (`[channel]` section): benign network failure
/// between clients and the server — seeded drop/corrupt/dup/reorder
/// dice with Gilbert–Elliott burst loss, plus the server's bounded
/// retransmission policy.  All probabilities default to 0; an all-zero
/// channel constructs nothing and is guaranteed bit-identical to a run
/// without this layer (trajectories, billing, checkpoint layout).
#[derive(Debug, Clone, PartialEq)]
pub struct ChannelConfig {
    /// Stationary per-attempt uplink loss probability.
    pub loss: f64,
    /// Per-delivery bit-corruption probability (flips one payload bit;
    /// caught by the FNV-1a hash and retried).
    pub corrupt: f64,
    /// Per-delivery duplication probability (second copy suppressed by
    /// sequence numbers).
    pub dup: f64,
    /// Per-delivery reorder probability (the copy arrives stale and is
    /// sequence-suppressed, forcing a retransmission).
    pub reorder: f64,
    /// Gilbert–Elliott burstiness: P(stay Bad).  0 ⇒ independent
    /// Bernoulli losses; higher values cluster the same stationary
    /// loss rate into bursts.
    pub burst: f64,
    /// Max retransmissions per upload before the server gives up on
    /// the client for this merge (0 = no retries).
    pub retry_max: usize,
    /// Base retransmission timeout in sim seconds.
    pub retry_base: f64,
    /// Exponential backoff multiplier per attempt (≥ 1).
    pub rto_mult: f64,
    /// Consecutive hash mismatches from one client before escalating
    /// to the committee/quarantine path.  1 reproduces the historical
    /// immediate flag bit-identically.
    pub tamper_threshold: usize,
}

impl Default for ChannelConfig {
    fn default() -> Self {
        Self {
            loss: 0.0,
            corrupt: 0.0,
            dup: 0.0,
            reorder: 0.0,
            burst: 0.0,
            retry_max: 3,
            retry_base: 0.5,
            rto_mult: 2.0,
            tamper_threshold: 1,
        }
    }
}

impl ChannelConfig {
    /// Whether the lossy channel engages at all.  With every failure
    /// probability at zero the session constructs no channel — the
    /// retry policy knobs alone never change a trajectory.
    pub fn is_active(&self) -> bool {
        self.loss > 0.0 || self.corrupt > 0.0 || self.dup > 0.0 || self.reorder > 0.0
    }
}

impl RobustConfig {
    /// Whether any fault/defense machinery engages on the aggregation
    /// path.  The estimator winsor clamp is deliberately excluded: it
    /// reshapes observations, not aggregation, and is fingerprinted
    /// separately.
    pub fn is_active(&self) -> bool {
        self.attack != AttackKind::None
            || self.agg != AggKind::Mean
            || self.sanitize
            || self.verify_frac > 0.0
    }
}

/// A full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Which artifact family to execute numerically ("mini"/"small").
    pub artifact_config: String,
    /// Which dims drive the analytic timing/memory model. Usually "base"
    /// (the paper's BERT-base) while numerics run on `artifact_config`.
    pub timing_dims: String,
    pub scheme: SchemeKind,
    pub scheduler: SchedulerKind,
    pub clients: Vec<ClientConfig>,
    /// When set, `clients` was synthesized from this spec (and the
    /// key=value round-trip re-synthesizes it instead of listing
    /// per-client sections).
    pub fleet: Option<FleetSpec>,
    /// Environment-trace recipe (non-stationary fleet dynamics +
    /// measurement noise).  `kind = none` with `obs_noise_sigma = 0`
    /// (the default) reproduces the static paper setting exactly.
    pub trace: TraceSpec,
    /// Server-side state-pool residency knobs.
    pub pool: PoolConfig,
    /// Byzantine fault injection + server-side defenses.
    pub robust: RobustConfig,
    /// Discrete-event asynchronous rounds (buffered bounded-staleness
    /// aggregation).  Disabled = the synchronous barrier, bit-exactly.
    pub asynchrony: AsyncConfig,
    /// Compressed update uploads (top-k + quantization + error
    /// feedback).  `compress = none` = dense uploads, bit-exactly.
    pub transport: TransportConfig,
    /// Lossy uplink channel + retransmission policy.  All-zero
    /// probabilities = the reliable path, bit-exactly.
    pub channel: ChannelConfig,
    pub server: ServerProfile,
    pub train: TrainConfig,
    /// Root of the artifacts directory.
    pub artifacts_dir: String,
}

impl ExperimentConfig {
    /// The paper's §V-A setup: six heterogeneous devices with pinned cuts,
    /// 100 Mbps links, BERT-base timing dims; numerics on `small`.
    pub fn paper() -> Self {
        let clients = paper_fleet()
            .into_iter()
            .map(|(device, cut)| ClientConfig {
                device,
                cut: Some(cut),
                link: Link::paper_default(),
            })
            .collect();
        Self {
            artifact_config: "small".into(),
            timing_dims: "base".into(),
            scheme: SchemeKind::Ours,
            scheduler: SchedulerKind::Proposed,
            clients,
            fleet: None,
            trace: TraceSpec::default(),
            pool: PoolConfig::default(),
            robust: RobustConfig::default(),
            asynchrony: AsyncConfig::default(),
            transport: TransportConfig::default(),
            channel: ChannelConfig::default(),
            server: ServerProfile::rtx4080s(),
            train: TrainConfig::default(),
            artifacts_dir: "artifacts".into(),
        }
    }

    /// Replace the client list with a synthesized fleet (recorded in
    /// `self.fleet` so serialization round-trips through the spec).
    pub fn apply_fleet(&mut self, spec: FleetSpec) {
        self.clients = spec.synthesize();
        self.fleet = Some(spec);
    }

    /// Fast preset for tests/benches: mini artifacts, fewer rounds.
    pub fn mini() -> Self {
        let mut c = Self::paper();
        c.artifact_config = "mini".into();
        c.train.max_rounds = 30;
        c.train.steps_per_round = 2;
        c
    }

    /// Resolve the analytic dims ("mini"/"small"/"base").
    pub fn timing_dims(&self) -> ModelDims {
        match self.timing_dims.as_str() {
            "base" => ModelDims::bert_base(),
            "small" => ModelDims::small(),
            _ => ModelDims::mini(),
        }
    }

    /// Cut assignment per client: pinned cut or split-selector choice.
    pub fn resolve_cuts(&self) -> Vec<usize> {
        let dims = self.timing_dims();
        self.clients
            .iter()
            .map(|c| {
                c.cut.unwrap_or_else(|| crate::devices::select_cut(&dims, &c.device, 30.0))
            })
            .collect()
    }

    pub fn validate(&self) -> Result<()> {
        if self.clients.is_empty() {
            bail!("at least one client required");
        }
        let dims = self.timing_dims();
        for (u, c) in self.clients.iter().enumerate() {
            if let Some(k) = c.cut {
                if k == 0 || k >= dims.layers {
                    bail!("client {u}: cut {k} out of range 1..{}", dims.layers);
                }
                if !dims.cuts.contains(&k) {
                    bail!(
                        "client {u}: cut {k} has no compiled artifact (available: {:?})",
                        dims.cuts
                    );
                }
            }
            if c.device.tflops <= 0.0 {
                bail!("client {u}: non-positive compute");
            }
        }
        if self.train.aggregation_interval == 0 || self.train.steps_per_round == 0 {
            bail!("train intervals must be positive");
        }
        if !self.train.lr.is_finite() || self.train.lr <= 0.0 {
            bail!("lr must be finite and > 0, got {}", self.train.lr);
        }
        if !self.train.min_delta.is_finite() || self.train.min_delta < 0.0 {
            bail!("min_delta must be finite and >= 0, got {}", self.train.min_delta);
        }
        if !self.train.dirichlet_alpha.is_finite() || self.train.dirichlet_alpha <= 0.0 {
            bail!("dirichlet_alpha must be finite and > 0, got {}", self.train.dirichlet_alpha);
        }
        if !(0.0..=1.0).contains(&self.train.dropout_prob) {
            bail!("dropout_prob must be in [0, 1], got {}", self.train.dropout_prob);
        }
        let a = self.train.timing_ewma_alpha;
        if !(a > 0.0 && a <= 1.0) {
            bail!("timing_ewma_alpha must be in (0, 1], got {a}");
        }
        if let Some(f) = &self.fleet {
            if f.n == 0 {
                bail!("fleet spec must synthesize at least one client");
            }
            if f.n != self.clients.len() {
                bail!(
                    "fleet spec says {} clients but config lists {} (call apply_fleet)",
                    f.n,
                    self.clients.len()
                );
            }
        }
        let tr = &self.trace;
        // NaN/inf would silently poison the timeline RNG streams and the
        // estimator EWMAs — the negated comparisons below are false for
        // NaN, so every float knob is gated on `is_finite` explicitly.
        if !tr.obs_noise_sigma.is_finite() || tr.obs_noise_sigma < 0.0 {
            bail!("trace obs_noise_sigma must be finite and >= 0, got {}", tr.obs_noise_sigma);
        }
        match tr.kind {
            TraceKind::None => {}
            TraceKind::RandomWalk => {
                let ok = |x: f64| x.is_finite() && x >= 0.0;
                if !ok(tr.mfu_sigma) || !ok(tr.link_sigma) || !ok(tr.revert) {
                    bail!("random-walk trace needs finite mfu_sigma/link_sigma/revert >= 0");
                }
            }
            TraceKind::Diurnal => {
                if !tr.period.is_finite() || tr.period <= 0.0 {
                    bail!("diurnal trace needs finite period > 0, got {}", tr.period);
                }
                if !(0.0..=0.95).contains(&tr.amp) {
                    bail!("diurnal trace amp must be in [0, 0.95], got {}", tr.amp);
                }
                if !tr.jitter.is_finite() || tr.jitter < 0.0 {
                    bail!("diurnal trace jitter must be finite and >= 0, got {}", tr.jitter);
                }
            }
            TraceKind::Markov => {
                let ok = |x: f64| x.is_finite() && x > 0.0;
                if !ok(tr.mean_up) || !ok(tr.mean_down) {
                    bail!(
                        "markov trace needs finite mean_up/mean_down > 0, got {}/{}",
                        tr.mean_up,
                        tr.mean_down
                    );
                }
            }
            TraceKind::Replay => {
                if tr.replay_path.is_empty() {
                    bail!("replay trace needs a replay_path (jsonl trace file)");
                }
            }
        }
        if tr.kind != TraceKind::Replay && !tr.replay_path.is_empty() {
            bail!(
                "trace replay_path is set but kind is {} — use kind = replay (a recorded \
                 trajectory is never silently ignored)",
                tr.kind
            );
        }
        if !tr.drift_sigma.is_finite() || tr.drift_sigma < 0.0 {
            bail!("trace drift_sigma must be finite and >= 0, got {}", tr.drift_sigma);
        }
        if tr.kind == TraceKind::None && tr.drift_sigma > 0.0 {
            bail!("fleet drift_sigma requires an active trace kind (kind != none)");
        }
        let r = &self.robust;
        if !r.attack_frac.is_finite() || !(0.0..=1.0).contains(&r.attack_frac) {
            bail!("robust attack_frac must be in [0, 1], got {}", r.attack_frac);
        }
        if !r.attack_lambda.is_finite() {
            bail!("robust attack_lambda must be finite, got {}", r.attack_lambda);
        }
        if r.clip.is_nan() || r.clip <= 0.0 {
            bail!("robust clip must be > 0 (inf disables clipping), got {}", r.clip);
        }
        if !r.sanitize_mult.is_finite() || r.sanitize_mult <= 0.0 {
            bail!("robust sanitize_mult must be finite and > 0, got {}", r.sanitize_mult);
        }
        if r.sanitize_adaptive && !r.sanitize {
            bail!(
                "sanitize_adaptive requires the sanitizer (--sanitize) — an adaptive \
                 threshold with no sanitizer is never silently ignored"
            );
        }
        if !r.verify_frac.is_finite() || !(0.0..=1.0).contains(&r.verify_frac) {
            bail!("robust verify_frac must be in [0, 1], got {}", r.verify_frac);
        }
        if r.winsor.is_nan() || r.winsor <= 1.0 {
            bail!("robust winsor must be > 1 (inf disables the clamp), got {}", r.winsor);
        }
        if r.is_active() && self.scheme == SchemeKind::Sl {
            bail!("robust options require a parallel scheme (ours|sfl) — sl aggregates no cohort");
        }
        if r.quarantine_ttl > 0 && r.verify_frac <= 0.0 {
            bail!(
                "quarantine_ttl requires a committee (verify_frac > 0) — probationers must be \
                 re-verified on re-admission"
            );
        }
        let a = &self.asynchrony;
        if !a.staleness_bound.is_finite() || a.staleness_bound <= 0.0 {
            bail!("async staleness_bound must be finite and > 0, got {}", a.staleness_bound);
        }
        if a.buffer_k == 0 {
            bail!("async buffer_k must be >= 1");
        }
        if !a.staleness_beta.is_finite() || a.staleness_beta < 0.0 {
            bail!("async staleness_beta must be finite and >= 0, got {}", a.staleness_beta);
        }
        if a.enabled && self.scheme == SchemeKind::Sl {
            bail!("async rounds require a parallel scheme (ours|sfl) — sl has no cohort to buffer");
        }
        let tp = &self.transport;
        if !tp.topk_frac.is_finite() || !(0.0..=1.0).contains(&tp.topk_frac) || tp.topk_frac == 0.0
        {
            bail!("transport topk_frac must be finite and in (0, 1], got {}", tp.topk_frac);
        }
        if tp.compress == CompressKind::None
            && (tp.quant != QuantKind::F32 || tp.error_feedback)
        {
            bail!(
                "transport quant/error_feedback require compress = topk — lossy knobs are \
                 never silently ignored"
            );
        }
        if tp.is_active() && self.scheme == SchemeKind::Sl {
            bail!(
                "compressed transport requires a parallel scheme (ours|sfl) — sl uploads no \
                 cohort deltas"
            );
        }
        let ch = &self.channel;
        for (name, p) in [
            ("loss", ch.loss),
            ("corrupt", ch.corrupt),
            ("dup", ch.dup),
            ("reorder", ch.reorder),
        ] {
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                bail!("channel {name} must be finite and in [0, 1], got {p}");
            }
        }
        if !ch.burst.is_finite() || !(0.0..1.0).contains(&ch.burst) {
            bail!("channel burst must be finite and in [0, 1), got {}", ch.burst);
        }
        if !ch.retry_base.is_finite() || ch.retry_base <= 0.0 {
            bail!("channel retry_base must be finite and > 0, got {}", ch.retry_base);
        }
        if !ch.rto_mult.is_finite() || ch.rto_mult < 1.0 {
            bail!("channel rto_mult must be finite and >= 1, got {}", ch.rto_mult);
        }
        if ch.tamper_threshold == 0 {
            bail!("channel tamper_threshold must be >= 1 (1 = historical immediate flag)");
        }
        if !ch.is_active() {
            // Retry-policy knobs without a lossy channel would be dead
            // config — reject instead of silently ignoring (the same
            // contract as transport's quant-without-compress).
            let d = ChannelConfig::default();
            if ch.retry_max != d.retry_max
                || ch.retry_base != d.retry_base
                || ch.rto_mult != d.rto_mult
                || ch.tamper_threshold != d.tamper_threshold
            {
                bail!(
                    "channel retry/timeout knobs require a lossy channel (a nonzero \
                     loss/corrupt/dup/reorder probability) — retry policy is never \
                     silently ignored"
                );
            }
        }
        if ch.is_active() && self.scheme == SchemeKind::Sl {
            bail!(
                "the lossy channel requires a parallel scheme (ours|sfl) — sl uploads no \
                 cohort deltas"
            );
        }
        if ch.burst > 0.0 && ch.loss <= 0.0 {
            bail!("channel burst requires a nonzero loss rate (burst shapes the loss process)");
        }
        Ok(())
    }

    /// Load from the sectioned key=value format. Unspecified keys fall
    /// back to the paper preset. Example (`configs/paper.exp`):
    ///
    /// ```text
    /// scheme = ours
    /// scheduler = proposed
    /// artifact_config = small
    /// lr = 0.002
    ///
    /// [server]
    /// name = RTX 4080S
    /// tflops = 52.2
    ///
    /// [client]
    /// name = Jetson Nano
    /// tflops = 0.472
    /// memory_mb = 4096
    /// cut = 1
    /// rate_mbps = 100
    /// ```
    pub fn from_kv_file(path: &Path) -> Result<Self> {
        let doc = KvDocument::load(path)?;
        let mut cfg = Self::paper();
        let r = &doc.root;
        if let Some(v) = r.get("scheme") {
            cfg.scheme = v.parse()?;
        }
        if let Some(v) = r.get("scheduler") {
            cfg.scheduler = v.parse()?;
        }
        if let Some(v) = r.get("artifact_config") {
            cfg.artifact_config = v.to_string();
        }
        if let Some(v) = r.get("timing_dims") {
            cfg.timing_dims = v.to_string();
        }
        if let Some(v) = r.get("artifacts_dir") {
            cfg.artifacts_dir = v.to_string();
        }
        let t = &mut cfg.train;
        t.steps_per_round = r.parse_or("steps_per_round", t.steps_per_round)?;
        t.aggregation_interval = r.parse_or("aggregation_interval", t.aggregation_interval)?;
        t.max_rounds = r.parse_or("max_rounds", t.max_rounds)?;
        t.lr = r.parse_or("lr", t.lr)?;
        if let Some(v) = r.get("lr_schedule") {
            t.lr_schedule = v.parse()?;
        }
        t.eval_interval = r.parse_or("eval_interval", t.eval_interval)?;
        t.eval_batches = r.parse_or("eval_batches", t.eval_batches)?;
        t.patience = r.parse_or("patience", t.patience)?;
        t.min_delta = r.parse_or("min_delta", t.min_delta)?;
        t.dirichlet_alpha = r.parse_or("dirichlet_alpha", t.dirichlet_alpha)?;
        t.dropout_prob = r.parse_or("dropout_prob", t.dropout_prob)?;
        t.max_participants = r.parse_or("max_participants", t.max_participants)?;
        t.oracle_timing = r.parse_or("oracle_timing", t.oracle_timing)?;
        t.timing_ewma_alpha = r.parse_or("timing_ewma_alpha", t.timing_ewma_alpha)?;
        t.timing_ewma_adaptive = r.parse_or("timing_ewma_adaptive", t.timing_ewma_adaptive)?;
        t.seed = r.parse_or("seed", t.seed)?;

        if let Some(s) = doc.sections_named("server").next() {
            cfg.server.name = s.get("name").unwrap_or(&cfg.server.name).to_string();
            cfg.server.tflops = s.parse_or("tflops", cfg.server.tflops)?;
            cfg.server.memory_mb = s.parse_or("memory_mb", cfg.server.memory_mb)?;
            cfg.server.mfu = s.parse_or("mfu", cfg.server.mfu)?;
            cfg.server.contention_per_job =
                s.parse_or("contention_per_job", cfg.server.contention_per_job)?;
        }

        let clients: Vec<ClientConfig> = doc
            .sections_named("client")
            .map(|s| -> Result<ClientConfig> {
                let mut device = DeviceProfile::new(
                    s.get("name").unwrap_or("client"),
                    s.parse::<f64>("tflops")?,
                    s.parse_or("memory_mb", 8192.0)?,
                );
                device.mfu = s.parse_or("mfu", DEFAULT_CLIENT_MFU)?;
                let cut = match s.get("cut") {
                    Some(v) => Some(v.parse::<usize>()?),
                    None => None,
                };
                Ok(ClientConfig {
                    device,
                    cut,
                    link: Link::new(
                        s.parse_or("rate_mbps", 100.0)?,
                        s.parse_or("latency_ms", 5.0)?,
                    ),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        if !clients.is_empty() {
            cfg.clients = clients;
        }
        // A [fleet] section synthesizes the client list and takes
        // precedence over explicit [client] sections.
        if let Some(s) = doc.sections_named("fleet").next() {
            let preset: FleetPreset = s.get("preset").unwrap_or("paper").parse()?;
            let mut spec = FleetSpec::new(preset, s.parse::<usize>("n")?, cfg.train.seed);
            spec.seed = s.parse_or("seed", spec.seed)?;
            spec.mfu_sigma = s.parse_or("mfu_sigma", spec.mfu_sigma)?;
            cfg.apply_fleet(spec);
        }
        // A [pool] section configures server-side state residency.
        if let Some(s) = doc.sections_named("pool").next() {
            cfg.pool.state_cap = s.parse_or("state_cap", cfg.pool.state_cap)?;
        }
        // A [trace] section configures the environment timeline.
        if let Some(s) = doc.sections_named("trace").next() {
            let mut tr = TraceSpec::default();
            if let Some(v) = s.get("kind") {
                tr.kind = v.parse()?;
            }
            tr.seed = s.parse_or("seed", tr.seed)?;
            tr.mfu_sigma = s.parse_or("mfu_sigma", tr.mfu_sigma)?;
            tr.link_sigma = s.parse_or("link_sigma", tr.link_sigma)?;
            tr.revert = s.parse_or("revert", tr.revert)?;
            tr.period = s.parse_or("period", tr.period)?;
            tr.amp = s.parse_or("amp", tr.amp)?;
            tr.jitter = s.parse_or("jitter", tr.jitter)?;
            tr.mean_up = s.parse_or("mean_up", tr.mean_up)?;
            tr.mean_down = s.parse_or("mean_down", tr.mean_down)?;
            tr.obs_noise_sigma = s.parse_or("obs_noise_sigma", tr.obs_noise_sigma)?;
            tr.drift_sigma = s.parse_or("drift_sigma", tr.drift_sigma)?;
            if let Some(p) = s.get("replay_path") {
                tr.replay_path = p.to_string();
            }
            cfg.trace = tr;
        }
        // A [robust] section configures fault injection + defenses.
        if let Some(s) = doc.sections_named("robust").next() {
            let r = &mut cfg.robust;
            if let Some(v) = s.get("attack") {
                r.attack = v.parse()?;
            }
            r.attack_frac = s.parse_or("attack_frac", r.attack_frac)?;
            r.attack_lambda = s.parse_or("attack_lambda", r.attack_lambda)?;
            if let Some(v) = s.get("agg") {
                r.agg = v.parse()?;
            }
            r.trim = s.parse_or("trim", r.trim)?;
            r.clip = s.parse_or("clip", r.clip)?;
            r.sanitize = s.parse_or("sanitize", r.sanitize)?;
            r.sanitize_mult = s.parse_or("sanitize_mult", r.sanitize_mult)?;
            r.sanitize_adaptive = s.parse_or("sanitize_adaptive", r.sanitize_adaptive)?;
            r.verify_frac = s.parse_or("verify_frac", r.verify_frac)?;
            r.winsor = s.parse_or("winsor", r.winsor)?;
            r.quarantine_ttl = s.parse_or("quarantine_ttl", r.quarantine_ttl)?;
        }
        // An [async] section configures event-driven rounds.
        if let Some(s) = doc.sections_named("async").next() {
            let a = &mut cfg.asynchrony;
            a.enabled = s.parse_or("enabled", a.enabled)?;
            a.staleness_bound = s.parse_or("staleness_bound", a.staleness_bound)?;
            a.buffer_k = s.parse_or("buffer_k", a.buffer_k)?;
            a.staleness_beta = s.parse_or("staleness_beta", a.staleness_beta)?;
        }
        // A [transport] section configures compressed uploads.
        if let Some(s) = doc.sections_named("transport").next() {
            let tp = &mut cfg.transport;
            if let Some(v) = s.get("compress") {
                tp.compress = v.parse()?;
            }
            tp.topk_frac = s.parse_or("topk_frac", tp.topk_frac)?;
            if let Some(v) = s.get("quant") {
                tp.quant = v.parse()?;
            }
            tp.error_feedback = s.parse_or("error_feedback", tp.error_feedback)?;
        }
        // A [channel] section configures the lossy uplink.
        if let Some(s) = doc.sections_named("channel").next() {
            let ch = &mut cfg.channel;
            ch.loss = s.parse_or("loss", ch.loss)?;
            ch.corrupt = s.parse_or("corrupt", ch.corrupt)?;
            ch.dup = s.parse_or("dup", ch.dup)?;
            ch.reorder = s.parse_or("reorder", ch.reorder)?;
            ch.burst = s.parse_or("burst", ch.burst)?;
            ch.retry_max = s.parse_or("retry_max", ch.retry_max)?;
            ch.retry_base = s.parse_or("retry_base", ch.retry_base)?;
            ch.rto_mult = s.parse_or("rto_mult", ch.rto_mult)?;
            ch.tamper_threshold = s.parse_or("tamper_threshold", ch.tamper_threshold)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize to the key=value format (round-trips via from_kv_file).
    pub fn to_kv(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("scheme = {}\n", self.scheme));
        out.push_str(&format!("scheduler = {}\n", self.scheduler));
        out.push_str(&format!("artifact_config = {}\n", self.artifact_config));
        out.push_str(&format!("timing_dims = {}\n", self.timing_dims));
        out.push_str(&format!("artifacts_dir = {}\n", self.artifacts_dir));
        let t = &self.train;
        out.push_str(&format!(
            "steps_per_round = {}\naggregation_interval = {}\nmax_rounds = {}\nlr = {}\n\
             lr_schedule = {}\n\
             eval_interval = {}\neval_batches = {}\npatience = {}\nmin_delta = {}\n\
             dirichlet_alpha = {}\ndropout_prob = {}\nmax_participants = {}\n\
             oracle_timing = {}\ntiming_ewma_alpha = {}\ntiming_ewma_adaptive = {}\nseed = {}\n",
            t.steps_per_round,
            t.aggregation_interval,
            t.max_rounds,
            t.lr,
            t.lr_schedule,
            t.eval_interval,
            t.eval_batches,
            t.patience,
            t.min_delta,
            t.dirichlet_alpha,
            t.dropout_prob,
            t.max_participants,
            t.oracle_timing,
            t.timing_ewma_alpha,
            t.timing_ewma_adaptive,
            t.seed
        ));
        out.push_str(&format!(
            "\n[server]\nname = {}\ntflops = {}\nmemory_mb = {}\nmfu = {}\ncontention_per_job = {}\n",
            self.server.name,
            self.server.tflops,
            self.server.memory_mb,
            self.server.mfu,
            self.server.contention_per_job
        ));
        // The environment trace always round-trips through its spec —
        // `from_kv_file`/`to_kv` symmetry holds for every section.
        let tr = &self.trace;
        out.push_str(&format!(
            "\n[trace]\nkind = {}\nseed = {}\nmfu_sigma = {}\nlink_sigma = {}\nrevert = {}\n\
             period = {}\namp = {}\njitter = {}\nmean_up = {}\nmean_down = {}\n\
             obs_noise_sigma = {}\n",
            tr.kind,
            tr.seed,
            tr.mfu_sigma,
            tr.link_sigma,
            tr.revert,
            tr.period,
            tr.amp,
            tr.jitter,
            tr.mean_up,
            tr.mean_down,
            tr.obs_noise_sigma
        ));
        out.push_str(&format!("drift_sigma = {}\n", tr.drift_sigma));
        if !tr.replay_path.is_empty() {
            out.push_str(&format!("replay_path = {}\n", tr.replay_path));
        }
        // The state pool always round-trips, like [trace] — symmetry.
        out.push_str(&format!("\n[pool]\nstate_cap = {}\n", self.pool.state_cap));
        // The robustness layer always round-trips too (f64 `inf`
        // Display/parse is symmetric, so the clip/winsor sentinels
        // survive the trip).
        let r = &self.robust;
        out.push_str(&format!(
            "\n[robust]\nattack = {}\nattack_frac = {}\nattack_lambda = {}\nagg = {}\n\
             trim = {}\nclip = {}\nsanitize = {}\nsanitize_mult = {}\nsanitize_adaptive = {}\n\
             verify_frac = {}\nwinsor = {}\nquarantine_ttl = {}\n",
            r.attack,
            r.attack_frac,
            r.attack_lambda,
            r.agg,
            r.trim,
            r.clip,
            r.sanitize,
            r.sanitize_mult,
            r.sanitize_adaptive,
            r.verify_frac,
            r.winsor,
            r.quarantine_ttl
        ));
        // The async section always round-trips too — disabled is the
        // synchronous barrier, bit-exactly.
        let a = &self.asynchrony;
        out.push_str(&format!(
            "\n[async]\nenabled = {}\nstaleness_bound = {}\nbuffer_k = {}\nstaleness_beta = {}\n",
            a.enabled, a.staleness_bound, a.buffer_k, a.staleness_beta
        ));
        // The transport section always round-trips too — none is the
        // dense upload path, bit-exactly.
        let tp = &self.transport;
        out.push_str(&format!(
            "\n[transport]\ncompress = {}\ntopk_frac = {}\nquant = {}\nerror_feedback = {}\n",
            tp.compress, tp.topk_frac, tp.quant, tp.error_feedback
        ));
        // The channel section always round-trips too — all-zero
        // probabilities are the reliable uplink, bit-exactly.
        let ch = &self.channel;
        out.push_str(&format!(
            "\n[channel]\nloss = {}\ncorrupt = {}\ndup = {}\nreorder = {}\nburst = {}\n\
             retry_max = {}\nretry_base = {}\nrto_mult = {}\ntamper_threshold = {}\n",
            ch.loss,
            ch.corrupt,
            ch.dup,
            ch.reorder,
            ch.burst,
            ch.retry_max,
            ch.retry_base,
            ch.rto_mult,
            ch.tamper_threshold
        ));
        // A synthesized fleet round-trips through its spec (same seed ⇒
        // bit-identical fleet); only hand-written fleets list clients.
        if let Some(f) = &self.fleet {
            out.push_str(&format!(
                "\n[fleet]\npreset = {}\nn = {}\nseed = {}\nmfu_sigma = {}\n",
                f.preset, f.n, f.seed, f.mfu_sigma
            ));
            return out;
        }
        for c in &self.clients {
            out.push_str(&format!(
                "\n[client]\nname = {}\ntflops = {}\nmemory_mb = {}\nmfu = {}\nrate_mbps = {}\nlatency_ms = {}\n",
                c.device.name,
                c.device.tflops,
                c.device.memory_mb,
                c.device.mfu,
                c.link.rate_mbps,
                c.link.latency_ms
            ));
            if let Some(k) = c.cut {
                out.push_str(&format!("cut = {k}\n"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_is_valid_and_matches_section_v() {
        let c = ExperimentConfig::paper();
        c.validate().unwrap();
        assert_eq!(c.clients.len(), 6);
        assert_eq!(c.resolve_cuts(), vec![1, 1, 2, 2, 3, 3]);
        assert_eq!(c.server.name, "RTX 4080S");
        assert!((c.clients[0].link.rate_mbps - 100.0).abs() < 1e-9);
    }

    #[test]
    fn kv_roundtrip() {
        let c = ExperimentConfig::paper();
        let text = c.to_kv();
        let dir = std::env::temp_dir().join("sfl_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("paper.exp");
        std::fs::write(&path, &text).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.clients.len(), 6);
        assert_eq!(back.scheme, SchemeKind::Ours);
        assert_eq!(back.resolve_cuts(), c.resolve_cuts());
        assert!((back.clients[0].device.tflops - 0.472).abs() < 1e-9);
    }

    #[test]
    fn kv_roundtrip_preserves_lr_schedule() {
        // Regression: to_kv used to omit lr_schedule, so a non-default
        // schedule silently reverted to constant after a round-trip.
        let mut c = ExperimentConfig::paper();
        c.train.lr_schedule =
            crate::coordinator::lr::LrSchedule::Cosine { horizon: 64, floor: 0.2 };
        let dir = std::env::temp_dir().join("sfl_cfg_test_lrs");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("lrs.exp");
        std::fs::write(&path, c.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.train.lr_schedule, c.train.lr_schedule);
    }

    #[test]
    fn validate_rejects_bad_float_knobs() {
        let mut c = ExperimentConfig::paper();
        c.train.lr = f32::NAN;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::paper();
        c.train.min_delta = -1.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::paper();
        c.train.dirichlet_alpha = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn enum_parsing() {
        assert_eq!("ours".parse::<SchemeKind>().unwrap(), SchemeKind::Ours);
        assert_eq!("SFL".parse::<SchemeKind>().unwrap(), SchemeKind::Sfl);
        assert!("bogus".parse::<SchemeKind>().is_err());
        assert_eq!("wf".parse::<SchedulerKind>().unwrap(), SchedulerKind::WorkloadFirst);
        assert_eq!(
            "workload_first".parse::<SchedulerKind>().unwrap(),
            SchedulerKind::WorkloadFirst
        );
        assert!("bogus".parse::<SchedulerKind>().is_err());
    }

    #[test]
    fn invalid_cut_rejected() {
        let mut c = ExperimentConfig::paper();
        c.clients[0].cut = Some(99);
        assert!(c.validate().is_err());
        c.clients[0].cut = Some(0);
        assert!(c.validate().is_err());
    }

    #[test]
    fn empty_clients_rejected() {
        let mut c = ExperimentConfig::paper();
        c.clients.clear();
        assert!(c.validate().is_err());
    }

    #[test]
    fn dropout_out_of_range_rejected() {
        let mut c = ExperimentConfig::paper();
        c.train.dropout_prob = 1.5;
        assert!(c.validate().is_err());
        c.train.dropout_prob = -0.1;
        assert!(c.validate().is_err());
        c.train.dropout_prob = 0.4;
        c.validate().unwrap();
    }

    #[test]
    fn fleet_kv_roundtrip_resynthesizes_the_same_fleet() {
        let mut c = ExperimentConfig::paper();
        c.apply_fleet(FleetSpec::new(FleetPreset::Lognormal, 40, 13));
        c.train.max_participants = 8;
        c.train.oracle_timing = true;
        c.validate().unwrap();
        let dir = std::env::temp_dir().join("sfl_cfg_fleet_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fleet.exp");
        std::fs::write(&path, c.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.fleet, c.fleet);
        assert_eq!(back.clients.len(), 40);
        assert_eq!(back.train.max_participants, 8);
        assert!(back.train.oracle_timing);
        for (a, b) in back.clients.iter().zip(c.clients.iter()) {
            assert_eq!(a.device.tflops.to_bits(), b.device.tflops.to_bits());
            assert_eq!(a.device.mfu.to_bits(), b.device.mfu.to_bits());
            assert_eq!(a.link.rate_mbps.to_bits(), b.link.rate_mbps.to_bits());
        }
    }

    #[test]
    fn fleet_and_estimator_knobs_validated() {
        let mut c = ExperimentConfig::paper();
        c.train.timing_ewma_alpha = 0.0;
        assert!(c.validate().is_err());
        c.train.timing_ewma_alpha = 1.5;
        assert!(c.validate().is_err());
        c.train.timing_ewma_alpha = 0.25;
        c.validate().unwrap();
        // A fleet spec that disagrees with the client list is rejected.
        c.fleet = Some(FleetSpec::new(FleetPreset::Paper, 99, 1));
        assert!(c.validate().is_err());
        c.apply_fleet(FleetSpec::new(FleetPreset::Paper, 12, 1));
        c.validate().unwrap();
        assert_eq!(c.resolve_cuts().len(), 12);
    }

    #[test]
    fn trace_kv_roundtrip_is_symmetric() {
        let mut c = ExperimentConfig::paper();
        c.trace = TraceSpec {
            kind: TraceKind::RandomWalk,
            seed: 99,
            mfu_sigma: 0.11,
            link_sigma: 0.07,
            revert: 0.015,
            obs_noise_sigma: 0.2,
            ..TraceSpec::default()
        };
        c.validate().unwrap();
        let dir = std::env::temp_dir().join("sfl_cfg_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.exp");
        std::fs::write(&path, c.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.trace, c.trace);
        // And the default (static) trace round-trips too — the [trace]
        // section is always written, so to_kv/from_kv stay symmetric.
        let d = ExperimentConfig::paper();
        std::fs::write(&path, d.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.trace, TraceSpec::default());
        assert!(back.trace.is_static());
    }

    #[test]
    fn trace_fleet_kv_roundtrip_combined() {
        // [trace] and [fleet] coexist in one file (the non-stationary
        // fleet experiment shape).
        let mut c = ExperimentConfig::paper();
        c.apply_fleet(FleetSpec::new(FleetPreset::Lognormal, 24, 5));
        c.trace = TraceSpec { kind: TraceKind::Markov, mean_up: 120.0, ..TraceSpec::default() };
        c.validate().unwrap();
        let dir = std::env::temp_dir().join("sfl_cfg_trace_fleet_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("both.exp");
        std::fs::write(&path, c.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.fleet, c.fleet);
        assert_eq!(back.trace, c.trace);
        assert_eq!(back.clients.len(), 24);
    }

    #[test]
    fn invalid_trace_specs_rejected() {
        let mut c = ExperimentConfig::paper();
        c.trace.obs_noise_sigma = -0.1;
        assert!(c.validate().is_err());
        c.trace.obs_noise_sigma = 0.0;
        c.trace.kind = TraceKind::Markov;
        c.trace.mean_up = 0.0;
        assert!(c.validate().is_err());
        c.trace.mean_up = 100.0;
        c.validate().unwrap();
        c.trace.kind = TraceKind::Diurnal;
        c.trace.amp = 1.5;
        assert!(c.validate().is_err());
        c.trace.amp = 0.3;
        c.trace.period = 0.0;
        assert!(c.validate().is_err());
        c.trace.period = 600.0;
        c.validate().unwrap();
        c.trace.kind = TraceKind::Replay;
        assert!(c.validate().is_err(), "replay without a path must be rejected");
        // A recorded trajectory on a non-replay kind must not be
        // silently dropped.
        c.trace.kind = TraceKind::RandomWalk;
        c.trace.replay_path = "trace.jsonl".into();
        assert!(c.validate().is_err(), "replay_path on a non-replay kind must be rejected");
        c.trace.replay_path = String::new();
        // NaN knobs must fail at config time, not poison the run.
        c.trace.mfu_sigma = f64::NAN;
        assert!(c.validate().is_err(), "NaN mfu_sigma must be rejected");
        c.trace.mfu_sigma = 0.05;
        c.trace.kind = TraceKind::None;
        c.trace.obs_noise_sigma = f64::NAN;
        assert!(c.validate().is_err(), "NaN obs_noise_sigma must be rejected");
        c.trace.obs_noise_sigma = f64::INFINITY;
        assert!(c.validate().is_err(), "infinite obs_noise_sigma must be rejected");
    }

    #[test]
    fn pool_kv_roundtrip_is_symmetric() {
        let dir = std::env::temp_dir().join("sfl_cfg_pool_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pool.exp");
        // Non-default cap round-trips...
        let mut c = ExperimentConfig::paper();
        c.pool.state_cap = 48;
        c.validate().unwrap();
        std::fs::write(&path, c.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.pool, c.pool);
        // ...and so does the default (eager) pool — the [pool] section
        // is always written.
        let d = ExperimentConfig::paper();
        std::fs::write(&path, d.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.pool, PoolConfig::default());
        assert_eq!(back.pool.state_cap, 0);
    }

    #[test]
    fn pool_fleet_trace_kv_roundtrip_combined() {
        // [pool], [fleet], and [trace] coexist in one experiment file —
        // the bench-scale pooled-fleet shape.
        let mut c = ExperimentConfig::paper();
        c.apply_fleet(FleetSpec::new(FleetPreset::Zipf, 30, 17));
        c.trace =
            TraceSpec { kind: TraceKind::RandomWalk, mfu_sigma: 0.05, ..TraceSpec::default() };
        c.pool.state_cap = 8;
        c.train.max_participants = 4;
        c.validate().unwrap();
        let dir = std::env::temp_dir().join("sfl_cfg_pool_combined_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("all.exp");
        std::fs::write(&path, c.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.pool, c.pool);
        assert_eq!(back.fleet, c.fleet);
        assert_eq!(back.trace, c.trace);
        assert_eq!(back.clients.len(), 30);
    }

    #[test]
    fn robust_kv_roundtrip_is_symmetric() {
        let dir = std::env::temp_dir().join("sfl_cfg_robust_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("robust.exp");
        // Non-default knobs round-trip (including the inf winsor
        // sentinel and a finite clip)...
        let mut c = ExperimentConfig::paper();
        c.robust = RobustConfig {
            attack: AttackKind::Scale,
            attack_frac: 0.2,
            attack_lambda: -4.0,
            agg: AggKind::Trimmed,
            trim: 2,
            clip: 0.5,
            sanitize: true,
            sanitize_mult: 8.0,
            verify_frac: 0.25,
            winsor: 4.0,
            quarantine_ttl: 3,
        };
        c.validate().unwrap();
        std::fs::write(&path, c.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.robust, c.robust);
        assert!(back.robust.is_active());
        // ...and so does the all-off default — the [robust] section is
        // always written, like [trace] and [pool].
        let d = ExperimentConfig::paper();
        std::fs::write(&path, d.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.robust, RobustConfig::default());
        assert!(!back.robust.is_active());
        assert!(back.robust.winsor.is_infinite());
    }

    #[test]
    fn invalid_robust_specs_rejected() {
        let mut c = ExperimentConfig::paper();
        c.robust.attack_frac = 1.5;
        assert!(c.validate().is_err());
        c.robust.attack_frac = f64::NAN;
        assert!(c.validate().is_err(), "NaN attack_frac must be rejected");
        c.robust.attack_frac = 0.2;
        c.robust.attack_lambda = f64::INFINITY;
        assert!(c.validate().is_err());
        c.robust.attack_lambda = -10.0;
        c.robust.clip = 0.0;
        assert!(c.validate().is_err());
        c.robust.clip = f64::NAN;
        assert!(c.validate().is_err(), "NaN clip must be rejected");
        c.robust.clip = f64::INFINITY; // inf disables clipping: valid
        c.validate().unwrap();
        c.robust.winsor = 1.0;
        assert!(c.validate().is_err(), "winsor must exceed 1");
        c.robust.winsor = f64::NAN;
        assert!(c.validate().is_err());
        c.robust.winsor = 4.0;
        c.robust.verify_frac = -0.1;
        assert!(c.validate().is_err());
        c.robust.verify_frac = 0.25;
        c.validate().unwrap();
        // Robust machinery needs an aggregation cohort.
        c.scheme = SchemeKind::Sl;
        assert!(c.validate().is_err(), "sl + robust must be rejected");
        c.robust = RobustConfig::default();
        c.validate().unwrap();
        // Fleet drift gates on an active trace kind.
        c.trace.drift_sigma = 0.05;
        assert!(c.validate().is_err(), "drift on a static trace must be rejected");
        c.trace.kind = TraceKind::RandomWalk;
        c.validate().unwrap();
        c.trace.drift_sigma = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn async_kv_roundtrip_is_symmetric() {
        let dir = std::env::temp_dir().join("sfl_cfg_async_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("async.exp");
        // Non-default knobs round-trip...
        let mut c = ExperimentConfig::paper();
        c.asynchrony =
            AsyncConfig { enabled: true, staleness_bound: 120.0, buffer_k: 3, staleness_beta: 1.0 };
        c.validate().unwrap();
        std::fs::write(&path, c.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.asynchrony, c.asynchrony);
        // ...and so does the disabled default — the [async] section is
        // always written, like [trace]/[pool]/[robust].
        let d = ExperimentConfig::paper();
        std::fs::write(&path, d.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.asynchrony, AsyncConfig::default());
        assert!(!back.asynchrony.enabled);
    }

    #[test]
    fn invalid_async_specs_rejected() {
        let mut c = ExperimentConfig::paper();
        c.asynchrony.staleness_bound = 0.0;
        assert!(c.validate().is_err());
        c.asynchrony.staleness_bound = f64::NAN;
        assert!(c.validate().is_err(), "NaN staleness_bound must be rejected");
        c.asynchrony.staleness_bound = 60.0;
        c.asynchrony.buffer_k = 0;
        assert!(c.validate().is_err());
        c.asynchrony.buffer_k = 4;
        c.asynchrony.staleness_beta = -0.5;
        assert!(c.validate().is_err());
        c.asynchrony.staleness_beta = f64::INFINITY;
        assert!(c.validate().is_err());
        c.asynchrony.staleness_beta = 0.5;
        c.asynchrony.enabled = true;
        c.validate().unwrap();
        // Async needs a parallel scheme.
        c.scheme = SchemeKind::Sl;
        assert!(c.validate().is_err(), "sl + async must be rejected");
    }

    #[test]
    fn transport_kv_roundtrip_is_symmetric() {
        let dir = std::env::temp_dir().join("sfl_cfg_transport_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("transport.exp");
        // Non-default knobs round-trip...
        let mut c = ExperimentConfig::paper();
        c.transport = TransportConfig {
            compress: CompressKind::TopK,
            topk_frac: 0.05,
            quant: QuantKind::Q8,
            error_feedback: true,
        };
        c.validate().unwrap();
        assert!(c.transport.is_active());
        std::fs::write(&path, c.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.transport, c.transport);
        // ...and so does the dense default — the [transport] section is
        // always written, like [async].
        let d = ExperimentConfig::paper();
        std::fs::write(&path, d.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.transport, TransportConfig::default());
        assert!(!back.transport.is_active());
    }

    #[test]
    fn degenerate_topk_is_not_active() {
        // k = 100%, f32, no EF never routes through the codec — the
        // eager-twin invariant keeps it on the dense path entirely.
        let tp = TransportConfig {
            compress: CompressKind::TopK,
            topk_frac: 1.0,
            quant: QuantKind::F32,
            error_feedback: false,
        };
        assert!(!tp.is_active());
        assert!(TransportConfig { error_feedback: true, ..tp }.is_active());
        assert!(TransportConfig { quant: QuantKind::Q8, ..tp }.is_active());
        assert!(TransportConfig { topk_frac: 0.5, ..tp }.is_active());
    }

    #[test]
    fn invalid_transport_specs_rejected() {
        let mut c = ExperimentConfig::paper();
        c.transport.compress = CompressKind::TopK;
        c.transport.topk_frac = 0.0;
        assert!(c.validate().is_err());
        c.transport.topk_frac = 1.5;
        assert!(c.validate().is_err());
        c.transport.topk_frac = f64::NAN;
        assert!(c.validate().is_err(), "NaN topk_frac must be rejected");
        c.transport.topk_frac = 0.05;
        c.transport.quant = QuantKind::Q8;
        c.transport.error_feedback = true;
        c.validate().unwrap();
        // Lossy knobs without compress = topk would be silently ignored.
        c.transport.compress = CompressKind::None;
        assert!(c.validate().is_err(), "quant/EF without topk must be rejected");
        c.transport = TransportConfig::default();
        c.validate().unwrap();
        // Compressed transport needs a parallel scheme.
        c.transport.compress = CompressKind::TopK;
        c.scheme = SchemeKind::Sl;
        assert!(c.validate().is_err(), "sl + transport must be rejected");
    }

    #[test]
    fn quarantine_ttl_and_adaptive_alpha_validated() {
        let mut c = ExperimentConfig::paper();
        // TTL without a committee is rejected — probation means
        // re-verification, which needs witnesses.
        c.robust.quarantine_ttl = 5;
        assert!(c.validate().is_err(), "quarantine_ttl without verify_frac must be rejected");
        c.robust.verify_frac = 0.25;
        c.validate().unwrap();
        // Adaptive EWMA round-trips through kv alongside the fixed α.
        c.train.timing_ewma_adaptive = true;
        let dir = std::env::temp_dir().join("sfl_cfg_ttl_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ttl.exp");
        std::fs::write(&path, c.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.robust.quarantine_ttl, 5);
        assert!(back.train.timing_ewma_adaptive);
    }

    #[test]
    fn channel_kv_roundtrip_is_symmetric() {
        let dir = std::env::temp_dir().join("sfl_cfg_channel_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("channel.exp");
        // Non-default knobs round-trip...
        let mut c = ExperimentConfig::paper();
        c.channel = ChannelConfig {
            loss: 0.1,
            corrupt: 0.02,
            dup: 0.01,
            reorder: 0.01,
            burst: 0.6,
            retry_max: 5,
            retry_base: 0.25,
            rto_mult: 1.5,
            tamper_threshold: 3,
        };
        c.validate().unwrap();
        assert!(c.channel.is_active());
        std::fs::write(&path, c.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.channel, c.channel);
        // ...and so does the reliable default — the [channel] section
        // is always written, like [transport].
        let d = ExperimentConfig::paper();
        std::fs::write(&path, d.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert_eq!(back.channel, ChannelConfig::default());
        assert!(!back.channel.is_active());
    }

    #[test]
    fn all_zero_channel_is_not_active() {
        let ch = ChannelConfig::default();
        assert!(!ch.is_active());
        assert!(ChannelConfig { loss: 0.1, ..ch.clone() }.is_active());
        assert!(ChannelConfig { corrupt: 0.02, ..ch.clone() }.is_active());
        assert!(ChannelConfig { dup: 0.01, ..ch.clone() }.is_active());
        assert!(ChannelConfig { reorder: 0.01, ..ch }.is_active());
    }

    #[test]
    fn invalid_channel_specs_rejected() {
        let mut c = ExperimentConfig::paper();
        c.channel.loss = 1.5;
        assert!(c.validate().is_err());
        c.channel.loss = f64::NAN;
        assert!(c.validate().is_err(), "NaN loss must be rejected");
        c.channel.loss = 0.1;
        c.channel.burst = 1.0;
        assert!(c.validate().is_err(), "burst = 1 (permanent Bad state) must be rejected");
        c.channel.burst = 0.5;
        c.validate().unwrap();
        c.channel.retry_base = 0.0;
        assert!(c.validate().is_err());
        c.channel.retry_base = 0.5;
        c.channel.rto_mult = 0.5;
        assert!(c.validate().is_err(), "shrinking backoff must be rejected");
        c.channel.rto_mult = 2.0;
        c.channel.tamper_threshold = 0;
        assert!(c.validate().is_err());
        c.channel.tamper_threshold = 1;
        c.validate().unwrap();
        // Burst without loss shapes nothing.
        c.channel.loss = 0.0;
        c.channel.corrupt = 0.02;
        assert!(c.validate().is_err(), "burst without loss must be rejected");
        c.channel.burst = 0.0;
        c.validate().unwrap();
        // The channel needs a parallel scheme.
        c.scheme = SchemeKind::Sl;
        assert!(c.validate().is_err(), "sl + channel must be rejected");
    }

    #[test]
    fn retry_knobs_without_lossy_channel_rejected() {
        let mut c = ExperimentConfig::paper();
        c.channel.retry_max = 7;
        assert!(c.validate().is_err(), "retry_max on a reliable channel must be rejected");
        c.channel = ChannelConfig::default();
        c.channel.tamper_threshold = 3;
        assert!(c.validate().is_err(), "tamper_threshold on a reliable channel must be rejected");
        c.channel = ChannelConfig::default();
        c.validate().unwrap();
        // The same knobs are fine once the channel is lossy.
        c.channel.loss = 0.05;
        c.channel.retry_max = 7;
        c.channel.tamper_threshold = 3;
        c.validate().unwrap();
    }

    #[test]
    fn sanitize_adaptive_requires_sanitizer_and_roundtrips() {
        let mut c = ExperimentConfig::paper();
        c.robust.sanitize_adaptive = true;
        assert!(c.validate().is_err(), "adaptive threshold without --sanitize must be rejected");
        c.robust.sanitize = true;
        c.validate().unwrap();
        let dir = std::env::temp_dir().join("sfl_cfg_sanadapt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sanadapt.exp");
        std::fs::write(&path, c.to_kv()).unwrap();
        let back = ExperimentConfig::from_kv_file(&path).unwrap();
        assert!(back.robust.sanitize && back.robust.sanitize_adaptive);
    }

    #[test]
    fn client_config_clones_are_counted() {
        let c = ExperimentConfig::paper();
        let before = client_clone_count();
        let _copy = c.clients[0].clone();
        assert_eq!(client_clone_count(), before + 1);
    }

    #[test]
    fn reset_clone_count_zeroes_and_counter_stays_live() {
        let c = ExperimentConfig::paper();
        let _warm = c.clients[0].clone();
        assert!(client_clone_count() > 0);
        reset_client_clone_count();
        assert_eq!(client_clone_count(), 0, "reset must zero this thread's counter");
        let _copy = c.clients[0].clone();
        assert_eq!(client_clone_count(), 1, "counter must stay live after a reset");
    }

    #[test]
    fn unpinned_cuts_use_selector() {
        let mut c = ExperimentConfig::paper();
        for cl in &mut c.clients {
            cl.cut = None;
        }
        let cuts = c.resolve_cuts();
        assert_eq!(cuts.len(), 6);
        assert!(cuts.iter().all(|&k| (1..=3).contains(&k)));
    }
}
