//! `sflint` — the project's static invariant gate.  Walks `rust/src/**`
//! and enforces rules R1–R5 (determinism, checkpoint coverage, config
//! symmetry, panic discipline, float order); see `rust/lint/README.md`.
//!
//! Run from `rust/`:
//!
//! ```text
//! cargo run --release --bin sflint -- --json sflint-findings.jsonl
//! ```
//!
//! Exit codes: 0 clean (only baselined findings), 1 fresh findings,
//! 2 usage or I/O error.

use anyhow::{bail, Context, Result};
use sfl::lint;
use std::path::PathBuf;

const USAGE: &str = "sflint — static invariant analyzer (rules R1-R5)

USAGE: sflint [--root DIR] [--baseline FILE] [--json FILE] [--write-baseline]

  --root DIR        source tree to scan            (default: src)
  --baseline FILE   grandfathered findings, JSONL  (default: lint/baseline.jsonl)
  --json FILE       also write all findings as JSONL to FILE
  --write-baseline  rewrite the baseline from the current findings and exit 0

Suppress a single finding in source with a trailing or preceding comment:
  // sflint:allow(rule, reason)        e.g. sflint:allow(R4, len checked above)";

fn main() {
    match run() {
        Ok(clean) => std::process::exit(i32::from(!clean)),
        Err(e) => {
            eprintln!("sflint: {e}");
            std::process::exit(2);
        }
    }
}

fn run() -> Result<bool> {
    let mut root = PathBuf::from("src");
    let mut baseline_path = PathBuf::from("lint/baseline.jsonl");
    let mut json_out: Option<PathBuf> = None;
    let mut write_baseline = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => root = PathBuf::from(need(&mut args, "--root")?),
            "--baseline" => baseline_path = PathBuf::from(need(&mut args, "--baseline")?),
            "--json" => json_out = Some(PathBuf::from(need(&mut args, "--json")?)),
            "--write-baseline" => write_baseline = true,
            "--help" | "-h" => {
                println!("{USAGE}");
                return Ok(true);
            }
            other => bail!("unknown argument `{other}` (try --help)"),
        }
    }

    let findings = lint::analyze_tree(&root)?;

    if write_baseline {
        let mut s = String::new();
        for f in &findings {
            s.push_str(&f.to_json());
            s.push('\n');
        }
        std::fs::write(&baseline_path, s)
            .with_context(|| format!("writing {}", baseline_path.display()))?;
        println!("sflint: wrote {} finding(s) to {}", findings.len(), baseline_path.display());
        return Ok(true);
    }

    let baseline = if baseline_path.exists() {
        lint::load_baseline(&baseline_path)?
    } else {
        Vec::new()
    };
    let (fresh, old) = lint::split_baselined(findings, &baseline);

    if let Some(p) = &json_out {
        let mut s = String::new();
        for f in fresh.iter().chain(old.iter()) {
            s.push_str(&f.to_json());
            s.push('\n');
        }
        std::fs::write(p, s).with_context(|| format!("writing {}", p.display()))?;
    }

    if !fresh.is_empty() {
        print!("{}", lint::render_table(&fresh));
    }
    let stale = baseline.iter().filter(|b| !old.iter().any(|f| &f.key() == *b)).count();
    if stale > 0 {
        println!("sflint: note: {stale} baseline entr(ies) no longer match — prune the baseline");
    }
    println!(
        "sflint: {} fresh finding(s), {} baselined, over `{}`",
        fresh.len(),
        old.len(),
        root.display()
    );
    Ok(fresh.is_empty())
}

fn need(args: &mut impl Iterator<Item = String>, flag: &str) -> Result<String> {
    args.next().with_context(|| format!("{flag} requires a value"))
}
