//! The round-stepped Session API: a [`Session`] drives any [`Scheme`]
//! one round at a time, owning every piece of shared bookkeeping exactly
//! once — sim-clock accrual, traffic metering, convergence detection,
//! metric series, the LR schedule, dropout sampling, and `RunResult`
//! assembly.  Schemes implement only the per-round orchestration that
//! actually differs between them (~100 lines each), so new baselines
//! and scenarios plug in without touching the driver.
//!
//! - [`Session::step_round`] runs one round and returns a [`RoundReport`]
//!   (streamed to every registered [`RoundObserver`]).
//! - [`Session::run_to_convergence`] loops `step_round` until the
//!   convergence detector fires or `max_rounds` is reached.
//! - [`Session::checkpoint`] / [`Session::resume`] persist and restore
//!   the *entire* session (model state, optimizer moments, batch
//!   iterators, RNG streams, metric series, traffic counters) so the
//!   remaining rounds replay bit-identically to an uninterrupted run.
//!
//! All three schemes share the zero-allocation steady state: training
//! buffers live in the per-scheme states and the session's
//! [`RoundScratch`] arena, updated in place via the runtime's `*_into`
//! primitives.

use crate::channel::{tier_mult, LossyChannel, NetStats};
use crate::checkpoint::{
    decode_f64s, decode_u64s, encode_f64s, encode_u64s, f64s_exact, load_adapters,
    load_iter_state, load_tensor_into, one_f64, one_i32, one_u64, save_adapters,
    save_iter_state, u64s_exact, write_sflp,
};
use crate::config::{ExperimentConfig, SchedulerKind, SchemeKind};
use crate::coordinator::estimator::TimingEstimator;
use crate::coordinator::lr::LrSchedule;
use crate::coordinator::scheduler::{make_scheduler, makespan, JobInfo, Scheduler};
use crate::coordinator::timing::{self, StepTiming};
use crate::coordinator::{RoundRecord, RunResult};
use crate::data::{self, BatchIter, DataPool, Dataset};
use crate::events::{
    staleness_weight, AsyncStats, BufferedUpdate, Event, EventEngine, UpdateBuffer, VersionVector,
};
use crate::faults::{
    differs, sanitize_updates, AggKind, AttackKind, Committee, FaultInjector, RobustStats,
};
use crate::lora::{
    clipped_fedavg_joined_into, fedavg_joined_into, trimmed_fedavg_joined_into, AdapterSet,
};
use crate::metrics::{Confusion, ConvergenceDetector, MetricSeries};
use crate::model::{memory, memory::MemoryBreakdown, ModelDims};
use crate::net::{Message, TrafficMeter};
use crate::pool::{PoolStats, StatePool};
use crate::runtime::{AdamState, ClientState, Engine, HeadState, ServerState};
use crate::tensor::{ops, rng::Rng, store::ParamStore, HostTensor};
use crate::trace::{EnvSnapshot, EnvTimeline, NoisyObservation, TraceKind};
use crate::transport::{corrupt_wire, Codec, DecodeArena, TransportStats};
use anyhow::{bail, Result};
use std::path::Path;

/// Enum-backed scheduler label shared by `RunResult` and
/// `telemetry::summary` — SL reports its fixed relay order, every other
/// scheme reports the configured scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerLabel {
    /// SL's fixed client relay — no scheduler runs.
    Sequential,
    /// A pluggable server-order policy (Alg. 2 / FIFO / WF / Random).
    Scheduled(SchedulerKind),
}

impl std::fmt::Display for SchedulerLabel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SchedulerLabel::Sequential => write!(f, "sequential"),
            // One mapping, owned by SchedulerKind's Display.
            SchedulerLabel::Scheduled(k) => write!(f, "{k}"),
        }
    }
}

/// Immutable experiment environment shared by the session and every
/// scheme: the engine, the resolved configuration, and the data layout.
pub struct SessionEnv<'e> {
    pub engine: &'e Engine,
    pub cfg: ExperimentConfig,
    /// Dims of the artifacts executed numerically.
    pub dims_exec: ModelDims,
    /// Dims driving the analytic timing/memory model.
    pub dims_time: ModelDims,
    /// Resolved cut point per client.
    pub cuts: Vec<usize>,
    pub ds: Dataset,
    /// The shared data pool: derives any client's shard / aggregation
    /// weight on demand (exact Dirichlet partition on feasible fleets,
    /// seeded derivation with overlap at bench scale — see
    /// [`data::DataPool`]).
    pub data: DataPool,
    /// Per-client timing-model jobs (true device profiles) — the
    /// simulation's ground truth, indexed by global client id.  Jobs
    /// are per-client constants, so both tables are built once and
    /// gathered per round.
    pub oracle_jobs: Vec<JobInfo>,
    /// Per-client jobs from *nominal* profiles (reported specs,
    /// class-default MFU) — the static eq. 10–12 cold-start model the
    /// timing estimator falls back to.
    pub nominal_jobs: Vec<JobInfo>,
}

impl SessionEnv<'_> {
    /// Evaluate a model on (up to `eval_batches` of) the test split:
    /// returns (accuracy, macro-F1, mean loss).
    pub fn evaluate(&self, lora: &AdapterSet, head: &HeadState) -> Result<(f64, f64, f32)> {
        let b = self.dims_exec.batch;
        let n_batches = (self.ds.test.len() / b).min(self.cfg.train.eval_batches);
        let mut conf = Confusion::new(self.dims_exec.classes);
        let mut loss_sum = 0.0f32;
        for i in 0..n_batches {
            let idx: Vec<usize> = (i * b..(i + 1) * b).collect();
            let mut tokens = Vec::with_capacity(b * self.dims_exec.seq);
            let mut labels = Vec::with_capacity(b);
            for &j in &idx {
                tokens.extend_from_slice(&self.ds.test[j].tokens);
                labels.push(self.ds.test[j].label);
            }
            let (logits, loss) = self.engine.eval(&tokens, &labels, lora, head)?;
            conf.record_logits(&logits, &labels);
            loss_sum += loss;
        }
        Ok((conf.accuracy(), conf.macro_f1(), loss_sum / n_batches.max(1) as f32))
    }
}

/// Preallocated working buffers shared by all schemes — the per-round
/// scratch arena.  Allocated once in [`Session::new`]; at steady state
/// every round (client forwards, server steps, client backwards,
/// aggregation, evaluation) reuses these buffers and performs zero
/// `HostTensor` allocations (asserted by tests via `tensor::alloc_count`).
#[derive(Debug)]
pub struct RoundScratch {
    /// Full-depth aggregate target (eqs. 5–7) + aggregated head —
    /// shared by aggregation and `eval_model` (their uses never overlap).
    pub agg_full: AdapterSet,
    pub head: HeadState,
    /// Activations / activation-gradient buffers ([B, L, H]).
    pub acts: HostTensor,
    pub act_grads: HostTensor,
    /// Flat batch buffers ([B*L] tokens, [B] labels).
    pub tokens: Vec<i32>,
    pub labels: Vec<i32>,
    /// Participant membership mask (reused every aggregation).
    pub mask: Vec<bool>,
}

/// Everything one round hands a [`Scheme`]: the shared environment, the
/// session-computed round inputs (LR, participants, prebuilt timing
/// jobs, aggregation flag), and mutable access to the traffic meter and
/// scratch arena.  Jobs are built once per round — they depend only on
/// the round's participants, not the step.
pub struct RoundCtx<'a, 'e> {
    pub env: &'a SessionEnv<'e>,
    /// 1-based round number.
    pub round: usize,
    /// This round's learning rate (LR schedule applied by the session).
    pub round_lr: f32,
    /// Participating client ids (dropout + availability applied by the
    /// session) — indices into `env.cfg.clients` / `env.cuts`, so
    /// schemes use the index-based timing variants instead of cloning
    /// participant `ClientConfig`s per round.
    pub participants: &'a [usize],
    /// Current environment sample (multipliers + availability) — the
    /// inactive timeline (all 1s) on static fleets.
    pub timeline: &'a EnvTimeline,
    /// True timing jobs for the participants (simulation ground truth),
    /// gathered once per round.  `jobs[i].client` is a global id label;
    /// schedulers return positions into this slice.
    pub jobs: &'a [JobInfo],
    /// The jobs the *scheduler* decides on: oracle (`== jobs`) under
    /// `--oracle-timing`, estimator-built otherwise.  Same length and
    /// client labels as `jobs`; only the timing fields may differ.
    pub sched_jobs: &'a [JobInfo],
    /// Whether this round ends with a LoRA aggregation (paper line 17).
    pub aggregate: bool,
    /// The session's Byzantine fault injector — `Some` only when a
    /// tensor/timing attack is configured.  Schemes route aggregation
    /// inputs through it so attackers submit tampered updates.
    pub faults: Option<&'a mut FaultInjector>,
    pub traffic: &'a mut TrafficMeter,
    pub scratch: &'a mut RoundScratch,
}

/// What one scheme round reports back for shared bookkeeping.
#[derive(Debug, Clone, Copy)]
pub struct RoundOutcome {
    /// Virtual time consumed by the round's training steps (accrued
    /// before the round record is written).
    pub train_elapsed: f64,
    /// Virtual time consumed by the aggregation phase, if any (accrued
    /// after the round record — Table I counts it toward the next eval).
    pub agg_elapsed: f64,
    pub mean_loss: f32,
}

/// Evaluation point attached to a [`RoundReport`] on eval rounds.
#[derive(Debug, Clone, Copy)]
pub struct EvalPoint {
    pub acc: f64,
    pub f1: f64,
    /// True once the convergence detector has fired.
    pub converged: bool,
}

/// One round's observable record, streamed to every [`RoundObserver`].
#[derive(Debug, Clone)]
pub struct RoundReport {
    pub scheme: SchemeKind,
    pub scheduler: SchedulerLabel,
    /// 1-based round number.
    pub round: usize,
    /// Virtual clock after this round (aggregation included).
    pub sim_time: f64,
    /// Mean per-step virtual training time this round — the scheduler's
    /// makespan under Ours, the contended/relay step time otherwise.
    pub step_time: f64,
    pub mean_loss: f32,
    /// Client ids that participated (failure injection visibility).
    pub participants: Vec<usize>,
    /// Fleet-wide environment sample for the round (present when an
    /// environment trace is active).
    pub env: Option<EnvSnapshot>,
    /// State-pool counters (present when pooled residency is active:
    /// `pool.state_cap > 0` under a pooling scheme).
    pub pool: Option<PoolStats>,
    /// Robust-aggregation counters (present when any `[robust]` option
    /// is engaged) — the last aggregation's flag/reject/trim tallies.
    pub robust: Option<RobustStats>,
    /// Buffered-async merge counters (present iff `--async`): buffer
    /// size, staleness, and the absolute engine clock at the merge.
    pub asynchrony: Option<AsyncStats>,
    /// Compressed-transport counters (present iff `[transport]` is
    /// active) — the last merge's billed uplink/downlink bytes,
    /// uplink compression ratio, and error-feedback residual norm.
    pub transport: Option<TransportStats>,
    /// Lossy-channel counters (present iff `[channel]` is active) —
    /// this round's transmissions, drops, corruptions, retransmissions,
    /// give-ups, and partial merges.
    pub net: Option<NetStats>,
    /// Present on eval rounds.
    pub eval: Option<EvalPoint>,
}

/// Streaming sink for round telemetry — replaces the old `quiet: bool`
/// flag.  Stdout progress and JSON-lines telemetry are two observers
/// (`telemetry::StdoutObserver`, `telemetry::JsonLinesObserver`).
pub trait RoundObserver {
    fn on_round(&mut self, report: &RoundReport);
    /// Called once by [`Session::run_to_convergence`] with the final result.
    fn on_complete(&mut self, _result: &RunResult) {}
}

/// Per-round orchestration — the only thing that differs between the
/// paper's schemes.  Implementations own their training state (client /
/// server LoRA, optimizer moments, batch iterators); everything shared
/// lives in the [`Session`].
pub trait Scheme {
    /// Label reported in `RunResult.scheduler`.
    fn scheduler(&self) -> SchedulerLabel;
    /// Execute one round: timing + numeric training (+ aggregation when
    /// `ctx.aggregate`), returning the virtual-time and loss outcome.
    fn round(&mut self, ctx: &mut RoundCtx<'_, '_>) -> Result<RoundOutcome>;
    /// The model whose accuracy/F1 the session tracks.  May be computed
    /// into `scratch` (parallel schemes) or borrowed from own state (SL).
    fn eval_model<'s>(
        &'s mut self,
        env: &SessionEnv<'_>,
        scratch: &'s mut RoundScratch,
    ) -> Result<(&'s AdapterSet, &'s HeadState)>;
    /// Analytic server-memory accountant for this scheme.
    fn memory(&self, env: &SessionEnv<'_>) -> MemoryBreakdown;
    /// Server adapter switches so far (0 for schemes without switching).
    fn adapter_switches(&self) -> u64 {
        0
    }
    /// State-pool counters for the round reports — `Some` only when the
    /// scheme runs a bounded (pooled) residency.
    fn pool_stats(&self) -> Option<PoolStats> {
        None
    }
    /// Robust-aggregation counters — `Some` only when the scheme runs
    /// the Byzantine-tolerant aggregation path.
    fn robust_stats(&self) -> Option<RobustStats> {
        None
    }
    /// Compressed-transport counters — `Some` only when the scheme runs
    /// the uplink codec (`[transport]` active).
    fn transport_stats(&self) -> Option<TransportStats> {
        None
    }
    /// Lossy-channel counters — `Some` only when the scheme simulates
    /// the lossy uplink (`[channel]` active).
    fn net_stats(&self) -> Option<NetStats> {
        None
    }
    /// The shared parallel-scheme core, when the scheme has one — the
    /// async event engine drives dispatch-time training and buffered
    /// merges through it directly.  `None` for SL (whose relay has no
    /// async semantics; `--async sl` is rejected at config validation).
    fn parallel_core(&mut self) -> Option<&mut ParallelCore> {
        None
    }
    /// Persist scheme-owned training state as named tensors
    /// (`scheme.*` namespace) for [`Session::checkpoint`].  Pooled
    /// schemes serialize sparsely: only materialized clients.
    fn save_state(&self, out: &mut Vec<(String, HostTensor)>) -> Result<()>;
    /// Restore scheme-owned state saved by [`Scheme::save_state`].
    fn load_state(&mut self, env: &SessionEnv<'_>, store: &ParamStore) -> Result<()>;
}

/// Build the scheme configured in `env.cfg.scheme`.
fn make_scheme(env: &SessionEnv<'_>) -> Result<Box<dyn Scheme>> {
    Ok(match env.cfg.scheme {
        SchemeKind::Ours => Box::new(OursScheme { core: ParallelCore::new(env)? }),
        SchemeKind::Sfl => Box::new(SflScheme { core: ParallelCore::new(env)? }),
        SchemeKind::Sl => Box::new(SlScheme::new(env)?),
    })
}

fn scheme_tag(kind: SchemeKind) -> i32 {
    match kind {
        SchemeKind::Ours => 0,
        SchemeKind::Sl => 1,
        SchemeKind::Sfl => 2,
    }
}

fn sched_tag(kind: SchedulerKind) -> u64 {
    match kind {
        SchedulerKind::Proposed => 0,
        SchedulerKind::Fifo => 1,
        SchedulerKind::WorkloadFirst => 2,
        SchedulerKind::Random => 3,
    }
}

fn trace_tag(kind: TraceKind) -> u64 {
    match kind {
        TraceKind::None => 0,
        TraceKind::RandomWalk => 1,
        TraceKind::Diurnal => 2,
        TraceKind::Markov => 3,
        TraceKind::Replay => 4,
    }
}

/// The config fingerprint stored in a checkpoint and verified on resume:
/// every knob listed here changes the replayed numerics or RNG streams,
/// so resuming under a different value would silently corrupt results.
/// `max_rounds` is deliberately absent — extending the horizon of a
/// resumed run is legitimate.
fn train_fingerprint(cfg: &ExperimentConfig) -> Vec<(&'static str, u64)> {
    let t = &cfg.train;
    let tr = &cfg.trace;
    let r = &cfg.robust;
    let (lrs_tag, lrs_p1, lrs_p2) = match t.lr_schedule {
        LrSchedule::Constant => (0u64, 0u64, 0u64),
        LrSchedule::Linear { horizon, floor } => (1, horizon as u64, floor.to_bits() as u64),
        LrSchedule::Cosine { horizon, floor } => (2, horizon as u64, floor.to_bits() as u64),
        LrSchedule::Warmup { warmup } => (3, warmup as u64, 0),
    };
    let mut fp = vec![
        ("seed", t.seed),
        ("scheduler", sched_tag(cfg.scheduler)),
        ("steps_per_round", t.steps_per_round as u64),
        ("aggregation_interval", t.aggregation_interval as u64),
        ("eval_interval", t.eval_interval as u64),
        ("eval_batches", t.eval_batches as u64),
        ("patience", t.patience as u64),
        ("min_delta", t.min_delta.to_bits()),
        ("dirichlet_alpha", t.dirichlet_alpha.to_bits()),
        ("dropout_prob", t.dropout_prob.to_bits()),
        ("max_participants", t.max_participants as u64),
        ("oracle_timing", t.oracle_timing as u64),
        ("timing_ewma_alpha", t.timing_ewma_alpha.to_bits()),
        ("lr", t.lr.to_bits() as u64),
        ("lr_schedule", lrs_tag),
        ("lr_schedule_horizon", lrs_p1),
        ("lr_schedule_floor", lrs_p2),
        // Environment trace: every knob feeds the timeline/noise RNG
        // streams, so resuming under a different trace would silently
        // desync the trajectory.  The replay *content* is covered
        // separately by the timeline's file hash.
        ("trace_kind", trace_tag(tr.kind)),
        ("trace_seed", tr.seed),
        ("trace_mfu_sigma", tr.mfu_sigma.to_bits()),
        ("trace_link_sigma", tr.link_sigma.to_bits()),
        ("trace_revert", tr.revert.to_bits()),
        ("trace_period", tr.period.to_bits()),
        ("trace_amp", tr.amp.to_bits()),
        ("trace_jitter", tr.jitter.to_bits()),
        ("trace_mean_up", tr.mean_up.to_bits()),
        ("trace_mean_down", tr.mean_down.to_bits()),
        ("trace_obs_noise_sigma", tr.obs_noise_sigma.to_bits()),
        ("trace_replay_path", crate::trace::fnv1a(tr.replay_path.as_bytes())),
    ];
    // Robust/drift knobs extend the fingerprint only when any of them is
    // engaged, so legacy (robust-off, drift-off) checkpoints keep their
    // exact historical layout — and a robust-on resume against a
    // robust-off checkpoint (or vice versa) fails the length check.
    if r.is_active() || r.winsor.is_finite() || tr.drift_sigma > 0.0 {
        fp.extend_from_slice(&[
            ("trace_drift_sigma", tr.drift_sigma.to_bits()),
            ("robust_attack", r.attack.tag()),
            ("robust_attack_frac", r.attack_frac.to_bits()),
            ("robust_attack_lambda", r.attack_lambda.to_bits()),
            ("robust_agg", r.agg.tag()),
            ("robust_trim", r.trim as u64),
            ("robust_clip", r.clip.to_bits()),
            ("robust_sanitize", r.sanitize as u64),
            ("robust_sanitize_mult", r.sanitize_mult.to_bits()),
            ("robust_verify_frac", r.verify_frac.to_bits()),
            ("robust_winsor", r.winsor.to_bits()),
        ]);
    }
    // Each opt-in feature below appends only when engaged, preserving
    // every pre-existing checkpoint layout exactly — and making an
    // on/off mismatch fail the resume length check.
    if r.quarantine_ttl > 0 {
        fp.push(("robust_quarantine_ttl", r.quarantine_ttl as u64));
    }
    if t.timing_ewma_adaptive {
        fp.push(("timing_ewma_adaptive", 1));
    }
    let a = &cfg.asynchrony;
    if a.enabled {
        fp.extend_from_slice(&[
            ("async_staleness_bound", a.staleness_bound.to_bits()),
            ("async_buffer_k", a.buffer_k as u64),
            ("async_staleness_beta", a.staleness_beta.to_bits()),
        ]);
    }
    // Transport knobs change the merged numerics (lossy uplink) and the
    // checkpoint key set (EF residuals), so they are fingerprinted —
    // but only when active, keeping legacy layouts byte-stable.
    let tp = &cfg.transport;
    if tp.is_active() {
        fp.extend_from_slice(&[
            ("transport_compress", tp.compress.tag()),
            ("transport_topk_frac", tp.topk_frac.to_bits()),
            ("transport_quant", tp.quant.tag() as u64),
            ("transport_error_feedback", tp.error_feedback as u64),
        ]);
    }
    // Channel knobs drive their own RNG stream, the retry billing, and
    // the checkpoint key set (sequence/backoff state), so they are
    // fingerprinted — but only when active, keeping channel-off
    // layouts byte-stable.
    let ch = &cfg.channel;
    if ch.is_active() {
        fp.extend_from_slice(&[
            ("channel_loss", ch.loss.to_bits()),
            ("channel_corrupt", ch.corrupt.to_bits()),
            ("channel_dup", ch.dup.to_bits()),
            ("channel_reorder", ch.reorder.to_bits()),
            ("channel_burst", ch.burst.to_bits()),
            ("channel_retry_max", ch.retry_max as u64),
            ("channel_retry_base", ch.retry_base.to_bits()),
            ("channel_rto_mult", ch.rto_mult.to_bits()),
            ("channel_tamper_threshold", ch.tamper_threshold as u64),
        ]);
    }
    // The adaptive sanitizer carries EWMA state in the checkpoint, so
    // the mode itself is fingerprinted when on.
    if r.sanitize_adaptive {
        fp.push(("robust_sanitize_adaptive", 1));
    }
    fp
}

// ---------------------------------------------------------------------
// Checkpoint plumbing: the bit-exact encoders and named-tensor helpers
// live in `crate::checkpoint` (shared with the state pool's sparse
// serialization); only the SL-specific iterator loops remain here.
// ---------------------------------------------------------------------

fn save_iters(out: &mut Vec<(String, HostTensor)>, iters: &[BatchIter]) {
    for (u, it) in iters.iter().enumerate() {
        let (indices, cursor, rng) = it.state();
        save_iter_state(out, u, indices, cursor, rng);
    }
}

fn load_iters(store: &ParamStore, iters: &mut [BatchIter]) -> Result<()> {
    for (u, it) in iters.iter_mut().enumerate() {
        load_iter_state(store, u, it)?;
    }
    Ok(())
}

/// Per-client batch iterators for the whole fleet (SL's relay walks
/// every participant, so its iterators stay eager; the parallel
/// schemes derive theirs lazily through the state pool).
fn fresh_iters(env: &SessionEnv<'_>) -> Vec<BatchIter> {
    let mut scratch = Vec::new();
    (0..env.cuts.len())
        .map(|u| env.data.iter_for(u, env.cfg.train.seed + 100 + u as u64, &mut scratch))
        .collect()
}

/// Zero an optimizer's moments and reset its owner's step counter —
/// SL's per-visit `fresh` semantics without allocating.
fn reset_adam(adam: &mut AdamState) -> Result<()> {
    for t in adam.m.iter_mut().chain(adam.v.iter_mut()) {
        t.as_f32_mut()?.fill(0.0);
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Parallel core — the training state Ours and SFL share (their numerics
// are identical; only timing and memory accounting differ).
// ---------------------------------------------------------------------

/// How the shared core's virtual clock accrues per training step.
enum CoreTiming {
    /// Ours: the makespan of each step's *executed* server order —
    /// computed from the true jobs under the order actually trained, so
    /// stateful schedulers can never be timed against orders that were
    /// not executed.
    PerOrder,
    /// SFL: order-independent contended-parallel step time.
    Fixed(f64),
}

/// Adaptive sanitizer (`--sanitize-mult adaptive`): EWMA smoothing of
/// the observed per-merge norm spread.
const SPREAD_EWMA_ALPHA: f64 = 0.2;
/// Adaptive sanitizer: effective multiplier = max(floor, gain · EWMA).
const ADAPTIVE_MULT_FLOOR: f64 = 2.0;
const ADAPTIVE_MULT_GAIN: f64 = 1.5;

/// Defense-side state for Byzantine-tolerant aggregation: the witness
/// committee, the robust-kernel choice, and reusable scratch buffers.
/// Built only when any `[robust]` option is engaged — the plain
/// aggregation path is untouched (bit-identical) otherwise.
struct RobustDefense {
    agg: AggKind,
    trim: usize,
    clip: f64,
    sanitize: bool,
    sanitize_mult: f64,
    /// `--sanitize-mult adaptive`: derive the outlier threshold from an
    /// EWMA of the per-merge norm spread instead of the fixed
    /// multiplier.  Off ⇒ the fixed path runs bit-identically.
    sanitize_adaptive: bool,
    /// EWMA of the per-merge norm spread (max / median); checkpointed
    /// only when the adaptive mode is on.
    spread_ewma: f64,
    /// Merges that have contributed a spread observation so far.
    spread_obs: u64,
    committee: Committee,
    /// Last aggregation's counters (streamed in round reports).
    stats: RobustStats,
    // Reused per-aggregation scratch — small index/flag buffers, never
    // `HostTensor`s, so the steady state stays tensor-alloc-free.
    survivors: Vec<usize>,
    witnesses: Vec<usize>,
    norms: Vec<f64>,
    keep: Vec<bool>,
    col: Vec<(f32, f32)>,
    /// Clients re-admitted from quarantine this round (scratch for the
    /// committee tick — their EF residuals are cleared on re-entry).
    readmitted: Vec<usize>,
}

/// Uplink-compression state for the merge paths: the shared codec, the
/// server-side decode arena, and a reusable wire buffer.  Built only
/// when `[transport]` is active — degenerate settings (`--compress
/// none`, or top-k at 100% / f32 / no error feedback) never construct
/// one, so the dense path stays verbatim: numerics, traffic billing,
/// and checkpoint layout are all bit-identical.
struct TransportState {
    codec: Codec,
    /// Recycled decode scratch — one client-half set per merge
    /// survivor, indexed by *accepted* position (compacted).
    arena: DecodeArena,
    /// Reused wire copy of the last encode, freeing the codec's payload
    /// borrow before billing / verification / decode.
    wire: Vec<u8>,
    /// Per-merge hash-verification flags, parallel to the merge's
    /// candidate list.
    ok: Vec<bool>,
    /// Last merge's telemetry (streamed in round reports).
    stats: TransportStats,
}

impl TransportState {
    /// One client's upload through the codec: encode its delta vs the
    /// dispatch baseline, verify the content hash, and (on success)
    /// decode the absolute client half into arena slot `slot`.  `sub`
    /// overrides the resident client half (the fault injector's
    /// rewritten submission); `base` overrides the baseline (async
    /// merges encode against the version the client dispatched at).
    /// Byte billing happens in the caller's fleet loop — uploads are
    /// billed for the whole cohort, before server-side rejection.
    /// Returns whether the payload passed verification — a `false` is
    /// the sender's problem, not an error.
    fn pass_one(
        &mut self,
        pool: &mut StatePool,
        env: &SessionEnv<'_>,
        slot: usize,
        u: usize,
        sub: Option<&AdapterSet>,
        base: Option<&AdapterSet>,
    ) -> Result<bool> {
        let k = env.cuts[u];
        {
            let resident = pool.resident(u).ok_or_else(|| {
                anyhow::anyhow!("participant {u} not resident at transport encode")
            })?;
            let x = sub.unwrap_or(&resident.cs.lora);
            let b = base.unwrap_or_else(|| pool.baseline());
            let (bv, _) = b.split_at_views(k)?;
            self.codec.stage_delta(x, &bv)?;
        }
        {
            let ef = if self.codec.error_feedback() { Some(pool.ef_mut(u)?) } else { None };
            let payload = self.codec.encode_staged(ef)?;
            self.wire.clear();
            self.wire.extend_from_slice(payload);
        }
        // Integrity gate: nothing with a bad hash reaches the merge.
        if !Codec::verify(&self.wire) {
            return Ok(false);
        }
        let b = base.unwrap_or_else(|| pool.baseline());
        let (bv, _) = b.split_at_views(k)?;
        Codec::decode_into(&self.wire, &bv, self.arena.slot_mut(slot, &env.dims_exec, k))?;
        Ok(true)
    }
}

/// Lossy-channel state for the merge paths: the seeded channel model
/// plus per-merge scratch for the retry-time and retry-byte accrual.
/// Built only when `[channel]` is active — channel-off runs construct
/// nothing, so numerics, billing, RNG streams, and checkpoint layout
/// all stay bit-identical to the pre-channel code.
struct ChannelState {
    ch: LossyChannel,
    /// Under `--async`, losses and retransmissions run on the engine's
    /// Timeout/Retransmit events — the sync merge-time retry loop must
    /// not roll the dice a second time.
    // sflint:allow(checkpoint-coverage, rebuilt from config at load)
    event_driven: bool,
    /// Per-merge acceptance mask, parallel to the candidate list.
    // sflint:allow(checkpoint-coverage, per-merge scratch; checkpoints are merge-aligned)
    ok: Vec<bool>,
    /// Retransmission legs each client incurred in the last sync merge.
    // sflint:allow(checkpoint-coverage, per-merge scratch; checkpoints are merge-aligned)
    extra_legs: Vec<u32>,
    /// Backoff wait each client accumulated in the last sync merge.
    // sflint:allow(checkpoint-coverage, per-merge scratch; checkpoints are merge-aligned)
    backoff: Vec<f64>,
}

/// Outcome of one upload's bounded-retransmission protocol (sync merge).
enum Delivery {
    /// A verified, in-order copy was accepted (and decoded into the
    /// arena when transport is active).
    Accepted,
    /// Retry budget exhausted — the sender is excluded from this merge
    /// (graceful degradation), never flagged.
    GaveUp,
    /// `tamper_threshold` consecutive hash mismatches — persistent
    /// integrity failure, escalated to the committee by robust callers.
    Tampered,
}

/// Outcome of one event-level delivery attempt (`--async` mode).
enum Attempt {
    /// Push the update into the merge buffer.
    Accepted,
    /// Dropped / corrupted / stale — retransmit or give up.
    Failed,
    /// Consecutive-mismatch threshold reached — escalate.
    Escalate,
}

impl ChannelState {
    /// One event-level delivery attempt of client `u`'s in-flight
    /// upload (async mode): channel dice plus sequence bookkeeping.
    /// There are no wire bytes at the event layer — the codec's
    /// verification runs later, at the merge — so a corrupted delivery
    /// is the server's receive-side integrity failure here.
    fn attempt_async(&mut self, u: usize, seq: u32, threshold: usize) -> Attempt {
        let tx = self.ch.transmit(u);
        if tx.dropped {
            return Attempt::Failed;
        }
        if tx.corrupted {
            if self.ch.note_mismatch(u) as usize >= threshold {
                return Attempt::Escalate;
            }
            return Attempt::Failed;
        }
        // A reordered copy arrives stale (behind newer traffic);
        // duplicates are likewise suppressed by the monotone check.
        let eff = if tx.reordered { seq.wrapping_sub(1) } else { seq };
        if self.ch.accept_seq(u, eff) {
            self.ch.clear_mismatch(u);
            Attempt::Accepted
        } else {
            Attempt::Failed
        }
    }
}

/// One client's upload across the lossy channel at a sync merge: stamp
/// a sequence number, transmit, and retransmit on failure with seeded
/// exponential backoff, up to `retry_max` retries.  With transport
/// active the payload is encoded exactly once — retransmissions reuse
/// the same wire bytes and sequence number, so error feedback is
/// charged once per merge — and every delivered copy re-runs the
/// literal FNV-1a verification (bit corruption flips a real wire bit,
/// self-inverted before the next attempt).  Without transport the same
/// dice and sequence bookkeeping run at message level: a corrupted
/// delivery is an integrity failure without bytes.  Fills
/// `ch.extra_legs[u]` / `ch.backoff[u]` for the retry-time accrual in
/// [`ParallelCore::aggregation_elapsed`].
#[allow(clippy::too_many_arguments)]
fn channel_upload_sync(
    ch: &mut ChannelState,
    mut tp: Option<&mut TransportState>,
    pool: &mut StatePool,
    env: &SessionEnv<'_>,
    slot: usize,
    u: usize,
    sub: Option<&AdapterSet>,
    base: Option<&AdapterSet>,
) -> Result<Delivery> {
    let ccfg = &env.cfg.channel;
    let seq = ch.ch.next_seq(u);
    if let Some(t) = tp.as_deref_mut() {
        let k = env.cuts[u];
        {
            let resident = pool.resident(u).ok_or_else(|| {
                anyhow::anyhow!("participant {u} not resident at transport encode")
            })?;
            let x = sub.unwrap_or(&resident.cs.lora);
            let b = base.unwrap_or_else(|| pool.baseline());
            let (bv, _) = b.split_at_views(k)?;
            t.codec.stage_seq(seq);
            t.codec.stage_delta(x, &bv)?;
        }
        let ef = if t.codec.error_feedback() { Some(pool.ef_mut(u)?) } else { None };
        let payload = t.codec.encode_staged(ef)?;
        t.wire.clear();
        t.wire.extend_from_slice(payload);
    }
    for attempt in 0..=ccfg.retry_max as u32 {
        let tx = ch.ch.transmit(u);
        if !tx.dropped {
            // Integrity first: a sender-side tampered payload fails on
            // *every* retransmission — that persistence is exactly what
            // distinguishes tampering from channel corruption.
            let verified = match tp.as_deref_mut() {
                Some(t) => {
                    if tx.corrupted {
                        corrupt_wire(&mut t.wire, tx.corrupt_bit);
                        let v = Codec::verify(&t.wire);
                        // Self-inverse: restore the real bytes for the
                        // next attempt (and for the decode below).
                        corrupt_wire(&mut t.wire, tx.corrupt_bit);
                        v
                    } else {
                        Codec::verify(&t.wire)
                    }
                }
                None => !tx.corrupted,
            };
            if verified {
                // Freshness: reordered copies arrive stale, duplicates
                // replay an already-accepted number — both suppressed.
                let eff = if tx.reordered { seq.wrapping_sub(1) } else { seq };
                if ch.ch.accept_seq(u, eff) {
                    ch.ch.clear_mismatch(u);
                    if let Some(t) = tp.as_deref_mut() {
                        let k = env.cuts[u];
                        let b = base.unwrap_or_else(|| pool.baseline());
                        let (bv, _) = b.split_at_views(k)?;
                        Codec::decode_into(
                            &t.wire,
                            &bv,
                            t.arena.slot_mut(slot, &env.dims_exec, k),
                        )?;
                    }
                    return Ok(Delivery::Accepted);
                }
            } else {
                let m = ch.ch.note_mismatch(u);
                if m as usize >= ccfg.tamper_threshold {
                    return Ok(Delivery::Tampered);
                }
            }
        }
        if (attempt as usize) < ccfg.retry_max {
            ch.ch.note_retry();
            ch.extra_legs[u] += 1;
            ch.backoff[u] += ch.ch.rto(attempt);
        } else {
            ch.ch.note_gave_up();
        }
    }
    Ok(Delivery::GaveUp)
}

/// Bill every retransmission leg the last sync merge incurred: a retry
/// re-sends the full upload, so each leg bills the same real uplink
/// bytes as the original (the codec's encoded size when transport is
/// active, dense otherwise).
fn bill_retry_traffic(
    env: &SessionEnv<'_>,
    ch: &ChannelState,
    transport: Option<&TransportState>,
    traffic: &mut TrafficMeter,
) {
    for (u, &legs) in ch.extra_legs.iter().enumerate() {
        if legs == 0 {
            continue;
        }
        let k = env.cuts[u];
        let bytes = match transport {
            Some(t) => t.codec.billed_bytes(k * env.dims_time.lora_params_per_layer()),
            None => env.dims_time.lora_bytes(k),
        };
        for _ in 0..legs {
            traffic.record(&Message::LoraUpload { bytes });
        }
    }
}

/// Bill one merge's fleet traffic: every cohort member's upload (at the
/// codec's analytic encoded size when transport is active — uploads
/// happen client-side, before any server-side rejection, so quarantined
/// and hash-rejected senders still bill) plus the dense aggregate
/// broadcast to the whole fleet.  Sizes come from the *timing* model's
/// parameter counts, mirroring how dense uploads bill
/// `dims_time.lora_bytes` regardless of the executed artifact.  Returns
/// `(billed uplink, dense-equivalent uplink, downlink)` byte totals for
/// the transport round stats.
fn bill_merge_traffic(
    env: &SessionEnv<'_>,
    mask: &[bool],
    transport: Option<&TransportState>,
    traffic: &mut TrafficMeter,
) -> (u64, u64, u64) {
    let (mut up_billed, mut up_dense, mut down_bytes) = (0u64, 0u64, 0u64);
    for (u, &k) in env.cuts.iter().enumerate() {
        let dense = env.dims_time.lora_bytes(k);
        if mask[u] {
            let bytes = match transport {
                Some(t) => t.codec.billed_bytes(k * env.dims_time.lora_params_per_layer()),
                None => dense,
            };
            traffic.record(&Message::LoraUpload { bytes });
            up_billed += bytes as u64;
            up_dense += dense as u64;
        }
        traffic.record(&Message::LoraDownload { bytes: dense });
        down_bytes += dense as u64;
    }
    (up_billed, up_dense, down_bytes)
}

/// The training state Ours and SFL share.  Public only so the
/// [`Scheme::parallel_core`] escape hatch can name it from the trait;
/// not part of the crate's intended API surface.
#[doc(hidden)]
pub struct ParallelCore {
    /// Per-client training state + batch iterators, owned by the state
    /// pool: eager (all resident) when `pool.state_cap == 0`, lazily
    /// materialized / spilled at `max(cap, cohort)` residency otherwise.
    /// Either way the trained values are bit-identical.
    pool: StatePool,
    sched: Box<dyn Scheduler>,
    // sflint:allow(checkpoint-coverage, rebuilt from config at load)
    kind: SchedulerKind,
    last_active: Option<usize>,
    switches: u64,
    /// Reused per-step order buffer (job indices) — the schedule path
    /// allocates nothing at steady state.
    // sflint:allow(checkpoint-coverage, scratch buffer, refilled every step)
    order_buf: Vec<usize>,
    /// Byzantine-tolerant aggregation (`Some` iff `[robust]` is active).
    robust: Option<RobustDefense>,
    /// Compressed update transport (`Some` iff `[transport]` is active).
    /// The only durable state is the per-client error-feedback
    /// residual, which lives in (and checkpoints with) the pool.
    // sflint:allow(checkpoint-coverage, EF residuals ride the pool; codec/arena are per-merge scratch)
    transport: Option<TransportState>,
    /// Seeded lossy-channel model (`Some` iff `[channel]` is active).
    channel: Option<ChannelState>,
    /// Who the last merge actually kept, with their *final* normalized
    /// weights (post sanitize/quarantine/decay).  The async engine
    /// delta-corrects stale survivors with exactly these weights — the
    /// robust path may reject or reweight, so callers cannot recompute
    /// them.  Reused buffers, filled by both merge paths.
    // sflint:allow(checkpoint-coverage, valid only within a merge; checkpoints are merge-aligned)
    merge_survivors: Vec<usize>,
    // sflint:allow(checkpoint-coverage, valid only within a merge; checkpoints are merge-aligned)
    merge_weights: Vec<f32>,
}

impl ParallelCore {
    fn new(env: &SessionEnv<'_>) -> Result<Self> {
        let full = env.engine.initial_lora()?;
        let head = env.engine.initial_head()?;
        let mut pool = StatePool::new(
            &env.dims_exec,
            &env.cuts,
            full,
            head,
            env.cfg.train.seed + 100,
            env.cfg.pool.state_cap,
            &env.data,
        )?;
        let r = &env.cfg.robust;
        let robust = r.is_active().then(|| {
            let mut committee = Committee::new(
                env.cuts.len(),
                r.verify_frac,
                env.cfg.train.seed ^ 0xC077_EE5E,
            );
            committee.set_ttl(r.quarantine_ttl);
            RobustDefense {
                agg: r.agg,
                trim: r.trim,
                clip: r.clip,
                sanitize: r.sanitize,
                sanitize_mult: r.sanitize_mult,
                sanitize_adaptive: r.sanitize_adaptive,
                spread_ewma: 0.0,
                spread_obs: 0,
                committee,
                stats: RobustStats::default(),
                survivors: Vec::with_capacity(env.cuts.len()),
                witnesses: Vec::with_capacity(env.cuts.len()),
                norms: Vec::with_capacity(env.cuts.len()),
                keep: Vec::with_capacity(env.cuts.len()),
                col: Vec::with_capacity(env.cuts.len()),
                readmitted: Vec::with_capacity(env.cuts.len()),
            }
        });
        let tcfg = &env.cfg.transport;
        let transport = tcfg.is_active().then(|| TransportState {
            codec: Codec::new(tcfg.topk_frac, tcfg.quant, tcfg.error_feedback),
            arena: DecodeArena::new(),
            wire: Vec::new(),
            ok: Vec::with_capacity(env.cuts.len()),
            stats: TransportStats::default(),
        });
        if tcfg.is_active() && tcfg.error_feedback {
            // EF residuals live in the pool like Adam state: spilled,
            // reloaded, and checkpointed bit-exactly per client.
            pool.enable_error_feedback();
        }
        // The lossy channel seeds its own RNG stream and scales each
        // client's failure probabilities by its link tier — slow links
        // fail more, fast links less (see `channel::tier_mult`).
        let ccfg = &env.cfg.channel;
        let channel = ccfg.is_active().then(|| ChannelState {
            ch: LossyChannel::new(
                ccfg,
                env.cfg.clients.iter().map(|c| tier_mult(c.link.rate_mbps)).collect(),
                env.cfg.train.seed,
            ),
            event_driven: env.cfg.asynchrony.enabled,
            ok: Vec::with_capacity(env.cuts.len()),
            extra_legs: vec![0; env.cuts.len()],
            backoff: vec![0.0; env.cuts.len()],
        });
        Ok(Self {
            pool,
            sched: make_scheduler(env.cfg.scheduler, env.cfg.train.seed),
            kind: env.cfg.scheduler,
            last_active: None,
            switches: 0,
            order_buf: Vec::with_capacity(env.cuts.len()),
            robust,
            transport,
            channel,
            merge_survivors: Vec::with_capacity(env.cuts.len()),
            merge_weights: Vec::with_capacity(env.cuts.len()),
        })
    }

    /// The round shape Ours and SFL share: train `steps_per_round`
    /// steps (accruing virtual time per `accrual`), then aggregate when
    /// the session says so.
    fn run_round(
        &mut self,
        ctx: &mut RoundCtx<'_, '_>,
        accrual: CoreTiming,
    ) -> Result<RoundOutcome> {
        let env = ctx.env;
        // Stamp the pool's LRU clock and bound residency at
        // max(state_cap, cohort) — a round's participants are never
        // evicted mid-round.
        self.pool.begin_round(ctx.round as u64, ctx.participants.len())?;
        // Net counters are per-round (rounds without an aggregation
        // report zeros — nothing crossed the channel).
        if let Some(chs) = self.channel.as_mut() {
            chs.ch.round_reset();
        }
        let time_orders = matches!(accrual, CoreTiming::PerOrder);
        let (mean_loss, ordered_elapsed) = self.train_steps(ctx, time_orders)?;
        let train_elapsed = match accrual {
            CoreTiming::PerOrder => ordered_elapsed,
            CoreTiming::Fixed(t) => env.cfg.train.steps_per_round as f64 * t,
        };
        let agg_elapsed = if ctx.aggregate {
            self.aggregate(
                env,
                ctx.round as u64,
                ctx.participants,
                ctx.faults.as_deref_mut(),
                ctx.traffic,
                ctx.scratch,
            )?;
            self.aggregation_elapsed(env, ctx.participants, ctx.timeline)
        } else {
            0.0
        };
        Ok(RoundOutcome { train_elapsed, agg_elapsed, mean_loss })
    }

    /// Aggregation-phase virtual time for `participants`: dense up +
    /// down transfers historically, or the codec's shrunken uplink when
    /// transport is active (the aggregate broadcast stays dense either
    /// way — every client needs every coordinate).
    fn aggregation_elapsed(
        &self,
        env: &SessionEnv<'_>,
        participants: &[usize],
        timeline: &EnvTimeline,
    ) -> f64 {
        let base = match self.transport.as_ref() {
            Some(tp) => timing::aggregation_time_split(
                &env.dims_time,
                &env.cfg.clients,
                &env.cuts,
                participants,
                timeline,
                &|k| tp.codec.billed_bytes(k * env.dims_time.lora_params_per_layer()),
            ),
            None => timing::aggregation_time_for(
                &env.dims_time,
                &env.cfg.clients,
                &env.cuts,
                participants,
                timeline,
            ),
        };
        // Retry penalty (sync merges only — async retransmissions
        // accrue on the event engine): the uploads run in parallel, so
        // the phase stretches by the slowest participant's backoff
        // waits plus its retransmission legs at its own uplink time.
        if let Some(chs) = self.channel.as_ref() {
            if !chs.event_driven {
                let retry = participants
                    .iter()
                    .map(|&u| {
                        chs.backoff[u]
                            + f64::from(chs.extra_legs[u]) * self.retry_leg(env, u, timeline).1
                    })
                    .fold(0.0, f64::max);
                return base + retry;
            }
        }
        base
    }

    /// One retransmission leg for client `u`: the billed uplink bytes
    /// and their transfer time under the current environment.
    fn retry_leg(
        &self,
        env: &SessionEnv<'_>,
        u: usize,
        timeline: &EnvTimeline,
    ) -> (usize, f64) {
        let k = env.cuts[u];
        let bytes = match self.transport.as_ref() {
            Some(t) => t.codec.billed_bytes(k * env.dims_time.lora_params_per_layer()),
            None => env.dims_time.lora_bytes(k),
        };
        let leg =
            env.cfg.clients[u].link.transfer_time(bytes) / timeline.link_mult(u).max(1e-6);
        (bytes, leg)
    }

    /// Escalate client `u` to the committee after `tamper_threshold`
    /// consecutive integrity failures on the async event path.  Without
    /// a robust defense there is no committee — the upload was already
    /// discarded, which is all the plain path can do.
    fn channel_escalate(&mut self, u: usize, round: u64) {
        if let Some(rb) = self.robust.as_mut() {
            rb.committee.flag(u, round);
            rb.stats.flagged += 1;
            // Flag entry clears the sender's error-feedback residual:
            // whatever it accrued before quarantine is stale against
            // any baseline it would re-enter under.
            self.pool.clear_error_feedback(u);
        }
    }

    /// `steps_per_round` mini-batch steps per participant, all in
    /// place.  Each step draws the server order from the scheduler
    /// exactly once (over `ctx.sched_jobs` — the scheduler's view) and
    /// shares it between execution and the virtual clock (makespan over
    /// the true `ctx.jobs`, walked only when `time_orders` — SFL's
    /// step time is order-independent).  Returns (mean loss, Σ step
    /// makespans, 0.0 when untimed).
    fn train_steps(
        &mut self,
        ctx: &mut RoundCtx<'_, '_>,
        time_orders: bool,
    ) -> Result<(f32, f64)> {
        let env = ctx.env;
        let jobs = ctx.jobs;
        let steps = env.cfg.train.steps_per_round;
        let mut loss_sum = 0.0f32;
        let mut loss_n = 0u32;
        let mut elapsed = 0.0f64;
        for _ in 0..steps {
            self.sched.order_into(ctx.sched_jobs, &mut self.order_buf);
            if time_orders {
                elapsed += makespan(jobs, &self.order_buf);
            }
            // Execute in the same order (adapter-switching bookkeeping);
            // take the buffer to keep the borrow checker out of the loop.
            let order = std::mem::take(&mut self.order_buf);
            for &i in &order {
                let u = jobs[i].client;
                let k = env.cuts[u];
                // Lazily materialize the client's state (bit-equal to
                // the eager path's); evicts the coldest non-cohort
                // resident when the pool is at capacity.
                let slot = self.pool.acquire(u, &env.data)?;
                let idx = slot.it.next_batch();
                data::materialize_batch_into(
                    &env.ds,
                    idx,
                    &mut ctx.scratch.tokens,
                    &mut ctx.scratch.labels,
                );
                env.engine.client_fwd_into(
                    k,
                    &ctx.scratch.tokens,
                    &slot.cs.lora,
                    &mut ctx.scratch.acts,
                )?;
                ctx.traffic
                    .record(&Message::Activations { bytes: env.dims_time.activation_bytes() });
                if self.last_active != Some(u) {
                    self.switches += 1;
                    self.last_active = Some(u);
                }
                let loss = env.engine.server_step_into(
                    k,
                    &ctx.scratch.acts,
                    &ctx.scratch.labels,
                    &mut slot.ss,
                    &mut ctx.scratch.act_grads,
                    ctx.round_lr,
                )?;
                ctx.traffic
                    .record(&Message::ActivationGrads { bytes: env.dims_time.activation_bytes() });
                env.engine.client_bwd_into(
                    k,
                    &ctx.scratch.tokens,
                    &mut slot.cs,
                    &ctx.scratch.act_grads,
                    ctx.round_lr,
                )?;
                loss_sum += loss;
                loss_n += 1;
            }
            self.order_buf = order;
        }
        Ok((loss_sum / loss_n.max(1) as f32, elapsed))
    }

    /// One client's full local round — `steps_per_round` mini-batch
    /// steps against its current pooled state — for the async engine's
    /// train-at-dispatch path.  The per-step numerics are the same
    /// sequence as this client's steps inside
    /// [`ParallelCore::train_steps`]; returns the client's mean loss.
    fn train_client(
        &mut self,
        env: &SessionEnv<'_>,
        u: usize,
        round_lr: f32,
        traffic: &mut TrafficMeter,
        scratch: &mut RoundScratch,
    ) -> Result<f32> {
        let steps = env.cfg.train.steps_per_round;
        let k = env.cuts[u];
        if self.last_active != Some(u) {
            self.switches += 1;
            self.last_active = Some(u);
        }
        let slot = self.pool.acquire(u, &env.data)?;
        let mut loss_sum = 0.0f32;
        for _ in 0..steps {
            let idx = slot.it.next_batch();
            data::materialize_batch_into(&env.ds, idx, &mut scratch.tokens, &mut scratch.labels);
            env.engine.client_fwd_into(k, &scratch.tokens, &slot.cs.lora, &mut scratch.acts)?;
            traffic.record(&Message::Activations { bytes: env.dims_time.activation_bytes() });
            let loss = env.engine.server_step_into(
                k,
                &scratch.acts,
                &scratch.labels,
                &mut slot.ss,
                &mut scratch.act_grads,
                round_lr,
            )?;
            traffic.record(&Message::ActivationGrads { bytes: env.dims_time.activation_bytes() });
            env.engine.client_bwd_into(k, &scratch.tokens, &mut slot.cs, &scratch.act_grads, round_lr)?;
            loss_sum += loss;
        }
        Ok(loss_sum / steps.max(1) as f32)
    }

    /// The FedAvg aggregation phase (paper Alg. 1 lines 17–30), fused
    /// and in place: each participant's halves are scattered straight
    /// into the full-depth scratch aggregate, then redistributed
    /// pool-wide — resident clients get it copied into their buffers,
    /// spilled clients drop their stale segments, and the pool baseline
    /// becomes the aggregate (so fresh clients derive it lazily).  Only
    /// participants contribute weight (failure injection); the
    /// aggregate is still distributed — and its traffic billed — to
    /// every client.
    fn aggregate(
        &mut self,
        env: &SessionEnv<'_>,
        round: u64,
        participants: &[usize],
        faults: Option<&mut FaultInjector>,
        traffic: &mut TrafficMeter,
        scratch: &mut RoundScratch,
    ) -> Result<()> {
        if self.merge_updates(env, round, participants, None, None, faults, traffic, scratch)? {
            self.pool.apply_aggregate(&scratch.agg_full, &scratch.head)?;
        }
        Ok(())
    }

    /// The merge half of aggregation: compute the new global model into
    /// `scratch` without applying it, so the async engine can
    /// delta-correct stale survivors first.  `decay[i]` multiplies
    /// participant `i`'s data weight before normalization (staleness
    /// decay; `None` for sync merges).  Returns `false` when nothing
    /// trustworthy survived (scratch is untouched, the model stands);
    /// on `true`, `merge_survivors` / `merge_weights` hold who was
    /// merged with which final normalized weight.
    fn merge_updates(
        &mut self,
        env: &SessionEnv<'_>,
        round: u64,
        participants: &[usize],
        decay: Option<&[f32]>,
        bases: Option<&[&AdapterSet]>,
        faults: Option<&mut FaultInjector>,
        traffic: &mut TrafficMeter,
        scratch: &mut RoundScratch,
    ) -> Result<bool> {
        if self.robust.is_some() {
            return self
                .merge_robust(env, round, participants, decay, bases, faults, traffic, scratch);
        }
        // Lossy-channel pass (sync merges): every upload runs the
        // bounded-retransmission protocol; what survives is marked in
        // `chs.ok` (and decoded into the arena when transport is also
        // active).  Under `--async` delivery already happened on the
        // engine's events, so the dice are not re-rolled — the plain
        // transport pass below handles integrity alone.
        let mut channel_ran = false;
        if let Some(chs) = self.channel.as_mut() {
            if !chs.event_driven {
                channel_ran = true;
                if let Some(t) = self.transport.as_mut() {
                    t.codec.round_reset();
                }
                chs.ok.clear();
                chs.ok.resize(participants.len(), false);
                chs.extra_legs.iter_mut().for_each(|l| *l = 0);
                chs.backoff.iter_mut().for_each(|x| *x = 0.0);
                let mut kept = 0usize;
                for (i, &u) in participants.iter().enumerate() {
                    let base = bases.map(|b| b[i]);
                    let d = channel_upload_sync(
                        chs,
                        self.transport.as_mut(),
                        &mut self.pool,
                        env,
                        kept,
                        u,
                        None,
                        base,
                    )?;
                    chs.ok[i] = matches!(d, Delivery::Accepted);
                    if chs.ok[i] {
                        kept += 1;
                    }
                }
                // Graceful degradation: retry exhaustion merges the
                // partial cohort with renormalized weights (below).
                if kept > 0 && kept < participants.len() {
                    chs.ch.note_partial_merge();
                }
            }
        }
        // Transport pass: each upload crosses the wire through the
        // codec — encode, verify the content hash, decode into the
        // arena (compacted by accepted position).  With the codec
        // inactive every position is trivially accepted and the
        // historical dense arithmetic below runs untouched.
        if !channel_ran {
            if let Some(tp) = self.transport.as_mut() {
                tp.codec.round_reset();
                tp.ok.clear();
                tp.ok.resize(participants.len(), false);
                let mut kept = 0usize;
                for (i, &u) in participants.iter().enumerate() {
                    let base = bases.map(|b| b[i]);
                    let ok = tp.pass_one(&mut self.pool, env, kept, u, None, base)?;
                    tp.ok[i] = ok;
                    if ok {
                        kept += 1;
                    }
                }
            }
        }
        let tp = self.transport.as_ref();
        // The acceptance mask: the channel's when its sync protocol
        // ran, the codec's hash flags otherwise, `None` (accept all)
        // when neither is active — the exact historical filter.
        let ok_mask: Option<&[bool]> = match self.channel.as_ref() {
            Some(chs) if !chs.event_driven => Some(&chs.ok),
            _ => tp.map(|t| t.ok.as_slice()),
        };
        // `None` keeps the exact historical arithmetic; `Some` folds the
        // decay into each weight before the same normalization.  Only
        // accepted positions carry weight (all of them when no
        // transport or channel is active — rejection needs one).
        let total: f32 = match decay {
            Some(d) => participants
                .iter()
                .zip(d)
                .enumerate()
                .filter(|&(i, _)| ok_mask.map_or(true, |m| m[i]))
                .map(|(_, (&u, &f))| env.data.weight(u) * f)
                .sum(),
            None => participants
                .iter()
                .enumerate()
                .filter(|&(i, _)| ok_mask.map_or(true, |m| m[i]))
                .map(|(_, &u)| env.data.weight(u))
                .sum(),
        };
        self.merge_survivors.clear();
        self.merge_weights.clear();
        let merged = {
            let arena = tp.map(|t| &t.arena);
            let mut contribs: Vec<(f32, &AdapterSet, &AdapterSet)> =
                Vec::with_capacity(participants.len());
            let mut head_pairs_w: Vec<(f32, &HostTensor)> =
                Vec::with_capacity(participants.len());
            let mut head_pairs_b: Vec<(f32, &HostTensor)> =
                Vec::with_capacity(participants.len());
            let mut kept = 0usize;
            for (i, &u) in participants.iter().enumerate() {
                if !ok_mask.map_or(true, |m| m[i]) {
                    continue;
                }
                let slot = self.pool.resident(u).ok_or_else(|| {
                    anyhow::anyhow!("participant {u} not resident at aggregation")
                })?;
                let raw = match decay {
                    Some(d) => env.data.weight(u) * d[i],
                    None => env.data.weight(u),
                };
                let w = raw / total;
                self.merge_survivors.push(u);
                self.merge_weights.push(w);
                // The merge consumes what actually crossed the wire:
                // the decoded (lossy) client half when transport is on.
                let client = match arena {
                    Some(a) => a.get(kept),
                    None => &slot.cs.lora,
                };
                kept += 1;
                contribs.push((w, client, &slot.ss.lora));
                head_pairs_w.push((w, &slot.ss.head.w));
                head_pairs_b.push((w, &slot.ss.head.b));
            }
            // All-rejected (only possible with an active transport or
            // channel) ⇒ the model stands; the historical path merges
            // always.
            let merged = ok_mask.is_none() || !contribs.is_empty();
            if merged {
                fedavg_joined_into(&contribs, &mut scratch.agg_full)?;
                ops::weighted_sum_into(&head_pairs_w, &mut scratch.head.w)?;
                ops::weighted_sum_into(&head_pairs_b, &mut scratch.head.b)?;
            }
            merged
        };
        // O(n) membership mask; traffic is billed for the whole fleet
        // exactly as the eager path did, at the encoded size when
        // transport is active — uploads happen before any server-side
        // rejection, so the whole cohort bills, not just survivors.
        scratch.mask.iter_mut().for_each(|m| *m = false);
        for &u in participants {
            scratch.mask[u] = true;
        }
        let (up_billed, up_dense, down_bytes) =
            bill_merge_traffic(env, &scratch.mask, self.transport.as_ref(), traffic);
        if let Some(t) = self.transport.as_mut() {
            t.codec.note_upload(up_billed, up_dense);
            t.stats = t.codec.round_stats(down_bytes);
        }
        if let Some(chs) = self.channel.as_ref() {
            if !chs.event_driven {
                bill_retry_traffic(env, chs, self.transport.as_ref(), traffic);
            }
        }
        Ok(merged)
    }

    /// Byzantine-tolerant merge: stage (possibly tampered) submissions
    /// through the fault injector, spot-verify a seeded witness
    /// committee against the server's resident replicas (quarantining
    /// liars), reject non-finite / norm-outlier updates, and merge the
    /// survivors with the configured robust kernel — into `scratch`,
    /// *not* applied (see [`ParallelCore::merge_updates`]).  Traffic is
    /// billed exactly like the plain path — rejection happens
    /// server-side, after the upload.
    fn merge_robust(
        &mut self,
        env: &SessionEnv<'_>,
        round: u64,
        participants: &[usize],
        decay: Option<&[f32]>,
        bases: Option<&[&AdapterSet]>,
        mut faults: Option<&mut FaultInjector>,
        traffic: &mut TrafficMeter,
        scratch: &mut RoundScratch,
    ) -> Result<bool> {
        let Some(rb) = self.robust.as_mut() else {
            bail!("robust aggregation invoked without defense state");
        };
        let pool = &mut self.pool;
        let out_survivors = &mut self.merge_survivors;
        let out_weights = &mut self.merge_weights;
        out_survivors.clear();
        out_weights.clear();
        // Quarantine re-admission (`--quarantine-ttl`): expired
        // sentences move to probation before this merge's counters are
        // read.  A no-op (and bit-identical) at ttl = 0.  A re-admitted
        // probationer starts clean: any error-feedback residual it
        // accrued before quarantine is stale against the current
        // baseline and must not leak into its first upload back.
        rb.committee.tick_into(round, &mut rb.readmitted);
        for i in 0..rb.readmitted.len() {
            pool.clear_error_feedback(rb.readmitted[i]);
        }
        rb.stats = RobustStats { quarantined: rb.committee.quarantined_count(), ..Default::default() };
        // 1. Quarantined clients are dropped before anything else — a
        // flagged client never contributes again.
        rb.survivors.clear();
        for &u in participants {
            if !rb.committee.is_quarantined(u) {
                rb.survivors.push(u);
            }
        }
        // 2. Attackers rewrite their submissions (honest clients pass
        // their trained halves through unchanged).
        if let Some(inj) = faults.as_deref_mut() {
            for &u in &rb.survivors {
                let slot = pool.resident(u).ok_or_else(|| {
                    anyhow::anyhow!("participant {u} not resident at aggregation")
                })?;
                inj.prepare(u, &slot.cs.lora, &slot.ss.lora, pool.baseline())?;
            }
        }
        // 3. Seeded spot verification: a deterministic witness sample of
        // this round's submissions is re-checked against the server-side
        // replica of each client's training state (the coordinator ran
        // the very same steps, so any bitwise mismatch is a lie).
        if rb.committee.is_active() {
            rb.witnesses.clear();
            let sample = rb.committee.select(&rb.survivors);
            rb.witnesses.extend_from_slice(sample);
            // Probationers (re-admitted after their TTL) are always
            // re-checked on their first merge back — appended *after*
            // the seeded draw so the witness RNG stream is untouched
            // and ttl = 0 runs stay bit-identical.
            for &u in &rb.survivors {
                if rb.committee.is_probation(u) && !rb.witnesses.contains(&u) {
                    rb.witnesses.push(u);
                }
            }
            for &u in &rb.witnesses {
                let slot = pool.resident(u).ok_or_else(|| {
                    anyhow::anyhow!("witness {u} not resident at verification")
                })?;
                let lied = match faults.as_deref().and_then(|inj| inj.submission(u)) {
                    Some((c, s)) => {
                        differs(c, &slot.cs.lora)? || differs(s, &slot.ss.lora)?
                    }
                    None => false,
                };
                if lied {
                    rb.committee.flag(u, round);
                    rb.stats.flagged += 1;
                    // Quarantine entry clears the liar's error-feedback
                    // residual — see the re-admission note above.
                    pool.clear_error_feedback(u);
                } else if rb.committee.is_probation(u) {
                    // A probationer that passes its re-check is fully
                    // rehabilitated (back to normal witness odds).
                    rb.committee.clear_probation(u);
                }
            }
            let committee = &rb.committee;
            rb.survivors.retain(|&u| !committee.is_quarantined(u));
            rb.stats.quarantined = rb.committee.quarantined_count();
        }
        // 3½. Lossy-channel delivery / transport decode.  With the
        // channel active (sync merges) each surviving upload runs the
        // bounded-retransmission protocol: a hash mismatch triggers a
        // retransmission first — benign corruption is the channel's
        // fault, not the sender's — and only `tamper_threshold`
        // consecutive mismatches escalate to the committee (threshold
        // 1 preserves the immediate-flag behavior).  Retry exhaustion
        // excludes the sender from this merge without flagging
        // (graceful degradation; the partial cohort renormalizes).
        let inj = faults.as_deref();
        let mut channel_ran = false;
        if let Some(chs) = self.channel.as_mut() {
            if !chs.event_driven {
                channel_ran = true;
                if let Some(t) = self.transport.as_mut() {
                    t.codec.round_reset();
                }
                chs.ok.clear();
                chs.ok.resize(rb.survivors.len(), false);
                chs.extra_legs.iter_mut().for_each(|l| *l = 0);
                chs.backoff.iter_mut().for_each(|x| *x = 0.0);
                let before = rb.survivors.len();
                let mut kept = 0usize;
                for (i, &u) in rb.survivors.iter().enumerate() {
                    let sub = inj.and_then(|j| j.submission(u)).map(|(c, _)| c);
                    let base = match bases {
                        Some(bs) => {
                            let p =
                                participants.iter().position(|&p| p == u).ok_or_else(|| {
                                    anyhow::anyhow!(
                                        "survivor {u} not among the merge participants"
                                    )
                                })?;
                            Some(bs[p])
                        }
                        None => None,
                    };
                    match channel_upload_sync(
                        chs,
                        self.transport.as_mut(),
                        pool,
                        env,
                        kept,
                        u,
                        sub,
                        base,
                    )? {
                        Delivery::Accepted => {
                            chs.ok[i] = true;
                            kept += 1;
                        }
                        Delivery::GaveUp => {}
                        Delivery::Tampered => {
                            rb.committee.flag(u, round);
                            rb.stats.flagged += 1;
                            // Quarantine entry clears the EF residual —
                            // see the re-admission note above.
                            pool.clear_error_feedback(u);
                        }
                    }
                }
                let ok = &chs.ok;
                let mut i = 0;
                rb.survivors.retain(|_| {
                    let keep = ok[i];
                    i += 1;
                    keep
                });
                rb.stats.quarantined = rb.committee.quarantined_count();
                if kept > 0 && kept < before {
                    chs.ch.note_partial_merge();
                }
            }
        }
        // Transport decode (channel off, or `--async` where delivery
        // already happened on the engine's events): each surviving
        // upload crosses the wire through the codec.  A hash mismatch
        // here flags the sender — immediately when no channel is
        // configured (the historical behavior), through the
        // consecutive-mismatch threshold when one is (async merges see
        // only sender-side tampering at this point; channel corruption
        // was already handled per event).
        if !channel_ran {
            if let Some(tp) = self.transport.as_mut() {
                tp.codec.round_reset();
                tp.ok.clear();
                tp.ok.resize(rb.survivors.len(), false);
                let mut kept = 0usize;
                for (i, &u) in rb.survivors.iter().enumerate() {
                    let sub = inj.and_then(|j| j.submission(u)).map(|(c, _)| c);
                    let base = match bases {
                        Some(bs) => {
                            let p =
                                participants.iter().position(|&p| p == u).ok_or_else(|| {
                                    anyhow::anyhow!(
                                        "survivor {u} not among the merge participants"
                                    )
                                })?;
                            Some(bs[p])
                        }
                        None => None,
                    };
                    let ok = tp.pass_one(pool, env, kept, u, sub, base)?;
                    tp.ok[i] = ok;
                    if ok {
                        kept += 1;
                        if let Some(chs) = self.channel.as_mut() {
                            chs.ch.clear_mismatch(u);
                        }
                    } else {
                        let escalate = match self.channel.as_mut() {
                            Some(chs) => {
                                chs.ch.note_mismatch(u) as usize
                                    >= env.cfg.channel.tamper_threshold
                            }
                            None => true,
                        };
                        if escalate {
                            rb.committee.flag(u, round);
                            rb.stats.flagged += 1;
                            // Quarantine entry clears the EF residual —
                            // see the re-admission note above.
                            pool.clear_error_feedback(u);
                        }
                    }
                }
                let ok = &tp.ok;
                let mut i = 0;
                rb.survivors.retain(|_| {
                    let keep = ok[i];
                    i += 1;
                    keep
                });
                rb.stats.quarantined = rb.committee.quarantined_count();
            }
        }
        // Traffic: billed for the original participants exactly like
        // the plain path — uploads happen client-side, before any
        // server-side rejection, at the encoded size when transport is
        // on.  (Meter totals are order-independent, so billing here —
        // before the sanitizer — matches the historical totals.)
        scratch.mask.iter_mut().for_each(|m| *m = false);
        for &u in participants {
            scratch.mask[u] = true;
        }
        let (up_billed, up_dense, down_bytes) =
            bill_merge_traffic(env, &scratch.mask, self.transport.as_ref(), traffic);
        if let Some(t) = self.transport.as_mut() {
            t.codec.note_upload(up_billed, up_dense);
            t.stats = t.codec.round_stats(down_bytes);
        }
        if let Some(chs) = self.channel.as_ref() {
            if !chs.event_driven {
                bill_retry_traffic(env, chs, self.transport.as_ref(), traffic);
            }
        }
        // 4. Gather the surviving submissions with their raw data
        // weights (normalized after sanitization, over what's kept).
        // With transport active the client half is the *decoded* one —
        // the merge consumes what actually crossed the wire.
        let arena = self.transport.as_ref().map(|t| &t.arena);
        let mut subs: Vec<(f32, &AdapterSet, &AdapterSet)> =
            Vec::with_capacity(rb.survivors.len());
        for (i, &u) in rb.survivors.iter().enumerate() {
            let slot = pool
                .resident(u)
                .ok_or_else(|| anyhow::anyhow!("participant {u} not resident at aggregation"))?;
            let (c, s) = match inj.and_then(|j| j.submission(u)) {
                Some(pair) => pair,
                None => (&slot.cs.lora, &slot.ss.lora),
            };
            let c = match arena {
                Some(a) => a.get(i),
                None => c,
            };
            // Staleness decay (async merges) folds into the raw weight,
            // indexed by the survivor's position in `participants`.
            let raw = match decay {
                Some(d) => {
                    let i = participants.iter().position(|&p| p == u).ok_or_else(|| {
                        anyhow::anyhow!("survivor {u} not among the merge participants")
                    })?;
                    env.data.weight(u) * d[i]
                }
                None => env.data.weight(u),
            };
            subs.push((raw, c, s));
        }
        // 5. Pre-merge sanitizer: reject non-finite or norm-outlier
        // deltas before they reach the kernel.  In adaptive mode the
        // multiplier tracks an EWMA of the observed per-round norm
        // spread — use-then-update: this round's threshold comes from
        // *prior* rounds only, so checkpoint/resume replays decide each
        // round from identical state.
        if rb.sanitize && !subs.is_empty() {
            let mult = if rb.sanitize_adaptive && rb.spread_obs > 0 {
                (rb.spread_ewma * ADAPTIVE_MULT_GAIN).max(ADAPTIVE_MULT_FLOOR)
            } else {
                rb.sanitize_mult
            };
            rb.stats.rejected = sanitize_updates(
                &subs,
                pool.baseline(),
                mult,
                &mut rb.norms,
                &mut rb.keep,
            )?;
            if rb.sanitize_adaptive {
                if let Some(spread) = crate::faults::norm_spread(&rb.norms) {
                    rb.spread_ewma = if rb.spread_obs == 0 {
                        spread
                    } else {
                        (1.0 - SPREAD_EWMA_ALPHA) * rb.spread_ewma + SPREAD_EWMA_ALPHA * spread
                    };
                    rb.spread_obs += 1;
                }
            }
            if rb.stats.rejected > 0 {
                let keep = &rb.keep;
                let mut i = 0;
                subs.retain(|_| {
                    let k = keep[i];
                    i += 1;
                    k
                });
                let mut i = 0;
                rb.survivors.retain(|_| {
                    let k = keep[i];
                    i += 1;
                    k
                });
            }
        }
        // 6. Nothing trustworthy left ⇒ skip the model update entirely
        // (the cohort keeps training from the unchanged baseline).
        let total: f32 = subs.iter().map(|&(w, _, _)| w).sum();
        if subs.is_empty() || !total.is_finite() || total <= 0.0 {
            return Ok(false);
        }
        for sub in subs.iter_mut() {
            sub.0 /= total;
        }
        // 7. The robust merge kernel (all in place, zero tensor allocs).
        match rb.agg {
            AggKind::Mean => fedavg_joined_into(&subs, &mut scratch.agg_full)?,
            AggKind::Trimmed => {
                // Cap the trim so at least one coordinate survives.
                let trim = rb.trim.min(subs.len().saturating_sub(1) / 2);
                rb.stats.trim_count = 2 * trim as u64;
                trimmed_fedavg_joined_into(&subs, trim, &mut rb.col, &mut scratch.agg_full)?;
            }
            AggKind::Clip => {
                rb.stats.trim_count = clipped_fedavg_joined_into(
                    &subs,
                    pool.baseline(),
                    rb.clip,
                    &mut scratch.agg_full,
                )?;
            }
        }
        // Heads follow the kept survivors with the same normalized
        // weights (the attack model targets the LoRA submissions).
        let mut head_pairs_w: Vec<(f32, &HostTensor)> = Vec::with_capacity(rb.survivors.len());
        let mut head_pairs_b: Vec<(f32, &HostTensor)> = Vec::with_capacity(rb.survivors.len());
        for (i, &u) in rb.survivors.iter().enumerate() {
            let slot = pool
                .resident(u)
                .ok_or_else(|| anyhow::anyhow!("participant {u} not resident at aggregation"))?;
            head_pairs_w.push((subs[i].0, &slot.ss.head.w));
            head_pairs_b.push((subs[i].0, &slot.ss.head.b));
        }
        ops::weighted_sum_into(&head_pairs_w, &mut scratch.head.w)?;
        ops::weighted_sum_into(&head_pairs_b, &mut scratch.head.b)?;
        // Expose who survived, with the weights the kernel actually
        // used (exact for mean; first-order for trimmed/clipped, whose
        // per-coordinate edits aren't expressible as one scalar).
        out_survivors.extend_from_slice(&rb.survivors);
        out_weights.extend(subs.iter().map(|&(w, _, _)| w));
        Ok(true)
    }

    /// Data-weighted global model (eqs. 5–8 evaluated without replacing
    /// per-client state), computed into the scratch arena.  Delegated
    /// to the pool, which accumulates resident / spilled / baseline
    /// clients in id order — bit-identical to the eager fedavg path.
    fn global_model_into(&self, env: &SessionEnv<'_>, scratch: &mut RoundScratch) -> Result<()> {
        self.pool.global_model_into(&env.data, &mut scratch.agg_full, &mut scratch.head)
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        self.pool.is_pooled().then(|| self.pool.stats())
    }

    fn robust_stats(&self) -> Option<RobustStats> {
        self.robust.as_ref().map(|rb| rb.stats)
    }

    fn transport_stats(&self) -> Option<TransportStats> {
        self.transport.as_ref().map(|tp| tp.stats)
    }

    fn net_stats(&self) -> Option<NetStats> {
        self.channel.as_ref().map(|c| c.ch.stats())
    }

    fn save_state(&self, out: &mut Vec<(String, HostTensor)>) -> Result<()> {
        self.pool.save_state(out)?;
        out.push(("scheme.switches".into(), encode_u64s("switches", &[self.switches])));
        let last = self.last_active.map(|u| u as i32).unwrap_or(-1);
        out.push((
            "scheme.last_active".into(),
            HostTensor::i32("scheme.last_active", vec![1], vec![last]),
        ));
        if let Some(st) = self.sched.rng_state() {
            out.push(("scheme.sched_rng".into(), encode_u64s("sched_rng", &[st])));
        }
        // Robust defense state rides only when engaged — a plain run's
        // checkpoint carries no new keys.
        if let Some(rb) = &self.robust {
            out.push((
                "scheme.robust_rng".into(),
                encode_u64s("robust_rng", &[rb.committee.rng_state()]),
            ));
            out.push((
                "scheme.quarantine".into(),
                encode_u64s("quarantine", &rb.committee.quarantine_words()),
            ));
            out.push((
                "scheme.flagged".into(),
                encode_u64s("flagged", &[rb.committee.flagged_total]),
            ));
            // Re-admission bookkeeping only exists when a TTL is set
            // (and is then also fingerprinted), so legacy robust
            // checkpoints keep their exact key set.
            if rb.committee.ttl() > 0 {
                out.push((
                    "scheme.probation".into(),
                    encode_u64s("probation", &rb.committee.ttl_state()),
                ));
            }
            // Adaptive-sanitizer EWMA rides only in adaptive mode (the
            // mode is fingerprinted); fixed-mult checkpoints keep their
            // exact key set.
            if rb.sanitize_adaptive {
                out.push((
                    "scheme.sanitize_ewma".into(),
                    encode_u64s("sanitize_ewma", &[rb.spread_ewma.to_bits(), rb.spread_obs]),
                ));
            }
        }
        // Lossy-channel state (RNG + per-client GE/seq/mismatch words)
        // exists only when the channel is configured — channel-off
        // checkpoints stay byte-identical to earlier layouts.
        if let Some(chs) = &self.channel {
            out.push(("scheme.channel".into(), encode_u64s("channel", &chs.ch.state())));
        }
        Ok(())
    }

    fn load_state(&mut self, env: &SessionEnv<'_>, store: &ParamStore) -> Result<()> {
        self.pool.load_state(store, &env.data)?;
        self.switches = one_u64(store, "scheme.switches")?;
        let last = one_i32(store, "scheme.last_active")?;
        self.last_active = if last < 0 { None } else { Some(last as usize) };
        if store.get("scheme.sched_rng").is_ok() {
            self.sched.set_rng_state(one_u64(store, "scheme.sched_rng")?);
        }
        if let Some(rb) = &mut self.robust {
            // The fingerprint guarantees a robust config resumes only a
            // robust checkpoint, so these keys must be present.
            rb.committee.set_rng_state(one_u64(store, "scheme.robust_rng")?);
            rb.committee.restore_quarantine(&decode_u64s(store.get("scheme.quarantine")?)?)?;
            rb.committee.flagged_total = one_u64(store, "scheme.flagged")?;
            if rb.committee.ttl() > 0 {
                rb.committee
                    .restore_ttl_state(&decode_u64s(store.get("scheme.probation")?)?)?;
            }
            if rb.sanitize_adaptive {
                let w = u64s_exact(store, "scheme.sanitize_ewma", 2)?;
                rb.spread_ewma = f64::from_bits(w[0]);
                rb.spread_obs = w[1];
            }
        }
        if let Some(chs) = &mut self.channel {
            chs.ch.restore_state(&decode_u64s(store.get("scheme.channel")?)?)?;
        }
        Ok(())
    }
}

/// **Ours** (paper Alg. 1): parallel client forwards → sequential server
/// LoRA training ordered by the pluggable scheduler → parallel client
/// backwards, with periodic aggregation.
pub struct OursScheme {
    core: ParallelCore,
}

impl Scheme for OursScheme {
    fn scheduler(&self) -> SchedulerLabel {
        SchedulerLabel::Scheduled(self.core.kind)
    }

    fn round(&mut self, ctx: &mut RoundCtx<'_, '_>) -> Result<RoundOutcome> {
        // Per-step orders are drawn (and timed) inside the shared core —
        // one draw per step, shared by timing and execution.
        self.core.run_round(ctx, CoreTiming::PerOrder)
    }

    fn eval_model<'s>(
        &'s mut self,
        env: &SessionEnv<'_>,
        scratch: &'s mut RoundScratch,
    ) -> Result<(&'s AdapterSet, &'s HeadState)> {
        self.core.global_model_into(env, scratch)?;
        Ok((&scratch.agg_full, &scratch.head))
    }

    fn memory(&self, env: &SessionEnv<'_>) -> MemoryBreakdown {
        if self.core.pool.is_pooled() {
            // Pooled accountant: only the resident clients hold
            // LoRA/optimizer state on the server.
            memory::pooled_server_memory(
                &env.dims_time,
                &env.cuts,
                &self.core.pool.resident_cuts(),
            )
        } else {
            memory::ours_server_memory(&env.dims_time, &env.cuts)
        }
    }

    fn adapter_switches(&self) -> u64 {
        self.core.switches
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        self.core.pool_stats()
    }

    fn robust_stats(&self) -> Option<RobustStats> {
        self.core.robust_stats()
    }

    fn transport_stats(&self) -> Option<TransportStats> {
        self.core.transport_stats()
    }

    fn net_stats(&self) -> Option<NetStats> {
        self.core.net_stats()
    }

    fn parallel_core(&mut self) -> Option<&mut ParallelCore> {
        Some(&mut self.core)
    }

    fn save_state(&self, out: &mut Vec<(String, HostTensor)>) -> Result<()> {
        self.core.save_state(out)
    }

    fn load_state(&mut self, env: &SessionEnv<'_>, store: &ParamStore) -> Result<()> {
        self.core.load_state(env, store)
    }
}

/// **SFL** baseline: numerically identical to Ours (the difference is
/// timing and memory — per-client server submodels train in parallel,
/// contending for the GPU).  The analytic memory model stays the
/// eager per-client-submodel accounting regardless of the state pool —
/// O(fleet) server residency is exactly the baseline's deficiency the
/// paper measures.
pub struct SflScheme {
    core: ParallelCore,
}

impl Scheme for SflScheme {
    fn scheduler(&self) -> SchedulerLabel {
        SchedulerLabel::Scheduled(self.core.kind)
    }

    fn round(&mut self, ctx: &mut RoundCtx<'_, '_>) -> Result<RoundOutcome> {
        let env = ctx.env;
        let step_time = timing::sfl_step_for(
            ctx.jobs,
            &env.dims_time,
            &env.cuts,
            ctx.participants,
            &env.cfg.server,
        );
        self.core.run_round(ctx, CoreTiming::Fixed(step_time))
    }

    fn eval_model<'s>(
        &'s mut self,
        env: &SessionEnv<'_>,
        scratch: &'s mut RoundScratch,
    ) -> Result<(&'s AdapterSet, &'s HeadState)> {
        self.core.global_model_into(env, scratch)?;
        Ok((&scratch.agg_full, &scratch.head))
    }

    fn memory(&self, env: &SessionEnv<'_>) -> MemoryBreakdown {
        memory::sfl_server_memory(&env.dims_time, &env.cuts)
    }

    fn adapter_switches(&self) -> u64 {
        self.core.switches
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        self.core.pool_stats()
    }

    fn robust_stats(&self) -> Option<RobustStats> {
        self.core.robust_stats()
    }

    fn transport_stats(&self) -> Option<TransportStats> {
        self.core.transport_stats()
    }

    fn net_stats(&self) -> Option<NetStats> {
        self.core.net_stats()
    }

    fn parallel_core(&mut self) -> Option<&mut ParallelCore> {
        Some(&mut self.core)
    }

    fn save_state(&self, out: &mut Vec<(String, HostTensor)>) -> Result<()> {
        self.core.save_state(out)
    }

    fn load_state(&mut self, env: &SessionEnv<'_>, store: &ParamStore) -> Result<()> {
        self.core.load_state(env, store)
    }
}

/// **SL** baseline: one global adapter set relayed through the clients,
/// no aggregation.  Ported onto the in-place primitives: the relay
/// copies into preallocated per-client state buffers (`split_into`,
/// `copy_from`, optimizer reset in place) and joins back with
/// `join_into`, so the steady state allocates zero `HostTensor`s —
/// same invariant as the parallel schemes.
///
/// Behavior change vs the old `Trainer::run_sl`: dropout sampling is
/// session-owned and scheme-agnostic, so with `dropout_prob > 0` SL now
/// relays only through the round's surviving participants (previously
/// SL ignored failure injection entirely).  `dropout_prob = 0` — the
/// paper's setting — is unchanged.
pub struct SlScheme {
    /// The relayed global model.
    full: AdapterSet,
    head: HeadState,
    /// Reused per-client working states (refilled at every visit).
    // sflint:allow(checkpoint-coverage, scratch, refilled from `full` at every visit)
    clients: Vec<ClientState>,
    // sflint:allow(checkpoint-coverage, scratch, refilled from `full` at every visit)
    servers: Vec<ServerState>,
    iters: Vec<BatchIter>,
}

impl SlScheme {
    fn new(env: &SessionEnv<'_>) -> Result<Self> {
        let full = env.engine.initial_lora()?;
        let head = env.engine.initial_head()?;
        let mut clients = Vec::new();
        let mut servers = Vec::new();
        for &k in &env.cuts {
            let (c, s) = full.split_at(k)?;
            clients.push(ClientState::fresh(c));
            servers.push(ServerState::fresh(s, head.clone()));
        }
        Ok(Self { full, head, clients, servers, iters: fresh_iters(env) })
    }
}

impl Scheme for SlScheme {
    fn scheduler(&self) -> SchedulerLabel {
        SchedulerLabel::Sequential
    }

    fn round(&mut self, ctx: &mut RoundCtx<'_, '_>) -> Result<RoundOutcome> {
        let env = ctx.env;
        let steps = env.cfg.train.steps_per_round;
        let train_elapsed = timing::sl_round_for(
            &env.dims_time,
            &env.cfg.clients,
            &env.cuts,
            &env.cfg.server,
            steps,
            ctx.participants,
            ctx.timeline,
        );
        let mut loss_sum = 0.0f32;
        let mut loss_n = 0u32;
        for &u in ctx.participants {
            let k = env.cuts[u];
            // Relay: client u receives the current global model into its
            // reused buffers; optimizer state is not relayed (fresh Adam
            // per visit, as in the baseline).
            self.full.split_into(k, &mut self.clients[u].lora, &mut self.servers[u].lora)?;
            ops::copy_from(&mut self.servers[u].head.w, &self.head.w)?;
            ops::copy_from(&mut self.servers[u].head.b, &self.head.b)?;
            reset_adam(&mut self.clients[u].adam)?;
            self.clients[u].step = 0;
            reset_adam(&mut self.servers[u].adam)?;
            self.servers[u].step = 0;
            for _ in 0..steps {
                let idx = self.iters[u].next_batch();
                data::materialize_batch_into(
                    &env.ds,
                    idx,
                    &mut ctx.scratch.tokens,
                    &mut ctx.scratch.labels,
                );
                env.engine.client_fwd_into(
                    k,
                    &ctx.scratch.tokens,
                    &self.clients[u].lora,
                    &mut ctx.scratch.acts,
                )?;
                ctx.traffic
                    .record(&Message::Activations { bytes: env.dims_time.activation_bytes() });
                let loss = env.engine.server_step_into(
                    k,
                    &ctx.scratch.acts,
                    &ctx.scratch.labels,
                    &mut self.servers[u],
                    &mut ctx.scratch.act_grads,
                    ctx.round_lr,
                )?;
                ctx.traffic
                    .record(&Message::ActivationGrads { bytes: env.dims_time.activation_bytes() });
                env.engine.client_bwd_into(
                    k,
                    &ctx.scratch.tokens,
                    &mut self.clients[u],
                    &ctx.scratch.act_grads,
                    ctx.round_lr,
                )?;
                loss_sum += loss;
                loss_n += 1;
            }
            // Hand the trained halves back to the relay.
            AdapterSet::join_into(&self.clients[u].lora, &self.servers[u].lora, &mut self.full)?;
            ops::copy_from(&mut self.head.w, &self.servers[u].head.w)?;
            ops::copy_from(&mut self.head.b, &self.servers[u].head.b)?;
        }
        Ok(RoundOutcome {
            train_elapsed,
            agg_elapsed: 0.0,
            mean_loss: loss_sum / loss_n.max(1) as f32,
        })
    }

    fn eval_model<'s>(
        &'s mut self,
        _env: &SessionEnv<'_>,
        _scratch: &'s mut RoundScratch,
    ) -> Result<(&'s AdapterSet, &'s HeadState)> {
        Ok((&self.full, &self.head))
    }

    fn memory(&self, env: &SessionEnv<'_>) -> MemoryBreakdown {
        memory::sl_server_memory(&env.dims_time, &env.cuts)
    }

    fn save_state(&self, out: &mut Vec<(String, HostTensor)>) -> Result<()> {
        save_adapters(out, "scheme.full", &self.full);
        out.push(("scheme.head.w".into(), self.head.w.clone()));
        out.push(("scheme.head.b".into(), self.head.b.clone()));
        save_iters(out, &self.iters);
        Ok(())
    }

    fn load_state(&mut self, _env: &SessionEnv<'_>, store: &ParamStore) -> Result<()> {
        load_adapters(store, "scheme.full", &mut self.full)?;
        load_tensor_into(store, "scheme.head.w", &mut self.head.w)?;
        load_tensor_into(store, "scheme.head.b", &mut self.head.b)?;
        load_iters(store, &mut self.iters)
    }
}

// ---------------------------------------------------------------------
// The session itself.
// ---------------------------------------------------------------------

/// Mutable shared bookkeeping, owned by the session and written exactly
/// once for all schemes.
struct Book {
    /// Completed rounds (1-based; 0 before the first `step_round`).
    round: usize,
    sim_time: f64,
    rounds: Vec<RoundRecord>,
    acc: MetricSeries,
    f1: MetricSeries,
    final_acc: f64,
    final_f1: f64,
    detector: ConvergenceDetector,
    traffic: TrafficMeter,
    dropout_rng: Rng,
    converged: bool,
    /// Online per-client timing model (ignored under `oracle_timing`).
    estimator: TimingEstimator,
    /// Environment timeline (non-stationary MFU/link/availability),
    /// sampled once per round; the inactive timeline on static fleets.
    timeline: EnvTimeline,
    /// Measurement noise between true timings and estimator input.
    obs_noise: NoisyObservation,
    /// Byzantine fault injector (`Some` iff an attack is configured):
    /// rewrites attacker submissions at aggregation and scales the
    /// timings TimingLie attackers report to the estimator.
    faults: Option<FaultInjector>,
    /// Reused per-round gathers of the participant jobs.
    jobs_buf: Vec<JobInfo>,
    sched_jobs_buf: Vec<JobInfo>,
    /// Engine exec counter at session start (or resume).
    exec_base: u64,
    /// Executions recorded by earlier segments of a resumed run.
    execs_prior: u64,
    // sflint:allow(determinism, wall-clock telemetry only; never feeds the sim)
    wall: std::time::Instant,
    wall_prior: f64,
    scratch: RoundScratch,
    /// The discrete-event engine every scheme's clock now runs through:
    /// sync rounds schedule their cohort barrier as one aggregation
    /// trigger (bit-identical to the old `+=` accrual); async mode
    /// runs the full arrival/completion/trigger protocol on it.
    engine: EventEngine,
    /// Buffered-async bookkeeping (`Some` iff `--async`).
    asyncx: Option<AsyncBook>,
}

/// Async-mode state: version vector, update buffer, in-flight markers,
/// and the baseline snapshots stale updates are delta-corrected against.
struct AsyncBook {
    versions: VersionVector,
    buffer: UpdateBuffer,
    /// Client dispatched but not yet completed — its pooled state holds
    /// trained-but-undelivered tensors, protected from baseline
    /// redistribution at merges.
    inflight: Vec<bool>,
    /// Mean loss of each client's latest dispatch (train-at-dispatch:
    /// the numerics run at dispatch, the metadata arrives at completion).
    pending_loss: Vec<f32>,
    /// Current staleness-timer epoch — a popped trigger from an earlier
    /// epoch is stale and ignored.
    trigger_epoch: u64,
    /// Baseline snapshots keyed by model version, GC'd to versions some
    /// in-flight dispatch still references.  Empty until the first
    /// `step_round_async` seeds version 0 and the arrival wave.
    baselines: Vec<(u64, AdapterSet, HeadState)>,
    /// Reused per-merge buffers.
    parts: Vec<usize>,
    decay: Vec<f32>,
    protect: Vec<bool>,
}

impl AsyncBook {
    fn new(n: usize) -> Self {
        Self {
            versions: VersionVector::new(n),
            buffer: UpdateBuffer::new(),
            inflight: vec![false; n],
            pending_loss: vec![0.0; n],
            trigger_epoch: 0,
            baselines: Vec::new(),
            parts: Vec::with_capacity(n),
            decay: Vec::with_capacity(n),
            protect: vec![false; n],
        }
    }
}

/// The resumable round-stepped experiment driver.  Owns the shared
/// bookkeeping; delegates per-round orchestration to the configured
/// [`Scheme`]; streams [`RoundReport`]s to registered observers.
pub struct Session<'e> {
    env: SessionEnv<'e>,
    scheme: Box<dyn Scheme>,
    book: Book,
    observers: Vec<Box<dyn RoundObserver>>,
}

impl<'e> Session<'e> {
    pub fn new(engine: &'e Engine, cfg: &ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let dims_exec = engine.dims().clone();
        let dims_time = cfg.timing_dims();
        let cuts = cfg.resolve_cuts();
        let spec = data::CorpusSpec {
            seed: cfg.train.seed,
            ..data::CorpusSpec::carer_like(dims_exec.vocab, dims_exec.seq)
        };
        let ds = data::generate(&spec);
        // The shared data pool lets shards overlap at bench scale, so
        // the only hard floor is that each round's *active cohort* gets
        // one batch each (the old `corpus / batch` fleet cap is gone).
        data::numeric_feasibility(
            ds.train.len(),
            cfg.clients.len(),
            dims_exec.batch,
            cfg.train.max_participants,
        )?;
        let pool_data = DataPool::new(
            &ds.train,
            cfg.clients.len(),
            cfg.train.dirichlet_alpha,
            cfg.train.seed + 1,
            dims_exec.batch,
        );
        // Per-client job tables: true profiles (ground truth) and
        // nominal profiles (the static cold-start model).  JobInfo is
        // per-client, so both are round-invariant on a stationary fleet.
        let oracle_jobs = timing::build_jobs(&dims_time, &cfg.clients, &cuts, &cfg.server);
        let nominal_jobs = timing::build_nominal_jobs(&dims_time, &cfg.clients, &cuts, &cfg.server);
        let env = SessionEnv {
            engine,
            cfg: cfg.clone(),
            dims_exec,
            dims_time,
            cuts,
            ds,
            data: pool_data,
            oracle_jobs,
            nominal_jobs,
        };
        let scheme = make_scheme(&env)?;

        let head0 = engine.initial_head()?;
        let acts_shape =
            vec![env.dims_exec.batch, env.dims_exec.seq, env.dims_exec.hidden];
        let scratch = RoundScratch {
            agg_full: AdapterSet::zeros(&env.dims_exec, env.dims_exec.layers),
            head: HeadState {
                w: HostTensor::zeros(head0.w.name.clone(), head0.w.shape.clone()),
                b: HostTensor::zeros(head0.b.name.clone(), head0.b.shape.clone()),
            },
            acts: HostTensor::zeros("acts", acts_shape.clone()),
            act_grads: HostTensor::zeros("act_grads", acts_shape),
            tokens: Vec::with_capacity(env.dims_exec.batch * env.dims_exec.seq),
            labels: Vec::with_capacity(env.dims_exec.batch),
            mask: vec![false; env.cuts.len()],
        };
        // The environment timeline is re-synthesized from its spec
        // (resume restores only the mutable generator state); a replay
        // trace whose file is missing fails loudly right here.
        let timeline = EnvTimeline::new(&cfg.trace, env.cuts.len())?;
        let obs_noise =
            NoisyObservation::new(cfg.train.seed ^ 0x0B5E_C0DE, cfg.trace.obs_noise_sigma);
        let t = &cfg.train;
        // The fault injector's RNG stream is derived like every other
        // auxiliary stream (seed ^ constant) — a clean run draws
        // nothing from it because it is never constructed.
        let r = &cfg.robust;
        let faults = (r.attack != AttackKind::None && r.attack_frac > 0.0).then(|| {
            FaultInjector::new(
                env.cuts.len(),
                r.attack,
                r.attack_frac,
                r.attack_lambda,
                t.seed ^ 0xFA17_5EED,
            )
        });
        let mut estimator = TimingEstimator::new(env.cuts.len(), t.timing_ewma_alpha);
        estimator.set_winsor(r.winsor);
        estimator.set_adaptive(t.timing_ewma_adaptive);
        let book = Book {
            round: 0,
            sim_time: 0.0,
            rounds: Vec::new(),
            acc: MetricSeries::default(),
            f1: MetricSeries::default(),
            final_acc: 0.0,
            final_f1: 0.0,
            detector: ConvergenceDetector::new(t.patience, t.min_delta),
            traffic: TrafficMeter::default(),
            dropout_rng: Rng::new(t.seed ^ 0xD809),
            converged: false,
            estimator,
            timeline,
            obs_noise,
            faults,
            jobs_buf: Vec::with_capacity(env.cuts.len()),
            sched_jobs_buf: Vec::with_capacity(env.cuts.len()),
            exec_base: engine.exec_count(),
            execs_prior: 0,
            // sflint:allow(determinism, wall-clock telemetry only; never feeds the sim)
            wall: std::time::Instant::now(),
            wall_prior: 0.0,
            scratch,
            engine: EventEngine::new(),
            asyncx: cfg.asynchrony.enabled.then(|| AsyncBook::new(env.cuts.len())),
        };
        Ok(Self { env, scheme, book, observers: Vec::new() })
    }

    /// Register a streaming telemetry sink.
    pub fn add_observer(&mut self, obs: Box<dyn RoundObserver>) {
        self.observers.push(obs);
    }

    pub fn env(&self) -> &SessionEnv<'e> {
        &self.env
    }

    pub fn cuts(&self) -> &[usize] {
        &self.env.cuts
    }

    pub fn dataset(&self) -> &Dataset {
        &self.env.ds
    }

    /// Completed rounds so far.
    pub fn round(&self) -> usize {
        self.book.round
    }

    /// Current virtual clock.
    pub fn sim_time(&self) -> f64 {
        self.book.sim_time
    }

    /// State-pool counters, when pooled residency is active (tests and
    /// diagnostics; the same snapshot streams in every round report).
    pub fn pool_stats(&self) -> Option<PoolStats> {
        self.scheme.pool_stats()
    }

    /// Test hook: corrupt the next `n` transport payloads after hashing
    /// (via [`Codec::tamper_next`]), so server-side verification
    /// rejects them.  No-op when `[transport]` is inactive.
    #[doc(hidden)]
    pub fn transport_tamper_next(&mut self, n: u32) {
        if let Some(core) = self.scheme.parallel_core() {
            if let Some(tp) = core.transport.as_mut() {
                tp.codec.tamper_next(n);
            }
        }
    }

    /// True once the run should stop: convergence detected or
    /// `max_rounds` reached.  (`step_round` may still be called past
    /// this point to train further.)
    pub fn done(&self) -> bool {
        self.book.converged || self.book.round >= self.env.cfg.train.max_rounds
    }

    /// Execute one round: dropout sampling, per-round job construction,
    /// scheme dispatch, sim-clock accrual, periodic evaluation and
    /// convergence tracking — then stream a [`RoundReport`].
    ///
    /// Under `--async` a "round" is one buffered merge driven by the
    /// discrete-event engine; otherwise the classic synchronous round,
    /// whose cohort barrier now also runs through the engine (as one
    /// aggregation-trigger event — bit-identical to the legacy accrual,
    /// asserted against [`Session::step_round_reference`] by tests).
    pub fn step_round(&mut self) -> Result<RoundReport> {
        if self.book.asyncx.is_some() {
            return self.step_round_async();
        }
        self.step_round_sync(true)
    }

    /// The pre-engine synchronous round, preserved verbatim as the
    /// bit-identity anchor for the sync-via-engine property tests.
    /// Not part of the intended API surface.
    #[doc(hidden)]
    pub fn step_round_reference(&mut self) -> Result<RoundReport> {
        self.step_round_sync(false)
    }

    /// The synchronous round body.  `via_engine` selects how the
    /// cohort's train-time barrier accrues onto the sim clock: through
    /// a scheduled+popped engine event (the production path) or the
    /// historical `+=` (the reference path).  An f64 stored into an
    /// event and read back is the same f64, so both are bit-identical —
    /// which is exactly what the property tests assert.
    fn step_round_sync(&mut self, via_engine: bool) -> Result<RoundReport> {
        let round = self.book.round + 1;
        let t = &self.env.cfg.train;
        let round_lr = t.lr_schedule.at(t.lr, round);

        // ---- environment timeline: one sample per round ----
        // Sampled at the sim clock's current time, before scheduling or
        // execution — the whole round sees one consistent environment.
        let env_snapshot = if self.book.timeline.is_active() {
            self.book.timeline.advance(self.book.sim_time);
            Some(self.book.timeline.snapshot())
        } else {
            None
        };

        // ---- failure injection: which clients participate? ----
        let n = self.env.cuts.len();
        let mut participants: Vec<usize> = if t.dropout_prob > 0.0 {
            let rng = &mut self.book.dropout_rng;
            let mut p: Vec<usize> =
                (0..n).filter(|_| rng.uniform() >= t.dropout_prob).collect();
            if p.is_empty() {
                // Never stall a round entirely: keep one survivor.
                p.push(rng.below(n));
            }
            p
        } else {
            (0..n).collect()
        };
        // ---- availability (environment churn) ----
        // An unavailable client is *skipped* for the round — composing
        // with dropout sampling — never dropped from the fleet.
        if self.book.timeline.is_active() {
            let tl = &self.book.timeline;
            participants.retain(|&u| tl.is_available(u));
            if participants.is_empty() {
                // Churn emptied the round (dropout removed every
                // available client): keep one survivor, drawn uniformly
                // from the *available* clients when any exist.  Only a
                // total blackout forces an unavailable one — a session
                // round cannot be skipped (aggregation/eval cadence and
                // the batch/RNG streams must advance), so best-effort
                // progress on one client is the deliberate semantic
                // here; the analytic regret harness, which has no such
                // constraint, skips blackout rounds instead (see
                // coordinator::regret).
                let available = (0..n).filter(|&u| tl.is_available(u)).count();
                let pick = if available > 0 {
                    let k = self.book.dropout_rng.below(available);
                    (0..n).filter(|&u| tl.is_available(u)).nth(k).unwrap_or(0)
                } else {
                    self.book.dropout_rng.below(n)
                };
                participants.push(pick);
            }
        }
        // ---- bounded participation (fleet scale) ----
        if t.max_participants > 0 && participants.len() > t.max_participants {
            // Partial Fisher–Yates: the first `max_participants` slots
            // become a uniform sample of the survivors.
            let rng = &mut self.book.dropout_rng;
            for i in 0..t.max_participants {
                let j = i + rng.below(participants.len() - i);
                participants.swap(i, j);
            }
            participants.truncate(t.max_participants);
            participants.sort_unstable();
        }
        // Gather the participants' true jobs into the reused buffer —
        // per-client constants on a static fleet, the environment-scaled
        // current-time jobs under an active timeline.  `jobs_buf` is the
        // simulation's ground truth; `sched_jobs_buf` is what the
        // scheduler sees — oracle (clairvoyant) under --oracle-timing,
        // otherwise the online estimate (static nominal model until a
        // client is observed).
        self.book.jobs_buf.clear();
        if self.book.timeline.is_active() {
            let tl = &self.book.timeline;
            self.book.jobs_buf.extend(participants.iter().map(|&u| {
                timing::scaled_job(&self.env.oracle_jobs[u], tl.mfu_mult(u), tl.link_mult(u))
            }));
        } else {
            self.book.jobs_buf.extend(participants.iter().map(|&u| self.env.oracle_jobs[u]));
        }
        self.book.sched_jobs_buf.clear();
        if t.oracle_timing {
            self.book.sched_jobs_buf.extend_from_slice(&self.book.jobs_buf);
        } else {
            let est = &self.book.estimator;
            self.book
                .sched_jobs_buf
                .extend(participants.iter().map(|&u| est.job_for(&self.env.nominal_jobs[u])));
        }
        let aggregate = round % t.aggregation_interval == 0;

        let outcome = {
            let mut ctx = RoundCtx {
                env: &self.env,
                round,
                round_lr,
                participants: &participants,
                timeline: &self.book.timeline,
                jobs: &self.book.jobs_buf,
                sched_jobs: &self.book.sched_jobs_buf,
                aggregate,
                faults: self.book.faults.as_mut(),
                traffic: &mut self.book.traffic,
                scratch: &mut self.book.scratch,
            };
            self.scheme.round(&mut ctx)?
        };
        // ---- online timing feedback ----
        // The round's true per-client timings (queue-independent
        // components) are what deployed clients would report back; the
        // estimator folds them into its EWMAs for the next round —
        // through the measurement-noise channel when configured.
        if !t.oracle_timing {
            let b = &mut self.book;
            for j in &b.jobs_buf {
                let clean = StepTiming::from_job(j);
                let mut obs =
                    if b.obs_noise.is_active() { clean.noisy(&mut b.obs_noise) } else { clean };
                // TimingLie attackers misreport every channel by |λ| —
                // the estimator only ever sees what clients claim.
                if let Some(inj) = &b.faults {
                    if inj.kind() == AttackKind::TimingLie && inj.is_attacker(j.client) {
                        obs = obs.scaled(inj.lie_factor());
                    }
                }
                b.estimator.observe(j.client, &obs);
            }
        }
        // Commit the round only after the scheme succeeded — a failed
        // round leaves the counter (and thus any later checkpoint)
        // pointing at the last fully completed round.  (Training state
        // may still be mid-step poisoned per the runtime's error
        // contract; discard the session on error rather than resuming
        // from its in-memory state.)
        self.book.round = round;

        if via_engine {
            let barrier = self.book.sim_time + outcome.train_elapsed;
            self.book.engine.schedule(barrier, Event::AggregationTrigger { epoch: round as u64 });
            let ev = self.book.engine.pop().ok_or_else(|| {
                anyhow::anyhow!("engine queue empty despite a just-scheduled barrier event")
            })?;
            self.book.sim_time = ev.time;
        } else {
            self.book.sim_time += outcome.train_elapsed;
        }
        self.book.rounds.push(RoundRecord {
            round,
            sim_time: self.book.sim_time,
            mean_loss: outcome.mean_loss,
        });
        self.book.sim_time += outcome.agg_elapsed;

        // ---- evaluation + convergence ----
        let mut eval = None;
        if round % t.eval_interval == 0 {
            let (lora, head) = self.scheme.eval_model(&self.env, &mut self.book.scratch)?;
            let (acc, f1, _eval_loss) = self.env.evaluate(lora, head)?;
            self.book.acc.push(round, self.book.sim_time, acc);
            self.book.f1.push(round, self.book.sim_time, f1);
            self.book.final_acc = acc;
            self.book.final_f1 = f1;
            let converged = self.book.detector.update(round, self.book.sim_time, acc);
            self.book.converged = converged;
            eval = Some(EvalPoint { acc, f1, converged });
        }

        let report = RoundReport {
            scheme: self.env.cfg.scheme,
            scheduler: self.scheme.scheduler(),
            round,
            sim_time: self.book.sim_time,
            step_time: outcome.train_elapsed / t.steps_per_round as f64,
            mean_loss: outcome.mean_loss,
            participants,
            env: env_snapshot,
            pool: self.scheme.pool_stats(),
            robust: self.scheme.robust_stats(),
            asynchrony: None,
            transport: self.scheme.transport_stats(),
            net: self.scheme.net_stats(),
            eval,
        };
        for obs in &mut self.observers {
            obs.on_round(&report);
        }
        Ok(report)
    }

    /// One buffered-async "round": run the discrete-event engine until
    /// a merge fires, then report it.  Clients arrive, train against
    /// the *current* global model at dispatch (train-at-dispatch), and
    /// deliver their update at a completion event `steps × solo-step`
    /// later; the server merges when `buffer_k` updates are buffered or
    /// the staleness bound `τ` elapses after the first one.  Stale
    /// survivors are decay-weighted (`1/(1+s)^β`) and delta-corrected
    /// against their dispatch baseline, so a merge of only fresh
    /// updates reproduces the synchronous arithmetic exactly.
    ///
    /// Round bookkeeping is keyed on the merge index: the LR schedule,
    /// eval cadence, and convergence detector see one "round" per
    /// merge.  `aggregation_interval` is ignored — every async round
    /// ends in its merge by construction.
    fn step_round_async(&mut self) -> Result<RoundReport> {
        let round = self.book.round + 1;
        let env = &self.env;
        let t = &env.cfg.train;
        let acfg = env.cfg.asynchrony;
        let steps = t.steps_per_round;
        let round_lr = t.lr_schedule.at(t.lr, round);
        let n = env.cuts.len();
        let sim_before = self.book.sim_time;

        let core = self
            .scheme
            .parallel_core()
            .ok_or_else(|| anyhow::anyhow!("--async requires a parallel scheme (ours/sfl)"))?;
        let b = &mut self.book;
        let Some(ab) = b.asyncx.as_mut() else {
            bail!("step_round_async called without async bookkeeping");
        };

        // First call: snapshot the version-0 baseline and seed the
        // initial arrival wave (id order at t = 0; engine sequence
        // numbers keep the order deterministic).  Resume never re-runs
        // this — checkpoints happen at merge boundaries, where the
        // restored `baselines` is non-empty.
        if ab.baselines.is_empty() {
            ab.baselines.push((0, core.pool.baseline().clone(), core.pool.baseline_head().clone()));
            for u in 0..n {
                b.engine.schedule(0.0, Event::ClientArrival { client: u });
            }
        }
        // Merge cohorts are capped by the buffer; participants stay
        // resident from (re-)acquisition below through the merge.
        core.pool.begin_round(round as u64, acfg.buffer_k)?;
        // Channel counters report per merge window, mirroring the sync
        // per-round reset.
        if let Some(chs) = core.channel.as_mut() {
            chs.ch.round_reset();
        }

        // ---- drive the event loop until a merge fires ----
        let (stats, participants, mean_loss, merge_time, agg_elapsed) = loop {
            let ev = match b.engine.pop() {
                Some(ev) => ev,
                None => bail!("async event queue drained — no client has pending work"),
            };
            let now = ev.time;
            let merge_due = match ev.event {
                Event::ClientArrival { client: u } | Event::AvailabilityFlip { client: u } => {
                    if b.timeline.is_active() {
                        b.timeline.advance(now);
                        if !b.timeline.is_available(u) {
                            // Unavailable at dispatch: back off one
                            // nominal local round and re-check.
                            let backoff = steps as f64 * timing::solo_step(&env.nominal_jobs[u]);
                            b.engine.schedule(now + backoff, Event::AvailabilityFlip { client: u });
                            continue;
                        }
                    }
                    if t.dropout_prob > 0.0 && b.dropout_rng.uniform() < t.dropout_prob {
                        // Dropout at dispatch: the client re-arrives one
                        // nominal round later instead of skipping a
                        // whole sync round.
                        let backoff = steps as f64 * timing::solo_step(&env.nominal_jobs[u]);
                        b.engine.schedule(now + backoff, Event::ClientArrival { client: u });
                        continue;
                    }
                    // Dispatch: the client's numerics run now, against
                    // the current global model; only the metadata waits
                    // for the completion event.
                    ab.versions.mark_dispatch(u);
                    ab.inflight[u] = true;
                    ab.pending_loss[u] =
                        core.train_client(env, u, round_lr, &mut b.traffic, &mut b.scratch)?;
                    let job = if b.timeline.is_active() {
                        timing::scaled_job(
                            &env.oracle_jobs[u],
                            b.timeline.mfu_mult(u),
                            b.timeline.link_mult(u),
                        )
                    } else {
                        env.oracle_jobs[u]
                    };
                    // Online timing feedback happens per dispatch (the
                    // client reports what it measured), through the
                    // same noise + TimingLie channel as sync rounds.
                    if !t.oracle_timing {
                        let clean = StepTiming::from_job(&job);
                        let mut obs = if b.obs_noise.is_active() {
                            clean.noisy(&mut b.obs_noise)
                        } else {
                            clean
                        };
                        if let Some(inj) = &b.faults {
                            if inj.kind() == AttackKind::TimingLie && inj.is_attacker(u) {
                                obs = obs.scaled(inj.lie_factor());
                            }
                        }
                        b.estimator.observe(u, &obs);
                    }
                    let duration = steps as f64 * timing::solo_step(&job);
                    b.engine.schedule(now + duration, Event::ClientCompletion { client: u });
                    false
                }
                Event::ClientCompletion { client: u } => {
                    // Lossy channel: completion carries the *first*
                    // delivery attempt.  A failed attempt leaves the
                    // client in flight — its trained-but-undelivered
                    // state is protected from re-dispatch — and arms a
                    // timeout for the retransmission machinery.
                    if let Some(chs) = core.channel.as_mut() {
                        let seq = chs.ch.next_seq(u);
                        let threshold = env.cfg.channel.tamper_threshold;
                        match chs.attempt_async(u, seq, threshold) {
                            Attempt::Accepted => {}
                            Attempt::Failed => {
                                if env.cfg.channel.retry_max > 0 {
                                    let rto = chs.ch.rto(0);
                                    b.engine.schedule(
                                        now + rto,
                                        Event::Timeout { client: u, attempt: 0 },
                                    );
                                } else {
                                    // No retry budget: the update is
                                    // lost outright.
                                    chs.ch.note_gave_up();
                                    ab.inflight[u] = false;
                                    b.engine.schedule(now, Event::ClientArrival { client: u });
                                }
                                continue;
                            }
                            Attempt::Escalate => {
                                core.channel_escalate(u, round as u64);
                                ab.inflight[u] = false;
                                b.engine.schedule(now, Event::ClientArrival { client: u });
                                continue;
                            }
                        }
                    }
                    ab.inflight[u] = false;
                    ab.buffer.push(BufferedUpdate {
                        client: u,
                        version: ab.versions.client_version(u),
                        loss: ab.pending_loss[u],
                        completed_at: now,
                    });
                    let due = ab.buffer.len() >= acfg.buffer_k;
                    if !due && ab.buffer.len() == 1 {
                        // First update into an empty buffer arms the
                        // staleness timer for this buffer epoch.
                        ab.trigger_epoch += 1;
                        b.engine.schedule(
                            now + acfg.staleness_bound,
                            Event::AggregationTrigger { epoch: ab.trigger_epoch },
                        );
                    }
                    due
                }
                Event::AggregationTrigger { epoch } => {
                    // A trigger from an earlier epoch is stale — its
                    // buffer already merged (or was re-armed).
                    epoch == ab.trigger_epoch && !ab.buffer.is_empty()
                }
                Event::Timeout { client: u, attempt } => {
                    // The server's per-message timeout fired: bill the
                    // retransmission's real uplink bytes and land the
                    // re-sent frame one transfer leg later.
                    if let Some(chs) = core.channel.as_mut() {
                        chs.ch.note_retry();
                    }
                    let (bytes, leg) = core.retry_leg(env, u, &b.timeline);
                    b.traffic.record(&Message::LoraUpload { bytes });
                    b.engine.schedule(now + leg, Event::Retransmit { client: u, attempt });
                    false
                }
                Event::Retransmit { client: u, attempt } => {
                    let Some(chs) = core.channel.as_mut() else {
                        bail!("retransmit event without an active channel");
                    };
                    // Retransmissions re-send the same frame: the same
                    // sequence number crosses the channel again and the
                    // FNV-1a verify re-runs at the merge.
                    let seq = chs.ch.current_seq(u);
                    let threshold = env.cfg.channel.tamper_threshold;
                    let retry_max = env.cfg.channel.retry_max;
                    match chs.attempt_async(u, seq, threshold) {
                        Attempt::Accepted => {
                            ab.inflight[u] = false;
                            ab.buffer.push(BufferedUpdate {
                                client: u,
                                version: ab.versions.client_version(u),
                                loss: ab.pending_loss[u],
                                completed_at: now,
                            });
                            let due = ab.buffer.len() >= acfg.buffer_k;
                            if !due && ab.buffer.len() == 1 {
                                ab.trigger_epoch += 1;
                                b.engine.schedule(
                                    now + acfg.staleness_bound,
                                    Event::AggregationTrigger { epoch: ab.trigger_epoch },
                                );
                            }
                            due
                        }
                        Attempt::Failed => {
                            let next = attempt + 1;
                            if (next as usize) < retry_max {
                                let rto = chs.ch.rto(next);
                                b.engine.schedule(
                                    now + rto,
                                    Event::Timeout { client: u, attempt: next },
                                );
                            } else {
                                // Retry budget exhausted: graceful
                                // degradation — the update ages out of
                                // the window and the client simply
                                // rejoins the arrival stream.
                                chs.ch.note_gave_up();
                                ab.inflight[u] = false;
                                b.engine.schedule(now, Event::ClientArrival { client: u });
                            }
                            false
                        }
                        Attempt::Escalate => {
                            core.channel_escalate(u, round as u64);
                            ab.inflight[u] = false;
                            b.engine.schedule(now, Event::ClientArrival { client: u });
                            false
                        }
                    }
                }
            };
            if !merge_due {
                continue;
            }

            // ---- buffered merge at `now` ----
            let cur = ab.versions.model_version();
            ab.parts.clear();
            ab.decay.clear();
            let mut max_staleness = 0u64;
            for e in ab.buffer.entries() {
                ab.parts.push(e.client);
                let s = cur - e.version;
                max_staleness = max_staleness.max(s);
                ab.decay.push(staleness_weight(s, acfg.staleness_beta) as f32);
            }
            let buffered = ab.parts.len();
            // Later dispatches may have spilled a buffered client's
            // pooled state — re-acquire (spill/reload is bit-exact) so
            // everything merged is resident.
            for &u in &ab.parts {
                core.pool.acquire(u, &env.data)?;
            }
            // With transport active each upload is encoded against the
            // baseline its sender dispatched from (b_v) — the decoded
            // absolute update then feeds the existing delta-correction
            // below unchanged.
            let mut base_refs: Vec<&AdapterSet> = Vec::new();
            if core.transport.is_some() {
                base_refs.reserve(ab.parts.len());
                for &u in &ab.parts {
                    let v = ab.versions.client_version(u);
                    let (_, base, _) = ab
                        .baselines
                        .iter()
                        .find(|(ver, _, _)| *ver == v)
                        .ok_or_else(|| {
                            anyhow::anyhow!("no baseline snapshot for model version {v}")
                        })?;
                    base_refs.push(base);
                }
            }
            let merged_ok = core.merge_updates(
                env,
                round as u64,
                &ab.parts,
                Some(&ab.decay),
                (!base_refs.is_empty()).then_some(base_refs.as_slice()),
                b.faults.as_mut(),
                &mut b.traffic,
                &mut b.scratch,
            )?;
            let mut merged = 0usize;
            if merged_ok {
                merged = core.merge_survivors.len();
                // Delta-correct stale survivors: a client dispatched at
                // version v trained from baseline b_v, so its absolute
                // update is re-centered onto the current baseline b_V:
                // agg += ŵ·(b_V − b_v).  Fresh survivors (v == V) are
                // untouched — an all-fresh merge is bit-identical to
                // the synchronous arithmetic.
                for (i, &u) in core.merge_survivors.iter().enumerate() {
                    let v = ab.versions.client_version(u);
                    if v == cur {
                        continue;
                    }
                    let w = core.merge_weights[i];
                    let (_, old_base, old_head) = ab
                        .baselines
                        .iter()
                        .find(|(ver, _, _)| *ver == v)
                        .ok_or_else(|| {
                            anyhow::anyhow!("no baseline snapshot for model version {v}")
                        })?;
                    let new_base = core.pool.baseline();
                    let new_head = core.pool.baseline_head();
                    for ti in 0..4 {
                        ops::axpy_into(
                            w,
                            new_base.tensors[ti].as_f32()?,
                            b.scratch.agg_full.tensors[ti].as_f32_mut()?,
                        )?;
                        ops::axpy_into(
                            -w,
                            old_base.tensors[ti].as_f32()?,
                            b.scratch.agg_full.tensors[ti].as_f32_mut()?,
                        )?;
                    }
                    ops::axpy_into(w, new_head.w.as_f32()?, b.scratch.head.w.as_f32_mut()?)?;
                    ops::axpy_into(-w, old_head.w.as_f32()?, b.scratch.head.w.as_f32_mut()?)?;
                    ops::axpy_into(w, new_head.b.as_f32()?, b.scratch.head.b.as_f32_mut()?)?;
                    ops::axpy_into(-w, old_head.b.as_f32()?, b.scratch.head.b.as_f32_mut()?)?;
                }
                // Apply everywhere except in-flight clients, whose
                // trained-but-undelivered state must survive until
                // their own completion merges or discards it.
                ab.protect.copy_from_slice(&ab.inflight);
                core.pool.apply_aggregate_protected(
                    &b.scratch.agg_full,
                    &b.scratch.head,
                    &ab.protect,
                )?;
                ab.versions.advance_model();
                ab.baselines.push((
                    ab.versions.model_version(),
                    core.pool.baseline().clone(),
                    core.pool.baseline_head().clone(),
                ));
                // GC snapshots no in-flight dispatch references.
                let mut min_ref = ab.versions.model_version();
                for (u, &f) in ab.inflight.iter().enumerate() {
                    if f {
                        min_ref = min_ref.min(ab.versions.client_version(u));
                    }
                }
                ab.baselines.retain(|(v, _, _)| *v >= min_ref);
            }
            // Aggregation-phase accounting over the merged cohort, then
            // the cohort re-arrives for its next dispatch.
            if b.timeline.is_active() {
                b.timeline.advance(now);
            }
            let agg_elapsed = core.aggregation_elapsed(env, &ab.parts, &b.timeline);
            for &u in &ab.parts {
                b.engine.schedule(now + agg_elapsed, Event::ClientArrival { client: u });
            }
            let mut loss_sum = 0.0f32;
            for e in ab.buffer.entries() {
                loss_sum += e.loss;
            }
            let mean_loss = loss_sum / buffered.max(1) as f32;
            let participants = ab.parts.clone();
            ab.buffer.clear();
            // Invalidate any armed staleness timer for the old buffer.
            ab.trigger_epoch += 1;
            let stats =
                AsyncStats { buffered, merged, max_staleness, wall_clock: now };
            break (stats, participants, mean_loss, now, agg_elapsed);
        };

        // ---- shared round bookkeeping (mirrors the sync path) ----
        self.book.round = round;
        // Merge r+1 can fire before merge r's aggregation phase ends
        // (training continued during it), so the *reported* clock is
        // clamped monotone.
        self.book.sim_time = self.book.sim_time.max(merge_time + agg_elapsed);
        self.book.rounds.push(RoundRecord {
            round,
            sim_time: self.book.sim_time,
            mean_loss,
        });

        let env_snapshot =
            self.book.timeline.is_active().then(|| self.book.timeline.snapshot());
        let mut eval = None;
        if round % self.env.cfg.train.eval_interval == 0 {
            let (lora, head) = self.scheme.eval_model(&self.env, &mut self.book.scratch)?;
            let (acc, f1, _eval_loss) = self.env.evaluate(lora, head)?;
            self.book.acc.push(round, self.book.sim_time, acc);
            self.book.f1.push(round, self.book.sim_time, f1);
            self.book.final_acc = acc;
            self.book.final_f1 = f1;
            let converged = self.book.detector.update(round, self.book.sim_time, acc);
            self.book.converged = converged;
            eval = Some(EvalPoint { acc, f1, converged });
        }

        let report = RoundReport {
            scheme: self.env.cfg.scheme,
            scheduler: self.scheme.scheduler(),
            round,
            sim_time: self.book.sim_time,
            step_time: (self.book.sim_time - sim_before) / steps as f64,
            mean_loss,
            participants,
            env: env_snapshot,
            pool: self.scheme.pool_stats(),
            robust: self.scheme.robust_stats(),
            asynchrony: Some(stats),
            transport: self.scheme.transport_stats(),
            net: self.scheme.net_stats(),
            eval,
        };
        for obs in &mut self.observers {
            obs.on_round(&report);
        }
        Ok(report)
    }

    /// Step rounds until [`Session::done`], then assemble the
    /// [`RunResult`] and notify observers' `on_complete`.
    pub fn run_to_convergence(&mut self) -> Result<RunResult> {
        while !self.done() {
            self.step_round()?;
        }
        let result = self.result();
        for obs in &mut self.observers {
            obs.on_complete(&result);
        }
        Ok(result)
    }

    /// Assemble the run record from the current state (valid at any
    /// round boundary — a partially-run session reports what it has).
    pub fn result(&self) -> RunResult {
        let mem = self.scheme.memory(&self.env);
        RunResult {
            scheme: self.env.cfg.scheme,
            scheduler: self.scheme.scheduler(),
            rounds: self.book.rounds.clone(),
            acc: self.book.acc.clone(),
            f1: self.book.f1.clone(),
            convergence_round: self.book.detector.converged().map(|(r, _)| r),
            convergence_time: self.book.detector.converged().map(|(_, t)| t),
            final_acc: self.book.final_acc,
            final_f1: self.book.final_f1,
            memory_mb: mem.total_mb(),
            memory: mem,
            adapter_switches: self.scheme.adapter_switches(),
            executions: self.book.execs_prior + self.env.engine.exec_count() - self.book.exec_base,
            uplink_bytes: self.book.traffic.uplink_bytes,
            downlink_bytes: self.book.traffic.downlink_bytes,
            wall_secs: self.book.wall_prior + self.book.wall.elapsed().as_secs_f64(),
        }
    }

    /// Persist the full session (SFLP format, one file) so that
    /// [`Session::resume`] replays the remaining rounds bit-identically
    /// to a run that was never interrupted.
    pub fn checkpoint(&self, path: &Path) -> Result<()> {
        let b = &self.book;
        let mut named: Vec<(String, HostTensor)> = vec![
            (
                "meta.kind".into(),
                HostTensor::i32("meta.kind", vec![1], vec![scheme_tag(self.env.cfg.scheme)]),
            ),
            (
                "meta.clients".into(),
                HostTensor::i32("meta.clients", vec![1], vec![self.env.cuts.len() as i32]),
            ),
            (
                "meta.train".into(),
                encode_u64s(
                    "train",
                    &train_fingerprint(&self.env.cfg)
                        .iter()
                        .map(|(_, v)| *v)
                        .collect::<Vec<_>>(),
                ),
            ),
            ("book.round".into(), encode_u64s("round", &[b.round as u64])),
            ("book.sim_time".into(), encode_f64s("sim_time", &[b.sim_time])),
            ("book.final".into(), encode_f64s("final", &[b.final_acc, b.final_f1])),
            (
                "book.traffic".into(),
                encode_u64s(
                    "traffic",
                    &[b.traffic.uplink_bytes, b.traffic.downlink_bytes, b.traffic.messages],
                ),
            ),
            (
                "book.execs".into(),
                encode_u64s(
                    "execs",
                    &[b.execs_prior + self.env.engine.exec_count() - b.exec_base],
                ),
            ),
            (
                "book.wall".into(),
                encode_f64s("wall", &[b.wall_prior + b.wall.elapsed().as_secs_f64()]),
            ),
            ("book.dropout_rng".into(), encode_u64s("dropout_rng", &[b.dropout_rng.state()])),
        ];
        // Online timing estimator (EWMAs + sample counts, bit-exact).
        let (est_values, est_samples) = b.estimator.state();
        named.push(("book.est.values".into(), encode_f64s("est.values", &est_values)));
        named.push(("book.est.samples".into(), encode_u64s("est.samples", &est_samples)));
        // Adaptive-α residual-variance EWMAs ride only when the mode is
        // on (and fingerprinted) — fixed-α checkpoints are unchanged.
        if b.estimator.is_adaptive() {
            named.push((
                "book.est.resid".into(),
                encode_f64s("est.resid", &b.estimator.adaptive_state()),
            ));
        }
        // Environment timeline: per-generator mutable state (RNG bits,
        // current values, last sample times) + the measurement-noise
        // RNG + the replay-file content hash (resume verification).
        named.push(("book.timeline".into(), encode_u64s("timeline", &b.timeline.state())));
        named.push(("book.obs_noise".into(), encode_u64s("obs_noise", &[b.obs_noise.state()])));
        named.push((
            "book.trace_hash".into(),
            encode_u64s("trace_hash", &[b.timeline.replay_hash()]),
        ));
        // Fault-injection state rides only when an attack is configured:
        // the injector RNG plus each Stale attacker's replay memory
        // (previous round's honest halves), so a resumed attacked run
        // replays the identical faulty submissions bit-exactly.
        if let Some(inj) = &b.faults {
            named.push(("book.fault_rng".into(), encode_u64s("fault_rng", &[inj.rng_state()])));
            let mask: Vec<u64> = inj.prev.iter().map(|p| p.is_some() as u64).collect();
            named.push(("book.stale.mask".into(), encode_u64s("stale.mask", &mask)));
            for (u, p) in inj.prev.iter().enumerate() {
                if let Some((c, s)) = p {
                    save_adapters(&mut named, &format!("book.stale{u}.c"), c);
                    save_adapters(&mut named, &format!("book.stale{u}.s"), s);
                }
            }
        }
        // Round records + metric series (f64 clocks stored bit-exactly).
        let rr: Vec<i32> = b.rounds.iter().map(|r| r.round as i32).collect();
        let rt: Vec<f64> = b.rounds.iter().map(|r| r.sim_time).collect();
        let rl: Vec<f32> = b.rounds.iter().map(|r| r.mean_loss).collect();
        let nr = rr.len();
        named.push((
            "book.rounds.round".into(),
            HostTensor::i32("book.rounds.round", vec![nr], rr),
        ));
        named.push(("book.rounds.time".into(), encode_f64s("rounds.time", &rt)));
        named.push((
            "book.rounds.loss".into(),
            HostTensor::f32("book.rounds.loss", vec![nr], rl),
        ));
        for (tag, series) in [("acc", &b.acc), ("f1", &b.f1)] {
            let sr: Vec<i32> = series.points.iter().map(|p| p.round as i32).collect();
            let st: Vec<f64> = series.points.iter().map(|p| p.sim_time).collect();
            let sv: Vec<f64> = series.points.iter().map(|p| p.value).collect();
            let ns = sr.len();
            named.push((
                format!("book.{tag}.round"),
                HostTensor::i32(format!("book.{tag}.round"), vec![ns], sr),
            ));
            named.push((format!("book.{tag}.time"), encode_f64s("time", &st)));
            named.push((format!("book.{tag}.value"), encode_f64s("value", &sv)));
        }
        // Convergence detector: best/stale plus the sticky fire point.
        let (best, stale, conv) = b.detector.state();
        named.push(("book.detector.best".into(), encode_f64s("best", &[best])));
        named.push(("book.detector.stale".into(), encode_u64s("stale", &[stale as u64])));
        let conv_words: Vec<u64> = match conv {
            Some((r, t)) => vec![r as u64, t.to_bits()],
            None => Vec::new(),
        };
        named.push(("book.detector.conv".into(), encode_u64s("conv", &conv_words)));
        // Async engine state rides only under `--async` (fingerprinted):
        // the full event queue, version vector, buffer metadata,
        // in-flight markers, pending losses, and every live baseline
        // snapshot — enough to resume mid-buffer bit-identically.
        if let Some(ab) = &b.asyncx {
            named.push(("book.events.engine".into(), encode_u64s("events.engine", &b.engine.state())));
            named.push((
                "book.events.versions".into(),
                encode_u64s("events.versions", &ab.versions.state()),
            ));
            named.push((
                "book.events.buffer".into(),
                encode_u64s("events.buffer", &ab.buffer.state()),
            ));
            let inflight: Vec<u64> = ab.inflight.iter().map(|&f| f as u64).collect();
            named.push(("book.events.inflight".into(), encode_u64s("events.inflight", &inflight)));
            named.push((
                "book.events.pending_loss".into(),
                HostTensor::f32(
                    "book.events.pending_loss",
                    vec![ab.pending_loss.len()],
                    ab.pending_loss.clone(),
                ),
            ));
            named.push((
                "book.events.trigger".into(),
                encode_u64s("events.trigger", &[ab.trigger_epoch]),
            ));
            let base_versions: Vec<u64> = ab.baselines.iter().map(|(v, _, _)| *v).collect();
            named.push((
                "book.events.base.versions".into(),
                encode_u64s("events.base.versions", &base_versions),
            ));
            for (v, base, head) in &ab.baselines {
                save_adapters(&mut named, &format!("book.events.base{v}.lora"), base);
                named.push((format!("book.events.base{v}.head.w"), head.w.clone()));
                named.push((format!("book.events.base{v}.head.b"), head.b.clone()));
            }
        }

        self.scheme.save_state(&mut named)?;
        let borrowed: Vec<(&str, &HostTensor)> =
            named.iter().map(|(n, t)| (n.as_str(), t)).collect();
        write_sflp(path, &borrowed)
    }

    /// Rebuild a session from a [`Session::checkpoint`] file.  `cfg`
    /// must describe the same experiment the checkpoint was taken from
    /// (scheme and fleet size are verified).
    pub fn resume(engine: &'e Engine, cfg: &ExperimentConfig, path: &Path) -> Result<Self> {
        let mut session = Session::new(engine, cfg)?;
        let store = ParamStore::load(path)?;
        let kind = one_i32(&store, "meta.kind")?;
        if kind != scheme_tag(cfg.scheme) {
            bail!(
                "checkpoint was taken under a different scheme (tag {kind}, config {:?})",
                cfg.scheme
            );
        }
        let n_clients = one_i32(&store, "meta.clients")? as usize;
        if n_clients != session.env.cuts.len() {
            bail!(
                "checkpoint has {n_clients} clients, config has {}",
                session.env.cuts.len()
            );
        }
        // Every fingerprinted knob must match, or the restored iterator /
        // RNG streams would replay against different data or policies.
        let fp = train_fingerprint(cfg);
        let saved = u64s_exact(&store, "meta.train", fp.len())?;
        for ((name, now), then) in fp.iter().zip(saved.iter()) {
            if now != then {
                bail!("checkpoint was taken under a different `{name}` — refusing to resume");
            }
        }

        let b = &mut session.book;
        b.round = one_u64(&store, "book.round")? as usize;
        b.sim_time = one_f64(&store, "book.sim_time")?;
        let finals = f64s_exact(&store, "book.final", 2)?;
        b.final_acc = finals[0];
        b.final_f1 = finals[1];
        let traffic = u64s_exact(&store, "book.traffic", 3)?;
        b.traffic.uplink_bytes = traffic[0];
        b.traffic.downlink_bytes = traffic[1];
        b.traffic.messages = traffic[2];
        b.execs_prior = one_u64(&store, "book.execs")?;
        b.exec_base = engine.exec_count();
        b.wall_prior = one_f64(&store, "book.wall")?;
        // sflint:allow(determinism, wall-clock telemetry only; never feeds the sim)
        b.wall = std::time::Instant::now();
        b.dropout_rng = Rng::from_state(one_u64(&store, "book.dropout_rng")?);
        let est_values = decode_f64s(store.get("book.est.values")?)?;
        let est_samples = decode_u64s(store.get("book.est.samples")?)?;
        b.estimator.restore_state(&est_values, &est_samples)?;
        if b.estimator.is_adaptive() {
            b.estimator.restore_adaptive_state(&decode_f64s(store.get("book.est.resid")?)?)?;
        }
        // Environment timeline: `Session::new` above re-synthesized the
        // generators from the spec (erroring if a replay trace file is
        // missing); restore their mutable state and verify the replay
        // content hash so a changed trace file fails loudly instead of
        // silently desyncing the remaining trajectory.
        let timeline_words = decode_u64s(store.get("book.timeline")?)?;
        b.timeline.restore_state(&timeline_words)?;
        b.obs_noise.restore_state(one_u64(&store, "book.obs_noise")?);
        let saved_hash = one_u64(&store, "book.trace_hash")?;
        if saved_hash != b.timeline.replay_hash() {
            bail!(
                "checkpoint was taken against a different replay trace file \
                 (content hash {saved_hash:#x} vs {:#x}) — refusing to resume",
                b.timeline.replay_hash()
            );
        }
        // Fault-injection state (the fingerprint guarantees the keys are
        // present exactly when an attack is configured).
        if let Some(inj) = &mut b.faults {
            inj.set_rng_state(one_u64(&store, "book.fault_rng")?);
            let mask = decode_u64s(store.get("book.stale.mask")?)?;
            if mask.len() != inj.prev.len() {
                bail!(
                    "checkpoint stale mask has {} clients, config has {}",
                    mask.len(),
                    inj.prev.len()
                );
            }
            let layers = session.env.dims_exec.layers;
            for (u, &m) in mask.iter().enumerate() {
                if m == 0 {
                    continue;
                }
                let k = session.env.cuts[u];
                let mut c = AdapterSet::zeros(&session.env.dims_exec, k);
                let mut s = AdapterSet::zeros(&session.env.dims_exec, layers - k);
                load_adapters(&store, &format!("book.stale{u}.c"), &mut c)?;
                load_adapters(&store, &format!("book.stale{u}.s"), &mut s)?;
                inj.prev[u] = Some((c, s));
            }
        }

        let rr = store.get("book.rounds.round")?.as_i32()?.to_vec();
        let rt = decode_f64s(store.get("book.rounds.time")?)?;
        let rl = store.get("book.rounds.loss")?.as_f32()?.to_vec();
        if rr.len() != rt.len() || rr.len() != rl.len() {
            bail!("checkpoint round records are inconsistent");
        }
        b.rounds = rr
            .iter()
            .zip(rt.iter())
            .zip(rl.iter())
            .map(|((&r, &t), &l)| RoundRecord { round: r as usize, sim_time: t, mean_loss: l })
            .collect();
        for (tag, series) in [("acc", &mut b.acc), ("f1", &mut b.f1)] {
            let sr = store.get(&format!("book.{tag}.round"))?.as_i32()?.to_vec();
            let st = decode_f64s(store.get(&format!("book.{tag}.time"))?)?;
            let sv = decode_f64s(store.get(&format!("book.{tag}.value"))?)?;
            if sr.len() != st.len() || sr.len() != sv.len() {
                bail!("checkpoint {tag} series is inconsistent");
            }
            series.points.clear();
            for ((&r, &t), &v) in sr.iter().zip(st.iter()).zip(sv.iter()) {
                series.push(r as usize, t, v);
            }
        }
        let best = one_f64(&store, "book.detector.best")?;
        let stale = one_u64(&store, "book.detector.stale")? as usize;
        let conv_words = decode_u64s(store.get("book.detector.conv")?)?;
        let conv = if conv_words.len() == 2 {
            Some((conv_words[0] as usize, f64::from_bits(conv_words[1])))
        } else {
            None
        };
        b.detector.restore_state(best, stale, conv);
        b.converged = conv.is_some();

        // Async engine state (the fingerprint guarantees these keys are
        // present exactly when `--async` is configured).
        if let Some(ab) = &mut b.asyncx {
            b.engine.restore_state(&decode_u64s(store.get("book.events.engine")?)?)?;
            ab.versions.restore_state(&decode_u64s(store.get("book.events.versions")?)?)?;
            ab.buffer.restore_state(&decode_u64s(store.get("book.events.buffer")?)?)?;
            let inflight = decode_u64s(store.get("book.events.inflight")?)?;
            if inflight.len() != ab.inflight.len() {
                bail!(
                    "checkpoint in-flight mask has {} clients, config has {}",
                    inflight.len(),
                    ab.inflight.len()
                );
            }
            for (f, &w) in ab.inflight.iter_mut().zip(inflight.iter()) {
                *f = w != 0;
            }
            let pl = store.get("book.events.pending_loss")?.as_f32()?;
            if pl.len() != ab.pending_loss.len() {
                bail!(
                    "checkpoint pending losses cover {} clients, config has {}",
                    pl.len(),
                    ab.pending_loss.len()
                );
            }
            ab.pending_loss.copy_from_slice(pl);
            ab.trigger_epoch = one_u64(&store, "book.events.trigger")?;
            let base_versions = decode_u64s(store.get("book.events.base.versions")?)?;
            let head0 = engine.initial_head()?;
            let layers = session.env.dims_exec.layers;
            ab.baselines.clear();
            for &v in &base_versions {
                let mut base = AdapterSet::zeros(&session.env.dims_exec, layers);
                load_adapters(&store, &format!("book.events.base{v}.lora"), &mut base)?;
                let mut head = HeadState { w: head0.w.clone(), b: head0.b.clone() };
                load_tensor_into(&store, &format!("book.events.base{v}.head.w"), &mut head.w)?;
                load_tensor_into(&store, &format!("book.events.base{v}.head.b"), &mut head.b)?;
                ab.baselines.push((v, base, head));
            }
        }

        session.scheme.load_state(&session.env, &store)?;
        Ok(session)
    }
}
