//! L3 coordinator — the paper's system contribution.
//!
//! Orchestrates the three schemes end-to-end over the PJRT runtime:
//!
//! - **Ours** (Alg. 1): parallel client forwards → sequential server
//!   LoRA training with adapter switching, ordered by a pluggable
//!   scheduler (Alg. 2 / FIFO / WF / Random) → parallel client
//!   backwards; periodic LoRA aggregation (eqs. 5–9).
//! - **SL**: one client at a time, model relayed between clients.
//! - **SFL**: per-client server submodels trained in parallel
//!   (numerically identical to Ours — the difference is timing + memory,
//!   which is exactly the paper's point).
//!
//! Numeric training executes the real AOT artifacts; protocol *timing*
//! runs on the virtual clock with the paper-scale dims (DESIGN.md §2).

pub mod lr;
pub mod scheduler;
pub mod timing;

use crate::config::{ExperimentConfig, SchemeKind};
use crate::data::{self, BatchIter, Dataset};
use crate::lora::{fedavg, AdapterSet};
use crate::metrics::{Confusion, ConvergenceDetector, MetricSeries};
use crate::model::{memory, ModelDims};
use crate::net::{Message, TrafficMeter};
use crate::runtime::{ClientState, Engine, HeadState, ServerState};
use crate::tensor::{ops, rng::Rng};
use anyhow::Result;
use scheduler::make_scheduler;

/// One round's training record.
#[derive(Debug, Clone, Copy)]
pub struct RoundRecord {
    pub round: usize,
    pub sim_time: f64,
    pub mean_loss: f32,
}

/// Everything one experiment run produces (the raw material for Table I
/// and Fig. 2).
#[derive(Debug)]
pub struct RunResult {
    pub scheme: SchemeKind,
    pub scheduler: String,
    pub rounds: Vec<RoundRecord>,
    pub acc: MetricSeries,
    pub f1: MetricSeries,
    pub convergence_round: Option<usize>,
    pub convergence_time: Option<f64>,
    pub final_acc: f64,
    pub final_f1: f64,
    pub memory_mb: f64,
    pub memory: memory::MemoryBreakdown,
    pub adapter_switches: u64,
    pub executions: u64,
    pub uplink_bytes: u64,
    pub downlink_bytes: u64,
    pub wall_secs: f64,
}

impl RunResult {
    /// Total simulated fine-tuning time (Table I "Convergence Time" when
    /// converged, else the time at the last round).
    pub fn total_time(&self) -> f64 {
        self.convergence_time
            .unwrap_or_else(|| self.rounds.last().map(|r| r.sim_time).unwrap_or(0.0))
    }
}

/// The experiment driver. Holds per-client data iterators and training
/// state; `run()` executes one scheme to convergence.
pub struct Trainer<'e> {
    engine: &'e Engine,
    cfg: ExperimentConfig,
    dims_exec: ModelDims,
    dims_time: ModelDims,
    cuts: Vec<usize>,
    ds: Dataset,
    shards: Vec<Vec<usize>>,
    weights: Vec<f32>,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: &ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let dims_exec = engine.dims().clone();
        let dims_time = cfg.timing_dims();
        let cuts = cfg.resolve_cuts();
        let spec = data::CorpusSpec {
            seed: cfg.train.seed,
            ..data::CorpusSpec::carer_like(dims_exec.vocab, dims_exec.seq)
        };
        let ds = data::generate(&spec);
        let shards = data::dirichlet_partition(
            &ds.train,
            cfg.clients.len(),
            cfg.train.dirichlet_alpha,
            cfg.train.seed + 1,
            dims_exec.batch,
        );
        let total: usize = shards.iter().map(|s| s.len()).sum();
        let weights: Vec<f32> =
            shards.iter().map(|s| s.len() as f32 / total as f32).collect();
        Ok(Self { engine, cfg: cfg.clone(), dims_exec, dims_time, cuts, ds, shards, weights })
    }

    pub fn cuts(&self) -> &[usize] {
        &self.cuts
    }

    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }

    fn fresh_states(&self) -> Result<(Vec<ClientState>, Vec<ServerState>)> {
        let full = self.engine.initial_lora()?;
        let head = self.engine.initial_head()?;
        let mut clients = Vec::new();
        let mut servers = Vec::new();
        for &k in &self.cuts {
            let (c, s) = full.split_at(k)?;
            clients.push(ClientState::fresh(c));
            servers.push(ServerState::fresh(s, head.clone()));
        }
        Ok((clients, servers))
    }

    /// Data-weighted global model (eqs. 5–8 evaluated without replacing
    /// per-client state): the model whose accuracy/F1 we track.
    fn global_model(
        &self,
        clients: &[ClientState],
        servers: &[ServerState],
    ) -> Result<(AdapterSet, HeadState)> {
        let fulls: Vec<AdapterSet> = clients
            .iter()
            .zip(servers.iter())
            .map(|(c, s)| AdapterSet::join(&c.lora, &s.lora))
            .collect::<Result<Vec<_>>>()?;
        let pairs: Vec<(f32, &AdapterSet)> =
            self.weights.iter().copied().zip(fulls.iter()).collect();
        let agg = fedavg(&pairs)?;
        let head_w = ops::weighted_sum(
            &self
                .weights
                .iter()
                .copied()
                .zip(servers.iter().map(|s| &s.head.w))
                .collect::<Vec<_>>(),
        )?;
        let head_b = ops::weighted_sum(
            &self
                .weights
                .iter()
                .copied()
                .zip(servers.iter().map(|s| &s.head.b))
                .collect::<Vec<_>>(),
        )?;
        Ok((agg, HeadState { w: head_w, b: head_b }))
    }

    /// Evaluate a model on (up to `eval_batches` of) the test split.
    pub fn evaluate(&self, lora: &AdapterSet, head: &HeadState) -> Result<(f64, f64, f32)> {
        let b = self.dims_exec.batch;
        let n_batches = (self.ds.test.len() / b).min(self.cfg.train.eval_batches);
        let mut conf = Confusion::new(self.dims_exec.classes);
        let mut loss_sum = 0.0f32;
        for i in 0..n_batches {
            let idx: Vec<usize> = (i * b..(i + 1) * b).collect();
            let mut tokens = Vec::with_capacity(b * self.dims_exec.seq);
            let mut labels = Vec::with_capacity(b);
            for &j in &idx {
                tokens.extend_from_slice(&self.ds.test[j].tokens);
                labels.push(self.ds.test[j].label);
            }
            let (logits, loss) = self.engine.eval(&tokens, &labels, lora, head)?;
            conf.record_logits(&logits, &labels);
            loss_sum += loss;
        }
        Ok((conf.accuracy(), conf.macro_f1(), loss_sum / n_batches.max(1) as f32))
    }

    /// The FedAvg aggregation phase (paper Alg. 1 lines 17–30): join,
    /// aggregate A and B separately, re-split at each client's cut.
    /// Only `participants` contribute weight (failure injection); the
    /// aggregate is still distributed to every client.
    fn aggregate(
        &self,
        clients: &mut [ClientState],
        servers: &mut [ServerState],
        participants: &[usize],
        traffic: &mut TrafficMeter,
    ) -> Result<()> {
        let total: f32 = participants.iter().map(|&u| self.weights[u]).sum();
        let fulls: Vec<AdapterSet> = participants
            .iter()
            .map(|&u| AdapterSet::join(&clients[u].lora, &servers[u].lora))
            .collect::<Result<Vec<_>>>()?;
        let pairs: Vec<(f32, &AdapterSet)> = participants
            .iter()
            .zip(fulls.iter())
            .map(|(&u, f)| (self.weights[u] / total, f))
            .collect();
        let agg = fedavg(&pairs)?;
        let head_pairs_w: Vec<(f32, &crate::tensor::HostTensor)> = participants
            .iter()
            .map(|&u| (self.weights[u] / total, &servers[u].head.w))
            .collect();
        let head_pairs_b: Vec<(f32, &crate::tensor::HostTensor)> = participants
            .iter()
            .map(|&u| (self.weights[u] / total, &servers[u].head.b))
            .collect();
        let head = HeadState {
            w: ops::weighted_sum(&head_pairs_w)?,
            b: ops::weighted_sum(&head_pairs_b)?,
        };
        for (u, &k) in self.cuts.iter().enumerate() {
            if participants.contains(&u) {
                traffic.record(&Message::LoraUpload { bytes: self.dims_time.lora_bytes(k) });
            }
            let (c, s) = agg.split_at(k)?;
            clients[u].lora = c;
            servers[u].lora = s;
            servers[u].head = head.clone();
            traffic.record(&Message::LoraDownload { bytes: self.dims_time.lora_bytes(k) });
        }
        Ok(())
    }

    /// Run the configured scheme to convergence. `quiet` suppresses the
    /// per-round progress lines.
    pub fn run(&self, quiet: bool) -> Result<RunResult> {
        match self.cfg.scheme {
            SchemeKind::Ours | SchemeKind::Sfl => self.run_parallel(quiet),
            SchemeKind::Sl => self.run_sl(quiet),
        }
    }

    /// Ours and SFL share numerics (per-client independent split training
    /// + periodic aggregation); they differ in timing and memory.
    fn run_parallel(&self, quiet: bool) -> Result<RunResult> {
        let wall = std::time::Instant::now();
        let t = &self.cfg.train;
        let (mut clients, mut servers) = self.fresh_states()?;
        let mut iters: Vec<BatchIter> = self
            .shards
            .iter()
            .enumerate()
            .map(|(u, s)| BatchIter::new(s, self.dims_exec.batch, t.seed + 100 + u as u64))
            .collect();
        let mut sched = make_scheduler(self.cfg.scheduler, t.seed);
        let mut detector = ConvergenceDetector::new(t.patience, t.min_delta);
        let mut traffic = TrafficMeter::default();
        let mut switches = 0u64;
        let mut last_active: Option<usize> = None;
        let mut sim_time = 0.0f64;
        let mut rounds = Vec::new();
        let mut acc_series = MetricSeries::default();
        let mut f1_series = MetricSeries::default();
        let (mut final_acc, mut final_f1) = (0.0, 0.0);

        let exec0 = self.engine.exec_count.get();
        let mut dropout_rng = Rng::new(t.seed ^ 0xD809);
        for round in 1..=t.max_rounds {
            let round_lr = t.lr_schedule.at(t.lr, round);
            // ---- failure injection: which clients participate? ----
            let participants: Vec<usize> = if t.dropout_prob > 0.0 {
                let mut p: Vec<usize> = (0..self.cuts.len())
                    .filter(|_| dropout_rng.uniform() >= t.dropout_prob)
                    .collect();
                if p.is_empty() {
                    // Never stall a round entirely: keep one survivor.
                    p.push(dropout_rng.below(self.cuts.len()));
                }
                p
            } else {
                (0..self.cuts.len()).collect()
            };
            let part_clients: Vec<crate::config::ClientConfig> =
                participants.iter().map(|&u| self.cfg.clients[u].clone()).collect();
            let part_cuts: Vec<usize> = participants.iter().map(|&u| self.cuts[u]).collect();

            // ---- timing for this round (virtual clock, paper dims) ----
            let step_time = match self.cfg.scheme {
                SchemeKind::Ours => {
                    let (st, _) = timing::ours_step(
                        &self.dims_time,
                        &part_clients,
                        &part_cuts,
                        &self.cfg.server,
                        sched.as_mut(),
                    );
                    st
                }
                SchemeKind::Sfl => {
                    let (st, _) =
                        timing::sfl_step(&self.dims_time, &part_clients, &part_cuts, &self.cfg.server);
                    st
                }
                SchemeKind::Sl => unreachable!(),
            };
            sim_time += t.steps_per_round as f64 * step_time;

            // ---- numeric training: steps_per_round per participant ----
            let mut loss_sum = 0.0f32;
            let mut loss_n = 0u32;
            for _ in 0..t.steps_per_round {
                // Server processing order (adapter switching bookkeeping).
                let jobs =
                    timing::build_jobs(&self.dims_time, &part_clients, &part_cuts, &self.cfg.server);
                let order: Vec<usize> =
                    sched.order(&jobs).into_iter().map(|i| participants[i]).collect();
                for &u in &order {
                    let k = self.cuts[u];
                    let idx = iters[u].next_batch().to_vec();
                    let (tokens, labels) = data::materialize_batch(&self.ds, &idx);
                    let acts = self.engine.client_fwd(k, &tokens, &clients[u].lora)?;
                    traffic.record(&Message::Activations {
                        bytes: self.dims_time.activation_bytes(),
                    });
                    if last_active != Some(u) {
                        switches += 1;
                        last_active = Some(u);
                    }
                    let out =
                        self.engine.server_step(k, &acts, &labels, &servers[u], round_lr)?;
                    servers[u] = out.state;
                    traffic.record(&Message::ActivationGrads {
                        bytes: self.dims_time.activation_bytes(),
                    });
                    clients[u] = self
                        .engine
                        .client_bwd(k, &tokens, &clients[u], &out.act_grads, round_lr)?;
                    loss_sum += out.loss;
                    loss_n += 1;
                }
            }
            let mean_loss = loss_sum / loss_n.max(1) as f32;
            rounds.push(RoundRecord { round, sim_time, mean_loss });

            // ---- aggregation every I rounds (paper line 17) ----
            if round % t.aggregation_interval == 0 {
                sim_time +=
                    timing::aggregation_time(&self.dims_time, &part_clients, &part_cuts);
                self.aggregate(&mut clients, &mut servers, &participants, &mut traffic)?;
            }

            // ---- evaluation + convergence ----
            if round % t.eval_interval == 0 {
                let (lora, head) = self.global_model(&clients, &servers)?;
                let (acc, f1, _eval_loss) = self.evaluate(&lora, &head)?;
                acc_series.push(round, sim_time, acc);
                f1_series.push(round, sim_time, f1);
                final_acc = acc;
                final_f1 = f1;
                if !quiet {
                    println!(
                        "[{:?}/{}] round {round:4}  t={sim_time:9.1}s  loss={mean_loss:.4}  acc={acc:.4}  f1={f1:.4}",
                        self.cfg.scheme,
                        sched.name()
                    );
                }
                if detector.update(round, sim_time, acc) {
                    break;
                }
            }
        }

        let mem = match self.cfg.scheme {
            SchemeKind::Sfl => memory::sfl_server_memory(&self.dims_time, &self.cuts),
            _ => memory::ours_server_memory(&self.dims_time, &self.cuts),
        };
        Ok(RunResult {
            scheme: self.cfg.scheme,
            scheduler: sched.name().to_string(),
            rounds,
            acc: acc_series,
            f1: f1_series,
            convergence_round: detector.converged().map(|(r, _)| r),
            convergence_time: detector.converged().map(|(_, t)| t),
            final_acc,
            final_f1,
            memory_mb: mem.total_mb(),
            memory: mem,
            adapter_switches: switches,
            executions: self.engine.exec_count.get() - exec0,
            uplink_bytes: traffic.uplink_bytes,
            downlink_bytes: traffic.downlink_bytes,
            wall_secs: wall.elapsed().as_secs_f64(),
        })
    }

    /// Sequential split learning: one global adapter set relayed through
    /// the clients; no aggregation (baseline [18]).
    fn run_sl(&self, quiet: bool) -> Result<RunResult> {
        let wall = std::time::Instant::now();
        let t = &self.cfg.train;
        let mut full = self.engine.initial_lora()?;
        let mut head = self.engine.initial_head()?;
        let mut iters: Vec<BatchIter> = self
            .shards
            .iter()
            .enumerate()
            .map(|(u, s)| BatchIter::new(s, self.dims_exec.batch, t.seed + 100 + u as u64))
            .collect();
        let mut detector = ConvergenceDetector::new(t.patience, t.min_delta);
        let mut traffic = TrafficMeter::default();
        let mut sim_time = 0.0f64;
        let mut rounds = Vec::new();
        let mut acc_series = MetricSeries::default();
        let mut f1_series = MetricSeries::default();
        let (mut final_acc, mut final_f1) = (0.0, 0.0);
        let exec0 = self.engine.exec_count.get();

        for round in 1..=t.max_rounds {
            let round_lr = t.lr_schedule.at(t.lr, round);
            sim_time += timing::sl_round(
                &self.dims_time,
                &self.cfg.clients,
                &self.cuts,
                &self.cfg.server,
                t.steps_per_round,
            );
            let mut loss_sum = 0.0f32;
            let mut loss_n = 0u32;
            for (u, &k) in self.cuts.iter().enumerate() {
                // Client u receives the current global model (relay).
                let (clora, slora) = full.split_at(k)?;
                let mut cstate = ClientState::fresh(clora);
                let mut sstate = ServerState::fresh(slora, head.clone());
                for _ in 0..t.steps_per_round {
                    let idx = iters[u].next_batch().to_vec();
                    let (tokens, labels) = data::materialize_batch(&self.ds, &idx);
                    let acts = self.engine.client_fwd(k, &tokens, &cstate.lora)?;
                    traffic.record(&Message::Activations {
                        bytes: self.dims_time.activation_bytes(),
                    });
                    let out = self.engine.server_step(k, &acts, &labels, &sstate, round_lr)?;
                    sstate = out.state;
                    traffic.record(&Message::ActivationGrads {
                        bytes: self.dims_time.activation_bytes(),
                    });
                    cstate =
                        self.engine.client_bwd(k, &tokens, &cstate, &out.act_grads, round_lr)?;
                    loss_sum += out.loss;
                    loss_n += 1;
                }
                full = AdapterSet::join(&cstate.lora, &sstate.lora)?;
                head = sstate.head;
            }
            let mean_loss = loss_sum / loss_n.max(1) as f32;
            rounds.push(RoundRecord { round, sim_time, mean_loss });

            if round % t.eval_interval == 0 {
                let (acc, f1, _) = self.evaluate(&full, &head)?;
                acc_series.push(round, sim_time, acc);
                f1_series.push(round, sim_time, f1);
                final_acc = acc;
                final_f1 = f1;
                if !quiet {
                    println!(
                        "[Sl] round {round:4}  t={sim_time:9.1}s  loss={mean_loss:.4}  acc={acc:.4}  f1={f1:.4}"
                    );
                }
                if detector.update(round, sim_time, acc) {
                    break;
                }
            }
        }

        let mem = memory::sl_server_memory(&self.dims_time, &self.cuts);
        Ok(RunResult {
            scheme: SchemeKind::Sl,
            scheduler: "sequential".into(),
            rounds,
            acc: acc_series,
            f1: f1_series,
            convergence_round: detector.converged().map(|(r, _)| r),
            convergence_time: detector.converged().map(|(_, t)| t),
            final_acc,
            final_f1,
            memory_mb: mem.total_mb(),
            memory: mem,
            adapter_switches: 0,
            executions: self.engine.exec_count.get() - exec0,
            uplink_bytes: traffic.uplink_bytes,
            downlink_bytes: traffic.downlink_bytes,
            wall_secs: wall.elapsed().as_secs_f64(),
        })
    }
}
