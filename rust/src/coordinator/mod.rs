//! L3 coordinator — the paper's system contribution.
//!
//! Orchestrates the three schemes end-to-end over the PJRT runtime:
//!
//! - **Ours** (Alg. 1): parallel client forwards → sequential server
//!   LoRA training with adapter switching, ordered by a pluggable
//!   scheduler (Alg. 2 / FIFO / WF / Random) → parallel client
//!   backwards; periodic LoRA aggregation (eqs. 5–9).
//! - **SL**: one client at a time, model relayed between clients.
//! - **SFL**: per-client server submodels trained in parallel
//!   (numerically identical to Ours — the difference is timing + memory,
//!   which is exactly the paper's point).
//!
//! Numeric training executes the real AOT artifacts; protocol *timing*
//! runs on the virtual clock with the paper-scale dims (DESIGN.md §2).

pub mod lr;
pub mod scheduler;
pub mod timing;

use crate::config::{ExperimentConfig, SchemeKind};
use crate::data::{self, BatchIter, Dataset};
use crate::lora::{fedavg_joined_into, AdapterSet};
use crate::metrics::{Confusion, ConvergenceDetector, MetricSeries};
use crate::model::{memory, ModelDims};
use crate::net::{Message, TrafficMeter};
use crate::runtime::{ClientState, Engine, HeadState, ServerState};
use crate::tensor::{ops, rng::Rng, HostTensor};
use anyhow::Result;
use scheduler::make_scheduler;

/// One round's training record.
#[derive(Debug, Clone, Copy)]
pub struct RoundRecord {
    pub round: usize,
    pub sim_time: f64,
    pub mean_loss: f32,
}

/// Everything one experiment run produces (the raw material for Table I
/// and Fig. 2).
#[derive(Debug)]
pub struct RunResult {
    pub scheme: SchemeKind,
    pub scheduler: String,
    pub rounds: Vec<RoundRecord>,
    pub acc: MetricSeries,
    pub f1: MetricSeries,
    pub convergence_round: Option<usize>,
    pub convergence_time: Option<f64>,
    pub final_acc: f64,
    pub final_f1: f64,
    pub memory_mb: f64,
    pub memory: memory::MemoryBreakdown,
    pub adapter_switches: u64,
    pub executions: u64,
    pub uplink_bytes: u64,
    pub downlink_bytes: u64,
    pub wall_secs: f64,
}

impl RunResult {
    /// Total simulated fine-tuning time (Table I "Convergence Time" when
    /// converged, else the time at the last round).
    pub fn total_time(&self) -> f64 {
        self.convergence_time
            .unwrap_or_else(|| self.rounds.last().map(|r| r.sim_time).unwrap_or(0.0))
    }
}

/// Preallocated working buffers for the training loop — the per-round
/// scratch arena.  Allocated once in [`Trainer::new`]; at steady state
/// every round (client forwards, server steps, client backwards,
/// aggregation, evaluation) reuses these buffers and performs zero
/// `HostTensor` allocations (asserted by tests/benches via
/// `tensor::alloc_count`).
#[derive(Debug)]
struct RoundScratch {
    /// Full-depth aggregate target (eqs. 5–7) + aggregated head —
    /// shared by `aggregate` and `global_model_into` (their uses never
    /// overlap).
    agg_full: AdapterSet,
    head: HeadState,
    /// Activations / activation-gradient buffers ([B, L, H]).
    acts: HostTensor,
    act_grads: HostTensor,
    /// Flat batch buffers ([B*L] tokens, [B] labels).
    tokens: Vec<i32>,
    labels: Vec<i32>,
    /// Participant membership mask (reused every aggregation).
    mask: Vec<bool>,
}

impl Default for RoundScratch {
    fn default() -> Self {
        Self {
            agg_full: AdapterSet { layers: 0, tensors: Vec::new() },
            head: HeadState {
                w: HostTensor::zeros("head.w", vec![0]),
                b: HostTensor::zeros("head.b", vec![0]),
            },
            acts: HostTensor::zeros("acts", vec![0]),
            act_grads: HostTensor::zeros("act_grads", vec![0]),
            tokens: Vec::new(),
            labels: Vec::new(),
            mask: Vec::new(),
        }
    }
}

/// The experiment driver. Holds per-client data iterators and training
/// state; `run()` executes one scheme to convergence.
pub struct Trainer<'e> {
    engine: &'e Engine,
    cfg: ExperimentConfig,
    dims_exec: ModelDims,
    dims_time: ModelDims,
    cuts: Vec<usize>,
    ds: Dataset,
    shards: Vec<Vec<usize>>,
    weights: Vec<f32>,
    scratch: RoundScratch,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: &ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let dims_exec = engine.dims().clone();
        let dims_time = cfg.timing_dims();
        let cuts = cfg.resolve_cuts();
        let spec = data::CorpusSpec {
            seed: cfg.train.seed,
            ..data::CorpusSpec::carer_like(dims_exec.vocab, dims_exec.seq)
        };
        let ds = data::generate(&spec);
        let shards = data::dirichlet_partition(
            &ds.train,
            cfg.clients.len(),
            cfg.train.dirichlet_alpha,
            cfg.train.seed + 1,
            dims_exec.batch,
        );
        let total: usize = shards.iter().map(|s| s.len()).sum();
        let weights: Vec<f32> =
            shards.iter().map(|s| s.len() as f32 / total as f32).collect();
        let head0 = engine.initial_head()?;
        let acts_shape = vec![dims_exec.batch, dims_exec.seq, dims_exec.hidden];
        let scratch = RoundScratch {
            agg_full: AdapterSet::zeros(&dims_exec, dims_exec.layers),
            head: HeadState {
                w: HostTensor::zeros(head0.w.name.clone(), head0.w.shape.clone()),
                b: HostTensor::zeros(head0.b.name.clone(), head0.b.shape.clone()),
            },
            acts: HostTensor::zeros("acts", acts_shape.clone()),
            act_grads: HostTensor::zeros("act_grads", acts_shape),
            tokens: Vec::with_capacity(dims_exec.batch * dims_exec.seq),
            labels: Vec::with_capacity(dims_exec.batch),
            mask: vec![false; cuts.len()],
        };
        Ok(Self {
            engine,
            cfg: cfg.clone(),
            dims_exec,
            dims_time,
            cuts,
            ds,
            shards,
            weights,
            scratch,
        })
    }

    pub fn cuts(&self) -> &[usize] {
        &self.cuts
    }

    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }

    fn fresh_states(&self) -> Result<(Vec<ClientState>, Vec<ServerState>)> {
        let full = self.engine.initial_lora()?;
        let head = self.engine.initial_head()?;
        let mut clients = Vec::new();
        let mut servers = Vec::new();
        for &k in &self.cuts {
            let (c, s) = full.split_at(k)?;
            clients.push(ClientState::fresh(c));
            servers.push(ServerState::fresh(s, head.clone()));
        }
        Ok((clients, servers))
    }

    /// Data-weighted global model (eqs. 5–8 evaluated without replacing
    /// per-client state), computed into the scratch arena: the model
    /// whose accuracy/F1 we track.  Fused aggregation — the per-client
    /// joins of eq. (5) are scattered straight into the full-depth
    /// scratch set, so no tensors are allocated.
    fn global_model_into(
        &self,
        clients: &[ClientState],
        servers: &[ServerState],
        scratch: &mut RoundScratch,
    ) -> Result<()> {
        let contribs: Vec<(f32, &AdapterSet, &AdapterSet)> = self
            .weights
            .iter()
            .copied()
            .zip(clients.iter().zip(servers.iter()))
            .map(|(w, (c, s))| (w, &c.lora, &s.lora))
            .collect();
        fedavg_joined_into(&contribs, &mut scratch.agg_full)?;
        ops::weighted_sum_into(
            &self
                .weights
                .iter()
                .copied()
                .zip(servers.iter().map(|s| &s.head.w))
                .collect::<Vec<_>>(),
            &mut scratch.head.w,
        )?;
        ops::weighted_sum_into(
            &self
                .weights
                .iter()
                .copied()
                .zip(servers.iter().map(|s| &s.head.b))
                .collect::<Vec<_>>(),
            &mut scratch.head.b,
        )?;
        Ok(())
    }

    /// Evaluate a model on (up to `eval_batches` of) the test split.
    pub fn evaluate(&self, lora: &AdapterSet, head: &HeadState) -> Result<(f64, f64, f32)> {
        let b = self.dims_exec.batch;
        let n_batches = (self.ds.test.len() / b).min(self.cfg.train.eval_batches);
        let mut conf = Confusion::new(self.dims_exec.classes);
        let mut loss_sum = 0.0f32;
        for i in 0..n_batches {
            let idx: Vec<usize> = (i * b..(i + 1) * b).collect();
            let mut tokens = Vec::with_capacity(b * self.dims_exec.seq);
            let mut labels = Vec::with_capacity(b);
            for &j in &idx {
                tokens.extend_from_slice(&self.ds.test[j].tokens);
                labels.push(self.ds.test[j].label);
            }
            let (logits, loss) = self.engine.eval(&tokens, &labels, lora, head)?;
            conf.record_logits(&logits, &labels);
            loss_sum += loss;
        }
        Ok((conf.accuracy(), conf.macro_f1(), loss_sum / n_batches.max(1) as f32))
    }

    /// The FedAvg aggregation phase (paper Alg. 1 lines 17–30), fused
    /// and in place: each participant's halves are scattered straight
    /// into the full-depth scratch aggregate (A and B separately), then
    /// re-split at each client's cut by copying back into the existing
    /// per-client state buffers — no joins, no intermediate sets.
    /// Only `participants` contribute weight (failure injection); the
    /// aggregate is still distributed to every client.
    fn aggregate(
        &self,
        clients: &mut [ClientState],
        servers: &mut [ServerState],
        participants: &[usize],
        traffic: &mut TrafficMeter,
        scratch: &mut RoundScratch,
    ) -> Result<()> {
        let total: f32 = participants.iter().map(|&u| self.weights[u]).sum();
        let contribs: Vec<(f32, &AdapterSet, &AdapterSet)> = participants
            .iter()
            .map(|&u| (self.weights[u] / total, &clients[u].lora, &servers[u].lora))
            .collect();
        fedavg_joined_into(&contribs, &mut scratch.agg_full)?;
        let head_pairs_w: Vec<(f32, &HostTensor)> = participants
            .iter()
            .map(|&u| (self.weights[u] / total, &servers[u].head.w))
            .collect();
        ops::weighted_sum_into(&head_pairs_w, &mut scratch.head.w)?;
        let head_pairs_b: Vec<(f32, &HostTensor)> = participants
            .iter()
            .map(|&u| (self.weights[u] / total, &servers[u].head.b))
            .collect();
        ops::weighted_sum_into(&head_pairs_b, &mut scratch.head.b)?;
        // O(n) membership mask (was an O(n²) `contains` scan per round).
        scratch.mask.iter_mut().for_each(|m| *m = false);
        for &u in participants {
            scratch.mask[u] = true;
        }
        for (u, &k) in self.cuts.iter().enumerate() {
            if scratch.mask[u] {
                traffic.record(&Message::LoraUpload { bytes: self.dims_time.lora_bytes(k) });
            }
            scratch.agg_full.split_into(k, &mut clients[u].lora, &mut servers[u].lora)?;
            ops::copy_from(&mut servers[u].head.w, &scratch.head.w)?;
            ops::copy_from(&mut servers[u].head.b, &scratch.head.b)?;
            traffic.record(&Message::LoraDownload { bytes: self.dims_time.lora_bytes(k) });
        }
        Ok(())
    }

    /// Run the configured scheme to convergence. `quiet` suppresses the
    /// per-round progress lines.  Takes `&mut self` because the run
    /// reuses the trainer's preallocated scratch arena.
    pub fn run(&mut self, quiet: bool) -> Result<RunResult> {
        // Detach the arena for the duration of the run so the hot loop
        // can borrow it mutably alongside `&self`.
        let mut scratch = std::mem::take(&mut self.scratch);
        let out = match self.cfg.scheme {
            SchemeKind::Ours | SchemeKind::Sfl => self.run_parallel(quiet, &mut scratch),
            SchemeKind::Sl => self.run_sl(quiet),
        };
        self.scratch = scratch;
        out
    }

    /// Ours and SFL share numerics (per-client independent split training
    /// + periodic aggregation); they differ in timing and memory.
    /// Steady state is allocation-free: every buffer the inner loop
    /// touches lives in `scratch` or in the per-client states, updated
    /// in place.
    fn run_parallel(&self, quiet: bool, scratch: &mut RoundScratch) -> Result<RunResult> {
        let wall = std::time::Instant::now();
        let t = &self.cfg.train;
        let (mut clients, mut servers) = self.fresh_states()?;
        let mut iters: Vec<BatchIter> = self
            .shards
            .iter()
            .enumerate()
            .map(|(u, s)| BatchIter::new(s, self.dims_exec.batch, t.seed + 100 + u as u64))
            .collect();
        let mut sched = make_scheduler(self.cfg.scheduler, t.seed);
        let mut detector = ConvergenceDetector::new(t.patience, t.min_delta);
        let mut traffic = TrafficMeter::default();
        let mut switches = 0u64;
        let mut last_active: Option<usize> = None;
        let mut sim_time = 0.0f64;
        let mut rounds = Vec::new();
        let mut acc_series = MetricSeries::default();
        let mut f1_series = MetricSeries::default();
        let (mut final_acc, mut final_f1) = (0.0, 0.0);

        let exec0 = self.engine.exec_count();
        let mut dropout_rng = Rng::new(t.seed ^ 0xD809);
        for round in 1..=t.max_rounds {
            let round_lr = t.lr_schedule.at(t.lr, round);
            // ---- failure injection: which clients participate? ----
            let participants: Vec<usize> = if t.dropout_prob > 0.0 {
                let mut p: Vec<usize> = (0..self.cuts.len())
                    .filter(|_| dropout_rng.uniform() >= t.dropout_prob)
                    .collect();
                if p.is_empty() {
                    // Never stall a round entirely: keep one survivor.
                    p.push(dropout_rng.below(self.cuts.len()));
                }
                p
            } else {
                (0..self.cuts.len()).collect()
            };
            let part_clients: Vec<crate::config::ClientConfig> =
                participants.iter().map(|&u| self.cfg.clients[u].clone()).collect();
            let part_cuts: Vec<usize> = participants.iter().map(|&u| self.cuts[u]).collect();

            // ---- timing for this round (virtual clock, paper dims) ----
            let step_time = match self.cfg.scheme {
                SchemeKind::Ours => {
                    let (st, _) = timing::ours_step(
                        &self.dims_time,
                        &part_clients,
                        &part_cuts,
                        &self.cfg.server,
                        sched.as_mut(),
                    );
                    st
                }
                SchemeKind::Sfl => {
                    let (st, _) =
                        timing::sfl_step(&self.dims_time, &part_clients, &part_cuts, &self.cfg.server);
                    st
                }
                SchemeKind::Sl => unreachable!(),
            };
            sim_time += t.steps_per_round as f64 * step_time;

            // ---- numeric training: steps_per_round per participant ----
            // In-place hot loop: batches materialize into reused
            // buffers, activations/grads land in scratch, and the
            // client/server states update their own tensors.
            let mut loss_sum = 0.0f32;
            let mut loss_n = 0u32;
            for _ in 0..t.steps_per_round {
                // Server processing order (adapter switching bookkeeping).
                let jobs =
                    timing::build_jobs(&self.dims_time, &part_clients, &part_cuts, &self.cfg.server);
                let order: Vec<usize> =
                    sched.order(&jobs).into_iter().map(|i| participants[i]).collect();
                for &u in &order {
                    let k = self.cuts[u];
                    let idx = iters[u].next_batch();
                    data::materialize_batch_into(
                        &self.ds,
                        idx,
                        &mut scratch.tokens,
                        &mut scratch.labels,
                    );
                    self.engine.client_fwd_into(
                        k,
                        &scratch.tokens,
                        &clients[u].lora,
                        &mut scratch.acts,
                    )?;
                    traffic.record(&Message::Activations {
                        bytes: self.dims_time.activation_bytes(),
                    });
                    if last_active != Some(u) {
                        switches += 1;
                        last_active = Some(u);
                    }
                    let loss = self.engine.server_step_into(
                        k,
                        &scratch.acts,
                        &scratch.labels,
                        &mut servers[u],
                        &mut scratch.act_grads,
                        round_lr,
                    )?;
                    traffic.record(&Message::ActivationGrads {
                        bytes: self.dims_time.activation_bytes(),
                    });
                    self.engine.client_bwd_into(
                        k,
                        &scratch.tokens,
                        &mut clients[u],
                        &scratch.act_grads,
                        round_lr,
                    )?;
                    loss_sum += loss;
                    loss_n += 1;
                }
            }
            let mean_loss = loss_sum / loss_n.max(1) as f32;
            rounds.push(RoundRecord { round, sim_time, mean_loss });

            // ---- aggregation every I rounds (paper line 17) ----
            if round % t.aggregation_interval == 0 {
                sim_time +=
                    timing::aggregation_time(&self.dims_time, &part_clients, &part_cuts);
                self.aggregate(&mut clients, &mut servers, &participants, &mut traffic, scratch)?;
            }

            // ---- evaluation + convergence ----
            if round % t.eval_interval == 0 {
                self.global_model_into(&clients, &servers, scratch)?;
                let (acc, f1, _eval_loss) = self.evaluate(&scratch.agg_full, &scratch.head)?;
                acc_series.push(round, sim_time, acc);
                f1_series.push(round, sim_time, f1);
                final_acc = acc;
                final_f1 = f1;
                if !quiet {
                    println!(
                        "[{:?}/{}] round {round:4}  t={sim_time:9.1}s  loss={mean_loss:.4}  acc={acc:.4}  f1={f1:.4}",
                        self.cfg.scheme,
                        sched.name()
                    );
                }
                if detector.update(round, sim_time, acc) {
                    break;
                }
            }
        }

        let mem = match self.cfg.scheme {
            SchemeKind::Sfl => memory::sfl_server_memory(&self.dims_time, &self.cuts),
            _ => memory::ours_server_memory(&self.dims_time, &self.cuts),
        };
        Ok(RunResult {
            scheme: self.cfg.scheme,
            scheduler: sched.name().to_string(),
            rounds,
            acc: acc_series,
            f1: f1_series,
            convergence_round: detector.converged().map(|(r, _)| r),
            convergence_time: detector.converged().map(|(_, t)| t),
            final_acc,
            final_f1,
            memory_mb: mem.total_mb(),
            memory: mem,
            adapter_switches: switches,
            executions: self.engine.exec_count() - exec0,
            uplink_bytes: traffic.uplink_bytes,
            downlink_bytes: traffic.downlink_bytes,
            wall_secs: wall.elapsed().as_secs_f64(),
        })
    }

    /// Sequential split learning: one global adapter set relayed through
    /// the clients; no aggregation (baseline [18]).
    fn run_sl(&self, quiet: bool) -> Result<RunResult> {
        let wall = std::time::Instant::now();
        let t = &self.cfg.train;
        let mut full = self.engine.initial_lora()?;
        let mut head = self.engine.initial_head()?;
        let mut iters: Vec<BatchIter> = self
            .shards
            .iter()
            .enumerate()
            .map(|(u, s)| BatchIter::new(s, self.dims_exec.batch, t.seed + 100 + u as u64))
            .collect();
        let mut detector = ConvergenceDetector::new(t.patience, t.min_delta);
        let mut traffic = TrafficMeter::default();
        let mut sim_time = 0.0f64;
        let mut rounds = Vec::new();
        let mut acc_series = MetricSeries::default();
        let mut f1_series = MetricSeries::default();
        let (mut final_acc, mut final_f1) = (0.0, 0.0);
        let exec0 = self.engine.exec_count();

        for round in 1..=t.max_rounds {
            let round_lr = t.lr_schedule.at(t.lr, round);
            sim_time += timing::sl_round(
                &self.dims_time,
                &self.cfg.clients,
                &self.cuts,
                &self.cfg.server,
                t.steps_per_round,
            );
            let mut loss_sum = 0.0f32;
            let mut loss_n = 0u32;
            for (u, &k) in self.cuts.iter().enumerate() {
                // Client u receives the current global model (relay).
                let (clora, slora) = full.split_at(k)?;
                let mut cstate = ClientState::fresh(clora);
                let mut sstate = ServerState::fresh(slora, head.clone());
                for _ in 0..t.steps_per_round {
                    let idx = iters[u].next_batch().to_vec();
                    let (tokens, labels) = data::materialize_batch(&self.ds, &idx);
                    let acts = self.engine.client_fwd(k, &tokens, &cstate.lora)?;
                    traffic.record(&Message::Activations {
                        bytes: self.dims_time.activation_bytes(),
                    });
                    let out = self.engine.server_step(k, &acts, &labels, &sstate, round_lr)?;
                    sstate = out.state;
                    traffic.record(&Message::ActivationGrads {
                        bytes: self.dims_time.activation_bytes(),
                    });
                    cstate =
                        self.engine.client_bwd(k, &tokens, &cstate, &out.act_grads, round_lr)?;
                    loss_sum += out.loss;
                    loss_n += 1;
                }
                full = AdapterSet::join(&cstate.lora, &sstate.lora)?;
                head = sstate.head;
            }
            let mean_loss = loss_sum / loss_n.max(1) as f32;
            rounds.push(RoundRecord { round, sim_time, mean_loss });

            if round % t.eval_interval == 0 {
                let (acc, f1, _) = self.evaluate(&full, &head)?;
                acc_series.push(round, sim_time, acc);
                f1_series.push(round, sim_time, f1);
                final_acc = acc;
                final_f1 = f1;
                if !quiet {
                    println!(
                        "[Sl] round {round:4}  t={sim_time:9.1}s  loss={mean_loss:.4}  acc={acc:.4}  f1={f1:.4}"
                    );
                }
                if detector.update(round, sim_time, acc) {
                    break;
                }
            }
        }

        let mem = memory::sl_server_memory(&self.dims_time, &self.cuts);
        Ok(RunResult {
            scheme: SchemeKind::Sl,
            scheduler: "sequential".into(),
            rounds,
            acc: acc_series,
            f1: f1_series,
            convergence_round: detector.converged().map(|(r, _)| r),
            convergence_time: detector.converged().map(|(_, t)| t),
            final_acc,
            final_f1,
            memory_mb: mem.total_mb(),
            memory: mem,
            adapter_switches: 0,
            executions: self.engine.exec_count() - exec0,
            uplink_bytes: traffic.uplink_bytes,
            downlink_bytes: traffic.downlink_bytes,
            wall_secs: wall.elapsed().as_secs_f64(),
        })
    }
}
