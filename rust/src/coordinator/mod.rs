//! L3 coordinator — the paper's system contribution, as a round-stepped
//! Session API.
//!
//! The driver is split along the axis the schemes actually differ on:
//!
//! - [`session::Session`] owns every piece of *shared* round bookkeeping
//!   exactly once — sim-clock accrual, traffic metering, convergence
//!   detection, metric series, the LR schedule, dropout sampling, and
//!   [`RunResult`] assembly — and steps any scheme one round at a time
//!   (`step_round` / `run_to_convergence`), with checkpoint/resume and
//!   streaming [`session::RoundObserver`] telemetry.
//! - [`session::Scheme`] implementations provide only the per-round
//!   orchestration:
//!   - [`session::OursScheme`] (Alg. 1): parallel client forwards →
//!     sequential server LoRA training with adapter switching, ordered
//!     by a pluggable scheduler (Alg. 2 / FIFO / WF / Random) →
//!     parallel client backwards; periodic aggregation (eqs. 5–9).
//!   - [`session::SlScheme`]: one client at a time, the model relayed
//!     between clients (baseline [18]).
//!   - [`session::SflScheme`]: per-client server submodels trained in
//!     parallel (numerically identical to Ours — the difference is
//!     timing + memory, which is exactly the paper's point).
//!
//! Numeric training executes the real AOT artifacts through the
//! in-place runtime primitives (zero `HostTensor` allocations at steady
//! state for *all three* schemes); protocol timing runs on the virtual
//! clock with the paper-scale dims (DESIGN.md §2).
//!
//! Scheduling is fleet-scale: schedulers emit job *indices* through a
//! reused buffer ([`scheduler::Scheduler::order_into`], O(n log n),
//! allocation-free), per-round participation can be bounded
//! (`max_participants`), and the per-client timings feeding Alg. 2 are
//! *learned* online by [`estimator::TimingEstimator`] (EWMA over
//! observed rounds, static eq. 10–12 cold start) unless the experiment
//! pins `oracle_timing`.  Synthetic fleets come from
//! [`fleet::FleetSpec`](crate::fleet::FleetSpec).
//!
//! Memory is fleet-scale too: with `pool.state_cap > 0` the parallel
//! schemes hold per-client LoRA/optimizer state in a
//! [`StatePool`](crate::pool::StatePool) — lazy materialization from
//! the aggregate baseline, bit-exact spill/reload, recycled arenas —
//! so a numeric session keeps O(active cohort) state resident instead
//! of O(fleet), and the shared [`DataPool`](crate::data::DataPool)
//! derives client shards on demand (EXPERIMENTS.md §Memory).
//!
//! Environments can be *non-stationary*: a seeded
//! [`EnvTimeline`](crate::trace::EnvTimeline) makes per-client MFU/link
//! multipliers and availability functions of simulated time (sampled
//! once per round and applied to the job tables before scheduling),
//! `obs_noise_sigma` degrades what the estimator observes, and
//! [`regret`] scores each scheduling policy per round against the
//! clairvoyant oracle schedule over the true current-time environment.
//!
//! [`Trainer`] survives only as a thin deprecated shim over
//! `Session::run_to_convergence` + the stdout observer.

pub mod estimator;
pub mod lr;
pub mod regret;
pub mod scheduler;
pub mod session;
pub mod timing;

use crate::config::{ExperimentConfig, SchemeKind};
use crate::metrics::MetricSeries;
use crate::model::memory;
use crate::runtime::Engine;
use anyhow::Result;

pub use session::{
    EvalPoint, RoundCtx, RoundObserver, RoundOutcome, RoundReport, RoundScratch, Scheme,
    SchedulerLabel, Session, SessionEnv,
};

/// One round's training record.
#[derive(Debug, Clone, Copy)]
pub struct RoundRecord {
    pub round: usize,
    pub sim_time: f64,
    pub mean_loss: f32,
}

/// Everything one experiment run produces (the raw material for Table I
/// and Fig. 2).
#[derive(Debug)]
pub struct RunResult {
    pub scheme: SchemeKind,
    pub scheduler: SchedulerLabel,
    pub rounds: Vec<RoundRecord>,
    pub acc: MetricSeries,
    pub f1: MetricSeries,
    pub convergence_round: Option<usize>,
    pub convergence_time: Option<f64>,
    pub final_acc: f64,
    pub final_f1: f64,
    pub memory_mb: f64,
    pub memory: memory::MemoryBreakdown,
    pub adapter_switches: u64,
    pub executions: u64,
    pub uplink_bytes: u64,
    pub downlink_bytes: u64,
    pub wall_secs: f64,
}

impl RunResult {
    /// Total simulated fine-tuning time (Table I "Convergence Time" when
    /// converged, else the time at the last round).
    pub fn total_time(&self) -> f64 {
        self.convergence_time
            .unwrap_or_else(|| self.rounds.last().map(|r| r.sim_time).unwrap_or(0.0))
    }
}

/// Deprecated single-shot driver, kept as a thin shim over [`Session`]
/// for older call sites.  New code should construct a `Session`
/// directly: it exposes round stepping, checkpoint/resume, and
/// observer-based telemetry.
pub struct Trainer<'e> {
    engine: &'e Engine,
    cfg: ExperimentConfig,
    cuts: Vec<usize>,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: &ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(Self { engine, cfg: cfg.clone(), cuts: cfg.resolve_cuts() })
    }

    pub fn cuts(&self) -> &[usize] {
        &self.cuts
    }

    /// Run the configured scheme to convergence.  `quiet` suppresses the
    /// per-round progress lines.
    #[deprecated(
        note = "use Session::run_to_convergence with a telemetry::StdoutObserver instead"
    )]
    pub fn run(&mut self, quiet: bool) -> Result<RunResult> {
        let mut session = Session::new(self.engine, &self.cfg)?;
        if !quiet {
            session.add_observer(Box::new(crate::telemetry::StdoutObserver));
        }
        session.run_to_convergence()
    }
}
