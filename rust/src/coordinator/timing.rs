//! Per-step timing under the paper's model (eqs. 10–12), for each scheme.
//!
//! T_u = T_u^f + T_u^fc + T_u^w + T_u^s + T_u^bc + T_u^b  (eq. 10)
//! with the waiting time T_u^w induced by the sequential server queue
//! (eq. 11) and the step completing at max_u T_u (eq. 12).

use super::scheduler::{JobInfo, Scheduler};
use crate::config::ClientConfig;
use crate::devices::ServerProfile;
use crate::model::ModelDims;
use crate::simclock::SequentialResource;
use crate::trace::{EnvTimeline, NoisyObservation};

/// Timing components of one client's step (diagnostics + telemetry).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepTiming {
    pub t_fwd: f64,
    pub t_fwd_comm: f64,
    pub t_wait: f64,
    pub t_server: f64,
    pub t_bwd_comm: f64,
    pub t_bwd: f64,
}

impl StepTiming {
    pub fn total(&self) -> f64 {
        self.t_fwd + self.t_fwd_comm + self.t_wait + self.t_server + self.t_bwd_comm + self.t_bwd
    }

    /// The queue-independent components of a job as one observation
    /// (zero wait) — what a deployed client would report back per round
    /// and what the [`TimingEstimator`](super::estimator::TimingEstimator)
    /// consumes in simulation.
    pub fn from_job(j: &JobInfo) -> Self {
        Self {
            t_fwd: j.arrival - j.bwd_comm_time, // fwd_comm == bwd_comm size
            t_fwd_comm: j.bwd_comm_time,
            t_wait: 0.0,
            t_server: j.server_time,
            t_bwd_comm: j.bwd_comm_time,
            t_bwd: j.client_bwd_time,
        }
    }

    /// All measured channels multiplied by `factor` — how a timing-lying
    /// client misreports its step to the estimator.  Wait is
    /// queue-derived on the server side and cannot be lied about.
    pub fn scaled(&self, factor: f64) -> Self {
        Self {
            t_fwd: self.t_fwd * factor,
            t_fwd_comm: self.t_fwd_comm * factor,
            t_wait: self.t_wait,
            t_server: self.t_server * factor,
            t_bwd_comm: self.t_bwd_comm * factor,
            t_bwd: self.t_bwd * factor,
        }
    }

    /// These timings as the estimator would *observe* them under
    /// multiplicative measurement noise: one lognormal factor per
    /// estimator channel (arrival, server, backward, downlink), drawn
    /// in that fixed order from the checkpointed noise RNG.  Wait is
    /// queue-derived, not measured, and stays exact.
    pub fn noisy(&self, noise: &mut NoisyObservation) -> Self {
        let (fa, fs, fb, fc) = (noise.factor(), noise.factor(), noise.factor(), noise.factor());
        Self {
            t_fwd: self.t_fwd * fa,
            t_fwd_comm: self.t_fwd_comm * fa,
            t_wait: self.t_wait,
            t_server: self.t_server * fs,
            t_bwd_comm: self.t_bwd_comm * fc,
            t_bwd: self.t_bwd * fb,
        }
    }
}

/// `base` (a static eq. 10–12 job) under the environment's current
/// multipliers: client-side compute scales by `1/mfu_mult`, both comm
/// legs by `1/link_mult`; server time is unaffected.  The capability
/// stays in the base job's key *family* — it is multiplied by
/// `mfu_mult`, so oracle jobs keep Alg. 2's canonical `N_c / C_u` key
/// (reported capability, now at its current-time effective value) and
/// identity multipliers reproduce the static job's key bit-for-bit.
/// Changing the key semantics here would make an active-but-idle
/// timeline (e.g. Markov churn, whose multipliers are constant 1)
/// schedule differently from the equivalent static run.
pub fn scaled_job(base: &JobInfo, mfu_mult: f64, link_mult: f64) -> JobInfo {
    let m = mfu_mult.max(1e-6);
    let l = link_mult.max(1e-6);
    let t_fwd = (base.arrival - base.bwd_comm_time) / m;
    let comm = base.bwd_comm_time / l;
    let bwd = base.client_bwd_time / m;
    JobInfo {
        client: base.client,
        arrival: t_fwd + comm,
        server_time: base.server_time,
        client_bwd_time: bwd,
        bwd_comm_time: comm,
        n_client_adapters: base.n_client_adapters,
        compute_capability: base.compute_capability * m,
    }
}

/// One uncontended training step of a single client's job: client
/// forward + uplink (`arrival`), server step, gradient downlink, and
/// client backward — the queue-free end-to-end latency the async
/// engine uses for a solo dispatch (no cohort, so no waiting).
pub fn solo_step(j: &JobInfo) -> f64 {
    j.arrival + j.server_time + j.bwd_comm_time + j.client_bwd_time
}

/// Build the per-client job descriptions for one step of the proposed
/// scheme (all clients start at relative time 0 — client forwards run in
/// parallel).
pub fn build_jobs(
    dims: &ModelDims,
    clients: &[ClientConfig],
    cuts: &[usize],
    server: &ServerProfile,
) -> Vec<JobInfo> {
    clients
        .iter()
        .zip(cuts.iter())
        .enumerate()
        .map(|(u, (c, &k))| {
            let t_fwd = c.device.compute_time(dims.client_fwd_flops(k));
            let t_fc = c.link.transfer_time(dims.activation_bytes());
            JobInfo {
                client: u,
                arrival: t_fwd + t_fc,
                server_time: server.compute_time(dims.server_flops(k), 1),
                client_bwd_time: c.device.compute_time(dims.client_bwd_flops(k)),
                bwd_comm_time: c.link.transfer_time(dims.activation_bytes()),
                n_client_adapters: k * ModelDims::ADAPTERS_PER_LAYER,
                compute_capability: c.device.tflops,
            }
        })
        .collect()
}

/// [`build_jobs`] over the server's *nominal* view of the fleet
/// (reported specs, class-default MFU) — the static eq. 10–12 model the
/// timing estimator cold-starts from.  One definition shared by the
/// session, the scale bench, and the acceptance tests.
pub fn build_nominal_jobs(
    dims: &ModelDims,
    clients: &[ClientConfig],
    cuts: &[usize],
    server: &ServerProfile,
) -> Vec<JobInfo> {
    let nominal: Vec<ClientConfig> = clients
        .iter()
        .map(|c| ClientConfig { device: c.device.nominal(), ..c.clone() })
        .collect();
    build_jobs(dims, &nominal, cuts, server)
}

/// One step of **Ours** under a given scheduler: parallel client
/// forwards, sequential server (eq. 11 queueing), parallel backwards.
/// Returns (step completion time, per-client timings in client order).
pub fn ours_step(
    dims: &ModelDims,
    clients: &[ClientConfig],
    cuts: &[usize],
    server: &ServerProfile,
    scheduler: &mut dyn Scheduler,
) -> (f64, Vec<StepTiming>) {
    let jobs = build_jobs(dims, clients, cuts, server);
    ours_step_with_jobs(&jobs, scheduler)
}

/// [`ours_step`] over prebuilt jobs — jobs depend only on the round's
/// participants, so callers build them once and reuse them.  Draws
/// exactly one order from the scheduler per call.
pub fn ours_step_with_jobs(
    jobs: &[JobInfo],
    scheduler: &mut dyn Scheduler,
) -> (f64, Vec<StepTiming>) {
    let mut order = Vec::with_capacity(jobs.len());
    scheduler.order_into(jobs, &mut order);
    ours_step_ordered(jobs, &order)
}

/// Timing of one **Ours** step under a *given* server order (job
/// indices).  The session computes each step's order exactly once and
/// shares it between this timing walk and the numeric execution, so
/// stateful schedulers can never account time against orders that were
/// not executed.  Per-client timings come back in job order.
pub fn ours_step_ordered(jobs: &[JobInfo], order: &[usize]) -> (f64, Vec<StepTiming>) {
    debug_assert_eq!(order.len(), jobs.len());
    let mut queue = SequentialResource::default();
    let mut timings = vec![StepTiming::default(); jobs.len()];
    let mut step_time = 0.0f64;
    for &i in order {
        let j = &jobs[i];
        let (start, finish) = queue.admit(j.arrival, j.server_time);
        let mut t = StepTiming::from_job(j);
        t.t_wait = start - j.arrival;
        step_time = step_time.max(finish + j.bwd_comm_time + j.client_bwd_time);
        timings[i] = t;
    }
    (step_time, timings)
}

/// One step of **SFL** (FedBERT-style): the server trains all U
/// server-side submodels in parallel, contending for the GPU.
pub fn sfl_step(
    dims: &ModelDims,
    clients: &[ClientConfig],
    cuts: &[usize],
    server: &ServerProfile,
) -> (f64, Vec<StepTiming>) {
    let jobs = build_jobs(dims, clients, cuts, server);
    sfl_step_with_jobs(&jobs, dims, cuts, server)
}

/// [`sfl_step`] over prebuilt jobs (see [`ours_step_with_jobs`]).
pub fn sfl_step_with_jobs(
    jobs: &[JobInfo],
    dims: &ModelDims,
    cuts: &[usize],
    server: &ServerProfile,
) -> (f64, Vec<StepTiming>) {
    let concurrency = jobs.len();
    let mut step_time = 0.0f64;
    let mut timings = vec![StepTiming::default(); jobs.len()];
    for (u, j) in jobs.iter().enumerate() {
        // Parallel execution: no queueing, but each job runs at the
        // contended 1/J rate (paper §V-B: memory-access competition).
        let t_server = server.compute_time(dims.server_flops(cuts[u]), concurrency);
        let t = StepTiming {
            t_fwd: j.arrival - j.bwd_comm_time,
            t_fwd_comm: j.bwd_comm_time,
            t_wait: 0.0,
            t_server,
            t_bwd_comm: j.bwd_comm_time,
            t_bwd: j.client_bwd_time,
        };
        step_time = step_time.max(j.arrival + t_server + j.bwd_comm_time + j.client_bwd_time);
        timings[u] = t;
    }
    (step_time, timings)
}

/// [`sfl_step_with_jobs`] for the session's round loop: `jobs[i]` is
/// participant `participants[i]`'s (possibly environment-scaled) job,
/// cuts are indexed from the full per-client table, and only the step
/// completion time comes back — no per-round `Vec` of timings, no
/// participant gathers.
pub fn sfl_step_for(
    jobs: &[JobInfo],
    dims: &ModelDims,
    cuts: &[usize],
    participants: &[usize],
    server: &ServerProfile,
) -> f64 {
    debug_assert_eq!(jobs.len(), participants.len());
    let concurrency = jobs.len();
    let mut step_time = 0.0f64;
    for (j, &u) in jobs.iter().zip(participants.iter()) {
        let t_server = server.compute_time(dims.server_flops(cuts[u]), concurrency);
        step_time = step_time.max(j.arrival + t_server + j.bwd_comm_time + j.client_bwd_time);
    }
    step_time
}

/// One *round* of **SL** (sequential split learning): clients run one at
/// a time, each doing `steps` local mini-batch steps, then the client
/// model is relayed to the next client through the server.
pub fn sl_round(
    dims: &ModelDims,
    clients: &[ClientConfig],
    cuts: &[usize],
    server: &ServerProfile,
    steps: usize,
) -> f64 {
    let mut total = 0.0f64;
    // Handoff relays only the *trainable* client-side state (LoRA
    // adapters) — the frozen base model was distributed once before
    // training, exactly as in the paper's LoRA setting.
    let max_cut = cuts.iter().copied().max().unwrap_or(1);
    let handoff_bytes = dims.lora_bytes(max_cut);
    for (u, (c, &k)) in clients.iter().zip(cuts.iter()).enumerate() {
        let per_step = c.device.compute_time(dims.client_fwd_flops(k))
            + c.link.transfer_time(dims.activation_bytes())
            + server.compute_time(dims.server_flops(k), 1)
            + c.link.transfer_time(dims.activation_bytes())
            + c.device.compute_time(dims.client_bwd_flops(k));
        total += steps as f64 * per_step;
        // Adapter handoff to the next client (skipped after the last).
        if u + 1 < clients.len() {
            total += c.link.transfer_time(handoff_bytes)
                + clients[u + 1].link.transfer_time(handoff_bytes);
        }
    }
    total
}

/// [`sl_round`] for the session's round loop: participants are indices
/// into the *full* client/cut tables (no per-round `ClientConfig`
/// clones), and the environment timeline's current multipliers scale
/// client compute (`1/mfu_mult`) and both comm legs (`1/link_mult`).
/// With the identity participants and an inactive timeline this equals
/// [`sl_round`] exactly (tested below).
pub fn sl_round_for(
    dims: &ModelDims,
    clients: &[ClientConfig],
    cuts: &[usize],
    server: &ServerProfile,
    steps: usize,
    participants: &[usize],
    env: &EnvTimeline,
) -> f64 {
    let mut total = 0.0f64;
    let max_cut = participants.iter().map(|&u| cuts[u]).max().unwrap_or(1);
    let handoff_bytes = dims.lora_bytes(max_cut);
    for (i, &u) in participants.iter().enumerate() {
        let c = &clients[u];
        let k = cuts[u];
        let m = env.mfu_mult(u).max(1e-6);
        let l = env.link_mult(u).max(1e-6);
        let per_step = c.device.compute_time(dims.client_fwd_flops(k)) / m
            + c.link.transfer_time(dims.activation_bytes()) / l
            + server.compute_time(dims.server_flops(k), 1)
            + c.link.transfer_time(dims.activation_bytes()) / l
            + c.device.compute_time(dims.client_bwd_flops(k)) / m;
        total += steps as f64 * per_step;
        if i + 1 < participants.len() {
            let v = participants[i + 1];
            let lv = env.link_mult(v).max(1e-6);
            total += c.link.transfer_time(handoff_bytes) / l
                + clients[v].link.transfer_time(handoff_bytes) / lv;
        }
    }
    total
}

/// LoRA aggregation-phase time (paper steps 2a–2c): parallel uploads of
/// client adapters, negligible server aggregation, parallel downloads.
pub fn aggregation_time(dims: &ModelDims, clients: &[ClientConfig], cuts: &[usize]) -> f64 {
    clients
        .iter()
        .zip(cuts.iter())
        .map(|(c, &k)| {
            c.link.transfer_time(dims.lora_bytes(k)) * 2.0 // up + down
        })
        .fold(0.0, f64::max)
}

/// [`aggregation_time`] for the session's round loop: participants are
/// indices into the full tables (no per-round clones) and the current
/// link multipliers scale each client's transfer.
pub fn aggregation_time_for(
    dims: &ModelDims,
    clients: &[ClientConfig],
    cuts: &[usize],
    participants: &[usize],
    env: &EnvTimeline,
) -> f64 {
    participants
        .iter()
        .map(|&u| {
            clients[u].link.transfer_time(dims.lora_bytes(cuts[u])) * 2.0
                / env.link_mult(u).max(1e-6)
        })
        .fold(0.0, f64::max)
}

/// [`aggregation_time_for`] with asymmetric legs: the uplink carries
/// `up_bytes(cut)` (a compressed-transport payload) while the aggregate
/// broadcast stays dense.  With `up_bytes = dims.lora_bytes` this is
/// bit-identical to [`aggregation_time_for`] (`x * 2.0 == x + x` in
/// IEEE-754, tested below).
pub fn aggregation_time_split(
    dims: &ModelDims,
    clients: &[ClientConfig],
    cuts: &[usize],
    participants: &[usize],
    env: &EnvTimeline,
    up_bytes: &dyn Fn(usize) -> usize,
) -> f64 {
    participants
        .iter()
        .map(|&u| {
            let link = &clients[u].link;
            (link.transfer_time(up_bytes(cuts[u])) + link.transfer_time(dims.lora_bytes(cuts[u])))
                / env.link_mult(u).max(1e-6)
        })
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::scheduler::{makespan, FifoScheduler, ProposedScheduler};

    fn setup() -> (ModelDims, Vec<ClientConfig>, Vec<usize>, ServerProfile) {
        let cfg = ExperimentConfig::paper();
        let dims = cfg.timing_dims();
        let cuts = cfg.resolve_cuts();
        (dims, cfg.clients, cuts, cfg.server)
    }

    #[test]
    fn solo_step_is_the_queue_free_latency() {
        let (dims, clients, cuts, server) = setup();
        let jobs = build_jobs(&dims, &clients, &cuts, &server);
        for j in &jobs {
            let s = solo_step(j);
            assert!(s > 0.0);
            // No queueing: a one-client cohort's makespan is its solo step.
            assert!((s - makespan(std::slice::from_ref(j), &[0])).abs() < 1e-12);
        }
        // An identity-scaled job keeps the exact same solo step.
        let scaled = scaled_job(&jobs[0], 1.0, 1.0);
        assert_eq!(solo_step(&scaled).to_bits(), solo_step(&jobs[0]).to_bits());
    }

    #[test]
    fn ours_step_components_positive_and_consistent() {
        let (dims, clients, cuts, server) = setup();
        let (step, timings) = ours_step(&dims, &clients, &cuts, &server, &mut ProposedScheduler);
        assert!(step > 0.0);
        for t in &timings {
            assert!(t.t_fwd > 0.0 && t.t_server > 0.0 && t.t_bwd > 0.0);
            // eq. 12: the step is at least every client's own total.
            assert!(step >= t.total() - 1e-9);
        }
        // eq. 12 is tight: some client achieves the max.
        assert!(timings.iter().any(|t| (step - t.total()).abs() < 1e-9));
    }

    #[test]
    fn waiting_time_is_eq11_under_fifo() {
        let (dims, clients, cuts, server) = setup();
        let jobs = build_jobs(&dims, &clients, &cuts, &server);
        let (_, timings) = ours_step(&dims, &clients, &cuts, &server, &mut FifoScheduler);
        // Under FIFO with distinct arrivals, each client's wait is bounded
        // by the sum of earlier server times (eq. 11 with idle gaps).
        let mut order: Vec<usize> = (0..jobs.len()).collect();
        order.sort_by(|&a, &b| jobs[a].arrival.partial_cmp(&jobs[b].arrival).unwrap());
        let mut sum_earlier = 0.0;
        for &u in &order {
            assert!(timings[u].t_wait <= sum_earlier + 1e-9);
            sum_earlier += jobs[u].server_time;
        }
    }

    #[test]
    fn ours_step_ordered_agrees_with_makespan_and_scheduler_draw() {
        use crate::coordinator::scheduler::{makespan, RandomScheduler};
        let (dims, clients, cuts, server) = setup();
        let jobs = build_jobs(&dims, &clients, &cuts, &server);
        // For any executed order, the step time is exactly the makespan
        // of that order — timing and execution share one schedule.
        let mut sched = RandomScheduler::new(17);
        let mut twin = RandomScheduler::new(17);
        let mut order = Vec::new();
        for _ in 0..4 {
            twin.order_into(&jobs, &mut order);
            let (t, timings) = ours_step_with_jobs(&jobs, &mut sched);
            assert!((t - makespan(&jobs, &order)).abs() < 1e-12);
            // Components are queue-independent except the wait.
            for (i, j) in jobs.iter().enumerate() {
                assert!((timings[i].t_server - j.server_time).abs() < 1e-12);
                assert!((timings[i].t_bwd - j.client_bwd_time).abs() < 1e-12);
            }
        }
        // Both RNG streams consumed one order per step — still in sync.
        assert_eq!(sched.rng_state(), twin.rng_state());
    }

    #[test]
    fn proposed_no_slower_than_fifo_on_paper_fleet() {
        let (dims, clients, cuts, server) = setup();
        let (t_prop, _) = ours_step(&dims, &clients, &cuts, &server, &mut ProposedScheduler);
        let (t_fifo, _) = ours_step(&dims, &clients, &cuts, &server, &mut FifoScheduler);
        assert!(t_prop <= t_fifo + 1e-9, "proposed {t_prop} vs fifo {t_fifo}");
    }

    #[test]
    fn sfl_step_slower_than_ours_on_paper_fleet() {
        // The paper's 6% training-time claim: contention makes parallel
        // server training slower than sequenced training.
        let (dims, clients, cuts, server) = setup();
        let (t_ours, _) = ours_step(&dims, &clients, &cuts, &server, &mut ProposedScheduler);
        let (t_sfl, _) = sfl_step(&dims, &clients, &cuts, &server);
        assert!(t_ours < t_sfl, "ours {t_ours} vs sfl {t_sfl}");
    }

    #[test]
    fn sl_round_much_slower_than_ours_round() {
        let (dims, clients, cuts, server) = setup();
        let steps = 4;
        let (t_step, _) = ours_step(&dims, &clients, &cuts, &server, &mut ProposedScheduler);
        let t_ours_round = steps as f64 * t_step;
        let t_sl = sl_round(&dims, &clients, &cuts, &server, steps);
        assert!(
            t_sl > 1.5 * t_ours_round,
            "sl {t_sl} vs ours-round {t_ours_round}"
        );
    }

    #[test]
    fn aggregation_time_is_max_over_clients() {
        let (dims, clients, cuts, _) = setup();
        let t = aggregation_time(&dims, &clients, &cuts);
        let worst = clients
            .iter()
            .zip(cuts.iter())
            .map(|(c, &k)| c.link.transfer_time(dims.lora_bytes(k)) * 2.0)
            .fold(0.0, f64::max);
        assert!((t - worst).abs() < 1e-12);
    }

    #[test]
    fn indexed_variants_match_the_cloning_originals() {
        // The session's round loop calls the `_for` variants with
        // participant indices into the full tables; with the identity
        // participants and an inactive timeline they must equal the
        // slice-based originals bit-for-bit.
        let (dims, clients, cuts, server) = setup();
        let ids: Vec<usize> = (0..clients.len()).collect();
        let env = EnvTimeline::inactive();
        let agg = aggregation_time(&dims, &clients, &cuts);
        let agg_for = aggregation_time_for(&dims, &clients, &cuts, &ids, &env);
        assert_eq!(agg.to_bits(), agg_for.to_bits());
        let sl = sl_round(&dims, &clients, &cuts, &server, 3);
        let sl_for = sl_round_for(&dims, &clients, &cuts, &server, 3, &ids, &env);
        assert_eq!(sl.to_bits(), sl_for.to_bits());
        let jobs = build_jobs(&dims, &clients, &cuts, &server);
        let (sfl, _) = sfl_step_with_jobs(&jobs, &dims, &cuts, &server);
        let sfl_for = sfl_step_for(&jobs, &dims, &cuts, &ids, &server);
        assert_eq!(sfl.to_bits(), sfl_for.to_bits());
        // And on a participant *subset* they index the global tables.
        let subset = vec![1usize, 4];
        let sub_clients: Vec<ClientConfig> =
            subset.iter().map(|&u| clients[u].clone()).collect();
        let sub_cuts: Vec<usize> = subset.iter().map(|&u| cuts[u]).collect();
        let a = aggregation_time(&dims, &sub_clients, &sub_cuts);
        let b = aggregation_time_for(&dims, &clients, &cuts, &subset, &env);
        assert_eq!(a.to_bits(), b.to_bits());
        let a = sl_round(&dims, &sub_clients, &sub_cuts, &server, 2);
        let b = sl_round_for(&dims, &clients, &cuts, &server, 2, &subset, &env);
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn split_aggregation_degenerates_to_symmetric() {
        // With a dense uplink the asymmetric variant is bit-identical
        // to the `* 2.0` original; a smaller uplink strictly shortens
        // the phase (down to no less than the dense download leg).
        let (dims, clients, cuts, _) = setup();
        let ids: Vec<usize> = (0..clients.len()).collect();
        let env = EnvTimeline::inactive();
        let sym = aggregation_time_for(&dims, &clients, &cuts, &ids, &env);
        let split =
            aggregation_time_split(&dims, &clients, &cuts, &ids, &env, &|k| dims.lora_bytes(k));
        assert_eq!(sym.to_bits(), split.to_bits());
        let tenth =
            aggregation_time_split(&dims, &clients, &cuts, &ids, &env, &|k| {
                dims.lora_bytes(k) / 10
            });
        assert!(tenth < sym && tenth > sym / 2.0, "tenth {tenth} vs sym {sym}");
    }

    #[test]
    fn scaled_job_scales_client_side_components_only() {
        let (dims, clients, cuts, server) = setup();
        let base = build_jobs(&dims, &clients, &cuts, &server);
        let j = scaled_job(&base[0], 2.0, 0.5);
        // MFU ×2 halves client compute; link ×0.5 doubles comm.
        let t_fwd0 = base[0].arrival - base[0].bwd_comm_time;
        assert!((j.client_bwd_time - base[0].client_bwd_time / 2.0).abs() < 1e-15);
        assert!((j.bwd_comm_time - base[0].bwd_comm_time * 2.0).abs() < 1e-15);
        assert!((j.arrival - (t_fwd0 / 2.0 + base[0].bwd_comm_time * 2.0)).abs() < 1e-12);
        assert_eq!(j.server_time.to_bits(), base[0].server_time.to_bits());
        // The capability stays in the base key family, scaled to its
        // current-time effective value — Alg. 2's N_c/C key halves.
        assert_eq!(j.compute_capability.to_bits(), (base[0].compute_capability * 2.0).to_bits());
        assert!((j.greedy_priority() - base[0].greedy_priority() / 2.0).abs() < 1e-9);
        // Identity multipliers leave the timings unchanged (up to the
        // fwd/comm recomposition of `arrival`, which is not bit-stable)
        // and the greedy key bit-identical — an active-but-idle
        // timeline must schedule exactly like the static run.
        let id = scaled_job(&base[0], 1.0, 1.0);
        assert!((id.arrival - base[0].arrival).abs() < 1e-12);
        assert_eq!(id.client_bwd_time.to_bits(), base[0].client_bwd_time.to_bits());
        assert_eq!(id.bwd_comm_time.to_bits(), base[0].bwd_comm_time.to_bits());
        assert_eq!(id.compute_capability.to_bits(), base[0].compute_capability.to_bits());
    }

    #[test]
    fn noisy_observation_perturbs_channels_multiplicatively() {
        let (dims, clients, cuts, server) = setup();
        let jobs = build_jobs(&dims, &clients, &cuts, &server);
        let clean = StepTiming::from_job(&jobs[0]);
        let mut off = NoisyObservation::new(5, 0.0);
        let same = clean.noisy(&mut off);
        assert_eq!(same.t_bwd.to_bits(), clean.t_bwd.to_bits());
        let mut on = NoisyObservation::new(5, 0.5);
        let noisy = clean.noisy(&mut on);
        assert!(noisy.t_bwd > 0.0 && noisy.t_server > 0.0);
        assert!(
            (noisy.t_bwd - clean.t_bwd).abs() > 1e-12
                || (noisy.t_server - clean.t_server).abs() > 1e-12,
            "sigma=0.5 noise left every channel untouched"
        );
        // fwd and fwd_comm share the arrival factor (one channel).
        assert!(
            ((noisy.t_fwd / clean.t_fwd) - (noisy.t_fwd_comm / clean.t_fwd_comm)).abs() < 1e-9
        );
    }
}
