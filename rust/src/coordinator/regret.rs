//! Scheduling-regret harness for non-stationary environments.
//!
//! Per round, the clairvoyant *oracle* schedule is Alg. 2 run on the
//! true (noise-free, current-time) environment jobs.  Each competing
//! policy proposes an order from its own (possibly stale or noisy)
//! view, but is *evaluated* against the true jobs; its per-round regret
//! is `makespan_policy − makespan_oracle` and the benchmark tracks the
//! cumulative sum across the trace:
//!
//! - **oracle** — Alg. 2 on the true jobs (regret 0 by construction;
//!   emitted as the sanity row).
//! - **estimator** — Alg. 2 on the online `TimingEstimator`'s view
//!   (nominal cold start, noisy observations fed back each round).
//! - **nominal** — Alg. 2 on the static reported-spec model, never
//!   updated: what scheduling looks like when drift is ignored.
//! - **random** — seeded random order over the true jobs (control).
//!
//! The per-round regret can be negative on rounds where a stale view
//! accidentally beats the greedy oracle (Alg. 2 is a heuristic, not the
//! exhaustive optimum); cumulatively the oracle view wins.
//!
//! Used by `benches/trace_regret.rs` (→ `BENCH_trace.json`) and the
//! acceptance tests in `tests/trace_env.rs` — pure timing model, no
//! artifacts needed.

use crate::config::ExperimentConfig;
use crate::coordinator::estimator::TimingEstimator;
use crate::coordinator::scheduler::{
    makespan, JobInfo, ProposedScheduler, RandomScheduler, Scheduler,
};
use crate::coordinator::timing::{self, StepTiming};
use crate::fleet::{FleetPreset, FleetSpec};
use crate::trace::{EnvTimeline, NoisyObservation, TraceSpec};
use anyhow::Result;

/// One regret experiment: a synthesized fleet driven through a trace.
#[derive(Debug, Clone)]
pub struct RegretConfig {
    /// Fleet size (lognormal preset).
    pub n: usize,
    /// Rounds to simulate.
    pub rounds: usize,
    pub fleet_seed: u64,
    /// Hidden per-device MFU jitter of the synthesized fleet (the
    /// static estimation gap, on top of the trace's drift).
    pub fleet_mfu_sigma: f64,
    /// The environment trace (including `obs_noise_sigma`).
    pub spec: TraceSpec,
    /// Estimator EWMA smoothing factor.
    pub ewma_alpha: f64,
}

impl RegretConfig {
    pub fn new(spec: TraceSpec) -> Self {
        Self {
            n: 100,
            rounds: 150,
            fleet_seed: 23,
            fleet_mfu_sigma: 0.25,
            spec,
            ewma_alpha: crate::coordinator::estimator::DEFAULT_EWMA_ALPHA,
        }
    }
}

/// Cumulative regret per policy (virtual seconds above the oracle).
#[derive(Debug, Clone, Copy)]
pub struct RegretReport {
    /// Rounds actually scored.
    pub rounds: usize,
    /// Σ oracle makespans — the scale reference for the regrets.
    pub oracle_total: f64,
    pub estimator: f64,
    pub nominal: f64,
    pub random: f64,
}

impl RegretReport {
    /// Cumulative regret as a fraction of the oracle's total time.
    pub fn relative(&self, regret: f64) -> f64 {
        regret / self.oracle_total.max(1e-12)
    }
}

/// Run the per-round policy comparison over the configured trace.
pub fn run_regret(rc: &RegretConfig) -> Result<RegretReport> {
    let mut cfg = ExperimentConfig::paper();
    let mut fleet = FleetSpec::new(FleetPreset::Lognormal, rc.n, rc.fleet_seed);
    fleet.mfu_sigma = rc.fleet_mfu_sigma;
    cfg.apply_fleet(fleet);
    let dims = cfg.timing_dims();
    let cuts = cfg.resolve_cuts();
    let base_jobs = timing::build_jobs(&dims, &cfg.clients, &cuts, &cfg.server);
    let nominal_jobs = timing::build_nominal_jobs(&dims, &cfg.clients, &cuts, &cfg.server);

    let mut timeline = EnvTimeline::new(&rc.spec, rc.n)?;
    let mut noise = NoisyObservation::new(rc.spec.seed ^ 0x0B5E_C0DE, rc.spec.obs_noise_sigma);
    let mut est = TimingEstimator::new(rc.n, rc.ewma_alpha);
    let mut greedy = ProposedScheduler;
    let mut random = RandomScheduler::new(rc.spec.seed ^ 0x5EED);

    // Reused per-round buffers.
    let mut participants: Vec<usize> = Vec::with_capacity(rc.n);
    let mut true_jobs: Vec<JobInfo> = Vec::with_capacity(rc.n);
    let mut view_jobs: Vec<JobInfo> = Vec::with_capacity(rc.n);
    let mut nom_part: Vec<JobInfo> = Vec::with_capacity(rc.n);
    let mut order: Vec<usize> = Vec::with_capacity(rc.n);

    let mut report =
        RegretReport { rounds: 0, oracle_total: 0.0, estimator: 0.0, nominal: 0.0, random: 0.0 };
    let mut sim_time = 0.0f64;
    for _ in 0..rc.rounds {
        timeline.advance(sim_time);
        participants.clear();
        participants.extend((0..rc.n).filter(|&u| timeline.is_available(u)));
        if participants.is_empty() {
            // Total churn blackout: nothing to schedule this round.
            // (The Session, which must keep its aggregation/eval
            // cadence and RNG streams advancing, instead forces one
            // best-effort survivor — the analytic harness has no such
            // constraint and simply skips the round.)
            sim_time += 1.0;
            continue;
        }
        true_jobs.clear();
        if timeline.is_active() {
            true_jobs.extend(participants.iter().map(|&u| {
                timing::scaled_job(&base_jobs[u], timeline.mfu_mult(u), timeline.link_mult(u))
            }));
        } else {
            true_jobs.extend(participants.iter().map(|&u| base_jobs[u]));
        }
        nom_part.clear();
        nom_part.extend(participants.iter().map(|&u| nominal_jobs[u]));

        // Clairvoyant oracle: Alg. 2 on the true current-time jobs.
        greedy.order_into(&true_jobs, &mut order);
        let m_star = makespan(&true_jobs, &order);
        report.oracle_total += m_star;

        // Estimator view (nominal fallback for cold clients).
        est.jobs_into(&nom_part, &mut view_jobs);
        greedy.order_into(&view_jobs, &mut order);
        report.estimator += makespan(&true_jobs, &order) - m_star;

        // Static nominal model, never updated.
        greedy.order_into(&nom_part, &mut order);
        report.nominal += makespan(&true_jobs, &order) - m_star;

        // Random control.
        random.order_into(&true_jobs, &mut order);
        report.random += makespan(&true_jobs, &order) - m_star;

        // Feedback: the estimator observes the round's true timings
        // through the measurement-noise channel.
        for j in &true_jobs {
            let clean = StepTiming::from_job(j);
            let obs = if noise.is_active() { clean.noisy(&mut noise) } else { clean };
            est.observe(j.client, &obs);
        }
        report.rounds += 1;
        sim_time += m_star;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceKind;

    #[test]
    fn static_environment_has_near_zero_estimator_regret_after_warmup() {
        // With no trace and no noise, the estimator converges to the
        // truth after its first observation round, so all but the cold
        // round contribute zero regret — and the nominal model's regret
        // only comes from the hidden fleet MFU jitter.
        let spec = TraceSpec::default();
        let mut rc = RegretConfig::new(spec);
        rc.n = 60;
        rc.rounds = 30;
        let rep = run_regret(&rc).unwrap();
        assert_eq!(rep.rounds, 30);
        assert!(rep.oracle_total > 0.0);
        // From round 1 the estimator's view equals the truth exactly
        // (first observation seeds the EWMA; no noise, no drift), so
        // any remaining regret comes from the measured-tail key vs the
        // oracle's reported-spec N_c/C key — bounded by the same 5%
        // makespan envelope `tests/fleet_sched.rs` gates (an estimator
        // that failed to converge would blow far past it).
        assert!(
            rep.relative(rep.estimator).abs() < 0.05,
            "static-fleet estimator regret outside the 5% envelope: {} over {} oracle seconds",
            rep.estimator,
            rep.oracle_total
        );
    }

    #[test]
    fn regret_is_deterministic() {
        let spec = TraceSpec { kind: TraceKind::RandomWalk, ..TraceSpec::default() };
        let mut rc = RegretConfig::new(spec);
        rc.n = 40;
        rc.rounds = 20;
        let a = run_regret(&rc).unwrap();
        let b = run_regret(&rc).unwrap();
        assert_eq!(a.oracle_total.to_bits(), b.oracle_total.to_bits());
        assert_eq!(a.estimator.to_bits(), b.estimator.to_bits());
        assert_eq!(a.nominal.to_bits(), b.nominal.to_bits());
        assert_eq!(a.random.to_bits(), b.random.to_bits());
    }
}
