//! Learning-rate schedules, applied per round by the coordinator.
//!
//! The AOT artifacts take `lr` as a runtime scalar input, so schedules
//! are a pure L3 concern — no recompilation to change policy.

use anyhow::{bail, Result};
use std::str::FromStr;

/// Per-round learning-rate policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    /// The paper's setting: fixed lr for the whole run.
    Constant,
    /// Linear decay from lr to `floor * lr` across `horizon` rounds.
    Linear { horizon: usize, floor: f32 },
    /// Cosine decay to `floor * lr` across `horizon` rounds.
    Cosine { horizon: usize, floor: f32 },
    /// Linear warmup over `warmup` rounds, then constant.
    Warmup { warmup: usize },
}

impl LrSchedule {
    /// Learning rate for 1-based `round`.
    pub fn at(&self, base_lr: f32, round: usize) -> f32 {
        match *self {
            LrSchedule::Constant => base_lr,
            LrSchedule::Linear { horizon, floor } => {
                let t = ((round - 1) as f32 / horizon.max(1) as f32).min(1.0);
                base_lr * (1.0 - t * (1.0 - floor))
            }
            LrSchedule::Cosine { horizon, floor } => {
                let t = ((round - 1) as f32 / horizon.max(1) as f32).min(1.0);
                let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
                base_lr * (floor + (1.0 - floor) * cos)
            }
            LrSchedule::Warmup { warmup } => {
                if round <= warmup {
                    base_lr * round as f32 / warmup.max(1) as f32
                } else {
                    base_lr
                }
            }
        }
    }
}

impl std::fmt::Display for LrSchedule {
    /// Emits the same form [`FromStr`] parses, so configs round-trip
    /// through `to_kv`/`from_kv_file`.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            LrSchedule::Constant => write!(f, "constant"),
            LrSchedule::Linear { horizon, floor } => write!(f, "linear:{horizon}:{floor}"),
            LrSchedule::Cosine { horizon, floor } => write!(f, "cosine:{horizon}:{floor}"),
            LrSchedule::Warmup { warmup } => write!(f, "warmup:{warmup}"),
        }
    }
}

impl FromStr for LrSchedule {
    type Err = anyhow::Error;

    /// Formats: `constant`, `linear:HORIZON[:FLOOR]`,
    /// `cosine:HORIZON[:FLOOR]`, `warmup:ROUNDS`.
    fn from_str(s: &str) -> Result<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts[0] {
            "constant" => Ok(LrSchedule::Constant),
            "linear" | "cosine" => {
                if parts.len() < 2 {
                    bail!("{} needs a horizon, e.g. {}:100", parts[0], parts[0]);
                }
                let horizon: usize = parts[1].parse()?;
                let floor: f32 =
                    if parts.len() > 2 { parts[2].parse()? } else { 0.1 };
                if !(0.0..=1.0).contains(&floor) {
                    bail!("floor must be in [0,1], got {floor}");
                }
                if parts[0] == "linear" {
                    Ok(LrSchedule::Linear { horizon, floor })
                } else {
                    Ok(LrSchedule::Cosine { horizon, floor })
                }
            }
            "warmup" => {
                if parts.len() < 2 {
                    bail!("warmup needs a round count, e.g. warmup:10");
                }
                Ok(LrSchedule::Warmup { warmup: parts[1].parse()? })
            }
            other => bail!("unknown lr schedule {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::Constant;
        assert_eq!(s.at(0.01, 1), 0.01);
        assert_eq!(s.at(0.01, 1000), 0.01);
    }

    #[test]
    fn linear_decays_to_floor() {
        let s = LrSchedule::Linear { horizon: 10, floor: 0.1 };
        assert_eq!(s.at(1.0, 1), 1.0);
        assert!((s.at(1.0, 11) - 0.1).abs() < 1e-6);
        assert!((s.at(1.0, 100) - 0.1).abs() < 1e-6); // clamped
        assert!(s.at(1.0, 3) > s.at(1.0, 7));
    }

    #[test]
    fn cosine_monotone_within_horizon() {
        let s = LrSchedule::Cosine { horizon: 20, floor: 0.0 };
        assert!((s.at(1.0, 1) - 1.0).abs() < 1e-6);
        let mut prev = f32::INFINITY;
        for round in 1..=21 {
            let lr = s.at(1.0, round);
            assert!(lr <= prev + 1e-6);
            prev = lr;
        }
        assert!(s.at(1.0, 21) < 1e-6);
    }

    #[test]
    fn warmup_ramps_then_holds() {
        let s = LrSchedule::Warmup { warmup: 4 };
        assert!((s.at(0.8, 1) - 0.2).abs() < 1e-6);
        assert!((s.at(0.8, 4) - 0.8).abs() < 1e-6);
        assert!((s.at(0.8, 50) - 0.8).abs() < 1e-6);
    }

    #[test]
    fn display_round_trips_through_from_str() {
        for s in [
            LrSchedule::Constant,
            LrSchedule::Linear { horizon: 100, floor: 0.1 },
            LrSchedule::Cosine { horizon: 50, floor: 0.25 },
            LrSchedule::Warmup { warmup: 10 },
        ] {
            assert_eq!(s.to_string().parse::<LrSchedule>().unwrap(), s);
        }
    }

    #[test]
    fn parsing_all_forms() {
        assert_eq!("constant".parse::<LrSchedule>().unwrap(), LrSchedule::Constant);
        assert_eq!(
            "linear:100".parse::<LrSchedule>().unwrap(),
            LrSchedule::Linear { horizon: 100, floor: 0.1 }
        );
        assert_eq!(
            "cosine:50:0.2".parse::<LrSchedule>().unwrap(),
            LrSchedule::Cosine { horizon: 50, floor: 0.2 }
        );
        assert_eq!(
            "warmup:10".parse::<LrSchedule>().unwrap(),
            LrSchedule::Warmup { warmup: 10 }
        );
        assert!("linear".parse::<LrSchedule>().is_err());
        assert!("cosine:10:7.0".parse::<LrSchedule>().is_err());
        assert!("bogus".parse::<LrSchedule>().is_err());
    }
}
