//! Online per-client timing estimation (fleet-scale scheduling without
//! oracle inputs).
//!
//! The paper's Alg. 2 assumes the server knows every client's
//! `N_c^u / C_u` — reported device specs standing in for the client-side
//! backward tail.  Reported specs lie in the field (thermal throttling,
//! background load, mis-reported MFU), and related systems (Fed
//! MobiLLM, SplitFrozen) learn per-device timings online instead.  The
//! [`TimingEstimator`] does the same here: an EWMA per client over the
//! *observed* round timings (server time, client backward time, comm,
//! arrival), feeding the scheduler measured [`JobInfo`]s.
//!
//! Cold start falls back to the static eq. 10–12 model evaluated on
//! *nominal* device profiles (reported specs, class-default MFU) — the
//! caller passes that fallback job per client.  Once a client has been
//! observed, [`TimingEstimator::job_for`] returns its measured
//! estimates; the learned effective capability is encoded as
//! `Ĉ_u = N_c^u / (T̂_b + T̂_bc)` so Alg. 2's unchanged `N_c^u / C_u`
//! key equals the measured backward tail — no oracle timing input
//! remains in the schedule decision.

use super::scheduler::JobInfo;
use super::timing::StepTiming;
use anyhow::{bail, Result};

/// Default EWMA smoothing factor (weight of the newest observation).
pub const DEFAULT_EWMA_ALPHA: f64 = 0.25;

/// Adaptive-α floor: even a perfectly stable client keeps tracking.
const ADAPTIVE_ALPHA_MIN: f64 = 0.05;
/// Adaptive-α ceiling: even a wildly drifting client keeps smoothing.
const ADAPTIVE_ALPHA_MAX: f64 = 0.75;
/// EWMA factor of the residual-variance tracker itself.
const RESID_VAR_ALPHA: f64 = 0.1;
/// Relative-residual scale at which the adaptive α reaches 0.5.
const RESID_SCALE: f64 = 0.25;

/// Per-client exponentially weighted moving averages.
#[derive(Debug, Clone, Copy, Default)]
struct Ewma {
    arrival: f64,
    server: f64,
    bwd: f64,
    comm: f64,
    samples: u64,
}

/// Per-client EWMA timing model, indexed by global client id.
#[derive(Debug, Clone)]
pub struct TimingEstimator {
    // sflint:allow(checkpoint-coverage, EWMA weight is fixed at construction)
    alpha: f64,
    /// Winsorization factor: each observed channel is clamped into
    /// `[ewma/k, ewma·k]` before folding, so one absurd report (a
    /// timing-lying client, a clock glitch) moves the estimate by a
    /// bounded factor.  `INFINITY` (the default) disables the clamp.
    // sflint:allow(checkpoint-coverage, winsor factor is fixed at construction)
    winsor: f64,
    /// When set, α is derived per client from the EWMA of squared
    /// relative residuals (`resid_var`): persistently large residuals
    /// mean the average is lagging a drifting truth, so the factor
    /// rises toward [`ADAPTIVE_ALPHA_MAX`]; a stable client settles at
    /// [`ADAPTIVE_ALPHA_MIN`].  Off (the default) leaves the fixed-α
    /// arithmetic bit-identical.
    // sflint:allow(checkpoint-coverage, mode flag is fixed at construction)
    adaptive: bool,
    // sflint:allow(checkpoint-coverage, rides in the adaptive_state serializer pair)
    resid_var: Vec<f64>,
    stats: Vec<Ewma>,
}

impl TimingEstimator {
    /// `alpha` is the EWMA weight of the newest observation, in (0, 1].
    pub fn new(n_clients: usize, alpha: f64) -> Self {
        Self {
            alpha,
            winsor: f64::INFINITY,
            adaptive: false,
            resid_var: vec![0.0; n_clients],
            stats: vec![Ewma::default(); n_clients],
        }
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Switch to residual-variance-adaptive per-client α.
    pub fn set_adaptive(&mut self, on: bool) {
        self.adaptive = on;
    }

    pub fn is_adaptive(&self) -> bool {
        self.adaptive
    }

    /// Enable the winsorized observation clamp with factor `k > 1`
    /// (non-finite `k` leaves observations unclamped).
    pub fn set_winsor(&mut self, k: f64) {
        self.winsor = k;
    }

    pub fn n_clients(&self) -> usize {
        self.stats.len()
    }

    fn winsorize(&self, current: f64, sample: f64) -> f64 {
        // Seeding samples and zero-valued channels (e.g. a client with
        // no comm cost) pass through: a zero EWMA has no scale to clamp
        // against, and pinning it at zero forever would be worse than
        // accepting the report.
        if !self.winsor.is_finite() || current <= 0.0 {
            return sample;
        }
        sample.clamp(current / self.winsor, current * self.winsor)
    }

    /// Fold one round's observed timings for `client` into the EWMAs.
    /// The first observation seeds the averages directly.
    pub fn observe(&mut self, client: usize, t: &StepTiming) {
        let (arrival, server, bwd, comm) =
            (t.t_fwd + t.t_fwd_comm, t.t_server, t.t_bwd, t.t_bwd_comm);
        let e = self.stats[client];
        let e_new = if e.samples == 0 {
            Ewma { arrival, server, bwd, comm, samples: 1 }
        } else {
            // Winsorize first (the clamp applies identically on both α
            // paths), then pick the factor.  With `adaptive` off this
            // is the historical fixed-α arithmetic, bit-exactly.
            let wa = self.winsorize(e.arrival, arrival);
            let ws = self.winsorize(e.server, server);
            let wb = self.winsorize(e.bwd, bwd);
            let wc = self.winsorize(e.comm, comm);
            let a = if self.adaptive {
                // Mean relative residual over the four channels, on the
                // winsorized sample — what the EWMA is about to chase.
                let rel = |cur: f64, s: f64| if cur > 0.0 { ((s - cur) / cur).abs() } else { 0.0 };
                let rho =
                    0.25 * (rel(e.arrival, wa) + rel(e.server, ws) + rel(e.bwd, wb) + rel(e.comm, wc));
                let v = &mut self.resid_var[client];
                *v += RESID_VAR_ALPHA * (rho * rho - *v);
                let s = v.sqrt();
                (s / (s + RESID_SCALE)).clamp(ADAPTIVE_ALPHA_MIN, ADAPTIVE_ALPHA_MAX)
            } else {
                self.alpha
            };
            Ewma {
                arrival: e.arrival + a * (wa - e.arrival),
                server: e.server + a * (ws - e.server),
                bwd: e.bwd + a * (wb - e.bwd),
                comm: e.comm + a * (wc - e.comm),
                samples: e.samples + 1,
            }
        };
        self.stats[client] = e_new;
    }

    /// Whether `client` has at least one observation.
    pub fn is_warm(&self, client: usize) -> bool {
        self.stats[client].samples > 0
    }

    /// Number of clients with at least one observation.
    pub fn warm_clients(&self) -> usize {
        self.stats.iter().filter(|e| e.samples > 0).count()
    }

    /// The scheduler-facing job for one client: measured estimates when
    /// warm, the caller's static-model `fallback` when cold.  The
    /// fallback supplies the id and the (server-known) adapter count
    /// `N_c^u`; the capability is always re-encoded as
    /// `N_c^u / (T_b + T_bc)` — measured tail when warm, the static
    /// model's *predicted* tail when cold — so the greedy `N_c/C` key
    /// compares tail-seconds across every client of a mixed warm/cold
    /// cohort, and no reported-TFLOPS oracle input survives.
    pub fn job_for(&self, fallback: &JobInfo) -> JobInfo {
        let e = &self.stats[fallback.client];
        let (arrival, server, bwd, comm) = if e.samples == 0 {
            (
                fallback.arrival,
                fallback.server_time,
                fallback.client_bwd_time,
                fallback.bwd_comm_time,
            )
        } else {
            (e.arrival, e.server, e.bwd, e.comm)
        };
        JobInfo {
            client: fallback.client,
            arrival,
            server_time: server,
            client_bwd_time: bwd,
            bwd_comm_time: comm,
            n_client_adapters: fallback.n_client_adapters,
            compute_capability: fallback.n_client_adapters as f64 / (bwd + comm).max(1e-12),
        }
    }

    /// Gather scheduler-facing jobs for a participant set into a reused
    /// buffer (no allocation at steady state).
    pub fn jobs_into(&self, fallbacks: &[JobInfo], out: &mut Vec<JobInfo>) {
        out.clear();
        out.extend(fallbacks.iter().map(|f| self.job_for(f)));
    }

    /// Flat state for checkpointing: 4 EWMAs per client + sample counts.
    pub fn state(&self) -> (Vec<f64>, Vec<u64>) {
        let mut values = Vec::with_capacity(self.stats.len() * 4);
        let mut samples = Vec::with_capacity(self.stats.len());
        for e in &self.stats {
            values.extend_from_slice(&[e.arrival, e.server, e.bwd, e.comm]);
            samples.push(e.samples);
        }
        (values, samples)
    }

    /// Restore from [`TimingEstimator::state`] (bit-exact resume).
    pub fn restore_state(&mut self, values: &[f64], samples: &[u64]) -> Result<()> {
        let n = self.stats.len();
        if values.len() != n * 4 || samples.len() != n {
            bail!(
                "estimator state has {}/{} entries, expected {}/{}",
                values.len(),
                samples.len(),
                n * 4,
                n
            );
        }
        for (u, e) in self.stats.iter_mut().enumerate() {
            e.arrival = values[u * 4];
            e.server = values[u * 4 + 1];
            e.bwd = values[u * 4 + 2];
            e.comm = values[u * 4 + 3];
            e.samples = samples[u];
        }
        Ok(())
    }

    /// Residual-variance tracker state — checkpointed only when the
    /// adaptive mode is on (the fixed path never touches it).
    pub fn adaptive_state(&self) -> Vec<f64> {
        self.resid_var.clone()
    }

    /// Restore [`TimingEstimator::adaptive_state`] (bit-exact resume).
    pub fn restore_adaptive_state(&mut self, values: &[f64]) -> Result<()> {
        if values.len() != self.resid_var.len() {
            bail!(
                "adaptive estimator state has {} entries, fleet needs {}",
                values.len(),
                self.resid_var.len()
            );
        }
        self.resid_var.copy_from_slice(values);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(client: usize, arrival: f64, server: f64, bwd: f64, comm: f64) -> JobInfo {
        JobInfo {
            client,
            arrival,
            server_time: server,
            client_bwd_time: bwd,
            bwd_comm_time: comm,
            n_client_adapters: 4,
            compute_capability: 2.0,
        }
    }

    #[test]
    fn cold_clients_fall_back_to_the_static_model() {
        let est = TimingEstimator::new(3, DEFAULT_EWMA_ALPHA);
        let fb = job(1, 0.7, 0.3, 2.0, 0.1);
        let j = est.job_for(&fb);
        assert!(!est.is_warm(1));
        assert!((j.arrival - fb.arrival).abs() < 1e-15);
        assert!((j.server_time - fb.server_time).abs() < 1e-15);
        assert!((j.client_bwd_time - fb.client_bwd_time).abs() < 1e-15);
        // The cold key is the static model's *predicted* tail in
        // seconds — commensurable with warm clients' measured tails,
        // never the raw reported-TFLOPS proxy.
        assert!((j.greedy_priority() - 2.1).abs() < 1e-12);
    }

    #[test]
    fn mixed_warm_cold_cohorts_sort_on_commensurable_keys() {
        // A warm client with a long measured tail must outrank a cold
        // client with a short predicted tail — the failure mode of
        // passing the raw fallback through (N/TFLOPS vs seconds).
        let mut est = TimingEstimator::new(2, 0.25);
        est.observe(0, &StepTiming::from_job(&job(0, 0.5, 0.4, 5.0, 0.2)));
        let warm = est.job_for(&job(0, 0.5, 0.4, 1.0, 0.1));
        let cold = est.job_for(&job(1, 0.5, 0.4, 0.8, 0.1));
        assert!((warm.greedy_priority() - 5.2).abs() < 1e-12);
        assert!((cold.greedy_priority() - 0.9).abs() < 1e-12);
        assert!(warm.greedy_priority() > cold.greedy_priority());
    }

    #[test]
    fn converges_to_stationary_timings_and_encodes_the_tail() {
        // Stationary fleet: constant observations. The first sample
        // seeds the EWMA, so the estimate is exact from round one and
        // stays exact — `job_for` must reproduce the observed job with
        // the measured tail as its greedy key.
        let truth = job(0, 0.9, 0.4, 3.0, 0.2);
        let nominal = job(0, 0.5, 0.4, 1.0, 0.1); // mis-reported specs
        let mut est = TimingEstimator::new(1, 0.25);
        for _ in 0..8 {
            est.observe(0, &StepTiming::from_job(&truth));
        }
        let j = est.job_for(&nominal);
        assert!((j.arrival - truth.arrival).abs() < 1e-12);
        assert!((j.server_time - truth.server_time).abs() < 1e-12);
        assert!((j.client_bwd_time - truth.client_bwd_time).abs() < 1e-12);
        assert!((j.bwd_comm_time - truth.bwd_comm_time).abs() < 1e-12);
        // Alg. 2's unchanged N/C key now equals the measured tail.
        let tail = truth.client_bwd_time + truth.bwd_comm_time;
        assert!((j.greedy_priority() - tail).abs() < 1e-12);
    }

    #[test]
    fn ewma_tracks_a_shift_in_device_speed() {
        let mut est = TimingEstimator::new(1, 0.5);
        est.observe(0, &StepTiming::from_job(&job(0, 0.5, 0.4, 2.0, 0.1)));
        // Device throttles: backward doubles. EWMA must move toward it.
        for _ in 0..16 {
            est.observe(0, &StepTiming::from_job(&job(0, 0.5, 0.4, 4.0, 0.1)));
        }
        let j = est.job_for(&job(0, 0.0, 0.0, 0.0, 0.0));
        assert!((j.client_bwd_time - 4.0).abs() < 1e-3, "got {}", j.client_bwd_time);
    }

    #[test]
    fn winsor_clamp_bounds_a_thousand_fold_outlier() {
        let (alpha, k) = (0.25, 4.0);
        let seed = job(0, 0.5, 0.4, 2.0, 0.1);
        let outlier = job(0, 500.0, 400.0, 2000.0, 100.0); // 1000× lie
        let mut clamped = TimingEstimator::new(1, alpha);
        clamped.set_winsor(k);
        clamped.observe(0, &StepTiming::from_job(&seed));
        clamped.observe(0, &StepTiming::from_job(&outlier));
        let j = clamped.job_for(&job(0, 0.0, 0.0, 0.0, 0.0));
        // Each channel's sample is clamped to k×EWMA, so the post-update
        // estimate is exactly (1 + α(k−1))×old = 1.75×old — and never
        // more than the clamp bound k×old.
        for (got, old) in [
            (j.arrival, seed.arrival),
            (j.server_time, seed.server_time),
            (j.client_bwd_time, seed.client_bwd_time),
            (j.bwd_comm_time, seed.bwd_comm_time),
        ] {
            assert!((got - 1.75 * old).abs() < 1e-9, "got {got}, old {old}");
            assert!(got <= k * old, "estimate moved past the clamp bound");
        }
        // The same outlier with the clamp off poisons the estimate.
        let mut open = TimingEstimator::new(1, alpha);
        open.observe(0, &StepTiming::from_job(&seed));
        open.observe(0, &StepTiming::from_job(&outlier));
        let p = open.job_for(&job(0, 0.0, 0.0, 0.0, 0.0));
        assert!(p.client_bwd_time > 100.0 * seed.client_bwd_time);
    }

    #[test]
    fn adaptive_alpha_is_off_by_default_and_matches_the_fixed_path() {
        // Same observation stream through a fixed-α estimator and a
        // default-constructed one: bit-identical estimates (the adaptive
        // branch must never engage unless switched on).
        let mut fixed = TimingEstimator::new(1, 0.25);
        let mut def = TimingEstimator::new(1, 0.25);
        assert!(!def.is_adaptive());
        for i in 0..6 {
            let j = job(0, 0.5 + 0.1 * i as f64, 0.4, 2.0 + i as f64, 0.1);
            fixed.observe(0, &StepTiming::from_job(&j));
            def.observe(0, &StepTiming::from_job(&j));
        }
        let (a, b) = (fixed.state().0, def.state().0);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn adaptive_alpha_settles_low_when_stable_and_tracks_drift_faster() {
        // Stable client: zero residuals keep the variance at zero, so
        // α pins to the floor and the estimate equals the truth.
        let mut est = TimingEstimator::new(1, 0.25);
        est.set_adaptive(true);
        let truth = job(0, 0.5, 0.4, 2.0, 0.1);
        for _ in 0..10 {
            est.observe(0, &StepTiming::from_job(&truth));
        }
        assert!((est.job_for(&truth).client_bwd_time - 2.0).abs() < 1e-12);

        // Drifting client: a sluggish fixed α lags a 3× throttle; the
        // adaptive factor sees persistent residuals and closes the gap
        // faster over the same number of observations.
        let slow_alpha = 0.05;
        let mut fixed = TimingEstimator::new(1, slow_alpha);
        let mut adap = TimingEstimator::new(1, slow_alpha);
        adap.set_adaptive(true);
        let before = job(0, 0.5, 0.4, 2.0, 0.1);
        let after = job(0, 1.5, 1.2, 6.0, 0.3);
        fixed.observe(0, &StepTiming::from_job(&before));
        adap.observe(0, &StepTiming::from_job(&before));
        for _ in 0..8 {
            fixed.observe(0, &StepTiming::from_job(&after));
            adap.observe(0, &StepTiming::from_job(&after));
        }
        let fb = job(0, 0.0, 0.0, 0.0, 0.0);
        let gap_fixed = (fixed.job_for(&fb).client_bwd_time - 6.0).abs();
        let gap_adap = (adap.job_for(&fb).client_bwd_time - 6.0).abs();
        assert!(
            gap_adap < gap_fixed,
            "adaptive gap {gap_adap} must beat fixed gap {gap_fixed}"
        );
    }

    #[test]
    fn adaptive_state_roundtrips() {
        let mut est = TimingEstimator::new(2, 0.25);
        est.set_adaptive(true);
        est.observe(1, &StepTiming::from_job(&job(1, 0.5, 0.4, 2.0, 0.1)));
        est.observe(1, &StepTiming::from_job(&job(1, 1.0, 0.8, 4.0, 0.2)));
        let (values, samples) = est.state();
        let resid = est.adaptive_state();
        assert!(resid[1] > 0.0, "drift must have registered residual variance");
        let mut back = TimingEstimator::new(2, 0.25);
        back.set_adaptive(true);
        back.restore_state(&values, &samples).unwrap();
        back.restore_adaptive_state(&resid).unwrap();
        // One more identical observation on both: bit-identical fold.
        let next = job(1, 1.2, 0.9, 5.0, 0.25);
        est.observe(1, &StepTiming::from_job(&next));
        back.observe(1, &StepTiming::from_job(&next));
        for (x, y) in est.state().0.iter().zip(back.state().0.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert!(back.restore_adaptive_state(&resid[..1]).is_err());
    }

    #[test]
    fn state_roundtrip_is_exact() {
        let mut est = TimingEstimator::new(2, 0.3);
        est.observe(1, &StepTiming::from_job(&job(1, 0.7, 0.3, 2.0, 0.1)));
        est.observe(1, &StepTiming::from_job(&job(1, 0.9, 0.5, 2.5, 0.2)));
        let (values, samples) = est.state();
        let mut back = TimingEstimator::new(2, 0.3);
        back.restore_state(&values, &samples).unwrap();
        let fb = job(1, 0.0, 0.0, 0.0, 0.0);
        let (a, b) = (est.job_for(&fb), back.job_for(&fb));
        assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        assert_eq!(a.server_time.to_bits(), b.server_time.to_bits());
        assert_eq!(a.client_bwd_time.to_bits(), b.client_bwd_time.to_bits());
        assert_eq!(a.bwd_comm_time.to_bits(), b.bwd_comm_time.to_bits());
        assert!(!back.is_warm(0) && back.is_warm(1));
        assert!(back.restore_state(&values[1..], &samples).is_err());
    }
}
