//! Server-side training-order scheduling (paper §IV, Alg. 2) + baselines.
//!
//! The server trains the per-client server-side LoRA adapters
//! *sequentially*; the processing order decides how much client-side
//! backward time and communication hide under server compute (eq. 13).
//! Alg. 2's greedy rule: process clients in **descending N_c^u / C_u**
//! — clients whose own backward pass is longest go first, so their
//! backprop overlaps the server's remaining queue.

use crate::config::SchedulerKind;
use crate::tensor::rng::Rng;

/// Everything a policy may inspect about one client's pending job.
#[derive(Debug, Clone, Copy)]
pub struct JobInfo {
    pub client: usize,
    /// Virtual time the activations arrive at the server (T^f + T^fc).
    pub arrival: f64,
    /// Server-side compute time for this client, T_u^s.
    pub server_time: f64,
    /// Client-side backward time, T_u^b.
    pub client_bwd_time: f64,
    /// Gradient downlink time, T_u^bc.
    pub bwd_comm_time: f64,
    /// N_c^u — number of client-side LoRA adapters.
    pub n_client_adapters: usize,
    /// C_u — client computing capability (TFLOPS).
    pub compute_capability: f64,
}

/// A training-order policy. Must return a permutation of the job indices.
pub trait Scheduler {
    fn name(&self) -> &'static str;
    /// Return client ids in server processing order.
    fn order(&mut self, jobs: &[JobInfo]) -> Vec<usize>;
    /// Internal RNG state, if the policy is stateful (checkpoint/resume).
    fn rng_state(&self) -> Option<u64> {
        None
    }
    /// Restore a stateful policy's RNG from [`Scheduler::rng_state`].
    fn set_rng_state(&mut self, _state: u64) {}
}

/// Alg. 2 — sort descending by N_c^u / C_u (longest client backward
/// first). Ties break by client id for determinism.
pub struct ProposedScheduler;

impl Scheduler for ProposedScheduler {
    fn name(&self) -> &'static str {
        "proposed"
    }

    fn order(&mut self, jobs: &[JobInfo]) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..jobs.len()).collect();
        idx.sort_by(|&a, &b| {
            let ka = jobs[a].n_client_adapters as f64 / jobs[a].compute_capability;
            let kb = jobs[b].n_client_adapters as f64 / jobs[b].compute_capability;
            kb.partial_cmp(&ka)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(jobs[a].client.cmp(&jobs[b].client))
        });
        idx.into_iter().map(|i| jobs[i].client).collect()
    }
}

/// FIFO — by activation arrival time (baseline [19]).
pub struct FifoScheduler;

impl Scheduler for FifoScheduler {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn order(&mut self, jobs: &[JobInfo]) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..jobs.len()).collect();
        idx.sort_by(|&a, &b| {
            jobs[a]
                .arrival
                .partial_cmp(&jobs[b].arrival)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(jobs[a].client.cmp(&jobs[b].client))
        });
        idx.into_iter().map(|i| jobs[i].client).collect()
    }
}

/// Workload-first — largest server-side workload first (baseline [6]).
pub struct WorkloadFirstScheduler;

impl Scheduler for WorkloadFirstScheduler {
    fn name(&self) -> &'static str {
        "workload_first"
    }

    fn order(&mut self, jobs: &[JobInfo]) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..jobs.len()).collect();
        idx.sort_by(|&a, &b| {
            jobs[b]
                .server_time
                .partial_cmp(&jobs[a].server_time)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(jobs[a].client.cmp(&jobs[b].client))
        });
        idx.into_iter().map(|i| jobs[i].client).collect()
    }
}

/// Seeded random order (control for the ablation bench).
pub struct RandomScheduler {
    rng: Rng,
}

impl RandomScheduler {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed) }
    }
}

impl Scheduler for RandomScheduler {
    fn name(&self) -> &'static str {
        "random"
    }

    fn order(&mut self, jobs: &[JobInfo]) -> Vec<usize> {
        let mut ids: Vec<usize> = jobs.iter().map(|j| j.client).collect();
        for i in (1..ids.len()).rev() {
            let j = self.rng.below(i + 1);
            ids.swap(i, j);
        }
        ids
    }

    fn rng_state(&self) -> Option<u64> {
        Some(self.rng.state())
    }

    fn set_rng_state(&mut self, state: u64) {
        self.rng = Rng::from_state(state);
    }
}

/// Factory from the config enum.
pub fn make_scheduler(kind: SchedulerKind, seed: u64) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Proposed => Box::new(ProposedScheduler),
        SchedulerKind::Fifo => Box::new(FifoScheduler),
        SchedulerKind::WorkloadFirst => Box::new(WorkloadFirstScheduler),
        SchedulerKind::Random => Box::new(RandomScheduler::new(seed)),
    }
}

/// Makespan of a schedule under the paper's timing model (eqs. 10–12):
/// sequential server, per-client completion = server finish + downlink +
/// client backward. Used by tests and the brute-force optimality check.
pub fn makespan(jobs: &[JobInfo], order: &[usize]) -> f64 {
    let by_client: std::collections::HashMap<usize, &JobInfo> =
        jobs.iter().map(|j| (j.client, j)).collect();
    let mut horizon = 0.0f64;
    let mut worst = 0.0f64;
    for &c in order {
        let j = by_client[&c];
        let start = horizon.max(j.arrival);
        let finish = start + j.server_time;
        horizon = finish;
        worst = worst.max(finish + j.bwd_comm_time + j.client_bwd_time);
    }
    worst
}

/// Exhaustive minimum makespan (small fleets only — tests).
pub fn brute_force_best(jobs: &[JobInfo]) -> (Vec<usize>, f64) {
    fn permute(ids: &mut Vec<usize>, k: usize, jobs: &[JobInfo], best: &mut (Vec<usize>, f64)) {
        if k == ids.len() {
            let m = makespan(jobs, ids);
            if m < best.1 {
                *best = (ids.clone(), m);
            }
            return;
        }
        for i in k..ids.len() {
            ids.swap(k, i);
            permute(ids, k + 1, jobs, best);
            ids.swap(k, i);
        }
    }
    let mut ids: Vec<usize> = jobs.iter().map(|j| j.client).collect();
    let mut best = (ids.clone(), f64::INFINITY);
    permute(&mut ids, 0, jobs, &mut best);
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(client: usize, nc: usize, cap: f64, ts: f64, tb: f64) -> JobInfo {
        JobInfo {
            client,
            arrival: 0.0,
            server_time: ts,
            client_bwd_time: tb,
            bwd_comm_time: 0.01,
            n_client_adapters: nc,
            compute_capability: cap,
        }
    }

    #[test]
    fn proposed_orders_by_nc_over_c_descending() {
        // Paper fleet ratios: Nano 1/0.472, TX2 1/1.33, SD8s 2/1.689,
        // SD8 2/2.774, A17 3/2.147, M3 3/3.533.
        let jobs = vec![
            job(0, 1, 0.472, 1.0, 5.0),
            job(1, 1, 1.33, 1.0, 2.0),
            job(2, 2, 1.689, 1.0, 3.0),
            job(3, 2, 2.774, 1.0, 1.5),
            job(4, 3, 2.147, 1.0, 4.0),
            job(5, 3, 3.533, 1.0, 2.5),
        ];
        let order = ProposedScheduler.order(&jobs);
        // ratios: 2.12, 0.75, 1.18, 0.72, 1.40, 0.85
        assert_eq!(order, vec![0, 4, 2, 5, 1, 3]);
    }

    #[test]
    fn fifo_orders_by_arrival() {
        let mut jobs = vec![job(0, 1, 1.0, 1.0, 1.0), job(1, 1, 1.0, 1.0, 1.0)];
        jobs[0].arrival = 5.0;
        jobs[1].arrival = 2.0;
        assert_eq!(FifoScheduler.order(&jobs), vec![1, 0]);
    }

    #[test]
    fn workload_first_orders_by_server_time() {
        let jobs = vec![job(0, 1, 1.0, 2.0, 1.0), job(1, 1, 1.0, 9.0, 1.0)];
        assert_eq!(WorkloadFirstScheduler.order(&jobs), vec![1, 0]);
    }

    #[test]
    fn all_schedulers_emit_permutations() {
        let jobs: Vec<JobInfo> =
            (0..6).map(|i| job(i, 1 + i % 3, 1.0 + i as f64, 1.0, 1.0)).collect();
        for mut s in [
            Box::new(ProposedScheduler) as Box<dyn Scheduler>,
            Box::new(FifoScheduler),
            Box::new(WorkloadFirstScheduler),
            Box::new(RandomScheduler::new(1)),
        ] {
            let mut order = s.order(&jobs);
            order.sort_unstable();
            assert_eq!(order, (0..6).collect::<Vec<_>>(), "{}", s.name());
        }
    }

    #[test]
    fn makespan_matches_hand_computation() {
        // Two clients arriving at 0: first runs [0,2], second [2,5].
        // Completions: 2 + 0.01 + tb0, 5 + 0.01 + tb1.
        let jobs = vec![job(0, 1, 1.0, 2.0, 4.0), job(1, 1, 1.0, 3.0, 0.5)];
        let m = makespan(&jobs, &[0, 1]);
        assert!((m - f64::max(2.0 + 0.01 + 4.0, 5.0 + 0.01 + 0.5)).abs() < 1e-12);
    }

    #[test]
    fn long_backward_first_beats_long_backward_last() {
        // The intuition behind Alg. 2: the slow-backprop client must go
        // first so its backward hides under the others' server time.
        let jobs = vec![job(0, 3, 0.3, 1.0, 10.0), job(1, 1, 3.0, 1.0, 0.1)];
        let slow_first = makespan(&jobs, &[0, 1]);
        let slow_last = makespan(&jobs, &[1, 0]);
        assert!(slow_first < slow_last);
        // And Alg. 2 picks the better one.
        let order = ProposedScheduler.order(&jobs);
        assert_eq!(order, vec![0, 1]);
    }

    #[test]
    fn proposed_matches_brute_force_when_server_times_equal() {
        // With equal server times and equal arrivals, scheduling is the
        // classic "longest tail first" problem where the greedy rule is
        // optimal; N_c/C is the paper's proxy for the tail length.
        let jobs = vec![
            job(0, 1, 0.5, 2.0, 1.0 / 0.5),
            job(1, 2, 1.0, 2.0, 2.0 / 1.0),
            job(2, 3, 0.6, 2.0, 3.0 / 0.6),
            job(3, 1, 2.0, 2.0, 1.0 / 2.0),
        ];
        let order = ProposedScheduler.order(&jobs);
        let (best, best_m) = brute_force_best(&jobs);
        let m = makespan(&jobs, &order);
        assert!(
            (m - best_m).abs() < 1e-9,
            "greedy {m} vs optimal {best_m} ({order:?} vs {best:?})"
        );
    }
}
