//! Server-side training-order scheduling (paper §IV, Alg. 2) + baselines.
//!
//! The server trains the per-client server-side LoRA adapters
//! *sequentially*; the processing order decides how much client-side
//! backward time and communication hide under server compute (eq. 13).
//! Alg. 2's greedy rule: process clients in **descending N_c^u / C_u**
//! — clients whose own backward pass is longest go first, so their
//! backprop overlaps the server's remaining queue.
//!
//! ## The order contract
//!
//! Every policy returns **job indices** (positions into the `jobs`
//! slice), never `JobInfo::client` labels.  `client` is a *global id*
//! carried along for telemetry and the timing estimator; on dropout
//! rounds it is non-contiguous (survivor ids), so indexing anything by
//! it is a bug.  All consumers (`timing::ours_step_ordered`,
//! `makespan`, the session's training loop) index jobs/timings with the
//! returned positions and read `jobs[i].client` only as a label.
//!
//! The hot entry point is [`Scheduler::order_into`]: it fills a caller
//! owned buffer and sorts in place (`sort_unstable_by`), so at steady
//! state the schedule path performs zero heap allocations and runs in
//! O(n log n) — fleet-scale rounds (10k–100k jobs) schedule without
//! touching the allocator (see `benches/sched_scale.rs`).

use crate::config::SchedulerKind;
use crate::tensor::rng::Rng;

/// Everything a policy may inspect about one client's pending job.
#[derive(Debug, Clone, Copy)]
pub struct JobInfo {
    /// Global client id — a *label*, not an index into the job slice.
    pub client: usize,
    /// Virtual time the activations arrive at the server (T^f + T^fc).
    pub arrival: f64,
    /// Server-side compute time for this client, T_u^s.
    pub server_time: f64,
    /// Client-side backward time, T_u^b.
    pub client_bwd_time: f64,
    /// Gradient downlink time, T_u^bc.
    pub bwd_comm_time: f64,
    /// N_c^u — number of client-side LoRA adapters.
    pub n_client_adapters: usize,
    /// C_u — client computing capability (adapters the client works
    /// through per unit tail time).  Oracle jobs carry the reported
    /// device TFLOPS; estimator-built jobs carry the *learned* effective
    /// capability N_c^u / (T̂_b + T̂_bc), so Alg. 2 needs no oracle input.
    pub compute_capability: f64,
}

impl JobInfo {
    /// Alg. 2's greedy key, N_c^u / C_u — the (proxied or measured)
    /// client-side tail the server tries to hide under its own queue.
    pub fn greedy_priority(&self) -> f64 {
        self.n_client_adapters as f64 / self.compute_capability
    }
}

/// Reset `out` to the identity permutation 0..n without reallocating
/// once its capacity has grown to n.
fn fill_identity(out: &mut Vec<usize>, n: usize) {
    out.clear();
    out.extend(0..n);
}

/// A training-order policy. Must emit a permutation of the job indices.
pub trait Scheduler {
    fn name(&self) -> &'static str;
    /// Fill `out` with the job *indices* (positions in `jobs`, not
    /// `JobInfo::client` ids) in server processing order.  Reuses the
    /// buffer — no allocation at steady state.
    fn order_into(&mut self, jobs: &[JobInfo], out: &mut Vec<usize>);
    /// Allocating convenience wrapper around [`Scheduler::order_into`].
    fn order(&mut self, jobs: &[JobInfo]) -> Vec<usize> {
        let mut out = Vec::with_capacity(jobs.len());
        self.order_into(jobs, &mut out);
        out
    }
    /// Internal RNG state, if the policy is stateful (checkpoint/resume).
    fn rng_state(&self) -> Option<u64> {
        None
    }
    /// Restore a stateful policy's RNG from [`Scheduler::rng_state`].
    fn set_rng_state(&mut self, _state: u64) {}
}

/// Alg. 2 — sort descending by N_c^u / C_u (longest client backward
/// first). Ties break by client id for determinism.
pub struct ProposedScheduler;

impl Scheduler for ProposedScheduler {
    fn name(&self) -> &'static str {
        "proposed"
    }

    fn order_into(&mut self, jobs: &[JobInfo], out: &mut Vec<usize>) {
        fill_identity(out, jobs.len());
        out.sort_unstable_by(|&a, &b| {
            let (ka, kb) = (jobs[a].greedy_priority(), jobs[b].greedy_priority());
            kb.total_cmp(&ka).then(jobs[a].client.cmp(&jobs[b].client))
        });
    }
}

/// FIFO — by activation arrival time (baseline [19]).
pub struct FifoScheduler;

impl Scheduler for FifoScheduler {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn order_into(&mut self, jobs: &[JobInfo], out: &mut Vec<usize>) {
        fill_identity(out, jobs.len());
        out.sort_unstable_by(|&a, &b| {
            jobs[a].arrival.total_cmp(&jobs[b].arrival).then(jobs[a].client.cmp(&jobs[b].client))
        });
    }
}

/// Workload-first — largest server-side workload first (baseline [6]).
pub struct WorkloadFirstScheduler;

impl Scheduler for WorkloadFirstScheduler {
    fn name(&self) -> &'static str {
        "workload_first"
    }

    fn order_into(&mut self, jobs: &[JobInfo], out: &mut Vec<usize>) {
        fill_identity(out, jobs.len());
        out.sort_unstable_by(|&a, &b| {
            jobs[b]
                .server_time
                .total_cmp(&jobs[a].server_time)
                .then(jobs[a].client.cmp(&jobs[b].client))
        });
    }
}

/// Seeded random order (control for the ablation bench).
pub struct RandomScheduler {
    rng: Rng,
}

impl RandomScheduler {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed) }
    }
}

impl Scheduler for RandomScheduler {
    fn name(&self) -> &'static str {
        "random"
    }

    fn order_into(&mut self, jobs: &[JobInfo], out: &mut Vec<usize>) {
        fill_identity(out, jobs.len());
        for i in (1..out.len()).rev() {
            let j = self.rng.below(i + 1);
            out.swap(i, j);
        }
    }

    fn rng_state(&self) -> Option<u64> {
        Some(self.rng.state())
    }

    fn set_rng_state(&mut self, state: u64) {
        self.rng = Rng::from_state(state);
    }
}

/// Factory from the config enum.
pub fn make_scheduler(kind: SchedulerKind, seed: u64) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Proposed => Box::new(ProposedScheduler),
        SchedulerKind::Fifo => Box::new(FifoScheduler),
        SchedulerKind::WorkloadFirst => Box::new(WorkloadFirstScheduler),
        SchedulerKind::Random => Box::new(RandomScheduler::new(seed)),
    }
}

/// Makespan of a schedule under the paper's timing model (eqs. 10–12):
/// sequential server, per-client completion = server finish + downlink +
/// client backward.  `order` holds job indices (the scheduler contract);
/// the walk is a straight slice scan — no per-call map, no allocation.
pub fn makespan(jobs: &[JobInfo], order: &[usize]) -> f64 {
    debug_assert_eq!(order.len(), jobs.len());
    let mut horizon = 0.0f64;
    let mut worst = 0.0f64;
    for &i in order {
        let j = &jobs[i];
        let start = horizon.max(j.arrival);
        let finish = start + j.server_time;
        horizon = finish;
        worst = worst.max(finish + j.bwd_comm_time + j.client_bwd_time);
    }
    worst
}

/// Exhaustive minimum makespan over job-index permutations (small
/// fleets only — tests).
pub fn brute_force_best(jobs: &[JobInfo]) -> (Vec<usize>, f64) {
    fn permute(idx: &mut Vec<usize>, k: usize, jobs: &[JobInfo], best: &mut (Vec<usize>, f64)) {
        if k == idx.len() {
            let m = makespan(jobs, idx);
            if m < best.1 {
                *best = (idx.clone(), m);
            }
            return;
        }
        for i in k..idx.len() {
            idx.swap(k, i);
            permute(idx, k + 1, jobs, best);
            idx.swap(k, i);
        }
    }
    let mut idx: Vec<usize> = (0..jobs.len()).collect();
    let mut best = (idx.clone(), f64::INFINITY);
    permute(&mut idx, 0, jobs, &mut best);
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(client: usize, nc: usize, cap: f64, ts: f64, tb: f64) -> JobInfo {
        JobInfo {
            client,
            arrival: 0.0,
            server_time: ts,
            client_bwd_time: tb,
            bwd_comm_time: 0.01,
            n_client_adapters: nc,
            compute_capability: cap,
        }
    }

    #[test]
    fn proposed_orders_by_nc_over_c_descending() {
        // Paper fleet ratios: Nano 1/0.472, TX2 1/1.33, SD8s 2/1.689,
        // SD8 2/2.774, A17 3/2.147, M3 3/3.533.
        let jobs = vec![
            job(0, 1, 0.472, 1.0, 5.0),
            job(1, 1, 1.33, 1.0, 2.0),
            job(2, 2, 1.689, 1.0, 3.0),
            job(3, 2, 2.774, 1.0, 1.5),
            job(4, 3, 2.147, 1.0, 4.0),
            job(5, 3, 3.533, 1.0, 2.5),
        ];
        let order = ProposedScheduler.order(&jobs);
        // ratios: 2.12, 0.75, 1.18, 0.72, 1.40, 0.85
        assert_eq!(order, vec![0, 4, 2, 5, 1, 3]);
    }

    #[test]
    fn fifo_orders_by_arrival() {
        let mut jobs = vec![job(0, 1, 1.0, 1.0, 1.0), job(1, 1, 1.0, 1.0, 1.0)];
        jobs[0].arrival = 5.0;
        jobs[1].arrival = 2.0;
        assert_eq!(FifoScheduler.order(&jobs), vec![1, 0]);
    }

    #[test]
    fn workload_first_orders_by_server_time() {
        let jobs = vec![job(0, 1, 1.0, 2.0, 1.0), job(1, 1, 1.0, 9.0, 1.0)];
        assert_eq!(WorkloadFirstScheduler.order(&jobs), vec![1, 0]);
    }

    #[test]
    fn all_schedulers_emit_permutations() {
        let jobs: Vec<JobInfo> =
            (0..6).map(|i| job(i, 1 + i % 3, 1.0 + i as f64, 1.0, 1.0)).collect();
        for mut s in [
            Box::new(ProposedScheduler) as Box<dyn Scheduler>,
            Box::new(FifoScheduler),
            Box::new(WorkloadFirstScheduler),
            Box::new(RandomScheduler::new(1)),
        ] {
            let mut order = s.order(&jobs);
            order.sort_unstable();
            assert_eq!(order, (0..6).collect::<Vec<_>>(), "{}", s.name());
        }
    }

    /// Regression for the id/index aliasing bug: on dropout rounds the
    /// surviving global ids are non-contiguous, so an order expressed in
    /// *ids* (the old contract) is not a valid index permutation — the
    /// consumers that index `jobs[u]` / `timings[u]` would panic or
    /// silently account the wrong client.  Every policy must emit dense
    /// job indices regardless of the id labels.
    #[test]
    fn order_is_index_permutation_under_non_contiguous_ids() {
        // Dropout-round shape: clients 7, 2, 11 survived.
        let jobs = vec![
            job(7, 3, 0.3, 1.0, 10.0),
            job(2, 1, 3.0, 1.0, 0.1),
            job(11, 2, 1.0, 1.0, 2.0),
        ];
        // Alg. 2 by position: priorities 10.0, 0.33, 2.0.
        assert_eq!(ProposedScheduler.order(&jobs), vec![0, 2, 1]);
        for mut s in [
            Box::new(ProposedScheduler) as Box<dyn Scheduler>,
            Box::new(FifoScheduler),
            Box::new(WorkloadFirstScheduler),
            Box::new(RandomScheduler::new(4)),
        ] {
            let mut order = s.order(&jobs);
            order.sort_unstable();
            assert_eq!(order, vec![0, 1, 2], "{} must emit job indices", s.name());
        }
        // And the index-walking makespan accepts the order directly.
        let order = ProposedScheduler.order(&jobs);
        assert!(makespan(&jobs, &order) > 0.0);
    }

    #[test]
    fn order_into_reuses_the_buffer() {
        let jobs: Vec<JobInfo> =
            (0..64).map(|i| job(i, 1 + i % 3, 1.0 + i as f64, 1.0, 1.0)).collect();
        let mut s = RandomScheduler::new(9);
        let mut buf = Vec::new();
        s.order_into(&jobs, &mut buf);
        let (cap, ptr) = (buf.capacity(), buf.as_ptr());
        for _ in 0..8 {
            s.order_into(&jobs, &mut buf);
            let _ = makespan(&jobs, &buf);
        }
        assert_eq!(buf.capacity(), cap, "order buffer must not regrow");
        assert_eq!(buf.as_ptr(), ptr, "order buffer must not reallocate");
    }

    #[test]
    fn makespan_matches_hand_computation() {
        // Two clients arriving at 0: first runs [0,2], second [2,5].
        // Completions: 2 + 0.01 + tb0, 5 + 0.01 + tb1.
        let jobs = vec![job(0, 1, 1.0, 2.0, 4.0), job(1, 1, 1.0, 3.0, 0.5)];
        let m = makespan(&jobs, &[0, 1]);
        assert!((m - f64::max(2.0 + 0.01 + 4.0, 5.0 + 0.01 + 0.5)).abs() < 1e-12);
    }

    #[test]
    fn long_backward_first_beats_long_backward_last() {
        // The intuition behind Alg. 2: the slow-backprop client must go
        // first so its backward hides under the others' server time.
        let jobs = vec![job(0, 3, 0.3, 1.0, 10.0), job(1, 1, 3.0, 1.0, 0.1)];
        let slow_first = makespan(&jobs, &[0, 1]);
        let slow_last = makespan(&jobs, &[1, 0]);
        assert!(slow_first < slow_last);
        // And Alg. 2 picks the better one.
        let order = ProposedScheduler.order(&jobs);
        assert_eq!(order, vec![0, 1]);
    }

    #[test]
    fn proposed_matches_brute_force_when_server_times_equal() {
        // With equal server times and equal arrivals, scheduling is the
        // classic "longest tail first" problem where the greedy rule is
        // optimal; N_c/C is the paper's proxy for the tail length.
        let jobs = vec![
            job(0, 1, 0.5, 2.0, 1.0 / 0.5),
            job(1, 2, 1.0, 2.0, 2.0 / 1.0),
            job(2, 3, 0.6, 2.0, 3.0 / 0.6),
            job(3, 1, 2.0, 2.0, 1.0 / 2.0),
        ];
        let order = ProposedScheduler.order(&jobs);
        let (best, best_m) = brute_force_best(&jobs);
        let m = makespan(&jobs, &order);
        assert!(
            (m - best_m).abs() < 1e-9,
            "greedy {m} vs optimal {best_m} ({order:?} vs {best:?})"
        );
    }
}
