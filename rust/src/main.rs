//! `sfl` — CLI launcher for the memory-efficient SFL framework.
//!
//! Subcommands map 1:1 onto the paper's evaluation artifacts:
//!   run      one experiment (scheme × scheduler) with progress
//!   table1   Table I — SL vs SFL vs Ours
//!   fig2     Fig. 2(a)/(b) — metric-vs-time series for 5 schemes
//!   fig2c    Fig. 2(c) — convergence-time comparison
//!   memory   analytic memory accountant report (no numerics)
//!   ablate   scheduler ablation across fleet sizes (analytic)
//!
//! Global flags: --config mini|small, --artifacts DIR, --out DIR,
//! --experiment FILE (key=value format, see configs/paper.exp),
//! --seed N and --dropout P (failure injection without an experiment
//! file).  Fleet-scale scheduling: --fleet N --fleet-preset
//! paper|lognormal|zipf --fleet-seed N --fleet-mfu-sigma S synthesize
//! the client list (`fleet::FleetSpec`); --max-participants N bounds
//! each round's cohort; --state-pool-cap N bounds server-resident
//! per-client training state (lazy materialization + spill, O(active)
//! memory — EXPERIMENTS.md §Memory); --oracle-timing pins the
//! scheduler to the analytic eq. 10–12 timings instead of the online
//! TimingEstimator.
//! Non-stationary environments: --trace
//! none|random_walk|diurnal|markov|replay --trace-seed N
//! --trace-replay FILE drive the `trace::EnvTimeline` (time-varying
//! MFU/link multipliers + availability churn), and --obs-noise-sigma S
//! adds lognormal measurement noise to what the estimator observes.
//! `run` also accepts --jsonl FILE to stream per-round JSON telemetry
//! (a Session observer; env snapshots included when a trace runs).
//! Byzantine robustness (EXPERIMENTS.md §Robustness): --attack
//! none|corrupt|scale|stale|timing-lie --attack-frac P --attack-lambda L
//! inject seeded faults; --agg mean|trimmed|clip (+ --trim K / --clip C),
//! --sanitize [--sanitize-mult M], and --verify-frac P select the
//! defenses; --winsor K clamps estimator observations; --drift-sigma S
//! composes a fleet-wide drift walk onto an active trace;
//! --quarantine-ttl N re-admits quarantined clients on probation after
//! N rounds; --timing-ewma-alpha <A|adaptive> sets the estimator
//! smoothing factor or switches it to the residual-driven adaptive
//! schedule.
//! Asynchronous rounds (EXPERIMENTS.md §Async): --async drives rounds
//! through the discrete-event engine with buffered bounded-staleness
//! aggregation; --staleness-bound S (seconds), --buffer-k K, and
//! --staleness-beta B tune the merge trigger and staleness decay.
//! Compressed uplink (EXPERIMENTS.md §Transport): --compress none|topk
//! --topk-frac F --quant f32|q8|q4 --error-feedback ship each client's
//! LoRA delta as a sparse quantized hash-sealed payload (billed at its
//! encoded size; degenerate settings stay bit-identical to dense).
//! Network faults (EXPERIMENTS.md §Network faults): --net-loss P
//! --net-corrupt P --net-dup P --net-reorder P --net-burst B run every
//! uplink through a seeded lossy channel (Gilbert–Elliott bursts);
//! --retry-max N --retry-base S --rto-mult M bound the server's
//! retransmission protocol, and --tamper-threshold N sets how many
//! consecutive hash mismatches escalate a sender to the committee
//! (1 = the historical immediate flag).  --sanitize-mult adaptive
//! tracks the per-round norm spread with an EWMA instead of a fixed
//! multiplier.  All-zero probabilities construct no channel at all —
//! bit-identical to a channel-free build.

use anyhow::{bail, Result};
use sfl::config::{ExperimentConfig, SchedulerKind, SchemeKind};
use sfl::coordinator::{timing, RunResult, Session};
use sfl::fleet::{FleetPreset, FleetSpec};
use sfl::devices::paper_fleet;
use sfl::model::{memory, ModelDims};
use sfl::runtime::Engine;
use sfl::telemetry::{self, JsonLinesObserver, StdoutObserver};
use sfl::util::args::Args;
use std::path::{Path, PathBuf};

const USAGE: &str = "usage: sfl [--config mini|small] [--artifacts DIR] [--out DIR] \
[--experiment FILE] [--seed N] [--dropout P] [--fleet N] [--fleet-preset paper|lognormal|zipf] \
[--fleet-seed N] [--fleet-mfu-sigma S] [--max-participants N] [--state-pool-cap N] \
[--trace none|random_walk|diurnal|markov|replay] [--trace-seed N] [--trace-replay FILE] \
[--obs-noise-sigma S] [--drift-sigma S] [--attack none|corrupt|scale|stale|timing-lie] \
[--attack-frac P] [--attack-lambda L] [--agg mean|trimmed|clip] [--trim K] [--clip C] \
[--sanitize] [--sanitize-mult M|adaptive] [--verify-frac P] [--winsor K] [--quarantine-ttl N] \
[--timing-ewma-alpha A|adaptive] [--async] [--staleness-bound S] [--buffer-k K] \
[--staleness-beta B] [--compress none|topk] [--topk-frac F] [--quant f32|q8|q4] \
[--error-feedback] [--net-loss P] [--net-corrupt P] [--net-dup P] [--net-reorder P] \
[--net-burst B] [--retry-max N] [--retry-base S] [--rto-mult M] [--tamper-threshold N] \
<run|table1|fig2|fig2c|memory|ablate> [--scheme ours|sl|sfl] \
[--scheduler proposed|fifo|wf|random] [--max-rounds N] [--quiet] [--oracle-timing] \
[--jsonl FILE]";

fn base_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("experiment") {
        Some(path) => ExperimentConfig::from_kv_file(Path::new(path))?,
        None => ExperimentConfig::paper(),
    };
    if let Some(c) = args.get("config") {
        cfg.artifact_config = c.to_string();
    }
    if let Some(d) = args.get("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    // Failure-injection knobs, overridable without an experiment file.
    if let Some(seed) = args.get_parse::<u64>("seed")? {
        cfg.train.seed = seed;
    }
    if let Some(p) = args.get_parse::<f64>("dropout")? {
        cfg.train.dropout_prob = p;
    }
    // Synthetic fleet + fleet-scale scheduling knobs.
    if let Some(n) = args.get_parse::<usize>("fleet")? {
        let preset: FleetPreset = args.get_or("fleet-preset", "paper").parse()?;
        let seed = args.get_parse::<u64>("fleet-seed")?.unwrap_or(cfg.train.seed);
        let mut spec = FleetSpec::new(preset, n, seed);
        if let Some(s) = args.get_parse::<f64>("fleet-mfu-sigma")? {
            spec.mfu_sigma = s;
        }
        cfg.apply_fleet(spec);
    } else if ["fleet-preset", "fleet-seed", "fleet-mfu-sigma"].iter().any(|f| args.has(f)) {
        bail!("--fleet-preset/--fleet-seed/--fleet-mfu-sigma require --fleet N");
    }
    if let Some(m) = args.get_parse::<usize>("max-participants")? {
        cfg.train.max_participants = m;
    }
    // Pooled server-side state residency: keep at most
    // max(N, round cohort) per-client state sets resident; 0 (default)
    // = eager.  Never changes numerics — pooled and eager runs train
    // bit-identical trajectories.
    if let Some(c) = args.get_parse::<usize>("state-pool-cap")? {
        cfg.pool.state_cap = c;
    }
    if args.has("oracle-timing") {
        cfg.train.oracle_timing = true;
    }
    // Environment-trace knobs (non-stationary fleet dynamics).
    if let Some(kind) = args.get("trace") {
        cfg.trace.kind = kind.parse()?;
    } else if ["trace-seed", "trace-replay"].iter().any(|f| args.has(f)) {
        bail!("--trace-seed/--trace-replay require --trace KIND");
    }
    if let Some(s) = args.get_parse::<u64>("trace-seed")? {
        cfg.trace.seed = s;
    }
    if let Some(p) = args.get("trace-replay") {
        cfg.trace.replay_path = p.to_string();
    }
    // Measurement noise is independent of the timeline kind — it also
    // applies to stationary fleets (estimator robustness studies).
    if let Some(s) = args.get_parse::<f64>("obs-noise-sigma")? {
        cfg.trace.obs_noise_sigma = s;
    }
    // Fleet-wide correlated drift rides on an active trace timeline.
    if let Some(s) = args.get_parse::<f64>("drift-sigma")? {
        cfg.trace.drift_sigma = s;
    }
    // Byzantine fault injection + robust-aggregation defenses.
    if let Some(kind) = args.get("attack") {
        cfg.robust.attack = kind.parse()?;
    } else if ["attack-frac", "attack-lambda"].iter().any(|f| args.has(f)) {
        bail!("--attack-frac/--attack-lambda require --attack KIND");
    }
    if let Some(p) = args.get_parse::<f64>("attack-frac")? {
        cfg.robust.attack_frac = p;
    }
    if let Some(l) = args.get_parse::<f64>("attack-lambda")? {
        cfg.robust.attack_lambda = l;
    }
    if let Some(agg) = args.get("agg") {
        cfg.robust.agg = agg.parse()?;
    } else if ["trim", "clip"].iter().any(|f| args.has(f)) {
        bail!("--trim/--clip require --agg trimmed|clip");
    }
    if let Some(k) = args.get_parse::<usize>("trim")? {
        cfg.robust.trim = k;
    }
    if let Some(c) = args.get_parse::<f64>("clip")? {
        cfg.robust.clip = c;
    }
    if args.has("sanitize") {
        cfg.robust.sanitize = true;
    } else if args.has("sanitize-mult") {
        bail!("--sanitize-mult requires --sanitize");
    }
    // A fixed outlier multiplier, or "adaptive" for the EWMA-of-spread
    // schedule (fixed values keep the historical bit-exact path).
    if let Some(m) = args.get("sanitize-mult") {
        if m == "adaptive" {
            cfg.robust.sanitize_adaptive = true;
        } else {
            cfg.robust.sanitize_mult = m
                .parse()
                .map_err(|e| anyhow::anyhow!("--sanitize-mult: {e} (float or `adaptive`)"))?;
        }
    }
    if let Some(p) = args.get_parse::<f64>("verify-frac")? {
        cfg.robust.verify_frac = p;
    }
    if let Some(k) = args.get_parse::<f64>("winsor")? {
        cfg.robust.winsor = k;
    }
    if let Some(n) = args.get_parse::<usize>("quarantine-ttl")? {
        cfg.robust.quarantine_ttl = n;
    }
    // Estimator smoothing: a fixed EWMA factor, or "adaptive" for the
    // residual-driven per-client schedule.
    if let Some(a) = args.get("timing-ewma-alpha") {
        if a == "adaptive" {
            cfg.train.timing_ewma_adaptive = true;
        } else {
            cfg.train.timing_ewma_alpha = a
                .parse()
                .map_err(|e| anyhow::anyhow!("--timing-ewma-alpha: {e} (float or `adaptive`)"))?;
        }
    }
    // Event-driven asynchronous rounds (buffered bounded-staleness).
    if args.has("async") {
        cfg.asynchrony.enabled = true;
    } else if ["staleness-bound", "buffer-k", "staleness-beta"].iter().any(|f| args.has(f)) {
        bail!("--staleness-bound/--buffer-k/--staleness-beta require --async");
    }
    if let Some(s) = args.get_parse::<f64>("staleness-bound")? {
        cfg.asynchrony.staleness_bound = s;
    }
    if let Some(k) = args.get_parse::<usize>("buffer-k")? {
        cfg.asynchrony.buffer_k = k;
    }
    if let Some(b) = args.get_parse::<f64>("staleness-beta")? {
        cfg.asynchrony.staleness_beta = b;
    }
    // Compressed update transport (EXPERIMENTS.md §Transport).
    if let Some(kind) = args.get("compress") {
        cfg.transport.compress = kind.parse()?;
    } else if ["topk-frac", "quant", "error-feedback"].iter().any(|f| args.has(f)) {
        bail!("--topk-frac/--quant/--error-feedback require --compress topk");
    }
    if let Some(f) = args.get_parse::<f64>("topk-frac")? {
        cfg.transport.topk_frac = f;
    }
    if let Some(q) = args.get("quant") {
        cfg.transport.quant = q.parse()?;
    }
    if args.has("error-feedback") {
        cfg.transport.error_feedback = true;
    }
    // Lossy uplink channel + bounded retransmission (EXPERIMENTS.md
    // §Network faults).  All-zero probabilities leave the channel
    // unconstructed; validate() rejects retry knobs without one.
    if let Some(p) = args.get_parse::<f64>("net-loss")? {
        cfg.channel.loss = p;
    }
    if let Some(p) = args.get_parse::<f64>("net-corrupt")? {
        cfg.channel.corrupt = p;
    }
    if let Some(p) = args.get_parse::<f64>("net-dup")? {
        cfg.channel.dup = p;
    }
    if let Some(p) = args.get_parse::<f64>("net-reorder")? {
        cfg.channel.reorder = p;
    }
    if let Some(b) = args.get_parse::<f64>("net-burst")? {
        cfg.channel.burst = b;
    }
    if let Some(n) = args.get_parse::<usize>("retry-max")? {
        cfg.channel.retry_max = n;
    }
    if let Some(s) = args.get_parse::<f64>("retry-base")? {
        cfg.channel.retry_base = s;
    }
    if let Some(m) = args.get_parse::<f64>("rto-mult")? {
        cfg.channel.rto_mult = m;
    }
    if let Some(n) = args.get_parse::<usize>("tamper-threshold")? {
        cfg.channel.tamper_threshold = n;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn run_one(
    engine: &Engine,
    cfg: &ExperimentConfig,
    scheme: SchemeKind,
    scheduler: SchedulerKind,
    max_rounds: Option<usize>,
    quiet: bool,
    jsonl: Option<&Path>,
) -> Result<RunResult> {
    let mut c = cfg.clone();
    c.scheme = scheme;
    c.scheduler = scheduler;
    if let Some(mr) = max_rounds {
        c.train.max_rounds = mr;
    }
    let mut session = Session::new(engine, &c)?;
    if !quiet {
        session.add_observer(Box::new(StdoutObserver));
    }
    if let Some(path) = jsonl {
        session.add_observer(Box::new(JsonLinesObserver::create(path)?));
    }
    session.run_to_convergence()
}

/// The five schemes compared in Fig. 2.
fn fig2_runs(
    engine: &Engine,
    cfg: &ExperimentConfig,
    max_rounds: Option<usize>,
) -> Result<Vec<(&'static str, RunResult)>> {
    let variants: [(&'static str, SchemeKind, SchedulerKind); 5] = [
        ("SL", SchemeKind::Sl, SchedulerKind::Proposed),
        ("SFL", SchemeKind::Sfl, SchedulerKind::Proposed),
        ("FIFO", SchemeKind::Ours, SchedulerKind::Fifo),
        ("WF", SchemeKind::Ours, SchedulerKind::WorkloadFirst),
        ("Ours", SchemeKind::Ours, SchedulerKind::Proposed),
    ];
    let mut runs = Vec::with_capacity(variants.len());
    for (name, scheme, sched) in variants {
        runs.push((name, run_one(engine, cfg, scheme, sched, max_rounds, true, None)?));
    }
    for (n, r) in &runs {
        println!("{}", telemetry::summary(n, r));
    }
    Ok(runs)
}

fn cmd_memory() {
    let dims = ModelDims::bert_base();
    let cuts: Vec<usize> = paper_fleet().iter().map(|(_, k)| *k).collect();
    let ours = memory::ours_server_memory(&dims, &cuts);
    let sfl_m = memory::sfl_server_memory(&dims, &cuts);
    let sl = memory::sl_server_memory(&dims, &cuts);
    println!("Analytic server memory (BERT-base, paper fleet):");
    for (name, b) in [("SL", &sl), ("SFL", &sfl_m), ("Ours", &ours)] {
        println!(
            "  {name:5} total={:8.2} MB  (model={:7.1}  acts={:7.1}  lora={:6.1}  buf={:6.1})",
            b.total_mb(),
            b.model_params / 1048576.0,
            b.activations / 1048576.0,
            b.lora_states / 1048576.0,
            b.buffers / 1048576.0,
        );
    }
    println!(
        "\n  SFL/Ours = {:.2}x (paper: 4.94x, i.e. 79% reduction)\n  Ours/SL  = {:.2}x (paper: 1.10x)",
        sfl_m.total_mb() / ours.total_mb(),
        ours.total_mb() / sl.total_mb()
    );
}

/// Analytic scheduler ablation: per-step makespan across fleet sizes
/// (no numeric execution — pure timing model).
fn cmd_ablate(cfg: &ExperimentConfig) {
    use sfl::coordinator::scheduler::make_scheduler;
    let dims = cfg.timing_dims();
    println!("scheduler ablation (per-step makespan, paper timing model)\n");
    println!("{:>8} {:>12} {:>12} {:>12} {:>12}", "fleet", "proposed", "fifo", "wf", "random");
    for mult in [1usize, 2, 4, 8] {
        let mut clients = Vec::new();
        let mut cuts = Vec::new();
        for _ in 0..mult {
            for (d, k) in paper_fleet() {
                clients.push(sfl::config::ClientConfig {
                    device: d,
                    cut: Some(k),
                    link: sfl::net::Link::paper_default(),
                });
                cuts.push(k);
            }
        }
        let mut row = format!("{:>8}", clients.len());
        for kind in [
            SchedulerKind::Proposed,
            SchedulerKind::Fifo,
            SchedulerKind::WorkloadFirst,
            SchedulerKind::Random,
        ] {
            let mut s = make_scheduler(kind, 7);
            let (t, _) = timing::ours_step(&dims, &clients, &cuts, &cfg.server, s.as_mut());
            row.push_str(&format!(" {t:>12.3}"));
        }
        println!("{row}");
    }
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    let cfg = base_config(&args)?;
    let out = PathBuf::from(args.get_or("out", "results"));
    let artifacts = PathBuf::from(args.get_or("artifacts", &cfg.artifacts_dir));
    let max_rounds = args.get_parse::<usize>("max-rounds")?;

    let sub = match args.subcommand.as_deref() {
        Some(s) => s.to_string(),
        None => {
            println!("{USAGE}");
            return Ok(());
        }
    };

    // Analytics-only subcommands (no artifacts needed).
    match sub.as_str() {
        "memory" => {
            cmd_memory();
            return Ok(());
        }
        "ablate" => {
            cmd_ablate(&cfg);
            return Ok(());
        }
        _ => {}
    }

    let engine = Engine::load(&artifacts, &cfg.artifact_config)?;
    println!(
        "engine: config={} ({} layers, hidden {}), artifacts at {}",
        cfg.artifact_config,
        engine.dims().layers,
        engine.dims().hidden,
        artifacts.display()
    );

    match sub.as_str() {
        "run" => {
            let scheme: SchemeKind = args.get_or("scheme", "ours").parse()?;
            let scheduler: SchedulerKind = args.get_or("scheduler", "proposed").parse()?;
            let jsonl = args.get("jsonl").map(PathBuf::from);
            let r = run_one(
                &engine,
                &cfg,
                scheme,
                scheduler,
                max_rounds,
                args.has("quiet"),
                jsonl.as_deref(),
            )?;
            println!("{}", telemetry::summary("run", &r));
        }
        "table1" => {
            let sl = run_one(
                &engine,
                &cfg,
                SchemeKind::Sl,
                SchedulerKind::Proposed,
                max_rounds,
                false,
                None,
            )?;
            let sfl_r = run_one(
                &engine,
                &cfg,
                SchemeKind::Sfl,
                SchedulerKind::Proposed,
                max_rounds,
                false,
                None,
            )?;
            let ours = run_one(
                &engine,
                &cfg,
                SchemeKind::Ours,
                SchedulerKind::Proposed,
                max_rounds,
                false,
                None,
            )?;
            let rows = [("SL", &sl), ("SFL", &sfl_r), ("Ours", &ours)];
            let table = telemetry::table1(&rows);
            println!("\nTable I (reproduced):\n{table}");
            telemetry::write_result(&out, "table1.md", &table)?;
        }
        "fig2" => {
            let runs = fig2_runs(&engine, &cfg, max_rounds)?;
            let rows: Vec<(&str, &RunResult)> = runs.iter().map(|(n, r)| (*n, r)).collect();
            telemetry::write_result(
                &out,
                "fig2a_accuracy.csv",
                &telemetry::fig2_csv(&rows, "accuracy"),
            )?;
            telemetry::write_result(&out, "fig2b_f1.csv", &telemetry::fig2_csv(&rows, "f1"))?;
        }
        "fig2c" => {
            let runs = fig2_runs(&engine, &cfg, max_rounds)?;
            let rows: Vec<(&str, &RunResult)> = runs.iter().map(|(n, r)| (*n, r)).collect();
            let csv = telemetry::fig2c_csv(&rows);
            println!("\nFig 2(c) convergence times:\n{csv}");
            telemetry::write_result(&out, "fig2c_convergence.csv", &csv)?;
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
    Ok(())
}
