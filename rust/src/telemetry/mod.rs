//! Result emitters: Table I rows, Fig. 2 series (CSV), JSON dumps — and
//! the streaming `RoundObserver` sinks the Session API feeds per round
//! ([`StdoutObserver`] progress lines, [`JsonLinesObserver`] telemetry).

use crate::coordinator::{RoundObserver, RoundReport, RunResult};
use anyhow::Result;
use std::io::Write;
use std::path::Path;

/// Prints the classic per-eval progress line — the observer equivalent
/// of the old `quiet: false` flag.
pub struct StdoutObserver;

impl RoundObserver for StdoutObserver {
    fn on_round(&mut self, r: &RoundReport) {
        if let Some(e) = &r.eval {
            println!(
                "[{:?}/{}] round {:4}  t={:9.1}s  loss={:.4}  acc={:.4}  f1={:.4}",
                r.scheme, r.scheduler, r.round, r.sim_time, r.mean_loss, e.acc, e.f1
            );
        }
    }
}

/// Streams one JSON object per round (and a final summary record) to
/// any writer — machine-readable run telemetry without buffering the
/// whole run.
pub struct JsonLinesObserver<W: Write> {
    out: W,
}

impl JsonLinesObserver<std::io::BufWriter<std::fs::File>> {
    /// Stream to a file (created/truncated).
    pub fn create(path: &Path) -> Result<Self> {
        Ok(Self::new(std::io::BufWriter::new(std::fs::File::create(path)?)))
    }
}

impl<W: Write> JsonLinesObserver<W> {
    pub fn new(out: W) -> Self {
        Self { out }
    }
}

impl<W: Write> RoundObserver for JsonLinesObserver<W> {
    fn on_round(&mut self, r: &RoundReport) {
        let eval = match &r.eval {
            Some(e) => format!(
                ",\"acc\":{:.6},\"f1\":{:.6},\"converged\":{}",
                e.acc, e.f1, e.converged
            ),
            None => String::new(),
        };
        // Per-round environment snapshot (present when a trace runs).
        let env = match &r.env {
            Some(s) => format!(
                ",\"env\":{{\"mfu_mean\":{:.6},\"link_mean\":{:.6},\"available\":{}}}",
                s.mfu_mean, s.link_mean, s.available
            ),
            None => String::new(),
        };
        // State-pool counters (present under pooled residency).
        let pool = match &r.pool {
            Some(p) => format!(
                ",\"pool\":{{\"resident\":{},\"spilled\":{},\"resident_bytes\":{},\
                 \"peak_resident_bytes\":{},\"spill_bytes\":{},\"hits\":{},\"misses\":{},\
                 \"evictions\":{}}}",
                p.resident,
                p.spilled,
                p.resident_bytes,
                p.peak_resident_bytes,
                p.spill_bytes,
                p.hits,
                p.misses,
                p.evictions
            ),
            None => String::new(),
        };
        // Robust-aggregation counters (present when `[robust]` runs).
        let robust = match &r.robust {
            Some(b) => format!(
                ",\"robust\":{{\"flagged\":{},\"quarantined\":{},\"rejected\":{},\
                 \"trim_count\":{}}}",
                b.flagged, b.quarantined, b.rejected, b.trim_count
            ),
            None => String::new(),
        };
        // Buffered-async merge counters (present under `--async`).
        let asynchrony = match &r.asynchrony {
            Some(a) => format!(
                ",\"async\":{{\"buffered\":{},\"merged\":{},\"max_staleness\":{},\
                 \"wall_clock\":{:.6}}}",
                a.buffered, a.merged, a.max_staleness, a.wall_clock
            ),
            None => String::new(),
        };
        // Compressed-transport counters (present when `[transport]` is
        // active): billed bytes per direction, uplink compression
        // ratio, and the error-feedback residual norm.
        let transport = match &r.transport {
            Some(t) => format!(
                ",\"transport\":{{\"up_bytes\":{},\"down_bytes\":{},\"ratio\":{:.6},\
                 \"ef_norm\":{:.6}}}",
                t.up_bytes, t.down_bytes, t.ratio, t.ef_norm
            ),
            None => String::new(),
        };
        // Lossy-channel counters (present when `[channel]` is active).
        let net = match &r.net {
            Some(c) => format!(
                ",\"net\":{{\"sent\":{},\"delivered\":{},\"dropped\":{},\"corrupted\":{},\
                 \"retries\":{},\"gave_up\":{},\"partial_merges\":{}}}",
                c.sent, c.delivered, c.dropped, c.corrupted, c.retries, c.gave_up,
                c.partial_merges
            ),
            None => String::new(),
        };
        let wrote = writeln!(
            self.out,
            "{{\"event\":\"round\",\"scheme\":\"{}\",\"scheduler\":\"{}\",\"round\":{},\
             \"sim_time\":{:.6},\"step_time\":{:.6},\"mean_loss\":{:.6},\
             \"participants\":{}{env}{pool}{robust}{asynchrony}{transport}{net}{eval}}}",
            r.scheme,
            r.scheduler,
            r.round,
            r.sim_time,
            r.step_time,
            r.mean_loss,
            r.participants.len(),
        );
        // Flush per round so `tail -f` monitoring sees lines live and a
        // killed run loses at most the in-flight record.
        if let Err(e) = wrote.and_then(|()| self.out.flush()) {
            eprintln!("jsonl telemetry: write failed: {e}");
        }
    }

    fn on_complete(&mut self, res: &RunResult) {
        let wrote = writeln!(
            self.out,
            "{{\"event\":\"complete\",\"scheme\":\"{}\",\"scheduler\":\"{}\",\"rounds\":{},\
             \"total_time\":{:.6},\"final_acc\":{:.6},\"final_f1\":{:.6},\"memory_mb\":{:.3},\
             \"executions\":{},\"uplink_bytes\":{},\"downlink_bytes\":{}}}",
            res.scheme,
            res.scheduler,
            res.rounds.len(),
            res.total_time(),
            res.final_acc,
            res.final_f1,
            res.memory_mb,
            res.executions,
            res.uplink_bytes,
            res.downlink_bytes,
        );
        if let Err(e) = wrote.and_then(|()| self.out.flush()) {
            eprintln!("jsonl telemetry: write failed: {e}");
        }
    }
}

/// Render Table I ("Performance Comparison of Different Schemes") from a
/// set of runs — same columns as the paper.
pub fn table1(rows: &[(&str, &RunResult)]) -> String {
    let mut out = String::new();
    out.push_str(
        "| Scheme | Memory (MB) | Conv. Round | Conv. Time (s) | Accuracy | F1 |\n",
    );
    out.push_str("|--------|-------------|-------------|----------------|----------|----|\n");
    for (name, r) in rows {
        out.push_str(&format!(
            "| {name} | {:.2} | {} | {:.2} | {:.4} | {:.4} |\n",
            r.memory_mb,
            r.convergence_round
                .map(|x| x.to_string())
                .unwrap_or_else(|| "—".into()),
            r.total_time(),
            r.final_acc,
            r.final_f1,
        ));
    }
    out
}

/// Fig. 2(a)/(b): metric-vs-time series for several runs as CSV
/// (`scheme,round,sim_time,value`).
pub fn fig2_csv(rows: &[(&str, &RunResult)], metric: &str) -> String {
    let mut out = String::from("scheme,round,sim_time_s,value\n");
    for (name, r) in rows {
        let series = if metric == "f1" { &r.f1 } else { &r.acc };
        for p in &series.points {
            out.push_str(&format!("{name},{},{:.3},{:.5}\n", p.round, p.sim_time, p.value));
        }
    }
    out
}

/// Fig. 2(c): convergence-time bars (`scheme,convergence_time_s`).
pub fn fig2c_csv(rows: &[(&str, &RunResult)]) -> String {
    let mut out = String::from("scheme,convergence_time_s\n");
    for (name, r) in rows {
        out.push_str(&format!("{name},{:.2}\n", r.total_time()));
    }
    out
}

/// Human-readable run summary (per-run diagnostics).
pub fn summary(name: &str, r: &RunResult) -> String {
    format!(
        "{name}: scheme={:?} sched={} rounds={} conv_round={:?} time={:.1}s \
         acc={:.4} f1={:.4} mem={:.1}MB switches={} execs={} up={}B down={}B wall={:.1}s",
        r.scheme,
        r.scheduler,
        r.rounds.len(),
        r.convergence_round,
        r.total_time(),
        r.final_acc,
        r.final_f1,
        r.memory_mb,
        r.adapter_switches,
        r.executions,
        r.uplink_bytes,
        r.downlink_bytes,
        r.wall_secs,
    )
}

/// Write a string artifact under `results/`, creating the directory.
pub fn write_result(dir: &Path, name: &str, contents: &str) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    let mut fh = std::fs::File::create(&path)?;
    fh.write_all(contents.as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SchedulerKind, SchemeKind};
    use crate::coordinator::SchedulerLabel;
    use crate::metrics::MetricSeries;
    use crate::model::memory::MemoryBreakdown;

    fn fake_run() -> RunResult {
        let mut acc = MetricSeries::default();
        acc.push(1, 10.0, 0.5);
        acc.push(2, 20.0, 0.8);
        RunResult {
            scheme: SchemeKind::Ours,
            scheduler: SchedulerLabel::Scheduled(SchedulerKind::Proposed),
            rounds: vec![],
            acc,
            f1: MetricSeries::default(),
            convergence_round: Some(2),
            convergence_time: Some(20.0),
            final_acc: 0.8,
            final_f1: 0.79,
            memory_mb: 1482.6,
            memory: MemoryBreakdown::default(),
            adapter_switches: 12,
            executions: 100,
            uplink_bytes: 1,
            downlink_bytes: 2,
            wall_secs: 3.0,
        }
    }

    #[test]
    fn table1_has_all_rows_and_columns() {
        let r = fake_run();
        let t = table1(&[("Ours", &r)]);
        assert!(t.contains("| Ours | 1482.60 | 2 | 20.00 | 0.8000 | 0.7900 |"));
    }

    #[test]
    fn fig2_csv_emits_series_points() {
        let r = fake_run();
        let csv = fig2_csv(&[("ours", &r)], "accuracy");
        assert!(csv.lines().count() == 3);
        assert!(csv.contains("ours,2,20.000,0.80000"));
    }

    #[test]
    fn fig2c_uses_total_time() {
        let r = fake_run();
        let csv = fig2c_csv(&[("ours", &r)]);
        assert!(csv.contains("ours,20.00"));
    }

    #[test]
    fn scheduler_label_display_matches_scheduler_names() {
        assert_eq!(SchedulerLabel::Sequential.to_string(), "sequential");
        assert_eq!(
            SchedulerLabel::Scheduled(SchedulerKind::WorkloadFirst).to_string(),
            "workload_first"
        );
        let r = fake_run();
        assert!(summary("x", &r).contains("sched=proposed"));
    }

    #[test]
    fn json_lines_observer_emits_round_and_summary_records() {
        use crate::coordinator::{EvalPoint, RoundReport};
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut obs = JsonLinesObserver::new(&mut buf);
            obs.on_round(&RoundReport {
                scheme: SchemeKind::Ours,
                scheduler: SchedulerLabel::Scheduled(SchedulerKind::Proposed),
                round: 3,
                sim_time: 12.5,
                step_time: 3.125,
                mean_loss: 1.25,
                participants: vec![0, 1, 2],
                env: None,
                pool: None,
                robust: None,
                asynchrony: None,
                transport: None,
                net: None,
                eval: Some(EvalPoint { acc: 0.5, f1: 0.4, converged: false }),
            });
            let r = fake_run();
            obs.on_complete(&r);
        }
        let s = String::from_utf8(buf).unwrap();
        assert_eq!(s.lines().count(), 2);
        assert!(s.contains("\"event\":\"round\""));
        assert!(s.contains("\"step_time\":3.125000"));
        assert!(s.contains("\"participants\":3"));
        assert!(!s.contains("\"env\""), "static run must not emit an env snapshot");
        assert!(!s.contains("\"pool\""), "eager run must not emit pool counters");
        assert!(s.contains("\"acc\":0.500000"));
        assert!(s.contains("\"event\":\"complete\""));
    }

    #[test]
    fn json_lines_observer_emits_pool_counters_when_pooled() {
        use crate::coordinator::RoundReport;
        use crate::pool::PoolStats;
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut obs = JsonLinesObserver::new(&mut buf);
            obs.on_round(&RoundReport {
                scheme: SchemeKind::Ours,
                scheduler: SchedulerLabel::Scheduled(SchedulerKind::Proposed),
                round: 2,
                sim_time: 4.0,
                step_time: 2.0,
                mean_loss: 0.75,
                participants: vec![3, 9],
                env: None,
                pool: Some(PoolStats {
                    hits: 10,
                    misses: 4,
                    evictions: 2,
                    resident: 2,
                    spilled: 2,
                    resident_bytes: 4096,
                    peak_resident_bytes: 8192,
                    spill_bytes: 1024,
                }),
                robust: None,
                asynchrony: None,
                transport: None,
                net: None,
                eval: None,
            });
        }
        let s = String::from_utf8(buf).unwrap();
        assert!(s.contains("\"pool\":{\"resident\":2,\"spilled\":2"), "{s}");
        assert!(s.contains("\"peak_resident_bytes\":8192"), "{s}");
        assert!(s.contains("\"evictions\":2}"), "{s}");
    }

    #[test]
    fn json_lines_observer_emits_env_snapshot_when_tracing() {
        use crate::coordinator::RoundReport;
        use crate::trace::EnvSnapshot;
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut obs = JsonLinesObserver::new(&mut buf);
            obs.on_round(&RoundReport {
                scheme: SchemeKind::Ours,
                scheduler: SchedulerLabel::Scheduled(SchedulerKind::Proposed),
                round: 1,
                sim_time: 2.0,
                step_time: 1.0,
                mean_loss: 0.5,
                participants: vec![0, 2],
                env: Some(EnvSnapshot { mfu_mean: 0.9125, link_mean: 1.05, available: 2 }),
                pool: None,
                robust: None,
                asynchrony: None,
                transport: None,
                net: None,
                eval: None,
            });
        }
        let s = String::from_utf8(buf).unwrap();
        assert!(s.contains("\"env\":{\"mfu_mean\":0.912500"), "{s}");
        assert!(s.contains("\"link_mean\":1.050000"), "{s}");
        assert!(s.contains("\"available\":2"), "{s}");
    }

    #[test]
    fn json_lines_observer_emits_robust_counters_when_active() {
        use crate::coordinator::RoundReport;
        use crate::faults::RobustStats;
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut obs = JsonLinesObserver::new(&mut buf);
            obs.on_round(&RoundReport {
                scheme: SchemeKind::Ours,
                scheduler: SchedulerLabel::Scheduled(SchedulerKind::Proposed),
                round: 4,
                sim_time: 8.0,
                step_time: 2.0,
                mean_loss: 0.6,
                participants: vec![0, 1, 4],
                env: None,
                pool: None,
                robust: Some(RobustStats {
                    flagged: 1,
                    quarantined: 2,
                    rejected: 3,
                    trim_count: 4,
                }),
                asynchrony: None,
                transport: None,
                net: None,
                eval: None,
            });
        }
        let s = String::from_utf8(buf).unwrap();
        assert!(s.contains("\"robust\":{\"flagged\":1,\"quarantined\":2"), "{s}");
        assert!(s.contains("\"rejected\":3,\"trim_count\":4}"), "{s}");
    }

    #[test]
    fn json_lines_observer_emits_async_counters_when_async() {
        use crate::coordinator::RoundReport;
        use crate::events::AsyncStats;
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut obs = JsonLinesObserver::new(&mut buf);
            obs.on_round(&RoundReport {
                scheme: SchemeKind::Ours,
                scheduler: SchedulerLabel::Scheduled(SchedulerKind::Proposed),
                round: 5,
                sim_time: 41.5,
                step_time: 2.0,
                mean_loss: 0.45,
                participants: vec![1, 6, 7],
                env: None,
                pool: None,
                robust: None,
                asynchrony: Some(AsyncStats {
                    buffered: 3,
                    merged: 3,
                    max_staleness: 2,
                    wall_clock: 41.25,
                }),
                transport: None,
                net: None,
                eval: None,
            });
        }
        let s = String::from_utf8(buf).unwrap();
        assert!(s.contains("\"async\":{\"buffered\":3,\"merged\":3"), "{s}");
        assert!(s.contains("\"max_staleness\":2,\"wall_clock\":41.250000}"), "{s}");
    }

    #[test]
    fn json_lines_observer_emits_transport_counters_when_active() {
        use crate::coordinator::RoundReport;
        use crate::transport::TransportStats;
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut obs = JsonLinesObserver::new(&mut buf);
            obs.on_round(&RoundReport {
                scheme: SchemeKind::Ours,
                scheduler: SchedulerLabel::Scheduled(SchedulerKind::Proposed),
                round: 6,
                sim_time: 50.0,
                step_time: 2.0,
                mean_loss: 0.4,
                participants: vec![0, 1],
                env: None,
                pool: None,
                robust: None,
                asynchrony: None,
                transport: Some(TransportStats {
                    up_bytes: 1234,
                    down_bytes: 65536,
                    ratio: 12.5,
                    ef_norm: 0.03125,
                }),
                net: None,
                eval: None,
            });
        }
        let s = String::from_utf8(buf).unwrap();
        assert!(s.contains("\"transport\":{\"up_bytes\":1234,\"down_bytes\":65536"), "{s}");
        assert!(s.contains("\"ratio\":12.500000,\"ef_norm\":0.031250}"), "{s}");
    }

    #[test]
    fn json_lines_observer_emits_net_counters_when_channel_active() {
        use crate::channel::NetStats;
        use crate::coordinator::RoundReport;
        let mut buf: Vec<u8> = Vec::new();
        {
            let mut obs = JsonLinesObserver::new(&mut buf);
            obs.on_round(&RoundReport {
                scheme: SchemeKind::Ours,
                scheduler: SchedulerLabel::Scheduled(SchedulerKind::Proposed),
                round: 7,
                sim_time: 60.0,
                step_time: 2.0,
                mean_loss: 0.35,
                participants: vec![0, 3],
                env: None,
                pool: None,
                robust: None,
                asynchrony: None,
                transport: None,
                net: Some(NetStats {
                    sent: 12,
                    delivered: 10,
                    dropped: 2,
                    corrupted: 1,
                    retries: 3,
                    gave_up: 1,
                    partial_merges: 1,
                }),
                eval: None,
            });
        }
        let s = String::from_utf8(buf).unwrap();
        assert!(s.contains("\"net\":{\"sent\":12,\"delivered\":10,\"dropped\":2"), "{s}");
        assert!(s.contains("\"corrupted\":1,\"retries\":3,\"gave_up\":1"), "{s}");
        assert!(s.contains("\"partial_merges\":1}"), "{s}");
    }
}
