//! Wireless link model between clients and the edge server.
//!
//! The paper sets every client↔server link to 100 Mbps (§V-A); we model
//! per-link rate + latency so heterogeneous-network ablations are a
//! config change, not a code change.


/// A (client ↔ server) wireless link.
#[derive(Debug, Clone, PartialEq)]
pub struct Link {
    /// Data rate in megabits per second.
    pub rate_mbps: f64,
    /// One-way latency in milliseconds.
    pub latency_ms: f64,
}

impl Link {
    pub fn paper_default() -> Self {
        Self { rate_mbps: 100.0, latency_ms: 5.0 }
    }

    pub fn new(rate_mbps: f64, latency_ms: f64) -> Self {
        Self { rate_mbps, latency_ms }
    }

    /// Wi-Fi tier — the paper's §V-A setting (100 Mbps, 5 ms).
    pub fn wifi() -> Self {
        Self::paper_default()
    }

    /// Cellular LTE tier — mid-band uplink typical of mobile clients.
    pub fn lte() -> Self {
        Self { rate_mbps: 35.0, latency_ms: 30.0 }
    }

    /// 5G tier — high rate, moderate latency.
    pub fn five_g() -> Self {
        Self { rate_mbps: 300.0, latency_ms: 10.0 }
    }

    /// This link with its rate scaled by `factor` (latency unchanged) —
    /// the fleet samplers' per-client rate jitter around a tier.
    pub fn scaled(&self, factor: f64) -> Self {
        Self { rate_mbps: self.rate_mbps * factor, latency_ms: self.latency_ms }
    }

    /// Seconds to move `bytes` over this link (one way).
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency_ms / 1e3 + (bytes as f64 * 8.0) / (self.rate_mbps * 1e6)
    }
}

/// Wire-protocol message kinds with their payload sizes — used by both the
/// timing model and telemetry byte counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Message {
    /// Client → server: activations + labels + split-layer index (step 1b).
    Activations { bytes: usize },
    /// Server → client: activations' gradients (step 1e).
    ActivationGrads { bytes: usize },
    /// Client → server: client-side LoRA adapters (aggregation step 2a).
    LoraUpload { bytes: usize },
    /// Server → client: aggregated client-side LoRA adapters (step 2c).
    LoraDownload { bytes: usize },
}

impl Message {
    pub fn bytes(&self) -> usize {
        match *self {
            Message::Activations { bytes }
            | Message::ActivationGrads { bytes }
            | Message::LoraUpload { bytes }
            | Message::LoraDownload { bytes } => bytes,
        }
    }
}

/// Cumulative traffic accounting per direction.
#[derive(Debug, Clone, Default)]
pub struct TrafficMeter {
    pub uplink_bytes: u64,
    pub downlink_bytes: u64,
    pub messages: u64,
}

impl TrafficMeter {
    pub fn record(&mut self, msg: &Message) {
        self.messages += 1;
        match msg {
            Message::Activations { bytes } | Message::LoraUpload { bytes } => {
                self.uplink_bytes += *bytes as u64;
            }
            Message::ActivationGrads { bytes } | Message::LoraDownload { bytes } => {
                self.downlink_bytes += *bytes as u64;
            }
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.uplink_bytes + self.downlink_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_at_paper_rate() {
        let l = Link::paper_default();
        // 6.29MB activations over 100 Mbps ≈ 0.528s (+5ms latency).
        let t = l.transfer_time(16 * 128 * 768 * 4);
        assert!((t - (0.005 + 6291456.0 * 8.0 / 100e6)).abs() < 1e-9);
    }

    #[test]
    fn faster_link_is_faster() {
        let a = Link::new(50.0, 5.0);
        let b = Link::new(200.0, 5.0);
        assert!(a.transfer_time(1_000_000) > b.transfer_time(1_000_000));
    }

    #[test]
    fn latency_dominates_small_messages() {
        let l = Link::new(100.0, 50.0);
        let t = l.transfer_time(100);
        assert!(t > 0.05 && t < 0.051);
    }

    #[test]
    fn link_tiers_rank_by_rate_and_scaling_preserves_latency() {
        assert!(Link::five_g().rate_mbps > Link::wifi().rate_mbps);
        assert!(Link::wifi().rate_mbps > Link::lte().rate_mbps);
        assert!(Link::lte().latency_ms > Link::wifi().latency_ms);
        let l = Link::wifi().scaled(0.5);
        assert!((l.rate_mbps - 50.0).abs() < 1e-12);
        assert!((l.latency_ms - Link::wifi().latency_ms).abs() < 1e-12);
        assert!(l.transfer_time(1_000_000) > Link::wifi().transfer_time(1_000_000));
    }

    #[test]
    fn traffic_meter_directions() {
        let mut m = TrafficMeter::default();
        m.record(&Message::Activations { bytes: 10 });
        m.record(&Message::ActivationGrads { bytes: 20 });
        m.record(&Message::LoraUpload { bytes: 5 });
        m.record(&Message::LoraDownload { bytes: 7 });
        assert_eq!(m.uplink_bytes, 15);
        assert_eq!(m.downlink_bytes, 27);
        assert_eq!(m.messages, 4);
        assert_eq!(m.total_bytes(), 42);
    }
}
